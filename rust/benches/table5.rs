//! Regenerates paper **Table 5**: single-thread ECM model components, ECM
//! and Roofline in-memory predictions, and the Benchmark measurement
//! (virtual testbed) for all five kernels on SNB and HSW — with the
//! published values and deltas printed beside ours.

use kerncraft::cache::CachePredictor;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::{reference, EcmModel, RooflineModel};
use kerncraft::sim::VirtualTestbed;
use std::collections::HashMap;

fn main() {
    println!("=== Table 5: single-thread predictions vs paper ===");
    println!(
        "{:<11} {:<4} | {:<38} | {:>8} {:>8} | {:>8} {:>8} | {:>8} {:>8}",
        "kernel", "arch", "ECM model {OL ‖ nOL | L1L2 | L2L3 | L3Mem}",
        "ECM", "paper", "Roof", "paper", "Bench", "paper"
    );
    println!("{}", "-".repeat(130));

    let mut worst_rel = 0.0f64;
    for row in reference::TABLE5 {
        let machine = MachineModel::builtin(row.arch).unwrap();
        let src = reference::kernel_source(row.kernel).unwrap();
        let consts: HashMap<String, i64> =
            row.constants.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        let program = parse(src).unwrap();
        let analysis = KernelAnalysis::from_program(&program, &consts).unwrap();
        let pm =
            PortModel::analyze(&analysis, &machine, &CodegenPolicy::for_machine(&machine))
                .unwrap();
        let traffic = CachePredictor::new(&machine).predict(&analysis).unwrap();
        let ecm = EcmModel::build(&pm, &traffic, &machine).unwrap();
        let roofline =
            RooflineModel::build(&analysis, &traffic, &machine, Some(&pm)).unwrap();

        // virtual-testbed "measurement" with a bounded trace
        let mut tb = VirtualTestbed::new(&machine);
        tb.max_iterations = 2_000_000;
        let bench = tb.run(&analysis).unwrap();

        let ours = [
            ecm.t_ol,
            ecm.t_nol,
            ecm.contributions[0].cycles,
            ecm.contributions[1].cycles,
            ecm.contributions[2].cycles,
        ];
        let model_str = format!(
            "{{{:.1} ‖ {:.1} | {:.1} | {:.1} | {:.1}}}",
            ours[0], ours[1], ours[2], ours[3], ours[4]
        );
        println!(
            "{:<11} {:<4} | {:<38} | {:>8.1} {:>8.1} | {:>8.1} {:>8.1} | {:>8.1} {:>8.1}",
            row.kernel,
            row.arch,
            model_str,
            ecm.t_mem(),
            row.ecm_mem,
            roofline.prediction(),
            row.roofline,
            bench.cy_per_cl,
            row.bench,
        );
        let rel = (ecm.t_mem() - row.ecm_mem).abs() / row.ecm_mem;
        worst_rel = worst_rel.max(rel);
    }
    println!("{}", "-".repeat(130));
    println!("worst ECM_mem relative deviation from the paper: {:.1}%", worst_rel * 100.0);
    assert!(worst_rel < 0.15, "Table 5 reproduction drifted beyond 15%");
    println!("table5 bench OK");
}
