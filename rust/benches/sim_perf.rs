//! Virtual-testbed throughput benchmark: runs the fast and reference
//! simulation engines over the same kernels and records wall time,
//! logical touches/second, and end-to-end Validate wall time into
//! BENCH_sim.json.
//!
//! Usage:
//!
//! ```text
//! cargo bench --bench sim_perf                           # pinned trajectory: 2d-5pt and 3d-7pt, small and large
//! cargo bench --bench sim_perf -- --smoke --out /tmp/x.json   # CI: tiny sizes, schema-identical
//! ```
//!
//! Each configuration is simulated by both engines from an identical
//! pre-built analysis (so only the trace replay is timed), then once
//! more through a fresh `Session` in Validate mode (so the recorded
//! `validate_wall_s` is what a CLI/serve user observes, parse and
//! in-core analysis included). The output schema (checked by CI against
//! both the smoke output and the committed BENCH_sim.json) is:
//!
//! ```text
//! {"bench": "sim_perf", "schema": 1, "runs": [
//!   {"kernel": "...", "size": "...", "constants": "...", "iterations": I,
//!    "truncated": B,
//!    "fast": {"wall_s": X, "touches": T, "touches_per_s": Y,
//!             "cy_per_cl": Z, "validate_wall_s": V, "extrapolated": B},
//!    "reference": {...}, "speedup": S, "validate_speedup": S2}, ...]}
//! ```
//!
//! `speedup` is reference wall over fast wall for the bare trace replay;
//! `validate_speedup` is the same ratio for the end-to-end Validate
//! evaluations.

use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::reference;
use kerncraft::session::{AnalysisRequest, KernelSpec, ModelKind, Session};
use kerncraft::sim::{SimEngine, SimResult, VirtualTestbed};
use std::collections::HashMap;
use std::time::Instant;

struct Args {
    out: String,
    smoke: bool,
}

fn parse_args() -> Args {
    let mut args = Args { out: "BENCH_sim.json".to_string(), smoke: false };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--smoke" => args.smoke = true,
            "--out" => {
                args.out = it.next().unwrap_or_else(|| die("--out needs a value"));
            }
            "--bench" => {} // passed through by `cargo bench`
            other => die(&format!("unknown argument: {other}")),
        }
    }
    args
}

fn die(msg: &str) -> ! {
    eprintln!("sim_perf: {msg}");
    std::process::exit(1);
}

struct Config {
    kernel: &'static str,
    size: &'static str,
    consts: Vec<(&'static str, i64)>,
}

/// The pinned trajectory: the Table 5 Jacobi plus the 3D 7-point
/// stencil, each at an L1-resident and a memory-bound size. The large
/// 3D-7pt working set (~34 MB for two arrays) exceeds the SNB L3
/// (20 MB), which is where trace compression pays the most.
fn configs(smoke: bool) -> Vec<Config> {
    if smoke {
        return vec![
            Config { kernel: "2D-5pt", size: "smoke", consts: vec![("N", 300), ("M", 120)] },
            Config {
                kernel: "3D-7pt",
                size: "smoke",
                consts: vec![("M", 20), ("N", 40), ("P", 40)],
            },
        ];
    }
    vec![
        Config { kernel: "2D-5pt", size: "small", consts: vec![("N", 600), ("M", 400)] },
        Config { kernel: "2D-5pt", size: "large", consts: vec![("N", 6000), ("M", 6000)] },
        Config {
            kernel: "3D-7pt",
            size: "small",
            consts: vec![("M", 60), ("N", 60), ("P", 60)],
        },
        Config {
            kernel: "3D-7pt",
            size: "large",
            consts: vec![("M", 50), ("N", 1200), ("P", 1200)],
        },
    ]
}

fn consts_map(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

struct EngineRun {
    wall_s: f64,
    validate_wall_s: f64,
    sim: SimResult,
}

/// Time the bare trace replay and an end-to-end Validate evaluation.
fn run_engine(
    machine: &MachineModel,
    analysis: &KernelAnalysis,
    cfg: &Config,
    engine: SimEngine,
) -> EngineRun {
    let tb = VirtualTestbed::new(machine).with_engine(engine);
    let t0 = Instant::now();
    let sim = tb.run(analysis).unwrap_or_else(|e| die(&format!("{}: {e}", cfg.kernel)));
    let wall_s = t0.elapsed().as_secs_f64();

    let src = reference::kernel_source(cfg.kernel)
        .unwrap_or_else(|| die(&format!("unknown kernel {}", cfg.kernel)));
    let mut req = AnalysisRequest::new(
        KernelSpec::source(format!("{}-{}", cfg.kernel, cfg.size), src.to_string()),
        "SNB",
    )
    .with_model(ModelKind::Validate)
    .with_sim_engine(engine);
    for (k, v) in &cfg.consts {
        req = req.with_constant(*k, *v);
    }
    let session = Session::new(); // fresh: no memo carry-over between engines
    let t1 = Instant::now();
    session.evaluate(&req).unwrap_or_else(|e| die(&format!("{} validate: {e}", cfg.kernel)));
    let validate_wall_s = t1.elapsed().as_secs_f64();
    EngineRun { wall_s, validate_wall_s, sim }
}

fn engine_json(r: &EngineRun) -> String {
    format!(
        "{{\"wall_s\": {:.4}, \"touches\": {}, \"touches_per_s\": {:.0}, \"cy_per_cl\": {:.3}, \"validate_wall_s\": {:.4}, \"extrapolated\": {}}}",
        r.wall_s,
        r.sim.touches,
        r.sim.touches as f64 / r.wall_s.max(1e-9),
        r.sim.cy_per_cl,
        r.validate_wall_s,
        r.sim.extrapolated
    )
}

fn main() {
    let args = parse_args();
    let machine = MachineModel::snb();
    let mut rows = Vec::new();
    for cfg in configs(args.smoke) {
        let src = reference::kernel_source(cfg.kernel)
            .unwrap_or_else(|| die(&format!("unknown kernel {}", cfg.kernel)));
        let program = parse(src).unwrap_or_else(|e| die(&format!("{}: {e}", cfg.kernel)));
        let analysis = KernelAnalysis::from_program(&program, &consts_map(&cfg.consts))
            .unwrap_or_else(|e| die(&format!("{}: {e}", cfg.kernel)));
        let consts_desc: Vec<String> =
            cfg.consts.iter().map(|(k, v)| format!("{k}={v}")).collect();
        eprintln!("sim_perf: {} {} ({}) ...", cfg.kernel, cfg.size, consts_desc.join(","));

        let fast = run_engine(&machine, &analysis, &cfg, SimEngine::Fast);
        let refr = run_engine(&machine, &analysis, &cfg, SimEngine::Reference);
        // per-level stats must agree or the comparison is meaningless
        // (cy/CL can differ by the documented skip-ahead bound)
        if fast.sim.iterations != refr.sim.iterations {
            die(&format!("{}: engines disagree on iteration count", cfg.kernel));
        }
        let speedup = refr.wall_s / fast.wall_s.max(1e-9);
        let validate_speedup = refr.validate_wall_s / fast.validate_wall_s.max(1e-9);
        eprintln!(
            "sim_perf: {} {}: fast {:.3}s ({:.1}M touches/s), reference {:.3}s, speedup {:.1}x",
            cfg.kernel,
            cfg.size,
            fast.wall_s,
            fast.sim.touches as f64 / fast.wall_s.max(1e-9) / 1e6,
            refr.wall_s,
            speedup
        );
        rows.push(format!(
            "    {{\"kernel\": \"{}\", \"size\": \"{}\", \"constants\": \"{}\", \"iterations\": {}, \"truncated\": {}, \"fast\": {}, \"reference\": {}, \"speedup\": {:.2}, \"validate_speedup\": {:.2}}}",
            cfg.kernel,
            cfg.size,
            consts_desc.join(","),
            fast.sim.iterations,
            fast.sim.truncated,
            engine_json(&fast),
            engine_json(&refr),
            speedup,
            validate_speedup
        ));
    }

    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"sim_perf\",\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str("  \"machine\": \"SNB\",\n");
    if args.smoke {
        out.push_str("  \"note\": \"smoke run (CI): tiny sizes, schema-identical\",\n");
    }
    out.push_str("  \"runs\": [\n");
    out.push_str(&rows.join(",\n"));
    out.push_str("\n  ]\n}\n");
    if let Err(e) = std::fs::write(&args.out, &out) {
        die(&format!("writing {}: {e}", args.out));
    }
    eprintln!("sim_perf: wrote {}", args.out);
}
