//! Regenerates paper **Figure 3**: single-core ECM contributions for the
//! 3D long-range stencil versus the inner/middle dimension N on SNB,
//! together with the layer-condition bands shown below the paper's plot.
//!
//! Since the sweep PR this bench runs on [`kerncraft::sweep::SweepEngine`]
//! — the whole grid is evaluated in parallel with stage memoization, and
//! the Auto cache predictor answers decisive levels analytically (the
//! `lc/walk` column shows how many levels skipped the backward walk).

use kerncraft::cache::CachePredictorKind;
use kerncraft::models::reference;
use kerncraft::session::ModelKind;
use kerncraft::sweep::{SweepEngine, SweepJob};
use std::sync::Arc;

fn main() {
    let src: Arc<str> = Arc::from(reference::KERNEL_LONG_RANGE);
    // log-spaced N values covering the paper's 10..4000 range; M is kept
    // equal to N as in the paper (clamped so the halo fits)
    let ns: Vec<i64> = vec![
        10, 14, 20, 28, 40, 56, 80, 100, 140, 200, 280, 400, 560, 800, 1100, 1600, 2200, 3000,
    ];
    let jobs: Vec<SweepJob> = ns
        .iter()
        .map(|&n| SweepJob {
            label: "long-range".into(),
            source: src.clone(),
            machine: "SNB".into(),
            cores: 1,
            constants: [("N".to_string(), n), ("M".to_string(), n.max(12))]
                .into_iter()
                .collect(),
            predictor: CachePredictorKind::Auto,
            model: ModelKind::Ecm,
        })
        .collect();

    let t0 = std::time::Instant::now();
    let out = SweepEngine::new().run(&jobs).expect("sweep failed");
    let dt = t0.elapsed();

    println!("=== Fig 3: long-range stencil ECM contributions vs N (SNB) ===");
    println!(
        "{:>6} | {:>7} {:>7} | {:>7} {:>7} {:>7} | {:>8} | lc/walk | layer conditions (dim@level)",
        "N", "T_OL", "T_nOL", "L1L2", "L2L3", "L3Mem", "ECM_Mem"
    );
    for row in &out.rows {
        let n = row.constants["N"];
        println!(
            "{:>6} | {:>7.1} {:>7.1} | {:>7.1} {:>7.1} {:>7.1} | {:>8.1} | {:>3}/{:<3} | {}",
            n,
            row.t_ol,
            row.t_nol,
            row.links[0].2,
            row.links[1].2,
            row.links[2].2,
            row.t_ecm_mem,
            row.lc_fast_levels,
            row.walk_levels,
            row.lc_breakpoints.join(" ")
        );
    }
    println!(
        "(Table 5 uses the N=100 row; paper reference {{57 ‖ 53 | 24 | 24 | 17.0}})"
    );
    println!(
        "{} points in {:.1} ms on {} threads; memo: {} program hits, {} incore hits",
        out.rows.len(),
        dt.as_secs_f64() * 1e3,
        out.threads_used,
        out.stats.program_hits,
        out.stats.incore_hits
    );
    println!("fig3 bench OK");
}
