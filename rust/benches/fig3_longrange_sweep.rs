//! Regenerates paper **Figure 3**: single-core ECM contributions for the
//! 3D long-range stencil versus the inner/middle dimension N on SNB,
//! together with the layer-condition bands shown below the paper's plot.

use kerncraft::cache::CachePredictor;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::{reference, EcmModel};
use std::collections::HashMap;

fn main() {
    let machine = MachineModel::snb();
    let src = reference::KERNEL_LONG_RANGE;
    let program = parse(src).unwrap();
    let policy = CodegenPolicy::for_machine(&machine);

    println!("=== Fig 3: long-range stencil ECM contributions vs N (SNB) ===");
    println!(
        "{:>6} | {:>7} {:>7} | {:>7} {:>7} {:>7} | {:>8} | layer conditions (dim@level)",
        "N", "T_OL", "T_nOL", "L1L2", "L2L3", "L3Mem", "ECM_Mem"
    );
    // log-spaced N values covering the paper's 10..4000 range; M is kept
    // equal to N as in the paper
    let ns: Vec<i64> = vec![
        10, 14, 20, 28, 40, 56, 80, 100, 140, 200, 280, 400, 560, 800, 1100, 1600, 2200, 3000,
    ];
    for &n in &ns {
        let consts: HashMap<String, i64> =
            [("N".to_string(), n), ("M".to_string(), n.max(12))].into_iter().collect();
        let analysis = match KernelAnalysis::from_program(&program, &consts) {
            Ok(a) => a,
            Err(_) => continue, // too small for the halo
        };
        if analysis.loops.iter().any(|l| l.trip() <= 0) {
            continue;
        }
        let pm = PortModel::analyze(&analysis, &machine, &policy).unwrap();
        let traffic = CachePredictor::new(&machine).predict(&analysis).unwrap();
        let ecm = EcmModel::build(&pm, &traffic, &machine).unwrap();

        // layer-condition band summary: innermost level where each dim's
        // condition holds
        let mut bands = Vec::new();
        for dim in 0..analysis.loops.len() {
            let holds: Vec<&str> = traffic
                .layer_conditions
                .iter()
                .filter(|lc| lc.dim_index == dim && lc.satisfied)
                .map(|lc| lc.level.as_str())
                .collect();
            bands.push(format!(
                "{}@{}",
                analysis.loops[dim].index,
                holds.first().copied().unwrap_or("MEM")
            ));
        }
        println!(
            "{:>6} | {:>7.1} {:>7.1} | {:>7.1} {:>7.1} {:>7.1} | {:>8.1} | {}",
            n,
            ecm.t_ol,
            ecm.t_nol,
            ecm.contributions[0].cycles,
            ecm.contributions[1].cycles,
            ecm.contributions[2].cycles,
            ecm.t_mem(),
            bands.join(" ")
        );
    }
    // the paper's Table 5 entry is the N=100 point
    println!("(Table 5 uses the N=100 row; paper reference {{57 ‖ 53 | 24 | 24 | 17.0}})");
    println!("fig3 bench OK");
}
