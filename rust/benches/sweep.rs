//! Sweep-engine benchmark: a 64-point Fig.-3-style grid (2D-5pt Jacobi,
//! 16 sizes × 2 machines × 2 core counts) evaluated three ways:
//!
//! 1. **serial baseline** — 64 independent requests, each through a FRESH
//!    `Session` (re-parsing and re-analyzing every point — what a shell
//!    loop over `kerncraft -p ECM --format json` would pay), offset-walk
//!    predictor;
//! 2. **engine, 1 thread** — one shared session, Auto predictor;
//! 3. **engine, N threads** — shared session + parallel, Auto predictor.
//!
//! Asserts that all three produce identical ECM numbers, then prints the
//! timings (the acceptance evidence: the shared-session engine beats the
//! fresh-session baseline on a multi-core runner).

use kerncraft::cache::CachePredictorKind;
use kerncraft::models::reference;
use kerncraft::session::Session;
use kerncraft::sweep::{build_jobs, SweepEngine};
use kerncraft::util::{median, monotonic_ns};
use std::sync::Arc;

fn main() {
    let src = reference::KERNEL_2D5PT;
    let ns: Vec<i64> = (7..23).map(|e| 1i64 << e).collect(); // 128 .. 4M
    let machines = ["SNB".to_string(), "HSW".to_string()];
    let cores = [1u32, 2];
    let jobs = build_jobs(
        "2d-5pt",
        Arc::from(src),
        &machines,
        &cores,
        &[("N".to_string(), ns.clone()), ("M".to_string(), vec![4000])],
        CachePredictorKind::Auto,
    );
    assert_eq!(jobs.len(), 64);

    // --- serial baseline: a fresh session per point, no memo reuse ---
    let serial_run = || -> Vec<f64> {
        let mut t_mems = Vec::with_capacity(jobs.len());
        for job in &jobs {
            let mut req = job.request();
            req.predictor = CachePredictorKind::Offsets;
            let report = Session::new().evaluate(&req).unwrap();
            t_mems.push(report.ecm.expect("ECM model requested").t_mem);
        }
        t_mems
    };

    let time_ms = |f: &mut dyn FnMut(), samples: usize| -> f64 {
        let mut t = Vec::with_capacity(samples);
        for _ in 0..samples {
            let t0 = monotonic_ns();
            f();
            t.push((monotonic_ns() - t0) as f64 / 1e6);
        }
        median(&t)
    };

    let mut serial_result = Vec::new();
    let serial_ms = time_ms(&mut || serial_result = serial_run(), 3);

    let mut engine1_rows = Vec::new();
    let engine1_ms = time_ms(
        &mut || engine1_rows = SweepEngine::serial().run(&jobs).unwrap().rows,
        3,
    );

    let mut enginep_rows = Vec::new();
    let mut threads_used = 1;
    let enginep_ms = time_ms(
        &mut || {
            let out = SweepEngine::new().run(&jobs).unwrap();
            threads_used = out.threads_used;
            enginep_rows = out.rows;
        },
        3,
    );

    // identical per-point numbers across all three paths
    assert_eq!(engine1_rows.len(), serial_result.len());
    for (row, want) in engine1_rows.iter().zip(&serial_result) {
        assert_eq!(row.t_ecm_mem, *want, "engine(1) diverged at {:?}", row.constants);
    }
    assert_eq!(engine1_rows, enginep_rows, "parallel rows must be bit-identical");

    println!("=== sweep bench: 64-point jacobi grid (16 N × 2 machines × 2 cores) ===");
    println!("fresh-session serial : {serial_ms:>9.2} ms   (baseline)");
    println!(
        "engine, 1 thread     : {engine1_ms:>9.2} ms   ({:.2}x vs serial)",
        serial_ms / engine1_ms
    );
    println!(
        "engine, {threads_used:>2} threads   : {enginep_ms:>9.2} ms   ({:.2}x vs serial)",
        serial_ms / enginep_ms
    );
    println!("sweep bench OK");
}
