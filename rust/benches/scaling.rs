//! Regenerates the paper's multicore-scaling claims (§2.3 and the
//! "saturating at 3 cores" line of Listing 5): ECM scaling curves and
//! saturation points for all five kernels on both machines.

use kerncraft::cache::CachePredictor;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::{reference, EcmModel, ScalingModel};
use std::collections::HashMap;

fn main() {
    println!("=== Multicore scaling (ECM): saturation points ===");
    println!(
        "{:<11} {:<4} | {:>5} | {:>9} | scaling curve (work/cy x1000 per core count)",
        "kernel", "arch", "n_s", "T_L3Mem"
    );
    for row in reference::TABLE5 {
        let machine = MachineModel::builtin(row.arch).unwrap();
        let src = reference::kernel_source(row.kernel).unwrap();
        let consts: HashMap<String, i64> =
            row.constants.iter().map(|(k, v)| (k.to_string(), *v)).collect();
        let analysis =
            KernelAnalysis::from_program(&parse(src).unwrap(), &consts).unwrap();
        let pm = PortModel::analyze(&analysis, &machine, &CodegenPolicy::for_machine(&machine))
            .unwrap();
        let traffic = CachePredictor::new(&machine).predict(&analysis).unwrap();
        let ecm = EcmModel::build(&pm, &traffic, &machine).unwrap();
        let sc = ScalingModel::build(&ecm, &machine);
        let curve: Vec<String> =
            sc.curve().iter().map(|(_, t)| format!("{:.1}", t * 1000.0)).collect();
        println!(
            "{:<11} {:<4} | {:>5} | {:>9.1} | {}",
            row.kernel,
            row.arch,
            sc.saturation,
            sc.t_mem_link,
            curve.join(" ")
        );
    }

    // the paper's headline scaling claim: jacobi on SNB saturates at 3
    let machine = MachineModel::snb();
    let consts: HashMap<String, i64> =
        [("N".to_string(), 6000i64), ("M".to_string(), 6000i64)].into_iter().collect();
    let analysis =
        KernelAnalysis::from_program(&parse(reference::KERNEL_2D5PT).unwrap(), &consts).unwrap();
    let pm =
        PortModel::analyze(&analysis, &machine, &CodegenPolicy::for_machine(&machine)).unwrap();
    let traffic = CachePredictor::new(&machine).predict(&analysis).unwrap();
    let ecm = EcmModel::build(&pm, &traffic, &machine).unwrap();
    assert_eq!(ecm.saturation_cores(), 3, "paper: 'saturating at 3 cores'");
    println!("scaling bench OK");
}
