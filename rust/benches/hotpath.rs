//! Hot-path microbenchmarks for the tool itself (criterion is not in the
//! offline crate set; this is a median-of-N harness). §Perf of
//! EXPERIMENTS.md tracks these numbers.
//!
//! Hot paths: (1) the backward-window cache predictor, (2) the
//! trace-driven virtual testbed, (3) full ECM analysis end to end through
//! the `Session` API — cold (empty caches) and warm (memoized stages).

use kerncraft::cache::CachePredictor;
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::reference;
use kerncraft::session::{AnalysisRequest, KernelSpec, Session};
use kerncraft::sim::VirtualTestbed;
use kerncraft::util::{median, monotonic_ns};
use std::collections::HashMap;

fn time_ms<F: FnMut()>(mut f: F, samples: usize) -> f64 {
    let mut t = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t0 = monotonic_ns();
        f();
        t.push((monotonic_ns() - t0) as f64 / 1e6);
    }
    median(&t)
}

fn main() {
    let machine = MachineModel::snb();

    // --- cache predictor on the three stencils ---
    println!("=== hotpath: analytic cache predictor ===");
    for (tag, n, m) in [("2D-5pt", 6000i64, 6000i64), ("UXX", 150, 150), ("long-range", 400, 400)]
    {
        let src = reference::kernel_source(tag).unwrap();
        let consts: HashMap<String, i64> =
            [("N".to_string(), n), ("M".to_string(), m)].into_iter().collect();
        let analysis =
            KernelAnalysis::from_program(&parse(src).unwrap(), &consts).unwrap();
        let ms = time_ms(
            || {
                let _ = CachePredictor::new(&machine).predict(&analysis).unwrap();
            },
            5,
        );
        println!("cache_predict {tag:<11} N={n:<5} -> {ms:>8.2} ms");
    }

    // --- virtual testbed throughput ---
    println!("=== hotpath: virtual testbed ===");
    let consts: HashMap<String, i64> =
        [("N".to_string(), 2000i64), ("M".to_string(), 600i64)].into_iter().collect();
    let analysis =
        KernelAnalysis::from_program(&parse(reference::KERNEL_2D5PT).unwrap(), &consts).unwrap();
    let mut iters = 0u64;
    let ms = time_ms(
        || {
            let mut tb = VirtualTestbed::new(&machine);
            tb.max_iterations = 1_200_000;
            let r = tb.run(&analysis).unwrap();
            iters = r.iterations;
        },
        3,
    );
    let mips = iters as f64 / ms / 1e3;
    println!("virtual_testbed jacobi {iters} iters -> {ms:>8.2} ms ({mips:.1} M it/s)");

    // --- full ECM pipeline through the session front end ---
    println!("=== hotpath: full ECM analysis (Session) ===");
    let req = AnalysisRequest::new(KernelSpec::named("2D-5pt"), "SNB")
        .with_constant("N", 2000)
        .with_constant("M", 600);
    let cold_ms = time_ms(
        || {
            let _ = Session::new().evaluate(&req).unwrap();
        },
        5,
    );
    let warm = Session::new();
    warm.evaluate(&req).unwrap();
    let warm_ms = time_ms(
        || {
            let _ = warm.evaluate(&req).unwrap();
        },
        5,
    );
    println!("full_ecm jacobi cold session -> {cold_ms:>8.2} ms (parse + analyze + models)");
    println!("full_ecm jacobi warm session -> {warm_ms:>8.2} ms (memoized parse/analysis/incore)");
    println!("hotpath bench OK");
}
