//! Regenerates paper **Figure 4**: the Fig-3 ECM predictions for the 3D
//! long-range stencil together with "measurements" — here the
//! trace-driven virtual testbed standing in for the SNB machine. The
//! paper's qualitative result must hold: good model/measurement agreement
//! for N ≳ 200, measurements above the model for small N (boundary
//! effects violate the steady-state assumption).

use kerncraft::cache::CachePredictor;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::{reference, EcmModel};
use kerncraft::sim::VirtualTestbed;
use std::collections::HashMap;

fn main() {
    let machine = MachineModel::snb();
    let program = parse(reference::KERNEL_LONG_RANGE).unwrap();
    let policy = CodegenPolicy::for_machine(&machine);

    println!("=== Fig 4: long-range ECM prediction vs virtual-testbed measurement (SNB) ===");
    println!("{:>6} | {:>10} | {:>12} | {:>7}", "N", "ECM cy/CL", "meas. cy/CL", "ratio");
    let ns: Vec<i64> = vec![12, 16, 24, 32, 48, 64, 100, 140, 200, 280, 400];
    let mut large_n_ratios = Vec::new();
    let mut small_n_ratios = Vec::new();
    for &n in &ns {
        let consts: HashMap<String, i64> =
            [("N".to_string(), n), ("M".to_string(), n)].into_iter().collect();
        let analysis = KernelAnalysis::from_program(&program, &consts).unwrap();
        if analysis.loops.iter().any(|l| l.trip() <= 0) {
            continue;
        }
        let pm = PortModel::analyze(&analysis, &machine, &policy).unwrap();
        let traffic = CachePredictor::new(&machine).predict(&analysis).unwrap();
        let ecm = EcmModel::build(&pm, &traffic, &machine).unwrap();
        let mut tb = VirtualTestbed::new(&machine);
        tb.max_iterations = 1_500_000;
        let sim = tb.run(&analysis).unwrap();
        let ratio = sim.cy_per_cl / ecm.t_mem();
        println!(
            "{:>6} | {:>10.1} | {:>12.1} | {:>7.2}",
            n,
            ecm.t_mem(),
            sim.cy_per_cl,
            ratio
        );
        if n >= 200 {
            large_n_ratios.push(ratio);
        }
        if n <= 24 {
            small_n_ratios.push(ratio);
        }
    }
    // shape assertions mirroring the paper's discussion
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    let large = mean(&large_n_ratios);
    let small = mean(&small_n_ratios);
    println!("mean measurement/model ratio: N≥200 → {large:.2}, N≤24 → {small:.2}");
    assert!(
        (large - 1.0).abs() < 0.35,
        "steady-state agreement broke down (ratio {large:.2})"
    );
    assert!(
        small > large,
        "small-N boundary effects should push measurements above the model"
    );
    println!("fig4 bench OK");
}
