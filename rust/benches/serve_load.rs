//! Load generator for the event-driven serve tier: replays a fixed
//! `/analyze` / `/healthz` / `/batch` mix over N concurrent keep-alive
//! connections against an in-process server and records throughput and
//! latency percentiles into BENCH_serve.json.
//!
//! Usage:
//!
//! ```text
//! cargo bench --bench serve_load                         # pinned trajectory: 16 and 500 connections
//! cargo bench --bench serve_load -- --smoke --out /tmp/x.json   # CI: small, fast, schema-identical
//! cargo bench --bench serve_load -- --connections 64,256 --duration-secs 10 --threads 8
//! ```
//!
//! Every run validates the client-side request tallies against the
//! server's `/metrics` per-endpoint counters and exits nonzero on any
//! mismatch, so the recorded numbers are backed by the server's own
//! accounting. The output schema (checked by CI against both the smoke
//! output and the committed BENCH_serve.json) is:
//!
//! ```text
//! {"bench": "serve_load", "schema": 1, "threads": T, "duration_s": D,
//!  "mix": "...", "runs": [{"connections": C, "requests": R, "errors": E,
//!                          "rps": X, "p50_ms": Y, "p99_ms": Z,
//!                          "metrics_validated": true}, ...]}
//! ```

use kerncraft::server::{Server, ServerOptions};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

const MIX: &str = "70% analyze / 20% healthz / 10% batch(3)";
const CLIENT_THREADS: usize = 8;

struct Args {
    connections: Vec<usize>,
    duration: Duration,
    threads: usize,
    out: String,
    smoke: bool,
}

/// Unwrap a flag's value or exit with a usage error.
fn need(v: Option<String>, flag: &str) -> String {
    v.unwrap_or_else(|| die(&format!("{flag} needs a value")))
}

fn parse_args() -> Args {
    let mut args = Args {
        connections: vec![16, 500],
        duration: Duration::from_secs(5),
        threads: 4,
        out: "BENCH_serve.json".to_string(),
        smoke: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--smoke" => {
                args.smoke = true;
                args.connections = vec![8, 64];
                args.duration = Duration::from_millis(1500);
            }
            "--connections" => {
                let v = need(it.next(), "--connections");
                args.connections.clear();
                for part in v.split(',') {
                    let n = part.trim().parse();
                    args.connections.push(n.unwrap_or_else(|_| die("bad connection count")));
                }
            }
            "--duration-secs" => {
                let v = need(it.next(), "--duration-secs");
                let secs: f64 = v.parse().unwrap_or_else(|_| die("bad --duration-secs"));
                args.duration = Duration::from_secs_f64(secs);
            }
            "--threads" => {
                let v = need(it.next(), "--threads");
                args.threads = v.parse().unwrap_or_else(|_| die("bad --threads"));
            }
            "--out" => args.out = need(it.next(), "--out"),
            "--bench" => {} // passed through by `cargo bench`
            other => die(&format!("unknown argument: {other}")),
        }
    }
    if args.connections.is_empty() {
        die("--connections needs at least one count");
    }
    args
}

fn die(msg: &str) -> ! {
    eprintln!("serve_load: {msg}");
    std::process::exit(1);
}

fn analyze_body(n: u64) -> String {
    format!(r#"{{"kernel": {{"name": "triad"}}, "machine": "SNB", "constants": {{"N": {n}}}}}"#)
}

fn post(path: &str, body: &str) -> Vec<u8> {
    let n = body.len();
    let req = format!("POST {path} HTTP/1.1\r\nhost: bench\r\ncontent-length: {n}\r\n\r\n{body}");
    req.into_bytes()
}

/// Read one keep-alive response; returns the status code.
fn read_response(r: &mut BufReader<TcpStream>) -> std::io::Result<u16> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    let parsed = line.split_whitespace().nth(1).and_then(|s| s.parse::<u16>().ok());
    let Some(status) = parsed else {
        return Err(std::io::Error::new(std::io::ErrorKind::InvalidData, line));
    };
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h)?;
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap_or(0);
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(status)
}

/// Client-side tallies from one worker thread.
#[derive(Default)]
struct Tally {
    analyze: u64,
    healthz: u64,
    batch: u64,
    errors: u64,
    latencies_us: Vec<u64>,
}

fn client_thread(addr: SocketAddr, conn_indices: Vec<usize>, deadline: Instant) -> Tally {
    // open this thread's keep-alive connections, one warmup /healthz
    // round-trip each (paces the opens past the listener backlog;
    // warmups are not recorded but ARE counted for /metrics validation
    // by the caller, one per connection)
    let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = Vec::new();
    for _ in &conn_indices {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
        stream.set_nodelay(true).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut s = &stream;
        s.write_all(b"GET /healthz HTTP/1.1\r\nhost: bench\r\n\r\n").unwrap();
        assert_eq!(read_response(&mut reader).unwrap(), 200);
        conns.push((stream, reader));
    }

    let one = analyze_body(65536);
    let batch_body = format!("[{one}, {one}, {one}]");
    let sizes = [4096u64, 65536, 1 << 20];
    let mut tally = Tally::default();
    let mut iter = 0usize;
    'outer: loop {
        for (slot, (stream, reader)) in conns.iter_mut().enumerate() {
            if Instant::now() >= deadline {
                break 'outer;
            }
            let ci = conn_indices[slot];
            // deterministic mix keyed on (connection, iteration)
            let pick = (ci + iter) % 10;
            let raw: Vec<u8> = match pick {
                0..=6 => {
                    tally.analyze += 1;
                    post("/analyze", &analyze_body(sizes[(ci + iter) % sizes.len()]))
                }
                7 | 8 => {
                    tally.healthz += 1;
                    b"GET /healthz HTTP/1.1\r\nhost: bench\r\n\r\n".to_vec()
                }
                _ => {
                    tally.batch += 1;
                    post("/batch", &batch_body)
                }
            };
            let t0 = Instant::now();
            let mut s = &*stream;
            s.write_all(&raw).unwrap();
            let status = read_response(reader).unwrap();
            tally.latencies_us.push(t0.elapsed().as_micros() as u64);
            if status != 200 {
                tally.errors += 1;
            }
        }
        iter += 1;
    }
    tally
}

/// Scrape one numeric sample from a `/metrics` exposition.
fn metric(text: &str, name: &str) -> u64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Ok(v) = rest.trim().parse() {
                return v;
            }
        }
    }
    die(&format!("metric {name} missing from /metrics"));
}

fn fetch_metrics(addr: SocketAddr) -> String {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let req = b"GET /metrics HTTP/1.1\r\nhost: bench\r\nconnection: close\r\n\r\n";
    stream.write_all(req).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8_lossy(&buf);
    match text.split_once("\r\n\r\n") {
        Some((_, body)) => body.to_string(),
        None => die("malformed /metrics response"),
    }
}

struct RunResult {
    connections: usize,
    requests: u64,
    errors: u64,
    rps: f64,
    p50_ms: f64,
    p99_ms: f64,
}

fn percentile(sorted_us: &[u64], q: f64) -> f64 {
    if sorted_us.is_empty() {
        return 0.0;
    }
    let ix = ((sorted_us.len() - 1) as f64 * q).round() as usize;
    sorted_us[ix] as f64 / 1000.0
}

fn run_one(connections: usize, duration: Duration, threads: usize) -> RunResult {
    let server = Server::bind(ServerOptions {
        listen: "127.0.0.1:0".to_string(),
        threads,
        cache_dir: None,
        max_body_bytes: 1 << 20,
        idle_timeout: Duration::from_secs(120),
        verbose: false,
    })
    .unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().unwrap());

    let client_threads = CLIENT_THREADS.min(connections);
    let deadline = Instant::now() + duration;
    let t0 = Instant::now();
    let workers: Vec<_> = (0..client_threads)
        .map(|t| {
            let mine: Vec<usize> = (0..connections).filter(|c| c % client_threads == t).collect();
            std::thread::spawn(move || client_thread(addr, mine, deadline))
        })
        .collect();
    let mut tally = Tally::default();
    for w in workers {
        let t = w.join().unwrap();
        tally.analyze += t.analyze;
        tally.healthz += t.healthz;
        tally.batch += t.batch;
        tally.errors += t.errors;
        tally.latencies_us.extend(t.latencies_us);
    }
    let elapsed = t0.elapsed();

    // the server's own accounting must agree with what we sent:
    // one warmup /healthz per connection on top of the recorded mix
    let metrics = fetch_metrics(addr);
    let healthz_total = tally.healthz + connections as u64;
    let checks = [
        ("kerncraft_requests_total{endpoint=\"analyze\"}", tally.analyze),
        ("kerncraft_requests_total{endpoint=\"healthz\"}", healthz_total),
        ("kerncraft_requests_total{endpoint=\"batch\"}", tally.batch),
        ("kerncraft_connections_total", connections as u64 + 1),
        ("kerncraft_queue_depth", 0),
    ];
    for (name, expected) in checks {
        let got = metric(&metrics, name);
        if got != expected {
            die(&format!("{connections} connections: {name} = {got}, client sent {expected}"));
        }
    }

    handle.stop();
    join.join().unwrap();

    let requests = tally.analyze + tally.healthz + tally.batch;
    tally.latencies_us.sort_unstable();
    RunResult {
        connections,
        requests,
        errors: tally.errors,
        rps: requests as f64 / elapsed.as_secs_f64(),
        p50_ms: percentile(&tally.latencies_us, 0.50),
        p99_ms: percentile(&tally.latencies_us, 0.99),
    }
}

fn main() {
    let args = parse_args();
    let mut runs = Vec::new();
    for &connections in &args.connections {
        eprintln!(
            "serve_load: {connections} connections x {:.1}s, {} workers ...",
            args.duration.as_secs_f64(),
            args.threads
        );
        let r = run_one(connections, args.duration, args.threads);
        eprintln!(
            "serve_load: {connections} conns: {} reqs, {:.0} rps, p50 {:.2} ms, p99 {:.2} ms, {} errors",
            r.requests,
            r.rps,
            r.p50_ms,
            r.p99_ms,
            r.errors
        );
        runs.push(r);
    }

    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"bench\": \"serve_load\",\n");
    out.push_str("  \"schema\": 1,\n");
    out.push_str(&format!("  \"threads\": {},\n", args.threads));
    out.push_str(&format!("  \"duration_s\": {:.2},\n", args.duration.as_secs_f64()));
    out.push_str(&format!("  \"mix\": \"{MIX}\",\n"));
    if args.smoke {
        out.push_str("  \"note\": \"smoke run (CI): short duration, small connection counts\",\n");
    }
    out.push_str("  \"runs\": [\n");
    for (i, r) in runs.iter().enumerate() {
        out.push_str(&format!(
            "    {{\"connections\": {}, \"requests\": {}, \"errors\": {}, \"rps\": {:.1}, \"p50_ms\": {:.3}, \"p99_ms\": {:.3}, \"metrics_validated\": true}}{}\n",
            r.connections,
            r.requests,
            r.errors,
            r.rps,
            r.p50_ms,
            r.p99_ms,
            if i + 1 < runs.len() { "," } else { "" }
        ));
    }
    out.push_str("  ]\n}\n");
    if let Err(e) = std::fs::write(&args.out, &out) {
        die(&format!("writing {}: {e}", args.out));
    }
    eprintln!("serve_load: wrote {}", args.out);
}
