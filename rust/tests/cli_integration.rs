//! CLI integration: every analysis mode × both machines × all five paper
//! kernels must produce a well-formed report.

use kerncraft::cli::run;
use kerncraft::models::reference;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(str::to_string).collect()
}

fn kernel_file(tag: &str) -> &'static str {
    match tag {
        "2D-5pt" => "kernels/2d-5pt.c",
        "UXX" => "kernels/uxx.c",
        "long-range" => "kernels/long-range.c",
        "Kahan-dot" => "kernels/kahan-ddot.c",
        "triad" => "kernels/triad.c",
        _ => unreachable!(),
    }
}

fn defines(tag: &str) -> String {
    let row = reference::TABLE5.iter().find(|r| r.kernel == tag).unwrap();
    row.constants
        .iter()
        .map(|(k, v)| format!("-D {k} {v}"))
        .collect::<Vec<_>>()
        .join(" ")
}

#[test]
fn ecm_mode_all_kernels_both_machines() {
    for tag in reference::kernel_tags() {
        for arch in ["SNB", "HSW"] {
            let cmd = format!("-p ECM -m {arch} {} {}", kernel_file(tag), defines(tag));
            let out = run(&argv(&cmd)).unwrap_or_else(|e| panic!("{tag}/{arch}: {e:#}"));
            assert!(out.contains("ECM model: {"), "{tag}/{arch}:\n{out}");
            assert!(out.contains("ECM prediction"), "{tag}/{arch}:\n{out}");
        }
    }
}

#[test]
fn roofline_modes_all_kernels() {
    for tag in reference::kernel_tags() {
        for mode in ["Roofline", "RooflinePort"] {
            let cmd = format!("-p {mode} -m SNB {} {}", kernel_file(tag), defines(tag));
            let out = run(&argv(&cmd)).unwrap_or_else(|e| panic!("{tag}/{mode}: {e:#}"));
            assert!(out.contains("Roofline prediction"), "{tag}/{mode}:\n{out}");
        }
    }
}

#[test]
fn ecmdata_and_ecmcpu_modes() {
    let out = run(&argv("-p ECMData -m HSW kernels/triad.c -D N 4000000")).unwrap();
    assert!(out.contains("ECM model"), "{out}");
    let out = run(&argv("-p ECMCPU -m HSW kernels/triad.c -D N 4000000")).unwrap();
    assert!(out.contains("T_OL"), "{out}");
}

#[test]
fn benchmark_virtual_all_kernels() {
    // use smaller sizes than Table 5 so the trace sim stays quick in CI
    let cases = [
        ("kernels/2d-5pt.c", "-D N 2000 -D M 400"),
        ("kernels/triad.c", "-D N 400000"),
        ("kernels/kahan-ddot.c", "-D N 400000"),
        ("kernels/uxx.c", "-D N 60 -D M 60"),
        ("kernels/long-range.c", "-D N 60 -D M 60"),
    ];
    for (file, defs) in cases {
        let cmd = format!("-p Benchmark -m SNB {file} {defs}");
        let out = run(&argv(&cmd)).unwrap_or_else(|e| panic!("{file}: {e:#}"));
        assert!(out.contains("virtual testbed"), "{file}:\n{out}");
    }
}

#[test]
fn native_benchmark_triad() {
    let out = run(&argv("-p Benchmark --bench-path native kernels/triad.c -D N 200000")).unwrap();
    assert!(out.contains("native host"), "{out}");
}

#[test]
fn verbose_shows_analysis_tables() {
    let out = run(&argv(
        "-p ECM -m SNB kernels/2d-5pt.c -D N 5000 -D M 500 -v",
    ))
    .unwrap();
    // Table 2 values from the paper: j | 1 | 499, i | 1 | 4999
    assert!(out.contains("j | 1 | 499 | +1"), "{out}");
    assert!(out.contains("i | 1 | 4999 | +1"), "{out}");
}

#[test]
fn cache_viz_flag() {
    let out = run(&argv(
        "-p ECM -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 --cache-viz",
    ))
    .unwrap();
    assert!(out.contains("cache usage prediction"), "{out}");
    assert!(out.contains("layer conditions"), "{out}");
}

#[test]
fn custom_machine_file_path() {
    let out = run(&argv(
        "-p ECM -m machines/hsw.yml kernels/triad.c -D N 4000000",
    ))
    .unwrap();
    assert!(out.contains("ECM model"), "{out}");
}

#[test]
fn missing_constant_is_a_clean_error() {
    let err = run(&argv("-p ECM -m SNB kernels/2d-5pt.c -D N 100")).unwrap_err();
    assert!(format!("{err:#}").contains("unbound constant 'M'"), "{err:#}");
}

#[test]
fn units_flow_through() {
    for unit in ["cy/CL", "It/s", "FLOP/s"] {
        let cmd =
            format!("-p ECM -m SNB kernels/triad.c -D N 4000000 --unit {unit}");
        let out = run(&argv(&cmd)).unwrap();
        assert!(!out.is_empty(), "{unit}");
    }
}

#[test]
fn unit_spellings_are_case_insensitive_and_errors_list_them() {
    let lower = run(&argv("-p ECM -m SNB kernels/triad.c -D N 4000000 --unit flop/s")).unwrap();
    let canon = run(&argv("-p ECM -m SNB kernels/triad.c -D N 4000000 --unit FLOP/s")).unwrap();
    assert_eq!(lower, canon);
    let err =
        run(&argv("-p ECM -m SNB kernels/triad.c -D N 4000000 --unit bogons")).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("cy/CL") && msg.contains("It/s") && msg.contains("FLOP/s"), "{msg}");
}

/// Golden-test normalization: numeric text (digits, sign, decimal point)
/// collapses to a single `#`, runs of spaces to a single space. The
/// fixture pins the report *shape* exactly while the simulated figures —
/// deterministic but not hand-derivable — are pinned by the tolerance
/// asserts below.
fn normalize_numbers(s: &str) -> String {
    let mut out = String::new();
    let mut last_hash = false;
    let mut last_space = false;
    for c in s.chars() {
        if c.is_ascii_digit() || c == '+' || c == '-' || c == '.' {
            if !last_hash {
                out.push('#');
            }
            last_hash = true;
            last_space = false;
        } else if c == ' ' {
            if !last_space {
                out.push(' ');
            }
            last_space = true;
            last_hash = false;
        } else {
            out.push(c);
            last_hash = false;
            last_space = false;
        }
    }
    out
}

#[test]
fn validate_golden_2d5pt_snb() {
    // the paper's headline validation case: 2D 5-point Jacobi on the SNB
    // machine file, Table 5 sizes. The rendered report shape is pinned by
    // a golden fixture; the figures by the paper's published tolerances.
    let out = run(&argv(
        "-p Validate -m machines/snb.yml kernels/2d-5pt.c -D N 6000 -D M 6000",
    ))
    .unwrap();
    let expected =
        std::fs::read_to_string("rust/tests/fixtures/validate_2d5pt_snb.expected").unwrap();
    assert_eq!(normalize_numbers(&out), expected, "raw output:\n{out}");

    // the same run as JSON: round-trip stable, figures near Table 5
    // (model 36.7 cy/CL, measured 36.4 cy/CL on SNB)
    let json = run(&argv(
        "-p Validate -m machines/snb.yml kernels/2d-5pt.c -D N 6000 -D M 6000 --format json",
    ))
    .unwrap();
    let report = kerncraft::session::AnalysisReport::from_json(json.trim()).unwrap();
    assert_eq!(report.to_json(), json.trim());
    let ecm = report.ecm.as_ref().expect("ECM section");
    assert!((ecm.t_mem - 36.7).abs() < 0.8, "{}", ecm.t_mem);
    let v = report.validation.expect("validation section");
    assert_eq!(v.analytic_cy_per_cl, ecm.t_mem);
    assert!((v.sim_cy_per_cl - 36.4).abs() / 36.4 < 0.2, "{}", v.sim_cy_per_cl);
    // implied by the two pins above (sim within 20%, t_mem within 0.8):
    // never assert tighter than their composition
    assert!(v.model_error_pct.abs() < 30.0, "{}", v.model_error_pct);
    assert!(v.truncated, "36M iterations exceed the testbed window");
    assert_eq!(v.levels.len(), 3);
    for l in &v.levels {
        assert!(l.hits + l.misses > 0, "{l:?}");
    }
}

#[test]
fn json_format_across_model_modes() {
    use kerncraft::session::AnalysisReport;
    for mode in ["ECM", "ECMData", "ECMCPU", "Roofline", "RooflinePort"] {
        let cmd = format!(
            "-p {mode} -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 --format json"
        );
        let out = run(&argv(&cmd)).unwrap_or_else(|e| panic!("{mode}: {e:#}"));
        assert_eq!(out.lines().count(), 1, "{mode}: one JSON line\n{out}");
        let report = AnalysisReport::from_json(out.trim())
            .unwrap_or_else(|e| panic!("{mode}: {e:#}\n{out}"));
        assert_eq!(report.model.name(), mode, "{mode}");
        assert_eq!(report.kernel, "2d-5pt");
        // round-trip stability: re-serializing yields the same document
        assert_eq!(report.to_json(), out.trim(), "{mode}");
    }
}
