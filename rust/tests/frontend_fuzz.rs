//! Frontend robustness tests: a grammar-driven fuzz generator for the
//! widened kernel language, the extended kernel corpus, and golden
//! diagnostic fixtures.
//!
//! * Valid generated nests must parse AND analyze without panicking —
//!   and in fact succeed, pinning the grammar the generator encodes.
//! * Mutated sources must produce a structured [`Diagnostic`] (or still
//!   parse), but NEVER panic the frontend.
//! * Every kernel in `kernels/extended/` (constructs the v1 frontend
//!   rejected) parses and analyzes end to end.
//! * The fixtures under `rust/tests/fixtures/diag/` pin the exact
//!   caret-rendered output of `kerncraft check` per diagnostic code.
//!
//! Tests run with the package root as working directory (see
//! Cargo.toml), so `kernels/` and `rust/tests/fixtures/` are reachable
//! by relative path.
//!
//! [`Diagnostic`]: kerncraft::kernel::Diagnostic

use kerncraft::kernel::{parse, KernelAnalysis};
use std::collections::HashMap;

/// Deterministic 64-bit LCG (fixed seed, no external crates) so every
/// run fuzzes the same corpus.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0 >> 33
    }

    fn below(&mut self, n: usize) -> usize {
        (self.next() % n as u64) as usize
    }

    fn chance(&mut self, one_in: usize) -> bool {
        self.below(one_in) == 0
    }
}

/// Generate one valid kernel from the surface grammar: 1-3 loops over
/// `i`/`j`/`k` in one of the accepted header shapes (canonical, `<=`,
/// flipped bound, compound/written-out increment), arrays subscripted
/// by the loop indices (optionally with `±1` offsets under shrunken
/// bounds), straight-line statements plus optional conditionals,
/// compound blocks, casts, and a typedef'd element type.
fn gen_kernel(rng: &mut Rng) -> String {
    let depth = 1 + rng.below(3);
    let idx = ["i", "j", "k"];
    let offsets = rng.chance(3); // ±1 subscripts need shrunken bounds
    let typedefed = rng.chance(4);
    let mut src = String::new();
    if typedefed {
        src.push_str("typedef double real;\n");
    }
    let ty = if typedefed { "real" } else { "double" };
    let dims: String = "[N]".repeat(depth);
    src.push_str(&format!("{ty} a{dims}, b{dims}, s;\n"));
    for v in idx.iter().take(depth) {
        let header = if offsets {
            format!("for (int {v} = 1; {v} < N - 1; ++{v})")
        } else {
            match rng.below(5) {
                0 => format!("for (int {v} = 0; {v} < N; ++{v})"),
                1 => format!("for (int {v} = 0; {v} <= N - 1; {v}++)"),
                2 => format!("for (int {v} = 0; N > {v}; {v} += 1)"),
                3 => format!("for (int {v} = 0; {v} < N; {v} = {v} + 1)"),
                _ => format!("for (int {v} = 0; {v} < N; {v} += 2)"),
            }
        };
        src.push_str(&header);
        src.push_str(" {\n");
    }
    let subs: String = idx.iter().take(depth).map(|v| format!("[{v}]")).collect();
    let inner = idx[depth - 1];
    let shifted = {
        let mut s = String::new();
        for v in idx.iter().take(depth - 1) {
            s.push_str(&format!("[{v}]"));
        }
        s + &format!("[{inner}-1]")
    };
    for _ in 0..(1 + rng.below(3)) {
        match rng.below(5) {
            0 => src.push_str(&format!("a{subs} = b{subs} * s;\n")),
            1 => src.push_str(&format!("a{subs} = a{subs} + b{subs};\n")),
            2 => src.push_str(&format!("s = s + b{subs};\n")),
            3 => src.push_str(&format!("a{subs} = (double)b{subs} + 0.5;\n")),
            _ if offsets => src.push_str(&format!("a{subs} = b{shifted} + b{subs};\n")),
            _ => src.push_str(&format!("{{ a{subs} = 2.0 * b{subs}; }}\n")),
        }
    }
    if rng.chance(4) {
        src.push_str(&format!(
            "if (b{subs} > 0.0 && s < 1.0) a{subs} = s; else a{subs} = 0.0;\n"
        ));
    }
    for _ in 0..depth {
        src.push_str("}\n");
    }
    src
}

/// Apply one random mutation: delete, duplicate, or replace a
/// character, or truncate the source.
fn mutate(src: &str, rng: &mut Rng) -> String {
    let mut out: Vec<char> = src.chars().collect();
    if out.is_empty() {
        return String::new();
    }
    match rng.below(4) {
        0 => {
            let p = rng.below(out.len());
            out.remove(p);
        }
        1 => {
            let p = rng.below(out.len());
            let c = out[p];
            out.insert(p, c);
        }
        2 => {
            const JUNK: [char; 16] = [
                '(', ')', ';', '[', ']', '{', '}', '=', '<', '>', '+', '-', '@', '&', '#', '.',
            ];
            let p = rng.below(out.len());
            out[p] = JUNK[rng.below(JUNK.len())];
        }
        _ => {
            let p = rng.below(out.len());
            out.truncate(p);
        }
    }
    out.into_iter().collect()
}

#[test]
fn fuzz_valid_nests_parse_and_analyze() {
    let mut rng = Rng(0x6b65726e63726166); // fixed seed: deterministic corpus
    let constants: HashMap<String, i64> = [("N".to_string(), 32)].into_iter().collect();
    for case in 0..500 {
        let src = gen_kernel(&mut rng);
        let program = parse(&src)
            .unwrap_or_else(|e| panic!("valid case {case} rejected: {e}\n--- source ---\n{src}"));
        KernelAnalysis::from_program(&program, &constants)
            .unwrap_or_else(|e| panic!("valid case {case} failed analysis: {e}\n{src}"));
    }
}

#[test]
fn fuzz_mutated_sources_never_panic() {
    let mut rng = Rng(0x64696167);
    let mut rejected = 0usize;
    let mut total = 0usize;
    for _ in 0..500 {
        let base = gen_kernel(&mut rng);
        for _ in 0..2 {
            let m = mutate(&base, &mut rng);
            total += 1;
            // a mutant may still parse; what it must never do is panic,
            // and every rejection must be a coded diagnostic
            if let Err(e) = parse(&m) {
                rejected += 1;
                assert!(
                    e.code().starts_with('E'),
                    "rejection without a stable code: {e}\n{m}"
                );
            }
        }
    }
    // sanity: single-character damage trips the frontend often enough
    // that a silent accept-everything parser would fail here
    assert!(rejected > total / 10, "only {rejected}/{total} mutants rejected");
}

#[test]
fn extended_corpus_parses_and_analyzes() {
    let constants: HashMap<String, i64> =
        [("N".to_string(), 64), ("M".to_string(), 32)].into_iter().collect();
    let mut seen = 0;
    let mut entries: Vec<_> = std::fs::read_dir("kernels/extended")
        .expect("kernels/extended exists")
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    for path in entries {
        if path.extension().and_then(|e| e.to_str()) != Some("c") {
            continue;
        }
        seen += 1;
        let src = std::fs::read_to_string(&path).unwrap();
        let program = parse(&src)
            .unwrap_or_else(|e| panic!("{} rejected: {e}", path.display()));
        KernelAnalysis::from_program(&program, &constants)
            .unwrap_or_else(|e| panic!("{} failed analysis: {e}", path.display()));
    }
    assert!(seen >= 10, "extended corpus has only {seen} kernels");
}

#[test]
fn extended_corpus_evaluates_through_the_session() {
    use kerncraft::session::{AnalysisRequest, KernelSpec, Session};
    let session = Session::new();
    for kernel in ["kernels/extended/typedef-axpy.c", "kernels/extended/conditional-threshold.c"] {
        let req = AnalysisRequest::new(KernelSpec::path(kernel), "SNB").with_constant("N", 65536);
        let report = session
            .evaluate(&req)
            .unwrap_or_else(|e| panic!("{kernel} failed end to end: {e:#}"));
        assert!(report.to_json().contains("\"ecm\""), "{kernel}");
    }
}

#[test]
fn golden_diagnostic_fixtures() {
    let mut entries: Vec<_> = std::fs::read_dir("rust/tests/fixtures/diag")
        .expect("diag fixtures exist")
        .map(|e| e.unwrap().path())
        .collect();
    entries.sort();
    let mut seen = 0;
    for path in entries {
        if path.extension().and_then(|e| e.to_str()) != Some("c") {
            continue;
        }
        seen += 1;
        let rel = path.to_str().unwrap().to_string();
        let (out, failed) = kerncraft::cli::run_check(&[rel.clone()]).unwrap();
        assert_eq!(failed, 1, "{rel} should fail the check:\n{out}");
        let expected = std::fs::read_to_string(path.with_extension("expected")).unwrap();
        assert_eq!(out, expected, "diagnostic drifted for {rel}");
    }
    assert!(seen >= 6, "only {seen} diagnostic fixtures");
}

#[test]
fn check_reports_ok_for_the_paper_kernels() {
    let files: Vec<String> = ["2d-5pt", "kahan-ddot", "long-range", "triad", "uxx"]
        .iter()
        .map(|n| format!("kernels/{n}.c"))
        .collect();
    let (out, failed) = kerncraft::cli::run_check(&files).unwrap();
    assert_eq!(failed, 0, "{out}");
    for f in &files {
        assert!(out.contains(&format!("{f}: ok")), "{out}");
    }
}
