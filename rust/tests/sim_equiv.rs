//! Equivalence suite for the fast virtual-testbed engine (DESIGN.md §1).
//!
//! The fast engine's whole claim is that compressed line-interval traces,
//! set sharding, and convergence skip-ahead are *accounting transforms*,
//! not approximations — so the pinning tests are adversarial on exactly
//! that claim:
//!
//! * on the five paper kernels plus the 3D 7-point stencil, the fast
//!   engine with skip-ahead off must report per-level hit/miss/writeback
//!   counts *identical* to the per-access reference engine, at every
//!   shard count, with cy/CL agreeing to float-summation-order noise;
//! * the simulated cycle total must be bit-identical across shard
//!   counts (per-unit windows are merged as integer counts before the
//!   serial float composition, so K must not leak into the result);
//! * over a hundred randomized 2-D stencils (same determinism
//!   discipline as advise_prop: seeded XorShift64, no ambient entropy)
//!   the exact-stats property must hold, and the default configuration
//!   (skip-ahead on) must land within 1% of the reference cy/CL;
//! * skip-ahead extrapolation must engage on a steady-state kernel and
//!   stay within its documented 0.5% cy/CL bound of the exact run;
//! * the truncation path (outer dimension clipped by `max_iterations`)
//!   must preserve all of the above.

use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::reference;
use kerncraft::sim::{SimEngine, SimResult, VirtualTestbed};
use kerncraft::util::XorShift64;
use std::collections::HashMap;

fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

fn analyze(src: &str, pairs: &[(&str, i64)]) -> KernelAnalysis {
    let program = parse(src).unwrap();
    KernelAnalysis::from_program(&program, &consts(pairs)).unwrap()
}

/// Run one configuration of the testbed.
fn run_with(
    m: &MachineModel,
    a: &KernelAnalysis,
    engine: SimEngine,
    skip_ahead: bool,
    shards: usize,
) -> SimResult {
    let mut tb = VirtualTestbed::new(m);
    tb.engine = engine;
    tb.skip_ahead = skip_ahead;
    tb.shards = shards;
    tb.run(a).unwrap()
}

/// Exact-equivalence check: integer statistics identical, cy/CL within
/// float-summation-order noise.
fn assert_stats_identical(r: &SimResult, f: &SimResult, tag: &str) {
    assert_eq!(r.iterations, f.iterations, "{tag}: iterations");
    assert_eq!(r.truncated, f.truncated, "{tag}: truncated");
    assert_eq!(r.touches, f.touches, "{tag}: touches");
    assert!(!f.extrapolated, "{tag}: exact mode must not extrapolate");
    assert_eq!(r.levels.len(), f.levels.len(), "{tag}: level count");
    for (a, b) in r.levels.iter().zip(&f.levels) {
        assert_eq!(a.level, b.level, "{tag}");
        assert_eq!(a.hits, b.hits, "{tag} {}: hits", a.level);
        assert_eq!(a.misses, b.misses, "{tag} {}: misses", a.level);
        assert_eq!(a.writebacks, b.writebacks, "{tag} {}: writebacks", a.level);
    }
    let rel = (r.cy_per_cl - f.cy_per_cl).abs() / r.cy_per_cl.abs().max(1e-12);
    assert!(
        rel < 1e-9,
        "{tag}: cy/CL {} vs {} (rel {rel:e})",
        r.cy_per_cl,
        f.cy_per_cl
    );
}

/// The corpus: the five Table 5 kernels plus the 3D 7-point stencil, at
/// sizes small enough for the per-access reference replay in CI.
fn corpus() -> Vec<(&'static str, Vec<(&'static str, i64)>)> {
    vec![
        ("2D-5pt", vec![("N", 600), ("M", 400)]),
        ("UXX", vec![("M", 20), ("N", 50)]),
        ("long-range", vec![("M", 20), ("N", 50)]),
        ("Kahan-dot", vec![("N", 60_000)]),
        ("triad", vec![("N", 60_000)]),
        ("3D-7pt", vec![("M", 20), ("N", 40), ("P", 40)]),
    ]
}

#[test]
fn paper_kernels_fast_matches_reference_exactly() {
    for machine in [MachineModel::snb(), MachineModel::hsw()] {
        for (tag, pairs) in corpus() {
            let src = reference::kernel_source(tag).unwrap();
            let a = analyze(src, &pairs);
            let r = run_with(&machine, &a, SimEngine::Reference, false, 0);
            assert_eq!(r.engine, SimEngine::Reference, "{tag}");
            for shards in [1, 4] {
                let f = run_with(&machine, &a, SimEngine::Fast, false, shards);
                assert_eq!(f.engine, SimEngine::Fast, "{tag}");
                assert_stats_identical(&r, &f, &format!("{tag} shards={shards}"));
            }
        }
    }
}

#[test]
fn cycles_are_bit_identical_across_shard_counts() {
    // Per-unit penalty/traffic windows are merged as integer counts
    // before the serial float composition, so the shard count must not
    // perturb even the last bit of the cycle total.
    let m = MachineModel::snb();
    for (tag, pairs) in [
        ("2D-5pt", vec![("N", 600), ("M", 400)]),
        ("3D-7pt", vec![("M", 20), ("N", 40), ("P", 40)]),
    ] {
        let a = analyze(reference::kernel_source(tag).unwrap(), &pairs);
        let base = run_with(&m, &a, SimEngine::Fast, false, 1);
        for shards in [2, 4, 8] {
            let f = run_with(&m, &a, SimEngine::Fast, false, shards);
            assert_eq!(
                base.cycles.to_bits(),
                f.cycles.to_bits(),
                "{tag}: shards={shards} perturbed the cycle total ({} vs {})",
                base.cycles,
                f.cycles
            );
        }
    }
}

/// A random 2-D stencil `b[j][i] = (Σ a[j+dj][i+di]) * s` with 2–6
/// distinct read offsets in `[-2, 2]²` (always including the center);
/// loop margins of 3 keep every offset in bounds. Same generator shape
/// as the advise_prop suite.
fn random_stencil(rng: &mut XorShift64) -> String {
    let mut offsets = vec![(0i64, 0i64)];
    for _ in 0..(1 + rng.next_below(5)) {
        let dj = rng.next_range(-2, 2);
        let di = rng.next_range(-2, 2);
        if !offsets.contains(&(dj, di)) {
            offsets.push((dj, di));
        }
    }
    let idx = |v: &str, d: i64| match d {
        0 => v.to_string(),
        d if d > 0 => format!("{v}+{d}"),
        d => format!("{v}{d}"),
    };
    let reads: Vec<String> = offsets
        .iter()
        .map(|&(dj, di)| format!("a[{}][{}]", idx("j", dj), idx("i", di)))
        .collect();
    format!(
        "double a[M][N], b[M][N], s;\nfor (int j = 3; j < M - 3; j++)\n  for (int i = 3; i < N - 3; i++)\n    b[j][i] = ({}) * s;",
        reads.join(" + ")
    )
}

#[test]
fn randomized_stencils_agree_with_reference() {
    let machine = MachineModel::snb();
    let mut rng = XorShift64::new(0x51_0E_0F_A57);
    let mut checked = 0usize;
    for case in 0..110 {
        let src = random_stencil(&mut rng);
        let m = 40 + rng.next_below(80) as i64;
        let n = 40 + rng.next_below(120) as i64;
        let a = analyze(&src, &[("M", m), ("N", n)]);
        let r = run_with(&machine, &a, SimEngine::Reference, false, 0);
        for shards in [1, 4] {
            let f = run_with(&machine, &a, SimEngine::Fast, false, shards);
            assert_stats_identical(
                &r,
                &f,
                &format!("case {case} (M={m} N={n} shards={shards})\n{src}"),
            );
        }
        // the default configuration (skip-ahead on, auto shards) may
        // extrapolate; its cy/CL must stay within 1% of the reference
        let d = run_with(&machine, &a, SimEngine::Fast, true, 0);
        let rel = (d.cy_per_cl - r.cy_per_cl).abs() / r.cy_per_cl.abs().max(1e-12);
        assert!(
            rel < 0.01,
            "case {case}: default fast cy/CL {} vs reference {} (rel {rel:e})\n{src}",
            d.cy_per_cl,
            r.cy_per_cl
        );
        checked += 1;
    }
    assert!(checked >= 100, "suite must check >= 100 randomized cases, got {checked}");
}

#[test]
fn skip_ahead_engages_and_respects_its_error_bound() {
    // A steady-state 2-D stencil long enough that the per-row
    // fingerprint repeats: extrapolation must engage, and the
    // extrapolated cy/CL must stay within the documented 0.5% bound of
    // the exact (skip-ahead off) run. Integer touches/iterations are
    // extrapolated exactly and must match.
    let m = MachineModel::snb();
    let a = analyze(
        reference::kernel_source("2D-5pt").unwrap(),
        &[("N", 3000), ("M", 3000)],
    );
    let exact = run_with(&m, &a, SimEngine::Fast, false, 0);
    let skip = run_with(&m, &a, SimEngine::Fast, true, 0);
    assert!(skip.extrapolated, "skip-ahead never engaged on a steady-state kernel");
    assert_eq!(exact.iterations, skip.iterations);
    assert_eq!(exact.touches, skip.touches);
    assert_eq!(exact.truncated, skip.truncated);
    let rel = (skip.cy_per_cl - exact.cy_per_cl).abs() / exact.cy_per_cl.abs().max(1e-12);
    assert!(
        rel < 0.005,
        "skip-ahead cy/CL {} vs exact {} (rel {rel:e}) breaks the 0.5% bound",
        skip.cy_per_cl,
        exact.cy_per_cl
    );
}

#[test]
fn truncation_path_is_equivalent_too() {
    // Clip the outer dimension with a reduced iteration cap so the
    // truncation branch of SimSetup is what both engines replay.
    let machine = MachineModel::snb();
    let a = analyze(
        reference::kernel_source("2D-5pt").unwrap(),
        &[("N", 400), ("M", 100_000)],
    );
    let run_capped = |engine: SimEngine, skip: bool, shards: usize| -> SimResult {
        let mut tb = VirtualTestbed::new(&machine);
        tb.engine = engine;
        tb.skip_ahead = skip;
        tb.shards = shards;
        tb.max_iterations = 100_000;
        tb.run(&a).unwrap()
    };
    let r = run_capped(SimEngine::Reference, false, 0);
    assert!(r.truncated);
    for shards in [1, 4] {
        let f = run_capped(SimEngine::Fast, false, shards);
        assert_stats_identical(&r, &f, &format!("truncated shards={shards}"));
    }
}
