//! Golden fixtures for `kerncraft advise` (DESIGN.md §5): the CLI text
//! report for the paper's 2-D and 3-D stencils on the SNB machine file.
//!
//! Same digit normalization as the in-core and Validate golden suites
//! (runs of digits/sign/point collapse to `#`, space runs to one
//! space): the fixture pins the report *shape* byte-for-byte, while the
//! hand-derived breakpoints are pinned by exact-substring asserts —
//! e.g. 2d-5pt on SNB keeps three `a` rows (j−1..j+1) plus one `b` row
//! live per j iteration, 4 × 8 B = 32 B per inner element, so the
//! L1/L2/L3 breakpoints land at 32768/32 = 1024, 262144/32 = 8192 and
//! 20971520/32 = 655360.

use kerncraft::cli::run;
use kerncraft::session::AnalysisReport;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(str::to_string).collect()
}

fn advise(cmd: &str) -> String {
    run(&argv(cmd)).unwrap_or_else(|e| panic!("{cmd}: {e:#}"))
}

/// Same normalization as the other golden suites: numeric text (digits,
/// sign, decimal point) collapses to a single `#`, space runs to one
/// space, everything else passes through verbatim.
fn normalize_numbers(s: &str) -> String {
    let mut out = String::new();
    let mut last_hash = false;
    let mut last_space = false;
    for c in s.chars() {
        if c.is_ascii_digit() || c == '+' || c == '-' || c == '.' {
            if !last_hash {
                out.push('#');
            }
            last_hash = true;
            last_space = false;
        } else if c == ' ' {
            if !last_space {
                out.push(' ');
            }
            last_space = true;
            last_hash = false;
        } else {
            out.push(c);
            last_hash = false;
            last_space = false;
        }
    }
    out
}

fn assert_matches_fixture(section: &str, fixture: &str) {
    let expected =
        std::fs::read_to_string(fixture).unwrap_or_else(|e| panic!("{fixture}: {e}"));
    assert_eq!(normalize_numbers(section), expected, "raw section:\n{section}");
}

#[test]
fn golden_2d5pt_snb() {
    let s = advise("advise kernels/2d-5pt.c -m machines/snb.yml -D N 6000 -D M 6000");
    assert_matches_fixture(&s, "rust/tests/fixtures/advise/2d-5pt_snb.expected");
    // hand-derived breakpoints: 4 rows (3 of `a`, 1 of `b`) × 8 B per
    // inner element ⇒ slope 32 B, no constant part, so the condition on
    // j flips at cache_bytes / 32 per level
    assert!(s.contains("L1    | j   |      32 |       0 |       1024"), "{s}");
    assert!(s.contains("L2    | j   |      32 |       0 |       8192"), "{s}");
    assert!(s.contains("L3    | j   |      32 |       0 |     655360"), "{s}");
    // only the L1 breakpoint lies below the current extent, so the
    // advice is a single candidate, and the whole run stays analytic
    assert!(s.contains("offset-walk levels across sub-evaluations: 0"), "{s}");
    assert!(s.contains("1. block i at 1024: unlocks j@L1"), "{s}");
    assert!(!s.contains("2. block"), "{s}");
}

#[test]
fn golden_2d5pt_snb_json_round_trips() {
    let out = advise(
        "advise kernels/2d-5pt.c -m machines/snb.yml -D N 6000 -D M 6000 --format json",
    );
    let report = AnalysisReport::from_json(&out).unwrap();
    let a = report.advise.expect("advise run must carry the advise section");
    assert_eq!(a.varied_dim, "i");
    assert_eq!(a.varied_constant, "N");
    assert_eq!(a.current_extent, 6000);
    assert_eq!(a.walk_levels, 0);
    assert_eq!(a.breakpoints.len(), 3);
    assert_eq!(
        a.breakpoints.iter().map(|b| b.extent).collect::<Vec<_>>(),
        [1024, 8192, 655360]
    );
    assert_eq!(a.candidates.len(), 1);
    assert_eq!(a.candidates[0].extent, 1024);
    assert_eq!(a.candidates[0].unlocks, ["j@L1"]);
}

#[test]
fn golden_3d7pt_snb() {
    let s = advise("advise kernels/3d-7pt.c -m machines/snb.yml -D M 400 -D N 400 -D P 6000");
    assert_matches_fixture(&s, "rust/tests/fixtures/advise/3d-7pt_snb.expected");
    // two conditions depend on the inner extent P: the j-rows (4 rows ×
    // 8 B = 32 B/element) and the k-planes (4 planes × N × 8 B =
    // 12800 B/element at N=400)
    assert!(s.contains("L1    | k   |   12800 |       0 |          2"), "{s}");
    assert!(s.contains("L1    | j   |      32 |       0 |       1024"), "{s}");
    assert!(s.contains("L2    | k   |   12800 |       0 |         20"), "{s}");
    assert!(s.contains("L2    | j   |      32 |       0 |       8192"), "{s}");
    assert!(s.contains("L3    | k   |   12800 |       0 |       1638"), "{s}");
    assert!(s.contains("L3    | j   |      32 |       0 |     655360"), "{s}");
    // of the six breakpoints only 1024 and 1638 are viable blocks
    // (>= 64, below the current extent 6000); the 1024 block satisfies
    // the j condition in L1 *and* the k condition in L3, so it ranks
    // first
    assert!(s.contains("1. block i at 1024: unlocks j@L1, k@L3"), "{s}");
    assert!(s.contains("2. block i at 1638: unlocks k@L3"), "{s}");
    assert!(s.contains("offset-walk levels across sub-evaluations: 0"), "{s}");
}
