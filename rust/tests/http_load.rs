//! Load, soak, and framing-torture tests for the event-driven HTTP
//! front end (`rust/src/server/reactor.rs`):
//!
//! * the soak test that is impossible on a thread-per-connection pool —
//!   500 idle keep-alive connections against a 4-worker server with
//!   `/healthz` still answering inside a tight deadline;
//! * byte-level framing torture: dribbled headers, pipelined requests,
//!   FIN mid-header, an oversized header line straddling read
//!   boundaries, and the 411/501/100-continue protocol edges;
//! * the idle-timeout contract: a silent keep-alive connection is
//!   reaped while a concurrently active one survives;
//! * prompt shutdown with hundreds of idle connections open and an
//!   in-flight response that must still be delivered.

use kerncraft::server::{Server, ServerHandle, ServerOptions};
use kerncraft::session::AnalysisReport;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::mpsc;
use std::time::{Duration, Instant};

fn start(
    threads: usize,
    idle_timeout: Duration,
) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let server = Server::bind(ServerOptions {
        listen: "127.0.0.1:0".to_string(),
        threads,
        cache_dir: None,
        max_body_bytes: 1 << 20,
        idle_timeout,
        verbose: false,
    })
    .unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().unwrap());
    (addr, handle, join)
}

/// Join the server thread under a watchdog: a shutdown that hangs
/// fails the test instead of hanging the suite.
fn join_within(join: std::thread::JoinHandle<()>, secs: u64, what: &str) {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(join.join());
    });
    match rx.recv_timeout(Duration::from_secs(secs)) {
        Ok(outcome) => outcome.unwrap(),
        Err(_) => panic!("{what}: server did not shut down within {secs}s"),
    }
}

/// One full request on a fresh connection (`Connection: close`).
fn send(addr: SocketAddr, raw: &[u8]) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(raw).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    parse_response(&buf)
}

fn parse_response(buf: &[u8]) -> (u16, String) {
    let text = String::from_utf8_lossy(buf).to_string();
    let (head, body) = text.split_once("\r\n\r\n").unwrap_or_else(|| panic!("{text}"));
    let status_line = head.lines().next().unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    (status, body.to_string())
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    let raw = format!("GET {path} HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n");
    send(addr, raw.as_bytes())
}

/// Read one response from a persistent (keep-alive) connection.
fn read_response(r: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

/// Scrape one numeric sample from a `/metrics` exposition.
fn metric(text: &str, name: &str) -> u64 {
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix(name) {
            if let Ok(v) = rest.trim().parse() {
                return v;
            }
        }
    }
    panic!("metric {name} missing from:\n{text}");
}

const KEEPALIVE_HEALTHZ: &[u8] = b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n";

const TRIAD: &str = r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#;

#[test]
fn soak_500_idle_keepalive_connections_served_by_4_workers() {
    let (addr, handle, join) = start(4, Duration::from_secs(60));

    // open 500 keep-alive connections; each proves liveness with one
    // round-trip, then sits idle. Holding the streams keeps them open.
    // (The round-trip also paces the opens so the listener backlog
    // never overflows.)
    let mut conns: Vec<(TcpStream, BufReader<TcpStream>)> = Vec::with_capacity(500);
    for i in 0..500 {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut s = &stream;
        s.write_all(KEEPALIVE_HEALTHZ).unwrap();
        let (status, body) = read_response(&mut reader);
        assert_eq!(status, 200, "connection {i}: {body}");
        conns.push((stream, reader));
    }

    // with every connection idle, a fresh health probe must answer
    // promptly — on the old thread-per-connection pool the 4 workers
    // would all be pinned by idle sockets and this would time out
    let t0 = Instant::now();
    let (status, body) = get(addr, "/healthz");
    let elapsed = t0.elapsed();
    assert_eq!(status, 200, "{body}");
    assert!(elapsed < Duration::from_secs(5), "healthz took {elapsed:?} under soak");

    // gauges reconcile: all 500 are still open, nothing is queued on
    // the evaluation workers, and nobody has idled out
    let (_, metrics) = get(addr, "/metrics");
    assert!(metric(&metrics, "kerncraft_open_connections") >= 500, "{metrics}");
    assert!(metric(&metrics, "kerncraft_connections_total") >= 501, "{metrics}");
    assert_eq!(metric(&metrics, "kerncraft_queue_depth"), 0, "{metrics}");
    assert_eq!(metric(&metrics, "kerncraft_idle_timeouts_total"), 0, "{metrics}");

    // shutdown with all 500 still open must be prompt
    handle.stop();
    join_within(join, 30, "soak");
    drop(conns);
}

#[test]
fn dribbled_header_bytes_parse_once_complete() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let raw = b"GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n";
    for chunk in raw.chunks(1) {
        stream.write_all(chunk).unwrap();
        stream.flush().unwrap();
        std::thread::sleep(Duration::from_millis(1));
    }
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let (status, body) = parse_response(&buf);
    assert_eq!(status, 200, "{body}");
    handle.stop();
    join_within(join, 30, "dribble");
}

#[test]
fn two_pipelined_requests_in_one_segment_get_two_responses() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    // both requests arrive in one write; the second closes the
    // connection so read_to_end terminates
    let raw = b"GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n\
                GET /healthz HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n";
    stream.write_all(raw).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let responses = text.matches("HTTP/1.1 200 OK").count();
    assert_eq!(responses, 2, "two responses expected:\n{text}");
    handle.stop();
    join_within(join, 30, "pipelined");
}

#[test]
fn pipelined_evaluation_requests_answer_in_order() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // two /analyze requests pipelined in one segment: the second must
    // wait for the first response (one in-flight request per
    // connection) and both must come back in order
    let mut raw = Vec::new();
    for id in ["p1", "p2"] {
        let body = format!(
            r#"{{"id": "{id}", "kernel": {{"name": "triad"}}, "machine": "SNB", "constants": {{"N": 65536}}}}"#
        );
        raw.extend_from_slice(
            format!(
                "POST /analyze HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        );
    }
    stream.write_all(&raw).unwrap();
    for id in ["p1", "p2"] {
        let (status, body) = read_response(&mut reader);
        assert_eq!(status, 200, "{body}");
        let report = AnalysisReport::from_json(&body).unwrap();
        assert_eq!(report.id.as_deref(), Some(id));
    }
    handle.stop();
    join_within(join, 30, "pipelined-analyze");
}

#[test]
fn partial_header_then_fin_answers_400() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(b"GET /healthz HTT").unwrap();
    stream.shutdown(std::net::Shutdown::Write).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let (status, body) = parse_response(&buf);
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("error"), "{body}");
    handle.stop();
    join_within(join, 30, "fin-mid-header");
}

#[test]
fn oversized_header_line_straddling_reads_is_rejected() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    // a request line over the 8 KiB cap, no newline ever sent, split
    // across two writes so the limit must fire on a partial buffer
    let body = vec![b'a'; 9 << 10];
    stream.write_all(b"GET /").unwrap();
    stream.write_all(&body[..4096]).unwrap();
    stream.flush().unwrap();
    std::thread::sleep(Duration::from_millis(20));
    stream.write_all(&body[4096..]).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let (status, body) = parse_response(&buf);
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("exceeds"), "{body}");
    handle.stop();
    join_within(join, 30, "oversized-line");
}

#[test]
fn protocol_limit_statuses_are_unchanged() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    // POST without Content-Length → 411
    let no_length = b"POST /analyze HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n";
    let (status, body) = send(addr, no_length);
    assert_eq!(status, 411, "{body}");
    // chunked transfer encoding → 501
    let chunked =
        b"POST /analyze HTTP/1.1\r\nhost: t\r\ntransfer-encoding: chunked\r\nconnection: close\r\n\r\n";
    let (status, body) = send(addr, chunked);
    assert_eq!(status, 501, "{body}");
    // declared length over the cap → 413 before any body byte
    let huge =
        b"POST /analyze HTTP/1.1\r\nhost: t\r\ncontent-length: 99999999\r\nconnection: close\r\n\r\n";
    let (status, body) = send(addr, huge);
    assert_eq!(status, 413, "{body}");
    handle.stop();
    join_within(join, 30, "limit-statuses");
}

#[test]
fn expect_continue_gets_interim_response_before_body() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let head = format!(
        "POST /analyze HTTP/1.1\r\nhost: t\r\nexpect: 100-continue\r\ncontent-length: {}\r\nconnection: close\r\n\r\n",
        TRIAD.len()
    );
    stream.write_all(head.as_bytes()).unwrap();
    // the interim response arrives before any body byte is sent
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    assert!(line.starts_with("HTTP/1.1 100 Continue"), "{line}");
    let mut blank = String::new();
    reader.read_line(&mut blank).unwrap();
    assert_eq!(blank.trim_end(), "", "interim response ends with a blank line");
    // now the body; the real response follows
    stream.write_all(TRIAD.as_bytes()).unwrap();
    let (status, body) = read_response(&mut reader);
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("\"kernel\": \"triad\""), "{body}");
    handle.stop();
    join_within(join, 30, "expect-continue");
}

#[test]
fn idle_connections_are_reaped_while_active_ones_survive() {
    let (addr, handle, join) = start(2, Duration::from_secs(1));

    // the silent connection: never sends a byte
    let mut silent = TcpStream::connect(addr).unwrap();
    silent.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    let t0 = Instant::now();

    // the active connection: a request every 300 ms, comfortably past
    // several idle windows in total
    let active = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut s = &stream;
        for i in 0..8 {
            s.write_all(KEEPALIVE_HEALTHZ).unwrap();
            let (status, body) = read_response(&mut reader);
            assert_eq!(status, 200, "active request {i}: {body}");
            std::thread::sleep(Duration::from_millis(300));
        }
    });

    // the server reaps the silent connection: EOF, no response bytes
    let mut buf = Vec::new();
    silent.read_to_end(&mut buf).unwrap();
    let elapsed = t0.elapsed();
    assert!(buf.is_empty(), "reap is silent, got {buf:?}");
    assert!(elapsed < Duration::from_secs(10), "reap took {elapsed:?}");

    active.join().unwrap();

    let (_, metrics) = get(addr, "/metrics");
    assert!(metric(&metrics, "kerncraft_idle_timeouts_total") >= 1, "{metrics}");

    handle.stop();
    join_within(join, 30, "idle-timeout");
}

#[test]
fn shutdown_with_open_connections_is_prompt_and_delivers_in_flight_responses() {
    let (addr, handle, join) = start(2, Duration::from_secs(60));

    // 50 idle keep-alive connections that will still be open at stop
    let mut idle = Vec::with_capacity(50);
    for _ in 0..50 {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut s = &stream;
        s.write_all(KEEPALIVE_HEALTHZ).unwrap();
        let (status, _) = read_response(&mut reader);
        assert_eq!(status, 200);
        idle.push((stream, reader));
    }

    // one in-flight evaluation: send the request, then stop the server
    // once /metrics proves it was dispatched
    let mut busy = TcpStream::connect(addr).unwrap();
    busy.set_read_timeout(Some(Duration::from_secs(60))).unwrap();
    let mut busy_reader = BufReader::new(busy.try_clone().unwrap());
    let raw = format!(
        "POST /analyze HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{TRIAD}",
        TRIAD.len()
    );
    busy.write_all(raw.as_bytes()).unwrap();
    let t0 = Instant::now();
    loop {
        let (_, metrics) = get(addr, "/metrics");
        if metric(&metrics, "kerncraft_requests_total{endpoint=\"analyze\"}") >= 1 {
            break;
        }
        assert!(t0.elapsed() < Duration::from_secs(20), "request never dispatched");
    }
    handle.stop();

    // the in-flight response is still delivered in full
    let (status, body) = read_response(&mut busy_reader);
    assert_eq!(status, 200, "{body}");
    let report = AnalysisReport::from_json(&body).unwrap();
    assert_eq!(report.kernel, "triad");

    // and shutdown completes promptly despite the 50 open connections
    join_within(join, 30, "shutdown");

    // the idle connections were closed by the server, not left hanging
    for (_, reader) in idle.iter_mut() {
        let mut rest = Vec::new();
        reader.read_to_end(&mut rest).unwrap();
        assert!(rest.is_empty(), "idle connection got bytes at shutdown: {rest:?}");
    }
}
