//! End-to-end `kerncraft serve --listen`: real TCP connections against
//! a bound [`kerncraft::server::Server`] — endpoint routing and status
//! codes, two concurrent keep-alive connections through a 4-worker
//! pool, the `/batch` index-carrying error shape, the `/stream`
//! JSON-lines pass-through, and the warm-restart contract of
//! `--cache-dir`: a fresh process answers a repeated request
//! byte-identically from disk without re-running any pipeline stage.

use kerncraft::server::{Server, ServerHandle, ServerOptions};
use kerncraft::session::AnalysisReport;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::path::PathBuf;

fn start(threads: usize, cache_dir: Option<PathBuf>) -> (SocketAddr, ServerHandle, std::thread::JoinHandle<()>) {
    let server = Server::bind(ServerOptions {
        listen: "127.0.0.1:0".to_string(),
        threads,
        cache_dir,
        max_body_bytes: 1 << 20,
        idle_timeout: std::time::Duration::from_secs(30),
        verbose: false,
    })
    .unwrap();
    let addr = server.local_addr();
    let handle = server.handle();
    let join = std::thread::spawn(move || server.run().unwrap());
    (addr, handle, join)
}

/// One full request on a fresh connection (`Connection: close`).
fn send(addr: SocketAddr, raw: &str) -> (u16, String) {
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.write_all(raw.as_bytes()).unwrap();
    let mut buf = Vec::new();
    stream.read_to_end(&mut buf).unwrap();
    let text = String::from_utf8(buf).unwrap();
    let (head, body) = text.split_once("\r\n\r\n").unwrap_or_else(|| panic!("{text}"));
    let status: u16 = head
        .lines()
        .next()
        .unwrap()
        .split_whitespace()
        .nth(1)
        .unwrap()
        .parse()
        .unwrap();
    (status, body.to_string())
}

fn get(addr: SocketAddr, path: &str) -> (u16, String) {
    send(addr, &format!("GET {path} HTTP/1.1\r\nhost: t\r\nconnection: close\r\n\r\n"))
}

fn post(addr: SocketAddr, path: &str, body: &str) -> (u16, String) {
    send(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\nconnection: close\r\n\r\n{body}",
            body.len()
        ),
    )
}

/// Read one response from a persistent (keep-alive) connection.
fn read_response(r: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    r.read_line(&mut line).unwrap();
    let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        r.read_line(&mut h).unwrap();
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

const TRIAD: &str =
    r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#;

#[test]
fn endpoints_route_and_report_statuses() {
    let (addr, handle, join) = start(2, None);

    let (status, body) = get(addr, "/healthz");
    assert_eq!(status, 200, "{body}");
    assert!(body.contains("ok"), "{body}");

    let (status, body) = post(addr, "/analyze", TRIAD);
    assert_eq!(status, 200, "{body}");
    let report = AnalysisReport::from_json(&body).unwrap();
    assert_eq!(report.kernel, "triad");
    assert!(report.ecm.is_some());

    // malformed JSON → 400; valid request that fails evaluation → 422
    let (status, body) = post(addr, "/analyze", "not json");
    assert_eq!(status, 400, "{body}");
    assert!(body.contains("\"error\""), "{body}");
    let (status, body) = post(
        addr,
        "/analyze",
        r#"{"id": "r1", "kernel": {"name": "nope"}, "machine": "SNB"}"#,
    );
    assert_eq!(status, 422, "{body}");
    assert!(body.contains("\"id\": \"r1\""), "{body}");

    // routing: unknown path, disallowed method, oversized declaration
    let (status, _) = get(addr, "/nope");
    assert_eq!(status, 404);
    let (status, _) = get(addr, "/analyze");
    assert_eq!(status, 405);
    let (status, body) = send(
        addr,
        "POST /analyze HTTP/1.1\r\nhost: t\r\ncontent-length: 99999999\r\nconnection: close\r\n\r\n",
    );
    assert_eq!(status, 413, "{body}");

    let (status, metrics) = get(addr, "/metrics");
    assert_eq!(status, 200);
    // four hits on /analyze: 200, 400, 422, and the 405 (wrong method
    // on a known path still counts against that endpoint)
    assert!(
        metrics.contains("kerncraft_requests_total{endpoint=\"analyze\"} 4"),
        "{metrics}"
    );
    assert!(
        metrics.contains("kerncraft_errors_total{endpoint=\"analyze\"} 3"),
        "{metrics}"
    );
    assert!(metrics.contains("kerncraft_memo_misses_total{stage=\"program\"}"), "{metrics}");
    assert!(!metrics.contains("report_cache"), "no cache configured: {metrics}");

    handle.stop();
    join.join().unwrap();
}

#[test]
fn two_concurrent_keepalive_connections_share_the_pool() {
    let (addr, handle, join) = start(4, None);

    let client = |tag: &'static str| {
        std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let mut reader = BufReader::new(stream.try_clone().unwrap());
            for i in 0..5 {
                // vary N so both cold and warm session paths are hit
                let body = format!(
                    r#"{{"id": "{tag}-{i}", "kernel": {{"name": "triad"}}, "machine": "SNB", "constants": {{"N": {}}}}}"#,
                    65536 + i
                );
                let raw = format!(
                    "POST /analyze HTTP/1.1\r\nhost: t\r\ncontent-length: {}\r\n\r\n{body}",
                    body.len()
                );
                stream.write_all(raw.as_bytes()).unwrap();
                let (status, resp) = read_response(&mut reader);
                assert_eq!(status, 200, "{resp}");
                let report = AnalysisReport::from_json(&resp).unwrap();
                assert_eq!(report.id.as_deref(), Some(format!("{tag}-{i}").as_str()));
                assert_eq!(report.kernel, "triad");
            }
        })
    };
    let a = client("a");
    let b = client("b");
    a.join().unwrap();
    b.join().unwrap();

    let (_, metrics) = get(addr, "/metrics");
    assert!(
        metrics.contains("kerncraft_requests_total{endpoint=\"analyze\"} 10"),
        "{metrics}"
    );
    // both clients talked over their own accepted connection
    let conns: u64 = metrics
        .lines()
        .find_map(|l| l.strip_prefix("kerncraft_connections_total "))
        .unwrap()
        .parse()
        .unwrap();
    assert!(conns >= 2, "{metrics}");

    handle.stop();
    join.join().unwrap();
}

#[test]
fn batch_answers_every_element_and_indexes_errors() {
    let (addr, handle, join) = start(4, None);
    let body = format!(
        r#"[{TRIAD}, {{"id": "bad", "kernel": {{"name": "nope"}}, "machine": "SNB"}}, {TRIAD}]"#
    );
    let (status, text) = post(addr, "/batch", &body);
    assert_eq!(status, 200, "{text}");
    let v = kerncraft::jsonio::parse(&text).unwrap();
    let items = v.items();
    assert_eq!(items.len(), 3, "{text}");
    assert!(items[0].get("ecm").is_some(), "{text}");
    assert_eq!(items[1].get("index").and_then(|x| x.as_u64()), Some(1), "{text}");
    assert_eq!(items[1].get("id").and_then(|x| x.as_str()), Some("bad"), "{text}");
    assert!(items[1].get("error").is_some(), "{text}");
    assert!(items[2].get("ecm").is_some(), "{text}");

    let (status, text) = post(addr, "/batch", "{}");
    assert_eq!(status, 400, "{text}");

    handle.stop();
    join.join().unwrap();
}

#[test]
fn stream_endpoint_carries_the_json_lines_protocol() {
    let (addr, handle, join) = start(2, None);
    // three physical lines: comment, good request, malformed request
    let body = concat!(
        "# comment\n",
        r#"{"id": "s1", "kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#,
        "\n",
        "not json\n"
    );
    let (status, text) = post(addr, "/stream", body);
    assert_eq!(status, 200, "{text}");
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), 2, "{text}");
    let report = AnalysisReport::from_json(lines[0]).unwrap();
    assert_eq!(report.id.as_deref(), Some("s1"));
    // the error line names the offending physical line of the body
    assert!(lines[1].contains("\"line\": 3"), "{}", lines[1]);
    assert!(lines[1].contains("\"error\""), "{}", lines[1]);

    let (_, metrics) = get(addr, "/metrics");
    assert!(
        metrics.contains("kerncraft_requests_total{endpoint=\"stream\"} 1"),
        "{metrics}"
    );
    assert!(metrics.contains("kerncraft_errors_total{endpoint=\"stream\"} 1"), "{metrics}");

    handle.stop();
    join.join().unwrap();
}

#[test]
fn warm_restart_serves_byte_identical_reports_from_cache_dir() {
    let dir = std::env::temp_dir()
        .join(format!("kerncraft_http_e2e_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let request = r#"{"id": "w", "kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#;

    // first server: cold cache — evaluates and stores
    let (addr_a, handle_a, join_a) = start(2, Some(dir.clone()));
    let (status, body_first) = post(addr_a, "/analyze", request);
    assert_eq!(status, 200, "{body_first}");
    let (_, metrics) = get(addr_a, "/metrics");
    assert!(metrics.contains("kerncraft_report_cache_hits_total 0"), "{metrics}");
    assert!(metrics.contains("kerncraft_report_cache_misses_total 1"), "{metrics}");
    assert!(metrics.contains("kerncraft_report_cache_stores_total 1"), "{metrics}");
    // kill the server
    handle_a.stop();
    join_a.join().unwrap();

    // fresh process stand-in: a brand-new server (new Session, new
    // caches) over the same directory answers from disk
    let (addr_b, handle_b, join_b) = start(2, Some(dir.clone()));
    let (status, body_again) = post(addr_b, "/analyze", request);
    assert_eq!(status, 200, "{body_again}");
    assert_eq!(body_again, body_first, "cached answer must be byte-identical");
    let (_, metrics) = get(addr_b, "/metrics");
    assert!(metrics.contains("kerncraft_report_cache_hits_total 1"), "{metrics}");
    assert!(metrics.contains("kerncraft_report_cache_misses_total 0"), "{metrics}");
    // no pipeline stage ran in the fresh process: every memo counter is
    // still zero — the MemoStats proof that the analysis was not re-run
    assert!(
        metrics.contains("kerncraft_memo_misses_total{stage=\"program\"} 0"),
        "{metrics}"
    );
    assert!(
        metrics.contains("kerncraft_memo_misses_total{stage=\"machine\"} 0"),
        "{metrics}"
    );
    assert!(
        metrics.contains("kerncraft_memo_misses_total{stage=\"incore\"} 0"),
        "{metrics}"
    );

    // a different request still evaluates (and seeds the cache for it)
    let other = r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 131072}}"#;
    let (status, _) = post(addr_b, "/analyze", other);
    assert_eq!(status, 200);
    let (_, metrics) = get(addr_b, "/metrics");
    assert!(metrics.contains("kerncraft_report_cache_stores_total 1"), "{metrics}");

    handle_b.stop();
    join_b.join().unwrap();
    let _ = std::fs::remove_dir_all(&dir);
}
