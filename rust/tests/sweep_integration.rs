//! Sweep subsystem integration: the CLI subcommand over the shipped
//! kernel corpus, the serial-equals-parallel guarantee against plain
//! `analyze`-style pipelines, and the layer-condition fast path
//! observability (acceptance criteria of the sweep PR).

use kerncraft::cache::{CachePredictor, CachePredictorKind};
use kerncraft::cli;
use kerncraft::incore::{CodegenPolicy, PortModel};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::EcmModel;
use kerncraft::sweep::{build_jobs, SweepEngine};
use std::collections::HashMap;
use std::sync::Arc;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(str::to_string).collect()
}

#[test]
fn sweep_cli_csv_row_count_and_header() {
    // 9 N-points x 2 machines = 18 rows + 1 header
    let out = cli::run(&argv(
        "sweep -m SNB,HSW kernels/2d-5pt.c -D N 128:32k:log2 -D M 4000 --threads 4",
    ))
    .unwrap();
    let lines: Vec<&str> = out.lines().filter(|l| !l.starts_with('#')).collect();
    assert_eq!(lines.len(), 1 + 9 * 2, "{out}");
    assert!(lines[0].contains("kernel,machine,cores,predictor,M,N"), "{}", lines[0]);
    assert!(lines[1].starts_with("2d-5pt,SNB,1,auto"), "{}", lines[1]);
    assert!(out.contains("2d-5pt,HSW"), "{out}");
}

#[test]
fn sweep_cli_json_format() {
    let out = cli::run(&argv(
        "sweep -m SNB kernels/triad.c -D N 1k:16k:log2 --format json",
    ))
    .unwrap();
    assert!(out.contains("\"rows\": ["), "{out}");
    assert!(out.contains("\"t_ecm_mem\""), "{out}");
    assert!(out.contains("\"lc_fast_levels\""), "{out}");
    assert_eq!(out.matches("\"kernel\": \"triad\"").count(), 5, "{out}");
}

#[test]
fn sweep_cli_accepts_table5_tags() {
    // a Table 5 tag instead of a file path resolves to the embedded source
    let out = cli::run(&argv("sweep -m SNB 2D-5pt -D N 256:1k:log2 -D M 2000")).unwrap();
    assert!(out.lines().count() >= 4, "{out}");
}

#[test]
fn sweep_over_32_points_matches_serial_analyze_calls() {
    // The acceptance criterion: >= 32 grid points, parallel+memoized
    // engine output identical to one-by-one serial pipeline runs.
    let src = kerncraft::models::reference::KERNEL_2D5PT;
    let ns: Vec<i64> = (7..23).map(|e| 1i64 << e).collect(); // 16 sizes
    let machines = ["SNB".to_string(), "HSW".to_string()];
    let jobs = build_jobs(
        "2d-5pt",
        Arc::from(src),
        &machines,
        &[1],
        &[("N".to_string(), ns.clone()), ("M".to_string(), vec![4000])],
        CachePredictorKind::Auto,
    );
    assert_eq!(jobs.len(), 32);
    let out = SweepEngine::new().run(&jobs).unwrap();

    let program = parse(src).unwrap();
    for (job, row) in jobs.iter().zip(&out.rows) {
        let machine = MachineModel::builtin(&job.machine).unwrap();
        let consts: HashMap<String, i64> =
            job.constants.iter().map(|(k, v)| (k.clone(), *v)).collect();
        let analysis = KernelAnalysis::from_program(&program, &consts).unwrap();
        let pm =
            PortModel::analyze(&analysis, &machine, &CodegenPolicy::for_machine(&machine))
                .unwrap();
        let traffic = CachePredictor::with_kind(&machine, job.cores, job.predictor)
            .predict(&analysis)
            .unwrap();
        let ecm = EcmModel::build(&pm, &traffic, &machine).unwrap();
        assert_eq!(row.t_ecm_mem, ecm.t_mem(), "{:?}", job.constants);
        assert_eq!(row.t_ol, ecm.t_ol);
        assert_eq!(row.t_nol, ecm.t_nol);
        for (link, c) in row.links.iter().zip(&ecm.contributions) {
            assert_eq!(link.1, c.lines, "{} at {:?}", link.0, job.constants);
            assert_eq!(link.2, c.cycles);
        }
    }
}

#[test]
fn auto_predictor_skips_the_walk_when_decisive() {
    // Jacobi at a clearly-decisive size: all three levels answered by the
    // layer conditions; the offset walk never runs (stage-counter hook).
    let src = kerncraft::models::reference::KERNEL_2D5PT;
    let jobs = build_jobs(
        "2d-5pt",
        Arc::from(src),
        &["SNB".to_string()],
        &[1],
        &[("N".to_string(), vec![4000]), ("M".to_string(), vec![4000])],
        CachePredictorKind::Auto,
    );
    let out = SweepEngine::serial().run(&jobs).unwrap();
    assert_eq!(out.rows[0].walk_levels, 0, "{:?}", out.rows[0]);
    assert_eq!(out.rows[0].lc_fast_levels, 3);

    // same point with the offsets predictor: everything walks
    let jobs = build_jobs(
        "2d-5pt",
        Arc::from(src),
        &["SNB".to_string()],
        &[1],
        &[("N".to_string(), vec![4000]), ("M".to_string(), vec![4000])],
        CachePredictorKind::Offsets,
    );
    let out_walk = SweepEngine::serial().run(&jobs).unwrap();
    assert_eq!(out_walk.rows[0].lc_fast_levels, 0);
    assert_eq!(out_walk.rows[0].walk_levels, 3);
    // and the numbers agree
    assert_eq!(out.rows[0].links, out_walk.rows[0].links);
    assert_eq!(out.rows[0].t_ecm_mem, out_walk.rows[0].t_ecm_mem);
}

#[test]
fn multi_core_sweep_partitions_shared_caches() {
    let src = kerncraft::models::reference::KERNEL_2D5PT;
    let jobs = build_jobs(
        "2d-5pt",
        Arc::from(src),
        &["SNB".to_string()],
        &[1, 8],
        &[("N".to_string(), vec![6000]), ("M".to_string(), vec![6000])],
        CachePredictorKind::Offsets,
    );
    // serial engine: memo counters are deterministic (no racing misses)
    let out = SweepEngine::serial().run(&jobs).unwrap();
    assert_eq!(out.rows.len(), 2);
    assert_eq!(out.rows[0].cores, 1);
    assert_eq!(out.rows[1].cores, 8);
    // memory traffic can only grow when the L3 share shrinks
    assert!(out.rows[1].memory_bytes_per_unit >= out.rows[0].memory_bytes_per_unit);
    // the in-core product was shared: one incore miss for both points
    assert_eq!(out.stats.incore_misses, 1, "{:?}", out.stats);
    assert_eq!(out.stats.incore_hits, 1);
}
