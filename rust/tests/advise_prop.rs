//! Property suite for the analytic blocking adviser (DESIGN.md §5).
//!
//! The adviser's whole value is that it *solves* the layer-condition
//! inequalities instead of sweeping problem sizes, so the pinning tests
//! are adversarial on exactly that claim:
//!
//! * over a hundred randomized 2-D stencil shapes and sizes, the solved
//!   breakpoints must agree with a brute-force layer-condition
//!   evaluation — the condition holds at the solved extent and breaks
//!   one element past it (the bound is inclusive);
//! * for a few seeds the flip point is re-derived by an exhaustive
//!   linear scan, not just probed at the solved value;
//! * the advise path itself must be deterministic across fresh
//!   sessions, must never recommend a block that predicts more memory
//!   traffic than the unblocked baseline, and must report zero
//!   offset-walk levels ([`PredictorStats`] plumbed through the
//!   report) — i.e. no sweep and no walk anywhere on the fast path;
//! * the analytic predictor and the offset walk must agree on per-level
//!   traffic for the five paper kernels at sizes strictly between
//!   adjacent breakpoints, where the steady-state assumption behind the
//!   layer conditions is uncontested.

use kerncraft::cache::{solve_lc_breakpoints, CachePredictor, CachePredictorKind};
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::MachineModel;
use kerncraft::models::reference;
use kerncraft::session::{AnalysisRequest, KernelSpec, ModelKind, Session};
use kerncraft::util::XorShift64;
use std::collections::HashMap;

fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
    pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
}

fn analyze(src: &str, pairs: &[(&str, i64)]) -> KernelAnalysis {
    let program = parse(src).unwrap();
    KernelAnalysis::from_program(&program, &consts(pairs)).unwrap()
}

/// Brute-force verdict of the layer condition `(level, dim_index)` at
/// inner extent `n`: rebuild the analysis and read the condition table
/// off a forced-LayerConditions prediction.
fn lc_satisfied(
    src: &str,
    machine: &MachineModel,
    m: i64,
    n: i64,
    level: &str,
    dim_index: usize,
) -> bool {
    let analysis = analyze(src, &[("M", m), ("N", n)]);
    let t = CachePredictor::with_kind(machine, 1, CachePredictorKind::LayerConditions)
        .predict(&analysis)
        .unwrap();
    t.layer_conditions
        .iter()
        .find(|e| e.level == level && e.dim_index == dim_index)
        .map(|e| e.satisfied)
        .unwrap_or(false)
}

/// A random 2-D stencil `b[j][i] = (Σ a[j+dj][i+di]) * s` with 2–6
/// distinct read offsets in `[-2, 2]²` (always including the center).
/// Loop margins of 3 keep every offset in bounds.
fn random_stencil(rng: &mut XorShift64) -> String {
    let mut offsets = vec![(0i64, 0i64)];
    for _ in 0..(1 + rng.next_below(5)) {
        let dj = rng.next_range(-2, 2);
        let di = rng.next_range(-2, 2);
        if !offsets.contains(&(dj, di)) {
            offsets.push((dj, di));
        }
    }
    let idx = |v: &str, d: i64| match d {
        0 => v.to_string(),
        d if d > 0 => format!("{v}+{d}"),
        d => format!("{v}{d}"),
    };
    let reads: Vec<String> = offsets
        .iter()
        .map(|&(dj, di)| format!("a[{}][{}]", idx("j", dj), idx("i", di)))
        .collect();
    format!(
        "double a[M][N], b[M][N], s;\nfor (int j = 3; j < M - 3; j++)\n  for (int i = 3; i < N - 3; i++)\n    b[j][i] = ({}) * s;",
        reads.join(" + ")
    )
}

#[test]
fn analytic_breakpoints_agree_with_brute_force_layer_conditions() {
    let machine = MachineModel::snb();
    let mut rng = XorShift64::new(0x5EED_AD51);
    let mut checked = 0usize;
    for case in 0..110 {
        let src = random_stencil(&mut rng);
        let n = 3000 + rng.next_below(5000) as i64;
        let m = 64 + rng.next_below(512) as i64;
        let analysis = analyze(&src, &[("M", m), ("N", n)]);
        let solve = solve_lc_breakpoints(&analysis, &machine, 1).unwrap();
        assert_eq!(solve.varied_dim, "i", "case {case}\n{src}");
        assert_eq!(solve.current_extent, n as u64, "case {case}\n{src}");
        // a 2-D stencil has one extent-dependent condition per cache
        // level (the outer dimension j); the inner condition is constant
        assert_eq!(solve.breakpoints.len(), 3, "case {case}\n{src}");
        for b in &solve.breakpoints {
            assert_eq!(b.dim_name, "j", "case {case}\n{src}");
            assert_eq!(b.dim_index, 0, "case {case}\n{src}");
            assert_eq!(b.const_bytes, 0, "case {case}\n{src}");
            assert!(b.slope_bytes > 0, "case {case}\n{src}");
            // the solved extent is the exact flip point of the
            // brute-force evaluation: satisfied there, broken one past
            // it (inclusive bound, so the ±1 window is tight)
            assert!(
                lc_satisfied(&src, &machine, m, b.extent as i64, &b.level, b.dim_index),
                "case {case}: {}@{} must hold at solved extent {}\n{src}",
                b.dim_name,
                b.level,
                b.extent
            );
            assert!(
                !lc_satisfied(&src, &machine, m, b.extent as i64 + 1, &b.level, b.dim_index),
                "case {case}: {}@{} must break at {}\n{src}",
                b.dim_name,
                b.level,
                b.extent + 1
            );
            checked += 1;
        }
    }
    assert!(checked >= 300, "suite must check >= 100 randomized cases, got {checked}");
}

#[test]
fn l1_breakpoint_matches_an_exhaustive_linear_scan() {
    let machine = MachineModel::snb();
    let mut rng = XorShift64::new(7);
    for _ in 0..3 {
        let src = random_stencil(&mut rng);
        let m = 200i64;
        let analysis = analyze(&src, &[("M", m), ("N", 6000)]);
        let solve = solve_lc_breakpoints(&analysis, &machine, 1).unwrap();
        let b = &solve.breakpoints[0]; // levels come inner→outer
        assert_eq!(b.level, "L1", "{src}");
        // scan every extent from far below the breakpoint to just past
        // it: the verdict must hold throughout and flip exactly once
        let mut first_violation = None;
        for n in 8..=(b.extent as i64 + 8) {
            if !lc_satisfied(&src, &machine, m, n, &b.level, b.dim_index) {
                first_violation = Some(n);
                break;
            }
        }
        assert_eq!(
            first_violation,
            Some(b.extent as i64 + 1),
            "scan disagrees with the solved L1 breakpoint {}\n{src}",
            b.extent
        );
    }
}

#[test]
fn advise_is_deterministic_analytic_and_never_worse() {
    let mut rng = XorShift64::new(0xBEEF);
    for case in 0..30 {
        let src = random_stencil(&mut rng);
        let n = 3000 + rng.next_below(5000) as i64;
        let m = 64 + rng.next_below(512) as i64;
        let req = AnalysisRequest::new(
            KernelSpec::source(format!("stencil-{case}"), src.clone()),
            "SNB",
        )
        .with_constant("N", n)
        .with_constant("M", m)
        .with_model(ModelKind::Advise)
        .with_predictor(CachePredictorKind::LayerConditions);
        let r1 = Session::new().evaluate(&req).unwrap();
        let r2 = Session::new().evaluate(&req).unwrap();
        let a = r1.advise.as_ref().unwrap();
        // deterministic: two fresh sessions, byte-identical advice
        assert_eq!(Some(a), r2.advise.as_ref(), "case {case}\n{src}");
        // the analytic fast path means zero offset-walk levels both in
        // the request's own prediction and across every advise
        // sub-evaluation — PredictorStats carried through the report
        let t = r1.traffic.as_ref().unwrap();
        assert_eq!(t.walk_levels, 0, "case {case}: outer prediction walked\n{src}");
        assert_eq!(
            t.lc_fast_levels as usize,
            t.levels.len(),
            "case {case}: every level must be answered analytically\n{src}"
        );
        assert_eq!(a.walk_levels, 0, "case {case}: a sub-evaluation walked\n{src}");
        // ranked advice: best first, and the top recommendation never
        // predicts more memory traffic or time than the baseline
        for w in a.candidates.windows(2) {
            assert!(w[0].t_mem <= w[1].t_mem, "case {case}: ranking broken\n{src}");
        }
        if let Some(best) = a.candidates.first() {
            assert!(
                best.memory_bytes_per_unit <= a.baseline_memory_bytes_per_unit + 1e-9,
                "case {case}: advice predicts more memory traffic than baseline\n{src}"
            );
            assert!(
                best.t_mem <= a.baseline_t_mem + 1e-9,
                "case {case}: advice predicts a slower kernel than baseline\n{src}"
            );
            assert!(best.speedup >= 1.0 - 1e-9, "case {case}\n{src}");
        }
    }
}

/// Offsets (backward walk) vs LayerConditions (analytic) agreement on
/// per-level traffic, within 1% per link.
fn assert_predictors_agree(src: &str, pairs: &[(&str, i64)], tag: &str) {
    let machine = MachineModel::snb();
    let analysis = analyze(src, pairs);
    let walk = CachePredictor::with_kind(&machine, 1, CachePredictorKind::Offsets)
        .predict(&analysis)
        .unwrap();
    let lc = CachePredictor::with_kind(&machine, 1, CachePredictorKind::LayerConditions)
        .predict(&analysis)
        .unwrap();
    assert_eq!(walk.levels.len(), lc.levels.len(), "{tag}");
    for (w, l) in walk.levels.iter().zip(lc.levels.iter()) {
        assert_eq!(w.level, l.level, "{tag}");
        let (a, b) = (w.total_lines(), l.total_lines());
        assert!(
            (a - b).abs() <= a.abs().max(1.0) * 0.01,
            "{tag} {}: walk predicts {a} lines/unit, layer conditions {b}",
            w.level
        );
    }
    let (a, b) = (walk.memory_bytes_per_unit(), lc.memory_bytes_per_unit());
    assert!(
        (a - b).abs() <= a.abs().max(1.0) * 0.01,
        "{tag} memory: walk predicts {a} B/unit, layer conditions {b}"
    );
}

#[test]
fn predictors_agree_between_breakpoints_on_the_paper_kernels() {
    // 2D-5pt: derive in-band sizes from the solved breakpoints — one
    // below the innermost breakpoint, then the midpoint of each
    // adjacent pair (capped to keep the reference walk small)
    let machine = MachineModel::snb();
    let src = reference::kernel_source("2D-5pt").unwrap();
    let base = analyze(src, &[("M", 4000), ("N", 6000)]);
    let solve = solve_lc_breakpoints(&base, &machine, 1).unwrap();
    let mut bps: Vec<u64> = solve.breakpoints.iter().map(|b| b.extent).collect();
    bps.sort_unstable();
    bps.dedup();
    assert!(bps.len() >= 2, "2D-5pt must have distinct per-level breakpoints");
    let mut sizes = vec![(bps[0] / 2) as i64];
    for w in bps.windows(2) {
        sizes.push(((w[0] + w[1]) / 2).min(120_000) as i64);
    }
    for n in sizes {
        assert_predictors_agree(src, &[("M", 4000), ("N", n)], &format!("2D-5pt N={n}"));
    }
    // the 3-D stencils share the varied extent across two dimensions
    // (a[M][N][N]), which the closed-form solve refuses — their in-band
    // sizes are fixed by hand, decisively inside a layer-condition band
    // on every level (j-rows fit L1 with >30% slack, k-planes fit L3
    // with >3x slack but overflow L2 by >20x)
    assert_predictors_agree(
        reference::kernel_source("UXX").unwrap(),
        &[("M", 64), ("N", 300)],
        "UXX",
    );
    assert_predictors_agree(
        reference::kernel_source("long-range").unwrap(),
        &[("M", 64), ("N", 256)],
        "long-range",
    );
    // the 1-D kernels stream with no inter-iteration reuse: both
    // predictors must report pure compulsory-miss traffic at any size
    assert_predictors_agree(
        reference::kernel_source("Kahan-dot").unwrap(),
        &[("N", 65536)],
        "Kahan-dot",
    );
    assert_predictors_agree(
        reference::kernel_source("triad").unwrap(),
        &[("N", 100_000)],
        "triad",
    );
}
