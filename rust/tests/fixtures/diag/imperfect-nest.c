double a[8], b[8][8];
for (int j = 0; j < 8; ++j) {
    a[j] = 0.0;
    for (int i = 0; i < 8; ++i)
        b[j][i] = a[j];
}
