double a[8], b[8];
for (int i = 0; i < 8; ++i)
    a[i] = b[i] > 0.0;
