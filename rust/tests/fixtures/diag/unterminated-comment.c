double a[8]; /* streaming buffer
for (int i = 0; i < 8; ++i) a[i] = 0.0;
