double a[8];
for (int i = 8; i > 0; --i)
    a[i] = 0.0;
