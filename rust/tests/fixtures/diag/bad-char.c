double a@[8];
