double a[8];
for (int i = 0; i < 8; ++i)
    a[i] = 2.0
