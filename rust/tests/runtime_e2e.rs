//! End-to-end PJRT runtime tests: load every AOT artifact produced by
//! `make artifacts`, execute it on the CPU PJRT client, and check the
//! numerics against the native Rust implementation of the same kernel.
//!
//! Environment-gated twice: the whole file needs the `pjrt` cargo feature
//! (the xla/xla_extension crate is not in the offline toolchain — see
//! rust/src/runtime.rs), and the tests skip with a notice when
//! `artifacts/` is absent so `cargo test --features pjrt` still works on
//! a fresh checkout; `make test` always builds the artifacts first.
#![cfg(feature = "pjrt")]

use kerncraft::bench_mode::native;
use kerncraft::runtime::{load_manifest, Runtime};
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.tsv").exists() {
        Some(dir)
    } else {
        eprintln!("skipping runtime e2e: run `make artifacts` first");
        None
    }
}

#[test]
fn all_artifacts_load_compile_and_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    let metas = load_manifest(&dir).unwrap();
    assert_eq!(metas.len(), 5, "five paper kernels expected");
    for meta in &metas {
        let loaded = rt.load(&dir, meta).unwrap_or_else(|e| panic!("{}: {e:#}", meta.name));
        let inputs = loaded.make_inputs(1).unwrap();
        let out = loaded
            .execute(&inputs)
            .unwrap_or_else(|e| panic!("executing {}: {e:#}", meta.name));
        // every kernel returns finite floating-point data
        let values: Vec<f64> = out.to_vec::<f64>().unwrap_or_default();
        assert!(!values.is_empty(), "{} returned no data", meta.name);
        assert!(
            values.iter().all(|v| v.is_finite()),
            "{} produced non-finite values",
            meta.name
        );
    }
}

#[test]
fn jacobi_artifact_matches_native_sweeps() {
    // The jacobi2d artifact runs 20 ping-pong sweeps over a 258x256 f64
    // grid. Recompute natively and compare.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let metas = load_manifest(&dir).unwrap();
    let meta = metas.iter().find(|m| m.name == "jacobi2d").unwrap();
    let loaded = rt.load(&dir, meta).unwrap();
    let inputs = loaded.make_inputs(7).unwrap();
    let out = loaded.execute(&inputs).unwrap();
    let got: Vec<f64> = out.to_vec::<f64>().unwrap();

    // reproduce the inputs: make_inputs is deterministic in the seed
    let a0: Vec<f64> = inputs[0].to_vec::<f64>().unwrap();
    let s: f64 = inputs[1].to_vec::<f64>().unwrap()[0];
    let (m, n) = (meta.inputs[0].1[0], meta.inputs[0].1[1]);
    let mut cur = a0;
    let mut nxt = vec![0.0f64; m * n];
    for _ in 0..meta.reps {
        nxt.iter_mut().for_each(|x| *x = 0.0);
        native::jacobi2d(&cur, &mut nxt, m, n, s);
        // match ref.jacobi2d semantics: boundary zeroed
        std::mem::swap(&mut cur, &mut nxt);
    }
    assert_eq!(got.len(), cur.len());
    let mut max_err = 0.0f64;
    for (g, w) in got.iter().zip(&cur) {
        max_err = max_err.max((g - w).abs());
    }
    assert!(max_err < 1e-9, "max |pjrt - native| = {max_err}");
}

#[test]
fn triad_artifact_matches_native() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let metas = load_manifest(&dir).unwrap();
    let meta = metas.iter().find(|m| m.name == "triad").unwrap();
    let loaded = rt.load(&dir, meta).unwrap();
    let inputs = loaded.make_inputs(3).unwrap();
    let out = loaded.execute(&inputs).unwrap();
    let got: Vec<f64> = out.to_vec::<f64>().unwrap();

    let b: Vec<f64> = inputs[0].to_vec::<f64>().unwrap();
    let c: Vec<f64> = inputs[1].to_vec::<f64>().unwrap();
    let d: Vec<f64> = inputs[2].to_vec::<f64>().unwrap();
    // reps sweeps with the carry fed back as `b`
    let mut cur = b;
    for _ in 0..meta.reps {
        let mut a = vec![0.0f64; cur.len()];
        for i in 0..cur.len() {
            a[i] = cur[i] + c[i] * d[i];
        }
        cur = a;
    }
    let mut max_err = 0.0f64;
    for (g, w) in got.iter().zip(&cur) {
        max_err = max_err.max((g - w).abs() / w.abs().max(1.0));
    }
    assert!(max_err < 1e-9, "max rel err = {max_err}");
}

#[test]
fn artifact_timing_is_positive_and_stable() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let metas = load_manifest(&dir).unwrap();
    let meta = metas.iter().find(|m| m.name == "triad").unwrap();
    let loaded = rt.load(&dir, meta).unwrap();
    let t = loaded.time(3).unwrap();
    assert!(t.median_ns > 0.0);
    assert_eq!(t.iterations, meta.reps * meta.iters_per_sweep);
    assert!(t.iterations_per_second() > 1e5, "{}", t.iterations_per_second());
}

#[test]
fn triad_param_order_probe() {
    // b=1, c=2, d=3 ⇒ after `reps` sweeps: 1 + reps·6 everywhere.
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let metas = load_manifest(&dir).unwrap();
    let meta = metas.iter().find(|m| m.name == "triad").unwrap();
    let loaded = rt.load(&dir, meta).unwrap();
    let n: usize = meta.inputs[0].1.iter().product();
    let mk = |v: f64| {
        xla::Literal::vec1(&vec![v; n])
            .reshape(&[n as i64])
            .unwrap()
    };
    let out = loaded.execute(&[mk(1.0), mk(2.0), mk(3.0)]).unwrap();
    let got: Vec<f64> = out.to_vec::<f64>().unwrap();
    let expect = 1.0 + meta.reps as f64 * 6.0;
    assert!(
        (got[0] - expect).abs() < 1e-9,
        "param mapping broken: got {} expected {expect}",
        got[0]
    );
}
