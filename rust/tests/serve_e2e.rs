//! End-to-end `kerncraft serve`: pipe JSON-lines requests through the
//! in-process serve loop (the same function the binary wires to stdin /
//! stdout) and verify the streamed reports, the shared-session cache
//! hits, and that a served report renders to the exact CLI text.

use kerncraft::cli::{run, serve};
use kerncraft::report::render_report;
use kerncraft::session::AnalysisReport;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(str::to_string).collect()
}

#[test]
fn serve_three_requests_share_the_session_cache() {
    // requests r1 and r3 share (machine, kernel, constants); r2 differs
    // in everything. r3 must be answered entirely from the session cache.
    let input = concat!(
        r#"{"id": "r1", "kernel": {"path": "kernels/triad.c"}, "machine": "SNB", "constants": {"N": 100000}}"#,
        "\n",
        r#"{"id": "r2", "kernel": {"name": "2D-5pt"}, "machine": "HSW", "constants": {"N": 2000, "M": 2000}, "model": "RooflinePort", "predictor": "auto"}"#,
        "\n",
        r#"{"id": "r3", "kernel": {"path": "kernels/triad.c"}, "machine": "SNB", "constants": {"N": 100000}}"#,
        "\n",
    );
    let mut output = Vec::new();
    let summary = serve(&mut input.as_bytes(), &mut output).unwrap();
    assert_eq!(summary.requests, 3);
    assert_eq!(summary.errors, 0);

    let text = String::from_utf8(output).unwrap();
    let reports: Vec<AnalysisReport> = text
        .lines()
        .map(|l| AnalysisReport::from_json(l).unwrap_or_else(|e| panic!("{e:#}\n{l}")))
        .collect();
    assert_eq!(reports.len(), 3);
    assert_eq!(reports[0].id.as_deref(), Some("r1"));
    assert_eq!(reports[2].id.as_deref(), Some("r3"));

    // r1 populates the caches: one miss per stage, no hits
    let s1 = &reports[0].session;
    assert_eq!(
        (s1.program_misses, s1.analysis_misses, s1.machine_misses, s1.incore_misses),
        (1, 1, 1, 1),
        "{s1:?}"
    );
    assert_eq!(s1.hits(), 0);

    // r2 shares nothing: misses again
    let s2 = &reports[1].session;
    assert_eq!(s2.program_misses, 1, "{s2:?}");
    assert_eq!(s2.machine_misses, 1);
    assert_eq!(s2.hits(), 0);

    // r3 repeats r1's (machine, kernel) pair: parse/analysis/incore and
    // the machine model all come from the session cache
    let s3 = &reports[2].session;
    assert_eq!(s3.program_hits, 1, "{s3:?}");
    assert_eq!(s3.analysis_hits, 1);
    assert_eq!(s3.machine_hits, 1);
    assert_eq!(s3.incore_hits, 1);
    assert_eq!(s3.misses(), 0);

    // identical requests produce identical figures
    assert_eq!(reports[0].ecm, reports[2].ecm);
    assert_eq!(reports[0].traffic, reports[2].traffic);

    // the run summary aggregates the per-request counters
    assert_eq!(summary.stats.hits(), 4);
    assert_eq!(summary.stats.misses(), 8);

    // r2 asked for RooflinePort and gets the roofline section
    assert!(reports[1].roofline.is_some());
    assert!(reports[1].ecm.is_none());
}

#[test]
fn served_report_renders_to_the_exact_cli_text() {
    // a remote consumer holding only the wire JSON can reproduce the
    // CLI's Listing 5 output byte for byte
    let input = concat!(
        r#"{"kernel": {"path": "kernels/2d-5pt.c"}, "machine": "SNB", "constants": {"N": 6000, "M": 6000}}"#,
        "\n"
    );
    let mut output = Vec::new();
    serve(&mut input.as_bytes(), &mut output).unwrap();
    let line = String::from_utf8(output).unwrap();
    let wire = AnalysisReport::from_json(line.trim()).unwrap();
    let rendered = render_report(&wire, false);

    let cli_text = run(&argv(
        "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
    ))
    .unwrap();
    assert_eq!(rendered, cli_text);
    assert!(rendered.contains("saturating at 3 cores"), "{rendered}");
}

#[test]
fn serve_from_request_file() {
    // the --input path goes through the same loop; exercise the file
    // front end end to end
    let dir = std::env::temp_dir().join("kerncraft_serve_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("requests.jsonl");
    std::fs::write(
        &path,
        "{\"kernel\": {\"name\": \"triad\"}, \"machine\": \"SNB\", \"constants\": {\"N\": 65536}}\n",
    )
    .unwrap();
    // run_serve writes to real stdout; use the parameterized loop with a
    // file reader instead, as run_serve does internally
    let file = std::fs::File::open(&path).unwrap();
    let mut reader = std::io::BufReader::new(file);
    let mut output = Vec::new();
    let summary = serve(&mut reader, &mut output).unwrap();
    assert_eq!(summary.requests, 1);
    assert_eq!(summary.errors, 0);
    let report = AnalysisReport::from_json(String::from_utf8(output).unwrap().trim()).unwrap();
    assert_eq!(report.kernel, "triad");
    std::fs::remove_file(&path).ok();
}
