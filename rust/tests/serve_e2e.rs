//! End-to-end `kerncraft serve`: pipe JSON-lines requests through the
//! in-process serve loop (the same function the binary wires to stdin /
//! stdout) and verify the streamed reports, the shared-session cache
//! hits, that a served report renders to the exact CLI text, and that
//! the `--threads K` worker-pool pipeline answers interleaved request
//! streams with every `id` echoed exactly once.

use kerncraft::cli::{run, serve, serve_with, ServeOptions};
use kerncraft::report::render_report;
use kerncraft::session::AnalysisReport;

fn argv(s: &str) -> Vec<String> {
    s.split_whitespace().map(str::to_string).collect()
}

/// An interleaved request stream: mixed machines and kernels, duplicates
/// for cache warmth, malformed lines, a Validate request, blanks and
/// comments. Returns (input text, ids of the lines that get responses,
/// ids whose responses must be error lines).
fn interleaved_stream() -> (String, Vec<String>, Vec<String>) {
    let mut input = String::from("# interleaved request stream\n\n");
    let mut ids = Vec::new();
    let mut error_ids = Vec::new();
    let mut push = |input: &mut String, id: String, line: String| {
        input.push_str(&line);
        input.push('\n');
        ids.push(id);
    };
    // 25 identical requests: with 4 workers, pigeonhole guarantees some
    // worker evaluates at least two of them back to back, so the session
    // caches MUST register hits regardless of scheduling
    for i in 0..25 {
        push(
            &mut input,
            format!("warm{i}"),
            format!(
                r#"{{"id": "warm{i}", "kernel": {{"path": "kernels/triad.c"}}, "machine": "SNB", "constants": {{"N": 100000}}}}"#
            ),
        );
    }
    // mixed machines/kernels/models
    push(
        &mut input,
        "jacobi-hsw".into(),
        r#"{"id": "jacobi-hsw", "kernel": {"name": "2D-5pt"}, "machine": "HSW", "constants": {"N": 2000, "M": 2000}, "model": "RooflinePort", "predictor": "auto"}"#.into(),
    );
    push(
        &mut input,
        "val".into(),
        r#"{"id": "val", "kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}, "model": "Validate"}"#.into(),
    );
    // malformed requests: unknown kernel, unknown model — the stream must
    // answer each with an error line carrying the id
    push(
        &mut input,
        "bad-kernel".into(),
        r#"{"id": "bad-kernel", "kernel": {"name": "nope"}, "machine": "SNB"}"#.into(),
    );
    error_ids.push("bad-kernel".to_string());
    push(
        &mut input,
        "bad-model".into(),
        r#"{"id": "bad-model", "kernel": {"name": "triad"}, "machine": "SNB", "model": "Nope"}"#.into(),
    );
    error_ids.push("bad-model".to_string());
    // a line that is not JSON at all: an error response without an id
    input.push_str("this is not json\n");
    ids.push(String::new());
    input.push_str("# trailing comment\n");
    (input, ids, error_ids)
}

/// The id of a response line: reports and error lines both echo it as a
/// leading `"id"` field; an idless error line maps to "".
fn response_id(line: &str) -> String {
    match AnalysisReport::from_json(line) {
        Ok(r) => r.id.unwrap_or_default(),
        Err(_) => {
            assert!(line.contains("\"error\""), "neither report nor error: {line}");
            match line.find("\"id\": \"") {
                Some(ix) => {
                    let rest = &line[ix + 7..];
                    rest[..rest.find('"').unwrap()].to_string()
                }
                None => String::new(),
            }
        }
    }
}

#[test]
fn concurrent_serve_answers_interleaved_stream_in_order() {
    let (input, ids, error_ids) = interleaved_stream();
    let mut output = Vec::new();
    let opts = ServeOptions { threads: 4, ordered: true };
    let summary = serve_with(&mut input.as_bytes(), &mut output, &opts).unwrap();
    assert_eq!(summary.requests, ids.len() as u64);
    assert_eq!(summary.errors, error_ids.len() as u64 + 1, "two bad ids + the non-JSON line");

    let text = String::from_utf8(output).unwrap();
    let lines: Vec<&str> = text.lines().collect();
    assert_eq!(lines.len(), ids.len(), "one response per request\n{text}");
    // ordered delivery: the i-th response echoes the i-th request id —
    // which also proves every id is echoed exactly once
    let got: Vec<String> = lines.iter().map(|l| response_id(l)).collect();
    assert_eq!(got, ids, "{text}");
    // error lines carry their ids and do not kill the stream
    for id in &error_ids {
        let line = lines[ids.iter().position(|x| x == id).unwrap()];
        assert!(line.contains("\"error\""), "{line}");
    }
    // error lines name the offending PHYSICAL input line, parallel
    // pipeline included: the stream opens with a comment and a blank
    // line, then 25 warm requests, jacobi-hsw, val — putting bad-kernel
    // on line 30 and bad-model on line 31
    let bad_kernel = lines[ids.iter().position(|x| x == "bad-kernel").unwrap()];
    assert!(bad_kernel.contains("\"line\": 30"), "{bad_kernel}");
    let bad_model = lines[ids.iter().position(|x| x == "bad-model").unwrap()];
    assert!(bad_model.contains("\"line\": 31"), "{bad_model}");
    // warm-cache hit counters rose: 25 identical requests through 4
    // workers cannot all miss
    assert!(summary.stats.hits() > 0, "{:?}", summary.stats);
    assert!(summary.stats.program_hits > 0, "{:?}", summary.stats);
    // the Validate response carries a JSON-round-trippable section
    let val = AnalysisReport::from_json(lines[ids.iter().position(|x| x == "val").unwrap()])
        .unwrap();
    let v = val.validation.expect("validation section over the wire");
    assert!(v.sim_cy_per_cl > 0.0);
}

#[test]
fn concurrent_serve_unordered_delivers_every_response() {
    let (input, ids, _) = interleaved_stream();
    let mut output = Vec::new();
    let opts = ServeOptions { threads: 4, ordered: false };
    let summary = serve_with(&mut input.as_bytes(), &mut output, &opts).unwrap();
    assert_eq!(summary.requests, ids.len() as u64);
    let text = String::from_utf8(output).unwrap();
    let mut got: Vec<String> = text.lines().map(response_id).collect();
    let mut want = ids.clone();
    got.sort();
    want.sort();
    // unordered delivery still answers every request exactly once
    assert_eq!(got, want, "{text}");
}

#[test]
fn concurrent_serve_matches_serial_responses() {
    // the worker pool must not change any response payload: run the same
    // stream serially and with 4 ordered workers and compare the lines
    // (memo counters differ by schedule, so compare id + model figures)
    let (input, _, _) = interleaved_stream();
    let mut serial_out = Vec::new();
    serve(&mut input.as_bytes(), &mut serial_out).unwrap();
    let mut par_out = Vec::new();
    let opts = ServeOptions { threads: 4, ordered: true };
    serve_with(&mut input.as_bytes(), &mut par_out, &opts).unwrap();
    let serial_text = String::from_utf8(serial_out).unwrap();
    let par_text = String::from_utf8(par_out).unwrap();
    for (s, p) in serial_text.lines().zip(par_text.lines()) {
        match (AnalysisReport::from_json(s), AnalysisReport::from_json(p)) {
            (Ok(sr), Ok(pr)) => {
                assert_eq!(sr.id, pr.id);
                assert_eq!(sr.ecm, pr.ecm, "{s}\n{p}");
                assert_eq!(sr.roofline, pr.roofline);
                assert_eq!(sr.validation, pr.validation);
            }
            (Err(_), Err(_)) => assert_eq!(s.contains("\"error\""), p.contains("\"error\"")),
            (a, b) => panic!("serial/parallel disagree:\n{s} ({a:?})\n{p} ({b:?})"),
        }
    }
    assert_eq!(serial_text.lines().count(), par_text.lines().count());
}

#[test]
fn serve_three_requests_share_the_session_cache() {
    // requests r1 and r3 share (machine, kernel, constants); r2 differs
    // in everything. r3 must be answered entirely from the session cache.
    let input = concat!(
        r#"{"id": "r1", "kernel": {"path": "kernels/triad.c"}, "machine": "SNB", "constants": {"N": 100000}}"#,
        "\n",
        r#"{"id": "r2", "kernel": {"name": "2D-5pt"}, "machine": "HSW", "constants": {"N": 2000, "M": 2000}, "model": "RooflinePort", "predictor": "auto"}"#,
        "\n",
        r#"{"id": "r3", "kernel": {"path": "kernels/triad.c"}, "machine": "SNB", "constants": {"N": 100000}}"#,
        "\n",
    );
    let mut output = Vec::new();
    let summary = serve(&mut input.as_bytes(), &mut output).unwrap();
    assert_eq!(summary.requests, 3);
    assert_eq!(summary.errors, 0);

    let text = String::from_utf8(output).unwrap();
    let reports: Vec<AnalysisReport> = text
        .lines()
        .map(|l| AnalysisReport::from_json(l).unwrap_or_else(|e| panic!("{e:#}\n{l}")))
        .collect();
    assert_eq!(reports.len(), 3);
    assert_eq!(reports[0].id.as_deref(), Some("r1"));
    assert_eq!(reports[2].id.as_deref(), Some("r3"));

    // r1 populates the caches: one miss per stage, no hits
    let s1 = &reports[0].session;
    assert_eq!(
        (s1.program_misses, s1.analysis_misses, s1.machine_misses, s1.incore_misses),
        (1, 1, 1, 1),
        "{s1:?}"
    );
    assert_eq!(s1.hits(), 0);

    // r2 shares nothing: misses again
    let s2 = &reports[1].session;
    assert_eq!(s2.program_misses, 1, "{s2:?}");
    assert_eq!(s2.machine_misses, 1);
    assert_eq!(s2.hits(), 0);

    // r3 repeats r1's (machine, kernel) pair: parse/analysis/incore and
    // the machine model all come from the session cache
    let s3 = &reports[2].session;
    assert_eq!(s3.program_hits, 1, "{s3:?}");
    assert_eq!(s3.analysis_hits, 1);
    assert_eq!(s3.machine_hits, 1);
    assert_eq!(s3.incore_hits, 1);
    assert_eq!(s3.misses(), 0);

    // identical requests produce identical figures
    assert_eq!(reports[0].ecm, reports[2].ecm);
    assert_eq!(reports[0].traffic, reports[2].traffic);

    // the run summary aggregates the per-request counters
    assert_eq!(summary.stats.hits(), 4);
    assert_eq!(summary.stats.misses(), 8);

    // r2 asked for RooflinePort and gets the roofline section
    assert!(reports[1].roofline.is_some());
    assert!(reports[1].ecm.is_none());
}

#[test]
fn served_report_renders_to_the_exact_cli_text() {
    // a remote consumer holding only the wire JSON can reproduce the
    // CLI's Listing 5 output byte for byte
    let input = concat!(
        r#"{"kernel": {"path": "kernels/2d-5pt.c"}, "machine": "SNB", "constants": {"N": 6000, "M": 6000}}"#,
        "\n"
    );
    let mut output = Vec::new();
    serve(&mut input.as_bytes(), &mut output).unwrap();
    let line = String::from_utf8(output).unwrap();
    let wire = AnalysisReport::from_json(line.trim()).unwrap();
    let rendered = render_report(&wire, false);

    let cli_text = run(&argv(
        "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
    ))
    .unwrap();
    assert_eq!(rendered, cli_text);
    assert!(rendered.contains("saturating at 3 cores"), "{rendered}");
}

#[test]
fn serve_from_request_file() {
    // the --input path goes through the same loop; exercise the file
    // front end end to end
    let dir = std::env::temp_dir().join("kerncraft_serve_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("requests.jsonl");
    std::fs::write(
        &path,
        "{\"kernel\": {\"name\": \"triad\"}, \"machine\": \"SNB\", \"constants\": {\"N\": 65536}}\n",
    )
    .unwrap();
    // run_serve writes to real stdout; use the parameterized loop with a
    // file reader instead, as run_serve does internally
    let file = std::fs::File::open(&path).unwrap();
    let mut reader = std::io::BufReader::new(file);
    let mut output = Vec::new();
    let summary = serve(&mut reader, &mut output).unwrap();
    assert_eq!(summary.requests, 1);
    assert_eq!(summary.errors, 0);
    let report = AnalysisReport::from_json(String::from_utf8(output).unwrap().trim()).unwrap();
    assert_eq!(report.kernel, "triad");
    std::fs::remove_file(&path).ok();
}
