//! Golden-file tests for the machine-YAML loader error paths: the full
//! error text — context chain included — is pinned by `.expected` files
//! next to the fixtures under `rust/tests/fixtures/`. Update the golden
//! file deliberately when an error message changes; these strings are
//! what operators act on.

use kerncraft::machine::MachineModel;

fn golden_error(fixture: &str) -> (String, String) {
    let yml = format!("rust/tests/fixtures/{fixture}.yml");
    let expected = format!("rust/tests/fixtures/{fixture}.expected");
    let err = MachineModel::from_file(&yml)
        .map(|_| ())
        .expect_err("fixture must fail to load");
    let got = format!("{err:#}");
    let want = std::fs::read_to_string(&expected)
        .unwrap_or_else(|e| panic!("reading {expected}: {e}"))
        .trim_end()
        .to_string();
    (got, want)
}

#[test]
fn missing_field_error_is_stable() {
    let (got, want) = golden_error("missing_clock");
    assert_eq!(got, want);
}

#[test]
fn todo_marker_is_rejected_with_its_field_path() {
    let (got, want) = golden_error("todo_marker");
    assert_eq!(got, want);
    // the path pinpoints the exact unresolved field, list index included
    assert!(got.contains("'memory hierarchy[0].size per group'"), "{got}");
}

#[test]
fn missing_file_error_names_the_path() {
    let err = MachineModel::from_file("rust/tests/fixtures/does_not_exist.yml").unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("does_not_exist.yml"), "{msg}");
}

#[test]
fn builtin_machines_carry_no_todo_markers() {
    // the shipped calibrated files must always pass the marker scan
    MachineModel::snb();
    MachineModel::hsw();
}
