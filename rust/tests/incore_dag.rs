//! In-core dependency-DAG suite: golden incore-section fixtures for the
//! CP/LCD report lines, structural DAG properties, and a lint pass that
//! loads every shipped machine file.
//!
//! The golden fixtures use the same digit normalization as the CLI
//! Validate fixture (runs of digits/sign/point collapse to `#`, space
//! runs to one space): the section *shape* — chain names, resolved
//! mnemonics with their counts, and port labels — is pinned
//! byte-for-byte, while the hand-derivable figures are pinned by
//! exact-substring asserts.

use kerncraft::incore::dag::DepDag;
use kerncraft::incore::isa::IsaSpec;
use kerncraft::kernel::{parse, KernelAnalysis};
use kerncraft::machine::{MachineModel, UopClass};
use kerncraft::report::incore_report;
use kerncraft::session::{AnalysisRequest, KernelSpec, ModelKind, Session};
use std::collections::HashMap;

/// Render the in-core section of a kernel file on a machine file the
/// way the CLI/serve pipeline does (ECMCPU: in-core only, no traffic
/// stage, so no benchmark data is needed).
fn incore_section(kernel_file: &str, machine: &str, consts: &[(&str, i64)]) -> String {
    let src =
        std::fs::read_to_string(kernel_file).unwrap_or_else(|e| panic!("{kernel_file}: {e}"));
    let mut req = AnalysisRequest::new(KernelSpec::source(kernel_file, src.as_str()), machine)
        .with_model(ModelKind::EcmCpu);
    for (k, v) in consts {
        req = req.with_constant(*k, *v);
    }
    let r = Session::new().evaluate(&req).unwrap_or_else(|e| panic!("{kernel_file}: {e:#}"));
    incore_report(r.incore.as_ref().expect("ECMCPU report carries an incore section"))
}

/// Same normalization as the Validate golden test: numeric text
/// (digits, sign, decimal point) collapses to a single `#`, space runs
/// to one space, everything else passes through verbatim.
fn normalize_numbers(s: &str) -> String {
    let mut out = String::new();
    let mut last_hash = false;
    let mut last_space = false;
    for c in s.chars() {
        if c.is_ascii_digit() || c == '+' || c == '-' || c == '.' {
            if !last_hash {
                out.push('#');
            }
            last_hash = true;
            last_space = false;
        } else if c == ' ' {
            if !last_space {
                out.push(' ');
            }
            last_space = true;
            last_hash = false;
        } else {
            out.push(c);
            last_hash = false;
            last_space = false;
        }
    }
    out
}

fn assert_matches_fixture(section: &str, fixture: &str) {
    let expected =
        std::fs::read_to_string(fixture).unwrap_or_else(|e| panic!("{fixture}: {e}"));
    assert_eq!(normalize_numbers(section), expected, "raw section:\n{section}");
}

#[test]
fn golden_kahan_snb() {
    let s = incore_section("kernels/kahan-ddot.c", "machines/snb.yml", &[("N", 1000000)]);
    assert_matches_fixture(&s, "rust/tests/fixtures/incore/kahan_snb.expected");
    // the 12 cy/it c→c chain over 8 scalar iterations/CL floors T_OL
    assert!(s.contains("T_OL = 96.0 cy/CL"), "{s}");
    assert!(s.contains("LCD = 96.0 cy/CL"), "{s}");
    // the full critical path adds the load and multiply: (4+5+12) × 8
    assert!(s.contains("CP = 168.0 cy/CL"), "{s}");
    assert!(s.contains("dominant chain: c (96.0 cy/CL)"), "{s}");
    assert!(s.contains("c=12.0[addsd,addsd,addsd,addsd]"), "{s}");
    assert!(s.contains("sum=3.0[addsd]"), "{s}");
}

#[test]
fn golden_kahan_a64fx() {
    let s = incore_section("kernels/kahan-ddot.c", "machines/a64fx.yml", &[("N", 1000000)]);
    assert_matches_fixture(&s, "rust/tests/fixtures/incore/kahan_a64fx.expected");
    // 9 cy FP adds and a 256 B cache line: 4×9 cy/it × 32 it/CL
    assert!(s.contains("LCD = 1152.0 cy/CL"), "{s}");
    assert!(s.contains("CP = 1792.0 cy/CL"), "{s}");
    assert!(s.contains("c=36.0[fadd,fadd,fadd,fadd]"), "{s}");
    assert!(s.contains("scalar (x1)"), "{s}");
}

#[test]
fn golden_2d5pt_snb() {
    let s = incore_section(
        "kernels/2d-5pt.c",
        "machines/snb.yml",
        &[("N", 6000), ("M", 6000)],
    );
    assert_matches_fixture(&s, "rust/tests/fixtures/incore/2d5pt_snb.expected");
    // no loop-carried scalar: LCD is zero and the stencil vectorizes
    assert!(s.contains("LCD = 0.0 cy/CL"), "{s}");
    assert!(s.contains("vectorized (x4)"), "{s}");
    assert!(!s.contains("LCD chains"), "{s}");
    assert!(!s.contains("dominant chain"), "{s}");
}

#[test]
fn golden_2d5pt_a64fx() {
    let s = incore_section(
        "kernels/2d-5pt.c",
        "machines/a64fx.yml",
        &[("N", 6000), ("M", 6000)],
    );
    assert_matches_fixture(&s, "rust/tests/fixtures/incore/2d5pt_a64fx.expected");
    assert!(s.contains("LCD = 0.0 cy/CL"), "{s}");
    assert!(s.contains("vectorized (x8)"), "{s}");
}

// -------------------------------------------------------------------------
// DAG structural properties
// -------------------------------------------------------------------------

fn build_dag(src: &str, consts: &[(&str, i64)], machine: &MachineModel) -> DepDag {
    let p = parse(src).unwrap();
    let c: HashMap<String, i64> = consts.iter().map(|(k, v)| (k.to_string(), *v)).collect();
    let a = KernelAnalysis::from_program(&p, &c).unwrap();
    DepDag::build(&a, &IsaSpec::resolve(machine, true))
}

fn kahan_src() -> String {
    std::fs::read_to_string("kernels/kahan-ddot.c").unwrap()
}

fn jacobi_src() -> String {
    std::fs::read_to_string("kernels/2d-5pt.c").unwrap()
}

const DOT: &str = "double a[N], b[N], s;\nfor (int i = 0; i < N; i++) s += a[i] * b[i];";

#[test]
fn forward_edges_are_acyclic_modulo_back_edges() {
    let m = MachineModel::snb();
    for (src, consts) in [
        (kahan_src(), vec![("N", 100000)]),
        (jacobi_src(), vec![("N", 500), ("M", 500)]),
        (DOT.to_string(), vec![("N", 100000)]),
    ] {
        let dag = build_dag(&src, &consts, &m);
        // node ids are a topological order of the forward edges: all
        // cyclicity lives in the explicit back-edge list
        assert!(dag.is_topologically_ordered());
        for &(def, phi) in dag.back_edges() {
            assert!(def > phi, "back-edge must point backwards: {def} -> {phi}");
        }
    }
}

#[test]
fn critical_path_dominates_chains_and_single_instructions() {
    let m = MachineModel::snb();
    for (src, consts) in [
        (kahan_src(), vec![("N", 100000)]),
        (jacobi_src(), vec![("N", 500), ("M", 500)]),
        (DOT.to_string(), vec![("N", 100000)]),
    ] {
        let dag = build_dag(&src, &consts, &m);
        let (cp, path) = dag.critical_path();
        // CP ≥ LCD ≥ 0, and CP ≥ the largest single-node latency
        assert!(cp >= dag.unbreakable_cycle_mean(true));
        assert!(cp >= dag.max_node_latency(), "cp {cp}");
        // the reported path realizes exactly the reported latency
        let path_latency: f64 = path.iter().map(|&id| dag.nodes()[id].latency).sum();
        assert!((path_latency - cp).abs() < 1e-9, "{path_latency} vs {cp}");
        // each chain's total cycle latency covers its slowest node
        for c in dag.chains(true) {
            let max_on_path =
                c.path.iter().map(|&id| dag.nodes()[id].latency).fold(0.0f64, f64::max);
            assert!(
                c.latency_per_it * c.vars.len() as f64 + 1e-9 >= max_on_path,
                "{:?}: {} < {max_on_path}",
                c.vars,
                c.latency_per_it
            );
        }
    }
}

#[test]
fn chain_enumeration_is_deterministic() {
    let m = MachineModel::snb();
    let kahan = kahan_src();
    let d1 = build_dag(&kahan, &[("N", 100000)], &m);
    let d2 = build_dag(&kahan, &[("N", 100000)], &m);
    assert_eq!(d1.chains(true), d2.chains(true));
    let names: Vec<String> = d1.chains(true).iter().map(|c| c.vars.join("->")).collect();
    assert_eq!(names, ["c", "c->sum", "sum"]);
    // the pure jacobi stencil carries nothing across iterations
    let dj = build_dag(&jacobi_src(), &[("N", 500), ("M", 500)], &m);
    assert!(dj.chains(true).is_empty());
    assert!(dj.back_edges().is_empty());
    // the dot-product reduction is a single breakable self-cycle
    let dd = build_dag(DOT, &[("N", 100000)], &m);
    let chains = dd.chains(true);
    assert_eq!(chains.len(), 1);
    assert!(chains[0].broken);
    assert_eq!(dd.unbreakable_cycle_mean(true), 0.0);
    assert!(dd.unbreakable_cycle_mean(false) > 0.0);
}

// -------------------------------------------------------------------------
// machine-file lint: every shipped description must load and resolve
// -------------------------------------------------------------------------

#[test]
fn every_shipped_machine_file_loads() {
    let mut seen = 0;
    for entry in std::fs::read_dir("machines").expect("machines/ exists") {
        let path = entry.unwrap().path();
        if path.extension().and_then(|e| e.to_str()) != Some("yml") {
            continue;
        }
        let name = path.display().to_string();
        let m = MachineModel::from_file(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(!m.ports.is_empty(), "{name}: no ports");
        assert!(!m.memory_hierarchy.is_empty(), "{name}: no memory hierarchy");
        // the in-core engine must resolve an instruction selection for
        // every machine (exercises family + instructions-table parsing)
        let spec = IsaSpec::resolve(&m, true);
        assert!(spec.latency(UopClass::Add) > 0.0, "{name}: zero ADD latency");
        assert!(!spec.mnemonic(UopClass::Load).is_empty(), "{name}");
        seen += 1;
    }
    assert!(seen >= 3, "expected snb/hsw/a64fx under machines/, saw {seen}");
}
