//! Report rendering: the CLI output formats of paper Listing 5 (ECM and
//! Roofline reports), the Fig. 2 cache-usage visualization, the machine
//! summary, and the CSV/JSON row formats of the `sweep` subcommand.
//!
//! The model renderers ([`ecm_report`], [`roofline_report`],
//! [`incore_report`]) are pure functions of the serializable
//! [`AnalysisReport`] — anything a remote consumer receives over the
//! `kerncraft serve` wire can be rendered to the exact CLI text locally.

use crate::cache::TrafficPrediction;
use crate::jsonio::{json_num, json_str};
use crate::kernel::KernelAnalysis;
use crate::machine::MachineModel;
use crate::models::Unit;
use crate::session::{AnalysisReport, EcmReport, IncoreReport};
use crate::sweep::{MemoStats, SweepOutput, SweepRow};
use crate::util::fmt_cy;

/// Render the ECM analysis report (paper Listing 5, upper half) from the
/// `ecm` + `scaling` sections. Empty when the report has no ECM section.
pub fn ecm_report(r: &AnalysisReport, verbose: bool) -> String {
    let (Some(ecm), Some(scaling)) = (&r.ecm, &r.scaling) else {
        return String::new();
    };
    let mut s = String::new();
    s.push_str(&format!("ECM model: {}\n", ecm_notation(ecm)));
    s.push_str(&format!("ECM prediction: {}\n", ecm_prediction_notation(ecm)));
    if r.unit != Unit::CyPerCl {
        let conv: Vec<String> = ecm
            .level_predictions
            .iter()
            .map(|p| {
                format!(
                    "{:.3e}",
                    r.unit.convert(
                        *p,
                        r.unit_iterations as f64,
                        r.flops_per_unit,
                        r.clock_hz
                    )
                )
            })
            .collect();
        s.push_str(&format!("ECM prediction ({}): {{{}}}\n", r.unit.suffix(), conv.join(" \\ ")));
    }
    if scaling.t_mem_link > 0.0 {
        s.push_str(&format!(
            "saturating at {} cores\n",
            scaling.saturation_cores.unwrap_or(u32::MAX)
        ));
    } else {
        s.push_str("no bandwidth saturation (cache-resident working set)\n");
    }
    if verbose {
        for c in &ecm.contributions {
            s.push_str(&format!(
                "  {}: {} CL/unit = {} cy{}\n",
                c.link,
                c.lines,
                fmt_cy(c.cycles),
                c.benchmark
                    .as_ref()
                    .map(|b| format!(" (bw from {b} benchmark)"))
                    .unwrap_or_default()
            ));
        }
    }
    s
}

/// The compact ECM notation of a report section (see
/// [`crate::util::ecm_notation_str`] for the shared format).
pub fn ecm_notation(e: &EcmReport) -> String {
    let cycles: Vec<f64> = e.contributions.iter().map(|c| c.cycles).collect();
    crate::util::ecm_notation_str(e.t_ol, e.t_nol, &cycles)
}

/// The per-level prediction notation of a report section.
pub fn ecm_prediction_notation(e: &EcmReport) -> String {
    crate::util::ecm_prediction_str(&e.level_predictions)
}

/// Render the Roofline report (paper Listing 5, lower half) from the
/// `roofline` section. Empty when the report has no Roofline section.
pub fn roofline_report(r: &AnalysisReport) -> String {
    let Some(rf) = &r.roofline else {
        return String::new();
    };
    let mut s = String::new();
    s.push_str("Bottlenecks:\n");
    s.push_str("  level   | ar.int. |  perfor. |   bandw.  | bw kernel\n");
    s.push_str("          | FLOP/B  |  cy/CL   |   GB/s    |\n");
    s.push_str("  --------+---------+----------+-----------+----------\n");
    for b in &rf.ceilings {
        s.push_str(&format!(
            "  {:<7} | {:>7} | {:>8} | {:>9} | {}\n",
            b.level,
            b.arith_intensity.map(|ai| format!("{ai:.2}")).unwrap_or_else(|| "-".into()),
            fmt_cy(b.cycles),
            b.bandwidth_bs.map(|bw| format!("{:.1}", bw / 1e9)).unwrap_or_else(|| "-".into()),
            b.benchmark.clone().unwrap_or_else(|| "-".into()),
        ));
    }
    let Some(bn) = rf.ceilings.get(rf.bottleneck) else {
        return s;
    };
    if rf.memory_bound {
        s.push_str(&format!(
            "Cache or mem bound: {} ({} benchmark)\n",
            bn.level,
            bn.benchmark.clone().unwrap_or_default()
        ));
        if let Some(ai) = bn.arith_intensity {
            s.push_str(&format!("Arithmetic Intensity: {ai:.2} FLOP/B\n"));
        }
    } else {
        s.push_str("CPU bound\n");
    }
    s.push_str(&format!(
        "Roofline prediction: {} {}\n",
        format_value(bn.cycles, r),
        r.unit.suffix()
    ));
    s
}

fn format_value(cy: f64, r: &AnalysisReport) -> String {
    match r.unit {
        Unit::CyPerCl => fmt_cy(cy),
        _ => format!(
            "{:.3e}",
            r.unit.convert(cy, r.unit_iterations as f64, r.flops_per_unit, r.clock_hz)
        ),
    }
}

/// Render the in-core report from the `incore` section: the port model's
/// throughput numbers plus the dependency-DAG CP/LCD analysis
/// (DESIGN.md §4).
pub fn incore_report(i: &IncoreReport) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "in-core (port model, {}): T_OL = {:.1} cy/CL, T_nOL = {:.1} cy/CL\n",
        i.isa, i.t_ol, i.t_nol
    ));
    s.push_str(&format!(
        "  TP = {:.1} cy/CL, CP = {:.1} cy/CL, LCD = {:.1} cy/CL, {} (x{})\n",
        i.tp,
        i.cp_cy,
        i.lcd_cy,
        if i.vectorized { "vectorized" } else { "scalar" },
        i.vector_elems
    ));
    s.push_str("  port pressure (cy/CL):");
    for (port, cycles) in &i.port_pressure {
        s.push_str(&format!(" {port}={cycles:.1}"));
    }
    s.push('\n');
    if !i.chains.is_empty() {
        s.push_str("  LCD chains (cy/it):");
        for c in &i.chains {
            s.push_str(&format!(
                " {}={:.1}[{}]{}",
                c.name,
                c.latency_per_it,
                c.instructions.join(","),
                if c.broken { "(broken)" } else { "" }
            ));
        }
        s.push('\n');
    }
    if let Some(d) = &i.dominant_chain {
        s.push_str(&format!("  dominant chain: {d} ({:.1} cy/CL)\n", i.lcd_cy));
    }
    s
}

/// Render the `validation` section ([`crate::session::ModelKind::Validate`]):
/// the virtual testbed's simulated cy/CL next to the analytic ECM
/// prediction, the relative model error, and the per-level cache
/// statistics of the simulated run. Empty when the report has no
/// validation section.
///
/// The numeric fields use fixed one-decimal formatting (not [`fmt_cy`])
/// so the golden test normalization stays shape-stable.
pub fn validation_report(r: &AnalysisReport) -> String {
    let Some(v) = &r.validation else {
        return String::new();
    };
    let mut s = String::new();
    s.push_str("model validation (virtual testbed vs analytic ECM):\n");
    s.push_str(&format!(
        "  simulated: {:.1} cy/CL over {} iterations{}\n",
        v.sim_cy_per_cl,
        v.iterations,
        if v.truncated { " (truncated steady state window)" } else { "" }
    ));
    s.push_str(&format!(
        "  analytic:  {:.1} cy/CL (ECM memory level prediction)\n",
        v.analytic_cy_per_cl
    ));
    s.push_str(&format!("  model error: {:+.1}% of simulated\n", v.model_error_pct));
    s.push_str("  level | hits       | misses     | writebacks\n");
    for l in &v.levels {
        s.push_str(&format!(
            "  {:<5} | {:>10} | {:>10} | {:>10}\n",
            l.level, l.hits, l.misses, l.writebacks
        ));
    }
    s
}

/// Render the `advise` section ([`crate::session::ModelKind::Advise`]):
/// the solved layer-condition breakpoint table and the ranked blocking
/// advice of the analytic adviser (DESIGN.md §5). Empty when the report
/// has no advise section.
///
/// The numeric fields use fixed formatting (not [`fmt_cy`]) so the
/// golden test normalization stays shape-stable.
pub fn advise_report(r: &AnalysisReport) -> String {
    let Some(a) = &r.advise else {
        return String::new();
    };
    let mut s = String::new();
    s.push_str("blocking advice (analytic layer-condition breakpoints):\n");
    s.push_str(&format!(
        "  varied dim: {} (constant {}, current extent {})\n",
        a.varied_dim, a.varied_constant, a.current_extent
    ));
    s.push_str(&format!(
        "  baseline: T_Mem {:.1} cy/CL, {:.0} B/unit memory traffic\n",
        a.baseline_t_mem, a.baseline_memory_bytes_per_unit
    ));
    s.push_str(&format!(
        "  offset-walk levels across sub-evaluations: {}\n",
        a.walk_levels
    ));
    s.push_str("  level | dim | slope B | const B | breakpoint\n");
    for b in &a.breakpoints {
        s.push_str(&format!(
            "  {:<5} | {:<3} | {:>7} | {:>7} | {:>10}\n",
            b.level, b.dim_name, b.slope_bytes, b.const_bytes, b.extent
        ));
    }
    if a.candidates.is_empty() {
        s.push_str(
            "  advice: none (no breakpoint below the current extent yields a viable block)\n",
        );
    } else {
        for (ix, c) in a.candidates.iter().enumerate() {
            s.push_str(&format!(
                "  {}. block {} at {}: unlocks {}, traffic x{:.2}, T_Mem {:.1} -> {:.1} cy/CL (x{:.2})\n",
                ix + 1,
                a.varied_dim,
                c.extent,
                if c.unlocks.is_empty() { "-".to_string() } else { c.unlocks.join(", ") },
                c.traffic_factor,
                a.baseline_t_mem,
                c.t_mem,
                c.speedup
            ));
        }
    }
    s
}

/// Render the model sections of a report the way the CLI mode for
/// `report.model` would (the text twin of [`AnalysisReport::to_json`]).
pub fn render_report(r: &AnalysisReport, verbose: bool) -> String {
    let mut s = String::new();
    // the in-core section always renders when present: CP/LCD are
    // first-class outputs, not verbose-only diagnostics
    if let Some(i) = &r.incore {
        s.push_str(&incore_report(i));
    }
    if r.ecm.is_some() {
        s.push_str(&ecm_report(r, verbose));
    }
    s.push_str(&roofline_report(r));
    s.push_str(&validation_report(r));
    s.push_str(&advise_report(r));
    s
}

/// Render the static-analysis tables (paper Tables 2-4).
pub fn analysis_report(analysis: &KernelAnalysis) -> String {
    let mut s = String::new();
    s.push_str("loop stack (Table 2):\n");
    s.push_str(&indent(&analysis.loop_stack_table()));
    s.push_str("data accesses (Tables 3/4):\n");
    s.push_str(&indent(&analysis.access_table()));
    s.push_str(&format!(
        "FLOPs per iteration: {} ({} ADD, {} MUL, {} DIV)\n",
        analysis.flops.total(),
        analysis.flops.adds,
        analysis.flops.muls,
        analysis.flops.divs
    ));
    s
}

/// ASCII rendering of the Fig. 2 cache-usage prediction: one line per
/// array access, annotated with the level it hits.
pub fn cache_viz(analysis: &KernelAnalysis, traffic: &TrafficPrediction) -> String {
    let mut s = String::new();
    s.push_str("cache usage prediction (cf. paper Fig. 2):\n");
    s.push_str("  access                      | 1D offset | served by\n");
    s.push_str("  ----------------------------+-----------+----------\n");
    for (ix, acc) in analysis.reads.iter().enumerate() {
        let arr = &analysis.arrays[acc.array];
        let dims: Vec<String> = acc.dims.iter().map(|d| format!("[{d}]")).collect();
        let label = format!("{}{}", arr.name, dims.join(""));
        s.push_str(&format!(
            "  {:<27} | {:>+9} | {}\n",
            label, acc.offset, traffic.access_hit_level[ix]
        ));
    }
    for acc in &analysis.writes {
        let arr = &analysis.arrays[acc.array];
        let dims: Vec<String> = acc.dims.iter().map(|d| format!("[{d}]")).collect();
        s.push_str(&format!(
            "  {:<27} | {:>+9} | store (write-allocate + evict)\n",
            format!("{}{}", arr.name, dims.join("")),
            acc.offset
        ));
    }
    s.push_str("\nlayer conditions:\n");
    s.push_str("  level | dim | required  | capacity  | satisfied\n");
    for lc in &traffic.layer_conditions {
        s.push_str(&format!(
            "  {:<5} | {:<3} | {:>9} | {:>9} | {}\n",
            lc.level,
            lc.dim_name,
            human_bytes(lc.required_bytes),
            human_bytes(lc.cache_bytes),
            if lc.satisfied { "yes" } else { "NO" }
        ));
    }
    s
}

/// Render a machine summary (Table 1 style).
pub fn machine_report(m: &MachineModel) -> String {
    let mut s = String::new();
    s.push_str(&format!("machine: {} ({})\n", m.model_name, m.arch));
    s.push_str(&format!(
        "  clock {} GHz, {} sockets x {} cores, {} threads/core\n",
        m.clock_hz / 1e9,
        m.sockets,
        m.cores_per_socket,
        m.threads_per_core
    ));
    s.push_str(&format!(
        "  DP peak {} flop/cy (ADD {}, MUL {}, FMA {})\n",
        m.flops_per_cycle_dp.total,
        m.flops_per_cycle_dp.add,
        m.flops_per_cycle_dp.mul,
        m.flops_per_cycle_dp.fma
    ));
    for lvl in &m.memory_hierarchy {
        s.push_str(&format!(
            "  {:<4} {:>9} x{} groups, {} cores/group{}\n",
            lvl.name,
            lvl.size_bytes.map(human_bytes).unwrap_or_else(|| "-".into()),
            lvl.groups,
            lvl.cores_per_group,
            lvl.cycles_per_cacheline
                .map(|c| format!(", {c} cy/CL to next level"))
                .unwrap_or_default()
        ));
    }
    s
}

/// Render sweep rows as CSV: one row per point, a stable header derived
/// from the union of constant names and the union of link labels across
/// all rows (machines may differ in cache-level names and counts).
pub fn sweep_csv(rows: &[SweepRow]) -> String {
    let mut const_names: Vec<&str> = Vec::new();
    for r in rows {
        for k in r.constants.keys() {
            if !const_names.contains(&k.as_str()) {
                const_names.push(k);
            }
        }
    }
    const_names.sort_unstable();
    // union of link labels in first-appearance order, so heterogeneous
    // machine hierarchies each keep their columns (absent links stay empty)
    let mut link_names: Vec<&str> = Vec::new();
    for r in rows {
        for (n, _, _) in &r.links {
            if !link_names.contains(&n.as_str()) {
                link_names.push(n);
            }
        }
    }

    let mut s = String::from("kernel,machine,cores,predictor");
    for c in &const_names {
        s.push(',');
        s.push_str(&csv_field(c));
    }
    s.push_str(",unit_it,T_OL,T_nOL,CP,LCD");
    for l in &link_names {
        s.push_str(",T_");
        s.push_str(l);
    }
    s.push_str(
        ",T_ECM_Mem,sat_cores,mem_B_per_unit,lc_fast_levels,walk_levels,sim_cy_per_cl,model_error_pct,lc_bands,advise_block,advise_t_mem\n",
    );

    for r in rows {
        s.push_str(&format!(
            "{},{},{},{}",
            csv_field(&r.label),
            csv_field(&r.machine),
            r.cores,
            r.predictor.name()
        ));
        for c in &const_names {
            s.push(',');
            if let Some(v) = r.constants.get(*c) {
                s.push_str(&v.to_string());
            }
        }
        s.push_str(&format!(
            ",{},{},{},{},{}",
            r.unit_iterations,
            fmt_cy(r.t_ol),
            fmt_cy(r.t_nol),
            fmt_cy(r.cp_cy),
            fmt_cy(r.lcd_cy)
        ));
        for l in &link_names {
            s.push(',');
            if let Some((_, _, cy)) = r.links.iter().find(|(n, _, _)| n == l) {
                s.push_str(&fmt_cy(*cy));
            }
        }
        let sat = if r.saturation_cores == u32::MAX {
            "inf".to_string()
        } else {
            r.saturation_cores.to_string()
        };
        s.push_str(&format!(
            ",{},{},{},{},{},{},{},{},{},{}\n",
            fmt_cy(r.t_ecm_mem),
            sat,
            r.memory_bytes_per_unit,
            r.lc_fast_levels,
            r.walk_levels,
            r.sim_cy_per_cl.map(|v| format!("{v:.3}")).unwrap_or_default(),
            r.model_error_pct.map(|v| format!("{v:.2}")).unwrap_or_default(),
            r.lc_breakpoints.join(" "),
            r.advise_block.map(|v| v.to_string()).unwrap_or_default(),
            r.advise_t_mem.map(|v| format!("{v:.3}")).unwrap_or_default()
        ));
    }
    s
}

/// Render sweep rows plus memo statistics as a JSON document (hand-rolled
/// on [`crate::jsonio`]; the offline crate set has no serde).
pub fn sweep_json(rows: &[SweepRow], stats: &MemoStats) -> String {
    let mut s = String::from("{\n  \"stats\": ");
    s.push_str(&stats.json_object());
    s.push_str(",\n  \"rows\": [\n");
    for (ix, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!(
            "\"kernel\": {}, \"machine\": {}, \"cores\": {}, \"predictor\": \"{}\"",
            json_str(&r.label),
            json_str(&r.machine),
            r.cores,
            r.predictor.name()
        ));
        s.push_str(", \"constants\": {");
        for (cx, (k, v)) in r.constants.iter().enumerate() {
            if cx > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("{}: {}", json_str(k), v));
        }
        s.push_str(&format!(
            "}}, \"unit_iterations\": {}, \"t_ol\": {}, \"t_nol\": {}, \"cp_cy\": {}, \"lcd_cy\": {}",
            r.unit_iterations,
            json_num(r.t_ol),
            json_num(r.t_nol),
            json_num(r.cp_cy),
            json_num(r.lcd_cy)
        ));
        s.push_str(", \"links\": [");
        for (lx, (name, lines, cycles)) in r.links.iter().enumerate() {
            if lx > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"link\": {}, \"lines\": {}, \"cycles\": {}}}",
                json_str(name),
                json_num(*lines),
                json_num(*cycles)
            ));
        }
        s.push_str(&format!(
            "], \"t_ecm_mem\": {}, \"saturation_cores\": {}, \"memory_bytes_per_unit\": {}, \"lc_fast_levels\": {}, \"walk_levels\": {}, \"sim_cy_per_cl\": {}, \"model_error_pct\": {}",
            json_num(r.t_ecm_mem),
            if r.saturation_cores == u32::MAX { "null".to_string() } else { r.saturation_cores.to_string() },
            json_num(r.memory_bytes_per_unit),
            r.lc_fast_levels,
            r.walk_levels,
            r.sim_cy_per_cl.map(json_num).unwrap_or_else(|| "null".to_string()),
            r.model_error_pct.map(json_num).unwrap_or_else(|| "null".to_string())
        ));
        s.push_str(", \"lc_bands\": [");
        for (bx, b) in r.lc_breakpoints.iter().enumerate() {
            if bx > 0 {
                s.push_str(", ");
            }
            s.push_str(&json_str(b));
        }
        s.push_str(&format!(
            "], \"advise_block\": {}, \"advise_t_mem\": {}}}",
            r.advise_block.map(|v| v.to_string()).unwrap_or_else(|| "null".to_string()),
            r.advise_t_mem.map(json_num).unwrap_or_else(|| "null".to_string())
        ));
        s.push_str(if ix + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Trailing `#`-comment block with engine statistics (verbose CSV mode).
pub fn sweep_stats_comment(out: &SweepOutput) -> String {
    let st = &out.stats;
    format!(
        "# points: {}  threads: {}\n# memo hits/misses: machine {}/{}  program {}/{}  analysis {}/{}  incore {}/{}\n",
        out.rows.len(),
        out.threads_used,
        st.machine_hits,
        st.machine_misses,
        st.program_hits,
        st.program_misses,
        st.analysis_hits,
        st.analysis_misses,
        st.incore_hits,
        st.incore_misses
    )
}

/// Quote a CSV field when it contains a delimiter, quote, or newline
/// (RFC 4180): kernel labels and machine paths are user-controlled.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

fn indent(text: &str) -> String {
    text.lines().map(|l| format!("  {l}\n")).collect()
}

fn human_bytes(b: u64) -> String {
    if b >= 1024 * 1024 {
        format!("{:.1} MB", b as f64 / (1024.0 * 1024.0))
    } else if b >= 1024 {
        format!("{:.1} kB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CachePredictor;
    use crate::incore::{CodegenPolicy, PortModel};
    use crate::kernel::parse;
    use crate::models::reference::KERNEL_2D5PT;
    use crate::session::{AnalysisRequest, KernelSpec, ModelKind, Session};
    use std::collections::HashMap;

    fn jacobi_report(model: ModelKind, unit: Unit) -> AnalysisReport {
        let session = Session::new();
        let req = AnalysisRequest::new(KernelSpec::named("2D-5pt"), "SNB")
            .with_constant("N", 6000)
            .with_constant("M", 6000)
            .with_model(model)
            .with_unit(unit);
        session.evaluate(&req).unwrap()
    }

    #[test]
    fn ecm_report_contains_notation_and_saturation() {
        let rep = ecm_report(&jacobi_report(ModelKind::Ecm, Unit::CyPerCl), true);
        assert!(rep.contains("ECM model: {"), "{rep}");
        assert!(rep.contains("saturating at 3 cores"), "{rep}");
        assert!(rep.contains("copy benchmark"), "{rep}");
    }

    #[test]
    fn roofline_report_shows_bottleneck_table() {
        let rep = roofline_report(&jacobi_report(ModelKind::RooflinePort, Unit::CyPerCl));
        assert!(rep.contains("L3-MEM"), "{rep}");
        assert!(rep.contains("Cache or mem bound"), "{rep}");
        assert!(rep.contains("Arithmetic Intensity"), "{rep}");
    }

    #[test]
    fn unit_conversion_appears_in_reports() {
        let rep = ecm_report(&jacobi_report(ModelKind::Ecm, Unit::FlopPerS), false);
        assert!(rep.contains("FLOP/s"), "{rep}");
        let rep = roofline_report(&jacobi_report(ModelKind::RooflinePort, Unit::ItPerS));
        assert!(rep.contains("It/s"), "{rep}");
    }

    #[test]
    fn renderers_are_pure_functions_of_serialized_reports() {
        // the defining property of the redesign: serialize, deserialize,
        // render — the text must be identical to rendering the original
        for model in
            [ModelKind::Ecm, ModelKind::RooflinePort, ModelKind::EcmCpu, ModelKind::Advise]
        {
            let r = jacobi_report(model, Unit::CyPerCl);
            let wire = AnalysisReport::from_json(&r.to_json()).unwrap();
            assert_eq!(render_report(&r, true), render_report(&wire, true), "{model:?}");
            assert!(!render_report(&r, false).is_empty(), "{model:?}");
        }
    }

    #[test]
    fn advise_report_renders_breakpoints_and_ranked_advice() {
        let r = jacobi_report(ModelKind::Advise, Unit::CyPerCl);
        let rep = advise_report(&r);
        assert!(rep.contains("blocking advice"), "{rep}");
        assert!(rep.contains("varied dim: i (constant N, current extent 6000)"), "{rep}");
        assert!(rep.contains("offset-walk levels across sub-evaluations: 0"), "{rep}");
        // the hand-derived SNB breakpoints (DESIGN.md §5)
        assert!(rep.contains("1024"), "{rep}");
        assert!(rep.contains("8192"), "{rep}");
        assert!(rep.contains("655360"), "{rep}");
        assert!(rep.contains("1. block i at 1024: unlocks j@L1"), "{rep}");
    }

    #[test]
    fn incore_report_renders_pressure_table() {
        let r = jacobi_report(ModelKind::EcmCpu, Unit::CyPerCl);
        let rep = incore_report(r.incore.as_ref().unwrap());
        assert!(rep.contains("T_OL"), "{rep}");
        assert!(rep.contains("port pressure"), "{rep}");
        assert!(rep.contains("vectorized"), "{rep}");
    }

    #[test]
    fn cache_viz_lists_all_accesses() {
        let m = MachineModel::snb();
        let p = parse(KERNEL_2D5PT).unwrap();
        let c: HashMap<String, i64> =
            [("N".to_string(), 6000i64), ("M".to_string(), 6000i64)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &c).unwrap();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        let viz = cache_viz(&a, &t);
        assert!(viz.contains("a[relative j][relative i-1]"), "{viz}");
        assert!(viz.contains("store (write-allocate + evict)"), "{viz}");
        assert!(viz.contains("layer conditions"), "{viz}");
        assert!(viz.contains("NO"), "L1 layer condition must fail:\n{viz}");
        // the in-core analysis of the same stack still works standalone
        let pm = PortModel::analyze(&a, &m, &CodegenPolicy::for_machine(&m)).unwrap();
        assert!(pm.t_nol > 0.0);
    }

    #[test]
    fn analysis_report_contains_tables() {
        let p = parse(KERNEL_2D5PT).unwrap();
        let c: HashMap<String, i64> =
            [("N".to_string(), 6000i64), ("M".to_string(), 6000i64)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &c).unwrap();
        let rep = analysis_report(&a);
        assert!(rep.contains("loop stack"));
        assert!(rep.contains("FLOPs per iteration: 4"));
    }

    #[test]
    fn machine_report_table1() {
        let rep = machine_report(&MachineModel::snb());
        assert!(rep.contains("SNB"));
        assert!(rep.contains("2.7 GHz"));
        assert!(rep.contains("20.0 MB"));
    }

    #[test]
    fn sweep_renderers_produce_wellformed_output() {
        use crate::cache::CachePredictorKind;
        use crate::sweep::{build_jobs, SweepEngine};
        use std::sync::Arc;
        let src: Arc<str> = Arc::from(
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];",
        );
        let jobs = build_jobs(
            "triad",
            src,
            &["SNB".to_string()],
            &[1],
            &[("N".to_string(), vec![4096, 8192])],
            CachePredictorKind::Auto,
        );
        let out = SweepEngine::serial().run(&jobs).unwrap();
        let csv = sweep_csv(&out.rows);
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("kernel,machine,cores,predictor,N,"), "{header}");
        assert!(header.contains("T_ECM_Mem"), "{header}");
        assert!(header.contains(",CP,LCD,"), "{header}");
        assert_eq!(lines.count(), 2, "{csv}");
        assert!(csv.contains("triad,SNB,1,auto,4096"), "{csv}");

        let json = sweep_json(&out.rows, &out.stats);
        assert!(json.contains("\"rows\": ["), "{json}");
        assert!(json.contains("\"t_ecm_mem\""), "{json}");
        assert!(json.contains("\"cp_cy\""), "{json}");
        assert!(json.contains("\"lcd_cy\""), "{json}");
        assert!(json.contains("\"N\": 4096"), "{json}");
        // crude balance check for the hand-rolled writer
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes, "{json}");

        let comment = sweep_stats_comment(&out);
        assert!(comment.starts_with("# points: 2"), "{comment}");
    }
}
