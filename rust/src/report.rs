//! Report rendering: the CLI output formats of paper Listing 5 (ECM and
//! Roofline reports), the Fig. 2 cache-usage visualization, the machine
//! summary, and the CSV/JSON row formats of the `sweep` subcommand.

use crate::cache::TrafficPrediction;
use crate::incore::PortModel;
use crate::kernel::KernelAnalysis;
use crate::machine::MachineModel;
use crate::models::{EcmModel, RooflineModel, ScalingModel, Unit};
use crate::sweep::{MemoStats, SweepOutput, SweepRow};
use crate::util::fmt_cy;

/// Render the ECM analysis report (paper Listing 5, upper half).
pub fn ecm_report(
    ecm: &EcmModel,
    scaling: &ScalingModel,
    unit: Unit,
    verbose: bool,
) -> String {
    let mut s = String::new();
    s.push_str(&format!("ECM model: {}\n", ecm.notation()));
    s.push_str(&format!("ECM prediction: {}\n", ecm.prediction_notation()));
    if unit != Unit::CyPerCl {
        let preds = ecm.level_predictions();
        let conv: Vec<String> = preds
            .iter()
            .map(|p| {
                format!(
                    "{:.3e}",
                    unit.convert(
                        *p,
                        ecm.iterations_per_cl as f64,
                        ecm.flops_per_cl,
                        ecm.clock_hz
                    )
                )
            })
            .collect();
        s.push_str(&format!("ECM prediction ({}): {{{}}}\n", unit.suffix(), conv.join(" \\ ")));
    }
    if scaling.t_mem_link > 0.0 {
        s.push_str(&format!("saturating at {} cores\n", scaling.saturation));
    } else {
        s.push_str("no bandwidth saturation (cache-resident working set)\n");
    }
    if verbose {
        for c in &ecm.contributions {
            s.push_str(&format!(
                "  {}: {} CL/unit = {} cy{}\n",
                c.link,
                c.lines,
                fmt_cy(c.cycles),
                c.benchmark
                    .as_ref()
                    .map(|b| format!(" (bw from {b} benchmark)"))
                    .unwrap_or_default()
            ));
        }
    }
    s
}

/// Render the Roofline report (paper Listing 5, lower half).
pub fn roofline_report(roofline: &RooflineModel, unit: Unit) -> String {
    let mut s = String::new();
    s.push_str("Bottlenecks:\n");
    s.push_str("  level   | ar.int. |  perfor. |   bandw.  | bw kernel\n");
    s.push_str("          | FLOP/B  |  cy/CL   |   GB/s    |\n");
    s.push_str("  --------+---------+----------+-----------+----------\n");
    for b in &roofline.bottlenecks {
        s.push_str(&format!(
            "  {:<7} | {:>7} | {:>8} | {:>9} | {}\n",
            b.level,
            b.arith_intensity.map(|ai| format!("{ai:.2}")).unwrap_or_else(|| "-".into()),
            fmt_cy(b.cycles),
            b.bandwidth_bs.map(|bw| format!("{:.1}", bw / 1e9)).unwrap_or_else(|| "-".into()),
            b.benchmark.clone().unwrap_or_else(|| "-".into()),
        ));
    }
    let bn = roofline.bottleneck();
    if roofline.is_memory_bound() {
        s.push_str(&format!(
            "Cache or mem bound: {} ({} benchmark)\n",
            bn.level,
            bn.benchmark.clone().unwrap_or_default()
        ));
        if let Some(ai) = bn.arith_intensity {
            s.push_str(&format!("Arithmetic Intensity: {ai:.2} FLOP/B\n"));
        }
    } else {
        s.push_str("CPU bound\n");
    }
    s.push_str(&format!(
        "Roofline prediction: {} {}\n",
        format_value(bn.cycles, roofline, unit),
        unit.suffix()
    ));
    s
}

fn format_value(cy: f64, r: &RooflineModel, unit: Unit) -> String {
    match unit {
        Unit::CyPerCl => fmt_cy(cy),
        _ => format!(
            "{:.3e}",
            unit.convert(cy, r.iterations_per_cl as f64, r.flops_per_cl, r.clock_hz)
        ),
    }
}

/// Render the in-core (ECMCPU) report.
pub fn incore_report(pm: &PortModel) -> String {
    pm.report()
}

/// Render the static-analysis tables (paper Tables 2-4).
pub fn analysis_report(analysis: &KernelAnalysis) -> String {
    let mut s = String::new();
    s.push_str("loop stack (Table 2):\n");
    s.push_str(&indent(&analysis.loop_stack_table()));
    s.push_str("data accesses (Tables 3/4):\n");
    s.push_str(&indent(&analysis.access_table()));
    s.push_str(&format!(
        "FLOPs per iteration: {} ({} ADD, {} MUL, {} DIV)\n",
        analysis.flops.total(),
        analysis.flops.adds,
        analysis.flops.muls,
        analysis.flops.divs
    ));
    s
}

/// ASCII rendering of the Fig. 2 cache-usage prediction: one line per
/// array access, annotated with the level it hits.
pub fn cache_viz(analysis: &KernelAnalysis, traffic: &TrafficPrediction) -> String {
    let mut s = String::new();
    s.push_str("cache usage prediction (cf. paper Fig. 2):\n");
    s.push_str("  access                      | 1D offset | served by\n");
    s.push_str("  ----------------------------+-----------+----------\n");
    for (ix, acc) in analysis.reads.iter().enumerate() {
        let arr = &analysis.arrays[acc.array];
        let dims: Vec<String> = acc.dims.iter().map(|d| format!("[{d}]")).collect();
        let label = format!("{}{}", arr.name, dims.join(""));
        s.push_str(&format!(
            "  {:<27} | {:>+9} | {}\n",
            label, acc.offset, traffic.access_hit_level[ix]
        ));
    }
    for acc in &analysis.writes {
        let arr = &analysis.arrays[acc.array];
        let dims: Vec<String> = acc.dims.iter().map(|d| format!("[{d}]")).collect();
        s.push_str(&format!(
            "  {:<27} | {:>+9} | store (write-allocate + evict)\n",
            format!("{}{}", arr.name, dims.join("")),
            acc.offset
        ));
    }
    s.push_str("\nlayer conditions:\n");
    s.push_str("  level | dim | required  | capacity  | satisfied\n");
    for lc in &traffic.layer_conditions {
        s.push_str(&format!(
            "  {:<5} | {:<3} | {:>9} | {:>9} | {}\n",
            lc.level,
            lc.dim_name,
            human_bytes(lc.required_bytes),
            human_bytes(lc.cache_bytes),
            if lc.satisfied { "yes" } else { "NO" }
        ));
    }
    s
}

/// Render a machine summary (Table 1 style).
pub fn machine_report(m: &MachineModel) -> String {
    let mut s = String::new();
    s.push_str(&format!("machine: {} ({})\n", m.model_name, m.arch));
    s.push_str(&format!(
        "  clock {} GHz, {} sockets x {} cores, {} threads/core\n",
        m.clock_hz / 1e9,
        m.sockets,
        m.cores_per_socket,
        m.threads_per_core
    ));
    s.push_str(&format!(
        "  DP peak {} flop/cy (ADD {}, MUL {}, FMA {})\n",
        m.flops_per_cycle_dp.total,
        m.flops_per_cycle_dp.add,
        m.flops_per_cycle_dp.mul,
        m.flops_per_cycle_dp.fma
    ));
    for lvl in &m.memory_hierarchy {
        s.push_str(&format!(
            "  {:<4} {:>9} x{} groups, {} cores/group{}\n",
            lvl.name,
            lvl.size_bytes.map(human_bytes).unwrap_or_else(|| "-".into()),
            lvl.groups,
            lvl.cores_per_group,
            lvl.cycles_per_cacheline
                .map(|c| format!(", {c} cy/CL to next level"))
                .unwrap_or_default()
        ));
    }
    s
}

/// Render sweep rows as CSV: one row per point, a stable header derived
/// from the union of constant names and the union of link labels across
/// all rows (machines may differ in cache-level names and counts).
pub fn sweep_csv(rows: &[SweepRow]) -> String {
    let mut const_names: Vec<&str> = Vec::new();
    for r in rows {
        for k in r.constants.keys() {
            if !const_names.contains(&k.as_str()) {
                const_names.push(k);
            }
        }
    }
    const_names.sort_unstable();
    // union of link labels in first-appearance order, so heterogeneous
    // machine hierarchies each keep their columns (absent links stay empty)
    let mut link_names: Vec<&str> = Vec::new();
    for r in rows {
        for (n, _, _) in &r.links {
            if !link_names.contains(&n.as_str()) {
                link_names.push(n);
            }
        }
    }

    let mut s = String::from("kernel,machine,cores,predictor");
    for c in &const_names {
        s.push(',');
        s.push_str(&csv_field(c));
    }
    s.push_str(",unit_it,T_OL,T_nOL");
    for l in &link_names {
        s.push_str(",T_");
        s.push_str(l);
    }
    s.push_str(",T_ECM_Mem,sat_cores,mem_B_per_unit,lc_fast_levels,walk_levels,lc_bands\n");

    for r in rows {
        s.push_str(&format!(
            "{},{},{},{}",
            csv_field(&r.label),
            csv_field(&r.machine),
            r.cores,
            r.predictor.name()
        ));
        for c in &const_names {
            s.push(',');
            if let Some(v) = r.constants.get(*c) {
                s.push_str(&v.to_string());
            }
        }
        s.push_str(&format!(",{},{},{}", r.unit_iterations, fmt_cy(r.t_ol), fmt_cy(r.t_nol)));
        for l in &link_names {
            s.push(',');
            if let Some((_, _, cy)) = r.links.iter().find(|(n, _, _)| n == l) {
                s.push_str(&fmt_cy(*cy));
            }
        }
        let sat = if r.saturation_cores == u32::MAX {
            "inf".to_string()
        } else {
            r.saturation_cores.to_string()
        };
        s.push_str(&format!(
            ",{},{},{},{},{},{}\n",
            fmt_cy(r.t_ecm_mem),
            sat,
            r.memory_bytes_per_unit,
            r.lc_fast_levels,
            r.walk_levels,
            r.lc_breakpoints.join(" ")
        ));
    }
    s
}

/// Render sweep rows plus memo statistics as a JSON document (hand-rolled:
/// the offline crate set has no serde).
pub fn sweep_json(rows: &[SweepRow], stats: &MemoStats) -> String {
    let mut s = String::from("{\n  \"stats\": {");
    s.push_str(&format!(
        "\"machine_hits\": {}, \"machine_misses\": {}, \"program_hits\": {}, \"program_misses\": {}, \"analysis_hits\": {}, \"analysis_misses\": {}, \"incore_hits\": {}, \"incore_misses\": {}",
        stats.machine_hits,
        stats.machine_misses,
        stats.program_hits,
        stats.program_misses,
        stats.analysis_hits,
        stats.analysis_misses,
        stats.incore_hits,
        stats.incore_misses
    ));
    s.push_str("},\n  \"rows\": [\n");
    for (ix, r) in rows.iter().enumerate() {
        s.push_str("    {");
        s.push_str(&format!(
            "\"kernel\": {}, \"machine\": {}, \"cores\": {}, \"predictor\": \"{}\"",
            json_str(&r.label),
            json_str(&r.machine),
            r.cores,
            r.predictor.name()
        ));
        s.push_str(", \"constants\": {");
        for (cx, (k, v)) in r.constants.iter().enumerate() {
            if cx > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!("{}: {}", json_str(k), v));
        }
        s.push_str(&format!(
            "}}, \"unit_iterations\": {}, \"t_ol\": {}, \"t_nol\": {}",
            r.unit_iterations,
            json_num(r.t_ol),
            json_num(r.t_nol)
        ));
        s.push_str(", \"links\": [");
        for (lx, (name, lines, cycles)) in r.links.iter().enumerate() {
            if lx > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"link\": {}, \"lines\": {}, \"cycles\": {}}}",
                json_str(name),
                json_num(*lines),
                json_num(*cycles)
            ));
        }
        s.push_str(&format!(
            "], \"t_ecm_mem\": {}, \"saturation_cores\": {}, \"memory_bytes_per_unit\": {}, \"lc_fast_levels\": {}, \"walk_levels\": {}",
            json_num(r.t_ecm_mem),
            if r.saturation_cores == u32::MAX { "null".to_string() } else { r.saturation_cores.to_string() },
            json_num(r.memory_bytes_per_unit),
            r.lc_fast_levels,
            r.walk_levels
        ));
        s.push_str(", \"lc_bands\": [");
        for (bx, b) in r.lc_breakpoints.iter().enumerate() {
            if bx > 0 {
                s.push_str(", ");
            }
            s.push_str(&json_str(b));
        }
        s.push_str("]}");
        s.push_str(if ix + 1 < rows.len() { ",\n" } else { "\n" });
    }
    s.push_str("  ]\n}\n");
    s
}

/// Trailing `#`-comment block with engine statistics (verbose CSV mode).
pub fn sweep_stats_comment(out: &SweepOutput) -> String {
    let st = &out.stats;
    format!(
        "# points: {}  threads: {}\n# memo hits/misses: machine {}/{}  program {}/{}  analysis {}/{}  incore {}/{}\n",
        out.rows.len(),
        out.threads_used,
        st.machine_hits,
        st.machine_misses,
        st.program_hits,
        st.program_misses,
        st.analysis_hits,
        st.analysis_misses,
        st.incore_hits,
        st.incore_misses
    )
}

/// Quote a CSV field when it contains a delimiter, quote, or newline
/// (RFC 4180): kernel labels and machine paths are user-controlled.
fn csv_field(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') || s.contains('\r') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn json_num(v: f64) -> String {
    // Rust's shortest-roundtrip float formatting is valid JSON for finite
    // values (bare integers included)
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

fn indent(text: &str) -> String {
    text.lines().map(|l| format!("  {l}\n")).collect()
}

fn human_bytes(b: u64) -> String {
    if b >= 1024 * 1024 {
        format!("{:.1} MB", b as f64 / (1024.0 * 1024.0))
    } else if b >= 1024 {
        format!("{:.1} kB", b as f64 / 1024.0)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cache::CachePredictor;
    use crate::incore::CodegenPolicy;
    use crate::kernel::parse;
    use crate::models::reference::KERNEL_2D5PT;
    use std::collections::HashMap;

    fn jacobi_stack() -> (KernelAnalysis, PortModel, TrafficPrediction, MachineModel) {
        let m = MachineModel::snb();
        let p = parse(KERNEL_2D5PT).unwrap();
        let c: HashMap<String, i64> =
            [("N".to_string(), 6000i64), ("M".to_string(), 6000i64)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &c).unwrap();
        let pm = PortModel::analyze(&a, &m, &CodegenPolicy::for_machine(&m)).unwrap();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        (a, pm, t, m)
    }

    #[test]
    fn ecm_report_contains_notation_and_saturation() {
        let (_, pm, t, m) = jacobi_stack();
        let ecm = EcmModel::build(&pm, &t, &m).unwrap();
        let sc = ScalingModel::build(&ecm, &m);
        let rep = ecm_report(&ecm, &sc, Unit::CyPerCl, true);
        assert!(rep.contains("ECM model: {"), "{rep}");
        assert!(rep.contains("saturating at 3 cores"), "{rep}");
        assert!(rep.contains("copy benchmark"), "{rep}");
    }

    #[test]
    fn roofline_report_shows_bottleneck_table() {
        let (a, pm, t, m) = jacobi_stack();
        let r = RooflineModel::build(&a, &t, &m, Some(&pm)).unwrap();
        let rep = roofline_report(&r, Unit::CyPerCl);
        assert!(rep.contains("L3-MEM"), "{rep}");
        assert!(rep.contains("Cache or mem bound"), "{rep}");
        assert!(rep.contains("Arithmetic Intensity"), "{rep}");
    }

    #[test]
    fn unit_conversion_appears_in_reports() {
        let (a, pm, t, m) = jacobi_stack();
        let ecm = EcmModel::build(&pm, &t, &m).unwrap();
        let sc = ScalingModel::build(&ecm, &m);
        let rep = ecm_report(&ecm, &sc, Unit::FlopPerS, false);
        assert!(rep.contains("FLOP/s"), "{rep}");
        let r = RooflineModel::build(&a, &t, &m, Some(&pm)).unwrap();
        let rep = roofline_report(&r, Unit::ItPerS);
        assert!(rep.contains("It/s"), "{rep}");
    }

    #[test]
    fn cache_viz_lists_all_accesses() {
        let (a, _, t, _) = jacobi_stack();
        let viz = cache_viz(&a, &t);
        assert!(viz.contains("a[relative j][relative i-1]"), "{viz}");
        assert!(viz.contains("store (write-allocate + evict)"), "{viz}");
        assert!(viz.contains("layer conditions"), "{viz}");
        assert!(viz.contains("NO"), "L1 layer condition must fail:\n{viz}");
    }

    #[test]
    fn analysis_report_contains_tables() {
        let (a, _, _, _) = jacobi_stack();
        let rep = analysis_report(&a);
        assert!(rep.contains("loop stack"));
        assert!(rep.contains("FLOPs per iteration: 4"));
    }

    #[test]
    fn machine_report_table1() {
        let rep = machine_report(&MachineModel::snb());
        assert!(rep.contains("SNB"));
        assert!(rep.contains("2.7 GHz"));
        assert!(rep.contains("20.0 MB"));
    }

    #[test]
    fn sweep_renderers_produce_wellformed_output() {
        use crate::cache::CachePredictorKind;
        use crate::sweep::{build_jobs, SweepEngine};
        use std::sync::Arc;
        let src: Arc<str> = Arc::from(
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];",
        );
        let jobs = build_jobs(
            "triad",
            src,
            &["SNB".to_string()],
            &[1],
            &[("N".to_string(), vec![4096, 8192])],
            CachePredictorKind::Auto,
        );
        let out = SweepEngine::serial().run(&jobs).unwrap();
        let csv = sweep_csv(&out.rows);
        let mut lines = csv.lines();
        let header = lines.next().unwrap();
        assert!(header.starts_with("kernel,machine,cores,predictor,N,"), "{header}");
        assert!(header.contains("T_ECM_Mem"), "{header}");
        assert_eq!(lines.count(), 2, "{csv}");
        assert!(csv.contains("triad,SNB,1,auto,4096"), "{csv}");

        let json = sweep_json(&out.rows, &out.stats);
        assert!(json.contains("\"rows\": ["), "{json}");
        assert!(json.contains("\"t_ecm_mem\""), "{json}");
        assert!(json.contains("\"N\": 4096"), "{json}");
        // crude balance check for the hand-rolled writer
        let opens = json.matches('{').count();
        let closes = json.matches('}').count();
        assert_eq!(opens, closes, "{json}");

        let comment = sweep_stats_comment(&out);
        assert!(comment.starts_with("# points: 2"), "{comment}");
    }
}
