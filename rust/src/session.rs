//! The unified analysis front end: a [`Session`] owns every cross-request
//! cache (parsed [`Program`]s, [`KernelAnalysis`] bindings, [`PortModel`]
//! in-core predictions, loaded [`MachineModel`]s) and evaluates typed
//! [`AnalysisRequest`]s into serializable [`AnalysisReport`]s.
//!
//! Every consumer goes through this API: the CLI single-run modes, the
//! batched [`crate::sweep::SweepEngine`] (a parallel map of requests over
//! one shared session), the `kerncraft serve` JSON-lines front end, the
//! benches and the examples. The pipeline stages stay independent,
//! composable components — the session only routes, memoizes and
//! assembles them:
//!
//! * machine key → [`MachineModel`] (builtin tag or YAML file),
//! * kernel source → [`Program`] (parse),
//! * (source, constants) → [`KernelAnalysis`] (static analysis),
//! * (source, constants, machine, codegen) → [`PortModel`] (in-core),
//! * per request: cache prediction, ECM / Roofline assembly, scaling,
//!   and (for [`ModelKind::Validate`]) a virtual-testbed run compared
//!   against the analytic prediction.
//!
//! The caches sit behind sharded locks and the memo counters are atomic,
//! so one session serves many threads at once — the sweep engine's worker
//! pool and `kerncraft serve --threads K` both lean on this. The overall
//! architecture is mapped in DESIGN.md §2.
//!
//! Memoization is observable: [`MemoStats`] counts hits and misses both
//! per session ([`Session::stats`]) and per request (the `session` field
//! of every [`AnalysisReport`]) — the acceptance hook for batch front
//! ends amortizing parse/analysis work across requests.
//!
//! ```no_run
//! use kerncraft::session::{AnalysisRequest, KernelSpec, Session};
//!
//! let session = Session::new();
//! let req = AnalysisRequest::new(KernelSpec::named("triad"), "SNB")
//!     .with_constant("N", 8_000_000);
//! let report = session.evaluate(&req).unwrap();
//! println!("{}", report.to_json());
//! ```
//!
//! Requests and reports round-trip through JSON (hand-rolled on
//! [`crate::jsonio`]; the offline crate set has no serde), which is the
//! wire format of `kerncraft serve`.

use crate::cache::{CachePredictor, CachePredictorKind, TrafficPrediction};
use crate::incore::{CodegenPolicy, PortModel};
use crate::jsonio::{self, json_num, json_str, JsonValue};
use crate::kernel::{KernelAnalysis, Program};
use crate::machine::MachineModel;
use crate::models::{reference, EcmModel, RooflineModel, ScalingModel, Unit};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

// ---------------------------------------------------------------------------
// request types
// ---------------------------------------------------------------------------

/// Which kernel a request analyzes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum KernelSpec {
    /// Inline source text with a display label.
    Source {
        label: String,
        source: Arc<str>,
    },
    /// A shipped reference kernel (Table 5 tag, e.g. `"2D-5pt"`).
    Named(String),
    /// A kernel file on disk.
    Path(String),
}

impl KernelSpec {
    /// Inline source with a label.
    pub fn source(label: impl Into<String>, source: impl Into<Arc<str>>) -> KernelSpec {
        KernelSpec::Source { label: label.into(), source: source.into() }
    }

    /// A Table 5 reference kernel by tag.
    pub fn named(tag: impl Into<String>) -> KernelSpec {
        KernelSpec::Named(tag.into())
    }

    /// A kernel file path.
    pub fn path(path: impl Into<String>) -> KernelSpec {
        KernelSpec::Path(path.into())
    }

    /// Resolve to (label, source text).
    fn resolve(&self) -> Result<(String, Arc<str>)> {
        match self {
            KernelSpec::Source { label, source } => Ok((label.clone(), source.clone())),
            KernelSpec::Named(tag) => reference::kernel_source(tag)
                .map(|s| (tag.clone(), Arc::from(s)))
                .ok_or_else(|| anyhow!("unknown reference kernel '{tag}' (use a Table 5 tag)")),
            KernelSpec::Path(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading kernel file {path}"))?;
                let label = std::path::Path::new(path)
                    .file_stem()
                    .and_then(|s| s.to_str())
                    .unwrap_or(path)
                    .to_string();
                Ok((label, Arc::from(text.as_str())))
            }
        }
    }
}

/// Which performance model(s) a request asks for (paper §4.6 modes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ModelKind {
    /// Full ECM: in-core + data transfers + scaling.
    #[default]
    Ecm,
    /// Data transfers only (no in-core model).
    EcmData,
    /// In-core model only (no cache prediction).
    EcmCpu,
    /// Roofline with the arithmetic-peak in-core bound.
    Roofline,
    /// Roofline with the port-model in-core bound (paper RooflineIACA).
    RooflinePort,
    /// Full ECM plus a virtual-testbed run (see [`crate::sim`]): the
    /// report gains a `validation` section comparing the simulated
    /// "measurement" against the analytic prediction — the paper's
    /// model-vs-benchmark loop (Table 5, Fig. 4) as one request.
    Validate,
    /// Full ECM plus the blocking adviser (see [`crate::advise`]): solve
    /// the layer-condition breakpoints analytically, evaluate candidate
    /// inner-dimension blockings through the session, and report ranked
    /// advice in an `advise` section (DESIGN.md §5).
    Advise,
}

impl ModelKind {
    /// Parse a model name (the CLI `-p` spellings).
    pub fn parse(s: &str) -> Option<ModelKind> {
        Some(match s {
            "ECM" => ModelKind::Ecm,
            "ECMData" => ModelKind::EcmData,
            "ECMCPU" => ModelKind::EcmCpu,
            "Roofline" => ModelKind::Roofline,
            "RooflinePort" | "RooflineIACA" => ModelKind::RooflinePort,
            "Validate" => ModelKind::Validate,
            "Advise" => ModelKind::Advise,
            _ => return None,
        })
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            ModelKind::Ecm => "ECM",
            ModelKind::EcmData => "ECMData",
            ModelKind::EcmCpu => "ECMCPU",
            ModelKind::Roofline => "Roofline",
            ModelKind::RooflinePort => "RooflinePort",
            ModelKind::Validate => "Validate",
            ModelKind::Advise => "Advise",
        }
    }

    fn needs_incore(&self) -> bool {
        matches!(
            self,
            ModelKind::Ecm
                | ModelKind::EcmCpu
                | ModelKind::RooflinePort
                | ModelKind::Validate
                | ModelKind::Advise
        )
    }

    fn needs_traffic(&self) -> bool {
        !matches!(self, ModelKind::EcmCpu)
    }

    /// Stable index for per-model counters (the
    /// `kerncraft_eval_seconds_total{model=...}` metric family).
    pub fn ix(&self) -> usize {
        match self {
            ModelKind::Ecm => 0,
            ModelKind::EcmData => 1,
            ModelKind::EcmCpu => 2,
            ModelKind::Roofline => 3,
            ModelKind::RooflinePort => 4,
            ModelKind::Validate => 5,
            ModelKind::Advise => 6,
        }
    }

    /// Every model, in counter-index order.
    pub const ALL: [ModelKind; 7] = [
        ModelKind::Ecm,
        ModelKind::EcmData,
        ModelKind::EcmCpu,
        ModelKind::Roofline,
        ModelKind::RooflinePort,
        ModelKind::Validate,
        ModelKind::Advise,
    ];
}

/// Which codegen policy the in-core model assumes.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CodegenSelection {
    /// [`CodegenPolicy::for_machine`] — the paper's icc 15 `-xAVX` model.
    #[default]
    MachineDefault,
    /// [`CodegenPolicy::scalar`] — no SIMD, no FMA.
    Scalar,
}

impl CodegenSelection {
    /// Parse `machine` / `scalar` (case-insensitive).
    pub fn parse(s: &str) -> Option<CodegenSelection> {
        match s.to_ascii_lowercase().as_str() {
            "machine" | "default" => Some(CodegenSelection::MachineDefault),
            "scalar" => Some(CodegenSelection::Scalar),
            _ => None,
        }
    }

    /// Canonical spelling.
    pub fn name(&self) -> &'static str {
        match self {
            CodegenSelection::MachineDefault => "machine",
            CodegenSelection::Scalar => "scalar",
        }
    }

    fn policy(&self, machine: &MachineModel) -> CodegenPolicy {
        match self {
            CodegenSelection::MachineDefault => CodegenPolicy::for_machine(machine),
            CodegenSelection::Scalar => CodegenPolicy::scalar(),
        }
    }
}

/// One typed analysis request — everything the pipeline needs to turn a
/// (kernel, problem size, machine) triple into a prediction.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisRequest {
    /// Optional caller-assigned id, echoed in the report (batch fronts).
    pub id: Option<String>,
    pub kernel: KernelSpec,
    /// Constant bindings (ordered, so memo keys are stable).
    pub constants: BTreeMap<String, i64>,
    /// Machine key: builtin tag ("SNB"/"HSW") or machine-file path.
    pub machine: String,
    /// Active cores (shared caches are partitioned accordingly).
    pub cores: u32,
    pub model: ModelKind,
    pub predictor: CachePredictorKind,
    pub codegen: CodegenSelection,
    /// Simulation engine for the virtual testbed ([`ModelKind::Validate`]
    /// only; ignored by the analytic models).
    pub sim_engine: crate::sim::SimEngine,
    /// Output unit the consumer intends to render in (carried through to
    /// the report; the report always stores cycles natively).
    pub unit: Unit,
}

impl AnalysisRequest {
    /// Request with defaults: 1 core, full ECM, offset-walk predictor,
    /// machine codegen policy, cy/CL.
    pub fn new(kernel: KernelSpec, machine: impl Into<String>) -> AnalysisRequest {
        AnalysisRequest {
            id: None,
            kernel,
            constants: BTreeMap::new(),
            machine: machine.into(),
            cores: 1,
            model: ModelKind::Ecm,
            predictor: CachePredictorKind::Offsets,
            codegen: CodegenSelection::MachineDefault,
            sim_engine: crate::sim::SimEngine::Fast,
            unit: Unit::CyPerCl,
        }
    }

    /// Bind one constant (builder style).
    pub fn with_constant(mut self, name: impl Into<String>, value: i64) -> Self {
        self.constants.insert(name.into(), value);
        self
    }

    /// Set the active core count.
    pub fn with_cores(mut self, cores: u32) -> Self {
        self.cores = cores;
        self
    }

    /// Select the model.
    pub fn with_model(mut self, model: ModelKind) -> Self {
        self.model = model;
        self
    }

    /// Select the cache predictor back end.
    pub fn with_predictor(mut self, predictor: CachePredictorKind) -> Self {
        self.predictor = predictor;
        self
    }

    /// Select the codegen policy.
    pub fn with_codegen(mut self, codegen: CodegenSelection) -> Self {
        self.codegen = codegen;
        self
    }

    /// Select the virtual-testbed engine (Validate mode).
    pub fn with_sim_engine(mut self, engine: crate::sim::SimEngine) -> Self {
        self.sim_engine = engine;
        self
    }

    /// Select the report unit.
    pub fn with_unit(mut self, unit: Unit) -> Self {
        self.unit = unit;
        self
    }

    /// Attach a caller id (echoed in the report).
    pub fn with_id(mut self, id: impl Into<String>) -> Self {
        self.id = Some(id.into());
        self
    }

    /// Canonical persistent-cache key: a [`crate::jsonio::content_hash`]
    /// over the canonicalized request (id stripped — correlation ids must
    /// not fragment the cache) plus content digests of the resolved
    /// kernel source and the machine description (builtin tags digest
    /// their embedded YAML, paths the file bytes — the same resolution
    /// order as [`MachineModel::load`]). Two processes computing the
    /// same key therefore agree byte for byte, and editing a kernel file
    /// or machine YAML orphans old entries without any bookkeeping — the
    /// digest simply changes. The report format itself is pinned by the
    /// crate version, so an upgraded binary never serves a stale layout.
    /// Errors only when the kernel spec or machine cannot be resolved
    /// (missing file, unknown tag) — exactly the requests the pipeline
    /// would reject anyway, so nothing unkeyable is ever cached.
    ///
    /// Note: [`Session::evaluate`] does not call this directly — it
    /// resolves the kernel once and keys through the session's memoized
    /// (model, digest) machine entry, so the bytes a key describes are
    /// exactly the bytes the evaluation consumes even while the files
    /// are being edited.
    pub fn cache_key(&self) -> Result<String> {
        let machine_digest = match MachineModel::builtin_yaml(&self.machine) {
            Some(yml) => jsonio::content_hash(yml.as_bytes()),
            None => {
                let bytes = std::fs::read(&self.machine).with_context(|| {
                    format!("reading machine file {}", self.machine)
                })?;
                jsonio::content_hash(&bytes)
            }
        };
        let (label, source) = self.kernel.resolve()?;
        Ok(self.cache_key_resolved(&machine_digest, &label, &source))
    }

    /// Compose the cache key from externally resolved inputs (the
    /// session passes the kernel source it will evaluate and the digest
    /// memoized with the machine model).
    fn cache_key_resolved(&self, machine_digest: &str, label: &str, source: &str) -> String {
        let mut normalized = self.clone();
        normalized.id = None;
        let wire = jsonio::parse(&normalized.to_json())
            .expect("request serialization is well-formed JSON");
        let mut canon = jsonio::canonical(&wire);
        canon.push_str("\u{1}label=");
        canon.push_str(label);
        canon.push_str("\u{1}kernel-digest=");
        canon.push_str(&jsonio::content_hash(source.as_bytes()));
        canon.push_str("\u{1}machine-digest=");
        canon.push_str(machine_digest);
        canon.push_str("\u{1}format=");
        canon.push_str(env!("CARGO_PKG_VERSION"));
        jsonio::content_hash(canon.as_bytes())
    }
}

/// Plug-in seam for a report-level cache consulted by
/// [`Session::evaluate`] before any pipeline stage runs: `get` answers a
/// [`AnalysisRequest::cache_key`] with a previously evaluated report,
/// `put` records a fresh one (its `id` already stripped, so one cached
/// entry serves every correlation id). Implementations must be safe to
/// share across the serve worker pool. The shipped implementation is the
/// disk-backed [`crate::server::cache::DiskCache`] behind
/// `kerncraft serve --cache-dir`; see docs/OPERATIONS.md for its layout
/// and invalidation rules.
pub trait ReportCache: Send + Sync {
    /// Look up a cached report by key (None on miss or invalid entry).
    fn get(&self, key: &str) -> Option<AnalysisReport>;
    /// Store an evaluated report under its key. Failures must be
    /// swallowed — a broken cache degrades to re-evaluation, never to a
    /// failed request.
    fn put(&self, key: &str, report: &AnalysisReport);
}

// ---------------------------------------------------------------------------
// report types
// ---------------------------------------------------------------------------

/// Memoization counters — per session ([`Session::stats`]) or per request
/// (the `session` field of [`AnalysisReport`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoStats {
    pub machine_hits: u64,
    pub machine_misses: u64,
    pub program_hits: u64,
    pub program_misses: u64,
    pub analysis_hits: u64,
    pub analysis_misses: u64,
    pub incore_hits: u64,
    pub incore_misses: u64,
}

impl MemoStats {
    /// Total hits across all stages.
    pub fn hits(&self) -> u64 {
        self.machine_hits + self.program_hits + self.analysis_hits + self.incore_hits
    }

    /// Total misses across all stages.
    pub fn misses(&self) -> u64 {
        self.machine_misses + self.program_misses + self.analysis_misses + self.incore_misses
    }

    /// Render as a JSON object (shared by report and sweep writers).
    pub fn json_object(&self) -> String {
        format!(
            "{{\"machine_hits\": {}, \"machine_misses\": {}, \"program_hits\": {}, \"program_misses\": {}, \"analysis_hits\": {}, \"analysis_misses\": {}, \"incore_hits\": {}, \"incore_misses\": {}}}",
            self.machine_hits,
            self.machine_misses,
            self.program_hits,
            self.program_misses,
            self.analysis_hits,
            self.analysis_misses,
            self.incore_hits,
            self.incore_misses
        )
    }

    /// Accumulate another snapshot (used to sum per-request deltas).
    pub fn absorb(&mut self, o: MemoStats) {
        self.machine_hits += o.machine_hits;
        self.machine_misses += o.machine_misses;
        self.program_hits += o.program_hits;
        self.program_misses += o.program_misses;
        self.analysis_hits += o.analysis_hits;
        self.analysis_misses += o.analysis_misses;
        self.incore_hits += o.incore_hits;
        self.incore_misses += o.incore_misses;
    }

    fn from_json_value(v: &JsonValue) -> Result<MemoStats> {
        Ok(MemoStats {
            machine_hits: get_u64(v, "machine_hits")?,
            machine_misses: get_u64(v, "machine_misses")?,
            program_hits: get_u64(v, "program_hits")?,
            program_misses: get_u64(v, "program_misses")?,
            analysis_hits: get_u64(v, "analysis_hits")?,
            analysis_misses: get_u64(v, "analysis_misses")?,
            incore_hits: get_u64(v, "incore_hits")?,
            incore_misses: get_u64(v, "incore_misses")?,
        })
    }
}

/// One loop-carried dependency chain in the in-core section.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainReport {
    /// Carried scalars on the cycle, joined with `->` (e.g. `c->sum`).
    pub name: String,
    /// Cycle-mean latency per scalar iteration.
    pub latency_per_it: f64,
    /// Chain cost per unit of work.
    pub cy_per_unit: f64,
    /// True when modulo variable expansion breaks this chain.
    pub broken: bool,
    /// Resolved mnemonics along the chain.
    pub instructions: Vec<String>,
}

/// In-core section (port model + dependency DAG) of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct IncoreReport {
    /// ISA family the instruction selection was resolved for
    /// ("x86"/"aarch64").
    pub isa: String,
    pub t_ol: f64,
    pub t_nol: f64,
    /// Pure throughput bound (IACA "TP").
    pub tp: f64,
    /// Dependency-DAG critical path per unit of work (OSACA "CP").
    pub cp_cy: f64,
    /// Loop-carried dependency bound per unit of work (OSACA "LCD",
    /// 0 when none).
    pub lcd_cy: f64,
    pub vectorized: bool,
    pub vector_elems: u32,
    /// (port name, cycles per unit) pressure table.
    pub port_pressure: Vec<(String, f64)>,
    /// Loop-carried dependency chains, unbroken-first then by
    /// descending latency.
    pub chains: Vec<ChainReport>,
    /// Name of the dominant (unbroken, highest-latency) chain, if any.
    pub dominant_chain: Option<String>,
}

impl IncoreReport {
    pub(crate) fn from_model(pm: &PortModel) -> IncoreReport {
        IncoreReport {
            isa: pm.isa.name().to_string(),
            t_ol: pm.t_ol,
            t_nol: pm.t_nol,
            tp: pm.tp,
            cp_cy: pm.cp_cy,
            lcd_cy: pm.lcd_cy,
            vectorized: pm.vectorized,
            vector_elems: pm.vector_elems,
            port_pressure: pm.pressure.iter().map(|p| (p.port.clone(), p.cycles)).collect(),
            chains: pm
                .chains
                .iter()
                .map(|c| ChainReport {
                    name: c.name.clone(),
                    latency_per_it: c.latency_per_it,
                    cy_per_unit: c.cy_per_unit,
                    broken: c.broken,
                    instructions: c.instructions.clone(),
                })
                .collect(),
            dominant_chain: pm.dominant_chain.clone(),
        }
    }
}

/// One cache-level traffic row of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelTrafficReport {
    pub level: String,
    pub read_miss_lines: f64,
    pub write_allocate_lines: f64,
    pub evict_lines: f64,
    pub hit_lines: f64,
    pub total_lines: f64,
}

/// Traffic section of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficReport {
    pub cacheline_bytes: u64,
    /// Inner to outer, one row per cache level.
    pub levels: Vec<LevelTrafficReport>,
    pub memory_bytes_per_unit: f64,
    /// Cache levels answered by the layer-condition fast path.
    pub lc_fast_levels: u32,
    /// Cache levels that ran the backward offset walk.
    pub walk_levels: u32,
    /// Per loop dimension: innermost level whose layer condition holds
    /// (`"j@L2"`, `"j@MEM"` when none does).
    pub lc_breakpoints: Vec<String>,
}

impl TrafficReport {
    fn from_prediction(t: &TrafficPrediction, analysis: &KernelAnalysis) -> TrafficReport {
        TrafficReport {
            cacheline_bytes: t.cacheline_bytes,
            levels: t
                .levels
                .iter()
                .map(|l| LevelTrafficReport {
                    level: l.level.clone(),
                    read_miss_lines: l.read_miss_lines,
                    write_allocate_lines: l.write_allocate_lines,
                    evict_lines: l.evict_lines,
                    hit_lines: l.hit_lines,
                    total_lines: l.total_lines(),
                })
                .collect(),
            memory_bytes_per_unit: t.memory_bytes_per_unit(),
            lc_fast_levels: t.stats.lc_fast_levels,
            walk_levels: t.stats.walk_levels,
            lc_breakpoints: t.lc_breakpoints(analysis),
        }
    }
}

/// One inter-level transfer contribution of the ECM section.
#[derive(Debug, Clone, PartialEq)]
pub struct EcmContributionReport {
    /// Link label, e.g. `"L1L2"`, `"L3Mem"`.
    pub link: String,
    /// Cache lines crossing this link per unit of work.
    pub lines: f64,
    /// Cycles per unit of work.
    pub cycles: f64,
    /// Microbenchmark the bandwidth came from (memory link only).
    pub benchmark: Option<String>,
}

/// ECM section of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct EcmReport {
    pub t_ol: f64,
    pub t_nol: f64,
    /// Data-transfer contributions, inner link first.
    pub contributions: Vec<EcmContributionReport>,
    /// In-memory prediction `max(T_OL, T_nOL + ΣT_data)`.
    pub t_mem: f64,
    /// Per-level predictions `{ECM_L1 \ ECM_L2 \ ... \ ECM_Mem}`.
    pub level_predictions: Vec<f64>,
    /// Saturation core count (None: never saturates, cache-resident).
    pub saturation_cores: Option<u32>,
    /// Saturated memory bandwidth used for the outermost link (bytes/s).
    pub mem_bandwidth_bs: f64,
}

impl EcmReport {
    fn from_model(e: &EcmModel) -> EcmReport {
        let sat = e.saturation_cores();
        EcmReport {
            t_ol: e.t_ol,
            t_nol: e.t_nol,
            contributions: e
                .contributions
                .iter()
                .map(|c| EcmContributionReport {
                    link: c.link.clone(),
                    lines: c.lines,
                    cycles: c.cycles,
                    benchmark: c.benchmark.clone(),
                })
                .collect(),
            t_mem: e.t_mem(),
            level_predictions: e.level_predictions(),
            saturation_cores: (sat != u32::MAX).then_some(sat),
            mem_bandwidth_bs: e.mem_bandwidth_bs,
        }
    }
}

/// Multicore scaling section of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct ScalingReport {
    /// Single-core in-memory time (cy per unit).
    pub t_single: f64,
    /// Memory-link time (cy per unit) — the plateau (0: cache-resident).
    pub t_mem_link: f64,
    /// Saturation core count (None: never saturates).
    pub saturation_cores: Option<u32>,
    /// Cores in one memory domain.
    pub domain_cores: u32,
}

impl ScalingReport {
    fn from_model(s: &ScalingModel) -> ScalingReport {
        ScalingReport {
            t_single: s.t_single,
            t_mem_link: s.t_mem_link,
            saturation_cores: (s.saturation != u32::MAX).then_some(s.saturation),
            domain_cores: s.domain_cores,
        }
    }
}

/// One candidate bottleneck (ceiling) of the Roofline section.
#[derive(Debug, Clone, PartialEq)]
pub struct RooflineCeilingReport {
    /// `"CPU"`, `"L1"`, `"L1-L2"`, ..., `"L3-MEM"`.
    pub level: String,
    /// Predicted time bound (cy per unit).
    pub cycles: f64,
    /// Bandwidth assumed (bytes/s), None for the CPU row.
    pub bandwidth_bs: Option<f64>,
    /// Matched microbenchmark, None for the CPU row.
    pub benchmark: Option<String>,
    /// Arithmetic intensity at this level (flop/byte), None for CPU.
    pub arith_intensity: Option<f64>,
}

/// Roofline section of a report.
#[derive(Debug, Clone, PartialEq)]
pub struct RooflineReport {
    /// True for the port-model in-core variant (RooflinePort).
    pub port_model: bool,
    pub ceilings: Vec<RooflineCeilingReport>,
    /// Index of the binding bottleneck in `ceilings`.
    pub bottleneck: usize,
    /// The prediction (cy per unit) — the bottleneck's bound.
    pub prediction_cycles: f64,
    /// Bound by data transfers rather than compute.
    pub memory_bound: bool,
}

impl RooflineReport {
    fn from_model(r: &RooflineModel) -> RooflineReport {
        let bottleneck = r.bottleneck_index();
        RooflineReport {
            port_model: r.mode == crate::models::RooflineMode::PortModel,
            ceilings: r
                .bottlenecks
                .iter()
                .map(|b| RooflineCeilingReport {
                    level: b.level.clone(),
                    cycles: b.cycles,
                    bandwidth_bs: b.bandwidth_bs,
                    benchmark: b.benchmark.clone(),
                    arith_intensity: b.arith_intensity,
                })
                .collect(),
            bottleneck,
            prediction_cycles: r.prediction(),
            memory_bound: r.is_memory_bound(),
        }
    }
}

/// Per-cache-level statistics of a virtual-testbed run, as reported in
/// the `validation` section.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ValidationLevelReport {
    pub level: String,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

/// Validation section of a report ([`ModelKind::Validate`]): the virtual
/// testbed's simulated "measurement" next to the analytic ECM in-memory
/// prediction, with the relative model error between them. This is the
/// paper's model-vs-benchmark comparison (Table 5, Fig. 4) with the
/// trace-driven simulator standing in for the SNB/HSW hardware (see
/// DESIGN.md §1).
#[derive(Debug, Clone, PartialEq)]
pub struct ValidationReport {
    /// Simulated cycles per cache line of work (the "measurement").
    pub sim_cy_per_cl: f64,
    /// Analytic ECM in-memory prediction (cy per CL).
    pub analytic_cy_per_cl: f64,
    /// Relative model error in percent, with the simulation as ground
    /// truth: `(analytic − simulated) / simulated · 100`.
    pub model_error_pct: f64,
    /// Inner iterations the testbed executed.
    pub iterations: u64,
    /// Whether the iteration space was truncated for tractability (the
    /// reported cy/CL is then a steady-state mean over the window).
    pub truncated: bool,
    /// Per-level hit/miss/writeback counts, inner to outer.
    pub levels: Vec<ValidationLevelReport>,
}

impl ValidationReport {
    pub(crate) fn build(sim: &crate::sim::SimResult, analytic_cy_per_cl: f64) -> ValidationReport {
        let model_error_pct = if sim.cy_per_cl > 0.0 {
            (analytic_cy_per_cl - sim.cy_per_cl) / sim.cy_per_cl * 100.0
        } else {
            0.0
        };
        ValidationReport {
            sim_cy_per_cl: sim.cy_per_cl,
            analytic_cy_per_cl,
            model_error_pct,
            iterations: sim.iterations,
            truncated: sim.truncated,
            levels: sim
                .levels
                .iter()
                .map(|l| ValidationLevelReport {
                    level: l.level.clone(),
                    hits: l.hits,
                    misses: l.misses,
                    writebacks: l.writebacks,
                })
                .collect(),
        }
    }
}

/// The complete, serializable result of one [`AnalysisRequest`]: every
/// figure the text reports render, as structured data. Sections absent
/// from the requested model are `None`.
#[derive(Debug, Clone, PartialEq)]
pub struct AnalysisReport {
    /// Caller id echoed from the request.
    pub id: Option<String>,
    /// Kernel display label.
    pub kernel: String,
    /// Machine key as requested.
    pub machine: String,
    /// Resolved microarchitecture tag.
    pub arch: String,
    pub cores: u32,
    pub constants: BTreeMap<String, i64>,
    pub model: ModelKind,
    pub predictor: CachePredictorKind,
    /// Unit the consumer asked to render in (data is stored in cycles).
    pub unit: Unit,
    pub clock_hz: f64,
    /// Inner iterations per unit of work (one cache line).
    pub unit_iterations: u64,
    /// Source flops per unit of work.
    pub flops_per_unit: f64,
    pub incore: Option<IncoreReport>,
    pub traffic: Option<TrafficReport>,
    pub ecm: Option<EcmReport>,
    pub scaling: Option<ScalingReport>,
    pub roofline: Option<RooflineReport>,
    pub validation: Option<ValidationReport>,
    /// Blocking advice ([`ModelKind::Advise`] only; see [`crate::advise`]).
    pub advise: Option<crate::advise::AdviceReport>,
    /// Memo hits/misses this request saw in the session caches.
    pub session: MemoStats,
}

/// A report plus the intermediate stage products it was assembled from —
/// for consumers that drill deeper than the serialized data (CLI verbose
/// tables, cache visualization, sweep rows).
pub struct Evaluation {
    pub report: AnalysisReport,
    pub machine: Arc<MachineModel>,
    pub analysis: Arc<KernelAnalysis>,
    pub incore: Option<Arc<PortModel>>,
    pub traffic: Option<TrafficPrediction>,
}

// ---------------------------------------------------------------------------
// the session
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Counters {
    machine_hits: AtomicU64,
    machine_misses: AtomicU64,
    program_hits: AtomicU64,
    program_misses: AtomicU64,
    analysis_hits: AtomicU64,
    analysis_misses: AtomicU64,
    incore_hits: AtomicU64,
    incore_misses: AtomicU64,
}

/// Per-stage cache bound: a long-running session (`kerncraft serve`)
/// must not grow without limit under distinct-request traffic. The bound
/// is enforced per shard ([`MAX_SHARD_ENTRIES`]); a full shard is cleared
/// wholesale — the stages are pure, so rebuilds are exact and only the
/// hit rate suffers.
const MAX_CACHE_ENTRIES: usize = 4096;

/// Lock shards per stage cache: concurrent `serve` / sweep workers hash
/// to different shards, so memo lookups rarely contend on one mutex.
const CACHE_SHARDS: usize = 8;

/// Entry bound per shard (the per-stage total stays [`MAX_CACHE_ENTRIES`]).
const MAX_SHARD_ENTRIES: usize = MAX_CACHE_ENTRIES / CACHE_SHARDS;

/// A string-keyed map behind sharded locks: the backing store of every
/// stage cache. Keys are hashed to one of [`CACHE_SHARDS`] independent
/// mutexes, so parallel front ends (`serve --threads`, the sweep engine)
/// mostly take disjoint locks. Each shard is bounded by
/// [`MAX_SHARD_ENTRIES`] and cleared wholesale when full.
struct ShardedMap<V> {
    shards: Vec<Mutex<HashMap<String, V>>>,
}

impl<V> Default for ShardedMap<V> {
    fn default() -> Self {
        ShardedMap {
            shards: (0..CACHE_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }
}

impl<V: Clone> ShardedMap<V> {
    fn shard(&self, key: &str) -> &Mutex<HashMap<String, V>> {
        use std::hash::{Hash, Hasher};
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % CACHE_SHARDS]
    }

    fn get(&self, key: &str) -> Option<V> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    /// Get-or-insert: on a race the first insert wins (stage products are
    /// pure, so racing values are equal). A full shard is cleared before
    /// inserting a new key.
    fn get_or_insert(&self, key: &str, value: V) -> V {
        let mut guard = self.shard(key).lock().unwrap();
        if guard.len() >= MAX_SHARD_ENTRIES && !guard.contains_key(key) {
            // bound the shard (outstanding Arcs stay alive; rebuilds of
            // cleared entries are bit-identical)
            guard.clear();
        }
        guard.entry(key.to_string()).or_insert(value).clone()
    }

    #[cfg(test)]
    fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }
}

/// The analysis session: owns the cross-request caches and evaluates
/// typed requests. Cheap to share across threads (`&self` API, sharded
/// internal locking, atomic memo counters) — [`crate::sweep::SweepEngine`]
/// maps a whole job grid through one session from its worker pool, and
/// `kerncraft serve --threads K` shares one session across its request
/// workers. Every stage cache is bounded (see [`MAX_CACHE_ENTRIES`]).
#[derive(Default)]
pub struct Session {
    /// Source-text interning: requests share kernels, so downstream memo
    /// keys carry a small id instead of the whole source string. Ids are
    /// allocated monotonically so clearing the intern table can never
    /// alias old downstream keys.
    sources: ShardedMap<usize>,
    next_source_id: std::sync::atomic::AtomicUsize,
    /// Machine key → (model, content digest). The pair is built from
    /// ONE file read and lives in one memo entry, so the model served
    /// and the persistent-cache key can never describe different
    /// versions of a concurrently edited machine file — old-model
    /// reports stored under new-content keys would permanently poison
    /// a shared `--cache-dir`.
    machines: ShardedMap<(Arc<MachineModel>, Arc<str>)>,
    programs: ShardedMap<Arc<Program>>,
    analyses: ShardedMap<Arc<KernelAnalysis>>,
    incore: ShardedMap<Arc<PortModel>>,
    counters: Counters,
    /// Optional report-level cache consulted before any stage runs (the
    /// persistent `--cache-dir` seam); None means every request
    /// evaluates.
    report_cache: Option<Arc<dyn ReportCache>>,
    /// Rejected-input tallies per diagnostic code (`E100`, `E200`, ...):
    /// every kernel the frontend refuses bumps its code here, feeding
    /// the `kerncraft_rejected_inputs_total` metric family.
    rejected: Mutex<BTreeMap<String, u64>>,
    /// Request tallies per machine ISA family ("x86", "aarch64"),
    /// feeding the `kerncraft_requests_total{isa=...}` metric family so
    /// operators can see the ISA mix across a fleet.
    isa_requests: Mutex<BTreeMap<String, u64>>,
    /// Wall-clock nanoseconds spent in successful pipeline evaluations,
    /// indexed by [`ModelKind::ix`] — feeds the
    /// `kerncraft_eval_seconds_total{model=...}` metric family. Memo
    /// hits still count (the stages ran, just fast); report-cache hits
    /// and failed evaluations don't run the pipeline and are excluded.
    eval_nanos: [AtomicU64; 7],
    /// Successful evaluation count per model (the `_count` row of the
    /// latency family).
    eval_count: [AtomicU64; 7],
    /// Virtual-testbed memory touches accounted per engine, indexed by
    /// [`crate::sim::SimEngine::ix`] — the
    /// `kerncraft_sim_touches_total{engine=...}` metric family.
    sim_touches: [AtomicU64; 2],
}

/// Memo lookup helper: double-checked get-or-insert through a sharded
/// map. The builder runs OUTSIDE any lock so concurrent requests don't
/// serialize on each other's parse/analyze work; on a race the first
/// insert wins (both values are equal — the stages are pure). Returns
/// the product and whether it was a hit.
fn memoize<T>(
    map: &ShardedMap<Arc<T>>,
    key: &str,
    build: impl FnOnce() -> Result<T>,
) -> Result<(Arc<T>, bool)> {
    if let Some(v) = map.get(key) {
        return Ok((v, true));
    }
    let built = Arc::new(build()?);
    Ok((map.get_or_insert(key, built), false))
}

fn consts_key(constants: &BTreeMap<String, i64>) -> String {
    let mut s = String::new();
    for (k, v) in constants {
        s.push_str(k);
        s.push('=');
        s.push_str(&v.to_string());
        s.push(';');
    }
    s
}

impl Session {
    /// Fresh session with empty caches.
    pub fn new() -> Session {
        Session::default()
    }

    /// Fresh session whose [`Session::evaluate`] consults (and fills) a
    /// report-level cache before running any pipeline stage — the seam
    /// `kerncraft serve --cache-dir` plugs its persistent
    /// [`crate::server::cache::DiskCache`] into. Cached answers are
    /// byte-identical re-serializations of the original report (the
    /// `session` memo counters included), so a warm restart repeats its
    /// own responses exactly.
    pub fn with_report_cache(cache: Arc<dyn ReportCache>) -> Session {
        Session { report_cache: Some(cache), ..Session::default() }
    }

    /// Per-model evaluation latency: `(model name, seconds, count)` for
    /// every [`ModelKind`], in [`ModelKind::ix`] order — the
    /// `kerncraft_eval_seconds_total` metric family (sum + count).
    pub fn eval_seconds_by_model(&self) -> Vec<(&'static str, f64, u64)> {
        ModelKind::ALL
            .iter()
            .map(|m| {
                (
                    m.name(),
                    self.eval_nanos[m.ix()].load(Ordering::Relaxed) as f64 / 1e9,
                    self.eval_count[m.ix()].load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    /// Virtual-testbed touches per engine: `(engine name, touches)` for
    /// every [`crate::sim::SimEngine`] — the
    /// `kerncraft_sim_touches_total` metric family.
    pub fn sim_touches_by_engine(&self) -> Vec<(&'static str, u64)> {
        crate::sim::SimEngine::ALL
            .iter()
            .map(|e| (e.name(), self.sim_touches[e.ix()].load(Ordering::Relaxed)))
            .collect()
    }

    /// Snapshot of the session-wide memoization counters.
    pub fn stats(&self) -> MemoStats {
        let c = &self.counters;
        MemoStats {
            machine_hits: c.machine_hits.load(Ordering::Relaxed),
            machine_misses: c.machine_misses.load(Ordering::Relaxed),
            program_hits: c.program_hits.load(Ordering::Relaxed),
            program_misses: c.program_misses.load(Ordering::Relaxed),
            analysis_hits: c.analysis_hits.load(Ordering::Relaxed),
            analysis_misses: c.analysis_misses.load(Ordering::Relaxed),
            incore_hits: c.incore_hits.load(Ordering::Relaxed),
            incore_misses: c.incore_misses.load(Ordering::Relaxed),
        }
    }

    /// Evaluate a request into a serializable report. With a report
    /// cache attached ([`Session::with_report_cache`]), a repeated
    /// request is answered from the cache without running any pipeline
    /// stage; the cached report's `id` is replaced by this request's.
    /// Only successful evaluations are cached — errors always re-run.
    pub fn evaluate(&self, req: &AnalysisRequest) -> Result<AnalysisReport> {
        let Some(cache) = &self.report_cache else {
            return Ok(self.evaluate_full(req)?.report);
        };
        // key resolution reads each input ONCE and the evaluation below
        // reuses exactly those bytes: the kernel source resolved here is
        // threaded into evaluate_resolved, and the machine digest comes
        // from the same memo entry the model is served from — so a
        // kernel or machine file edited mid-request can never store a
        // new-content report under an old-content key (or vice versa),
        // which would permanently poison a shared cache directory. Key
        // resolution is not a pipeline stage, so it is deliberately NOT
        // counted in the memo stats — a request answered from the
        // persistent cache reports zero stage activity. An unresolvable
        // kernel or machine cannot be keyed; fall through so the
        // pipeline produces its real error (nothing unkeyable is ever
        // cached).
        let Ok((label, source)) = req.kernel.resolve() else {
            return Ok(self.evaluate_full(req)?.report);
        };
        let Ok((machine, machine_digest, _)) = self.memoized_machine(&req.machine) else {
            return Ok(self.evaluate_resolved(req, label, source)?.report);
        };
        let key = req.cache_key_resolved(&machine_digest, &label, &source);
        if let Some(mut report) = cache.get(&key) {
            // cache hits skip evaluate_resolved, so the ISA tally (a
            // request counter, not a stage counter) happens here
            self.note_isa(&machine);
            report.id = req.id.clone();
            return Ok(report);
        }
        let report = self.evaluate_resolved(req, label, source)?.report;
        let mut stored = report.clone();
        stored.id = None;
        cache.put(&key, &stored);
        Ok(report)
    }

    /// Evaluate a request, also returning the intermediate stage products.
    pub fn evaluate_full(&self, req: &AnalysisRequest) -> Result<Evaluation> {
        let (label, source) = req.kernel.resolve()?;
        self.evaluate_resolved(req, label, source)
    }

    /// [`Session::evaluate_full`] with the kernel already resolved —
    /// the seam that lets the persistent-cache path key and evaluate
    /// one single read of a kernel file.
    fn evaluate_resolved(
        &self,
        req: &AnalysisRequest,
        label: String,
        source: Arc<str>,
    ) -> Result<Evaluation> {
        if req.cores == 0 {
            bail!("request needs at least one core");
        }
        let eval_start = std::time::Instant::now();
        let mut local = MemoStats::default();

        // --- memoized stages (same key scheme the sweep engine used) ---
        let (machine, _digest, hit) = self.memoized_machine(&req.machine)?;
        note(hit, &mut local.machine_hits, &mut local.machine_misses);
        note_global(hit, &self.counters.machine_hits, &self.counters.machine_misses);
        self.note_isa(&machine);

        let (analysis, akey, program_hit, analysis_hit) =
            self.memoized_analysis(&source, &req.constants)?;
        note(program_hit, &mut local.program_hits, &mut local.program_misses);
        note(analysis_hit, &mut local.analysis_hits, &mut local.analysis_misses);

        let incore = if req.model.needs_incore() {
            let ikey =
                format!("{akey}\u{1}{}\u{1}{}", req.machine, req.codegen.name());
            let (pm, hit) = memoize(&self.incore, &ikey, || {
                PortModel::analyze(&analysis, &machine, &req.codegen.policy(&machine))
            })?;
            note(hit, &mut local.incore_hits, &mut local.incore_misses);
            note_global(hit, &self.counters.incore_hits, &self.counters.incore_misses);
            Some(pm)
        } else {
            None
        };

        // --- per-request stages ---
        let traffic = if req.model.needs_traffic() {
            Some(
                CachePredictor::with_kind(&machine, req.cores, req.predictor)
                    .predict(&analysis)?,
            )
        } else {
            None
        };

        let (ecm, scaling) = match req.model {
            ModelKind::Ecm | ModelKind::Validate | ModelKind::Advise => {
                let t = traffic.as_ref().unwrap();
                let e = EcmModel::build(incore.as_ref().unwrap(), t, &machine)?;
                let s = ScalingModel::build(&e, &machine);
                (Some(e), Some(s))
            }
            ModelKind::EcmData => {
                let t = traffic.as_ref().unwrap();
                let e = EcmModel::build_data_only(t, &machine)?;
                let s = ScalingModel::build(&e, &machine);
                (Some(e), Some(s))
            }
            _ => (None, None),
        };

        let roofline = match req.model {
            ModelKind::Roofline | ModelKind::RooflinePort => Some(RooflineModel::build_cores(
                &analysis,
                traffic.as_ref().unwrap(),
                &machine,
                incore.as_deref(),
                req.cores,
            )?),
            _ => None,
        };

        // Validate: run the virtual testbed with the memoized in-core
        // model and compare against the analytic in-memory prediction.
        let validation = if req.model == ModelKind::Validate {
            let pm = incore.as_deref().expect("Validate needs the in-core model");
            let sim = crate::sim::VirtualTestbed::new(&machine)
                .with_engine(req.sim_engine)
                .run_with_incore(&analysis, pm)?;
            self.sim_touches[sim.engine.ix()].fetch_add(sim.touches, Ordering::Relaxed);
            Some(ValidationReport::build(&sim, ecm.as_ref().unwrap().t_mem()))
        } else {
            None
        };

        // Advise: solve the layer-condition breakpoints analytically and
        // evaluate candidate blockings through this same session — each
        // sub-request is a plain ECM evaluation with the analytic
        // predictor forced (DESIGN.md §5, crate::advise).
        let advise = if req.model == ModelKind::Advise {
            Some(crate::advise::build_advice(self, req, &machine, &analysis, &label, &source)?)
        } else {
            None
        };

        // --- assemble the report ---
        let unit_iterations = match (&traffic, &incore) {
            (Some(t), _) => t.unit_iterations,
            (None, Some(pm)) => pm.iterations_per_cl,
            (None, None) => unreachable!("every model needs traffic or incore"),
        };
        let flops_per_unit = match req.model {
            ModelKind::Ecm | ModelKind::EcmData | ModelKind::Validate | ModelKind::Advise => {
                ecm.as_ref().unwrap().flops_per_cl
            }
            ModelKind::EcmCpu => incore.as_ref().unwrap().flops_per_cl,
            ModelKind::Roofline | ModelKind::RooflinePort => {
                roofline.as_ref().unwrap().flops_per_cl
            }
        };

        let report = AnalysisReport {
            id: req.id.clone(),
            kernel: label,
            machine: req.machine.clone(),
            arch: machine.arch.clone(),
            cores: req.cores,
            constants: req.constants.clone(),
            model: req.model,
            predictor: req.predictor,
            unit: req.unit,
            clock_hz: machine.clock_hz,
            unit_iterations,
            flops_per_unit,
            incore: incore.as_deref().map(IncoreReport::from_model),
            traffic: traffic
                .as_ref()
                .map(|t| TrafficReport::from_prediction(t, &analysis)),
            ecm: ecm.as_ref().map(EcmReport::from_model),
            scaling: scaling.as_ref().map(ScalingReport::from_model),
            roofline: roofline.as_ref().map(RooflineReport::from_model),
            validation,
            advise,
            session: local,
        };

        let nanos = eval_start.elapsed().as_nanos() as u64;
        self.eval_nanos[req.model.ix()].fetch_add(nanos, Ordering::Relaxed);
        self.eval_count[req.model.ix()].fetch_add(1, Ordering::Relaxed);

        Ok(Evaluation { report, machine, analysis, incore, traffic })
    }

    /// Memoized machine lookup — for consumers needing the model itself
    /// (machine reports, benchmark modes).
    pub fn machine(&self, key: &str) -> Result<Arc<MachineModel>> {
        let (m, _digest, hit) = self.memoized_machine(key)?;
        note_global(hit, &self.counters.machine_hits, &self.counters.machine_misses);
        Ok(m)
    }

    /// Machine model + content digest, memoized as one entry built from
    /// one file read ([`MachineModel::load_with_digest`]): the model a
    /// request is evaluated with and the digest its persistent-cache
    /// key carries are created, shared, and evicted together, so they
    /// can never describe different versions of an edited machine file.
    /// Callers record the returned hit flag in the memo counters where
    /// the lookup is a pipeline stage (evaluation), and drop it where
    /// it is not (cache-key resolution).
    fn memoized_machine(&self, key: &str) -> Result<(Arc<MachineModel>, Arc<str>, bool)> {
        if let Some((m, d)) = self.machines.get(key) {
            return Ok((m, d, true));
        }
        let (model, digest) = MachineModel::load_with_digest(key)?;
        let (m, d) = self
            .machines
            .get_or_insert(key, (Arc::new(model), Arc::from(digest.as_str())));
        Ok((m, d, false))
    }

    /// Memoized static analysis of a kernel under constant bindings —
    /// for consumers that stop before the performance models (benchmark
    /// modes, visualizations).
    pub fn kernel_analysis(
        &self,
        kernel: &KernelSpec,
        constants: &BTreeMap<String, i64>,
    ) -> Result<Arc<KernelAnalysis>> {
        let (_, source) = kernel.resolve()?;
        let (analysis, _, _, _) = self.memoized_analysis(&source, constants)?;
        Ok(analysis)
    }

    /// Shared program + analysis memoization (one key scheme for every
    /// entry point). Returns the analysis, its memo key, and the
    /// (program, analysis) hit flags; session-wide counters are recorded
    /// here, per-request counters by the caller.
    fn memoized_analysis(
        &self,
        source: &str,
        constants: &BTreeMap<String, i64>,
    ) -> Result<(Arc<KernelAnalysis>, String, bool, bool)> {
        let source_id = self.intern_source(source);
        let (program, program_hit) = memoize(&self.programs, &source_id.to_string(), || {
            crate::kernel::parse(source).map_err(anyhow::Error::from)
        })
        .map_err(|e| self.note_rejected(e))?;
        note_global(
            program_hit,
            &self.counters.program_hits,
            &self.counters.program_misses,
        );
        let akey = format!("{source_id}\u{1}{}", consts_key(constants));
        let (analysis, analysis_hit) = memoize(&self.analyses, &akey, || {
            let consts: HashMap<String, i64> =
                constants.iter().map(|(k, v)| (k.clone(), *v)).collect();
            KernelAnalysis::from_program(&program, &consts).map_err(anyhow::Error::from)
        })
        .map_err(|e| self.note_rejected(e))?;
        note_global(
            analysis_hit,
            &self.counters.analysis_hits,
            &self.counters.analysis_misses,
        );
        Ok((analysis, akey, program_hit, analysis_hit))
    }

    /// Record a frontend rejection under its diagnostic code (pass-through
    /// on non-[`KernelError`] failures such as I/O problems).
    fn note_rejected(&self, e: anyhow::Error) -> anyhow::Error {
        if let Some(ke) = e.downcast_ref::<crate::kernel::KernelError>() {
            let mut map = self.rejected.lock().unwrap();
            *map.entry(ke.code().to_string()).or_insert(0) += 1;
        }
        e
    }

    /// Snapshot of the per-diagnostic-code rejected-input tallies,
    /// sorted by code (stable metric ordering).
    pub fn rejected_by_code(&self) -> Vec<(String, u64)> {
        let map = self.rejected.lock().unwrap();
        map.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    /// Record one evaluated request against its machine's ISA family.
    fn note_isa(&self, machine: &MachineModel) {
        let mut map = self.isa_requests.lock().unwrap();
        *map.entry(machine.isa.family.name().to_string()).or_insert(0) += 1;
    }

    /// Snapshot of the per-ISA-family request tallies, sorted by family
    /// name (stable metric ordering) — the
    /// `kerncraft_requests_total{isa=...}` series.
    pub fn requests_by_isa(&self) -> Vec<(String, u64)> {
        let map = self.isa_requests.lock().unwrap();
        map.iter().map(|(k, v)| (k.clone(), *v)).collect()
    }

    fn intern_source(&self, source: &str) -> usize {
        // hit path: no allocation, no clone of the (possibly large) source
        if let Some(id) = self.sources.get(source) {
            return id;
        }
        // ids are monotonic, so dropping old interns (a full shard being
        // cleared) cannot alias the downstream program/analysis keys they
        // minted; on a race the first insert wins and both callers use it
        let id = self.next_source_id.fetch_add(1, Ordering::Relaxed);
        self.sources.get_or_insert(source, id)
    }
}

fn note(hit: bool, hits: &mut u64, misses: &mut u64) {
    if hit {
        *hits += 1;
    } else {
        *misses += 1;
    }
}

fn note_global(hit: bool, hits: &AtomicU64, misses: &AtomicU64) {
    if hit {
        hits.fetch_add(1, Ordering::Relaxed);
    } else {
        misses.fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// JSON wire format
// ---------------------------------------------------------------------------

pub(crate) fn get_str(v: &JsonValue, k: &str) -> Result<String> {
    v.get(k)
        .and_then(|x| x.as_str())
        .map(str::to_string)
        .ok_or_else(|| anyhow!("missing or non-string field '{k}'"))
}

pub(crate) fn get_f64(v: &JsonValue, k: &str) -> Result<f64> {
    v.get(k)
        .and_then(|x| x.as_f64())
        .ok_or_else(|| anyhow!("missing or non-numeric field '{k}'"))
}

pub(crate) fn get_u64(v: &JsonValue, k: &str) -> Result<u64> {
    v.get(k)
        .and_then(|x| x.as_u64())
        .ok_or_else(|| anyhow!("missing or non-integer field '{k}'"))
}

pub(crate) fn get_u32(v: &JsonValue, k: &str) -> Result<u32> {
    u32::try_from(get_u64(v, k)?).map_err(|_| anyhow!("field '{k}' exceeds u32"))
}

fn get_bool(v: &JsonValue, k: &str) -> Result<bool> {
    v.get(k)
        .and_then(|x| x.as_bool())
        .ok_or_else(|| anyhow!("missing or non-boolean field '{k}'"))
}

/// Missing and `null` both map to `None`.
fn opt_str(v: &JsonValue, k: &str) -> Option<String> {
    v.get(k).and_then(|x| x.as_str()).map(str::to_string)
}

fn opt_f64(v: &JsonValue, k: &str) -> Option<f64> {
    v.get(k).and_then(|x| x.as_f64())
}

fn opt_u32(v: &JsonValue, k: &str) -> Option<u32> {
    v.get(k).and_then(|x| x.as_u64()).and_then(|x| u32::try_from(x).ok())
}

fn json_opt_str(v: &Option<String>) -> String {
    match v {
        Some(s) => json_str(s),
        None => "null".to_string(),
    }
}

fn json_opt_num(v: Option<f64>) -> String {
    match v {
        Some(x) => json_num(x),
        None => "null".to_string(),
    }
}

fn json_opt_u32(v: Option<u32>) -> String {
    match v {
        Some(x) => x.to_string(),
        None => "null".to_string(),
    }
}

fn json_constants(constants: &BTreeMap<String, i64>) -> String {
    let mut s = String::from("{");
    for (ix, (k, v)) in constants.iter().enumerate() {
        if ix > 0 {
            s.push_str(", ");
        }
        s.push_str(&json_str(k));
        s.push_str(": ");
        s.push_str(&v.to_string());
    }
    s.push('}');
    s
}

fn constants_from_json(v: &JsonValue) -> Result<BTreeMap<String, i64>> {
    let mut out = BTreeMap::new();
    match v {
        JsonValue::Obj(entries) => {
            for (k, val) in entries {
                out.insert(
                    k.clone(),
                    val.as_i64()
                        .ok_or_else(|| anyhow!("constant '{k}' must be an integer"))?,
                );
            }
            Ok(out)
        }
        JsonValue::Null => Ok(out),
        _ => bail!("'constants' must be an object of integers"),
    }
}

impl AnalysisRequest {
    /// Serialize to a single-line JSON object (the `serve` wire format).
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        if let Some(id) = &self.id {
            s.push_str("\"id\": ");
            s.push_str(&json_str(id));
            s.push_str(", ");
        }
        s.push_str("\"kernel\": ");
        match &self.kernel {
            KernelSpec::Source { label, source } => {
                s.push_str("{\"label\": ");
                s.push_str(&json_str(label));
                s.push_str(", \"source\": ");
                s.push_str(&json_str(source));
                s.push('}');
            }
            KernelSpec::Named(tag) => {
                s.push_str("{\"name\": ");
                s.push_str(&json_str(tag));
                s.push('}');
            }
            KernelSpec::Path(path) => {
                s.push_str("{\"path\": ");
                s.push_str(&json_str(path));
                s.push('}');
            }
        }
        s.push_str(", \"machine\": ");
        s.push_str(&json_str(&self.machine));
        s.push_str(", \"constants\": ");
        s.push_str(&json_constants(&self.constants));
        s.push_str(&format!(", \"cores\": {}", self.cores));
        s.push_str(", \"model\": ");
        s.push_str(&json_str(self.model.name()));
        s.push_str(", \"predictor\": ");
        s.push_str(&json_str(self.predictor.name()));
        s.push_str(", \"codegen\": ");
        s.push_str(&json_str(self.codegen.name()));
        s.push_str(", \"sim_engine\": ");
        s.push_str(&json_str(self.sim_engine.name()));
        s.push_str(", \"unit\": ");
        s.push_str(&json_str(self.unit.suffix()));
        s.push('}');
        s
    }

    /// Parse a request from JSON text. Only `kernel` and `machine` are
    /// required; everything else takes the [`AnalysisRequest::new`]
    /// defaults.
    pub fn from_json(text: &str) -> Result<AnalysisRequest> {
        let v = jsonio::parse(text).context("parsing analysis request")?;
        Self::from_json_value(&v)
    }

    /// Parse a request from an already-parsed JSON value.
    pub fn from_json_value(v: &JsonValue) -> Result<AnalysisRequest> {
        let kv = v
            .get("kernel")
            .ok_or_else(|| anyhow!("request missing 'kernel'"))?;
        let kernel = if let Some(src) = kv.get("source") {
            let source = src
                .as_str()
                .ok_or_else(|| anyhow!("'kernel.source' must be a string"))?;
            let label = kv.get("label").and_then(|l| l.as_str()).unwrap_or("kernel");
            KernelSpec::source(label, source)
        } else if let Some(name) = kv.get("name") {
            KernelSpec::named(
                name.as_str()
                    .ok_or_else(|| anyhow!("'kernel.name' must be a string"))?,
            )
        } else if let Some(path) = kv.get("path") {
            KernelSpec::path(
                path.as_str()
                    .ok_or_else(|| anyhow!("'kernel.path' must be a string"))?,
            )
        } else {
            bail!("'kernel' needs one of 'source', 'name', 'path'");
        };
        let mut req = AnalysisRequest::new(kernel, get_str(v, "machine")?);
        if let Some(id) = v.get("id").filter(|x| !x.is_null()) {
            // a wrong-typed id would silently break response correlation
            req.id = Some(
                id.as_str()
                    .ok_or_else(|| anyhow!("'id' must be a string"))?
                    .to_string(),
            );
        }
        if let Some(c) = v.get("constants") {
            req.constants = constants_from_json(c)?;
        }
        if let Some(c) = v.get("cores") {
            req.cores = c
                .as_u64()
                .and_then(|x| u32::try_from(x).ok())
                .ok_or_else(|| anyhow!("'cores' must be a positive integer"))?;
        }
        if let Some(m) = v.get("model") {
            let name = m.as_str().ok_or_else(|| anyhow!("'model' must be a string"))?;
            req.model = ModelKind::parse(name).ok_or_else(|| {
                anyhow!(
                    "unknown model '{name}' (ECM, ECMData, ECMCPU, Roofline, RooflinePort, Validate, Advise)"
                )
            })?;
        }
        if let Some(p) = v.get("predictor") {
            let name = p
                .as_str()
                .ok_or_else(|| anyhow!("'predictor' must be a string"))?;
            req.predictor = CachePredictorKind::parse(name)
                .ok_or_else(|| anyhow!("unknown cache predictor '{name}' (offsets|lc|auto)"))?;
        }
        if let Some(c) = v.get("codegen") {
            let name = c
                .as_str()
                .ok_or_else(|| anyhow!("'codegen' must be a string"))?;
            req.codegen = CodegenSelection::parse(name)
                .ok_or_else(|| anyhow!("unknown codegen '{name}' (machine|scalar)"))?;
        }
        if let Some(e) = v.get("sim_engine") {
            let name = e
                .as_str()
                .ok_or_else(|| anyhow!("'sim_engine' must be a string"))?;
            req.sim_engine = crate::sim::SimEngine::parse(name)
                .ok_or_else(|| anyhow!("unknown sim engine '{name}' (fast|reference)"))?;
        }
        if let Some(u) = v.get("unit") {
            let name = u.as_str().ok_or_else(|| anyhow!("'unit' must be a string"))?;
            req.unit = Unit::parse(name).ok_or_else(|| {
                anyhow!("unknown unit '{name}' (valid: {})", Unit::VALID_SPELLINGS)
            })?;
        }
        Ok(req)
    }
}

impl IncoreReport {
    fn json(&self) -> String {
        let mut s = format!(
            "{{\"isa\": {}, \"t_ol\": {}, \"t_nol\": {}, \"tp\": {}, \"cp_cy\": {}, \"lcd_cy\": {}, \"vectorized\": {}, \"vector_elems\": {}, \"port_pressure\": [",
            json_str(&self.isa),
            json_num(self.t_ol),
            json_num(self.t_nol),
            json_num(self.tp),
            json_num(self.cp_cy),
            json_num(self.lcd_cy),
            self.vectorized,
            self.vector_elems
        );
        for (ix, (port, cycles)) in self.port_pressure.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"port\": {}, \"cycles\": {}}}",
                json_str(port),
                json_num(*cycles)
            ));
        }
        s.push_str("], \"chains\": [");
        for (ix, c) in self.chains.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            let instrs: Vec<String> = c.instructions.iter().map(|i| json_str(i)).collect();
            s.push_str(&format!(
                "{{\"name\": {}, \"latency_per_it\": {}, \"cy_per_unit\": {}, \"broken\": {}, \"instructions\": [{}]}}",
                json_str(&c.name),
                json_num(c.latency_per_it),
                json_num(c.cy_per_unit),
                c.broken,
                instrs.join(", ")
            ));
        }
        s.push(']');
        if let Some(d) = &self.dominant_chain {
            s.push_str(&format!(", \"dominant_chain\": {}", json_str(d)));
        }
        s.push('}');
        s
    }

    fn from_json_value(v: &JsonValue) -> Result<IncoreReport> {
        let mut port_pressure = Vec::new();
        for p in v
            .get("port_pressure")
            .ok_or_else(|| anyhow!("incore missing 'port_pressure'"))?
            .items()
        {
            port_pressure.push((get_str(p, "port")?, get_f64(p, "cycles")?));
        }
        let mut chains = Vec::new();
        for c in v.get("chains").ok_or_else(|| anyhow!("incore missing 'chains'"))?.items() {
            let mut instructions = Vec::new();
            for i in c
                .get("instructions")
                .ok_or_else(|| anyhow!("chain missing 'instructions'"))?
                .items()
            {
                instructions.push(
                    i.as_str()
                        .ok_or_else(|| anyhow!("chain instruction must be a string"))?
                        .to_string(),
                );
            }
            chains.push(ChainReport {
                name: get_str(c, "name")?,
                latency_per_it: get_f64(c, "latency_per_it")?,
                cy_per_unit: get_f64(c, "cy_per_unit")?,
                broken: get_bool(c, "broken")?,
                instructions,
            });
        }
        let dominant_chain = match v.get("dominant_chain") {
            None => None,
            Some(d) => {
                Some(d.as_str().ok_or_else(|| anyhow!("bad 'dominant_chain'"))?.to_string())
            }
        };
        Ok(IncoreReport {
            isa: get_str(v, "isa")?,
            t_ol: get_f64(v, "t_ol")?,
            t_nol: get_f64(v, "t_nol")?,
            tp: get_f64(v, "tp")?,
            cp_cy: get_f64(v, "cp_cy")?,
            lcd_cy: get_f64(v, "lcd_cy")?,
            vectorized: get_bool(v, "vectorized")?,
            vector_elems: get_u32(v, "vector_elems")?,
            port_pressure,
            chains,
            dominant_chain,
        })
    }
}

impl TrafficReport {
    fn json(&self) -> String {
        let mut s = format!("{{\"cacheline_bytes\": {}, \"levels\": [", self.cacheline_bytes);
        for (ix, l) in self.levels.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"level\": {}, \"read_miss_lines\": {}, \"write_allocate_lines\": {}, \"evict_lines\": {}, \"hit_lines\": {}, \"total_lines\": {}}}",
                json_str(&l.level),
                json_num(l.read_miss_lines),
                json_num(l.write_allocate_lines),
                json_num(l.evict_lines),
                json_num(l.hit_lines),
                json_num(l.total_lines)
            ));
        }
        s.push_str(&format!(
            "], \"memory_bytes_per_unit\": {}, \"lc_fast_levels\": {}, \"walk_levels\": {}, \"lc_breakpoints\": [",
            json_num(self.memory_bytes_per_unit),
            self.lc_fast_levels,
            self.walk_levels
        ));
        for (ix, b) in self.lc_breakpoints.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&json_str(b));
        }
        s.push_str("]}");
        s
    }

    fn from_json_value(v: &JsonValue) -> Result<TrafficReport> {
        let mut levels = Vec::new();
        for l in v
            .get("levels")
            .ok_or_else(|| anyhow!("traffic missing 'levels'"))?
            .items()
        {
            levels.push(LevelTrafficReport {
                level: get_str(l, "level")?,
                read_miss_lines: get_f64(l, "read_miss_lines")?,
                write_allocate_lines: get_f64(l, "write_allocate_lines")?,
                evict_lines: get_f64(l, "evict_lines")?,
                hit_lines: get_f64(l, "hit_lines")?,
                total_lines: get_f64(l, "total_lines")?,
            });
        }
        let lc_breakpoints = v
            .get("lc_breakpoints")
            .ok_or_else(|| anyhow!("traffic missing 'lc_breakpoints'"))?
            .items()
            .iter()
            .map(|x| {
                x.as_str()
                    .map(str::to_string)
                    .ok_or_else(|| anyhow!("lc_breakpoints entries must be strings"))
            })
            .collect::<Result<Vec<String>>>()?;
        Ok(TrafficReport {
            cacheline_bytes: get_u64(v, "cacheline_bytes")?,
            levels,
            memory_bytes_per_unit: get_f64(v, "memory_bytes_per_unit")?,
            lc_fast_levels: get_u32(v, "lc_fast_levels")?,
            walk_levels: get_u32(v, "walk_levels")?,
            lc_breakpoints,
        })
    }
}

impl EcmReport {
    fn json(&self) -> String {
        let mut s = format!(
            "{{\"t_ol\": {}, \"t_nol\": {}, \"contributions\": [",
            json_num(self.t_ol),
            json_num(self.t_nol)
        );
        for (ix, c) in self.contributions.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"link\": {}, \"lines\": {}, \"cycles\": {}, \"benchmark\": {}}}",
                json_str(&c.link),
                json_num(c.lines),
                json_num(c.cycles),
                json_opt_str(&c.benchmark)
            ));
        }
        s.push_str(&format!("], \"t_mem\": {}, \"level_predictions\": [", json_num(self.t_mem)));
        for (ix, p) in self.level_predictions.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&json_num(*p));
        }
        s.push_str(&format!(
            "], \"saturation_cores\": {}, \"mem_bandwidth_bs\": {}}}",
            json_opt_u32(self.saturation_cores),
            json_num(self.mem_bandwidth_bs)
        ));
        s
    }

    fn from_json_value(v: &JsonValue) -> Result<EcmReport> {
        let mut contributions = Vec::new();
        for c in v
            .get("contributions")
            .ok_or_else(|| anyhow!("ecm missing 'contributions'"))?
            .items()
        {
            contributions.push(EcmContributionReport {
                link: get_str(c, "link")?,
                lines: get_f64(c, "lines")?,
                cycles: get_f64(c, "cycles")?,
                benchmark: opt_str(c, "benchmark"),
            });
        }
        let level_predictions = v
            .get("level_predictions")
            .ok_or_else(|| anyhow!("ecm missing 'level_predictions'"))?
            .items()
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| anyhow!("bad level prediction")))
            .collect::<Result<Vec<f64>>>()?;
        Ok(EcmReport {
            t_ol: get_f64(v, "t_ol")?,
            t_nol: get_f64(v, "t_nol")?,
            contributions,
            t_mem: get_f64(v, "t_mem")?,
            level_predictions,
            saturation_cores: opt_u32(v, "saturation_cores"),
            mem_bandwidth_bs: get_f64(v, "mem_bandwidth_bs")?,
        })
    }
}

impl ScalingReport {
    fn json(&self) -> String {
        format!(
            "{{\"t_single\": {}, \"t_mem_link\": {}, \"saturation_cores\": {}, \"domain_cores\": {}}}",
            json_num(self.t_single),
            json_num(self.t_mem_link),
            json_opt_u32(self.saturation_cores),
            self.domain_cores
        )
    }

    fn from_json_value(v: &JsonValue) -> Result<ScalingReport> {
        Ok(ScalingReport {
            t_single: get_f64(v, "t_single")?,
            t_mem_link: get_f64(v, "t_mem_link")?,
            saturation_cores: opt_u32(v, "saturation_cores"),
            domain_cores: get_u32(v, "domain_cores")?,
        })
    }
}

impl RooflineReport {
    fn json(&self) -> String {
        let mut s = format!("{{\"port_model\": {}, \"ceilings\": [", self.port_model);
        for (ix, c) in self.ceilings.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"level\": {}, \"cycles\": {}, \"bandwidth_bs\": {}, \"benchmark\": {}, \"arith_intensity\": {}}}",
                json_str(&c.level),
                json_num(c.cycles),
                json_opt_num(c.bandwidth_bs),
                json_opt_str(&c.benchmark),
                json_opt_num(c.arith_intensity)
            ));
        }
        s.push_str(&format!(
            "], \"bottleneck\": {}, \"prediction_cycles\": {}, \"memory_bound\": {}}}",
            self.bottleneck,
            json_num(self.prediction_cycles),
            self.memory_bound
        ));
        s
    }

    fn from_json_value(v: &JsonValue) -> Result<RooflineReport> {
        let mut ceilings = Vec::new();
        for c in v
            .get("ceilings")
            .ok_or_else(|| anyhow!("roofline missing 'ceilings'"))?
            .items()
        {
            ceilings.push(RooflineCeilingReport {
                level: get_str(c, "level")?,
                cycles: get_f64(c, "cycles")?,
                bandwidth_bs: opt_f64(c, "bandwidth_bs"),
                benchmark: opt_str(c, "benchmark"),
                arith_intensity: opt_f64(c, "arith_intensity"),
            });
        }
        let bottleneck = get_u64(v, "bottleneck")? as usize;
        if bottleneck >= ceilings.len() {
            bail!(
                "roofline 'bottleneck' index {bottleneck} out of range ({} ceilings)",
                ceilings.len()
            );
        }
        Ok(RooflineReport {
            port_model: get_bool(v, "port_model")?,
            ceilings,
            bottleneck,
            prediction_cycles: get_f64(v, "prediction_cycles")?,
            memory_bound: get_bool(v, "memory_bound")?,
        })
    }
}

impl ValidationReport {
    fn json(&self) -> String {
        let mut s = format!(
            "{{\"sim_cy_per_cl\": {}, \"analytic_cy_per_cl\": {}, \"model_error_pct\": {}, \"iterations\": {}, \"truncated\": {}, \"levels\": [",
            json_num(self.sim_cy_per_cl),
            json_num(self.analytic_cy_per_cl),
            json_num(self.model_error_pct),
            self.iterations,
            self.truncated
        );
        for (ix, l) in self.levels.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"level\": {}, \"hits\": {}, \"misses\": {}, \"writebacks\": {}}}",
                json_str(&l.level),
                l.hits,
                l.misses,
                l.writebacks
            ));
        }
        s.push_str("]}");
        s
    }

    fn from_json_value(v: &JsonValue) -> Result<ValidationReport> {
        let mut levels = Vec::new();
        for l in v
            .get("levels")
            .ok_or_else(|| anyhow!("validation missing 'levels'"))?
            .items()
        {
            levels.push(ValidationLevelReport {
                level: get_str(l, "level")?,
                hits: get_u64(l, "hits")?,
                misses: get_u64(l, "misses")?,
                writebacks: get_u64(l, "writebacks")?,
            });
        }
        Ok(ValidationReport {
            sim_cy_per_cl: get_f64(v, "sim_cy_per_cl")?,
            analytic_cy_per_cl: get_f64(v, "analytic_cy_per_cl")?,
            model_error_pct: get_f64(v, "model_error_pct")?,
            iterations: get_u64(v, "iterations")?,
            truncated: get_bool(v, "truncated")?,
            levels,
        })
    }
}

impl AnalysisReport {
    /// Serialize to a single-line JSON object (the `serve` wire format).
    /// Finite floats round-trip exactly; absent sections are omitted.
    pub fn to_json(&self) -> String {
        let mut s = String::from("{");
        if let Some(id) = &self.id {
            s.push_str("\"id\": ");
            s.push_str(&json_str(id));
            s.push_str(", ");
        }
        s.push_str("\"kernel\": ");
        s.push_str(&json_str(&self.kernel));
        s.push_str(", \"machine\": ");
        s.push_str(&json_str(&self.machine));
        s.push_str(", \"arch\": ");
        s.push_str(&json_str(&self.arch));
        s.push_str(&format!(", \"cores\": {}", self.cores));
        s.push_str(", \"constants\": ");
        s.push_str(&json_constants(&self.constants));
        s.push_str(", \"model\": ");
        s.push_str(&json_str(self.model.name()));
        s.push_str(", \"predictor\": ");
        s.push_str(&json_str(self.predictor.name()));
        s.push_str(", \"unit\": ");
        s.push_str(&json_str(self.unit.suffix()));
        s.push_str(&format!(
            ", \"clock_hz\": {}, \"unit_iterations\": {}, \"flops_per_unit\": {}",
            json_num(self.clock_hz),
            self.unit_iterations,
            json_num(self.flops_per_unit)
        ));
        if let Some(i) = &self.incore {
            s.push_str(", \"incore\": ");
            s.push_str(&i.json());
        }
        if let Some(t) = &self.traffic {
            s.push_str(", \"traffic\": ");
            s.push_str(&t.json());
        }
        if let Some(e) = &self.ecm {
            s.push_str(", \"ecm\": ");
            s.push_str(&e.json());
        }
        if let Some(sc) = &self.scaling {
            s.push_str(", \"scaling\": ");
            s.push_str(&sc.json());
        }
        if let Some(r) = &self.roofline {
            s.push_str(", \"roofline\": ");
            s.push_str(&r.json());
        }
        if let Some(v) = &self.validation {
            s.push_str(", \"validation\": ");
            s.push_str(&v.json());
        }
        if let Some(a) = &self.advise {
            s.push_str(", \"advise\": ");
            s.push_str(&a.json());
        }
        s.push_str(", \"session\": ");
        s.push_str(&self.session.json_object());
        s.push('}');
        s
    }

    /// Parse a report back from JSON text (the round-trip inverse of
    /// [`AnalysisReport::to_json`]).
    pub fn from_json(text: &str) -> Result<AnalysisReport> {
        let v = jsonio::parse(text).context("parsing analysis report")?;
        Self::from_json_value(&v)
    }

    /// Parse a report from an already-parsed JSON value.
    pub fn from_json_value(v: &JsonValue) -> Result<AnalysisReport> {
        let section = |k: &str| v.get(k).filter(|x| !x.is_null());
        let model_name = get_str(v, "model")?;
        let predictor_name = get_str(v, "predictor")?;
        let unit_name = get_str(v, "unit")?;
        Ok(AnalysisReport {
            id: opt_str(v, "id"),
            kernel: get_str(v, "kernel")?,
            machine: get_str(v, "machine")?,
            arch: get_str(v, "arch")?,
            cores: get_u32(v, "cores")?,
            constants: v
                .get("constants")
                .map(constants_from_json)
                .transpose()?
                .unwrap_or_default(),
            model: ModelKind::parse(&model_name)
                .ok_or_else(|| anyhow!("unknown model '{model_name}'"))?,
            predictor: CachePredictorKind::parse(&predictor_name)
                .ok_or_else(|| anyhow!("unknown predictor '{predictor_name}'"))?,
            unit: Unit::parse(&unit_name)
                .ok_or_else(|| anyhow!("unknown unit '{unit_name}'"))?,
            clock_hz: get_f64(v, "clock_hz")?,
            unit_iterations: get_u64(v, "unit_iterations")?,
            flops_per_unit: get_f64(v, "flops_per_unit")?,
            incore: section("incore").map(IncoreReport::from_json_value).transpose()?,
            traffic: section("traffic").map(TrafficReport::from_json_value).transpose()?,
            ecm: section("ecm").map(EcmReport::from_json_value).transpose()?,
            scaling: section("scaling").map(ScalingReport::from_json_value).transpose()?,
            roofline: section("roofline")
                .map(RooflineReport::from_json_value)
                .transpose()?,
            validation: section("validation")
                .map(ValidationReport::from_json_value)
                .transpose()?,
            advise: section("advise")
                .map(crate::advise::AdviceReport::from_json_value)
                .transpose()?,
            session: v
                .get("session")
                .map(MemoStats::from_json_value)
                .transpose()?
                .unwrap_or_default(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const TRIAD: &str =
        "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";

    fn triad_request() -> AnalysisRequest {
        AnalysisRequest::new(KernelSpec::source("triad", TRIAD), "SNB")
            .with_constant("N", 8_000_000)
    }

    #[test]
    fn request_json_round_trip_all_kernel_specs() {
        let reqs = [
            triad_request()
                .with_cores(4)
                .with_model(ModelKind::RooflinePort)
                .with_predictor(CachePredictorKind::Auto)
                .with_codegen(CodegenSelection::Scalar)
                .with_unit(Unit::FlopPerS)
                .with_id("req-1"),
            AnalysisRequest::new(KernelSpec::named("2D-5pt"), "HSW")
                .with_constant("N", 6000)
                .with_constant("M", 6000)
                .with_model(ModelKind::Validate)
                .with_sim_engine(crate::sim::SimEngine::Reference),
            AnalysisRequest::new(KernelSpec::path("kernels/triad.c"), "machines/snb.yml"),
        ];
        for req in reqs {
            let json = req.to_json();
            let back = AnalysisRequest::from_json(&json).unwrap();
            assert_eq!(req, back, "{json}");
        }
    }

    #[test]
    fn request_json_defaults_apply() {
        let req =
            AnalysisRequest::from_json(r#"{"kernel": {"name": "triad"}, "machine": "SNB"}"#)
                .unwrap();
        assert_eq!(req.cores, 1);
        assert_eq!(req.model, ModelKind::Ecm);
        assert_eq!(req.predictor, CachePredictorKind::Offsets);
        assert_eq!(req.codegen, CodegenSelection::MachineDefault);
        assert_eq!(req.sim_engine, crate::sim::SimEngine::Fast);
        assert_eq!(req.unit, Unit::CyPerCl);
        assert!(req.constants.is_empty());
        assert!(req.id.is_none());
    }

    #[test]
    fn request_json_rejects_bad_fields() {
        assert!(AnalysisRequest::from_json(r#"{"machine": "SNB"}"#).is_err(), "no kernel");
        assert!(
            AnalysisRequest::from_json(r#"{"kernel": {"name": "t"}}"#).is_err(),
            "no machine"
        );
        assert!(AnalysisRequest::from_json(
            r#"{"kernel": {"name": "t"}, "machine": "SNB", "model": "Nope"}"#
        )
        .is_err());
        assert!(AnalysisRequest::from_json(
            r#"{"kernel": {"name": "t"}, "machine": "SNB", "unit": "parsecs"}"#
        )
        .is_err());
        assert!(AnalysisRequest::from_json(
            r#"{"kernel": {"name": "t"}, "machine": "SNB", "constants": {"N": 1.5}}"#
        )
        .is_err());
        assert!(
            AnalysisRequest::from_json(
                r#"{"kernel": {"name": "t"}, "machine": "SNB", "id": 7}"#
            )
            .is_err(),
            "non-string id must be rejected, not dropped"
        );
    }

    #[test]
    fn evaluate_matches_direct_pipeline() {
        use crate::kernel::parse;
        let session = Session::new();
        let report = session.evaluate(&triad_request()).unwrap();

        let m = MachineModel::snb();
        let p = parse(TRIAD).unwrap();
        let consts: HashMap<String, i64> =
            [("N".to_string(), 8_000_000i64)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &consts).unwrap();
        let pm = PortModel::analyze(&a, &m, &CodegenPolicy::for_machine(&m)).unwrap();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        let e = EcmModel::build(&pm, &t, &m).unwrap();

        let ecm = report.ecm.as_ref().unwrap();
        assert_eq!(ecm.t_mem, e.t_mem());
        assert_eq!(ecm.t_ol, e.t_ol);
        assert_eq!(ecm.t_nol, e.t_nol);
        assert_eq!(ecm.contributions.len(), e.contributions.len());
        for (cr, c) in ecm.contributions.iter().zip(&e.contributions) {
            assert_eq!(cr.link, c.link);
            assert_eq!(cr.lines, c.lines);
            assert_eq!(cr.cycles, c.cycles);
        }
        assert_eq!(report.arch, "SNB");
        assert_eq!(report.unit_iterations, t.unit_iterations);
    }

    #[test]
    fn second_request_hits_every_cache() {
        let session = Session::new();
        let req = triad_request();
        let first = session.evaluate(&req).unwrap();
        assert_eq!(first.session.misses(), 4, "{:?}", first.session);
        assert_eq!(first.session.hits(), 0);
        let second = session.evaluate(&req).unwrap();
        assert_eq!(second.session.hits(), 4, "{:?}", second.session);
        assert_eq!(second.session.misses(), 0);
        assert_eq!(second.session.program_hits, 1);
        assert_eq!(second.session.analysis_hits, 1);
        assert_eq!(second.session.incore_hits, 1);
        assert_eq!(second.session.machine_hits, 1);
        // session-wide counters aggregate both requests
        let total = session.stats();
        assert_eq!(total.hits(), 4);
        assert_eq!(total.misses(), 4);
        // the models themselves are identical
        assert_eq!(first.ecm, second.ecm);
    }

    #[test]
    fn report_json_round_trip_every_model() {
        let session = Session::new();
        for model in [
            ModelKind::Ecm,
            ModelKind::EcmData,
            ModelKind::EcmCpu,
            ModelKind::Roofline,
            ModelKind::RooflinePort,
        ] {
            let req = triad_request().with_model(model).with_id(model.name());
            let report = session.evaluate(&req).unwrap();
            let json = report.to_json();
            let back = AnalysisReport::from_json(&json).unwrap();
            assert_eq!(report, back, "{}:\n{json}", model.name());
            // JSON is a single line (the serve framing requirement)
            assert!(!json.contains('\n'), "{json}");
        }
    }

    #[test]
    fn model_sections_match_the_request() {
        let session = Session::new();
        let r = session
            .evaluate(&triad_request().with_model(ModelKind::EcmCpu))
            .unwrap();
        assert!(r.incore.is_some() && r.traffic.is_none() && r.ecm.is_none());
        let r = session
            .evaluate(&triad_request().with_model(ModelKind::EcmData))
            .unwrap();
        assert!(r.incore.is_none() && r.ecm.is_some() && r.scaling.is_some());
        let r = session
            .evaluate(&triad_request().with_model(ModelKind::Roofline))
            .unwrap();
        assert!(r.roofline.is_some() && r.incore.is_none());
        assert!(!r.roofline.as_ref().unwrap().port_model);
        let r = session
            .evaluate(&triad_request().with_model(ModelKind::RooflinePort))
            .unwrap();
        let rf = r.roofline.as_ref().unwrap();
        assert!(rf.port_model);
        assert_eq!(rf.prediction_cycles, rf.ceilings[rf.bottleneck].cycles);
        assert!(rf.memory_bound, "in-memory triad is bandwidth bound");
    }

    #[test]
    fn validate_mode_produces_validation_section() {
        assert_eq!(ModelKind::parse("Validate"), Some(ModelKind::Validate));
        let session = Session::new();
        let req = AnalysisRequest::new(KernelSpec::source("triad", TRIAD), "SNB")
            .with_constant("N", 400_000)
            .with_model(ModelKind::Validate);
        let r = session.evaluate(&req).unwrap();
        // Validate carries the full ECM report plus the validation section
        assert!(r.incore.is_some() && r.traffic.is_some());
        assert!(r.ecm.is_some() && r.scaling.is_some());
        let v = r.validation.as_ref().expect("validation section");
        assert_eq!(v.analytic_cy_per_cl, r.ecm.as_ref().unwrap().t_mem);
        assert!(v.sim_cy_per_cl > 0.0, "{v:?}");
        assert!(v.iterations > 0);
        assert_eq!(v.levels.len(), 3, "SNB has three cache levels: {:?}", v.levels);
        // the documented error definition: (analytic − sim) / sim · 100
        let expect = (v.analytic_cy_per_cl - v.sim_cy_per_cl) / v.sim_cy_per_cl * 100.0;
        assert!((v.model_error_pct - expect).abs() < 1e-9, "{v:?}");
        // streaming triad: testbed and analytic model agree closely
        assert!(v.model_error_pct.abs() < 20.0, "{v:?}");
        // JSON round trip preserves the section bit for bit
        let json = r.to_json();
        let back = AnalysisReport::from_json(&json).unwrap();
        assert_eq!(r, back, "{json}");
        assert!(!json.contains('\n'), "{json}");
    }

    #[test]
    fn eval_and_sim_counters_accumulate() {
        let session = Session::new();
        assert!(session.eval_seconds_by_model().iter().all(|(_, _, c)| *c == 0));
        assert!(session.sim_touches_by_engine().iter().all(|(_, t)| *t == 0));
        session.evaluate(&triad_request()).unwrap();
        let eval = session.eval_seconds_by_model();
        let ecm = eval.iter().find(|(m, _, _)| *m == "ECM").unwrap();
        assert_eq!(ecm.2, 1, "{eval:?}");
        assert!(ecm.1 >= 0.0, "{eval:?}");
        // Validate runs the testbed and advances its engine's touch count
        let req = AnalysisRequest::new(KernelSpec::source("triad", TRIAD), "SNB")
            .with_constant("N", 400_000)
            .with_model(ModelKind::Validate)
            .with_sim_engine(crate::sim::SimEngine::Reference);
        session.evaluate(&req).unwrap();
        let eval = session.eval_seconds_by_model();
        assert_eq!(eval.iter().find(|(m, _, _)| *m == "Validate").unwrap().2, 1);
        let sim = session.sim_touches_by_engine();
        let by = |name: &str| sim.iter().find(|(e, _)| *e == name).unwrap().1;
        assert!(by("reference") > 0, "{sim:?}");
        assert_eq!(by("fast"), 0, "the fast engine never ran: {sim:?}");
        // a failed evaluation advances nothing
        let count_sum: u64 = eval.iter().map(|(_, _, c)| c).sum();
        session.evaluate(&triad_request().with_cores(0)).unwrap_err();
        let after: u64 = session.eval_seconds_by_model().iter().map(|(_, _, c)| c).sum();
        assert_eq!(count_sum, after);
    }

    #[test]
    fn named_and_path_kernels_resolve() {
        let session = Session::new();
        let named = AnalysisRequest::new(KernelSpec::named("triad"), "SNB")
            .with_constant("N", 100_000);
        let r = session.evaluate(&named).unwrap();
        assert_eq!(r.kernel, "triad");
        let err = session
            .evaluate(&AnalysisRequest::new(KernelSpec::named("nope"), "SNB"))
            .unwrap_err();
        assert!(format!("{err}").contains("unknown reference kernel"), "{err}");
    }

    #[test]
    fn scalar_codegen_is_cached_separately() {
        let session = Session::new();
        let vec_req = triad_request();
        let sc_req = triad_request().with_codegen(CodegenSelection::Scalar);
        let vec_rep = session.evaluate(&vec_req).unwrap();
        let sc_rep = session.evaluate(&sc_req).unwrap();
        // different policies must not share the in-core memo slot
        assert_eq!(sc_rep.session.incore_misses, 1, "{:?}", sc_rep.session);
        let v = vec_rep.incore.as_ref().unwrap();
        let s = sc_rep.incore.as_ref().unwrap();
        assert!(v.vectorized && !s.vectorized);
        assert!(s.t_ol > v.t_ol, "scalar code is slower in-core");
    }

    #[test]
    fn zero_cores_is_a_clean_error() {
        let session = Session::new();
        let err = session.evaluate(&triad_request().with_cores(0)).unwrap_err();
        assert!(format!("{err}").contains("at least one core"), "{err}");
    }

    #[test]
    fn cache_key_ignores_id_and_tracks_content() {
        let base = triad_request();
        let k1 = base.cache_key().unwrap();
        assert_eq!(k1.len(), 32, "{k1}");
        assert!(k1.bytes().all(|b| b.is_ascii_hexdigit()));
        assert_eq!(
            base.clone().with_id("x").cache_key().unwrap(),
            k1,
            "correlation ids must not fragment the cache"
        );
        // every analysis-relevant field lands in the key
        assert_ne!(base.clone().with_constant("N", 1).cache_key().unwrap(), k1);
        assert_ne!(base.clone().with_cores(2).cache_key().unwrap(), k1);
        assert_ne!(base.clone().with_model(ModelKind::Roofline).cache_key().unwrap(), k1);
        assert_ne!(
            base.clone().with_predictor(CachePredictorKind::LayerConditions).cache_key().unwrap(),
            k1
        );
        assert_ne!(
            base.clone().with_codegen(CodegenSelection::Scalar).cache_key().unwrap(),
            k1
        );
        let hsw = AnalysisRequest::new(KernelSpec::source("triad", TRIAD), "HSW")
            .with_constant("N", 8_000_000);
        assert_ne!(hsw.cache_key().unwrap(), k1);
        // an unresolvable kernel cannot be keyed
        assert!(AnalysisRequest::new(KernelSpec::named("nope"), "SNB").cache_key().is_err());
    }

    #[test]
    fn machine_model_and_digest_are_memoized_together() {
        let session = Session::new();
        // builtin tags digest the embedded YAML (same resolution order
        // as MachineModel::load: a stray file named SNB in the working
        // directory must not leak into the keys)
        let (m1, d1, hit1) = session.memoized_machine("SNB").unwrap();
        assert!(!hit1);
        assert_eq!(
            &*d1,
            jsonio::content_hash(crate::machine::SNB_YML.as_bytes()).as_str()
        );
        // the second lookup shares the exact entry — model and digest
        // can only ever be replaced together
        let (m2, d2, hit2) = session.memoized_machine("SNB").unwrap();
        assert!(hit2);
        assert!(Arc::ptr_eq(&m1, &m2));
        assert!(Arc::ptr_eq(&d1, &d2));
        // file paths digest the text the model was parsed from
        let (_, df, _) = session.memoized_machine("machines/snb.yml").unwrap();
        assert_eq!(
            &*df,
            jsonio::content_hash(&std::fs::read("machines/snb.yml").unwrap()).as_str()
        );
        // an unresolvable machine is an error, never a sentinel key
        assert!(session.memoized_machine("no/such/machine.yml").is_err());
        assert!(AnalysisRequest::new(KernelSpec::source("t", TRIAD), "no/such.yml")
            .cache_key()
            .is_err());
    }

    /// In-memory [`ReportCache`] double: stores wire JSON, counts hits.
    #[derive(Default)]
    struct MemCache {
        map: Mutex<HashMap<String, String>>,
        hits: AtomicU64,
        misses: AtomicU64,
    }

    impl ReportCache for MemCache {
        fn get(&self, key: &str) -> Option<AnalysisReport> {
            match self.map.lock().unwrap().get(key) {
                Some(json) => {
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    Some(AnalysisReport::from_json(json).unwrap())
                }
                None => {
                    self.misses.fetch_add(1, Ordering::Relaxed);
                    None
                }
            }
        }

        fn put(&self, key: &str, report: &AnalysisReport) {
            self.map.lock().unwrap().insert(key.to_string(), report.to_json());
        }
    }

    #[test]
    fn report_cache_seam_short_circuits_second_evaluation() {
        let cache = Arc::new(MemCache::default());
        let session = Session::with_report_cache(cache.clone());
        let first = session.evaluate(&triad_request().with_id("a")).unwrap();
        assert_eq!(cache.misses.load(Ordering::Relaxed), 1);
        let after_first = session.stats();
        assert!(after_first.misses() > 0, "first request ran the pipeline");
        let second = session.evaluate(&triad_request().with_id("b")).unwrap();
        assert_eq!(cache.hits.load(Ordering::Relaxed), 1);
        // the cached answer ran no stage: session-wide counters unchanged
        assert_eq!(session.stats(), after_first);
        assert_eq!(second.id.as_deref(), Some("b"), "cached answers echo the new id");
        let mut expect = first.clone();
        expect.id = Some("b".to_string());
        assert_eq!(second, expect, "cached answer matches the original bit for bit");
        // failing requests are never cached (and still fail cleanly)
        assert!(session
            .evaluate(&AnalysisRequest::new(KernelSpec::named("nope"), "SNB"))
            .is_err());
        assert!(cache.map.lock().unwrap().len() == 1);
    }

    #[test]
    fn intern_table_stays_bounded_with_unique_ids() {
        let session = Session::new();
        // far more distinct sources than the cap: the table must stay
        // bounded and ids must never repeat (or downstream program keys
        // minted before a clear could alias new ones)
        let mut seen = std::collections::HashSet::new();
        for i in 0..(2 * MAX_CACHE_ENTRIES + 10) {
            let id = session.intern_source(&format!("kernel {i}"));
            assert!(seen.insert(id), "source id {id} reused");
        }
        assert!(session.sources.len() <= MAX_CACHE_ENTRIES);
        // re-interning a live entry is a stable hit
        let a = session.intern_source("stable");
        let b = session.intern_source("stable");
        assert_eq!(a, b);
    }
}

