//! Benchmark mode (paper §4.7): run the kernel and *measure* cy/CL
//! instead of predicting it.
//!
//! Three measurement paths:
//! * **virtual** — the trace-driven testbed ([`crate::sim`]), standing in
//!   for the paper's SNB/HSW machines (used by Table 5's Bench column);
//! * **native** — hand-written Rust loops for the five paper kernels,
//!   timed with the TSC on the *host* CPU;
//! * **pjrt** — the AOT-lowered JAX/Pallas artifacts executed through the
//!   PJRT runtime ([`crate::runtime`]), proving the three-layer stack
//!   composes end to end.
//!
//! Native and PJRT numbers are host measurements; they validate relative
//! behaviour (who is memory-bound, where saturation happens), not the
//! SNB/HSW absolute cycle counts.

use crate::kernel::KernelAnalysis;
use crate::machine::MachineModel;
use crate::util::{estimate_tsc_hz, median, monotonic_ns};
use anyhow::{bail, Result};
use std::hint::black_box;

/// One benchmark-mode measurement.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Which path produced it ("virtual", "native", "pjrt").
    pub path: &'static str,
    /// Cycles per cache line of work (8 iterations for doubles).
    pub cy_per_cl: f64,
    /// Iterations per second.
    pub it_per_s: f64,
    /// Wall seconds measured (0 for the virtual path).
    pub wall_s: f64,
    pub iterations: u64,
}

/// Run the virtual-testbed benchmark for a kernel analysis.
pub fn run_virtual(analysis: &KernelAnalysis, machine: &MachineModel) -> Result<BenchResult> {
    let sim = crate::sim::VirtualTestbed::new(machine).run(analysis)?;
    Ok(BenchResult {
        path: "virtual",
        cy_per_cl: sim.cy_per_cl,
        it_per_s: sim.iterations_per_second(machine.clock_hz),
        wall_s: 0.0,
        iterations: sim.iterations,
    })
}

/// Native Rust implementations of the five paper kernels, for host
/// measurements. Returns iterations executed.
pub mod native {
    use super::black_box;

    /// 2D 5-point Jacobi sweep.
    pub fn jacobi2d(a: &[f64], b: &mut [f64], m: usize, n: usize, s: f64) -> u64 {
        for j in 1..m - 1 {
            for i in 1..n - 1 {
                b[j * n + i] =
                    (a[j * n + i - 1] + a[j * n + i + 1] + a[(j - 1) * n + i] + a[(j + 1) * n + i])
                        * s;
            }
        }
        ((m - 2) * (n - 2)) as u64
    }

    /// Schönauer triad.
    pub fn triad(a: &mut [f64], b: &[f64], c: &[f64], d: &[f64]) -> u64 {
        let n = a.len();
        for i in 0..n {
            a[i] = b[i] + c[i] * d[i];
        }
        n as u64
    }

    /// Kahan-compensated dot product.
    pub fn kahan_ddot(a: &[f64], b: &[f64]) -> (f64, u64) {
        let (mut sum, mut c) = (0.0f64, 0.0f64);
        for i in 0..a.len() {
            let prod = a[i] * b[i];
            let y = prod - c;
            let t = sum + y;
            c = black_box((t - sum) - y);
            sum = t;
        }
        (sum, a.len() as u64)
    }

    /// UXX stencil sweep (arrays are m×n×n, row-major).
    #[allow(clippy::too_many_arguments)]
    pub fn uxx(
        u1: &mut [f64],
        d1: &[f64],
        xx: &[f64],
        xy: &[f64],
        xz: &[f64],
        m: usize,
        n: usize,
        c1: f64,
        c2: f64,
        dth: f64,
    ) -> u64 {
        let at = |k: usize, j: usize, i: usize| k * n * n + j * n + i;
        for k in 2..m - 2 {
            for j in 2..n - 2 {
                for i in 2..n - 2 {
                    let d = (d1[at(k - 1, j, i)]
                        + d1[at(k - 1, j - 1, i)]
                        + d1[at(k, j, i)]
                        + d1[at(k, j - 1, i)])
                        * 0.25;
                    u1[at(k, j, i)] += (dth / d)
                        * (c1 * (xx[at(k, j, i)] - xx[at(k, j, i - 1)])
                            + c2 * (xx[at(k, j, i + 1)] - xx[at(k, j, i - 2)])
                            + c1 * (xy[at(k, j, i)] - xy[at(k, j - 1, i)])
                            + c2 * (xy[at(k, j + 1, i)] - xy[at(k, j - 2, i)])
                            + c1 * (xz[at(k, j, i)] - xz[at(k - 1, j, i)])
                            + c2 * (xz[at(k + 1, j, i)] - xz[at(k - 2, j, i)]));
                }
            }
        }
        (((m - 4) * (n - 4)) as u64) * ((n - 4) as u64)
    }

    /// Fourth-order long-range stencil sweep.
    pub fn long_range(
        u: &mut [f64],
        v: &[f64],
        roc: &[f64],
        m: usize,
        n: usize,
        c: &[f64; 5],
    ) -> u64 {
        let at = |k: usize, j: usize, i: usize| k * n * n + j * n + i;
        for k in 4..m - 4 {
            for j in 4..n - 4 {
                for i in 4..n - 4 {
                    let mut lap = c[0] * v[at(k, j, i)];
                    for o in 1..5usize {
                        lap += c[o] * (v[at(k, j, i + o)] + v[at(k, j, i - o)]);
                        lap += c[o] * (v[at(k, j + o, i)] + v[at(k, j - o, i)]);
                        lap += c[o] * (v[at(k + o, j, i)] + v[at(k - o, j, i)]);
                    }
                    u[at(k, j, i)] = 2.0 * v[at(k, j, i)] - u[at(k, j, i)] + roc[at(k, j, i)] * lap;
                }
            }
        }
        (((m - 8) * (n - 8)) as u64) * ((n - 8) as u64)
    }
}

/// Run a native host benchmark for a Table 5 kernel tag.
pub fn run_native(tag: &str, constants: &[(&str, i64)], samples: usize) -> Result<BenchResult> {
    let get = |name: &str| -> usize {
        constants
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| *v as usize)
            .unwrap_or(0)
    };
    let tsc_hz = estimate_tsc_hz();
    let mut wall = Vec::new();
    let mut iters = 0u64;
    for _ in 0..samples.max(1) {
        let t0 = monotonic_ns();
        iters = match tag {
            "2D-5pt" => {
                let (m, n) = (get("M"), get("N"));
                let a = vec![0.5f64; m * n];
                let mut b = vec![0.0f64; m * n];
                let it = native::jacobi2d(&a, &mut b, m, n, 0.25);
                black_box(&b);
                it
            }
            "triad" => {
                let n = get("N");
                let mut a = vec![0.0f64; n];
                let (b, c, d) = (vec![1.0f64; n], vec![2.0f64; n], vec![3.0f64; n]);
                let it = native::triad(&mut a, &b, &c, &d);
                black_box(&a);
                it
            }
            "Kahan-dot" => {
                let n = get("N");
                let (a, b) = (vec![0.5f64; n], vec![0.25f64; n]);
                let (s, it) = native::kahan_ddot(&a, &b);
                black_box(s);
                it
            }
            "UXX" => {
                let (m, n) = (get("M"), get("N"));
                let mut u1 = vec![1.0f64; m * n * n];
                let d1 = vec![2.0f64; m * n * n];
                let xx = vec![0.5f64; m * n * n];
                let xy = vec![0.25f64; m * n * n];
                let xz = vec![0.75f64; m * n * n];
                let it = native::uxx(&mut u1, &d1, &xx, &xy, &xz, m, n, 0.5, 0.25, 0.1);
                black_box(&u1);
                it
            }
            "long-range" => {
                let (m, n) = (get("M"), get("N"));
                let mut u = vec![1.0f64; m * n * n];
                let v = vec![0.5f64; m * n * n];
                let roc = vec![0.25f64; m * n * n];
                let it = native::long_range(&mut u, &v, &roc, m, n, &[0.5, 0.2, 0.1, 0.05, 0.025]);
                black_box(&u);
                it
            }
            other => bail!("unknown kernel tag '{other}'"),
        };
        let t1 = monotonic_ns();
        wall.push((t1 - t0) as f64 / 1e9);
    }
    let wall_s = median(&wall);
    let it_per_s = iters as f64 / wall_s;
    // cy/CL on the HOST: host cycles per 8 iterations
    let cy_per_cl = tsc_hz / it_per_s * 8.0;
    Ok(BenchResult { path: "native", cy_per_cl, it_per_s, wall_s, iterations: iters })
}

/// Run the PJRT (AOT artifact) benchmark for an artifact name.
pub fn run_pjrt(artifacts_dir: &std::path::Path, name: &str, samples: usize) -> Result<BenchResult> {
    let rt = crate::runtime::Runtime::cpu()?;
    let metas = crate::runtime::load_manifest(artifacts_dir)?;
    let meta = metas
        .iter()
        .find(|m| m.name == name)
        .ok_or_else(|| anyhow::anyhow!("artifact '{name}' not in manifest"))?;
    let loaded = rt.load(artifacts_dir, meta)?;
    let timing = loaded.time(samples)?;
    let tsc_hz = estimate_tsc_hz();
    let it_per_s = timing.iterations_per_second();
    Ok(BenchResult {
        path: "pjrt",
        cy_per_cl: tsc_hz / it_per_s * 8.0,
        it_per_s,
        wall_s: timing.median_ns / 1e9,
        iterations: timing.iterations,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::parse;
    use std::collections::HashMap;

    #[test]
    fn native_jacobi_computes_correctly() {
        let (m, n) = (6, 8);
        let a: Vec<f64> = (0..m * n).map(|x| x as f64).collect();
        let mut b = vec![0.0; m * n];
        native::jacobi2d(&a, &mut b, m, n, 0.25);
        // b[1][1] = (a[1][0] + a[1][2] + a[0][1] + a[2][1]) * 0.25
        let want = (a[n] + a[n + 2] + a[1] + a[2 * n + 1]) * 0.25;
        assert_eq!(b[n + 1], want);
        assert_eq!(b[0], 0.0, "boundary untouched");
    }

    #[test]
    fn native_kahan_beats_naive_on_ill_conditioned_sum() {
        let n = 4096;
        let mut a = vec![1e-8f64; n];
        a[0] = 1e16;
        a[n - 1] = -1e16;
        let b = vec![1.0f64; n];
        let (s, _) = native::kahan_ddot(&a, &b);
        let exact = 1e-8 * (n as f64 - 2.0);
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((s - exact).abs() <= (naive - exact).abs());
    }

    #[test]
    fn native_triad_values() {
        let mut a = vec![0.0; 16];
        let b = vec![1.0; 16];
        let c = vec![2.0; 16];
        let d = vec![3.0; 16];
        native::triad(&mut a, &b, &c, &d);
        assert!(a.iter().all(|&x| x == 7.0));
    }

    #[test]
    fn run_native_reports_positive_rates() {
        let r = run_native("triad", &[("N", 100_000)], 3).unwrap();
        assert!(r.it_per_s > 0.0);
        assert!(r.cy_per_cl > 0.0);
        assert_eq!(r.iterations, 100_000);
    }

    #[test]
    fn run_native_rejects_unknown_tag() {
        assert!(run_native("nope", &[], 1).is_err());
    }

    #[test]
    fn virtual_bench_agrees_with_sim() {
        let m = MachineModel::snb();
        let src = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        let cmap: HashMap<String, i64> = [("N".to_string(), 500_000i64)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &cmap).unwrap();
        let r = run_virtual(&a, &m).unwrap();
        assert_eq!(r.path, "virtual");
        assert!(r.cy_per_cl > 40.0 && r.cy_per_cl < 60.0, "{}", r.cy_per_cl);
    }
}
