//! Minimal YAML-subset parser for machine description files.
//!
//! The paper distributes hardware descriptions as YAML (Listing 2). The
//! offline crate set has no YAML library, so we implement the subset the
//! machine files actually use:
//!
//! * indentation-scoped block maps (`key: value` / `key:` + indented body),
//! * block lists (`- item`, `- {inline map}`),
//! * inline (flow) lists `[a, b, c]` and inline maps `{k: v, k2: v2}`,
//! * scalars with optional units (`2.7 GHz`, `32 kB`, `64 B/cy`),
//! * `#` comments and `null`.
//!
//! Anchors, multi-line strings, multi-document streams etc. are
//! intentionally unsupported and rejected loudly.

use thiserror::Error;

/// Parse error with line information.
#[derive(Debug, Error)]
#[error("yaml error at line {line}: {msg}")]
pub struct YamlError {
    pub line: usize,
    pub msg: String,
}

/// Parsed YAML value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// Scalar stored verbatim (unit parsing happens in the accessors).
    Scalar(String),
    /// `null` / `~` / empty.
    Null,
    List(Vec<Value>),
    /// Insertion-ordered key/value pairs.
    Map(Vec<(String, Value)>),
}

impl Value {
    /// Look up a key in a map value.
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Map(entries) => entries.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Map entries (empty for non-maps).
    pub fn entries(&self) -> &[(String, Value)] {
        match self {
            Value::Map(e) => e,
            _ => &[],
        }
    }

    /// List items (empty for non-lists).
    pub fn items(&self) -> &[Value] {
        match self {
            Value::List(v) => v,
            _ => &[],
        }
    }

    /// Raw scalar string, if this is a scalar.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Scalar(s) => Some(s.as_str()),
            _ => None,
        }
    }

    /// Parse the scalar as `f64`, ignoring a trailing unit word
    /// (`"2.7 GHz"` → 2.7).
    pub fn as_f64(&self) -> Option<f64> {
        let s = self.as_str()?;
        let first = s.split_whitespace().next()?;
        first.parse().ok()
    }

    /// Parse as integer.
    pub fn as_i64(&self) -> Option<i64> {
        let s = self.as_str()?;
        let first = s.split_whitespace().next()?;
        first.parse().ok()
    }

    /// Parse as boolean (`true`/`false`).
    pub fn as_bool(&self) -> Option<bool> {
        match self.as_str()? {
            "true" | "True" => Some(true),
            "false" | "False" => Some(false),
            _ => None,
        }
    }

    /// Parse a size with unit into bytes: `32 kB`, `20 MB`, `64 B`.
    /// Uses binary multipliers (kB = 1024) as cache sizes conventionally do.
    pub fn as_bytes(&self) -> Option<u64> {
        let s = self.as_str()?;
        let mut parts = s.split_whitespace();
        let num: f64 = parts.next()?.parse().ok()?;
        let mult = match parts.next().unwrap_or("B") {
            "B" => 1.0,
            "kB" | "KB" | "KiB" => 1024.0,
            "MB" | "MiB" => 1024.0 * 1024.0,
            "GB" | "GiB" => 1024.0 * 1024.0 * 1024.0,
            _ => return None,
        };
        Some((num * mult) as u64)
    }

    /// Parse a frequency into Hz: `2.7 GHz`, `2300 MHz`.
    pub fn as_hz(&self) -> Option<f64> {
        let s = self.as_str()?;
        let mut parts = s.split_whitespace();
        let num: f64 = parts.next()?.parse().ok()?;
        Some(match parts.next().unwrap_or("Hz") {
            "Hz" => num,
            "kHz" => num * 1e3,
            "MHz" => num * 1e6,
            "GHz" => num * 1e9,
            _ => return None,
        })
    }

    /// Parse a bandwidth into bytes/second: `40.8 GB/s` (decimal
    /// multipliers, matching how memory bandwidth is reported).
    pub fn as_bandwidth(&self) -> Option<f64> {
        let s = self.as_str()?;
        let mut parts = s.split_whitespace();
        let num: f64 = parts.next()?.parse().ok()?;
        Some(match parts.next().unwrap_or("B/s") {
            "B/s" => num,
            "kB/s" => num * 1e3,
            "MB/s" => num * 1e6,
            "GB/s" => num * 1e9,
            _ => return None,
        })
    }
}

/// Parse a YAML-subset document into a [`Value`].
pub fn parse(src: &str) -> Result<Value, YamlError> {
    // Pre-process: strip comments and blank lines, record indentation.
    // Lines with unbalanced `[`/`{` are merged with their continuation
    // lines so flow collections may wrap.
    let mut lines: Vec<(usize, usize, String)> = Vec::new(); // (lineno, indent, content)
    for (ln, raw) in src.lines().enumerate() {
        let no_comment = strip_comment(raw);
        let trimmed = no_comment.trim_end();
        if trimmed.trim().is_empty() {
            continue;
        }
        let indent = trimmed.len() - trimmed.trim_start().len();
        if let Some((_, _, prev)) = lines.last_mut() {
            if flow_depth(prev) > 0 {
                prev.push(' ');
                prev.push_str(trimmed.trim_start());
                continue;
            }
        }
        lines.push((ln + 1, indent, trimmed.trim_start().to_string()));
    }
    let mut pos = 0;
    let v = parse_block(&lines, &mut pos, 0)?;
    if pos != lines.len() {
        return Err(YamlError {
            line: lines[pos].0,
            msg: "unexpected content after document (bad indentation?)".into(),
        });
    }
    Ok(v)
}

/// Net `[`/`{` nesting depth of a line (quote-aware).
fn flow_depth(s: &str) -> i32 {
    let mut depth = 0i32;
    let mut in_quote: Option<char> = None;
    for c in s.chars() {
        match in_quote {
            Some(q) => {
                if c == q {
                    in_quote = None;
                }
            }
            None => match c {
                '"' | '\'' => in_quote = Some(c),
                '[' | '{' => depth += 1,
                ']' | '}' => depth -= 1,
                _ => {}
            },
        }
    }
    depth
}

fn strip_comment(line: &str) -> String {
    // '#' starts a comment unless inside quotes
    let mut out = String::new();
    let mut in_quote: Option<char> = None;
    for c in line.chars() {
        match in_quote {
            Some(q) => {
                if c == q {
                    in_quote = None;
                }
                out.push(c);
            }
            None => {
                if c == '#' {
                    break;
                }
                if c == '"' || c == '\'' {
                    in_quote = Some(c);
                }
                out.push(c);
            }
        }
    }
    out
}

fn parse_block(
    lines: &[(usize, usize, String)],
    pos: &mut usize,
    indent: usize,
) -> Result<Value, YamlError> {
    if *pos >= lines.len() {
        return Ok(Value::Null);
    }
    let (_, first_indent, first) = &lines[*pos];
    if *first_indent != indent {
        return Err(YamlError {
            line: lines[*pos].0,
            msg: format!("expected indent {indent}, found {first_indent}"),
        });
    }
    if first.starts_with("- ") || first == "-" {
        parse_block_list(lines, pos, indent)
    } else {
        parse_block_map(lines, pos, indent)
    }
}

fn parse_block_list(
    lines: &[(usize, usize, String)],
    pos: &mut usize,
    indent: usize,
) -> Result<Value, YamlError> {
    let mut items = Vec::new();
    while *pos < lines.len() {
        let (ln, ind, content) = &lines[*pos];
        if *ind < indent {
            break;
        }
        if *ind > indent {
            return Err(YamlError { line: *ln, msg: "unexpected deeper indent in list".into() });
        }
        if !(content.starts_with("- ") || content == "-") {
            break;
        }
        let rest = content.strip_prefix('-').unwrap().trim_start();
        *pos += 1;
        if rest.is_empty() {
            // nested block under the dash
            let inner_indent =
                lines.get(*pos).map(|(_, i, _)| *i).filter(|i| *i > indent).ok_or(YamlError {
                    line: *ln,
                    msg: "empty list item".into(),
                })?;
            items.push(parse_block(lines, pos, inner_indent)?);
        } else if let Some(stripped) = rest.strip_suffix(':') {
            // `- key:` — a map item whose first key has a nested value
            let key = unquote(stripped);
            let inner_indent =
                lines.get(*pos).map(|(_, i, _)| *i).filter(|i| *i > indent).ok_or(YamlError {
                    line: *ln,
                    msg: "missing value for list-item key".into(),
                })?;
            let v = parse_block(lines, pos, inner_indent)?;
            items.push(Value::Map(vec![(key, v)]));
        } else {
            items.push(parse_inline(rest, *ln)?);
        }
    }
    Ok(Value::List(items))
}

fn parse_block_map(
    lines: &[(usize, usize, String)],
    pos: &mut usize,
    indent: usize,
) -> Result<Value, YamlError> {
    let mut entries = Vec::new();
    while *pos < lines.len() {
        let (ln, ind, content) = &lines[*pos];
        if *ind < indent {
            break;
        }
        if *ind > indent {
            return Err(YamlError { line: *ln, msg: "unexpected deeper indent in map".into() });
        }
        if content.starts_with("- ") {
            break;
        }
        let colon = find_key_colon(content).ok_or(YamlError {
            line: *ln,
            msg: format!("expected 'key: value', found '{content}'"),
        })?;
        let key = unquote(content[..colon].trim());
        let rest = content[colon + 1..].trim();
        *pos += 1;
        if rest.is_empty() {
            // nested block (map or list) or null
            match lines.get(*pos) {
                Some((_, i, _)) if *i > indent => {
                    let inner = *i;
                    entries.push((key, parse_block(lines, pos, inner)?));
                }
                Some((_, i, c)) if *i == indent && (c.starts_with("- ") || c == "-") => {
                    // list at same indentation level (common YAML style)
                    entries.push((key, parse_block_list(lines, pos, indent)?));
                }
                _ => entries.push((key, Value::Null)),
            }
        } else {
            entries.push((key, parse_inline(rest, *ln)?));
        }
    }
    Ok(Value::Map(entries))
}

/// Find the colon separating key from value at nesting depth 0.
fn find_key_colon(s: &str) -> Option<usize> {
    let mut depth = 0i32;
    let mut in_quote: Option<char> = None;
    for (i, c) in s.char_indices() {
        match in_quote {
            Some(q) => {
                if c == q {
                    in_quote = None;
                }
            }
            None => match c {
                '"' | '\'' => in_quote = Some(c),
                '[' | '{' => depth += 1,
                ']' | '}' => depth -= 1,
                ':' if depth == 0 => {
                    // require end-of-string or whitespace after ':' so that
                    // e.g. "B/s" in units never splits
                    let next = s[i + 1..].chars().next();
                    if next.is_none() || next == Some(' ') {
                        return Some(i);
                    }
                }
                _ => {}
            },
        }
    }
    None
}

/// Parse an inline value: flow list, flow map, or scalar.
fn parse_inline(s: &str, line: usize) -> Result<Value, YamlError> {
    let s = s.trim();
    if s == "null" || s == "~" {
        return Ok(Value::Null);
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or(YamlError {
            line,
            msg: "unterminated inline list".into(),
        })?;
        let mut items = Vec::new();
        for part in split_flow(body) {
            let p = part.trim();
            if !p.is_empty() {
                items.push(parse_inline(p, line)?);
            }
        }
        return Ok(Value::List(items));
    }
    if let Some(body) = s.strip_prefix('{') {
        let body = body.strip_suffix('}').ok_or(YamlError {
            line,
            msg: "unterminated inline map".into(),
        })?;
        let mut entries = Vec::new();
        for part in split_flow(body) {
            let p = part.trim();
            if p.is_empty() {
                continue;
            }
            let colon = find_key_colon(p).ok_or(YamlError {
                line,
                msg: format!("expected 'key: value' in inline map, found '{p}'"),
            })?;
            let key = unquote(p[..colon].trim());
            let val = parse_inline(p[colon + 1..].trim(), line)?;
            entries.push((key, val));
        }
        return Ok(Value::Map(entries));
    }
    Ok(Value::Scalar(unquote(s)))
}

/// Split a flow collection body on top-level commas.
fn split_flow(s: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut in_quote: Option<char> = None;
    let mut cur = String::new();
    for c in s.chars() {
        match in_quote {
            Some(q) => {
                if c == q {
                    in_quote = None;
                }
                cur.push(c);
            }
            None => match c {
                '"' | '\'' => {
                    in_quote = Some(c);
                    cur.push(c);
                }
                '[' | '{' => {
                    depth += 1;
                    cur.push(c);
                }
                ']' | '}' => {
                    depth -= 1;
                    cur.push(c);
                }
                ',' if depth == 0 => {
                    out.push(std::mem::take(&mut cur));
                }
                _ => cur.push(c),
            },
        }
    }
    if !cur.trim().is_empty() {
        out.push(cur);
    }
    out
}

fn unquote(s: &str) -> String {
    let s = s.trim();
    if (s.starts_with('"') && s.ends_with('"') && s.len() >= 2)
        || (s.starts_with('\'') && s.ends_with('\'') && s.len() >= 2)
    {
        s[1..s.len() - 1].to_string()
    } else {
        s.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_map() {
        let v = parse("clock: 2.7 GHz\ncores per socket: 8\n").unwrap();
        assert_eq!(v.get("clock").unwrap().as_hz(), Some(2.7e9));
        assert_eq!(v.get("cores per socket").unwrap().as_i64(), Some(8));
    }

    #[test]
    fn parses_nested_map() {
        let src = "FLOPs per cycle:\n  DP: {total: 8, ADD: 4, MUL: 4}\n  SP: {total: 16, ADD: 8, MUL: 8}\n";
        let v = parse(src).unwrap();
        let dp = v.get("FLOPs per cycle").unwrap().get("DP").unwrap();
        assert_eq!(dp.get("total").unwrap().as_i64(), Some(8));
        assert_eq!(dp.get("MUL").unwrap().as_i64(), Some(4));
    }

    #[test]
    fn parses_inline_list_with_quotes() {
        let v = parse("non-overlapping ports: [\"2D\", \"3D\"]\n").unwrap();
        let items = v.get("non-overlapping ports").unwrap().items();
        assert_eq!(items[0].as_str(), Some("2D"));
        assert_eq!(items[1].as_str(), Some("3D"));
    }

    #[test]
    fn parses_block_list_of_inline_maps() {
        let src = "memory hierarchy:\n  - {level: L1, size per group: 32 kB, ways: 8}\n  - {level: L2, size per group: 256 kB, ways: 8}\n";
        let v = parse(src).unwrap();
        let mh = v.get("memory hierarchy").unwrap().items();
        assert_eq!(mh.len(), 2);
        assert_eq!(mh[0].get("level").unwrap().as_str(), Some("L1"));
        assert_eq!(mh[0].get("size per group").unwrap().as_bytes(), Some(32 * 1024));
    }

    #[test]
    fn parses_list_at_key_indent() {
        // `key:` followed by `- item` at the same indent
        let src = "levels:\n- one\n- two\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get("levels").unwrap().items().len(), 2);
    }

    #[test]
    fn null_values() {
        let v = parse("bandwidth: null\nsize: ~\n").unwrap();
        assert_eq!(v.get("bandwidth"), Some(&Value::Null));
        assert_eq!(v.get("size"), Some(&Value::Null));
    }

    #[test]
    fn comments_are_stripped() {
        let v = parse("# header\nclock: 2.3 GHz  # fixed\n").unwrap();
        assert_eq!(v.get("clock").unwrap().as_hz(), Some(2.3e9));
    }

    #[test]
    fn unit_accessors() {
        assert_eq!(Value::Scalar("64 B".into()).as_bytes(), Some(64));
        assert_eq!(Value::Scalar("20 MB".into()).as_bytes(), Some(20 * 1024 * 1024));
        assert_eq!(Value::Scalar("40.8 GB/s".into()).as_bandwidth(), Some(40.8e9));
        assert_eq!(Value::Scalar("true".into()).as_bool(), Some(true));
    }

    #[test]
    fn nested_inline_structures() {
        let v = parse("x: {a: [1, 2], b: {c: 3}}\n").unwrap();
        let x = v.get("x").unwrap();
        assert_eq!(x.get("a").unwrap().items().len(), 2);
        assert_eq!(x.get("b").unwrap().get("c").unwrap().as_i64(), Some(3));
    }

    #[test]
    fn colon_in_unit_not_split() {
        // "B/s" style strings must not confuse the key splitter
        let v = parse("bw: 12 GB/s\n").unwrap();
        assert_eq!(v.get("bw").unwrap().as_bandwidth(), Some(12e9));
    }

    #[test]
    fn rejects_bad_indent() {
        assert!(parse("a: 1\n   b: 2\n").is_err());
    }

    #[test]
    fn quoted_keys() {
        let v = parse("\"0DV\": [DIV]\n").unwrap();
        assert_eq!(v.get("0DV").unwrap().items()[0].as_str(), Some("DIV"));
    }

    #[test]
    fn deep_nesting_blocks() {
        let src = "a:\n  b:\n    c: 1\n  d: 2\n";
        let v = parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().get("b").unwrap().get("c").unwrap().as_i64(), Some(1));
        assert_eq!(v.get("a").unwrap().get("d").unwrap().as_i64(), Some(2));
    }

    #[test]
    fn list_item_with_nested_block() {
        let src = "ms:\n  - level: MEM\n    kernel: copy\n";
        // `- key: value` with continuation lines is NOT in our subset;
        // ensure it errors rather than silently mis-parsing.
        assert!(parse(src).is_err());
    }
}
