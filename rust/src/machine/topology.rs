//! Host topology probe — the `likwid-topology` substitute (paper §4.2).
//!
//! Reads `/proc/cpuinfo` and `/sys/devices/system/cpu` to build a machine
//! file *skeleton* for the host. Port tables and latencies cannot be
//! probed and must be filled in by hand, exactly as the paper notes for
//! `likwid_auto_bench.py` ("cache transfer speeds ... need to be manually
//! added"). Bandwidth measurements come from [`crate::microbench`].

use std::fs;

/// Probed cache level.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ProbedCache {
    pub level: u32,
    pub size_bytes: u64,
    pub ways: u32,
    pub shared_cpus: u32,
    /// "Data", "Instruction", "Unified"
    pub kind: String,
}

/// Probed host topology.
#[derive(Debug, Clone, Default)]
pub struct Topology {
    pub model_name: String,
    pub logical_cpus: u32,
    pub cores: u32,
    pub sockets: u32,
    pub threads_per_core: u32,
    pub caches: Vec<ProbedCache>,
    pub cacheline_bytes: u64,
    /// Base clock estimate in Hz. `None` when the probe could not
    /// determine it — the emitted machine file then carries an explicit
    /// `TODO` marker that [`crate::machine::MachineModel`] refuses to
    /// consume, instead of a silently fabricated frequency.
    pub clock_hz: Option<f64>,
}

impl Topology {
    /// Probe the current host. Fails soft: missing sysfs entries yield
    /// defaults rather than errors, so this works in containers too.
    pub fn probe() -> Self {
        let cpuinfo = fs::read_to_string("/proc/cpuinfo").unwrap_or_default();
        let mut model_name = String::from("unknown");
        let mut clock_mhz = 0.0f64;
        let mut physical_ids = Vec::new();
        let mut core_ids = Vec::new();
        let mut logical = 0u32;
        for line in cpuinfo.lines() {
            let mut split = line.splitn(2, ':');
            let key = split.next().unwrap_or("").trim();
            let val = split.next().unwrap_or("").trim();
            match key {
                "processor" => logical += 1,
                "model name" if model_name == "unknown" => model_name = val.to_string(),
                "cpu MHz" if clock_mhz == 0.0 => clock_mhz = val.parse().unwrap_or(0.0),
                "physical id" => physical_ids.push(val.to_string()),
                "core id" => core_ids.push(val.to_string()),
                _ => {}
            }
        }
        let sockets = {
            let mut ids = physical_ids.clone();
            ids.sort();
            ids.dedup();
            (ids.len() as u32).max(1)
        };
        let cores = {
            let mut pairs: Vec<(String, String)> = physical_ids
                .iter()
                .cloned()
                .zip(core_ids.iter().cloned())
                .collect();
            pairs.sort();
            pairs.dedup();
            if pairs.is_empty() {
                logical.max(1)
            } else {
                pairs.len() as u32
            }
        };
        let threads_per_core = if cores > 0 { (logical / cores).max(1) } else { 1 };

        let mut caches = Vec::new();
        for ix in 0..8 {
            let base = format!("/sys/devices/system/cpu/cpu0/cache/index{ix}");
            let read = |f: &str| fs::read_to_string(format!("{base}/{f}")).ok();
            let Some(level) = read("level").and_then(|s| s.trim().parse::<u32>().ok()) else {
                break;
            };
            let kind = read("type").map(|s| s.trim().to_string()).unwrap_or_default();
            if kind == "Instruction" {
                continue;
            }
            let size_bytes = read("size")
                .map(|s| parse_size(s.trim()))
                .unwrap_or(0);
            let ways = read("ways_of_associativity")
                .and_then(|s| s.trim().parse().ok())
                .unwrap_or(8);
            let shared_cpus = read("shared_cpu_list")
                .map(|s| count_cpu_list(s.trim()))
                .unwrap_or(1);
            caches.push(ProbedCache { level, size_bytes, ways, shared_cpus, kind });
        }
        let cacheline_bytes = fs::read_to_string(
            "/sys/devices/system/cpu/cpu0/cache/index0/coherency_line_size",
        )
        .ok()
        .and_then(|s| s.trim().parse().ok())
        .unwrap_or(64);

        Topology {
            model_name,
            logical_cpus: logical.max(1),
            cores,
            sockets,
            threads_per_core,
            caches,
            cacheline_bytes,
            clock_hz: if clock_mhz > 0.0 { Some(clock_mhz * 1e6) } else { None },
        }
    }

    /// Render a machine-file skeleton in our YAML dialect. Fields the
    /// probe could not determine are emitted as explicit `TODO` markers
    /// (not fabricated placeholder values): the machine-file loader
    /// refuses to consume them until a measured value is filled in.
    pub fn to_machine_yaml(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!("model name: {}\n", self.model_name));
        s.push_str("micro-architecture: HOST\n");
        match self.clock_hz {
            Some(hz) => s.push_str(&format!("clock: {:.3} GHz\n", hz / 1e9)),
            None => s.push_str(
                "clock: TODO  # probe could not read the base clock; fill in a measured value (e.g. `lscpu`)\n",
            ),
        }
        s.push_str(&format!("sockets: {}\n", self.sockets));
        s.push_str(&format!(
            "cores per socket: {}\n",
            (self.cores / self.sockets.max(1)).max(1)
        ));
        s.push_str(&format!("threads per core: {}\n", self.threads_per_core));
        s.push_str(&format!("cacheline size: {} B\n", self.cacheline_bytes));
        s.push_str(
            "\nFLOPs per cycle:  # TODO: verify for this microarchitecture\n  SP: {total: 16, ADD: 8, MUL: 8}\n  DP: {total: 8, ADD: 4, MUL: 4}\n",
        );
        s.push_str(
            "\nports:  # TODO: port table must be filled in by hand\n  \"0\": [MUL]\n  \"0DV\": [DIV]\n  \"1\": [ADD]\n  \"2\": [AGU]\n  \"2D\": [LOAD]\n  \"3\": [AGU]\n  \"3D\": [LOAD]\n  \"4\": [STORE]\n  \"5\": [MISC]\noverlapping ports: [\"0\", \"0DV\", \"1\", \"2\", \"3\", \"4\", \"5\"]\nnon-overlapping ports: [\"2D\", \"3D\"]\n",
        );
        s.push_str("\nisa:\n  vector bytes: 32\n  fma: false\n  load uop bytes: 16\n  store uop bytes: 16\n  preferred load bytes: 16\n  preferred store bytes: 32\n");
        s.push_str("\nlatency:\n  ADD: 3\n  MUL: 5\n  FMA: 5\n  LOAD: 4\n");
        s.push_str("\nthroughput:\n  DIV:\n    \"1\": 22\n    \"2\": 22\n    \"4\": 42\n");
        s.push_str("\nmemory hierarchy:\n");
        let mut data_caches: Vec<&ProbedCache> =
            self.caches.iter().filter(|c| c.kind != "Instruction").collect();
        data_caches.sort_by_key(|c| c.level);
        for c in &data_caches {
            s.push_str(&format!(
                "  - {{level: L{}, size per group: {} kB, ways: {}, cores per group: {}, groups: {}, cycles per cacheline transfer: 2, access latency: {}}}\n",
                c.level,
                c.size_bytes / 1024,
                c.ways,
                (c.shared_cpus / self.threads_per_core).max(1),
                (self.logical_cpus / c.shared_cpus.max(1)).max(1),
                4 * c.level * c.level,
            ));
        }
        s.push_str(&format!(
            "  - {{level: MEM, cores per group: {}, groups: {}, access latency: 200}}\n",
            (self.cores / self.sockets.max(1)).max(1),
            self.sockets
        ));
        s.push_str("\n# benchmarks: run `cargo run --example machine_probe` to fill this in\n");
        s
    }
}

fn parse_size(s: &str) -> u64 {
    // sysfs sizes look like "32K", "256K", "20480K"
    let (num, mult) = if let Some(k) = s.strip_suffix(['K', 'k']) {
        (k, 1024u64)
    } else if let Some(m) = s.strip_suffix(['M', 'm']) {
        (m, 1024 * 1024)
    } else {
        (s, 1)
    };
    num.trim().parse::<u64>().unwrap_or(0) * mult
}

fn count_cpu_list(s: &str) -> u32 {
    // "0-3,8-11" → 8
    let mut count = 0u32;
    for part in s.split(',') {
        if let Some((a, b)) = part.split_once('-') {
            if let (Ok(a), Ok(b)) = (a.trim().parse::<u32>(), b.trim().parse::<u32>()) {
                count += b.saturating_sub(a) + 1;
            }
        } else if !part.trim().is_empty() {
            count += 1;
        }
    }
    count.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::machine::MachineModel;

    #[test]
    fn probe_does_not_panic() {
        let t = Topology::probe();
        assert!(t.logical_cpus >= 1);
        assert!(t.cacheline_bytes >= 16);
    }

    fn synthetic_topology(clock_hz: Option<f64>) -> Topology {
        Topology {
            model_name: "Test CPU".into(),
            logical_cpus: 8,
            cores: 4,
            sockets: 1,
            threads_per_core: 2,
            caches: vec![
                ProbedCache {
                    level: 1,
                    size_bytes: 32 * 1024,
                    ways: 8,
                    shared_cpus: 2,
                    kind: "Data".into(),
                },
                ProbedCache {
                    level: 2,
                    size_bytes: 1024 * 1024,
                    ways: 16,
                    shared_cpus: 8,
                    kind: "Unified".into(),
                },
            ],
            cacheline_bytes: 64,
            clock_hz,
        }
    }

    #[test]
    fn skeleton_with_known_clock_parses_as_machine_file() {
        let yml = synthetic_topology(Some(3.1e9)).to_machine_yaml();
        let m = MachineModel::from_yaml(&yml).expect("skeleton must parse");
        assert_eq!(m.arch, "HOST");
        assert!((m.clock_hz - 3.1e9).abs() < 1e6);
        assert!(!m.memory_hierarchy.is_empty());
    }

    #[test]
    fn skeleton_with_unknown_clock_cannot_be_consumed_silently() {
        // An unprobed clock must NOT turn into a fabricated "2.0 GHz": the
        // skeleton carries a TODO marker and the loader rejects it with a
        // pointer to the offending field.
        let yml = synthetic_topology(None).to_machine_yaml();
        assert!(yml.contains("clock: TODO"), "{yml}");
        let err = MachineModel::from_yaml(&yml).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("TODO"), "{msg}");
        assert!(msg.contains("clock"), "{msg}");
    }

    #[test]
    fn probe_skeleton_roundtrips_or_flags_todo() {
        // On hosts where /proc/cpuinfo reveals the clock the skeleton
        // parses outright; elsewhere it must fail loudly via the marker.
        let t = Topology::probe();
        let yml = t.to_machine_yaml();
        match MachineModel::from_yaml(&yml) {
            Ok(m) => assert_eq!(m.arch, "HOST"),
            Err(e) => assert!(format!("{e:#}").contains("TODO"), "{e:#}"),
        }
    }

    #[test]
    fn parse_size_suffixes() {
        assert_eq!(parse_size("32K"), 32 * 1024);
        assert_eq!(parse_size("20480K"), 20480 * 1024);
        assert_eq!(parse_size("8M"), 8 * 1024 * 1024);
        assert_eq!(parse_size("64"), 64);
    }

    #[test]
    fn cpu_list_counting() {
        assert_eq!(count_cpu_list("0-3,8-11"), 8);
        assert_eq!(count_cpu_list("0"), 1);
        assert_eq!(count_cpu_list("0,1,2"), 3);
    }
}
