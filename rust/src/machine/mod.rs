//! Machine model (paper §4.2): microarchitecture, topology, memory
//! hierarchy, execution ports, and the microbenchmark database.
//!
//! Machine descriptions are YAML files (paper Listing 2). Two calibrated
//! descriptions ship with the crate — `machines/snb.yml` (Xeon E5-2680,
//! Sandy Bridge-EP) and `machines/hsw.yml` (Xeon E5-2695 v3, Haswell-EP in
//! Cluster-on-Die mode) — reproducing the paper's Table 1 testbed. The
//! measured-bandwidth sections hold values consistent with the published
//! ECM reference results (DESIGN.md §1 documents the substitution: we
//! cannot run likwid-bench on the authors' Xeons, so the shipped numbers
//! are calibrated to the publicly documented measurements).

pub mod topology;
pub mod yaml;

use crate::incore::isa::{InstrOverride, IsaFamily};
use anyhow::{anyhow, bail, Context, Result};
use yaml::Value;

/// µop classes used by the port model (IACA substitute).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UopClass {
    /// Floating-point add/subtract.
    Add,
    /// Floating-point multiply.
    Mul,
    /// Floating-point divide (occupies the divider for several cycles).
    Div,
    /// Fused multiply-add.
    Fma,
    /// Load data movement (the "2D"/"3D" port portions in the paper).
    Load,
    /// Store data movement.
    Store,
    /// Address generation.
    Agu,
    /// Store-address generation (HSW port 7; simple addressing only).
    StAgu,
    /// Everything else (branches, shuffles, loop overhead).
    Misc,
}

impl UopClass {
    fn parse(s: &str) -> Option<Self> {
        Some(match s {
            "ADD" => UopClass::Add,
            "MUL" => UopClass::Mul,
            "DIV" => UopClass::Div,
            "FMA" => UopClass::Fma,
            "LOAD" => UopClass::Load,
            "STORE" => UopClass::Store,
            "AGU" => UopClass::Agu,
            "STAGU" => UopClass::StAgu,
            "MISC" => UopClass::Misc,
            _ => return None,
        })
    }
}

/// One execution port and the µop classes it accepts.
#[derive(Debug, Clone)]
pub struct Port {
    pub name: String,
    pub accepts: Vec<UopClass>,
}

/// Peak flop rates per cycle for one precision.
#[derive(Debug, Clone, Copy, Default)]
pub struct FlopsPerCycle {
    pub total: f64,
    pub add: f64,
    pub mul: f64,
    /// 0 when the architecture has no FMA.
    pub fma: f64,
}

/// ISA/codegen parameters of the architecture.
#[derive(Debug, Clone)]
pub struct IsaParams {
    /// Instruction-set family (`isa: family:`, default x86); selects the
    /// in-core engine's default instruction mnemonics (DESIGN.md §4).
    pub family: IsaFamily,
    /// SIMD register width in bytes (32 for AVX).
    pub vector_bytes: u64,
    /// Whether FMA contraction is available.
    pub fma: bool,
    /// Max bytes a single load µop moves (16 on SNB, 32 on HSW).
    pub load_uop_bytes: u64,
    /// Max bytes a single store µop moves.
    pub store_uop_bytes: u64,
    /// Load instruction width the modeled compiler prefers (the paper's
    /// icc 15 emits half-wide 16-byte AVX loads for these kernels).
    pub preferred_load_bytes: u64,
    /// Store instruction width the modeled compiler prefers.
    pub preferred_store_bytes: u64,
}

/// Instruction latencies (cycles) for the critical-path model.
#[derive(Debug, Clone, Copy)]
pub struct Latencies {
    pub add: f64,
    pub mul: f64,
    pub fma: f64,
    pub load: f64,
}

/// One level of the memory hierarchy.
#[derive(Debug, Clone)]
pub struct MemLevel {
    /// "L1", "L2", "L3", "MEM".
    pub name: String,
    /// Capacity per group in bytes (None for MEM).
    pub size_bytes: Option<u64>,
    /// Associativity (for the trace-driven simulator).
    pub ways: u32,
    /// Cores sharing one group of this level.
    pub cores_per_group: u32,
    /// Number of groups in the whole system.
    pub groups: u32,
    /// Documented cycles to move one cache line between this level and the
    /// next-outer one (the ECM T_{Lk,Lk+1} unit cost). None ⇒ derived from
    /// measured bandwidth (the MEM link).
    pub cycles_per_cacheline: Option<f64>,
    /// Load-to-use latency in cycles (used by the virtual testbed).
    pub latency: f64,
}

/// Stream signature of a microbenchmark kernel: (pure reads, read+write,
/// pure writes) — the taxonomy of the paper's Listing 2.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamSig {
    pub reads: u32,
    pub read_writes: u32,
    pub writes: u32,
}

impl StreamSig {
    /// Squared Euclidean distance between stream signatures, used for the
    /// "closest match" benchmark selection (paper §4.6.1).
    pub fn dist2(&self, other: &StreamSig) -> i64 {
        let d = |a: u32, b: u32| {
            let d = a as i64 - b as i64;
            d * d
        };
        d(self.reads, other.reads)
            + d(self.read_writes, other.read_writes)
            + d(self.writes, other.writes)
    }
}

/// One microbenchmark kernel description.
#[derive(Debug, Clone)]
pub struct BenchKernel {
    pub name: String,
    pub streams: StreamSig,
    pub flops_per_iteration: u32,
}

/// Measured bandwidths of one benchmark kernel in one memory level:
/// `bandwidth_bs[c]` is bytes/second using `c+1` cores.
#[derive(Debug, Clone)]
pub struct BenchMeasurement {
    pub level: String,
    pub kernel: String,
    pub bandwidth_bs: Vec<f64>,
}

/// Microbenchmark database of the machine file.
#[derive(Debug, Clone, Default)]
pub struct BenchmarkDb {
    pub kernels: Vec<BenchKernel>,
    pub measurements: Vec<BenchMeasurement>,
}

impl BenchmarkDb {
    /// Find the benchmark kernel closest to the given stream signature.
    pub fn closest_kernel(&self, sig: &StreamSig) -> Option<&BenchKernel> {
        self.kernels.iter().min_by_key(|k| k.streams.dist2(sig))
    }

    /// Measured bandwidth (bytes/s) of `kernel` in `level` with `cores`.
    /// Saturates at the highest measured core count.
    pub fn bandwidth(&self, level: &str, kernel: &str, cores: u32) -> Option<f64> {
        let m = self
            .measurements
            .iter()
            .find(|m| m.level == level && m.kernel == kernel)?;
        if m.bandwidth_bs.is_empty() {
            return None;
        }
        let ix = (cores.max(1) as usize - 1).min(m.bandwidth_bs.len() - 1);
        Some(m.bandwidth_bs[ix])
    }

    /// Saturated (max-core) bandwidth of `kernel` in `level`.
    pub fn saturated_bandwidth(&self, level: &str, kernel: &str) -> Option<f64> {
        let m = self
            .measurements
            .iter()
            .find(|m| m.level == level && m.kernel == kernel)?;
        m.bandwidth_bs.iter().copied().fold(None, |acc, b| {
            Some(match acc {
                None => b,
                Some(a) if b > a => b,
                Some(a) => a,
            })
        })
    }
}

/// Complete machine description.
#[derive(Debug, Clone)]
pub struct MachineModel {
    pub model_name: String,
    /// Short microarchitecture tag: "SNB", "HSW".
    pub arch: String,
    pub clock_hz: f64,
    pub sockets: u32,
    pub cores_per_socket: u32,
    pub threads_per_core: u32,
    pub cacheline_bytes: u64,
    pub flops_per_cycle_dp: FlopsPerCycle,
    pub flops_per_cycle_sp: FlopsPerCycle,
    pub ports: Vec<Port>,
    /// Port names whose occupancy belongs to the overlapping time T_OL.
    pub overlapping_ports: Vec<String>,
    /// Port names whose occupancy is the non-overlapping time T_nOL
    /// (the load/store data portions, "2D"/"3D" in the paper).
    pub non_overlapping_ports: Vec<String>,
    pub isa: IsaParams,
    pub latency: Latencies,
    /// Per-instruction overrides from the optional `instructions:` table
    /// (mnemonic, latency, explicit port assignment per µop class) —
    /// the OSACA-style instruction database, see DESIGN.md §4.
    pub instructions: Vec<(UopClass, InstrOverride)>,
    /// DIV reciprocal throughput (divider occupancy in cycles) by vector
    /// element count: `div_throughput[&1]` scalar, `[&4]` 4-wide AVX.
    pub div_throughput: Vec<(u32, f64)>,
    /// Inner (register-adjacent) to outer ordering: L1, L2, L3, MEM.
    pub memory_hierarchy: Vec<MemLevel>,
    pub benchmarks: BenchmarkDb,
}

impl MachineModel {
    /// Parse a machine description from YAML text.
    pub fn from_yaml(text: &str) -> Result<Self> {
        let v = yaml::parse(text).map_err(|e| anyhow!("{e}"))?;
        Self::from_value(&v)
    }

    /// Load a machine description from a file path.
    pub fn from_file(path: &str) -> Result<Self> {
        Ok(Self::from_file_with_digest(path)?.0)
    }

    /// Load a machine description from a file path together with the
    /// [`crate::jsonio::content_hash`] of the text the model was parsed
    /// from. One read serves both, so the model and the digest can
    /// never describe different versions of a concurrently edited file
    /// — the invariant the persistent report cache keys rely on.
    pub fn from_file_with_digest(path: &str) -> Result<(Self, String)> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading machine file {path}"))?;
        let model = Self::from_yaml(&text)
            .with_context(|| format!("parsing machine file {path}"))?;
        Ok((model, crate::jsonio::content_hash(text.as_bytes())))
    }

    /// Built-in Sandy Bridge-EP (Xeon E5-2680) description — paper Table 1.
    pub fn snb() -> Self {
        Self::from_yaml(SNB_YML).expect("builtin snb.yml must parse")
    }

    /// Built-in Haswell-EP (Xeon E5-2695 v3, Cluster-on-Die) description.
    pub fn hsw() -> Self {
        Self::from_yaml(HSW_YML).expect("builtin hsw.yml must parse")
    }

    /// Embedded YAML text of a built-in machine, or None for keys that
    /// are not builtin tags. Cheap (no parse) — the persistent report
    /// cache digests this to key builtin machines by *content*, with
    /// the same tag resolution order as [`MachineModel::load`].
    pub fn builtin_yaml(tag: &str) -> Option<&'static str> {
        match tag.to_ascii_uppercase().as_str() {
            "SNB" | "SANDYBRIDGE" => Some(SNB_YML),
            "HSW" | "HASWELL" => Some(HSW_YML),
            "A64FX" => Some(A64FX_YML),
            _ => None,
        }
    }

    /// Look up a built-in machine by tag ("SNB"/"HSW", case-insensitive).
    pub fn builtin(tag: &str) -> Option<Self> {
        Self::builtin_yaml(tag)
            .map(|yml| Self::from_yaml(yml).expect("builtin machine yml must parse"))
    }

    /// Resolve a machine key — a builtin tag or a machine-file path — the
    /// way every front end (CLI `-m`, sweep jobs, session requests) does.
    pub fn load(key: &str) -> Result<Self> {
        if let Some(m) = Self::builtin(key) {
            return Ok(m);
        }
        Self::from_file(key)
    }

    /// [`MachineModel::load`] plus the content digest of the
    /// description actually parsed (the embedded YAML for builtin tags,
    /// the file text for paths — same resolution order as `load`).
    pub fn load_with_digest(key: &str) -> Result<(Self, String)> {
        if let Some(yml) = Self::builtin_yaml(key) {
            let model = Self::from_yaml(yml).expect("builtin machine yml must parse");
            return Ok((model, crate::jsonio::content_hash(yml.as_bytes())));
        }
        Self::from_file_with_digest(key)
    }

    /// Memory level by name.
    pub fn level(&self, name: &str) -> Option<&MemLevel> {
        self.memory_hierarchy.iter().find(|l| l.name == name)
    }

    /// Cache levels only (everything except MEM), inner to outer.
    pub fn cache_levels(&self) -> Vec<&MemLevel> {
        self.memory_hierarchy.iter().filter(|l| l.name != "MEM").collect()
    }

    /// DIV throughput for a given vector element count (falls back to the
    /// widest configured width at or below `elems`).
    pub fn div_cycles(&self, elems: u32) -> f64 {
        let mut best: Option<(u32, f64)> = None;
        for &(w, c) in &self.div_throughput {
            if w <= elems && best.map(|(bw, _)| w > bw).unwrap_or(true) {
                best = Some((w, c));
            }
        }
        best.map(|(_, c)| c)
            .or_else(|| self.div_throughput.first().map(|&(_, c)| c))
            .unwrap_or(20.0)
    }

    /// Number of ports accepting a µop class.
    pub fn ports_accepting(&self, class: UopClass) -> usize {
        self.ports.iter().filter(|p| p.accepts.contains(&class)).count()
    }

    /// Cores in one memory group (ccNUMA domain) — the unit for saturated
    /// memory bandwidth.
    pub fn cores_per_numa_domain(&self) -> u32 {
        self.level("MEM").map(|l| l.cores_per_group).unwrap_or(self.cores_per_socket)
    }

    fn from_value(v: &Value) -> Result<Self> {
        if let Some(path) = find_todo(v, "") {
            bail!(
                "machine file field '{path}' is an unresolved TODO (emitted by the \
                 topology probe for values it could not determine) — fill in a \
                 measured value before using this file"
            );
        }
        let req = |key: &str| {
            v.get(key).ok_or_else(|| anyhow!("machine file missing key '{key}'"))
        };
        let model_name = req("model name")?.as_str().unwrap_or("unknown").to_string();
        let arch = req("micro-architecture")?
            .as_str()
            .ok_or_else(|| anyhow!("bad micro-architecture"))?
            .to_string();
        let clock_hz = req("clock")?.as_hz().ok_or_else(|| anyhow!("bad clock"))?;
        let sockets = req("sockets")?.as_i64().unwrap_or(1) as u32;
        let cores_per_socket = req("cores per socket")?.as_i64().unwrap_or(1) as u32;
        let threads_per_core = v
            .get("threads per core")
            .and_then(|x| x.as_i64())
            .unwrap_or(1) as u32;
        let cacheline_bytes = req("cacheline size")?
            .as_bytes()
            .ok_or_else(|| anyhow!("bad cacheline size"))?;

        let fpc = |prec: &str| -> Result<FlopsPerCycle> {
            let node = v
                .get("FLOPs per cycle")
                .and_then(|f| f.get(prec))
                .ok_or_else(|| anyhow!("missing FLOPs per cycle / {prec}"))?;
            Ok(FlopsPerCycle {
                total: node.get("total").and_then(|x| x.as_f64()).unwrap_or(0.0),
                add: node.get("ADD").and_then(|x| x.as_f64()).unwrap_or(0.0),
                mul: node.get("MUL").and_then(|x| x.as_f64()).unwrap_or(0.0),
                fma: node.get("FMA").and_then(|x| x.as_f64()).unwrap_or(0.0),
            })
        };

        let mut ports = Vec::new();
        for (name, classes) in req("ports")?.entries() {
            let mut accepts = Vec::new();
            for c in classes.items() {
                let cname = c.as_str().unwrap_or("");
                accepts.push(
                    UopClass::parse(cname)
                        .ok_or_else(|| anyhow!("unknown uop class '{cname}' on port {name}"))?,
                );
            }
            ports.push(Port { name: name.clone(), accepts });
        }
        let str_list = |key: &str| -> Vec<String> {
            v.get(key)
                .map(|l| l.items().iter().filter_map(|x| x.as_str().map(str::to_string)).collect())
                .unwrap_or_default()
        };
        let overlapping_ports = str_list("overlapping ports");
        let non_overlapping_ports = str_list("non-overlapping ports");

        let isa_node = req("isa")?;
        let family = match isa_node.get("family").and_then(|x| x.as_str()) {
            None => IsaFamily::X86,
            Some(s) => IsaFamily::parse(s)
                .ok_or_else(|| anyhow!("unknown isa family '{s}' (expected x86 or aarch64)"))?,
        };
        let isa = IsaParams {
            family,
            vector_bytes: isa_node.get("vector bytes").and_then(|x| x.as_i64()).unwrap_or(32)
                as u64,
            fma: isa_node.get("fma").and_then(|x| x.as_bool()).unwrap_or(false),
            load_uop_bytes: isa_node
                .get("load uop bytes")
                .and_then(|x| x.as_i64())
                .unwrap_or(32) as u64,
            store_uop_bytes: isa_node
                .get("store uop bytes")
                .and_then(|x| x.as_i64())
                .unwrap_or(32) as u64,
            preferred_load_bytes: isa_node
                .get("preferred load bytes")
                .and_then(|x| x.as_i64())
                .unwrap_or(32) as u64,
            preferred_store_bytes: isa_node
                .get("preferred store bytes")
                .and_then(|x| x.as_i64())
                .unwrap_or(32) as u64,
        };

        let lat_node = req("latency")?;
        let latency = Latencies {
            add: lat_node.get("ADD").and_then(|x| x.as_f64()).unwrap_or(3.0),
            mul: lat_node.get("MUL").and_then(|x| x.as_f64()).unwrap_or(5.0),
            fma: lat_node.get("FMA").and_then(|x| x.as_f64()).unwrap_or(5.0),
            load: lat_node.get("LOAD").and_then(|x| x.as_f64()).unwrap_or(4.0),
        };

        let mut instructions = Vec::new();
        if let Some(table) = v.get("instructions") {
            for (cname, spec) in table.entries() {
                let class = UopClass::parse(cname)
                    .ok_or_else(|| anyhow!("unknown uop class '{cname}' in instructions table"))?;
                let ov = InstrOverride {
                    mnemonic: spec
                        .get("mnemonic")
                        .and_then(|x| x.as_str())
                        .map(str::to_string),
                    latency: spec.get("latency").and_then(|x| x.as_f64()),
                    ports: spec
                        .get("ports")
                        .map(|l| {
                            l.items()
                                .iter()
                                .filter_map(|x| x.as_str().map(str::to_string))
                                .collect()
                        })
                        .unwrap_or_default(),
                };
                instructions.push((class, ov));
            }
        }

        let mut div_throughput = Vec::new();
        if let Some(div) = v.get("throughput").and_then(|t| t.get("DIV")) {
            for (w, c) in div.entries() {
                div_throughput.push((
                    w.parse::<u32>().map_err(|_| anyhow!("bad DIV width '{w}'"))?,
                    c.as_f64().ok_or_else(|| anyhow!("bad DIV cycles"))?,
                ));
            }
        }

        let mut memory_hierarchy = Vec::new();
        for item in req("memory hierarchy")?.items() {
            let name = item
                .get("level")
                .and_then(|x| x.as_str())
                .ok_or_else(|| anyhow!("memory level missing 'level'"))?
                .to_string();
            memory_hierarchy.push(MemLevel {
                size_bytes: item.get("size per group").and_then(|x| x.as_bytes()),
                ways: item.get("ways").and_then(|x| x.as_i64()).unwrap_or(8) as u32,
                cores_per_group: item
                    .get("cores per group")
                    .and_then(|x| x.as_i64())
                    .unwrap_or(1) as u32,
                groups: item.get("groups").and_then(|x| x.as_i64()).unwrap_or(1) as u32,
                cycles_per_cacheline: item
                    .get("cycles per cacheline transfer")
                    .and_then(|x| x.as_f64()),
                latency: item.get("access latency").and_then(|x| x.as_f64()).unwrap_or(4.0),
                name,
            });
        }
        if memory_hierarchy.is_empty() {
            bail!("machine file has an empty memory hierarchy");
        }

        let mut benchmarks = BenchmarkDb::default();
        if let Some(b) = v.get("benchmarks") {
            if let Some(kernels) = b.get("kernels") {
                for (name, k) in kernels.entries() {
                    benchmarks.kernels.push(BenchKernel {
                        name: name.clone(),
                        streams: StreamSig {
                            reads: k.get("read streams").and_then(|x| x.as_i64()).unwrap_or(0)
                                as u32,
                            read_writes: k
                                .get("read+write streams")
                                .and_then(|x| x.as_i64())
                                .unwrap_or(0) as u32,
                            writes: k.get("write streams").and_then(|x| x.as_i64()).unwrap_or(0)
                                as u32,
                        },
                        flops_per_iteration: k
                            .get("FLOPs per iteration")
                            .and_then(|x| x.as_i64())
                            .unwrap_or(0) as u32,
                    });
                }
            }
            if let Some(ms) = b.get("measurements") {
                for m in ms.items() {
                    let level = m
                        .get("level")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("measurement missing level"))?
                        .to_string();
                    let kernel = m
                        .get("kernel")
                        .and_then(|x| x.as_str())
                        .ok_or_else(|| anyhow!("measurement missing kernel"))?
                        .to_string();
                    let bandwidth_bs: Vec<f64> = m
                        .get("bandwidth GB/s")
                        .map(|l| {
                            l.items()
                                .iter()
                                .filter_map(|x| x.as_f64())
                                .map(|g| g * 1e9)
                                .collect()
                        })
                        .unwrap_or_default();
                    if bandwidth_bs.is_empty() {
                        bail!("measurement {level}/{kernel} has no bandwidths");
                    }
                    benchmarks.measurements.push(BenchMeasurement { level, kernel, bandwidth_bs });
                }
            }
        }

        Ok(MachineModel {
            model_name,
            arch,
            clock_hz,
            sockets,
            cores_per_socket,
            threads_per_core,
            cacheline_bytes,
            flops_per_cycle_dp: fpc("DP")?,
            flops_per_cycle_sp: fpc("SP")?,
            ports,
            overlapping_ports,
            non_overlapping_ports,
            isa,
            latency,
            instructions,
            div_throughput,
            memory_hierarchy,
            benchmarks,
        })
    }
}

/// Depth-first scan for `TODO` scalar markers (emitted by the topology
/// probe for fields it cannot determine); returns the key path of the
/// first one found.
fn find_todo(v: &Value, path: &str) -> Option<String> {
    match v {
        Value::Scalar(s) if s.trim().starts_with("TODO") => Some(path.to_string()),
        Value::Map(entries) => entries.iter().find_map(|(k, child)| {
            let p = if path.is_empty() { k.clone() } else { format!("{path}.{k}") };
            find_todo(child, &p)
        }),
        Value::List(items) => items.iter().enumerate().find_map(|(ix, child)| {
            find_todo(child, &format!("{path}[{ix}]"))
        }),
        _ => None,
    }
}

/// Built-in machine files (also available on disk under `machines/`).
pub const SNB_YML: &str = include_str!("../../../machines/snb.yml");
/// Haswell-EP description.
pub const HSW_YML: &str = include_str!("../../../machines/hsw.yml");
/// Fujitsu A64FX (AArch64/SVE) description.
pub const A64FX_YML: &str = include_str!("../../../machines/a64fx.yml");

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snb_parses_and_matches_table1() {
        let m = MachineModel::snb();
        assert_eq!(m.arch, "SNB");
        assert_eq!(m.clock_hz, 2.7e9);
        assert_eq!(m.cores_per_socket, 8);
        assert_eq!(m.sockets, 2);
        assert_eq!(m.cacheline_bytes, 64);
        assert_eq!(m.flops_per_cycle_dp.total, 8.0);
        assert!(!m.isa.fma);
        // L1-L2 32 B/cy ⇒ 2 cy per 64 B cache line (Table 1)
        assert_eq!(m.level("L1").unwrap().cycles_per_cacheline, Some(2.0));
        assert_eq!(m.level("L2").unwrap().cycles_per_cacheline, Some(2.0));
        assert_eq!(m.level("L1").unwrap().size_bytes, Some(32 * 1024));
        assert_eq!(m.level("L3").unwrap().size_bytes, Some(20 * 1024 * 1024));
    }

    #[test]
    fn hsw_parses_and_matches_table1() {
        let m = MachineModel::hsw();
        assert_eq!(m.arch, "HSW");
        assert_eq!(m.clock_hz, 2.3e9);
        // Cluster-on-Die: 7 cores per memory domain
        assert_eq!(m.cores_per_numa_domain(), 7);
        assert!(m.isa.fma);
        assert_eq!(m.flops_per_cycle_dp.total, 16.0);
        // L1-L2 64 B/cy ⇒ 1 cy/CL on Haswell
        assert_eq!(m.level("L1").unwrap().cycles_per_cacheline, Some(1.0));
        assert_eq!(m.level("L2").unwrap().cycles_per_cacheline, Some(2.0));
    }

    #[test]
    fn ports_classified() {
        let m = MachineModel::snb();
        assert_eq!(m.ports_accepting(UopClass::Load), 2);
        assert_eq!(m.ports_accepting(UopClass::Agu), 2);
        assert_eq!(m.ports_accepting(UopClass::Store), 1);
        assert!(m.non_overlapping_ports.contains(&"2D".to_string()));
        let hsw = MachineModel::hsw();
        assert_eq!(hsw.ports_accepting(UopClass::Fma), 2);
    }

    #[test]
    fn benchmark_closest_match() {
        let m = MachineModel::snb();
        // jacobi at MEM: 1 read stream, 1 write ⇒ copy
        let sig = StreamSig { reads: 1, read_writes: 0, writes: 1 };
        assert_eq!(m.benchmarks.closest_kernel(&sig).unwrap().name, "copy");
        // kahan: 2 pure reads ⇒ load
        let sig = StreamSig { reads: 2, read_writes: 0, writes: 0 };
        assert_eq!(m.benchmarks.closest_kernel(&sig).unwrap().name, "load");
        // triad: 3 reads + 1 write ⇒ triad
        let sig = StreamSig { reads: 3, read_writes: 0, writes: 1 };
        assert_eq!(m.benchmarks.closest_kernel(&sig).unwrap().name, "triad");
    }

    #[test]
    fn bandwidth_lookup_and_saturation() {
        let m = MachineModel::snb();
        let b1 = m.benchmarks.bandwidth("MEM", "copy", 1).unwrap();
        let b8 = m.benchmarks.bandwidth("MEM", "copy", 8).unwrap();
        assert!(b1 < b8);
        // beyond measured core count: saturate
        assert_eq!(m.benchmarks.bandwidth("MEM", "copy", 99), Some(b8));
        assert_eq!(m.benchmarks.saturated_bandwidth("MEM", "copy"), Some(b8));
    }

    #[test]
    fn mem_bandwidth_reproduces_paper_t_l3mem() {
        // Jacobi on SNB: 3 cache lines (192 B) per unit of work at the
        // saturated copy bandwidth must be ≈12.7 cy (paper Table 5).
        let m = MachineModel::snb();
        let bw = m.benchmarks.saturated_bandwidth("MEM", "copy").unwrap();
        let cy = 192.0 / bw * m.clock_hz;
        assert!((cy - 12.7).abs() < 0.2, "got {cy}");
        // Haswell: 192 B at the CoD-domain copy bandwidth ≈ 16.7 cy.
        let h = MachineModel::hsw();
        let bw = h.benchmarks.saturated_bandwidth("MEM", "copy").unwrap();
        let cy = 192.0 / bw * h.clock_hz;
        assert!((cy - 16.7).abs() < 0.2, "got {cy}");
    }

    #[test]
    fn div_cycles_width_fallback() {
        let m = MachineModel::snb();
        assert_eq!(m.div_cycles(4), 42.0);
        assert_eq!(m.div_cycles(1), 22.0);
        assert_eq!(m.div_cycles(2), 22.0);
        let h = MachineModel::hsw();
        assert_eq!(h.div_cycles(4), 28.0);
    }

    #[test]
    fn builtin_lookup() {
        assert!(MachineModel::builtin("snb").is_some());
        assert!(MachineModel::builtin("Haswell").is_some());
        assert!(MachineModel::builtin("EPYC").is_none());
    }

    #[test]
    fn missing_key_is_reported() {
        let err = MachineModel::from_yaml("clock: 2 GHz\n").unwrap_err();
        assert!(format!("{err}").contains("missing key"));
    }

    #[test]
    fn cache_levels_excludes_mem() {
        let m = MachineModel::snb();
        let names: Vec<&str> = m.cache_levels().iter().map(|l| l.name.as_str()).collect();
        assert_eq!(names, vec!["L1", "L2", "L3"]);
    }
}
