//! Minimal JSON reader/writer for the typed request/report API.
//!
//! The offline crate set has no serde, so the [`crate::session`] wire
//! format is hand-rolled on this module: a strict RFC 8259 subset parser
//! (objects, arrays, strings with escapes, numbers, booleans, null — no
//! comments, no trailing commas) plus string/number writers shared with
//! the report renderers. Every report section — including the
//! `validation` section of [`crate::session::ModelKind::Validate`]
//! responses — round-trips through here, which is what lets the serve
//! wire format (docs/SERVE.md) stay lossless without serde.
//!
//! Numbers are kept as their source text ([`JsonValue::Num`] stores the
//! literal): integers round-trip exactly at any magnitude, and floats
//! written with Rust's shortest-roundtrip formatting parse back to the
//! identical bit pattern — the property the `AnalysisReport` round-trip
//! tests rely on.

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    /// Number stored as its literal text (exact round-trips).
    Num(String),
    Str(String),
    Arr(Vec<JsonValue>),
    /// Insertion-ordered key/value pairs.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Object field lookup (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(entries) => {
                entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// Object entries (empty for non-objects).
    pub fn entries(&self) -> &[(String, JsonValue)] {
        match self {
            JsonValue::Obj(e) => e,
            _ => &[],
        }
    }

    /// Array items (empty for non-arrays).
    pub fn items(&self) -> &[JsonValue] {
        match self {
            JsonValue::Arr(v) => v,
            _ => &[],
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            JsonValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Parse as a finite float. Literals that overflow `f64` (e.g.
    /// `1e400`) are rejected rather than saturated to infinity — a
    /// non-finite value could not be re-serialized as a JSON number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(s) => s.parse::<f64>().ok().filter(|v| v.is_finite()),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            // integer literals parse directly; no float truncation
            JsonValue::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            JsonValue::Num(s) => s.parse().ok(),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, JsonValue::Null)
    }
}

/// Nesting cap: callers feed the parser untrusted service input, so
/// recursion must be bounded well below the thread stack.
const MAX_DEPTH: usize = 128;

/// Parse one JSON document; trailing non-whitespace is an error.
pub fn parse(text: &str) -> Result<JsonValue> {
    let bytes = text.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != bytes.len() {
        bail!("trailing characters after JSON value at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self, depth: usize) -> Result<JsonValue> {
        if depth > MAX_DEPTH {
            bail!("JSON nested deeper than {MAX_DEPTH} levels");
        }
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(JsonValue::Str(self.string()?)),
            Some(b't') => self.literal("true", JsonValue::Bool(true)),
            Some(b'f') => self.literal("false", JsonValue::Bool(false)),
            Some(b'n') => self.literal("null", JsonValue::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!(
                "unexpected {:?} at byte {}",
                other.map(|c| c as char),
                self.pos
            ),
        }
    }

    fn literal(&mut self, word: &str, v: JsonValue) -> Result<JsonValue> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.pos)
        }
    }

    fn object(&mut self, depth: usize) -> Result<JsonValue> {
        self.expect(b'{')?;
        let mut entries = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(JsonValue::Obj(entries));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            entries.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(JsonValue::Obj(entries));
                }
                other => bail!(
                    "expected ',' or '}}' at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<JsonValue> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(JsonValue::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(JsonValue::Arr(items));
                }
                other => bail!(
                    "expected ',' or ']' at byte {}, found {:?}",
                    self.pos,
                    other.map(|c| c as char)
                ),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        bail!("unterminated escape");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let cp = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                // a high surrogate must be followed by a
                                // \u-escaped low surrogate — anything else
                                // is malformed, not silently recombined
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if (0xDC00..0xE000).contains(&lo) {
                                        let combined = 0x10000
                                            + ((cp - 0xD800) << 10)
                                            + (lo - 0xDC00);
                                        char::from_u32(combined)
                                    } else {
                                        None
                                    }
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| {
                                anyhow!("invalid \\u escape near byte {}", self.pos)
                            })?);
                        }
                        other => bail!("bad escape '\\{}'", other as char),
                    }
                }
                _ if b < 0x20 => bail!("unescaped control character in string"),
                _ if b < 0x80 => out.push(b as char),
                _ => {
                    // multi-byte UTF-8: back up and take the full char
                    self.pos -= 1;
                    let width = match b {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = self
                        .bytes
                        .get(self.pos..self.pos + width)
                        .and_then(|w| std::str::from_utf8(w).ok())
                        .ok_or_else(|| anyhow!("invalid UTF-8 in string"))?;
                    out.push(chunk.chars().next().unwrap());
                    self.pos += width;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        if self.pos + 4 > self.bytes.len() {
            bail!("truncated \\u escape");
        }
        let digits = &self.bytes[self.pos..self.pos + 4];
        // from_str_radix alone would accept a leading '+': require hex digits
        if !digits.iter().all(|b| b.is_ascii_hexdigit()) {
            bail!("bad \\u escape at byte {}", self.pos);
        }
        let hex = std::str::from_utf8(digits).expect("hex digits are ASCII");
        let v = u32::from_str_radix(hex, 16).map_err(|_| anyhow!("bad \\u escape '{hex}'"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<JsonValue> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|b| b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-')
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let lit = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_rfc8259_number(lit) {
            bail!("bad number literal '{lit}'");
        }
        Ok(JsonValue::Num(lit.to_string()))
    }
}

/// RFC 8259 number grammar: `[-] int [frac] [exp]` with `int` being `0`
/// or a non-zero-led digit run — stricter than `str::parse::<f64>`,
/// which tolerates `01`, `1.`, `.5`.
fn is_rfc8259_number(s: &str) -> bool {
    let b = s.as_bytes();
    let mut i = 0;
    if b.get(i) == Some(&b'-') {
        i += 1;
    }
    match b.get(i) {
        Some(b'0') => i += 1,
        Some(c) if c.is_ascii_digit() => {
            while b.get(i).is_some_and(|c| c.is_ascii_digit()) {
                i += 1;
            }
        }
        _ => return false,
    }
    if b.get(i) == Some(&b'.') {
        i += 1;
        let frac_start = i;
        while b.get(i).is_some_and(|c| c.is_ascii_digit()) {
            i += 1;
        }
        if i == frac_start {
            return false;
        }
    }
    if matches!(b.get(i), Some(b'e' | b'E')) {
        i += 1;
        if matches!(b.get(i), Some(b'+' | b'-')) {
            i += 1;
        }
        let exp_start = i;
        while b.get(i).is_some_and(|c| c.is_ascii_digit()) {
            i += 1;
        }
        if i == exp_start {
            return false;
        }
    }
    i == b.len()
}

/// Canonical serialization: a deterministic single-line rendering with
/// object keys sorted and numeric literals normalized, so two documents
/// that differ only in key order or number spelling (`6e3` vs `6000`)
/// canonicalize to the same bytes. This is the hashing input of the
/// persistent report cache (`kerncraft serve --cache-dir`, see
/// docs/OPERATIONS.md): cache keys are [`content_hash`]es of canonical
/// text, never of raw wire bytes.
pub fn canonical(v: &JsonValue) -> String {
    let mut out = String::new();
    write_canonical(v, &mut out);
    out
}

fn write_canonical(v: &JsonValue, out: &mut String) {
    match v {
        JsonValue::Null => out.push_str("null"),
        JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        JsonValue::Num(lit) => out.push_str(&canonical_number(lit)),
        JsonValue::Str(s) => out.push_str(&json_str(s)),
        JsonValue::Arr(items) => {
            out.push('[');
            for (ix, item) in items.iter().enumerate() {
                if ix > 0 {
                    out.push(',');
                }
                write_canonical(item, out);
            }
            out.push(']');
        }
        JsonValue::Obj(entries) => {
            let mut sorted: Vec<&(String, JsonValue)> = entries.iter().collect();
            sorted.sort_by(|a, b| a.0.cmp(&b.0));
            out.push('{');
            for (ix, (k, val)) in sorted.iter().enumerate() {
                if ix > 0 {
                    out.push(',');
                }
                out.push_str(&json_str(k));
                out.push(':');
                write_canonical(val, out);
            }
            out.push('}');
        }
    }
}

/// Normalize a number literal: integers render via `i64`/`u64`, floats
/// via Rust's shortest-roundtrip formatting. Literals outside both
/// ranges (overflowing exponents) keep their source text.
fn canonical_number(lit: &str) -> String {
    if let Ok(i) = lit.parse::<i64>() {
        return i.to_string();
    }
    if let Ok(u) = lit.parse::<u64>() {
        return u.to_string();
    }
    match lit.parse::<f64>() {
        Ok(f) if f.is_finite() => format!("{f}"),
        _ => lit.to_string(),
    }
}

/// 128-bit FNV-1a digest as 32 lowercase hex characters. Not
/// cryptographic — it keys the persistent report cache, where a
/// collision costs a wrong cache answer only if an adversary controls
/// the inputs *and* the operator shares one cache dir with them; the
/// offline crate set has no hash crates, and 128 bits keep accidental
/// collisions out of reach for any realistic request corpus.
pub fn content_hash(bytes: &[u8]) -> String {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013b;
    let mut h = OFFSET;
    for &b in bytes {
        h ^= b as u128;
        h = h.wrapping_mul(PRIME);
    }
    format!("{h:032x}")
}

/// Quote and escape a string as a JSON string literal.
pub fn json_str(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Format a float as a JSON number. Rust's shortest-roundtrip formatting
/// is valid JSON for finite values (bare integers included); non-finite
/// values become `null`.
pub fn json_num(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), JsonValue::Null);
        assert_eq!(parse("true").unwrap(), JsonValue::Bool(true));
        assert_eq!(parse(" false ").unwrap(), JsonValue::Bool(false));
        assert_eq!(parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(parse("-7.25e2").unwrap().as_f64(), Some(-725.0));
        assert_eq!(parse("\"hi\\n\\\"there\\\"\"").unwrap().as_str(), Some("hi\n\"there\""));
    }

    #[test]
    fn parses_nested_structures() {
        let v = parse(r#"{"a": [1, 2, {"b": null}], "c": {"d": "e"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().items().len(), 3);
        assert!(v.get("a").unwrap().items()[2].get("b").unwrap().is_null());
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("e"));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn rejects_malformed_documents() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("1 2").is_err(), "trailing garbage");
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn number_grammar_is_rfc8259_strict() {
        for good in ["0", "-0", "7", "-120", "0.5", "1.25e-3", "1E+10", "5e-324"] {
            assert!(parse(good).is_ok(), "{good}");
        }
        for bad in ["01", "1.", "-.5", "1e", "1e+", "0x1", "-", "1.e5"] {
            assert!(parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""\u00e9""#).unwrap().as_str(), Some("é"));
        // surrogate pair: U+1F600
        assert_eq!(parse(r#""\ud83d\ude00""#).unwrap().as_str(), Some("\u{1F600}"));
        assert!(parse(r#""\ud83d""#).is_err(), "lone surrogate");
        assert!(parse(r#""\ud83dA""#).is_err(), "high surrogate + non-low escape");
        assert!(parse(r#""\ud800\ud800""#).is_err(), "two high surrogates");
        assert!(parse(r#""\udc00""#).is_err(), "lone low surrogate");
        assert!(parse(r#""\u+041""#).is_err(), "sign is not a hex digit");
        assert!(parse(r#""\u00 9""#).is_err(), "space is not a hex digit");
    }

    #[test]
    fn overflowing_literals_are_not_saturated_to_infinity() {
        let v = parse("1e400").unwrap();
        assert_eq!(v.as_f64(), None, "non-finite values are rejected");
        assert_eq!(parse("-1e999").unwrap().as_f64(), None);
    }

    #[test]
    fn numbers_round_trip_exactly() {
        for v in [0.1, 1.0 / 3.0, 12.7, f64::MAX, 5e-324] {
            let lit = json_num(v);
            let back = parse(&lit).unwrap().as_f64().unwrap();
            assert_eq!(v.to_bits(), back.to_bits(), "{lit}");
        }
        let big = i64::MAX;
        let lit = format!("{big}");
        assert_eq!(parse(&lit).unwrap().as_i64(), Some(big));
    }

    #[test]
    fn string_writer_escapes() {
        assert_eq!(json_str("a\"b\\c\n"), "\"a\\\"b\\\\c\\n\"");
        assert_eq!(json_str("\u{1}"), "\"\\u0001\"");
        // writer output parses back to the original
        let s = "weird \u{7} mix \t \"quoted\" \\ done";
        assert_eq!(parse(&json_str(s)).unwrap().as_str(), Some(s));
    }

    #[test]
    fn json_num_non_finite_is_null() {
        assert_eq!(json_num(f64::NAN), "null");
        assert_eq!(json_num(f64::INFINITY), "null");
    }

    #[test]
    fn canonical_is_key_order_and_spelling_independent() {
        let a = parse(r#"{"b": 1, "a": {"y": 6e3, "x": [1, 2.50]}}"#).unwrap();
        let b = parse(r#"{"a": {"x": [1, 2.5], "y": 6000}, "b": 1}"#).unwrap();
        assert_eq!(canonical(&a), canonical(&b));
        assert_eq!(canonical(&a), r#"{"a":{"x":[1,2.5],"y":6000},"b":1}"#);
        // canonical text is itself valid JSON
        assert_eq!(parse(&canonical(&a)).unwrap(), parse(&canonical(&b)).unwrap());
        // large integers canonicalize without float truncation
        let big = parse(&format!("{}", i64::MAX)).unwrap();
        assert_eq!(canonical(&big), format!("{}", i64::MAX));
    }

    #[test]
    fn content_hash_is_stable_and_spreads() {
        // pinned digest: a silent hash change would orphan every
        // persistent cache entry ever written
        assert_eq!(content_hash(b""), "6c62272e07bb014262b821756295c58d");
        assert_eq!(content_hash(b"a"), "d228cb696f1a8caf78912b704e4a8964");
        assert_ne!(content_hash(b"request-1"), content_hash(b"request-2"));
    }

    #[test]
    fn nesting_depth_is_bounded() {
        // service input: a pathological nesting bomb must error, not
        // overflow the stack
        let bomb = "[".repeat(100_000);
        let err = parse(&bomb).unwrap_err();
        assert!(format!("{err}").contains("nested deeper"), "{err}");
        // ordinary nesting stays well within the cap
        let ok = format!("{}1{}", "[".repeat(64), "]".repeat(64));
        assert!(parse(&ok).is_ok());
    }
}
