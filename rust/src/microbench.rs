//! STREAM-style microbenchmark suite — the `likwid-bench` substitute.
//!
//! Measures achievable bandwidth of the five benchmark kernels the paper's
//! machine files use (load, copy, update, daxpy, triad) for a range of
//! working-set sizes, so `examples/machine_probe.rs` can fill the
//! `benchmarks:` section of a host machine file (paper §4.2,
//! `likwid_auto_bench.py`).

use crate::util::{median, monotonic_ns};
use std::hint::black_box;

/// The benchmark kernels with their per-iteration traffic in bytes
/// (including write-allocate, as likwid-bench reports).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamKernel {
    Load,
    Copy,
    Update,
    Daxpy,
    Triad,
}

impl StreamKernel {
    /// All kernels in machine-file order.
    pub fn all() -> [StreamKernel; 5] {
        [
            StreamKernel::Load,
            StreamKernel::Copy,
            StreamKernel::Update,
            StreamKernel::Daxpy,
            StreamKernel::Triad,
        ]
    }

    /// Machine-file name.
    pub fn name(&self) -> &'static str {
        match self {
            StreamKernel::Load => "load",
            StreamKernel::Copy => "copy",
            StreamKernel::Update => "update",
            StreamKernel::Daxpy => "daxpy",
            StreamKernel::Triad => "triad",
        }
    }

    /// Bytes moved per iteration, counting write-allocate traffic.
    pub fn bytes_per_iteration(&self) -> u64 {
        match self {
            StreamKernel::Load => 8,          // read a
            StreamKernel::Copy => 24,         // read b + WA a + write a
            StreamKernel::Update => 16,       // read a + write a
            StreamKernel::Daxpy => 24,        // read a, b + write a
            StreamKernel::Triad => 40,        // read b, c, d + WA a + write a
        }
    }
}

/// One measurement: kernel × working-set size.
#[derive(Debug, Clone)]
pub struct BandwidthSample {
    pub kernel: StreamKernel,
    /// Total working set in bytes (all arrays).
    pub working_set: u64,
    /// Measured bandwidth in bytes/second.
    pub bandwidth_bs: f64,
}

/// Measure one kernel at one per-array length, repeating the sweep until
/// ~`min_ms` of work and taking the median of `samples`.
pub fn measure(kernel: StreamKernel, n: usize, samples: usize, min_ms: u64) -> BandwidthSample {
    let mut a = vec![1.0f64; n];
    let b = vec![2.0f64; n];
    let c = vec![3.0f64; n];
    let d = vec![4.0f64; n];
    let s = 1.000001f64;

    let bytes_per_sweep = kernel.bytes_per_iteration() * n as u64;
    // calibrate sweep count for the target duration
    let mut sweeps = 1u64;
    loop {
        let t0 = monotonic_ns();
        run_sweeps(kernel, &mut a, &b, &c, &d, s, sweeps);
        let dt = monotonic_ns() - t0;
        if dt >= min_ms * 1_000_000 || sweeps > 1 << 24 {
            break;
        }
        sweeps = (sweeps * 2).max(((min_ms * 1_000_000) / dt.max(1)) * sweeps + 1);
    }

    let mut bws = Vec::with_capacity(samples);
    for _ in 0..samples.max(1) {
        let t0 = monotonic_ns();
        run_sweeps(kernel, &mut a, &b, &c, &d, s, sweeps);
        let dt = (monotonic_ns() - t0) as f64 / 1e9;
        bws.push(bytes_per_sweep as f64 * sweeps as f64 / dt);
    }
    let arrays = match kernel {
        StreamKernel::Load | StreamKernel::Update => 1,
        StreamKernel::Copy => 2,
        StreamKernel::Daxpy => 2,
        StreamKernel::Triad => 4,
    };
    BandwidthSample {
        kernel,
        working_set: arrays * n as u64 * 8,
        bandwidth_bs: median(&bws),
    }
}

fn run_sweeps(
    kernel: StreamKernel,
    a: &mut [f64],
    b: &[f64],
    c: &[f64],
    d: &[f64],
    s: f64,
    sweeps: u64,
) {
    let n = a.len();
    for _ in 0..sweeps {
        match kernel {
            StreamKernel::Load => {
                let mut acc = 0.0f64;
                for x in a.iter() {
                    acc += *x;
                }
                black_box(acc);
            }
            StreamKernel::Copy => {
                for i in 0..n {
                    a[i] = b[i];
                }
            }
            StreamKernel::Update => {
                for i in 0..n {
                    a[i] *= s;
                }
            }
            StreamKernel::Daxpy => {
                for i in 0..n {
                    a[i] += s * b[i];
                }
            }
            StreamKernel::Triad => {
                for i in 0..n {
                    a[i] = b[i] + c[i] * d[i];
                }
            }
        }
        black_box(&a[0]);
    }
}

/// Sweep all kernels over per-level working-set sizes derived from the
/// host caches: returns (level_name, samples).
pub fn sweep_levels(cache_sizes: &[(String, u64)]) -> Vec<(String, Vec<BandwidthSample>)> {
    let mut out = Vec::new();
    for (name, size) in cache_sizes {
        // target half the capacity so the set comfortably fits
        let per_array = (size / 2 / 8).max(512) as usize;
        let mut samples = Vec::new();
        for k in StreamKernel::all() {
            samples.push(measure(k, per_array, 3, 20));
        }
        out.push((name.clone(), samples));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_accounting() {
        assert_eq!(StreamKernel::Copy.bytes_per_iteration(), 24);
        assert_eq!(StreamKernel::Triad.bytes_per_iteration(), 40);
    }

    #[test]
    fn measure_produces_positive_bandwidth() {
        let s = measure(StreamKernel::Copy, 4096, 2, 5);
        assert!(s.bandwidth_bs > 1e6, "{}", s.bandwidth_bs);
        assert_eq!(s.working_set, 2 * 4096 * 8);
    }

    #[test]
    fn cache_resident_faster_than_memory_sized() {
        // a 16 kB set should beat a 64 MB set on any real machine;
        // tolerate noisy CI by only asserting a loose ordering
        let small = measure(StreamKernel::Triad, 2048, 3, 10);
        let large = measure(StreamKernel::Triad, 8 << 20, 1, 10);
        assert!(
            small.bandwidth_bs > large.bandwidth_bs * 0.8,
            "small {} vs large {}",
            small.bandwidth_bs,
            large.bandwidth_bs
        );
    }

    #[test]
    fn all_kernels_run() {
        for k in StreamKernel::all() {
            let s = measure(k, 1024, 1, 2);
            assert!(s.bandwidth_bs.is_finite() && s.bandwidth_bs > 0.0);
        }
    }
}
