//! The blocking adviser (`kerncraft advise`, [`ModelKind::Advise`]):
//! turn the analytic layer-condition machinery into ranked cache-blocking
//! advice for one kernel/machine pair — the first mode that answers
//! "how do I make it fast" instead of "how fast is it".
//!
//! The engine (DESIGN.md §5) runs three stages:
//!
//! 1. **Breakpoint solve** — [`crate::cache::solve_lc_breakpoints`]
//!    decomposes every layer-condition footprint into
//!    `const + slope · extent` of the array dimension streamed by the
//!    innermost loop and inverts the inequality per cache level. No
//!    problem-size sweep, no offset walk — the breakpoints come out of
//!    closed-form division.
//! 2. **Candidate enumeration** — every distinct breakpoint extent below
//!    the current extent (and at least [`MIN_BLOCK_EXTENT`]) is a
//!    candidate inner-dimension block size. Each candidate is evaluated
//!    through the owning [`Session`] as a plain ECM request with the
//!    `LayerConditions` predictor forced, so the whole advise path stays
//!    analytic (`walk_levels` across all sub-evaluations is asserted to
//!    be observable in the report — zero on the fast path).
//! 3. **Ranking** — candidates are ordered by predicted in-memory ECM
//!    time (`t_mem` ascending), ties broken toward the larger block
//!    (less blocking overhead), then by the unlocked conditions. The
//!    report carries traffic factor and speedup against the unblocked
//!    baseline.
//!
//! Riding on [`Session`] means advise requests are memoized, cacheable
//! (`--cache-dir`) and serveable (`POST /advise`) like every other model.

use crate::cache::{solve_lc_breakpoints, CachePredictorKind};
use crate::jsonio::{json_num, json_str, JsonValue};
use crate::kernel::{Expr, KernelAnalysis};
use crate::machine::MachineModel;
use crate::session::{
    get_f64, get_str, get_u32, get_u64, AnalysisRequest, KernelSpec, ModelKind, Session,
};
use anyhow::{anyhow, bail, Result};
use std::sync::Arc;

/// Smallest inner-dimension block worth recommending: below this the
/// per-block loop overhead (stream startup/drain at every block edge)
/// eats whatever the cache saves.
pub const MIN_BLOCK_EXTENT: u64 = 64;

/// One solved breakpoint row of the advise section (the Fig. 3 bands,
/// solved instead of swept — DESIGN.md §5).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AdviceBreakpoint {
    /// Cache level name.
    pub level: String,
    /// Loop index variable of the condition.
    pub dim_name: String,
    /// Loop depth of the condition (0 = outermost).
    pub dim_index: u32,
    /// Capacity of the level (per active core for shared levels).
    pub cache_bytes: u64,
    /// Extent-independent part of the required footprint.
    pub const_bytes: u64,
    /// Required bytes per element of the varied extent.
    pub slope_bytes: u64,
    /// Largest varied extent satisfying the condition (inclusive).
    pub extent: u64,
}

/// One ranked blocking candidate of the advise section.
#[derive(Debug, Clone, PartialEq)]
pub struct AdviceCandidate {
    /// Proposed block extent of the varied dimension.
    pub extent: u64,
    /// Conditions this block newly satisfies, e.g. `"j@L1"`.
    pub unlocks: Vec<String>,
    /// Predicted in-memory ECM time at this block (cy per unit).
    pub t_mem: f64,
    /// Memory traffic at this block (bytes per unit).
    pub memory_bytes_per_unit: f64,
    /// Baseline total inter-level traffic (bytes per unit, summed over
    /// every link) over the candidate's — ≥ 1 when the block helps. A
    /// block that only relieves an inner link (L1–L2, say) still shows
    /// here even when the memory link is unchanged.
    pub traffic_factor: f64,
    /// Baseline `t_mem` over candidate `t_mem`.
    pub speedup: f64,
}

/// The `advise` section of an `AnalysisReport` ([`ModelKind::Advise`]):
/// the solved breakpoint table plus ranked blocking advice.
#[derive(Debug, Clone, PartialEq)]
pub struct AdviceReport {
    /// Innermost loop index variable — the dimension being blocked.
    pub varied_dim: String,
    /// Kernel constant binding the varied array extent (the one a
    /// blocking transformation would shrink).
    pub varied_constant: String,
    /// Current value of that constant.
    pub current_extent: u64,
    /// Unblocked in-memory ECM time (cy per unit).
    pub baseline_t_mem: f64,
    /// Unblocked memory traffic (bytes per unit).
    pub baseline_memory_bytes_per_unit: f64,
    /// Offset-walk levels summed over every sub-evaluation: 0 means the
    /// entire advise ran on the analytic layer-condition fast path.
    pub walk_levels: u32,
    /// Solved breakpoints, levels inner→outer.
    pub breakpoints: Vec<AdviceBreakpoint>,
    /// Ranked advice, best predicted time first.
    pub candidates: Vec<AdviceCandidate>,
}

/// Build the advise section for an already-resolved request: solve the
/// breakpoints analytically (DESIGN.md §5), then evaluate the unblocked
/// baseline and each candidate block through `session` as plain ECM
/// requests with the analytic predictor forced.
pub(crate) fn build_advice(
    session: &Session,
    req: &AnalysisRequest,
    machine: &MachineModel,
    analysis: &KernelAnalysis,
    label: &str,
    source: &Arc<str>,
) -> Result<AdviceReport> {
    let solve = solve_lc_breakpoints(analysis, machine, req.cores)?;
    let varied_constant = varied_constant(analysis, source, &solve)?;
    let current_extent = solve.current_extent;

    let sub = |extent: u64| -> AnalysisRequest {
        let mut r = AnalysisRequest::new(
            KernelSpec::source(label, source.clone()),
            req.machine.clone(),
        )
        .with_cores(req.cores)
        .with_model(ModelKind::Ecm)
        .with_predictor(CachePredictorKind::LayerConditions)
        .with_codegen(req.codegen);
        r.constants = req.constants.clone();
        r.constants.insert(varied_constant.clone(), extent as i64);
        r
    };
    let mut walk_levels = 0u32;
    // (t_mem, memory bytes/unit, total bytes/unit over every link)
    let mut eval = |extent: u64| -> Result<(f64, f64, f64)> {
        let rep = session.evaluate(&sub(extent))?;
        let t = rep.traffic.as_ref().expect("the ECM model carries traffic");
        walk_levels += t.walk_levels;
        let total = t.levels.iter().map(|l| l.total_lines).sum::<f64>()
            * t.cacheline_bytes as f64;
        let e = rep.ecm.as_ref().expect("the ECM model carries its section");
        Ok((e.t_mem, t.memory_bytes_per_unit, total))
    };

    let (baseline_t_mem, baseline_mem, baseline_total) = eval(current_extent)?;

    let mut extents: Vec<u64> = solve
        .breakpoints
        .iter()
        .map(|b| b.extent)
        .filter(|&e| e >= MIN_BLOCK_EXTENT && e < current_extent)
        .collect();
    extents.sort_unstable();
    extents.dedup();

    let mut candidates = Vec::with_capacity(extents.len());
    for extent in extents {
        // a condition is newly satisfied at this block iff its breakpoint
        // admits the block but not the current extent (inclusive bounds)
        let unlocks: Vec<String> = solve
            .breakpoints
            .iter()
            .filter(|b| b.extent >= extent && b.extent < current_extent)
            .map(|b| format!("{}@{}", b.dim_name, b.level))
            .collect();
        let (t_mem, mem, total) = eval(extent)?;
        candidates.push(AdviceCandidate {
            extent,
            unlocks,
            t_mem,
            memory_bytes_per_unit: mem,
            traffic_factor: if total > 0.0 { baseline_total / total } else { 1.0 },
            speedup: if t_mem > 0.0 { baseline_t_mem / t_mem } else { 1.0 },
        });
    }
    candidates.sort_by(|a, b| {
        a.t_mem
            .total_cmp(&b.t_mem)
            .then_with(|| b.extent.cmp(&a.extent))
            .then_with(|| a.unlocks.cmp(&b.unlocks))
    });

    Ok(AdviceReport {
        varied_dim: solve.varied_dim.clone(),
        varied_constant,
        current_extent,
        baseline_t_mem,
        baseline_memory_bytes_per_unit: baseline_mem,
        walk_levels,
        breakpoints: solve
            .breakpoints
            .iter()
            .map(|b| AdviceBreakpoint {
                level: b.level.clone(),
                dim_name: b.dim_name.clone(),
                dim_index: b.dim_index as u32,
                cache_bytes: b.cache_bytes,
                const_bytes: b.const_bytes,
                slope_bytes: b.slope_bytes,
                extent: b.extent,
            })
            .collect(),
        candidates,
    })
}

/// Resolve which kernel constant binds the varied array extent, and
/// verify the linearity assumption structurally: the constant must appear
/// as the whole dimension expression at the varied position of every
/// participating array, and must size no *other* dimension of any
/// accessed array — an `a[M][N][N]` shape would make the outer footprints
/// quadratic in the block size, defeating the closed-form solve
/// (DESIGN.md §5).
fn varied_constant(
    analysis: &KernelAnalysis,
    source: &str,
    solve: &crate::cache::LcBlockingSolve,
) -> Result<String> {
    let program = crate::kernel::parse(source).map_err(anyhow::Error::from)?;
    let mut name: Option<String> = None;
    for (aix, pos) in solve.varied_positions.iter().enumerate() {
        let Some(pos) = pos else { continue };
        let arr = &analysis.arrays[aix];
        let decl = program
            .decl(&arr.name)
            .ok_or_else(|| anyhow!("array '{}' has no declaration", arr.name))?;
        let dim = decl
            .dims
            .get(*pos)
            .ok_or_else(|| anyhow!("array '{}' has no dimension {pos}", arr.name))?;
        let Expr::Var(v) = dim else {
            bail!(
                "array '{}': the varied dimension {} is not bound to a plain constant — \
                 cannot rebind it for blocking",
                arr.name,
                pos
            );
        };
        match &name {
            None => name = Some(v.clone()),
            Some(n) if n == v => {}
            Some(n) => bail!(
                "arrays bind the varied dimension to different constants ('{n}' vs '{v}') — \
                 no single blocking factor governs it"
            ),
        }
    }
    let name =
        name.ok_or_else(|| anyhow!("no array dimension is bound to the varied loop"))?;
    for (aix, arr) in analysis.arrays.iter().enumerate() {
        let Some(decl) = program.decl(&arr.name) else { continue };
        for (pos, dim) in decl.dims.iter().enumerate() {
            if solve.varied_positions[aix] == Some(pos) {
                continue;
            }
            let mut reused = false;
            dim.visit(&mut |e| {
                if matches!(e, Expr::Var(v) if *v == name) {
                    reused = true;
                }
            });
            if reused {
                bail!(
                    "constant '{}' also sizes dimension {} of array '{}' — the blocked \
                     footprints are not linear in it",
                    name,
                    pos,
                    arr.name
                );
            }
        }
    }
    Ok(name)
}

// ---------------------------------------------------------------------------
// JSON (de)serialization — the session report house style
// ---------------------------------------------------------------------------

impl AdviceReport {
    /// Serialize as a JSON object (one section of the report line).
    pub(crate) fn json(&self) -> String {
        let mut s = format!(
            "{{\"varied_dim\": {}, \"varied_constant\": {}, \"current_extent\": {}, \
             \"baseline_t_mem\": {}, \"baseline_memory_bytes_per_unit\": {}, \
             \"walk_levels\": {}, \"breakpoints\": [",
            json_str(&self.varied_dim),
            json_str(&self.varied_constant),
            self.current_extent,
            json_num(self.baseline_t_mem),
            json_num(self.baseline_memory_bytes_per_unit),
            self.walk_levels
        );
        for (ix, b) in self.breakpoints.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            s.push_str(&format!(
                "{{\"level\": {}, \"dim\": {}, \"dim_index\": {}, \"cache_bytes\": {}, \
                 \"const_bytes\": {}, \"slope_bytes\": {}, \"extent\": {}}}",
                json_str(&b.level),
                json_str(&b.dim_name),
                b.dim_index,
                b.cache_bytes,
                b.const_bytes,
                b.slope_bytes,
                b.extent
            ));
        }
        s.push_str("], \"candidates\": [");
        for (ix, c) in self.candidates.iter().enumerate() {
            if ix > 0 {
                s.push_str(", ");
            }
            let unlocks: Vec<String> = c.unlocks.iter().map(|u| json_str(u)).collect();
            s.push_str(&format!(
                "{{\"extent\": {}, \"unlocks\": [{}], \"t_mem\": {}, \
                 \"memory_bytes_per_unit\": {}, \"traffic_factor\": {}, \"speedup\": {}}}",
                c.extent,
                unlocks.join(", "),
                json_num(c.t_mem),
                json_num(c.memory_bytes_per_unit),
                json_num(c.traffic_factor),
                json_num(c.speedup)
            ));
        }
        s.push_str("]}");
        s
    }

    /// Deserialize from a parsed JSON section.
    pub(crate) fn from_json_value(v: &JsonValue) -> Result<AdviceReport> {
        let mut breakpoints = Vec::new();
        if let Some(JsonValue::Arr(items)) = v.get("breakpoints") {
            for b in items {
                breakpoints.push(AdviceBreakpoint {
                    level: get_str(b, "level")?,
                    dim_name: get_str(b, "dim")?,
                    dim_index: get_u32(b, "dim_index")?,
                    cache_bytes: get_u64(b, "cache_bytes")?,
                    const_bytes: get_u64(b, "const_bytes")?,
                    slope_bytes: get_u64(b, "slope_bytes")?,
                    extent: get_u64(b, "extent")?,
                });
            }
        }
        let mut candidates = Vec::new();
        if let Some(JsonValue::Arr(items)) = v.get("candidates") {
            for c in items {
                let unlocks = match c.get("unlocks") {
                    Some(JsonValue::Arr(us)) => us
                        .iter()
                        .map(|u| {
                            u.as_str()
                                .map(str::to_string)
                                .ok_or_else(|| anyhow!("'unlocks' entries must be strings"))
                        })
                        .collect::<Result<Vec<_>>>()?,
                    _ => Vec::new(),
                };
                candidates.push(AdviceCandidate {
                    extent: get_u64(c, "extent")?,
                    unlocks,
                    t_mem: get_f64(c, "t_mem")?,
                    memory_bytes_per_unit: get_f64(c, "memory_bytes_per_unit")?,
                    traffic_factor: get_f64(c, "traffic_factor")?,
                    speedup: get_f64(c, "speedup")?,
                });
            }
        }
        Ok(AdviceReport {
            varied_dim: get_str(v, "varied_dim")?,
            varied_constant: get_str(v, "varied_constant")?,
            current_extent: get_u64(v, "current_extent")?,
            baseline_t_mem: get_f64(v, "baseline_t_mem")?,
            baseline_memory_bytes_per_unit: get_f64(v, "baseline_memory_bytes_per_unit")?,
            walk_levels: get_u32(v, "walk_levels")?,
            breakpoints,
            candidates,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const JACOBI: &str = "double a[M][N], b[M][N], s;\n\
        for (int j = 1; j < M - 1; j++)\n  for (int i = 1; i < N - 1; i++)\n    \
        b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;";

    fn advise_request(n: i64, m: i64) -> AnalysisRequest {
        AnalysisRequest::new(KernelSpec::source("2d-5pt", JACOBI), "SNB")
            .with_constant("N", n)
            .with_constant("M", m)
            .with_model(ModelKind::Advise)
    }

    #[test]
    fn jacobi_advice_is_analytic_and_improves_traffic() {
        let session = Session::new();
        let report = session.evaluate(&advise_request(6000, 6000)).unwrap();
        let a = report.advise.as_ref().unwrap();
        assert_eq!(a.varied_dim, "i");
        assert_eq!(a.varied_constant, "N");
        assert_eq!(a.current_extent, 6000);
        assert_eq!(a.walk_levels, 0, "advise must stay on the analytic path");
        // the only breakpoint below N=6000 is the L1 one at 1024
        assert_eq!(a.candidates.len(), 1);
        let c = &a.candidates[0];
        assert_eq!(c.extent, 1024);
        assert_eq!(c.unlocks, vec!["j@L1".to_string()]);
        assert!(c.memory_bytes_per_unit <= a.baseline_memory_bytes_per_unit);
        assert!(c.t_mem <= a.baseline_t_mem);
        assert!(c.traffic_factor >= 1.0);
    }

    #[test]
    fn advice_report_round_trips_through_json() {
        let session = Session::new();
        let report = session.evaluate(&advise_request(6000, 6000)).unwrap();
        let parsed =
            crate::session::AnalysisReport::from_json(&report.to_json()).unwrap();
        assert_eq!(report, parsed);
    }

    #[test]
    fn one_dimensional_kernel_is_a_clean_error() {
        let session = Session::new();
        let req = AnalysisRequest::new(KernelSpec::named("triad"), "SNB")
            .with_constant("N", 1_000_000)
            .with_model(ModelKind::Advise);
        let err = session.evaluate(&req).unwrap_err();
        assert!(format!("{err:#}").contains("depth >= 2"), "{err:#}");
    }

    #[test]
    fn shared_dimension_constants_are_rejected() {
        // uxx-style a[M][N][N]: rebinding N would change two dimensions —
        // the footprints are quadratic in it and the solve must refuse
        let session = Session::new();
        let req = AnalysisRequest::new(KernelSpec::named("UXX"), "SNB")
            .with_constant("N", 500)
            .with_constant("M", 500)
            .with_model(ModelKind::Advise);
        let err = session.evaluate(&req).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("also sizes") || msg.contains("not linear"), "{msg}");
    }
}
