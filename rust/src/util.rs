//! Small shared utilities: deterministic PRNG for property tests, cycle
//! timing, and numeric helpers.

/// xorshift64* PRNG — deterministic, dependency-free source of test
/// randomness (the offline crate set has no `rand`).
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    /// Create a generator from a non-zero seed (zero is mapped to a fixed
    /// odd constant to keep the sequence non-degenerate).
    pub fn new(seed: u64) -> Self {
        Self { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform value in `[0, bound)`; `bound` must be non-zero.
    pub fn next_below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        self.next_u64() % bound
    }

    /// Uniform `i64` in `[lo, hi]` (inclusive).
    pub fn next_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + (self.next_below((hi - lo + 1) as u64) as i64)
    }

    /// Uniform `f64` in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.next_below(items.len() as u64) as usize]
    }
}

/// Read the timestamp counter. On x86_64 this is `rdtsc`; elsewhere we
/// fall back to a monotonic-nanosecond clock (1 "cycle" == 1 ns).
#[inline]
pub fn rdtsc() -> u64 {
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::x86_64::_rdtsc()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        monotonic_ns()
    }
}

/// Monotonic nanoseconds (CLOCK_MONOTONIC).
pub fn monotonic_ns() -> u64 {
    use std::time::Instant;
    use std::sync::OnceLock;
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    let epoch = EPOCH.get_or_init(Instant::now);
    epoch.elapsed().as_nanos() as u64
}

/// Estimate the TSC frequency in Hz by spinning for ~50 ms. Used to convert
/// measured cycles to wall time (and vice versa) in Benchmark mode.
pub fn estimate_tsc_hz() -> f64 {
    use std::time::{Duration, Instant};
    let t0 = Instant::now();
    let c0 = rdtsc();
    while t0.elapsed() < Duration::from_millis(50) {
        std::hint::spin_loop();
    }
    let c1 = rdtsc();
    let dt = t0.elapsed().as_secs_f64();
    (c1.wrapping_sub(c0)) as f64 / dt
}

/// Greatest common divisor.
pub fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 { a } else { gcd(b, a % b) }
}

/// Least common multiple (saturating).
pub fn lcm(a: u64, b: u64) -> u64 {
    if a == 0 || b == 0 {
        return 0;
    }
    (a / gcd(a, b)).saturating_mul(b)
}

/// Round `x` up to the next multiple of `m` (`m > 0`).
pub fn round_up(x: u64, m: u64) -> u64 {
    debug_assert!(m > 0);
    x.div_ceil(m) * m
}

/// Median of a slice (copies + sorts; fine for bench-sized inputs).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 { v[n / 2] } else { 0.5 * (v[n / 2 - 1] + v[n / 2]) }
}

/// Format a float trimming trailing zeros, kerncraft-report style
/// (e.g. `12.7`, `8`, `9.54`).
pub fn fmt_cy(x: f64) -> String {
    if (x - x.round()).abs() < 5e-3 {
        format!("{}", x.round() as i64)
    } else {
        let s = format!("{x:.2}");
        let s = s.trim_end_matches('0').trim_end_matches('.').to_string();
        s
    }
}

/// The compact Listing-5 ECM notation, e.g. `{9 ‖ 8 | 10 | 6 | 12.7} cy/CL`
/// — the single source of this format, shared by `EcmModel` and the
/// report renderer so the model and the wire-report render identically.
pub fn ecm_notation_str(t_ol: f64, t_nol: f64, link_cycles: &[f64]) -> String {
    let mut parts = vec![format!("{} \u{2016} {}", fmt_cy(t_ol), fmt_cy(t_nol))];
    for c in link_cycles {
        parts.push(fmt_cy(*c));
    }
    format!("{{{}}} cy/CL", parts.join(" | "))
}

/// The per-level ECM prediction notation, e.g. `{9 \ 18 \ 24 \ 36.7} cy/CL`.
pub fn ecm_prediction_str(level_predictions: &[f64]) -> String {
    let preds: Vec<String> = level_predictions.iter().map(|p| fmt_cy(*p)).collect();
    format!("{{{}}} cy/CL", preds.join(" \\ "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xorshift_is_deterministic() {
        let mut a = XorShift64::new(42);
        let mut b = XorShift64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn xorshift_zero_seed_is_nondegenerate() {
        let mut r = XorShift64::new(0);
        let v: Vec<u64> = (0..4).map(|_| r.next_u64()).collect();
        assert!(v.iter().any(|&x| x != 0));
    }

    #[test]
    fn next_range_bounds() {
        let mut r = XorShift64::new(7);
        for _ in 0..1000 {
            let v = r.next_range(-5, 9);
            assert!((-5..=9).contains(&v));
        }
    }

    #[test]
    fn next_f64_in_unit_interval() {
        let mut r = XorShift64::new(3);
        for _ in 0..1000 {
            let f = r.next_f64();
            assert!((0.0..1.0).contains(&f));
        }
    }

    #[test]
    fn gcd_lcm_basics() {
        assert_eq!(gcd(12, 18), 6);
        assert_eq!(lcm(4, 6), 12);
        assert_eq!(lcm(0, 5), 0);
        assert_eq!(gcd(7, 0), 7);
    }

    #[test]
    fn round_up_works() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn fmt_cy_trims() {
        assert_eq!(fmt_cy(8.0), "8");
        assert_eq!(fmt_cy(12.70), "12.7");
        assert_eq!(fmt_cy(9.539), "9.54");
    }

    #[test]
    fn tsc_is_monotonic_enough() {
        let a = rdtsc();
        let b = rdtsc();
        // Allow equality on coarse clocks; must not go backwards.
        assert!(b >= a);
    }
}
