//! In-core execution model — the IACA substitute (paper §2.1/§4.4).
//!
//! IACA is proprietary and Intel-only; per the reproduction contract we
//! replace it with an explicit model that computes the same quantities
//! from the same ingredients:
//!
//! 1. **Codegen** ([`CodegenPolicy`]): the kernel statements are lowered
//!    to an abstract µop stream the way the paper's icc 15 `-xAVX` build
//!    would — AVX vectorization (disabled for unbreakable loop-carried
//!    recurrences, cf. Kahan §5.2.1), per-array load widths (arrays with
//!    any 32-byte-misaligned access get half-wide 16 B loads, exactly the
//!    behaviour the paper observes in §5.1.1), optional FMA contraction.
//! 2. **Port scheduling**: µops are distributed over the machine file's
//!    port table; the throughput bound is the exact fractional-scheduling
//!    lower bound max_S (Σ µops with port-set ⊆ S)/|S| over port subsets.
//! 3. **Critical path**: loop-carried scalar recurrences are detected in
//!    the dependency graph and their maximum cycle mean (latency per
//!    iteration) bounds the overlapping time, reproducing the 96 cy/CL of
//!    the Kahan dot product.
//!
//! Outputs are the ECM inputs T_OL and T_nOL in cycles per cache line of
//! work, plus TP/CP diagnostics mirroring IACA's report.

use crate::kernel::{BinOp, Expr, KernelAnalysis, ScalarUse};
use crate::machine::{MachineModel, UopClass};
use anyhow::{bail, Result};
use std::collections::HashMap;

/// Compiler-behaviour model used when lowering the kernel to µops.
#[derive(Debug, Clone)]
pub struct CodegenPolicy {
    /// Vectorize with this many elements per SIMD lane set (1 = scalar).
    /// Automatically reduced to 1 when an unbreakable recurrence exists.
    pub vector_elems: u32,
    /// Contract mul+add pairs to FMA.
    pub fma_contract: bool,
    /// Loads from arrays with any misaligned access are split in half
    /// (icc `-xAVX` behaviour on Sandy Bridge).
    pub split_unaligned_loads: bool,
    /// Break single-statement reductions by modulo variable expansion
    /// (icc default `-fp-model fast`); multi-statement recurrences like
    /// Kahan are never broken.
    pub break_reductions: bool,
}

impl CodegenPolicy {
    /// The policy matching the paper's build (icc 15, `-xAVX`, one binary
    /// for both machines).
    pub fn for_machine(machine: &MachineModel) -> Self {
        CodegenPolicy {
            vector_elems: (machine.isa.vector_bytes / 8).max(1) as u32,
            fma_contract: machine.isa.fma,
            // the modeled compiler splits misaligned-stream loads when its
            // preferred load width is below the SIMD width (icc -xAVX does
            // this; the paper runs ONE such binary on both machines)
            split_unaligned_loads: machine.isa.preferred_load_bytes < machine.isa.vector_bytes,
            break_reductions: true,
        }
    }

    /// Fully scalar policy (no SIMD, no FMA) — the naive-codegen baseline.
    pub fn scalar() -> Self {
        CodegenPolicy {
            vector_elems: 1,
            fma_contract: false,
            split_unaligned_loads: false,
            break_reductions: false,
        }
    }
}

/// Per-port pressure in cycles per cache line of work.
#[derive(Debug, Clone, PartialEq)]
pub struct PortPressure {
    pub port: String,
    pub cycles: f64,
}

/// µop counts per cache line of work (diagnostics).
#[derive(Debug, Clone, Copy, Default)]
pub struct UopCounts {
    pub load: f64,
    pub store: f64,
    pub agu: f64,
    pub add: f64,
    pub mul: f64,
    pub fma: f64,
    pub div: f64,
    pub misc: f64,
}

/// The in-core prediction (all numbers in cycles per cache line of work).
#[derive(Debug, Clone)]
pub struct PortModel {
    /// Overlapping time: max pressure on overlapping ports, or the
    /// recurrence critical path if that is larger.
    pub t_ol: f64,
    /// Non-overlapping time: pressure on the data ports ("2D"/"3D").
    pub t_nol: f64,
    /// Pure throughput bound (max over all ports) — IACA "TP".
    pub tp: f64,
    /// Recurrence critical path per cache line (0 when none) — IACA "CP"
    /// flavour for loop-carried chains.
    pub cp: f64,
    /// Whether the code was vectorized.
    pub vectorized: bool,
    /// Elements per SIMD operation used.
    pub vector_elems: u32,
    /// Port pressure table.
    pub pressure: Vec<PortPressure>,
    /// µop counts per cache line.
    pub uops: UopCounts,
    /// Source-level flops per cache line of work.
    pub flops_per_cl: f64,
    /// Inner iterations per cache line of work.
    pub iterations_per_cl: u64,
}

impl PortModel {
    /// Analyze a kernel on a machine under a codegen policy.
    pub fn analyze(
        analysis: &KernelAnalysis,
        machine: &MachineModel,
        policy: &CodegenPolicy,
    ) -> Result<PortModel> {
        if analysis.loops.is_empty() {
            bail!("kernel has no loops");
        }
        let elem = analysis.element.size();
        let iterations_per_cl = analysis.unit_of_work(machine.cacheline_bytes);

        // --- recurrence analysis (critical path) ---
        let rec = RecurrenceGraph::build(analysis, machine);
        let unbreakable = rec.unbreakable_cycle_mean(policy.break_reductions);
        let vector_elems = if unbreakable > 0.0 { 1 } else { policy.vector_elems.max(1) };
        let vectorized = vector_elems > 1;
        let cp = unbreakable * iterations_per_cl as f64;

        // --- load/store µop accounting ---
        // Arrays with any 32 B-misaligned read access get half-wide loads
        // when the policy splits unaligned loads.
        let vec_bytes = (vector_elems as u64 * elem).max(elem);
        let mut misaligned = vec![false; analysis.arrays.len()];
        if policy.split_unaligned_loads && vectorized {
            for acc in &analysis.reads {
                if (acc.offset * elem as i64).rem_euclid(machine.isa.vector_bytes as i64) != 0 {
                    misaligned[acc.array] = true;
                }
            }
        }
        let mut load_uops = 0f64;
        let mut load_instr = 0f64;
        for acc in &analysis.reads {
            // each access streams one cache line of each array per CL of
            // work (scalar offsets inside one line are register-reused)
            let bytes = machine.cacheline_bytes as f64;
            let instr_bytes = if !vectorized {
                elem
            } else if misaligned[acc.array] {
                (vec_bytes / 2).max(elem)
            } else {
                vec_bytes
            };
            let n_instr = bytes / instr_bytes as f64;
            let uops_per_instr = (instr_bytes as f64 / machine.isa.load_uop_bytes as f64).max(1.0);
            load_instr += n_instr;
            load_uops += n_instr * uops_per_instr;
        }
        let mut store_uops = 0f64;
        let mut store_instr = 0f64;
        for _acc in &analysis.writes {
            let bytes = machine.cacheline_bytes as f64;
            let instr_bytes = if vectorized { vec_bytes } else { elem };
            let n_instr = bytes / instr_bytes as f64;
            let uops_per_instr =
                (instr_bytes as f64 / machine.isa.store_uop_bytes as f64).max(1.0);
            store_instr += n_instr;
            store_uops += n_instr * uops_per_instr;
        }
        let agu_uops = load_instr + store_instr;

        // --- arithmetic µop accounting ---
        let f = analysis.flops;
        let (mut adds, mut muls) = (f.adds as f64, f.muls as f64);
        let mut fmas = 0f64;
        if policy.fma_contract && vectorized {
            let fused = adds.min(muls);
            fmas = fused;
            adds -= fused;
            muls -= fused;
        }
        let divs = f.divs as f64;
        let simd_ops_per_cl = |per_iter: f64| -> f64 {
            per_iter * iterations_per_cl as f64 / vector_elems as f64
        };
        let add_uops = simd_ops_per_cl(adds);
        let mul_uops = simd_ops_per_cl(muls);
        let fma_uops = simd_ops_per_cl(fmas);
        let div_uops = simd_ops_per_cl(divs);
        // loop overhead: compare+branch+index increment per asm iteration
        let misc_uops = 2.0 * iterations_per_cl as f64 / vector_elems as f64;

        let uops = UopCounts {
            load: load_uops,
            store: store_uops,
            agu: agu_uops,
            add: add_uops,
            mul: mul_uops,
            fma: fma_uops,
            div: div_uops,
            misc: misc_uops,
        };

        // --- port scheduling ---
        // class → (uop count, cycles per uop)
        let div_cost = machine.div_cycles(vector_elems);
        let class_load: Vec<(UopClass, f64)> = vec![
            (UopClass::Load, load_uops),
            (UopClass::Store, store_uops),
            (UopClass::Agu, agu_uops),
            (UopClass::Add, add_uops),
            (UopClass::Mul, mul_uops),
            (UopClass::Fma, fma_uops),
            (UopClass::Div, div_uops * div_cost),
            (UopClass::Misc, misc_uops),
        ];
        let sched = schedule_ports(machine, &class_load)?;
        let t_nol = sched.max_over(machine, &machine.non_overlapping_ports);
        let t_ol_ports = sched.max_over(machine, &machine.overlapping_ports);
        let t_ol = t_ol_ports.max(cp);
        let tp = sched.global_max;
        let pressure = sched.pressure;

        Ok(PortModel {
            t_ol,
            t_nol,
            tp,
            cp,
            vectorized,
            vector_elems,
            pressure,
            uops,
            flops_per_cl: f.total() as f64 * iterations_per_cl as f64,
            iterations_per_cl,
        })
    }

    /// IACA-style text report (delegates to the shared
    /// [`crate::report::incore_report`] renderer so the model and the
    /// serialized report always print identically).
    pub fn report(&self) -> String {
        crate::report::incore_report(&crate::session::IncoreReport::from_model(self))
    }
}

/// Result of scheduling µop classes onto ports.
struct Schedule {
    /// Per-port pressure under an optimal (min-max) fractional schedule.
    pressure: Vec<PortPressure>,
    /// (port-mask, load) pairs, kept for subset queries.
    masks: Vec<(u32, f64)>,
    /// Exact optimal makespan over all ports.
    global_max: f64,
}

impl Schedule {
    /// Exact optimal max pressure over the given port subset: the
    /// fractional-scheduling bound max_S (sum of classes with ports in S)/|S|,
    /// restricted to subsets of `names`.
    fn max_over(&self, machine: &MachineModel, names: &[String]) -> f64 {
        let mut allowed = 0u32;
        for (i, p) in machine.ports.iter().enumerate() {
            if names.contains(&p.name) {
                allowed |= 1 << i;
            }
        }
        subset_bound_masked(&self.masks, allowed)
    }
}

/// Distribute µop classes over ports with an optimal min-max fractional
/// schedule. The achievable makespan equals the lower bound
/// max_S (sum of loads of classes with port-set in S) / |S| over subsets.
fn schedule_ports(machine: &MachineModel, class_load: &[(UopClass, f64)]) -> Result<Schedule> {
    let n = machine.ports.len();
    if n == 0 {
        bail!("machine has no ports");
    }
    if n > 20 {
        bail!("port table too large for subset scheduling");
    }
    // port mask per class
    let mut masks: Vec<(u32, f64)> = Vec::new();
    for &(class, load) in class_load {
        if load <= 0.0 {
            continue;
        }
        let mut mask = 0u32;
        for (i, p) in machine.ports.iter().enumerate() {
            if p.accepts.contains(&class) {
                mask |= 1 << i;
            }
        }
        if mask == 0 {
            bail!("no port accepts {:?} uops on {}", class, machine.arch);
        }
        masks.push((mask, load));
    }
    let global_max = subset_bound_masked(&masks, (1u32 << n) - 1);

    // Per-port pressure for reporting: water-fill classes in order of
    // ascending port-set size (most-constrained first), topping up the
    // least-loaded legal ports. Exact for laminar port-set families
    // (ours are: ADD {1} inside FMA/MUL {0,1}; everything else disjoint).
    let mut cycles = vec![0f64; n];
    let mut order: Vec<&(u32, f64)> = masks.iter().collect();
    order.sort_by_key(|(m, _)| m.count_ones());
    for &&(mask, load) in &order {
        let ports: Vec<usize> = (0..n).filter(|i| mask & (1 << i) != 0).collect();
        let mut remaining = load;
        while remaining > 1e-12 {
            let min_level = ports.iter().map(|&i| cycles[i]).fold(f64::INFINITY, f64::min);
            let at_min: Vec<usize> =
                ports.iter().copied().filter(|&i| cycles[i] <= min_level + 1e-12).collect();
            let next_level = ports
                .iter()
                .map(|&i| cycles[i])
                .filter(|&c| c > min_level + 1e-12)
                .fold(f64::INFINITY, f64::min);
            let room = if next_level.is_finite() {
                (next_level - min_level) * at_min.len() as f64
            } else {
                f64::INFINITY
            };
            let fill = remaining.min(room);
            let per = fill / at_min.len() as f64;
            for &i in &at_min {
                cycles[i] += per;
            }
            remaining -= fill;
        }
    }
    let pressure = machine
        .ports
        .iter()
        .zip(cycles)
        .map(|(p, c)| PortPressure { port: p.name.clone(), cycles: c })
        .collect();
    Ok(Schedule { pressure, masks, global_max })
}

/// Fractional scheduling bound restricted to subsets of `allowed`.
fn subset_bound_masked(masks: &[(u32, f64)], allowed: u32) -> f64 {
    let mut best = 0f64;
    let mut subset = allowed;
    loop {
        if subset != 0 {
            let mut load = 0f64;
            for &(mask, l) in masks {
                if mask & !subset == 0 {
                    load += l;
                }
            }
            best = best.max(load / subset.count_ones() as f64);
        }
        if subset == 0 {
            break;
        }
        subset = (subset - 1) & allowed;
    }
    best
}

/// Loop-carried scalar dependency graph with operation latencies.
struct RecurrenceGraph {
    /// edge (from, to) → latency across one iteration
    edges: HashMap<(String, String), f64>,
    carried: Vec<String>,
    /// carried vars that are breakable single-op reductions
    breakable: Vec<String>,
}

impl RecurrenceGraph {
    fn build(analysis: &KernelAnalysis, machine: &MachineModel) -> Self {
        let carried: Vec<String> = analysis
            .carried_scalars()
            .into_iter()
            .map(str::to_string)
            .collect();
        let lat_add = machine.latency.add;
        let lat_mul = machine.latency.mul;
        let lat_div = machine.div_cycles(1);

        // symbolic evaluation: var → {carried source → max latency}
        let mut env: HashMap<String, HashMap<String, f64>> = HashMap::new();
        for c in &carried {
            env.insert(c.clone(), HashMap::from([(c.clone(), 0.0)]));
        }
        let mut edges: HashMap<(String, String), f64> = HashMap::new();
        let mut breakable: Vec<String> = Vec::new();

        for st in &analysis.stmts {
            let lhs_name = match &st.lhs {
                Expr::Var(v) => Some(v.clone()),
                _ => None,
            };
            // effective rhs includes the compound-assign op
            let mut deps = expr_deps(&st.rhs, &env, lat_add, lat_mul, lat_div);
            if let Some(op) = st.op.bin_op() {
                let op_lat = match op {
                    BinOp::Add | BinOp::Sub => lat_add,
                    BinOp::Mul => lat_mul,
                    BinOp::Div => lat_div,
                };
                // lhs is also an input
                if let Some(name) = &lhs_name {
                    if let Some(m) = env.get(name) {
                        for (src, l) in m {
                            let e = deps.entry(src.clone()).or_insert(0.0);
                            *e = e.max(l + op_lat);
                        }
                    }
                }
                for l in deps.values_mut() {
                    *l += 0.0; // op latency already applied to lhs path;
                               // rhs paths get it too:
                }
                // apply op latency to pure-rhs paths as well
                let rhs_deps = expr_deps(&st.rhs, &env, lat_add, lat_mul, lat_div);
                for (src, l) in rhs_deps {
                    let e = deps.entry(src.clone()).or_insert(0.0);
                    *e = e.max(l + op_lat);
                }
            }
            if let Some(name) = lhs_name {
                if carried.contains(&name) {
                    // record edges source → name
                    for (src, l) in &deps {
                        let key = (src.clone(), name.clone());
                        let e = edges.entry(key).or_insert(0.0);
                        *e = (*e).max(*l);
                    }
                    // breakability: a single compound add/mul of a
                    // carried var by itself (s += expr-without-carried)
                    let self_only = deps.len() == 1 && deps.contains_key(&name);
                    let simple_reduction = matches!(
                        st.op,
                        crate::kernel::AssignOp::Add | crate::kernel::AssignOp::Mul
                    ) || is_simple_self_update(&st.rhs, &name);
                    if self_only && simple_reduction && !breakable.contains(&name) {
                        breakable.push(name.clone());
                    }
                }
                env.insert(name, deps);
            }
        }
        RecurrenceGraph { edges, carried, breakable }
    }

    /// Maximum cycle mean (latency per iteration) over recurrence cycles
    /// that cannot be broken by modulo variable expansion.
    fn unbreakable_cycle_mean(&self, break_reductions: bool) -> f64 {
        // enumerate simple cycles by DFS (graphs here are tiny)
        let nodes: Vec<&String> = self.carried.iter().collect();
        let mut best = 0f64;
        for start in &nodes {
            let mut stack = vec![((*start).clone(), 0.0f64, vec![(*start).clone()])];
            while let Some((cur, lat, path)) = stack.pop() {
                for ((from, to), w) in &self.edges {
                    if from != &cur {
                        continue;
                    }
                    if to == *start {
                        let cycle_len = path.len() as f64;
                        let mean = (lat + w) / cycle_len;
                        // a pure self-cycle of a breakable reduction is
                        // eliminated by the compiler
                        let breakable_cycle = break_reductions
                            && path.len() == 1
                            && self.breakable.contains(*start);
                        if !breakable_cycle {
                            best = best.max(mean);
                        }
                    } else if !path.contains(to) && self.carried.contains(to) {
                        let mut p = path.clone();
                        p.push(to.clone());
                        stack.push((to.clone(), lat + w, p));
                    }
                }
            }
        }
        best
    }
}

/// `s = s + expr` (or `s = expr + s`) with no other carried deps counts
/// as a simple reduction.
fn is_simple_self_update(rhs: &Expr, name: &str) -> bool {
    match rhs {
        Expr::Binary { op: BinOp::Add | BinOp::Mul, lhs, rhs } => {
            matches!(lhs.as_ref(), Expr::Var(v) if v == name)
                || matches!(rhs.as_ref(), Expr::Var(v) if v == name)
        }
        _ => false,
    }
}

/// Latency map of an expression: carried source var → max path latency.
fn expr_deps(
    e: &Expr,
    env: &HashMap<String, HashMap<String, f64>>,
    lat_add: f64,
    lat_mul: f64,
    lat_div: f64,
) -> HashMap<String, f64> {
    match e {
        Expr::Var(v) => env.get(v).cloned().unwrap_or_default(),
        Expr::Int(_) | Expr::Float(_) | Expr::Index { .. } => HashMap::new(),
        Expr::Neg(inner) => expr_deps(inner, env, lat_add, lat_mul, lat_div),
        Expr::Binary { op, lhs, rhs } => {
            let op_lat = match op {
                BinOp::Add | BinOp::Sub => lat_add,
                BinOp::Mul => lat_mul,
                BinOp::Div => lat_div,
            };
            let l = expr_deps(lhs, env, lat_add, lat_mul, lat_div);
            let r = expr_deps(rhs, env, lat_add, lat_mul, lat_div);
            let mut out = HashMap::new();
            for (src, lat) in l.into_iter().chain(r) {
                let e = out.entry(src).or_insert(0.0f64);
                *e = (*e).max(lat + op_lat);
            }
            out
        }
    }
}

// silence: ScalarUse is re-exported for callers of this module's results
#[allow(unused_imports)]
use ScalarUse as _ScalarUse;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{parse, KernelAnalysis};
    use std::collections::HashMap as Map;

    fn consts(pairs: &[(&str, i64)]) -> Map<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn analyze(src: &str, c: &[(&str, i64)], machine: &MachineModel) -> PortModel {
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(c)).unwrap();
        PortModel::analyze(&a, machine, &CodegenPolicy::for_machine(machine)).unwrap()
    }

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    const KAHAN: &str = r#"
        double a[N], b[N], c;
        double sum, prod, t, y;
        for (int i = 0; i < N; ++i) {
            prod = a[i] * b[i];
            y = prod - c;
            t = sum + y;
            c = (t - sum) - y;
            sum = t;
        }
    "#;

    const TRIAD: &str = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";

    #[test]
    fn jacobi_snb_tol_tnol_match_paper() {
        // Paper Table 5: SNB {9.5 ‖ 8 | ...} — we model 9/8 (the 0.5
        // difference stems from odd spill µops IACA sees; documented).
        let m = MachineModel::snb();
        let pm = analyze(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert!(pm.vectorized);
        assert_eq!(pm.t_nol, 8.0, "{:?}", pm.pressure);
        assert!((pm.t_ol - 9.0).abs() < 0.6, "T_OL = {}", pm.t_ol);
    }

    #[test]
    fn jacobi_hsw_tol_tnol_match_paper() {
        // Paper: HSW {9.4 ‖ 8 | ...}
        let m = MachineModel::hsw();
        let pm = analyze(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert_eq!(pm.t_nol, 8.0, "{:?}", pm.pressure);
        assert!((pm.t_ol - 9.0).abs() < 0.6, "T_OL = {}", pm.t_ol);
    }

    #[test]
    fn kahan_recurrence_dominates() {
        // Paper: T_OL = 96 cy/CL on both architectures — the 12 cy
        // loop-carried chain (4 sequential 3 cy adds) × 8 iterations.
        for m in [MachineModel::snb(), MachineModel::hsw()] {
            let pm = analyze(KAHAN, &[("N", 1000000)], &m);
            assert!(!pm.vectorized, "loop-carried dependency forbids SIMD");
            assert_eq!(pm.cp, 96.0, "{}", m.arch);
            assert_eq!(pm.t_ol, 96.0, "{}", m.arch);
            assert_eq!(pm.t_nol, 8.0, "{} {:?}", m.arch, pm.pressure);
        }
    }

    #[test]
    fn triad_snb_matches_paper() {
        // Paper: SNB {4 ‖ 6 | ...}: aligned streams ⇒ full-wide loads.
        let m = MachineModel::snb();
        let pm = analyze(TRIAD, &[("N", 8000000)], &m);
        assert_eq!(pm.t_nol, 6.0, "{:?}", pm.pressure);
        assert_eq!(pm.t_ol, 4.0, "{:?}", pm.pressure);
    }

    #[test]
    fn triad_hsw_matches_paper() {
        // Paper: HSW {4 ‖ 3 | ...}: full-wide loads are single µops.
        let m = MachineModel::hsw();
        let pm = analyze(TRIAD, &[("N", 8000000)], &m);
        assert_eq!(pm.t_nol, 3.0, "{:?}", pm.pressure);
        assert_eq!(pm.t_ol, 4.0, "{:?}", pm.pressure);
    }

    #[test]
    fn dot_product_reduction_is_broken() {
        // s += a[i]*b[i] — icc breaks the reduction by MVE ⇒ vectorized,
        // no recurrence bound (paper §2.1).
        let m = MachineModel::snb();
        let pm = analyze(
            "double a[N], b[N], s;\nfor (int i = 0; i < N; i++) s += a[i] * b[i];",
            &[("N", 1000000)],
            &m,
        );
        assert!(pm.vectorized);
        assert_eq!(pm.cp, 0.0);
    }

    #[test]
    fn scalar_policy_disables_simd() {
        let m = MachineModel::snb();
        let p = parse(TRIAD).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 1000)])).unwrap();
        let pm = PortModel::analyze(&a, &m, &CodegenPolicy::scalar()).unwrap();
        assert!(!pm.vectorized);
        // scalar loads: 3 arrays × 8 elements = 24 µops on 2 ports
        assert_eq!(pm.t_nol, 12.0);
    }

    #[test]
    fn division_occupies_divider() {
        // UXX-like: one divide per iteration ⇒ 2 vector divides per CL at
        // 42 cy each on SNB (Table 5: T_OL = 84).
        let src = r#"
            double u[M][N], d[M][N], dth;
            for (int j = 1; j < M-1; j++)
                for (int i = 1; i < N-1; i++)
                    u[j][i] = u[j][i] + dth / d[j][i];
        "#;
        let m = MachineModel::snb();
        let pm = analyze(src, &[("N", 500), ("M", 500)], &m);
        assert_eq!(pm.t_ol, 84.0, "{:?}", pm.pressure);
        let h = MachineModel::hsw();
        let pmh = analyze(src, &[("N", 500), ("M", 500)], &h);
        assert_eq!(pmh.t_ol, 56.0, "{:?}", pmh.pressure);
    }

    #[test]
    fn tp_at_least_max_of_tol_tnol_parts() {
        let m = MachineModel::snb();
        let pm = analyze(JACOBI, &[("N", 6000), ("M", 6000)], &m);
        assert!(pm.tp <= pm.t_ol.max(pm.t_nol) + 1e-9);
        assert!(pm.tp >= pm.t_nol - 1e-9);
    }

    #[test]
    fn property_cp_nonnegative_and_tp_positive() {
        let mut rng = crate::util::XorShift64::new(0xBEEF);
        let m = MachineModel::snb();
        for _ in 0..8 {
            let k = rng.next_range(1, 3);
            let src = format!(
                "double a[N], b[N], c[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] * {k}.0 + c[i+{k}];"
            );
            let pm = analyze(&src, &[("N", 100000)], &m);
            assert!(pm.cp >= 0.0);
            assert!(pm.tp > 0.0);
            assert!(pm.t_nol > 0.0);
        }
    }

    #[test]
    fn flops_per_cl() {
        let m = MachineModel::snb();
        let pm = analyze(TRIAD, &[("N", 100000)], &m);
        assert_eq!(pm.flops_per_cl, 16.0); // 2 flops × 8 iterations
    }

    #[test]
    fn report_contains_ports() {
        let m = MachineModel::snb();
        let pm = analyze(TRIAD, &[("N", 100000)], &m);
        let r = pm.report();
        assert!(r.contains("T_OL"));
        assert!(r.contains("port pressure"));
    }
}
