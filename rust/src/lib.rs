//! # kerncraft — automatic loop kernel analysis and performance modeling
//!
//! A from-scratch reproduction of *"Automatic Loop Kernel Analysis and
//! Performance Modeling With Kerncraft"* (Hammer, Hager, Eitzinger,
//! Wellein; PMBS @ SC'15, DOI 10.1145/2832087.2832092), grown into a
//! service-shaped library with thin front ends.
//!
//! The pipeline stages mirror the paper's Figure 1; the [`session`]
//! module is the one front door every consumer goes through:
//!
//! ```text
//!                        session::AnalysisRequest
//!             {kernel, constants, machine, cores, model,
//!              predictor, codegen, unit}   (JSON ⇄ typed)
//!                               │
//!                               ▼
//!  ┌─────────────────────── session::Session ───────────────────────┐
//!  │ cross-request caches:  source ──► kernel::Program              │
//!  │   (MemoStats counters) (source, constants) ──► KernelAnalysis  │
//!  │                        machine key ──► machine::MachineModel   │
//!  │                        (…, machine, codegen) ──► incore::      │
//!  │                                                  PortModel     │
//!  │ per request:  cache:: traffic (layer-cond. fast path ⇄ offset  │
//!  │               walk) ──► models::ecm / models::roofline /       │
//!  │               models::scaling                                  │
//!  └──────────────────────────────┬──────────────────────────────────┘
//!                                 ▼
//!                     session::AnalysisReport
//!           (serde-style JSON ⇄ typed; every figure the text
//!            reports show, plus per-request MemoStats)
//!                                 │
//!        ┌──────────────┬─────────┴───────┬──────────────────┐
//!        ▼              ▼                 ▼                  ▼
//!   cli:: single    cli:: serve      sweep::SweepEngine   report::
//!   runs (`-p ECM`, (JSON-lines      (parallel map of     pure text
//!   `--format       service; worker  requests through     renderers of
//!   json`)          pool with        one shared session;  AnalysisReport
//!                   `--threads K`,   `--validate` rows)
//!                   ordered or
//!                   `--unordered`)
//!                        │
//!                        ▼
//!                   server:: HTTP front end (`serve --listen`):
//!                   /analyze /batch /stream /healthz /metrics over
//!                   hand-rolled HTTP/1.1, plus the persistent
//!                   cross-process report cache (`--cache-dir`,
//!                   server::cache::DiskCache behind the
//!                   session::ReportCache seam)
//!
//!   validation:  `-p Validate` runs sim:: (trace-driven SNB/HSW
//!                testbed) next to the analytic ECM and reports the
//!                relative model error; bench_mode:: native host loops,
//!                runtime:: PJRT artifacts (JAX/Pallas AOT; `pjrt`
//!                feature)
//! ```
//!
//! Entry points: [`session::Session`] for programmatic use,
//! [`sweep::SweepEngine`] for batched grids, [`cli`] for the command-line
//! front ends (`kerncraft`, `kerncraft sweep`, `kerncraft serve`),
//! [`server::Server`] for the embedded HTTP service, and the individual
//! stage modules for composing custom pipelines. The design rationale
//! (measurement substitution, session architecture) lives in DESIGN.md;
//! the serve wire protocol in docs/SERVE.md and the operator guide in
//! docs/OPERATIONS.md.

pub mod advise;
pub mod bench_mode;
pub mod cache;
pub mod cli;
pub mod incore;
pub mod jsonio;
pub mod kernel;
pub mod machine;
pub mod microbench;
pub mod models;
pub mod report;
pub mod runtime;
pub mod server;
pub mod session;
pub mod sim;
pub mod sweep;
pub mod util;

use anyhow::Result;
use std::collections::HashMap;

/// One-shot convenience API, superseded by [`session::Session`] (which
/// memoizes every stage across calls and returns the serializable
/// [`session::AnalysisReport`]):
///
/// ```no_run
/// use kerncraft::session::{AnalysisRequest, KernelSpec, Session};
/// let session = Session::new();
/// let req = AnalysisRequest::new(
///     KernelSpec::source("triad", "double a[N], b[N], c[N], d[N];\n\
///                                  for (int i = 0; i < N; i++)\n  a[i] = b[i] + c[i] * d[i];"),
///     "SNB",
/// )
/// .with_constant("N", 10_000_000);
/// let report = session.evaluate(&req).unwrap();
/// assert!(report.ecm.unwrap().t_mem > 0.0);
/// ```
#[deprecated(
    since = "0.3.0",
    note = "use session::Session::evaluate with a session::AnalysisRequest"
)]
pub fn analyze(
    source: &str,
    constants: &HashMap<String, i64>,
    machine: &machine::MachineModel,
) -> Result<AnalysisOutput> {
    let program = kernel::parse(source)?;
    let analysis = kernel::KernelAnalysis::from_program(&program, constants)?;
    let incore = incore::PortModel::analyze(&analysis, machine, &incore::CodegenPolicy::for_machine(machine))?;
    let traffic = cache::CachePredictor::new(machine).predict(&analysis)?;
    let ecm = models::EcmModel::build(&incore, &traffic, machine)?;
    let roofline = models::RooflineModel::build(&analysis, &traffic, machine, Some(&incore))?;
    Ok(AnalysisOutput { analysis, incore, traffic, ecm, roofline })
}

/// Bundled result of [`analyze`]: every intermediate product is exposed so
/// callers can drill into any stage. New code should use
/// [`session::Session::evaluate_full`], which returns the same products
/// plus the serializable report.
pub struct AnalysisOutput {
    /// Static analysis of the kernel source (loop stack, accesses, flops).
    pub analysis: kernel::KernelAnalysis,
    /// In-core port-model prediction (IACA substitute).
    pub incore: incore::PortModel,
    /// Per-level data traffic prediction.
    pub traffic: cache::TrafficPrediction,
    /// Execution-Cache-Memory model.
    pub ecm: models::EcmModel,
    /// Roofline model (port-model in-core variant).
    pub roofline: models::RooflineModel,
}
