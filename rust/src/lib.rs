//! # kerncraft — automatic loop kernel analysis and performance modeling
//!
//! A from-scratch reproduction of *"Automatic Loop Kernel Analysis and
//! Performance Modeling With Kerncraft"* (Hammer, Hager, Eitzinger,
//! Wellein; PMBS @ SC'15, DOI 10.1145/2832087.2832092).
//!
//! The pipeline mirrors the paper's Figure 1, with the batched sweep
//! engine layered on top:
//!
//! ```text
//!   kernel.c ──► kernel::parse ──► kernel::KernelAnalysis
//!                                   │ loop stack (Table 2)
//!                                   │ data accesses (Tables 3/4)
//!                                   │ flop counts
//!                    machine.yml ──►│
//!                                   ▼
//!            ┌──────────────┬────────────────────────┐
//!            │ incore::     │ cache::                │
//!            │ port model   │ layer-cond. fast path  │
//!            │ (IACA subst.)│ ⇄ offset walk (Auto)   │
//!            └──────┬───────┴─────────┬──────────────┘
//!                   ▼                 ▼
//!              models::ecm / models::roofline ──► report::
//!                   ▲                                ▲
//!      validation:  │            sweep:: ───────────┘
//!        sim::      │  parallel grid evaluation over
//!        bench_mode │  (source × constants × machine × cores),
//!        runtime::  │  memoizing Program / KernelAnalysis /
//!                   │  PortModel / MachineModel across points
//!                   │  (CLI: `kerncraft sweep -D N 128:8M:log2`)
//!                   │
//!                   └─ trace-driven virtual testbed (SNB/HSW stand-in),
//!                      native host loops, PJRT artifacts (JAX/Pallas
//!                      kernels AOT-lowered to HLO text; `pjrt` feature)
//! ```
//!
//! Entry points: [`analyze`] for one-shot analysis, [`sweep::SweepEngine`]
//! for batched grids, [`cli`] for the command-line front end, and the
//! individual modules for programmatic use.

pub mod bench_mode;
pub mod cache;
pub mod cli;
pub mod incore;
pub mod kernel;
pub mod machine;
pub mod microbench;
pub mod models;
pub mod report;
pub mod runtime;
pub mod sim;
pub mod sweep;
pub mod util;

use anyhow::Result;
use std::collections::HashMap;

/// One-shot convenience API: parse `source`, bind `constants`, and build
/// the full ECM + Roofline analysis against `machine`.
///
/// (`no_run`: doctest binaries do not inherit the xla_extension rpath;
/// the same flow is exercised by `cli::tests::end_to_end_ecm_run_...`.)
///
/// ```no_run
/// use kerncraft::machine::MachineModel;
/// let src = "double a[N], b[N], c[N], d[N];\n\
///            for (int i = 0; i < N; i++)\n  a[i] = b[i] + c[i] * d[i];";
/// let machine = MachineModel::snb();
/// let consts = [("N".to_string(), 10_000_000i64)].into_iter().collect();
/// let out = kerncraft::analyze(src, &consts, &machine).unwrap();
/// assert!(out.ecm.t_mem() > 0.0);
/// ```
pub fn analyze(
    source: &str,
    constants: &HashMap<String, i64>,
    machine: &machine::MachineModel,
) -> Result<AnalysisOutput> {
    let program = kernel::parse(source)?;
    let analysis = kernel::KernelAnalysis::from_program(&program, constants)?;
    let incore = incore::PortModel::analyze(&analysis, machine, &incore::CodegenPolicy::for_machine(machine))?;
    let traffic = cache::CachePredictor::new(machine).predict(&analysis)?;
    let ecm = models::EcmModel::build(&incore, &traffic, machine)?;
    let roofline = models::RooflineModel::build(&analysis, &traffic, machine, Some(&incore))?;
    Ok(AnalysisOutput { analysis, incore, traffic, ecm, roofline })
}

/// Bundled result of [`analyze`]: every intermediate product is exposed so
/// callers (CLI, benches, examples) can drill into any stage.
pub struct AnalysisOutput {
    /// Static analysis of the kernel source (loop stack, accesses, flops).
    pub analysis: kernel::KernelAnalysis,
    /// In-core port-model prediction (IACA substitute).
    pub incore: incore::PortModel,
    /// Per-level data traffic prediction.
    pub traffic: cache::TrafficPrediction,
    /// Execution-Cache-Memory model.
    pub ecm: models::EcmModel,
    /// Roofline model (port-model in-core variant).
    pub roofline: models::RooflineModel,
}
