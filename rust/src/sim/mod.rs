//! Trace-driven virtual testbed — the stand-in for running the kernel on
//! the paper's Sandy Bridge / Haswell machines (DESIGN.md §1 documents
//! the measurement-substitution strategy, how the knobs below were
//! calibrated against the paper's Tables 1 and 5, and the fast-engine
//! design: compressed line-interval traces, set-sharded simulation, and
//! convergence skip-ahead).
//!
//! Front doors: `-p Benchmark --bench-path virtual` measures alone;
//! `-p Validate` ([`crate::session::ModelKind::Validate`]) runs the
//! testbed next to the analytic ECM prediction and reports both plus the
//! relative model error — the paper's model-vs-measurement loop.
//!
//! Where the analytic predictor (`cache::CachePredictor`) reasons about a
//! steady-state unit of work, this module *executes* the kernel's memory
//! trace against a set-associative, inclusive, write-allocate/write-back
//! LRU cache hierarchy configured from the same machine file, and charges
//! cycles with an ECM-style composition rule per unit of work:
//!
//! `T_unit = max(T_OL, T_nOL + Σ_links lines·cy/CL + latency penalties)`
//!
//! Cold caches, loop boundaries (pipeline restart at each inner-loop
//! entry), and imperfect prefetching on non-sequential misses are
//! modeled, so short loops deviate from the analytic model exactly the
//! way the paper's Fig. 4 measurements do.
//!
//! Two engines execute that trace behind one API ([`SimEngine`]):
//!
//! * [`reference`] replays every memory reference of every iteration
//!   through the hierarchy — simple, slow, the ground truth.
//! * [`fast`] compresses each access term's trace into cache-line
//!   intervals (one real access per line, elided repeats accounted as
//!   guaranteed L1 hits), optionally shards the stream by set index
//!   across workers, and extrapolates once per-row hit/miss
//!   fingerprints repeat. Per-level hit/miss/writeback counts are
//!   *identical* to the reference engine (the `sim_equiv` suite pins
//!   this); cy/CL agrees to float-summation-order noise, or to the
//!   documented skip-ahead bound when extrapolation is on.
//!
//! For large problems the outer iteration space is truncated after the
//! working set has cycled several times — the reported cy/CL is the
//! steady-state mean over the simulated window.

use crate::incore::{CodegenPolicy, PortModel};
use crate::kernel::KernelAnalysis;
use crate::machine::MachineModel;
use anyhow::{bail, Result};

pub mod fast;
pub mod reference;
mod trace;

/// Which simulation engine a [`VirtualTestbed`] run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SimEngine {
    /// Compressed-trace engine (DESIGN.md §1): line-interval streams,
    /// optional set sharding, convergence skip-ahead. The default.
    #[default]
    Fast,
    /// Per-access replay of every memory reference — the original
    /// implementation, kept as the equivalence baseline.
    Reference,
}

impl SimEngine {
    /// Canonical spelling (CLI flag value, metrics label, wire field).
    pub fn name(self) -> &'static str {
        match self {
            SimEngine::Fast => "fast",
            SimEngine::Reference => "reference",
        }
    }

    /// Parse a canonical spelling.
    pub fn parse(s: &str) -> Option<SimEngine> {
        match s {
            "fast" => Some(SimEngine::Fast),
            "reference" => Some(SimEngine::Reference),
            _ => None,
        }
    }

    /// Stable index for per-engine counters.
    pub fn ix(self) -> usize {
        match self {
            SimEngine::Fast => 0,
            SimEngine::Reference => 1,
        }
    }

    /// Every engine, in counter-index order.
    pub const ALL: [SimEngine; 2] = [SimEngine::Fast, SimEngine::Reference];
}

/// One set-associative LRU cache level.
///
/// Ages are a 64-bit logical clock (higher = more recent). They were
/// `u32` with `wrapping_add` once: after 2³² accesses the clock wrapped
/// and freshly-touched lines compared *older* than stale ones, silently
/// inverting the recency order — long Validate runs evicted their hot
/// set. 64 bits cannot wrap in any feasible run (5 GHz × 100 years
/// < 2⁶⁴); the regression test below pins the old failure point.
pub(crate) struct CacheLevel {
    pub(crate) sets: usize,
    pub(crate) ways: usize,
    /// tags\[set\]\[way\] — line address + 1 (0 = empty way).
    pub(crate) tags: Vec<u64>,
    /// LRU age per way (higher = more recent).
    pub(crate) ages: Vec<u64>,
    pub(crate) dirty: Vec<bool>,
    pub(crate) clock: u64,
    // statistics
    pub(crate) hits: u64,
    pub(crate) misses: u64,
    pub(crate) writebacks: u64,
}

impl CacheLevel {
    pub(crate) fn new(size_bytes: u64, ways: u32, line_size: u64) -> CacheLevel {
        let lines = (size_bytes / line_size).max(1);
        let ways = (ways as u64).min(lines).max(1) as usize;
        let sets = (lines as usize / ways).max(1);
        CacheLevel::with_sets(sets, ways)
    }

    /// Level with an explicit geometry (the sharded fast engine carves
    /// a level into `sets/K` subsets per worker).
    pub(crate) fn with_sets(sets: usize, ways: usize) -> CacheLevel {
        CacheLevel {
            sets,
            ways,
            tags: vec![0; sets * ways],
            ages: vec![0; sets * ways],
            dirty: vec![false; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    /// Access a line address; returns (hit, evicted_dirty_line).
    pub(crate) fn access(&mut self, line: u64, write: bool) -> (bool, Option<u64>) {
        let set = (line as usize) % self.sets;
        self.clock += 1;
        let age = self.clock;
        self.access_in_set(set, line, write, age)
    }

    /// [`CacheLevel::access`] with the set index and LRU age supplied by
    /// the caller (the fast engine maps lines to shard-local sets and
    /// stamps L1 ages with the *global* access index, so elided touches
    /// can be aged lazily).
    pub(crate) fn access_in_set(
        &mut self,
        set: usize,
        line: u64,
        write: bool,
        age: u64,
    ) -> (bool, Option<u64>) {
        // store line+1 so 0 marks an empty way
        let key = line + 1;
        let base = set * self.ways;
        let mut lru_way = 0;
        let mut lru_age = u64::MAX;
        for w in 0..self.ways {
            let ix = base + w;
            if self.tags[ix] == key {
                self.hits += 1;
                self.ages[ix] = age;
                if write {
                    self.dirty[ix] = true;
                }
                return (true, None);
            }
            if self.tags[ix] == 0 {
                lru_way = w;
                lru_age = 0;
            } else if self.ages[ix] < lru_age {
                lru_age = self.ages[ix];
                lru_way = w;
            }
        }
        self.misses += 1;
        let ix = base + lru_way;
        let evicted = if self.tags[ix] != 0 && self.dirty[ix] {
            self.writebacks += 1;
            Some(self.tags[ix] - 1)
        } else {
            None
        };
        self.tags[ix] = key;
        self.ages[ix] = age;
        self.dirty[ix] = write;
        (false, evicted)
    }
}

/// Per-level statistics of a simulation run.
#[derive(Debug, Clone)]
pub struct LevelStats {
    pub level: String,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

/// Result of a virtual-testbed run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total simulated cycles.
    pub cycles: f64,
    /// Cycles per cache line of work (the Table 5 "Bench." unit).
    pub cy_per_cl: f64,
    /// Simulated inner iterations.
    pub iterations: u64,
    /// Whether the iteration space was truncated for tractability.
    pub truncated: bool,
    pub levels: Vec<LevelStats>,
    /// In-core times used (cy per CL of work).
    pub t_ol: f64,
    pub t_nol: f64,
    /// Logical memory touches accounted (iterations × references per
    /// iteration, extrapolated touches included) — the unit the
    /// `kerncraft_sim_touches_total` metric and `sim_perf` bench count.
    pub touches: u64,
    /// Engine that produced this result.
    pub engine: SimEngine,
    /// Whether convergence skip-ahead extrapolated part of the window
    /// (fast engine only; implies the documented cy/CL error bound).
    pub extrapolated: bool,
}

impl SimResult {
    /// Measured performance in It/s at the given clock.
    pub fn iterations_per_second(&self, clock_hz: f64) -> f64 {
        self.iterations as f64 / (self.cycles / clock_hz)
    }
}

/// The virtual testbed.
pub struct VirtualTestbed<'m> {
    machine: &'m MachineModel,
    /// Hard cap on simulated inner iterations (after warm-up estimation).
    pub max_iterations: u64,
    /// Pipeline restart penalty charged at every inner-loop entry.
    pub loop_start_penalty: f64,
    /// Extra latency charged for a miss that the streaming prefetcher
    /// did not anticipate (fraction of the serving level's latency).
    pub prefetch_miss_factor: f64,
    /// Engine selection (default [`SimEngine::Fast`]).
    pub engine: SimEngine,
    /// Convergence skip-ahead (fast engine only): extrapolate once the
    /// per-row fingerprint repeats. Turn off for bit-exact statistics.
    pub skip_ahead: bool,
    /// Set-shard worker count for the fast engine: 0 = auto (available
    /// parallelism, clamped to what divides every level's set count).
    pub shards: usize,
}

impl<'m> VirtualTestbed<'m> {
    /// Testbed with default knobs.
    pub fn new(machine: &'m MachineModel) -> Self {
        VirtualTestbed {
            machine,
            max_iterations: 4_000_000,
            loop_start_penalty: 25.0,
            prefetch_miss_factor: 0.6,
            engine: SimEngine::Fast,
            skip_ahead: true,
            shards: 0,
        }
    }

    /// Select the engine (builder style).
    pub fn with_engine(mut self, engine: SimEngine) -> Self {
        self.engine = engine;
        self
    }

    /// Run the kernel on the virtual testbed.
    pub fn run(&self, analysis: &KernelAnalysis) -> Result<SimResult> {
        let policy = CodegenPolicy::for_machine(self.machine);
        let pm = PortModel::analyze(analysis, self.machine, &policy)?;
        self.run_with_incore(analysis, &pm)
    }

    /// Run with a pre-computed in-core model.
    pub fn run_with_incore(
        &self,
        analysis: &KernelAnalysis,
        pm: &PortModel,
    ) -> Result<SimResult> {
        let setup = SimSetup::build(self, analysis, pm)?;
        match self.engine {
            SimEngine::Reference => reference::run(self, analysis, &setup),
            SimEngine::Fast => fast::run(self, analysis, &setup),
        }
    }
}

/// Everything both engines derive from (machine, kernel, in-core model)
/// before executing the trace: hierarchy geometry, link costs, array
/// layout, iteration bounds (with the outermost dimension truncated for
/// tractability), and the per-unit in-core times.
pub(crate) struct SimSetup {
    /// (size-derived sets, ways) per level, innermost first.
    pub(crate) geometry: Vec<(usize, usize)>,
    /// Human level names (for [`LevelStats`]).
    pub(crate) level_names: Vec<String>,
    /// Cycles per cache line crossing each link, innermost first.
    pub(crate) link_cpc: Vec<f64>,
    /// Latency of the level that serves a miss at each level.
    pub(crate) link_lat: Vec<f64>,
    /// Byte base offset per array (analytic predictor's placement rule).
    pub(crate) bases: Vec<i64>,
    pub(crate) elem_sizes: Vec<i64>,
    pub(crate) cl: u64,
    /// Loop trip counts with the outermost already truncated.
    pub(crate) trips: Vec<u64>,
    /// Truncated outermost end bound (reference engine walks to here).
    pub(crate) outer_end: i64,
    pub(crate) truncated: bool,
    /// Total simulated inner iterations (product of `trips`).
    pub(crate) total: u64,
    pub(crate) unit_iters: u64,
    pub(crate) t_ol: f64,
    pub(crate) t_nol: f64,
}

impl SimSetup {
    pub(crate) fn build(
        tb: &VirtualTestbed,
        analysis: &KernelAnalysis,
        pm: &PortModel,
    ) -> Result<SimSetup> {
        let machine = tb.machine;
        let cl = machine.cacheline_bytes;
        if analysis.loops.is_empty() {
            bail!("kernel has no loops");
        }
        let mut geometry = Vec::new();
        let mut level_names = Vec::new();
        let mut link_cpc = Vec::new();
        let mut link_lat = Vec::new();
        let cache_levels = machine.cache_levels();
        for lvl in &cache_levels {
            let Some(size) = lvl.size_bytes else {
                bail!("cache level {} lacks a size", lvl.name)
            };
            let probe = CacheLevel::new(size, lvl.ways, cl);
            geometry.push((probe.sets, probe.ways));
            level_names.push(lvl.name.clone());
            let cpc = match lvl.cycles_per_cacheline {
                Some(c) => c,
                None => {
                    // memory link: saturated bandwidth of the copy kernel
                    let bw = machine
                        .benchmarks
                        .saturated_bandwidth("MEM", "copy")
                        .unwrap_or(20e9);
                    cl as f64 / bw * machine.clock_hz
                }
            };
            link_cpc.push(cpc);
        }
        for (ix, lvl) in cache_levels.iter().enumerate() {
            // latency of the level that serves a miss at this level
            let next = machine
                .memory_hierarchy
                .get(ix + 1)
                .map(|l| l.latency)
                .unwrap_or(lvl.latency * 4.0);
            link_lat.push(next);
        }

        // array layout (same placement rule as the analytic predictor)
        let layout = crate::cache::ArrayLayout::new(analysis, cl);
        let bases: Vec<i64> =
            (0..analysis.arrays.len()).map(|a| layout.base_of(a)).collect();
        let elem_sizes: Vec<i64> =
            analysis.arrays.iter().map(|a| a.ty.size() as i64).collect();

        // iteration bounds, possibly truncated in the OUTERMOST dimension
        let trips_full: Vec<i64> =
            analysis.loops.iter().map(|l| l.trip().max(0)).collect();
        if let Some(l) = analysis.loops.iter().find(|l| l.trip() <= 0) {
            // an empty space would otherwise clamp(1, 0) below (panic) and
            // then issue out-of-bounds accesses for the phantom iteration
            bail!(
                "empty iteration space: loop '{}' runs {}..{} (step {}) — nothing to simulate",
                l.index,
                l.start,
                l.end,
                l.step
            );
        }
        // saturating product: gigantic nests only need to compare > cap
        let total_full: u64 = trips_full
            .iter()
            .fold(1u64, |acc, t| acc.saturating_mul(*t as u64));
        let mut outer_trip = trips_full[0] as u64;
        let mut truncated = false;
        if analysis.loops.len() > 1 {
            if total_full > tb.max_iterations {
                let inner_total: u64 = trips_full[1..]
                    .iter()
                    .fold(1u64, |acc, t| acc.saturating_mul(*t as u64))
                    .max(1);
                outer_trip =
                    (tb.max_iterations / inner_total).clamp(1, trips_full[0] as u64);
                truncated = outer_trip < trips_full[0] as u64;
            }
        } else if total_full > tb.max_iterations {
            outer_trip = tb.max_iterations;
            truncated = true;
        }
        let mut trips: Vec<u64> = trips_full.iter().map(|&t| t as u64).collect();
        trips[0] = outer_trip;
        let total = trips.iter().product::<u64>();
        let outer_end =
            analysis.loops[0].start + outer_trip as i64 * analysis.loops[0].step;

        Ok(SimSetup {
            geometry,
            level_names,
            link_cpc,
            link_lat,
            bases,
            elem_sizes,
            cl,
            trips,
            outer_end,
            truncated,
            total,
            unit_iters: analysis.unit_of_work(cl).max(1),
            t_ol: pm.t_ol,
            t_nol: pm.t_nol,
        })
    }

    /// Fresh full-geometry hierarchy (reference engine / single shard).
    pub(crate) fn hierarchy(&self) -> Vec<CacheLevel> {
        self.geometry
            .iter()
            .map(|&(sets, ways)| CacheLevel::with_sets(sets, ways))
            .collect()
    }

    /// Package per-level counters into the public result.
    pub(crate) fn level_stats(&self, levels: &[CacheLevel]) -> Vec<LevelStats> {
        self.level_names
            .iter()
            .zip(levels)
            .map(|(name, l)| LevelStats {
                level: name.clone(),
                hits: l.hits,
                misses: l.misses,
                writebacks: l.writebacks,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::parse;
    use std::collections::HashMap;

    fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn analyze(src: &str, c: &[(&str, i64)]) -> KernelAnalysis {
        let p = parse(src).unwrap();
        KernelAnalysis::from_program(&p, &consts(c)).unwrap()
    }

    #[test]
    fn cache_level_lru_behaviour() {
        // 2 sets × 2 ways of 64 B lines = 256 B cache
        let mut c = CacheLevel::new(256, 2, 64);
        assert_eq!(c.sets, 2);
        // fill set 0 (even lines)
        assert!(!c.access(0, false).0);
        assert!(!c.access(2, false).0);
        assert!(c.access(0, false).0, "0 still resident");
        // third distinct even line evicts LRU (line 2)
        assert!(!c.access(4, false).0);
        assert!(c.access(0, false).0, "0 was MRU, stays");
        assert!(!c.access(2, false).0, "2 was evicted");
    }

    #[test]
    fn dirty_eviction_produces_writeback() {
        let mut c = CacheLevel::new(128, 1, 64); // 2 sets × 1 way
        c.access(0, true); // dirty
        let (_, ev) = c.access(2, false); // same set, evicts line 0
        assert_eq!(ev, Some(0));
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn lru_clock_survives_the_u32_wrap_point() {
        // Regression: with a u32 clock and `wrapping_add`, the access
        // after 2³² wrapped the clock to 0 and the freshest line became
        // the eviction victim. Start the 64-bit clock just below the old
        // wrap point and cross it: recency order must be preserved.
        let mut c = CacheLevel::new(256, 2, 64); // 2 sets × 2 ways
        c.clock = u64::from(u32::MAX) - 1;
        assert!(!c.access(0, false).0); // age = 2³²−1
        assert!(!c.access(2, false).0); // age = 2³² (u32 would wrap to 0)
        assert!(c.clock > u64::from(u32::MAX), "clock crossed 2³²");
        // set 0 is full; a third line must evict line 0 (the older one),
        // not line 2 — under the wrapped u32 clock line 2's age read as
        // 0 and it was evicted instead.
        assert!(!c.access(4, false).0);
        assert!(c.access(2, false).0, "freshly-touched line survived the wrap");
        assert!(!c.access(0, false).0, "the genuinely old line was the victim");
    }

    #[test]
    fn triad_steady_state_matches_ecm() {
        // For the pure streaming triad the virtual testbed must land close
        // to the analytic ECM in-memory prediction (≈47.9 cy/CL on SNB).
        let m = MachineModel::snb();
        let a = analyze(
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];",
            &[("N", 2_000_000)],
        );
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        assert!(
            (sim.cy_per_cl - 47.9).abs() / 47.9 < 0.15,
            "sim {} vs ECM 47.9",
            sim.cy_per_cl
        );
    }

    #[test]
    fn jacobi_bench_close_to_paper_measurement() {
        // Paper Table 5: measured 36.4 cy/CL on SNB (model 36.7).
        let m = MachineModel::snb();
        let a = analyze(
            crate::models::reference::KERNEL_2D5PT,
            &[("N", 6000), ("M", 6000)],
        );
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        assert!(
            (sim.cy_per_cl - 36.4).abs() / 36.4 < 0.2,
            "sim {} vs paper bench 36.4",
            sim.cy_per_cl
        );
    }

    #[test]
    fn simulated_traffic_matches_analytic_steady_state() {
        // jacobi: the analytic model predicts 5 CL crossing the L1 link
        // per unit of work (3 read rows + write-allocate + evict).
        let m = MachineModel::snb();
        let a = analyze(
            crate::models::reference::KERNEL_2D5PT,
            &[("N", 6000), ("M", 6000)],
        );
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        let units = sim.iterations as f64 / 8.0;
        let l1 = &sim.levels[0];
        let lines_per_unit = (l1.misses + l1.writebacks) as f64 / units;
        assert!(
            (lines_per_unit - 5.0).abs() < 0.5,
            "L1 link lines/unit = {lines_per_unit}"
        );
    }

    #[test]
    fn truncation_engages_for_huge_spaces() {
        let m = MachineModel::snb();
        let a = analyze(
            crate::models::reference::KERNEL_2D5PT,
            &[("N", 4000), ("M", 100000)],
        );
        let tb = VirtualTestbed::new(&m);
        let sim = tb.run(&a).unwrap();
        assert!(sim.truncated);
        assert!(sim.iterations <= tb.max_iterations + 4000 * 8);
    }

    #[test]
    fn empty_iteration_space_is_a_clean_error() {
        // M=2 leaves the outer loop with zero trips; this used to reach a
        // clamp(1, 0) panic in the truncation path and then simulate a
        // phantom out-of-bounds iteration.
        let m = MachineModel::snb();
        let a = analyze(crate::models::reference::KERNEL_2D5PT, &[("N", 100), ("M", 2)]);
        let err = VirtualTestbed::new(&m).run(&a).unwrap_err();
        assert!(format!("{err}").contains("empty iteration space"), "{err}");
    }

    #[test]
    fn small_n_exceeds_steady_state_model() {
        // Fig 4: for very short inner loops the measurement lies above the
        // analytic prediction (boundary effects dominate).
        let m = MachineModel::snb();
        let small = analyze(
            crate::models::reference::KERNEL_LONG_RANGE,
            &[("N", 20), ("M", 20)],
        );
        let big = analyze(
            crate::models::reference::KERNEL_LONG_RANGE,
            &[("N", 400), ("M", 400)],
        );
        let tb = VirtualTestbed::new(&m);
        let s_small = tb.run(&small).unwrap();
        let s_big = tb.run(&big).unwrap();
        // per-CL cost at tiny N must exceed the large-N steady state
        assert!(
            s_small.cy_per_cl > s_big.cy_per_cl,
            "small {} vs big {}",
            s_small.cy_per_cl,
            s_big.cy_per_cl
        );
    }

    #[test]
    fn hits_grow_with_cache_friendliness() {
        let m = MachineModel::snb();
        // N small enough for the L1 layer condition
        let friendly = analyze(crate::models::reference::KERNEL_2D5PT, &[("N", 200), ("M", 4000)]);
        let hostile = analyze(crate::models::reference::KERNEL_2D5PT, &[("N", 6000), ("M", 140)]);
        let tb = VirtualTestbed::new(&m);
        let f = tb.run(&friendly).unwrap();
        let h = tb.run(&hostile).unwrap();
        let f_l1_rate = f.levels[0].hits as f64 / (f.levels[0].hits + f.levels[0].misses) as f64;
        let h_l1_rate = h.levels[0].hits as f64 / (h.levels[0].hits + h.levels[0].misses) as f64;
        assert!(f_l1_rate > h_l1_rate, "{f_l1_rate} vs {h_l1_rate}");
    }

    #[test]
    fn kahan_is_core_bound_in_sim_too() {
        let m = MachineModel::snb();
        let a = analyze(crate::models::reference::KERNEL_KAHAN, &[("N", 2_000_000)]);
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        // paper bench: 101.1 cy/CL (model 96): core-bound, so the sim must
        // land at T_OL (96) ± small memory effects
        assert!((sim.cy_per_cl - 96.0).abs() / 96.0 < 0.12, "sim {}", sim.cy_per_cl);
    }

    #[test]
    fn engine_parse_round_trips() {
        for e in SimEngine::ALL {
            assert_eq!(SimEngine::parse(e.name()), Some(e));
        }
        assert_eq!(SimEngine::parse("warp"), None);
        assert_eq!(SimEngine::default(), SimEngine::Fast);
    }
}
