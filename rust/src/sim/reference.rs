//! The reference engine: replay every memory reference of every
//! iteration through the LRU hierarchy, one `access` per reference.
//!
//! This is the original (pre-compression) implementation, kept verbatim
//! as the ground truth the [`super::fast`] engine is proven against:
//! `rust/tests/sim_equiv.rs` pins per-level hit/miss/writeback counts of
//! both engines equal on the paper kernels and on randomized stencils.
//! Select it with `--sim-engine reference` or
//! [`super::SimEngine::Reference`].

use super::{SimEngine, SimResult, SimSetup, VirtualTestbed};
use crate::kernel::KernelAnalysis;
use anyhow::Result;

pub(crate) fn run(
    tb: &VirtualTestbed,
    analysis: &KernelAnalysis,
    setup: &SimSetup,
) -> Result<SimResult> {
    let cl = setup.cl;
    let mut levels = setup.hierarchy();

    // prefetcher model: per-array rolling lists of the lines touched
    // in the current and previous unit of work — a miss whose
    // predecessor line appears there is stream-prefetched (bandwidth
    // only). Small Vecs beat hash sets here: ≤ a few dozen entries,
    // scanned linearly (§Perf iteration 2).
    let mut cur_lines: Vec<Vec<i64>> = vec![Vec::new(); analysis.arrays.len()];
    let mut prev_lines: Vec<Vec<i64>> = vec![Vec::new(); analysis.arrays.len()];

    let unit_iters = setup.unit_iters;
    let t_ol = setup.t_ol;
    let t_nol = setup.t_nol;
    // in-core time per iteration
    let ol_per_iter = t_ol / unit_iters as f64;
    let nol_per_iter = t_nol / unit_iters as f64;

    let mut cycles = 0f64;
    let mut iterations: u64 = 0;
    // per-unit accumulators
    let mut unit_count = 0u64;
    let mut unit_link_lines = vec![0u64; levels.len()];
    let mut unit_penalty = 0f64;

    let n_loops = analysis.loops.len();
    let mut idx: Vec<i64> = analysis.loops.iter().map(|l| l.start).collect();
    // outermost bound already adjusted for truncation
    let outer_end = setup.outer_end;

    'outer: loop {
        // --- one inner iteration: issue all accesses ---
        for acc in analysis.reads.iter() {
            let a = acc.array;
            let off =
                acc.offset + acc.coeffs.iter().zip(&idx).map(|(c, p)| c * p).sum::<i64>();
            let byte = setup.bases[a] + off * setup.elem_sizes[a];
            let line = byte.div_euclid(cl as i64) as u64;
            touch(
                tb,
                setup,
                &mut levels,
                line,
                false,
                a,
                &mut cur_lines,
                &prev_lines,
                &mut unit_link_lines,
                &mut unit_penalty,
            );
        }
        for acc in analysis.writes.iter() {
            let a = acc.array;
            let off =
                acc.offset + acc.coeffs.iter().zip(&idx).map(|(c, p)| c * p).sum::<i64>();
            let byte = setup.bases[a] + off * setup.elem_sizes[a];
            let line = byte.div_euclid(cl as i64) as u64;
            touch(
                tb,
                setup,
                &mut levels,
                line,
                true,
                a,
                &mut cur_lines,
                &prev_lines,
                &mut unit_link_lines,
                &mut unit_penalty,
            );
        }
        iterations += 1;
        unit_count += 1;

        // close a unit of work: ECM composition
        if unit_count == unit_iters {
            let mut data: f64 = 0.0;
            for (k, lines) in unit_link_lines.iter().enumerate() {
                data += *lines as f64 * setup.link_cpc[k];
            }
            let t_unit = (ol_per_iter * unit_count as f64)
                .max(nol_per_iter * unit_count as f64 + data + unit_penalty);
            cycles += t_unit;
            unit_count = 0;
            unit_link_lines.iter_mut().for_each(|x| *x = 0);
            unit_penalty = 0.0;
            for (cur, prev) in cur_lines.iter_mut().zip(prev_lines.iter_mut()) {
                std::mem::swap(cur, prev);
                cur.clear();
            }
        }

        // --- advance the loop nest ---
        let mut k = n_loops - 1;
        loop {
            idx[k] += analysis.loops[k].step;
            let end = if k == 0 { outer_end } else { analysis.loops[k].end };
            if idx[k] < end {
                if k != n_loops - 1 {
                    // entering a fresh inner loop: pipeline restart
                    unit_penalty += tb.loop_start_penalty;
                }
                break;
            }
            if k == 0 {
                break 'outer;
            }
            idx[k] = analysis.loops[k].start;
            k -= 1;
        }
    }
    // flush the trailing partial unit
    if unit_count > 0 {
        let mut data: f64 = 0.0;
        for (k, lines) in unit_link_lines.iter().enumerate() {
            data += *lines as f64 * setup.link_cpc[k];
        }
        cycles += (ol_per_iter * unit_count as f64)
            .max(nol_per_iter * unit_count as f64 + data + unit_penalty);
    }

    let refs_per_iter = (analysis.reads.len() + analysis.writes.len()) as u64;
    let units = iterations as f64 / unit_iters as f64;
    Ok(SimResult {
        cycles,
        cy_per_cl: cycles / units,
        iterations,
        truncated: setup.truncated,
        levels: setup.level_stats(&levels),
        t_ol,
        t_nol,
        touches: iterations * refs_per_iter,
        engine: SimEngine::Reference,
        extrapolated: false,
    })
}

/// Issue one line access through the hierarchy, updating traffic and
/// penalty accumulators. Dirty evictions propagate inclusively: an
/// eviction from level k marks (or installs) the line dirty in level
/// k+1 and counts one write-back crossing that link.
#[allow(clippy::too_many_arguments)]
fn touch(
    tb: &VirtualTestbed,
    setup: &SimSetup,
    levels: &mut [super::CacheLevel],
    line: u64,
    write: bool,
    array: usize,
    cur_lines: &mut [Vec<i64>],
    prev_lines: &[Vec<i64>],
    unit_link_lines: &mut [u64],
    unit_penalty: &mut f64,
) {
    // sequential-stream detection: predecessor (or same) line seen in
    // this or the previous unit of work
    let sline = line as i64;
    let hit_list = |v: &[i64]| v.iter().any(|&h| h == sline || h == sline - 1);
    let sequential = hit_list(&cur_lines[array]) || hit_list(&prev_lines[array]);
    if !cur_lines[array].contains(&sline) {
        cur_lines[array].push(sline);
    }

    let n = levels.len();
    let mut depth = 0usize;
    for k in 0..n {
        let (hit, evicted) = levels[k].access(line, write && k == 0);
        if let Some(dirty_line) = evicted {
            // write-back: crosses the link below level k, then marks
            // the line dirty further out (installing it if the
            // hierarchy drifted from strict inclusion)
            unit_link_lines[k] += 1;
            let mut wb = dirty_line;
            for kk in k + 1..n {
                let (hit_wb, ev2) = levels[kk].access(wb, true);
                if let Some(d2) = ev2 {
                    unit_link_lines[kk] += 1;
                    if hit_wb {
                        break;
                    }
                    wb = d2;
                    continue;
                }
                break;
            }
        }
        if hit {
            break;
        }
        // miss: the fill crosses this link
        unit_link_lines[k] += 1;
        depth = k + 1;
    }
    // latency penalty for non-sequential (unprefetched) misses
    if depth > 0 && !sequential {
        let lat = setup.link_lat[depth - 1];
        *unit_penalty += lat * tb.prefetch_miss_factor;
    }
}
