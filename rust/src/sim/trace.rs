//! Compressed line-interval trace generation for the fast engine
//! (DESIGN.md §1).
//!
//! Every access expression of the kernel body is an affine *term*
//! `addr(digits) = base + Σ strides[k]·digit[k]`. Along the innermost
//! loop the byte stride is constant, so the per-iteration access stream
//! of a term decomposes into maximal runs of consecutive iterations that
//! touch the *same* cache line. The generator emits one [`Event`] per
//! run — O(lines touched) instead of O(references) — and the fast engine
//! accounts the elided repeats as guaranteed L1 hits.
//!
//! The generator also owns the *sequential-stream* state (the per-array
//! current/previous-unit line lists the reference engine keeps): that
//! detection is inherently serial, so the flags are precomputed here,
//! on the compressed stream, before events are sharded across workers.

use super::SimSetup;
use crate::kernel::KernelAnalysis;

/// One affine access term (one entry of `reads` ++ `writes`).
pub(crate) struct Term {
    pub array: usize,
    pub write: bool,
    /// Byte address at the all-zeros digit vector.
    pub base: i64,
    /// Byte stride per unit increment of each loop digit, outer→inner.
    pub strides: Vec<i64>,
}

/// A maximal run of consecutive inner iterations `[i_start, i_end)` in
/// which one term touches one cache line every iteration.
#[derive(Clone, Copy, Debug)]
pub(crate) struct Event {
    /// Global access index of the first touch: `i_start·P + p` — the
    /// total order both engines issue references in (iteration-major,
    /// term-minor), and exactly the reference engine's L1 clock minus 1.
    pub g: u64,
    pub line: u64,
    /// Index into [`Trace::terms`] (== the term's position `p`).
    pub term: u32,
    pub i_start: u64,
    pub i_end: u64,
    /// Sequential-stream flag of the first touch (elided repeats are L1
    /// hits and never consult it; materialized replays are provably
    /// sequential — see `fast.rs`).
    pub seq: bool,
}

/// Iterations per 1-D chunk, in units of work (multi-dim kernels row on
/// the innermost trip instead). Must stay a multiple of the unit so
/// chunk boundaries never split a unit-phase period.
const CHUNK_UNITS: u64 = 8192;

pub(crate) struct Trace {
    pub terms: Vec<Term>,
    /// Terms per iteration (`terms.len()` as u64).
    pub p: u64,
    pub trips: Vec<u64>,
    pub total: u64,
    /// Iterations per row: the innermost trip count, or the 1-D chunk.
    /// A *row* is both the event-generation granule and the fingerprint
    /// unit of convergence skip-ahead.
    pub row_len: u64,
    /// Total rows (`ceil(total / row_len)`; only a trailing 1-D chunk
    /// can be partial).
    pub rows: u64,
    /// Rows per sweep of the second-innermost loop — skip-ahead never
    /// extrapolates across this boundary (outer-loop wrap rows are not
    /// part of the detected steady state). For nests of depth ≤ 2 the
    /// whole space is one plane.
    pub rows_per_plane: u64,
    unit_iters: u64,
    cl: i64,
    // --- serial sequential-detection state ---
    cur: Vec<Vec<i64>>,
    prev: Vec<Vec<i64>>,
    /// Global iteration index of the next unit-of-work boundary.
    next_boundary: u64,
    /// Scratch: (g, array, line) pushes at unit boundaries inside runs.
    carries: Vec<(u64, u32, i64)>,
}

impl Trace {
    pub(crate) fn new(analysis: &KernelAnalysis, setup: &SimSetup) -> Trace {
        let n = analysis.loops.len();
        let mut terms = Vec::new();
        for (write, accs) in [(false, &analysis.reads), (true, &analysis.writes)] {
            for acc in accs.iter() {
                let esz = setup.elem_sizes[acc.array];
                let mut base = setup.bases[acc.array] + acc.offset * esz;
                let mut strides = Vec::with_capacity(n);
                for (k, &c) in acc.coeffs.iter().enumerate() {
                    base += c * analysis.loops[k].start * esz;
                    strides.push(c * analysis.loops[k].step * esz);
                }
                terms.push(Term { array: acc.array, write, base, strides });
            }
        }
        let total = setup.total;
        let row_len = if n >= 2 {
            setup.trips[n - 1].max(1)
        } else {
            (CHUNK_UNITS * setup.unit_iters).min(total.max(1))
        };
        let rows = total.div_ceil(row_len);
        let rows_per_plane = if n >= 3 { setup.trips[n - 2].max(1) } else { rows };
        let p = terms.len() as u64;
        Trace {
            terms,
            p,
            trips: setup.trips.clone(),
            total,
            row_len,
            rows,
            rows_per_plane,
            unit_iters: setup.unit_iters,
            cl: setup.cl as i64,
            cur: vec![Vec::new(); analysis.arrays.len()],
            prev: vec![Vec::new(); analysis.arrays.len()],
            next_boundary: setup.unit_iters,
            carries: Vec::new(),
        }
    }

    /// Global iteration bounds `[start, end)` of one row.
    pub(crate) fn row_range(&self, row: u64) -> (u64, u64) {
        let start = row * self.row_len;
        (start, (start + self.row_len).min(self.total))
    }

    /// Generate the events of `[i0, i1)` (must lie inside one row) into
    /// `out`, sorted by `g`, with sequential flags resolved.
    ///
    /// Calls must walk the iteration space in order; after a skip-ahead
    /// jump, re-seed the sequential state with [`Trace::reseed`] first.
    pub(crate) fn gen_events(&mut self, i0: u64, i1: u64, out: &mut Vec<Event>) {
        out.clear();
        if i0 >= i1 || self.terms.is_empty() {
            return;
        }
        let n = self.trips.len();
        let cl = self.cl;
        let p_total = self.p;
        // inner-digit window and per-term line-sweep origin
        let (row_base, d_lo) = if n >= 2 {
            let row = i0 / self.row_len;
            (row * self.row_len, i0 % self.row_len)
        } else {
            (0, i0)
        };
        let d_hi = d_lo + (i1 - i0);
        debug_assert!(n == 1 || d_hi <= self.row_len);
        let mut outer = vec![0u64; n.saturating_sub(1)];
        if n >= 2 {
            let mut r = i0 / self.row_len;
            for k in (0..n - 1).rev() {
                outer[k] = r % self.trips[k];
                r /= self.trips[k];
            }
        }
        for (t, term) in self.terms.iter().enumerate() {
            let mut a0 = term.base;
            for (k, &d) in outer.iter().enumerate() {
                a0 += term.strides[k] * d as i64;
            }
            let s = term.strides[n - 1];
            let mut d = d_lo;
            while d < d_hi {
                let line = (a0 + s * d as i64).div_euclid(cl);
                // closed-form end of the run: last digit still on `line`
                let d_next = if s > 0 {
                    (((line + 1) * cl - 1 - a0).div_euclid(s) as u64 + 1).min(d_hi)
                } else if s < 0 {
                    ((a0 - line * cl).div_euclid(-s) as u64 + 1).min(d_hi)
                } else {
                    d_hi
                };
                let i_start = row_base + d;
                out.push(Event {
                    g: i_start * p_total + t as u64,
                    line: line as u64,
                    term: t as u32,
                    i_start,
                    i_end: row_base + d_next,
                    seq: false,
                });
                d = d_next;
            }
        }
        out.sort_unstable_by_key(|e| e.g);
        self.resolve_seq(out);
    }

    /// Walk the block's compressed stream in issue order, maintaining
    /// the current/previous-unit line lists exactly as the reference
    /// engine does (every elided repeat still *pushes* its line — the
    /// carries replay those pushes at each unit boundary inside a run).
    fn resolve_seq(&mut self, events: &mut [Event]) {
        let u = self.unit_iters;
        let p_total = self.p;
        self.carries.clear();
        for e in events.iter() {
            let mut m = (e.i_start / u + 1) * u;
            while m < e.i_end {
                self.carries
                    .push((m * p_total + e.term as u64, self.terms[e.term as usize].array as u32, e.line as i64));
                m += u;
            }
        }
        self.carries.sort_unstable_by_key(|c| c.0);
        let mut ci = 0;
        for e in events.iter_mut() {
            // drain carries issued before this event
            while ci < self.carries.len() && self.carries[ci].0 < e.g {
                let (g, arr, line) = self.carries[ci];
                self.cross_boundaries(g / p_total);
                push_absent(&mut self.cur[arr as usize], line);
                ci += 1;
            }
            self.cross_boundaries(e.i_start);
            let arr = self.terms[e.term as usize].array;
            let line = e.line as i64;
            let hit = |v: &[i64]| v.iter().any(|&h| h == line || h == line - 1);
            e.seq = hit(&self.cur[arr]) || hit(&self.prev[arr]);
            push_absent(&mut self.cur[arr], line);
        }
        while ci < self.carries.len() {
            let (g, arr, line) = self.carries[ci];
            self.cross_boundaries(g / p_total);
            push_absent(&mut self.cur[arr as usize], line);
            ci += 1;
        }
    }

    fn cross_boundaries(&mut self, i: u64) {
        while i >= self.next_boundary {
            for (cur, prev) in self.cur.iter_mut().zip(self.prev.iter_mut()) {
                std::mem::swap(cur, prev);
                cur.clear();
            }
            self.next_boundary += self.unit_iters;
        }
    }

    /// Rebuild the sequential-stream state for resuming at iteration
    /// `resume_i` (a row start), as if every earlier iteration had been
    /// walked: the lists only ever hold lines of the current and
    /// previous unit of work, so replaying those ≤ 2·unit iterations
    /// reconstructs them exactly.
    pub(crate) fn reseed(&mut self, resume_i: u64) {
        for v in self.cur.iter_mut().chain(self.prev.iter_mut()) {
            v.clear();
        }
        let u = self.unit_iters;
        let m0 = resume_i / u * u;
        if m0 > 0 {
            let prev = &mut self.prev;
            for i in m0 - u..m0 {
                lines_of(&self.terms, &self.trips, self.cl, i, |arr, line| {
                    push_absent(&mut prev[arr], line)
                });
            }
        }
        let cur = &mut self.cur;
        for i in m0..resume_i {
            lines_of(&self.terms, &self.trips, self.cl, i, |arr, line| {
                push_absent(&mut cur[arr], line)
            });
        }
        self.next_boundary = m0 + u;
    }
}

/// Enumerate (array, line) touched at global iteration `i`.
fn lines_of(terms: &[Term], trips: &[u64], cl: i64, i: u64, mut f: impl FnMut(usize, i64)) {
    let n = trips.len();
    let mut digits = vec![0u64; n];
    let mut r = i;
    for k in (0..n).rev() {
        digits[k] = r % trips[k];
        r /= trips[k];
    }
    for term in terms {
        let mut a = term.base;
        for (k, &d) in digits.iter().enumerate() {
            a += term.strides[k] * d as i64;
        }
        f(term.array, a.div_euclid(cl));
    }
}

fn push_absent(v: &mut Vec<i64>, line: i64) {
    if !v.contains(&line) {
        v.push(line);
    }
}
