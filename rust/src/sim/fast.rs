//! The fast engine (DESIGN.md §1): compressed line-interval traces,
//! set-sharded simulation, and convergence skip-ahead.
//!
//! Three layers, each preserving the reference engine's integer
//! statistics exactly unless noted:
//!
//! 1. **Trace compression** ([`super::trace`]): one [`Event`] per
//!    maximal run of consecutive iterations touching one line. The
//!    elided repeats are L1 hits *by construction* while the line stays
//!    resident, so they are credited optimistically at event time; if
//!    the line is evicted mid-run the credit is revoked from the
//!    eviction point and the first post-eviction touch is replayed as a
//!    real access (a *materialization*, scheduled on a min-heap in
//!    global access order). L1 LRU ages are the global access index —
//!    identical to the reference engine's L1 clock — and elided
//!    recency is folded in lazily: victim selection raises each
//!    candidate's recorded age to the last touch implied by any live
//!    run on its line.
//! 2. **Set sharding**: lines that map to the same cache set always
//!    share `line mod K` (K a power of two dividing every level's set
//!    count), so the event stream partitions into K fully independent
//!    sub-simulations, merged by summing counters. Per-unit penalty
//!    and traffic *counts* are merged before the serial cycle
//!    composition, so the composed cycles are bit-identical for every
//!    K.
//! 3. **Convergence skip-ahead**: per row (one innermost-loop run, or
//!    one aligned chunk of a 1-D loop) the engine fingerprints the
//!    per-level stat deltas and composed cycles. Once the last
//!    3·P_align rows form three identical periods (P_align rows
//!    realign the unit-of-work phase), the steady state is declared
//!    and the remaining rows of the current plane — minus a P_align
//!    tail — are extrapolated by exact integer multiplication of the
//!    period's stats and one f64 multiply of its cycles.
//!
//! **Error bound** (documented in DESIGN.md §1): with skip-ahead off
//! the engine is exact (integer stats identical, cycles equal up to
//! f64 summation-order ulps). With skip-ahead on, extrapolated rows
//! reproduce the detected steady state exactly; only the ≤ P_align
//! tail rows after each jump resume from a slightly stale cache image,
//! bounding the cy/CL deviation by (tail rows / total rows) of the
//! per-row cost — ≤ 0.5 % on the paper kernels (pinned by
//! `sim_equiv`).

use super::trace::{Event, Term, Trace};
use super::{CacheLevel, LevelStats, SimEngine, SimResult, SimSetup, VirtualTestbed};
use crate::kernel::KernelAnalysis;
use anyhow::Result;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Iteration cap per event-generation block (bounds event memory; runs
/// never span blocks, so each block's replay heap drains at its end).
const BLOCK_ITERS: u64 = 1 << 17;

/// One term's live line-run: the event was issued, the tail of the run
/// is credited as L1 hits, and the line's true recency is implied by
/// the run until it ends or the line is evicted.
#[derive(Clone, Copy, Default)]
struct Flight {
    line: u64,
    i_start: u64,
    i_end: u64,
    active: bool,
    /// Line still resident in this shard's L1 (maintained on eviction).
    resident: bool,
    write: bool,
}

/// Immutable per-block context shared by all shard workers.
struct Ctx<'a> {
    terms: &'a [Term],
    /// Terms per iteration.
    p: u64,
    /// Iterations per unit of work.
    u: u64,
}

struct ShardState {
    k: u64,
    levels: Vec<CacheLevel>,
    flights: Vec<Flight>,
    /// Scheduled materializations: (global access index, term).
    pending: BinaryHeap<Reverse<(u64, u32)>>,
    /// Per-row traffic window, flattened `[level][unit - u_lo]`: lines
    /// crossing each link, per unit of work.
    win_lines: Vec<u64>,
    /// Non-sequential misses served at depth `level+1`, per unit.
    win_nonseq: Vec<u64>,
    win_len: usize,
    u_lo: u64,
    /// Scratch: effective LRU ages during victim selection.
    eff: Vec<u64>,
}

impl ShardState {
    fn new(setup: &SimSetup, k: usize, n_terms: usize) -> ShardState {
        ShardState {
            k: k as u64,
            levels: setup
                .geometry
                .iter()
                .map(|&(sets, ways)| CacheLevel::with_sets(sets / k, ways))
                .collect(),
            flights: vec![Flight::default(); n_terms],
            pending: BinaryHeap::new(),
            win_lines: Vec::new(),
            win_nonseq: Vec::new(),
            win_len: 0,
            u_lo: 0,
            eff: Vec::new(),
        }
    }

    fn begin_row(&mut self, u_lo: u64, win_len: usize) {
        self.u_lo = u_lo;
        self.win_len = win_len;
        let n = self.levels.len() * win_len;
        self.win_lines.clear();
        self.win_lines.resize(n, 0);
        self.win_nonseq.clear();
        self.win_nonseq.resize(n, 0);
    }

    /// Shard-local set of a line: the full-geometry set index
    /// `line mod sets` factors as (shard, local set) when K divides
    /// the set count, so two lines collide in a shard's L1 iff they
    /// collide in the reference engine's.
    #[inline]
    fn local_set(&self, level: usize, line: u64) -> usize {
        ((line / self.k) % self.levels[level].sets as u64) as usize
    }

    /// Process one block's events (sorted by `g`), interleaving any
    /// scheduled materializations in global access order. Runs never
    /// span blocks, so the heap fully drains before returning.
    fn process(&mut self, events: &[Event], ctx: &Ctx) {
        for e in events {
            while let Some(&Reverse((g, t))) = self.pending.peek() {
                if g >= e.g {
                    break;
                }
                self.pending.pop();
                self.materialize(g, t as usize, ctx);
            }
            self.handle_event(e, ctx);
        }
        while let Some(Reverse((g, t))) = self.pending.pop() {
            self.materialize(g, t as usize, ctx);
        }
    }

    fn handle_event(&mut self, e: &Event, ctx: &Ctx) {
        let t = e.term as usize;
        // Settle the term's previous flight in this shard: its lazy
        // recency must survive the slot reuse, so raise the recorded
        // L1 age of its line (if still resident) to the run's last
        // implied touch.
        let old = self.flights[t];
        if old.active {
            if old.resident {
                let set = self.local_set(0, old.line);
                let base = set * self.levels[0].ways;
                let key = old.line + 1;
                let ia = (old.i_end - 1) * ctx.p + t as u64 + 1;
                for w in 0..self.levels[0].ways {
                    let ix = base + w;
                    if self.levels[0].tags[ix] == key {
                        if ia > self.levels[0].ages[ix] {
                            self.levels[0].ages[ix] = ia;
                        }
                        break;
                    }
                }
            }
            self.flights[t].active = false;
        }
        let write = ctx.terms[t].write;
        self.touch(e.line, write, e.g + 1, e.i_start, t as u64, e.seq, ctx);
        self.flights[t] = Flight {
            line: e.line,
            i_start: e.i_start,
            i_end: e.i_end,
            active: true,
            resident: true,
            write,
        };
        // optimistic credit: the run's remaining touches are L1 hits
        // while the line stays resident (revoked on eviction)
        self.levels[0].hits += e.i_end - e.i_start - 1;
    }

    /// Replay the first post-eviction touch of a run at its true
    /// position in the access order, then re-credit the tail.
    fn materialize(&mut self, g: u64, t: usize, ctx: &Ctx) {
        let fl = self.flights[t];
        debug_assert!(fl.active && !fl.resident);
        let i_m = (g - t as u64) / ctx.p;
        // Replays are always sequential: the same line was touched at
        // i_m − 1 (≥ i_start), so it sits in the current or previous
        // unit's line list — no prefetch penalty, ever.
        self.touch(fl.line, fl.write, g + 1, i_m, t as u64, true, ctx);
        self.flights[t].resident = true;
        self.levels[0].hits += fl.i_end - (i_m + 1);
    }

    /// One real access walk through the hierarchy — the reference
    /// engine's `touch`, with L1 handled manually (explicit global-
    /// index age, effective-age victim selection) and deeper levels on
    /// the shard-local clock.
    fn touch(
        &mut self,
        line: u64,
        write: bool,
        age: u64,
        i_now: u64,
        p_now: u64,
        seq: bool,
        ctx: &Ctx,
    ) {
        let n = self.levels.len();
        let wl = self.win_len;
        let uu = (i_now / ctx.u - self.u_lo) as usize;
        let set = self.local_set(0, line);
        let ways = self.levels[0].ways;
        let base = set * ways;
        let key = line + 1;
        for w in 0..ways {
            let ix = base + w;
            if self.levels[0].tags[ix] == key {
                self.levels[0].hits += 1;
                self.levels[0].ages[ix] = age;
                if write {
                    self.levels[0].dirty[ix] = true;
                }
                return;
            }
        }
        // L1 miss — victim by *effective* age: the recorded age raised
        // by the last touch implied by any live run on the way's line.
        self.eff.clear();
        for w in 0..ways {
            self.eff.push(self.levels[0].ages[base + w]);
        }
        for tt in 0..self.flights.len() {
            let fl = self.flights[tt];
            if !fl.active || !fl.resident || self.local_set(0, fl.line) != set {
                continue;
            }
            debug_assert!(i_now > 0 || (tt as u64) < p_now);
            let last_i =
                (if (tt as u64) < p_now { i_now } else { i_now - 1 }).min(fl.i_end - 1);
            let ia = last_i * ctx.p + tt as u64 + 1;
            let fkey = fl.line + 1;
            for w in 0..ways {
                if self.levels[0].tags[base + w] == fkey {
                    if ia > self.eff[w] {
                        self.eff[w] = ia;
                    }
                    break;
                }
            }
        }
        // same selection rule as the reference: last empty way, else
        // first strictly-minimal age
        let mut lru_way = 0usize;
        let mut lru_age = u64::MAX;
        for w in 0..ways {
            if self.levels[0].tags[base + w] == 0 {
                lru_way = w;
                lru_age = 0;
            } else if self.eff[w] < lru_age {
                lru_age = self.eff[w];
                lru_way = w;
            }
        }
        self.levels[0].misses += 1;
        let ix = base + lru_way;
        let victim_key = self.levels[0].tags[ix];
        let victim_dirty = self.levels[0].dirty[ix];
        self.levels[0].tags[ix] = key;
        self.levels[0].ages[ix] = age;
        self.levels[0].dirty[ix] = write;
        if victim_key != 0 {
            let victim = victim_key - 1;
            if victim_dirty {
                self.levels[0].writebacks += 1;
                self.win_lines[uu] += 1;
                self.writeback_chain(1, victim, uu);
            }
            self.evict_runs(victim, i_now, p_now, ctx);
        }
        // the fill crosses the L1 link; walk outward until a hit
        self.win_lines[uu] += 1;
        let mut depth = 1usize;
        for kk in 1..n {
            let lset = self.local_set(kk, line);
            let lvl = &mut self.levels[kk];
            lvl.clock += 1;
            let a = lvl.clock;
            let (hit, ev) = lvl.access_in_set(lset, line, false, a);
            if let Some(d) = ev {
                self.win_lines[kk * wl + uu] += 1;
                self.writeback_chain(kk + 1, d, uu);
            }
            if hit {
                break;
            }
            self.win_lines[kk * wl + uu] += 1;
            depth = kk + 1;
        }
        if !seq {
            self.win_nonseq[(depth - 1) * wl + uu] += 1;
        }
    }

    /// Dirty-eviction propagation from level `start` outward (the
    /// reference engine's write-back chain, verbatim).
    fn writeback_chain(&mut self, start: usize, mut wb: u64, uu: usize) {
        let n = self.levels.len();
        let wl = self.win_len;
        for kk in start..n {
            let set = self.local_set(kk, wb);
            let lvl = &mut self.levels[kk];
            lvl.clock += 1;
            let a = lvl.clock;
            let (hit_wb, ev2) = lvl.access_in_set(set, wb, true, a);
            if let Some(_d2) = ev2 {
                self.win_lines[kk * wl + uu] += 1;
                if hit_wb {
                    break;
                }
                wb = _d2;
                continue;
            }
            break;
        }
    }

    /// An L1 eviction invalidates the optimistic hit credit of every
    /// live run on the victim line from the next touch onward; that
    /// touch is rescheduled as a real access.
    fn evict_runs(&mut self, victim: u64, i_now: u64, p_now: u64, ctx: &Ctx) {
        for t in 0..self.flights.len() {
            let fl = self.flights[t];
            if !fl.active || !fl.resident || fl.line != victim {
                continue;
            }
            self.flights[t].resident = false;
            let from = if (t as u64) > p_now { i_now } else { i_now + 1 };
            let i_next = from.max(fl.i_start + 1);
            if i_next < fl.i_end {
                self.levels[0].hits -= fl.i_end - i_next;
                self.pending.push(Reverse((i_next * ctx.p + t as u64, t as u32)));
            }
        }
    }
}

/// Per-row fingerprint: per-level (hits, misses, writebacks) deltas,
/// the row's composed cycles (bitwise), and its iteration count.
#[derive(PartialEq)]
struct RowDelta {
    stats: Vec<(u64, u64, u64)>,
    cycles_bits: u64,
    iters: u64,
}

fn gcd(a: u64, b: u64) -> u64 {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

/// Largest power of two ≤ the requested worker count that divides
/// every level's set count (so the shard factorization is exact).
fn choose_shards(tb: &VirtualTestbed, setup: &SimSetup) -> usize {
    let req = if tb.shards == 0 {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    } else {
        tb.shards
    };
    let mut k = 1usize;
    while k * 2 <= req && setup.geometry.iter().all(|&(sets, _)| sets % (k * 2) == 0) {
        k *= 2;
    }
    k
}

pub(crate) fn run(
    tb: &VirtualTestbed,
    analysis: &KernelAnalysis,
    setup: &SimSetup,
) -> Result<SimResult> {
    if analysis.reads.is_empty() && analysis.writes.is_empty() {
        // no memory terms — nothing to compress, nothing to shard
        return super::reference::run(tb, analysis, setup);
    }
    let mut trace = Trace::new(analysis, setup);
    let n_levels = setup.geometry.len();
    let u = setup.unit_iters;
    let p_cnt = trace.p;
    let k = choose_shards(tb, setup);
    let mut shards: Vec<ShardState> =
        (0..k).map(|_| ShardState::new(setup, k, trace.terms.len())).collect();

    let ol_pi = setup.t_ol / u as f64;
    let nol_pi = setup.t_nol / u as f64;
    let lsp = tb.loop_start_penalty;
    let pf = tb.prefetch_miss_factor;
    let n_loops = analysis.loops.len();
    let t_in = *setup.trips.last().unwrap();
    let total = setup.total;

    // Inner-loop entries inside unit `uidx`, in closed form — the
    // reference engine charges the pipeline-restart penalty to the
    // unit containing the first iteration after each inner wrap.
    let loop_entries = |uidx: u64| -> u64 {
        if n_loops < 2 {
            return 0;
        }
        let lo = (uidx * u).max(1);
        let hi = ((uidx + 1) * u).min(total);
        if hi <= lo {
            0
        } else {
            (hi - 1) / t_in - (lo - 1) / t_in
        }
    };
    let close_unit = |uidx: u64, cnt: u64, lines: &[u64], nonseq: &[u64]| -> f64 {
        let mut pen = loop_entries(uidx) as f64 * lsp;
        for kk in 0..n_levels {
            pen += nonseq[kk] as f64 * (setup.link_lat[kk] * pf);
        }
        let mut data = 0.0;
        for kk in 0..n_levels {
            data += lines[kk] as f64 * setup.link_cpc[kk];
        }
        let c = cnt as f64;
        (ol_pi * c).max(nol_pi * c + data + pen)
    };

    let mut cycles = 0f64;
    let mut next_unit: u64 = 0;
    let mut carry_lines = vec![0u64; n_levels];
    let mut carry_nonseq = vec![0u64; n_levels];

    // skip-ahead state
    let p_align = u / gcd(u, trace.row_len);
    let window = (3 * p_align) as usize;
    let tail_keep = p_align;
    let full_rows = if total % trace.row_len == 0 { trace.rows } else { trace.rows - 1 };
    let mut hist: VecDeque<RowDelta> = VecDeque::new();
    let mut prev_tot: Vec<(u64, u64, u64)> = vec![(0, 0, 0); n_levels];
    let mut extra: Vec<(u64, u64, u64)> = vec![(0, 0, 0); n_levels];
    let mut extrapolated = false;

    let mut ev_buf: Vec<Event> = Vec::new();
    let mut parts: Vec<Vec<Event>> = vec![Vec::new(); k];
    let mut lines_buf: Vec<u64> = Vec::new();
    let mut nonseq_buf: Vec<u64> = Vec::new();
    let mut gl = vec![0u64; n_levels];
    let mut gn = vec![0u64; n_levels];

    let mut r: u64 = 0;
    while r < trace.rows {
        let (r0, r1) = trace.row_range(r);
        let u_lo = r0 / u;
        let win_len = ((r1 - 1) / u - u_lo + 1) as usize;
        for s in shards.iter_mut() {
            s.begin_row(u_lo, win_len);
        }
        let mut i = r0;
        while i < r1 {
            let i1 = (i + BLOCK_ITERS).min(r1);
            trace.gen_events(i, i1, &mut ev_buf);
            let ctx = Ctx { terms: &trace.terms, p: p_cnt, u };
            if k == 1 {
                shards[0].process(&ev_buf, &ctx);
            } else {
                for pvec in parts.iter_mut() {
                    pvec.clear();
                }
                for e in &ev_buf {
                    parts[(e.line % k as u64) as usize].push(*e);
                }
                std::thread::scope(|sc| {
                    for (s, evs) in shards.iter_mut().zip(parts.iter()) {
                        let c = &ctx;
                        sc.spawn(move || s.process(evs, c));
                    }
                });
            }
            i = i1;
        }
        // merge the shards' per-unit windows, then compose serially
        lines_buf.clear();
        lines_buf.resize(n_levels * win_len, 0);
        nonseq_buf.clear();
        nonseq_buf.resize(n_levels * win_len, 0);
        for s in shards.iter() {
            for x in 0..n_levels * win_len {
                lines_buf[x] += s.win_lines[x];
                nonseq_buf[x] += s.win_nonseq[x];
            }
        }
        let mut row_cycles = 0f64;
        while (next_unit + 1) * u <= r1 {
            let uu = (next_unit - u_lo) as usize;
            for kk in 0..n_levels {
                gl[kk] = lines_buf[kk * win_len + uu] + carry_lines[kk];
                gn[kk] = nonseq_buf[kk * win_len + uu] + carry_nonseq[kk];
                carry_lines[kk] = 0;
                carry_nonseq[kk] = 0;
            }
            row_cycles += close_unit(next_unit, u, &gl, &gn);
            next_unit += 1;
        }
        if next_unit * u < r1 {
            // the row ends mid-unit: stash the open unit's counts
            let uu = (next_unit - u_lo) as usize;
            for kk in 0..n_levels {
                carry_lines[kk] += lines_buf[kk * win_len + uu];
                carry_nonseq[kk] += nonseq_buf[kk * win_len + uu];
            }
        }
        cycles += row_cycles;

        // per-row stat deltas for the convergence fingerprint
        let mut tot = vec![(0u64, 0u64, 0u64); n_levels];
        for (kk, slot) in tot.iter_mut().enumerate() {
            let (mut h, mut m, mut wb) = extra[kk];
            for s in shards.iter() {
                h += s.levels[kk].hits;
                m += s.levels[kk].misses;
                wb += s.levels[kk].writebacks;
            }
            *slot = (h, m, wb);
        }
        let stats_delta: Vec<(u64, u64, u64)> = (0..n_levels)
            .map(|kk| {
                (
                    tot[kk].0 - prev_tot[kk].0,
                    tot[kk].1 - prev_tot[kk].1,
                    tot[kk].2 - prev_tot[kk].2,
                )
            })
            .collect();
        prev_tot = tot;
        hist.push_back(RowDelta {
            stats: stats_delta,
            cycles_bits: row_cycles.to_bits(),
            iters: r1 - r0,
        });
        if hist.len() > window {
            hist.pop_front();
        }

        // convergence: the last `window` rows form three identical
        // unit-phase-aligned periods, wholly inside the current plane
        if tb.skip_ahead && hist.len() == window && r1 - r0 == trace.row_len {
            let plane = r / trace.rows_per_plane;
            let plane_start = plane * trace.rows_per_plane;
            let plane_end = ((plane + 1) * trace.rows_per_plane).min(full_rows);
            let pa = p_align as usize;
            let converged = r + 1 >= plane_start + window as u64
                && (0..2 * pa).all(|j| hist[window - 1 - j] == hist[window - 1 - j - pa]);
            if converged {
                let avail = plane_end.saturating_sub(r + 1).saturating_sub(tail_keep);
                let s_rows = avail / p_align * p_align;
                if s_rows >= p_align {
                    let reps = s_rows / p_align;
                    let mut period_cycles = 0f64;
                    for j in 0..pa {
                        let d = &hist[window - 1 - j];
                        period_cycles += f64::from_bits(d.cycles_bits);
                        for kk in 0..n_levels {
                            extra[kk].0 += reps * d.stats[kk].0;
                            extra[kk].1 += reps * d.stats[kk].1;
                            extra[kk].2 += reps * d.stats[kk].2;
                            prev_tot[kk].0 += reps * d.stats[kk].0;
                            prev_tot[kk].1 += reps * d.stats[kk].1;
                            prev_tot[kk].2 += reps * d.stats[kk].2;
                        }
                    }
                    cycles += reps as f64 * period_cycles;
                    next_unit += s_rows * trace.row_len / u;
                    r += s_rows;
                    trace.reseed((r + 1) * trace.row_len);
                    hist.clear();
                    extrapolated = true;
                }
            }
        }
        r += 1;
    }
    // trailing partial unit
    if next_unit * u < total {
        let cnt = total - next_unit * u;
        cycles += close_unit(next_unit, cnt, &carry_lines, &carry_nonseq);
    }

    let levels: Vec<LevelStats> = setup
        .level_names
        .iter()
        .enumerate()
        .map(|(kk, name)| {
            let (mut h, mut m, mut wb) = extra[kk];
            for s in shards.iter() {
                h += s.levels[kk].hits;
                m += s.levels[kk].misses;
                wb += s.levels[kk].writebacks;
            }
            LevelStats { level: name.clone(), hits: h, misses: m, writebacks: wb }
        })
        .collect();
    let units = total as f64 / u as f64;
    Ok(SimResult {
        cycles,
        cy_per_cl: cycles / units,
        iterations: total,
        truncated: setup.truncated,
        levels,
        t_ol: setup.t_ol,
        t_nol: setup.t_nol,
        touches: total * p_cnt,
        engine: SimEngine::Fast,
        extrapolated,
    })
}
