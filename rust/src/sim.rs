//! Trace-driven virtual testbed — the stand-in for running the kernel on
//! the paper's Sandy Bridge / Haswell machines (DESIGN.md §1 documents
//! the measurement-substitution strategy and how the knobs below were
//! calibrated against the paper's Tables 1 and 5).
//!
//! Front doors: `-p Benchmark --bench-path virtual` measures alone;
//! `-p Validate` ([`crate::session::ModelKind::Validate`]) runs the
//! testbed next to the analytic ECM prediction and reports both plus the
//! relative model error — the paper's model-vs-measurement loop.
//!
//! Where the analytic predictor (`cache::CachePredictor`) reasons about a
//! steady-state unit of work, this module *executes* the kernel's memory
//! trace against a set-associative, inclusive, write-allocate/write-back
//! LRU cache hierarchy configured from the same machine file, and charges
//! cycles with an ECM-style composition rule per unit of work:
//!
//! `T_unit = max(T_OL, T_nOL + Σ_links lines·cy/CL + latency penalties)`
//!
//! Cold caches, loop boundaries (pipeline restart at each inner-loop
//! entry), and imperfect prefetching on non-sequential misses are
//! modeled, so short loops deviate from the analytic model exactly the
//! way the paper's Fig. 4 measurements do.
//!
//! For large problems the outer iteration space is truncated after the
//! working set has cycled several times — the reported cy/CL is the
//! steady-state mean over the simulated window.

use crate::incore::{CodegenPolicy, PortModel};
use crate::kernel::KernelAnalysis;
use crate::machine::MachineModel;
use anyhow::{bail, Result};

/// One set-associative LRU cache level.
struct CacheLevel {
    sets: usize,
    ways: usize,
    /// tags\[set\]\[way\] — line address + 1 (0 = empty way).
    tags: Vec<u64>,
    /// LRU age per way (higher = more recent).
    ages: Vec<u32>,
    dirty: Vec<bool>,
    clock: u32,
    // statistics
    hits: u64,
    misses: u64,
    writebacks: u64,
}

impl CacheLevel {
    fn new(size_bytes: u64, ways: u32, line_size: u64) -> CacheLevel {
        let lines = (size_bytes / line_size).max(1);
        let ways = (ways as u64).min(lines).max(1) as usize;
        let sets = (lines as usize / ways).max(1);
        CacheLevel {
            sets,
            ways,
            tags: vec![0; sets * ways],
            ages: vec![0; sets * ways],
            dirty: vec![false; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    /// Access a line address; returns (hit, evicted_dirty_line).
    fn access(&mut self, line: u64, write: bool) -> (bool, Option<u64>) {
        // store line+1 so 0 marks an empty way
        let key = line + 1;
        let set = (line as usize) % self.sets;
        let base = set * self.ways;
        self.clock = self.clock.wrapping_add(1);
        let mut lru_way = 0;
        let mut lru_age = u32::MAX;
        for w in 0..self.ways {
            let ix = base + w;
            if self.tags[ix] == key {
                self.hits += 1;
                self.ages[ix] = self.clock;
                if write {
                    self.dirty[ix] = true;
                }
                return (true, None);
            }
            if self.tags[ix] == 0 {
                lru_way = w;
                lru_age = 0;
            } else if self.ages[ix] < lru_age {
                lru_age = self.ages[ix];
                lru_way = w;
            }
        }
        self.misses += 1;
        let ix = base + lru_way;
        let evicted = if self.tags[ix] != 0 && self.dirty[ix] {
            self.writebacks += 1;
            Some(self.tags[ix] - 1)
        } else {
            None
        };
        self.tags[ix] = key;
        self.ages[ix] = self.clock;
        self.dirty[ix] = write;
        (false, evicted)
    }
}

/// Per-level statistics of a simulation run.
#[derive(Debug, Clone)]
pub struct LevelStats {
    pub level: String,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

/// Result of a virtual-testbed run.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total simulated cycles.
    pub cycles: f64,
    /// Cycles per cache line of work (the Table 5 "Bench." unit).
    pub cy_per_cl: f64,
    /// Simulated inner iterations.
    pub iterations: u64,
    /// Whether the iteration space was truncated for tractability.
    pub truncated: bool,
    pub levels: Vec<LevelStats>,
    /// In-core times used (cy per CL of work).
    pub t_ol: f64,
    pub t_nol: f64,
}

impl SimResult {
    /// Measured performance in It/s at the given clock.
    pub fn iterations_per_second(&self, clock_hz: f64) -> f64 {
        self.iterations as f64 / (self.cycles / clock_hz)
    }
}

/// The virtual testbed.
pub struct VirtualTestbed<'m> {
    machine: &'m MachineModel,
    /// Hard cap on simulated inner iterations (after warm-up estimation).
    pub max_iterations: u64,
    /// Pipeline restart penalty charged at every inner-loop entry.
    pub loop_start_penalty: f64,
    /// Extra latency charged for a miss that the streaming prefetcher
    /// did not anticipate (fraction of the serving level's latency).
    pub prefetch_miss_factor: f64,
}

impl<'m> VirtualTestbed<'m> {
    /// Testbed with default knobs.
    pub fn new(machine: &'m MachineModel) -> Self {
        VirtualTestbed {
            machine,
            max_iterations: 4_000_000,
            loop_start_penalty: 25.0,
            prefetch_miss_factor: 0.6,
        }
    }

    /// Run the kernel on the virtual testbed.
    pub fn run(&self, analysis: &KernelAnalysis) -> Result<SimResult> {
        let policy = CodegenPolicy::for_machine(self.machine);
        let pm = PortModel::analyze(analysis, self.machine, &policy)?;
        self.run_with_incore(analysis, &pm)
    }

    /// Run with a pre-computed in-core model.
    pub fn run_with_incore(
        &self,
        analysis: &KernelAnalysis,
        pm: &PortModel,
    ) -> Result<SimResult> {
        let cl = self.machine.cacheline_bytes;
        if analysis.loops.is_empty() {
            bail!("kernel has no loops");
        }
        // build hierarchy
        let mut levels: Vec<CacheLevel> = Vec::new();
        let mut link_cpc: Vec<f64> = Vec::new(); // cycles per CL per link
        let mut link_lat: Vec<f64> = Vec::new();
        let cache_levels = self.machine.cache_levels();
        for lvl in &cache_levels {
            let Some(size) = lvl.size_bytes else {
                bail!("cache level {} lacks a size", lvl.name)
            };
            levels.push(CacheLevel::new(size, lvl.ways, cl));
            let cpc = match lvl.cycles_per_cacheline {
                Some(c) => c,
                None => {
                    // memory link: saturated bandwidth of the copy kernel
                    let bw = self
                        .machine
                        .benchmarks
                        .saturated_bandwidth("MEM", "copy")
                        .unwrap_or(20e9);
                    cl as f64 / bw * self.machine.clock_hz
                }
            };
            link_cpc.push(cpc);
        }
        for (ix, lvl) in cache_levels.iter().enumerate() {
            // latency of the level that serves a miss at this level
            let next = self
                .machine
                .memory_hierarchy
                .get(ix + 1)
                .map(|l| l.latency)
                .unwrap_or(lvl.latency * 4.0);
            link_lat.push(next);
        }

        // array layout (same placement rule as the analytic predictor)
        let layout = crate::cache::ArrayLayout::new(analysis, cl);

        // iteration bounds, possibly truncated in the OUTERMOST dimension
        let trips: Vec<i64> = analysis.loops.iter().map(|l| l.trip().max(0)).collect();
        if let Some(l) = analysis.loops.iter().find(|l| l.trip() <= 0) {
            // an empty space would otherwise clamp(1, 0) below (panic) and
            // then issue out-of-bounds accesses for the phantom iteration
            bail!(
                "empty iteration space: loop '{}' runs {}..{} (step {}) — nothing to simulate",
                l.index,
                l.start,
                l.end,
                l.step
            );
        }
        // saturating product: gigantic nests only need to compare > cap
        let total: u64 = trips
            .iter()
            .fold(1u64, |acc, t| acc.saturating_mul(*t as u64));
        let mut outer_trip = trips[0] as u64;
        let mut truncated = false;
        if analysis.loops.len() > 1 {
            if total > self.max_iterations {
                let inner_total: u64 = trips[1..]
                    .iter()
                    .fold(1u64, |acc, t| acc.saturating_mul(*t as u64))
                    .max(1);
                outer_trip = (self.max_iterations / inner_total).clamp(1, trips[0] as u64);
                truncated = outer_trip < trips[0] as u64;
            }
        } else if total > self.max_iterations {
            outer_trip = self.max_iterations;
            truncated = true;
        }

        // prefetcher model: per-array rolling lists of the lines touched
        // in the current and previous unit of work — a miss whose
        // predecessor line appears there is stream-prefetched (bandwidth
        // only). Small Vecs beat hash sets here: ≤ a few dozen entries,
        // scanned linearly (§Perf iteration 2).
        let mut cur_lines: Vec<Vec<i64>> = vec![Vec::new(); analysis.arrays.len()];
        let mut prev_lines: Vec<Vec<i64>> = vec![Vec::new(); analysis.arrays.len()];

        let elem_sizes: Vec<i64> =
            analysis.arrays.iter().map(|a| a.ty.size() as i64).collect();
        let unit_iters = analysis.unit_of_work(cl).max(1);
        let t_ol = pm.t_ol;
        let t_nol = pm.t_nol;
        // in-core time per iteration
        let ol_per_iter = t_ol / unit_iters as f64;
        let nol_per_iter = t_nol / unit_iters as f64;

        let mut cycles = 0f64;
        let mut iterations: u64 = 0;
        // per-unit accumulators
        let mut unit_count = 0u64;
        let mut unit_link_lines = vec![0u64; levels.len()];
        let mut unit_penalty = 0f64;

        let n_loops = analysis.loops.len();
        let mut idx: Vec<i64> = analysis.loops.iter().map(|l| l.start).collect();
        // adjust outermost bound for truncation
        let outer_end =
            analysis.loops[0].start + outer_trip as i64 * analysis.loops[0].step;

        'outer: loop {
            // --- one inner iteration: issue all accesses ---
            for acc in analysis.reads.iter() {
                let a = acc.array;
                let off =
                    acc.offset + acc.coeffs.iter().zip(&idx).map(|(c, p)| c * p).sum::<i64>();
                let byte = layout.base_of(a) + off * elem_sizes[a];
                let line = byte.div_euclid(cl as i64) as u64;
                self.touch(
                    &mut levels,
                    line,
                    false,
                    a,
                    &mut cur_lines,
                    &prev_lines,
                    &link_lat,
                    &mut unit_link_lines,
                    &mut unit_penalty,
                );
            }
            for acc in analysis.writes.iter() {
                let a = acc.array;
                let off =
                    acc.offset + acc.coeffs.iter().zip(&idx).map(|(c, p)| c * p).sum::<i64>();
                let byte = layout.base_of(a) + off * elem_sizes[a];
                let line = byte.div_euclid(cl as i64) as u64;
                self.touch(
                    &mut levels,
                    line,
                    true,
                    a,
                    &mut cur_lines,
                    &prev_lines,
                    &link_lat,
                    &mut unit_link_lines,
                    &mut unit_penalty,
                );
            }
            iterations += 1;
            unit_count += 1;

            // close a unit of work: ECM composition
            if unit_count == unit_iters {
                let mut data: f64 = 0.0;
                for (k, lines) in unit_link_lines.iter().enumerate() {
                    data += *lines as f64 * link_cpc[k];
                }
                let t_unit = (ol_per_iter * unit_count as f64)
                    .max(nol_per_iter * unit_count as f64 + data + unit_penalty);
                cycles += t_unit;
                unit_count = 0;
                unit_link_lines.iter_mut().for_each(|x| *x = 0);
                unit_penalty = 0.0;
                for (cur, prev) in cur_lines.iter_mut().zip(prev_lines.iter_mut()) {
                    std::mem::swap(cur, prev);
                    cur.clear();
                }
            }

            // --- advance the loop nest ---
            let mut k = n_loops - 1;
            loop {
                idx[k] += analysis.loops[k].step;
                let end = if k == 0 { outer_end } else { analysis.loops[k].end };
                if idx[k] < end {
                    if k != n_loops - 1 {
                        // entering a fresh inner loop: pipeline restart
                        unit_penalty += self.loop_start_penalty;
                    }
                    break;
                }
                if k == 0 {
                    break 'outer;
                }
                idx[k] = analysis.loops[k].start;
                k -= 1;
            }
        }
        // flush the trailing partial unit
        if unit_count > 0 {
            let mut data: f64 = 0.0;
            for (k, lines) in unit_link_lines.iter().enumerate() {
                data += *lines as f64 * link_cpc[k];
            }
            cycles += (ol_per_iter * unit_count as f64)
                .max(nol_per_iter * unit_count as f64 + data + unit_penalty);
        }

        let stats = cache_levels
            .iter()
            .zip(&levels)
            .map(|(m, l)| LevelStats {
                level: m.name.clone(),
                hits: l.hits,
                misses: l.misses,
                writebacks: l.writebacks,
            })
            .collect();
        let units = iterations as f64 / unit_iters as f64;
        Ok(SimResult {
            cycles,
            cy_per_cl: cycles / units,
            iterations,
            truncated,
            levels: stats,
            t_ol,
            t_nol,
        })
    }

    /// Issue one line access through the hierarchy, updating traffic and
    /// penalty accumulators. Dirty evictions propagate inclusively: an
    /// eviction from level k marks (or installs) the line dirty in level
    /// k+1 and counts one write-back crossing that link.
    #[allow(clippy::too_many_arguments)]
    fn touch(
        &self,
        levels: &mut [CacheLevel],
        line: u64,
        write: bool,
        array: usize,
        cur_lines: &mut [Vec<i64>],
        prev_lines: &[Vec<i64>],
        link_lat: &[f64],
        unit_link_lines: &mut [u64],
        unit_penalty: &mut f64,
    ) {
        // sequential-stream detection: predecessor (or same) line seen in
        // this or the previous unit of work
        let sline = line as i64;
        let hit_list = |v: &[i64]| v.iter().any(|&h| h == sline || h == sline - 1);
        let sequential = hit_list(&cur_lines[array]) || hit_list(&prev_lines[array]);
        if !cur_lines[array].contains(&sline) {
            cur_lines[array].push(sline);
        }

        let n = levels.len();
        let mut depth = 0usize;
        for k in 0..n {
            let (hit, evicted) = levels[k].access(line, write && k == 0);
            if let Some(dirty_line) = evicted {
                // write-back: crosses the link below level k, then marks
                // the line dirty further out (installing it if the
                // hierarchy drifted from strict inclusion)
                unit_link_lines[k] += 1;
                let mut wb = dirty_line;
                for kk in k + 1..n {
                    let (hit_wb, ev2) = levels[kk].access(wb, true);
                    if let Some(d2) = ev2 {
                        unit_link_lines[kk] += 1;
                        if hit_wb {
                            break;
                        }
                        wb = d2;
                        continue;
                    }
                    break;
                }
            }
            if hit {
                break;
            }
            // miss: the fill crosses this link
            unit_link_lines[k] += 1;
            depth = k + 1;
        }
        // latency penalty for non-sequential (unprefetched) misses
        if depth > 0 && !sequential {
            let lat = link_lat[depth - 1];
            *unit_penalty += lat * self.prefetch_miss_factor;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::parse;
    use std::collections::HashMap;

    fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn analyze(src: &str, c: &[(&str, i64)]) -> KernelAnalysis {
        let p = parse(src).unwrap();
        KernelAnalysis::from_program(&p, &consts(c)).unwrap()
    }

    #[test]
    fn cache_level_lru_behaviour() {
        // 2 sets × 2 ways of 64 B lines = 256 B cache
        let mut c = CacheLevel::new(256, 2, 64);
        assert_eq!(c.sets, 2);
        // fill set 0 (even lines)
        assert!(!c.access(0, false).0);
        assert!(!c.access(2, false).0);
        assert!(c.access(0, false).0, "0 still resident");
        // third distinct even line evicts LRU (line 2)
        assert!(!c.access(4, false).0);
        assert!(c.access(0, false).0, "0 was MRU, stays");
        assert!(!c.access(2, false).0, "2 was evicted");
    }

    #[test]
    fn dirty_eviction_produces_writeback() {
        let mut c = CacheLevel::new(128, 1, 64); // 2 sets × 1 way
        c.access(0, true); // dirty
        let (_, ev) = c.access(2, false); // same set, evicts line 0
        assert_eq!(ev, Some(0));
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn triad_steady_state_matches_ecm() {
        // For the pure streaming triad the virtual testbed must land close
        // to the analytic ECM in-memory prediction (≈47.9 cy/CL on SNB).
        let m = MachineModel::snb();
        let a = analyze(
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];",
            &[("N", 2_000_000)],
        );
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        assert!(
            (sim.cy_per_cl - 47.9).abs() / 47.9 < 0.15,
            "sim {} vs ECM 47.9",
            sim.cy_per_cl
        );
    }

    #[test]
    fn jacobi_bench_close_to_paper_measurement() {
        // Paper Table 5: measured 36.4 cy/CL on SNB (model 36.7).
        let m = MachineModel::snb();
        let a = analyze(
            crate::models::reference::KERNEL_2D5PT,
            &[("N", 6000), ("M", 6000)],
        );
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        assert!(
            (sim.cy_per_cl - 36.4).abs() / 36.4 < 0.2,
            "sim {} vs paper bench 36.4",
            sim.cy_per_cl
        );
    }

    #[test]
    fn simulated_traffic_matches_analytic_steady_state() {
        // jacobi: the analytic model predicts 5 CL crossing the L1 link
        // per unit of work (3 read rows + write-allocate + evict).
        let m = MachineModel::snb();
        let a = analyze(
            crate::models::reference::KERNEL_2D5PT,
            &[("N", 6000), ("M", 6000)],
        );
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        let units = sim.iterations as f64 / 8.0;
        let l1 = &sim.levels[0];
        let lines_per_unit = (l1.misses + l1.writebacks) as f64 / units;
        assert!(
            (lines_per_unit - 5.0).abs() < 0.5,
            "L1 link lines/unit = {lines_per_unit}"
        );
    }

    #[test]
    fn truncation_engages_for_huge_spaces() {
        let m = MachineModel::snb();
        let a = analyze(
            crate::models::reference::KERNEL_2D5PT,
            &[("N", 4000), ("M", 100000)],
        );
        let tb = VirtualTestbed::new(&m);
        let sim = tb.run(&a).unwrap();
        assert!(sim.truncated);
        assert!(sim.iterations <= tb.max_iterations + 4000 * 8);
    }

    #[test]
    fn empty_iteration_space_is_a_clean_error() {
        // M=2 leaves the outer loop with zero trips; this used to reach a
        // clamp(1, 0) panic in the truncation path and then simulate a
        // phantom out-of-bounds iteration.
        let m = MachineModel::snb();
        let a = analyze(crate::models::reference::KERNEL_2D5PT, &[("N", 100), ("M", 2)]);
        let err = VirtualTestbed::new(&m).run(&a).unwrap_err();
        assert!(format!("{err}").contains("empty iteration space"), "{err}");
    }

    #[test]
    fn small_n_exceeds_steady_state_model() {
        // Fig 4: for very short inner loops the measurement lies above the
        // analytic prediction (boundary effects dominate).
        let m = MachineModel::snb();
        let small = analyze(
            crate::models::reference::KERNEL_LONG_RANGE,
            &[("N", 20), ("M", 20)],
        );
        let big = analyze(
            crate::models::reference::KERNEL_LONG_RANGE,
            &[("N", 400), ("M", 400)],
        );
        let tb = VirtualTestbed::new(&m);
        let s_small = tb.run(&small).unwrap();
        let s_big = tb.run(&big).unwrap();
        // per-CL cost at tiny N must exceed the large-N steady state
        assert!(
            s_small.cy_per_cl > s_big.cy_per_cl,
            "small {} vs big {}",
            s_small.cy_per_cl,
            s_big.cy_per_cl
        );
    }

    #[test]
    fn hits_grow_with_cache_friendliness() {
        let m = MachineModel::snb();
        // N small enough for the L1 layer condition
        let friendly = analyze(crate::models::reference::KERNEL_2D5PT, &[("N", 200), ("M", 4000)]);
        let hostile = analyze(crate::models::reference::KERNEL_2D5PT, &[("N", 6000), ("M", 140)]);
        let tb = VirtualTestbed::new(&m);
        let f = tb.run(&friendly).unwrap();
        let h = tb.run(&hostile).unwrap();
        let f_l1_rate = f.levels[0].hits as f64 / (f.levels[0].hits + f.levels[0].misses) as f64;
        let h_l1_rate = h.levels[0].hits as f64 / (h.levels[0].hits + h.levels[0].misses) as f64;
        assert!(f_l1_rate > h_l1_rate, "{f_l1_rate} vs {h_l1_rate}");
    }

    #[test]
    fn kahan_is_core_bound_in_sim_too() {
        let m = MachineModel::snb();
        let a = analyze(crate::models::reference::KERNEL_KAHAN, &[("N", 2_000_000)]);
        let sim = VirtualTestbed::new(&m).run(&a).unwrap();
        // paper bench: 101.1 cy/CL (model 96): core-bound, so the sim must
        // land at T_OL (96) ± small memory effects
        assert!((sim.cy_per_cl - 96.0).abs() / 96.0 < 0.12, "sim {}", sim.cy_per_cl);
    }
}
