//! Command-line interface, mirroring the paper's tool invocation
//! (Listing 5):
//!
//! ```text
//! kerncraft -p ECM --cores 1 -m machines/snb.yml kernels/2d-5pt.c \
//!           -D N 6000 -D M 6000 [--unit cy/CL] [-v]
//! ```
//!
//! Analysis modes (paper §4.6): `ECM`, `ECMData`, `ECMCPU`, `Roofline`,
//! `RooflinePort` (the paper's RooflineIACA), `Benchmark`. Extras beyond
//! the paper CLI: `--cache-viz` (Fig 2), `--machine-report` (Table 1),
//! `--bench-path virtual|native|pjrt` for the three Benchmark backends,
//! `--cache-predictor offsets|lc|auto` (upstream Kerncraft's knob), and
//! the batched **sweep** subcommand:
//!
//! ```text
//! kerncraft sweep -m SNB,HSW kernels/2d-5pt.c -D N 128:8M:log2 -D M 4000 \
//!           [--cores 1,2] [--predictor auto] [--format csv|json] [--threads K]
//! ```
//!
//! Grid axes use `START:END[:log2|*K|+K]` with binary magnitude suffixes
//! (`8M` = 8·1024²); every combination of machine × cores × grid point is
//! evaluated by [`crate::sweep::SweepEngine`] in parallel with
//! stage memoization, and emitted as CSV or JSON rows.

use crate::cache::{CachePredictor, CachePredictorKind};
use crate::incore::{CodegenPolicy, PortModel};
use crate::kernel::{parse, KernelAnalysis};
use crate::machine::MachineModel;
use crate::models::{EcmModel, RooflineModel, ScalingModel, Unit};
use crate::report;
use crate::sweep;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::sync::Arc;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub mode: Mode,
    pub machine: String,
    pub kernel_path: Option<String>,
    pub constants: HashMap<String, i64>,
    pub cores: u32,
    pub unit: Unit,
    pub verbose: bool,
    pub cache_viz: bool,
    pub machine_report: bool,
    pub bench_path: String,
    pub artifacts_dir: String,
    pub scalar_codegen: bool,
    pub cache_predictor: CachePredictorKind,
}

/// Analysis mode (paper §4.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Ecm,
    EcmData,
    EcmCpu,
    Roofline,
    RooflinePort,
    Benchmark,
}

impl Mode {
    fn parse(s: &str) -> Option<Mode> {
        Some(match s {
            "ECM" => Mode::Ecm,
            "ECMData" => Mode::EcmData,
            "ECMCPU" => Mode::EcmCpu,
            "Roofline" => Mode::Roofline,
            "RooflinePort" | "RooflineIACA" => Mode::RooflinePort,
            "Benchmark" => Mode::Benchmark,
            _ => return None,
        })
    }
}

/// Parse argv (without the program name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let mut args = Args {
        mode: Mode::Ecm,
        machine: "SNB".to_string(),
        kernel_path: None,
        constants: HashMap::new(),
        cores: 1,
        unit: Unit::CyPerCl,
        verbose: false,
        cache_viz: false,
        machine_report: false,
        bench_path: "virtual".to_string(),
        artifacts_dir: "artifacts".to_string(),
        scalar_codegen: false,
        cache_predictor: CachePredictorKind::Offsets,
    };
    let mut it = argv.iter().peekable();
    let mut next_val = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                        flag: &str|
     -> Result<String> {
        it.next()
            .cloned()
            .ok_or_else(|| anyhow!("missing value after {flag}"))
    };
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "-p" | "--pmodel" => {
                let v = next_val(&mut it, "-p")?;
                args.mode =
                    Mode::parse(&v).ok_or_else(|| anyhow!("unknown analysis mode '{v}'"))?;
            }
            "-m" | "--machine" => args.machine = next_val(&mut it, "-m")?,
            "-D" | "--define" => {
                let name = next_val(&mut it, "-D")?;
                let value = next_val(&mut it, "-D NAME")?;
                let value: i64 =
                    value.parse().with_context(|| format!("bad value for -D {name}"))?;
                args.constants.insert(name, value);
            }
            "--cores" => {
                args.cores = next_val(&mut it, "--cores")?.parse().context("--cores")?
            }
            "--unit" => {
                let v = next_val(&mut it, "--unit")?;
                args.unit = Unit::parse(&v).ok_or_else(|| anyhow!("unknown unit '{v}'"))?;
            }
            "--cache-predictor" => {
                let v = next_val(&mut it, "--cache-predictor")?;
                args.cache_predictor = CachePredictorKind::parse(&v)
                    .ok_or_else(|| anyhow!("unknown cache predictor '{v}' (offsets|lc|auto)"))?;
            }
            "-v" | "--verbose" => args.verbose = true,
            "--cache-viz" => args.cache_viz = true,
            "--machine-report" => args.machine_report = true,
            "--bench-path" => args.bench_path = next_val(&mut it, "--bench-path")?,
            "--artifacts" => args.artifacts_dir = next_val(&mut it, "--artifacts")?,
            "--scalar" => args.scalar_codegen = true,
            "-h" | "--help" => {
                bail!("{}", usage());
            }
            other if !other.starts_with('-') => {
                if args.kernel_path.is_some() {
                    bail!("multiple kernel files given");
                }
                args.kernel_path = Some(other.to_string());
            }
            other => bail!("unknown flag '{other}'\n{}", usage()),
        }
    }
    Ok(args)
}

/// CLI usage text.
pub fn usage() -> String {
    "usage: kerncraft -p MODE [-m MACHINE] kernel.c -D NAME VALUE ...\n\
     modes: ECM ECMData ECMCPU Roofline RooflinePort Benchmark\n\
     MACHINE: SNB | HSW | path/to/machine.yml\n\
     options: --cores N  --unit {cy/CL,It/s,FLOP/s}  -v\n\
              --cache-predictor {offsets,lc,auto}\n\
              --cache-viz  --machine-report  --scalar\n\
              --bench-path {virtual,native,pjrt}  --artifacts DIR\n\
     \n\
     batched sweeps over problem-size grids:\n\
     kerncraft sweep [-m M1,M2] kernel.c -D NAME GRID [-D NAME2 GRID2 ...]\n\
              GRID: VALUE | START:END[:log2|*K|+K]   (suffixes k/M/G, 1024-based)\n\
              --cores LIST  --predictor {offsets,lc,auto}  --threads K\n\
              --format {csv,json}  --serial  -v"
        .to_string()
}

/// Load the machine model named by `-m` (builtin tag or file path).
pub fn load_machine(name: &str) -> Result<MachineModel> {
    if let Some(m) = MachineModel::builtin(name) {
        return Ok(m);
    }
    MachineModel::from_file(name)
}

/// Run the CLI; returns the report text.
pub fn run(argv: &[String]) -> Result<String> {
    if argv.first().map(String::as_str) == Some("sweep") {
        return run_sweep(&argv[1..]);
    }
    let args = parse_args(argv)?;
    let machine = load_machine(&args.machine)?;
    let mut out = String::new();

    if args.machine_report {
        out.push_str(&report::machine_report(&machine));
        if args.kernel_path.is_none() {
            return Ok(out);
        }
    }

    let Some(path) = &args.kernel_path else {
        bail!("no kernel file given\n{}", usage());
    };
    let source = std::fs::read_to_string(path)
        .with_context(|| format!("reading kernel file {path}"))?;
    let program = parse(&source)?;
    let analysis = KernelAnalysis::from_program(&program, &args.constants)?;

    if args.verbose {
        out.push_str(&report::analysis_report(&analysis));
        out.push('\n');
    }

    let policy = if args.scalar_codegen {
        CodegenPolicy::scalar()
    } else {
        CodegenPolicy::for_machine(&machine)
    };
    let predictor =
        |m: &MachineModel| CachePredictor::with_kind(m, args.cores, args.cache_predictor);

    match args.mode {
        Mode::EcmCpu => {
            let pm = PortModel::analyze(&analysis, &machine, &policy)?;
            out.push_str(&report::incore_report(&pm));
        }
        Mode::EcmData => {
            let traffic = predictor(&machine).predict(&analysis)?;
            let ecm = EcmModel::build_data_only(&traffic, &machine)?;
            let sc = ScalingModel::build(&ecm, &machine);
            out.push_str(&report::ecm_report(&ecm, &sc, args.unit, args.verbose));
            if args.cache_viz {
                out.push_str(&report::cache_viz(&analysis, &traffic));
            }
        }
        Mode::Ecm => {
            let pm = PortModel::analyze(&analysis, &machine, &policy)?;
            let traffic = predictor(&machine).predict(&analysis)?;
            let ecm = EcmModel::build(&pm, &traffic, &machine)?;
            let sc = ScalingModel::build(&ecm, &machine);
            if args.verbose {
                out.push_str(&report::incore_report(&pm));
            }
            out.push_str(&report::ecm_report(&ecm, &sc, args.unit, args.verbose));
            if args.cache_viz {
                out.push_str(&report::cache_viz(&analysis, &traffic));
            }
        }
        Mode::Roofline | Mode::RooflinePort => {
            let traffic = predictor(&machine).predict(&analysis)?;
            let pm = if args.mode == Mode::RooflinePort {
                Some(PortModel::analyze(&analysis, &machine, &policy)?)
            } else {
                None
            };
            let roofline = RooflineModel::build_cores(
                &analysis,
                &traffic,
                &machine,
                pm.as_ref(),
                args.cores,
            )?;
            out.push_str(&report::roofline_report(&roofline, args.unit));
            if args.cache_viz {
                out.push_str(&report::cache_viz(&analysis, &traffic));
            }
        }
        Mode::Benchmark => match args.bench_path.as_str() {
            "virtual" => {
                let r = crate::bench_mode::run_virtual(&analysis, &machine)?;
                out.push_str(&format!(
                    "Benchmark (virtual testbed {}): {:.1} cy/CL ({:.3e} It/s)\n",
                    machine.arch, r.cy_per_cl, r.it_per_s
                ));
            }
            "native" => {
                // map the kernel file back to a Table 5 tag by structure
                let tag = native_tag_for(path)
                    .ok_or_else(|| anyhow!("no native implementation for {path}"))?;
                let consts: Vec<(&str, i64)> =
                    args.constants.iter().map(|(k, v)| (k.as_str(), *v)).collect();
                let r = crate::bench_mode::run_native(tag, &consts, 3)?;
                out.push_str(&format!(
                    "Benchmark (native host): {:.1} host-cy/CL ({:.3e} It/s)\n",
                    r.cy_per_cl, r.it_per_s
                ));
            }
            "pjrt" => {
                let name = pjrt_name_for(path)
                    .ok_or_else(|| anyhow!("no artifact mapping for {path}"))?;
                let r = crate::bench_mode::run_pjrt(
                    std::path::Path::new(&args.artifacts_dir),
                    name,
                    3,
                )?;
                out.push_str(&format!(
                    "Benchmark (PJRT artifact '{}'): {:.1} host-cy/CL ({:.3e} It/s, wall {:.3} ms)\n",
                    name,
                    r.cy_per_cl,
                    r.it_per_s,
                    r.wall_s * 1e3
                ));
            }
            other => bail!("unknown --bench-path '{other}'"),
        },
    }
    Ok(out)
}

/// Parsed `sweep` subcommand arguments.
#[derive(Debug, Clone)]
pub struct SweepArgs {
    pub machines: Vec<String>,
    pub kernel_path: Option<String>,
    /// (name, grid values) in the order given on the command line.
    pub axes: Vec<(String, Vec<i64>)>,
    pub cores: Vec<u32>,
    pub predictor: CachePredictorKind,
    pub threads: Option<usize>,
    pub format: SweepFormat,
    pub verbose: bool,
}

/// Sweep output format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepFormat {
    Csv,
    Json,
}

/// Parse `sweep` subcommand argv (after the `sweep` word).
pub fn parse_sweep_args(argv: &[String]) -> Result<SweepArgs> {
    let mut args = SweepArgs {
        machines: vec!["SNB".to_string()],
        kernel_path: None,
        axes: Vec::new(),
        cores: vec![1],
        predictor: CachePredictorKind::Auto,
        threads: None,
        format: SweepFormat::Csv,
        verbose: false,
    };
    let mut it = argv.iter().peekable();
    let mut next_val = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                        flag: &str|
     -> Result<String> {
        it.next()
            .cloned()
            .ok_or_else(|| anyhow!("missing value after {flag}"))
    };
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "-m" | "--machine" => {
                let v = next_val(&mut it, "-m")?;
                args.machines = v.split(',').map(str::to_string).filter(|s| !s.is_empty()).collect();
                if args.machines.is_empty() {
                    bail!("empty machine list");
                }
            }
            "-D" | "--define" => {
                let name = next_val(&mut it, "-D")?;
                let spec = next_val(&mut it, "-D NAME")?;
                let values = sweep::parse_grid(&spec)
                    .with_context(|| format!("grid for -D {name}"))?;
                if args.axes.iter().any(|(n, _)| *n == name) {
                    bail!("duplicate -D {name}");
                }
                args.axes.push((name, values));
            }
            "--cores" => {
                let v = next_val(&mut it, "--cores")?;
                args.cores = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse::<u32>().with_context(|| format!("bad core count '{s}'")))
                    .collect::<Result<_>>()?;
                if args.cores.is_empty() {
                    bail!("empty core list");
                }
            }
            "--predictor" | "--cache-predictor" => {
                let v = next_val(&mut it, "--predictor")?;
                args.predictor = CachePredictorKind::parse(&v)
                    .ok_or_else(|| anyhow!("unknown cache predictor '{v}' (offsets|lc|auto)"))?;
            }
            "--threads" => {
                args.threads =
                    Some(next_val(&mut it, "--threads")?.parse().context("--threads")?);
            }
            "--serial" => args.threads = Some(1),
            "--format" => {
                args.format = match next_val(&mut it, "--format")?.as_str() {
                    "csv" => SweepFormat::Csv,
                    "json" => SweepFormat::Json,
                    other => bail!("unknown sweep format '{other}' (csv|json)"),
                };
            }
            "-v" | "--verbose" => args.verbose = true,
            "-h" | "--help" => bail!("{}", usage()),
            other if !other.starts_with('-') => {
                if args.kernel_path.is_some() {
                    bail!("multiple kernel files given");
                }
                args.kernel_path = Some(other.to_string());
            }
            other => bail!("unknown sweep flag '{other}'\n{}", usage()),
        }
    }
    Ok(args)
}

/// Run the `sweep` subcommand; returns CSV or JSON text.
pub fn run_sweep(argv: &[String]) -> Result<String> {
    let args = parse_sweep_args(argv)?;
    let Some(path) = &args.kernel_path else {
        bail!("no kernel file given for sweep\n{}", usage());
    };
    if args.axes.is_empty() {
        bail!("sweep needs at least one -D axis\n{}", usage());
    }
    // file path, or a Table 5 tag as a convenience
    let (label, source) = match std::fs::read_to_string(path) {
        Ok(text) => {
            let label = std::path::Path::new(path)
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or(path)
                .to_string();
            (label, text)
        }
        Err(io) => match crate::models::reference::kernel_source(path) {
            Some(src) => (path.clone(), src.to_string()),
            None => {
                return Err(anyhow::Error::new(io)
                    .context(format!("reading kernel file {path} (not a Table 5 tag either)")))
            }
        },
    };
    let source: Arc<str> = Arc::from(source.as_str());
    let jobs = sweep::build_jobs(
        &label,
        source,
        &args.machines,
        &args.cores,
        &args.axes,
        args.predictor,
    );
    if jobs.is_empty() {
        bail!("sweep grid is empty");
    }
    let engine = match args.threads {
        Some(n) => sweep::SweepEngine::with_threads(n),
        None => sweep::SweepEngine::new(),
    };
    let out = engine.run(&jobs)?;
    let mut text = match args.format {
        SweepFormat::Csv => report::sweep_csv(&out.rows),
        SweepFormat::Json => report::sweep_json(&out.rows, &out.stats),
    };
    if args.verbose && args.format == SweepFormat::Csv {
        text.push_str(&report::sweep_stats_comment(&out));
    }
    Ok(text)
}

/// Map a kernel file path to the Table 5 tag used by the native bench.
fn native_tag_for(path: &str) -> Option<&'static str> {
    let stem = std::path::Path::new(path).file_stem()?.to_str()?;
    Some(match stem {
        "2d-5pt" => "2D-5pt",
        "uxx" => "UXX",
        "long-range" => "long-range",
        "kahan-ddot" => "Kahan-dot",
        "triad" => "triad",
        _ => return None,
    })
}

/// Map a kernel file path to the AOT artifact name.
fn pjrt_name_for(path: &str) -> Option<&'static str> {
    let stem = std::path::Path::new(path).file_stem()?.to_str()?;
    Some(match stem {
        "2d-5pt" => "jacobi2d",
        "uxx" => "uxx",
        "long-range" => "long_range",
        "kahan-ddot" => "kahan_ddot",
        "triad" => "triad",
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_paper_invocation() {
        let a = parse_args(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert_eq!(a.mode, Mode::Ecm);
        assert_eq!(a.machine, "SNB");
        assert_eq!(a.constants["N"], 6000);
        assert_eq!(a.cores, 1);
        assert_eq!(a.kernel_path.as_deref(), Some("kernels/2d-5pt.c"));
        assert_eq!(a.cache_predictor, CachePredictorKind::Offsets);
    }

    #[test]
    fn roofline_iaca_alias() {
        let a = parse_args(&argv("-p RooflineIACA k.c")).unwrap();
        assert_eq!(a.mode, Mode::RooflinePort);
    }

    #[test]
    fn rejects_unknown_mode_and_flag() {
        assert!(parse_args(&argv("-p Nope k.c")).is_err());
        assert!(parse_args(&argv("--frobnicate k.c")).is_err());
    }

    #[test]
    fn unit_flag() {
        let a = parse_args(&argv("-p ECM --unit FLOP/s k.c")).unwrap();
        assert_eq!(a.unit, Unit::FlopPerS);
    }

    #[test]
    fn cache_predictor_flag() {
        let a = parse_args(&argv("-p ECM --cache-predictor auto k.c")).unwrap();
        assert_eq!(a.cache_predictor, CachePredictorKind::Auto);
        assert!(parse_args(&argv("-p ECM --cache-predictor nope k.c")).is_err());
    }

    #[test]
    fn end_to_end_ecm_run_matches_listing5() {
        // paper Listing 5 invocation against the shipped kernel corpus
        let out = run(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert!(out.contains("ECM model"), "{out}");
        assert!(out.contains("saturating at 3 cores"), "{out}");
    }

    #[test]
    fn ecm_run_with_auto_predictor_matches_offsets() {
        let base = "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000";
        let walk = run(&argv(base)).unwrap();
        let auto = run(&argv(&format!("{base} --cache-predictor auto"))).unwrap();
        assert_eq!(walk, auto, "auto predictor must not change the report");
    }

    #[test]
    fn end_to_end_roofline_run() {
        let out = run(&argv(
            "-p RooflinePort --unit cy/CL --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 -v",
        ))
        .unwrap();
        assert!(out.contains("Bottlenecks"), "{out}");
        assert!(out.contains("Cache or mem bound"), "{out}");
    }

    #[test]
    fn benchmark_virtual_mode_runs() {
        let out = run(&argv(
            "-p Benchmark -m SNB kernels/triad.c -D N 500000",
        ))
        .unwrap();
        assert!(out.contains("virtual testbed"), "{out}");
    }

    #[test]
    fn machine_report_standalone() {
        let out = run(&argv("--machine-report -m HSW")).unwrap();
        assert!(out.contains("HSW"), "{out}");
    }

    #[test]
    fn mapping_tables() {
        assert_eq!(native_tag_for("kernels/2d-5pt.c"), Some("2D-5pt"));
        assert_eq!(pjrt_name_for("kernels/long-range.c"), Some("long_range"));
        assert_eq!(native_tag_for("kernels/custom.c"), None);
    }

    #[test]
    fn parses_sweep_invocation() {
        let a = parse_sweep_args(&argv(
            "-m SNB,HSW kernels/2d-5pt.c -D N 128:1k:log2 -D M 4000 --cores 1,2 --predictor lc --format json --threads 3",
        ))
        .unwrap();
        assert_eq!(a.machines, vec!["SNB", "HSW"]);
        assert_eq!(a.kernel_path.as_deref(), Some("kernels/2d-5pt.c"));
        assert_eq!(a.axes.len(), 2);
        assert_eq!(a.axes[0].0, "N");
        assert_eq!(a.axes[0].1, vec![128, 256, 512, 1024]);
        assert_eq!(a.axes[1].1, vec![4000]);
        assert_eq!(a.cores, vec![1, 2]);
        assert_eq!(a.predictor, CachePredictorKind::LayerConditions);
        assert_eq!(a.format, SweepFormat::Json);
        assert_eq!(a.threads, Some(3));
    }

    #[test]
    fn sweep_rejects_bad_specs() {
        assert!(parse_sweep_args(&argv("k.c -D N 10:5:log2")).is_err());
        assert!(parse_sweep_args(&argv("k.c -D N 1 -D N 2")).is_err());
        assert!(parse_sweep_args(&argv("k.c --format xml")).is_err());
        assert!(run_sweep(&argv("kernels/triad.c")).is_err(), "missing -D axis");
    }
}
