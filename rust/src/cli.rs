//! Command-line interface, mirroring the paper's tool invocation
//! (Listing 5):
//!
//! ```text
//! kerncraft -p ECM --cores 1 -m machines/snb.yml kernels/2d-5pt.c \
//!           -D N 6000 -D M 6000 [--unit cy/CL] [-v]
//! ```
//!
//! Analysis modes (paper §4.6): `ECM`, `ECMData`, `ECMCPU`, `Roofline`,
//! `RooflinePort` (the paper's RooflineIACA), `Benchmark`. Extras beyond
//! the paper CLI: `--cache-viz` (Fig 2), `--machine-report` (Table 1),
//! `--bench-path virtual|native|pjrt` for the three Benchmark backends.

use crate::cache::CachePredictor;
use crate::incore::{CodegenPolicy, PortModel};
use crate::kernel::{parse, KernelAnalysis};
use crate::machine::MachineModel;
use crate::models::{EcmModel, RooflineModel, ScalingModel, Unit};
use crate::report;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub mode: Mode,
    pub machine: String,
    pub kernel_path: Option<String>,
    pub constants: HashMap<String, i64>,
    pub cores: u32,
    pub unit: Unit,
    pub verbose: bool,
    pub cache_viz: bool,
    pub machine_report: bool,
    pub bench_path: String,
    pub artifacts_dir: String,
    pub scalar_codegen: bool,
}

/// Analysis mode (paper §4.6).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Ecm,
    EcmData,
    EcmCpu,
    Roofline,
    RooflinePort,
    Benchmark,
}

impl Mode {
    fn parse(s: &str) -> Option<Mode> {
        Some(match s {
            "ECM" => Mode::Ecm,
            "ECMData" => Mode::EcmData,
            "ECMCPU" => Mode::EcmCpu,
            "Roofline" => Mode::Roofline,
            "RooflinePort" | "RooflineIACA" => Mode::RooflinePort,
            "Benchmark" => Mode::Benchmark,
            _ => return None,
        })
    }
}

/// Parse argv (without the program name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let mut args = Args {
        mode: Mode::Ecm,
        machine: "SNB".to_string(),
        kernel_path: None,
        constants: HashMap::new(),
        cores: 1,
        unit: Unit::CyPerCl,
        verbose: false,
        cache_viz: false,
        machine_report: false,
        bench_path: "virtual".to_string(),
        artifacts_dir: "artifacts".to_string(),
        scalar_codegen: false,
    };
    let mut it = argv.iter().peekable();
    let mut next_val = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                        flag: &str|
     -> Result<String> {
        it.next()
            .cloned()
            .ok_or_else(|| anyhow!("missing value after {flag}"))
    };
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "-p" | "--pmodel" => {
                let v = next_val(&mut it, "-p")?;
                args.mode =
                    Mode::parse(&v).ok_or_else(|| anyhow!("unknown analysis mode '{v}'"))?;
            }
            "-m" | "--machine" => args.machine = next_val(&mut it, "-m")?,
            "-D" | "--define" => {
                let name = next_val(&mut it, "-D")?;
                let value = next_val(&mut it, "-D NAME")?;
                let value: i64 =
                    value.parse().with_context(|| format!("bad value for -D {name}"))?;
                args.constants.insert(name, value);
            }
            "--cores" => {
                args.cores = next_val(&mut it, "--cores")?.parse().context("--cores")?
            }
            "--unit" => {
                let v = next_val(&mut it, "--unit")?;
                args.unit = Unit::parse(&v).ok_or_else(|| anyhow!("unknown unit '{v}'"))?;
            }
            "-v" | "--verbose" => args.verbose = true,
            "--cache-viz" => args.cache_viz = true,
            "--machine-report" => args.machine_report = true,
            "--bench-path" => args.bench_path = next_val(&mut it, "--bench-path")?,
            "--artifacts" => args.artifacts_dir = next_val(&mut it, "--artifacts")?,
            "--scalar" => args.scalar_codegen = true,
            "-h" | "--help" => {
                bail!("{}", usage());
            }
            other if !other.starts_with('-') => {
                if args.kernel_path.is_some() {
                    bail!("multiple kernel files given");
                }
                args.kernel_path = Some(other.to_string());
            }
            other => bail!("unknown flag '{other}'\n{}", usage()),
        }
    }
    Ok(args)
}

/// CLI usage text.
pub fn usage() -> String {
    "usage: kerncraft -p MODE [-m MACHINE] kernel.c -D NAME VALUE ...\n\
     modes: ECM ECMData ECMCPU Roofline RooflinePort Benchmark\n\
     MACHINE: SNB | HSW | path/to/machine.yml\n\
     options: --cores N  --unit {cy/CL,It/s,FLOP/s}  -v\n\
              --cache-viz  --machine-report  --scalar\n\
              --bench-path {virtual,native,pjrt}  --artifacts DIR"
        .to_string()
}

/// Load the machine model named by `-m` (builtin tag or file path).
pub fn load_machine(name: &str) -> Result<MachineModel> {
    if let Some(m) = MachineModel::builtin(name) {
        return Ok(m);
    }
    MachineModel::from_file(name)
}

/// Run the CLI; returns the report text.
pub fn run(argv: &[String]) -> Result<String> {
    let args = parse_args(argv)?;
    let machine = load_machine(&args.machine)?;
    let mut out = String::new();

    if args.machine_report {
        out.push_str(&report::machine_report(&machine));
        if args.kernel_path.is_none() {
            return Ok(out);
        }
    }

    let Some(path) = &args.kernel_path else {
        bail!("no kernel file given\n{}", usage());
    };
    let source = std::fs::read_to_string(path)
        .with_context(|| format!("reading kernel file {path}"))?;
    let program = parse(&source)?;
    let analysis = KernelAnalysis::from_program(&program, &args.constants)?;

    if args.verbose {
        out.push_str(&report::analysis_report(&analysis));
        out.push('\n');
    }

    let policy = if args.scalar_codegen {
        CodegenPolicy::scalar()
    } else {
        CodegenPolicy::for_machine(&machine)
    };

    match args.mode {
        Mode::EcmCpu => {
            let pm = PortModel::analyze(&analysis, &machine, &policy)?;
            out.push_str(&report::incore_report(&pm));
        }
        Mode::EcmData => {
            let traffic =
                CachePredictor::with_cores(&machine, args.cores).predict(&analysis)?;
            let ecm = EcmModel::build_data_only(&traffic, &machine)?;
            let sc = ScalingModel::build(&ecm, &machine);
            out.push_str(&report::ecm_report(&ecm, &sc, args.unit, args.verbose));
            if args.cache_viz {
                out.push_str(&report::cache_viz(&analysis, &traffic));
            }
        }
        Mode::Ecm => {
            let pm = PortModel::analyze(&analysis, &machine, &policy)?;
            let traffic =
                CachePredictor::with_cores(&machine, args.cores).predict(&analysis)?;
            let ecm = EcmModel::build(&pm, &traffic, &machine)?;
            let sc = ScalingModel::build(&ecm, &machine);
            if args.verbose {
                out.push_str(&report::incore_report(&pm));
            }
            out.push_str(&report::ecm_report(&ecm, &sc, args.unit, args.verbose));
            if args.cache_viz {
                out.push_str(&report::cache_viz(&analysis, &traffic));
            }
        }
        Mode::Roofline | Mode::RooflinePort => {
            let traffic =
                CachePredictor::with_cores(&machine, args.cores).predict(&analysis)?;
            let pm = if args.mode == Mode::RooflinePort {
                Some(PortModel::analyze(&analysis, &machine, &policy)?)
            } else {
                None
            };
            let roofline = RooflineModel::build_cores(
                &analysis,
                &traffic,
                &machine,
                pm.as_ref(),
                args.cores,
            )?;
            out.push_str(&report::roofline_report(&roofline, args.unit));
            if args.cache_viz {
                out.push_str(&report::cache_viz(&analysis, &traffic));
            }
        }
        Mode::Benchmark => match args.bench_path.as_str() {
            "virtual" => {
                let r = crate::bench_mode::run_virtual(&analysis, &machine)?;
                out.push_str(&format!(
                    "Benchmark (virtual testbed {}): {:.1} cy/CL ({:.3e} It/s)\n",
                    machine.arch, r.cy_per_cl, r.it_per_s
                ));
            }
            "native" => {
                // map the kernel file back to a Table 5 tag by structure
                let tag = native_tag_for(path)
                    .ok_or_else(|| anyhow!("no native implementation for {path}"))?;
                let consts: Vec<(&str, i64)> =
                    args.constants.iter().map(|(k, v)| (k.as_str(), *v)).collect();
                let r = crate::bench_mode::run_native(tag, &consts, 3)?;
                out.push_str(&format!(
                    "Benchmark (native host): {:.1} host-cy/CL ({:.3e} It/s)\n",
                    r.cy_per_cl, r.it_per_s
                ));
            }
            "pjrt" => {
                let name = pjrt_name_for(path)
                    .ok_or_else(|| anyhow!("no artifact mapping for {path}"))?;
                let r = crate::bench_mode::run_pjrt(
                    std::path::Path::new(&args.artifacts_dir),
                    name,
                    3,
                )?;
                out.push_str(&format!(
                    "Benchmark (PJRT artifact '{}'): {:.1} host-cy/CL ({:.3e} It/s, wall {:.3} ms)\n",
                    name,
                    r.cy_per_cl,
                    r.it_per_s,
                    r.wall_s * 1e3
                ));
            }
            other => bail!("unknown --bench-path '{other}'"),
        },
    }
    Ok(out)
}

/// Map a kernel file path to the Table 5 tag used by the native bench.
fn native_tag_for(path: &str) -> Option<&'static str> {
    let stem = std::path::Path::new(path).file_stem()?.to_str()?;
    Some(match stem {
        "2d-5pt" => "2D-5pt",
        "uxx" => "UXX",
        "long-range" => "long-range",
        "kahan-ddot" => "Kahan-dot",
        "triad" => "triad",
        _ => return None,
    })
}

/// Map a kernel file path to the AOT artifact name.
fn pjrt_name_for(path: &str) -> Option<&'static str> {
    let stem = std::path::Path::new(path).file_stem()?.to_str()?;
    Some(match stem {
        "2d-5pt" => "jacobi2d",
        "uxx" => "uxx",
        "long-range" => "long_range",
        "kahan-ddot" => "kahan_ddot",
        "triad" => "triad",
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_paper_invocation() {
        let a = parse_args(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert_eq!(a.mode, Mode::Ecm);
        assert_eq!(a.machine, "SNB");
        assert_eq!(a.constants["N"], 6000);
        assert_eq!(a.cores, 1);
        assert_eq!(a.kernel_path.as_deref(), Some("kernels/2d-5pt.c"));
    }

    #[test]
    fn roofline_iaca_alias() {
        let a = parse_args(&argv("-p RooflineIACA k.c")).unwrap();
        assert_eq!(a.mode, Mode::RooflinePort);
    }

    #[test]
    fn rejects_unknown_mode_and_flag() {
        assert!(parse_args(&argv("-p Nope k.c")).is_err());
        assert!(parse_args(&argv("--frobnicate k.c")).is_err());
    }

    #[test]
    fn unit_flag() {
        let a = parse_args(&argv("-p ECM --unit FLOP/s k.c")).unwrap();
        assert_eq!(a.unit, Unit::FlopPerS);
    }

    #[test]
    fn end_to_end_ecm_run_matches_listing5() {
        // paper Listing 5 invocation against the shipped kernel corpus
        let out = run(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert!(out.contains("ECM model"), "{out}");
        assert!(out.contains("saturating at 3 cores"), "{out}");
    }

    #[test]
    fn end_to_end_roofline_run() {
        let out = run(&argv(
            "-p RooflinePort --unit cy/CL --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 -v",
        ))
        .unwrap();
        assert!(out.contains("Bottlenecks"), "{out}");
        assert!(out.contains("Cache or mem bound"), "{out}");
    }

    #[test]
    fn benchmark_virtual_mode_runs() {
        let out = run(&argv(
            "-p Benchmark -m SNB kernels/triad.c -D N 500000",
        ))
        .unwrap();
        assert!(out.contains("virtual testbed"), "{out}");
    }

    #[test]
    fn machine_report_standalone() {
        let out = run(&argv("--machine-report -m HSW")).unwrap();
        assert!(out.contains("HSW"), "{out}");
    }

    #[test]
    fn mapping_tables() {
        assert_eq!(native_tag_for("kernels/2d-5pt.c"), Some("2D-5pt"));
        assert_eq!(pjrt_name_for("kernels/long-range.c"), Some("long_range"));
        assert_eq!(native_tag_for("kernels/custom.c"), None);
    }
}
