//! Command-line front end — a thin shell over [`crate::session`],
//! mirroring the paper's tool invocation (Listing 5):
//!
//! ```text
//! kerncraft -p ECM --cores 1 -m machines/snb.yml kernels/2d-5pt.c \
//!           -D N 6000 -D M 6000 [--unit cy/CL] [--format json] [-v]
//! ```
//!
//! Analysis modes (paper §4.6): `ECM`, `ECMData`, `ECMCPU`, `Roofline`,
//! `RooflinePort` (the paper's RooflineIACA), `Benchmark`. Every analysis
//! run builds one typed [`AnalysisRequest`], evaluates it through a
//! [`Session`], and renders the resulting [`crate::session::AnalysisReport`]
//! as text (default) or JSON (`--format json`).
//!
//! Batch subcommands:
//!
//! ```text
//! kerncraft sweep -m SNB,HSW kernels/2d-5pt.c -D N 128:8M:log2 -D M 4000 \
//!           [--cores 1,2] [--predictor auto] [--format csv|json] [--threads K]
//!           [--validate]
//! kerncraft serve [--input FILE] [--threads K] [--unordered] [-v]
//! ```
//!
//! `sweep` expands grid axes (`START:END[:log2|*K|+K]`, binary magnitude
//! suffixes) into jobs for [`crate::sweep::SweepEngine`]. `serve` reads
//! JSON-lines [`AnalysisRequest`]s from stdin (or `--input FILE`) and
//! streams one JSON [`crate::session::AnalysisReport`] per line back,
//! amortizing machine/kernel parsing across requests through one shared
//! session — each response carries its per-request cache-hit counters.
//! With `--threads K` a worker pool evaluates requests concurrently over
//! the shared session, delivering responses in request order (default)
//! or as completed (`--unordered`). With `--listen ADDR` the same
//! pipeline is served over HTTP instead ([`crate::server`]: `/analyze`,
//! `/batch`, `/stream`, `/healthz`, `/metrics`), and `--cache-dir DIR`
//! attaches the persistent cross-process report cache
//! ([`crate::server::cache::DiskCache`]) in either mode. The full wire
//! protocol lives in docs/SERVE.md, operational guidance in
//! docs/OPERATIONS.md.

use crate::cache::CachePredictorKind;
use crate::jsonio::{self, json_str};
use crate::machine::MachineModel;
use crate::models::Unit;
use crate::report;
use crate::session::{
    AnalysisRequest, CodegenSelection, KernelSpec, MemoStats, ModelKind, Session,
};
use crate::sweep;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, Write};
use std::sync::{Arc, Mutex};

/// Parsed command line.
#[derive(Debug, Clone)]
pub struct Args {
    pub mode: Mode,
    pub machine: String,
    pub kernel_path: Option<String>,
    pub constants: HashMap<String, i64>,
    pub cores: u32,
    pub unit: Unit,
    pub verbose: bool,
    pub cache_viz: bool,
    pub machine_report: bool,
    pub bench_path: String,
    pub artifacts_dir: String,
    pub scalar_codegen: bool,
    pub cache_predictor: CachePredictorKind,
    pub sim_engine: crate::sim::SimEngine,
    pub format: OutputFormat,
}

/// Single-run output format.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum OutputFormat {
    #[default]
    Text,
    Json,
}

/// Analysis mode (paper §4.6): one of the session model kinds, or the
/// Benchmark mode that executes code instead of evaluating models.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Model(ModelKind),
    Benchmark,
}

impl Mode {
    fn parse(s: &str) -> Option<Mode> {
        ModelKind::parse(s)
            .map(Mode::Model)
            .or_else(|| (s == "Benchmark").then_some(Mode::Benchmark))
    }

    /// The session model this mode maps to (None for Benchmark).
    fn model(&self) -> Option<ModelKind> {
        match self {
            Mode::Model(m) => Some(*m),
            Mode::Benchmark => None,
        }
    }
}

/// Parse argv (without the program name).
pub fn parse_args(argv: &[String]) -> Result<Args> {
    let mut args = Args {
        mode: Mode::Model(ModelKind::Ecm),
        machine: "SNB".to_string(),
        kernel_path: None,
        constants: HashMap::new(),
        cores: 1,
        unit: Unit::CyPerCl,
        verbose: false,
        cache_viz: false,
        machine_report: false,
        bench_path: "virtual".to_string(),
        artifacts_dir: "artifacts".to_string(),
        scalar_codegen: false,
        cache_predictor: CachePredictorKind::Offsets,
        sim_engine: crate::sim::SimEngine::Fast,
        format: OutputFormat::Text,
    };
    let mut it = argv.iter().peekable();
    let mut next_val = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                        flag: &str|
     -> Result<String> {
        it.next()
            .cloned()
            .ok_or_else(|| anyhow!("missing value after {flag}"))
    };
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "-p" | "--pmodel" => {
                let v = next_val(&mut it, "-p")?;
                args.mode =
                    Mode::parse(&v).ok_or_else(|| anyhow!("unknown analysis mode '{v}'"))?;
            }
            "-m" | "--machine" => args.machine = next_val(&mut it, "-m")?,
            "-D" | "--define" => {
                let name = next_val(&mut it, "-D")?;
                let value = next_val(&mut it, "-D NAME")?;
                let value: i64 =
                    value.parse().with_context(|| format!("bad value for -D {name}"))?;
                args.constants.insert(name, value);
            }
            "--cores" => {
                args.cores = next_val(&mut it, "--cores")?.parse().context("--cores")?
            }
            "--unit" => {
                let v = next_val(&mut it, "--unit")?;
                args.unit = Unit::parse(&v).ok_or_else(|| {
                    anyhow!("unknown unit '{v}' (valid: {})", Unit::VALID_SPELLINGS)
                })?;
            }
            "--cache-predictor" => {
                let v = next_val(&mut it, "--cache-predictor")?;
                args.cache_predictor = CachePredictorKind::parse(&v)
                    .ok_or_else(|| anyhow!("unknown cache predictor '{v}' (offsets|lc|auto)"))?;
            }
            "--sim-engine" => {
                let v = next_val(&mut it, "--sim-engine")?;
                args.sim_engine = crate::sim::SimEngine::parse(&v)
                    .ok_or_else(|| anyhow!("unknown sim engine '{v}' (fast|reference)"))?;
            }
            "--format" => {
                args.format = match next_val(&mut it, "--format")?.as_str() {
                    "text" => OutputFormat::Text,
                    "json" => OutputFormat::Json,
                    other => bail!("unknown output format '{other}' (text|json)"),
                };
            }
            "-v" | "--verbose" => args.verbose = true,
            "--cache-viz" => args.cache_viz = true,
            "--machine-report" => args.machine_report = true,
            "--bench-path" => args.bench_path = next_val(&mut it, "--bench-path")?,
            "--artifacts" => args.artifacts_dir = next_val(&mut it, "--artifacts")?,
            "--scalar" => args.scalar_codegen = true,
            "-h" | "--help" => {
                bail!("{}", usage());
            }
            other if !other.starts_with('-') => {
                if args.kernel_path.is_some() {
                    bail!("multiple kernel files given");
                }
                args.kernel_path = Some(other.to_string());
            }
            other => bail!("unknown flag '{other}'\n{}", usage()),
        }
    }
    Ok(args)
}

/// CLI usage text.
pub fn usage() -> String {
    "usage: kerncraft -p MODE [-m MACHINE] kernel.c -D NAME VALUE ...\n\
     modes: ECM ECMData ECMCPU Roofline RooflinePort Validate Advise Benchmark\n\
            (Validate = full ECM plus a virtual-testbed run with the\n\
             simulated-vs-analytic comparison; the cache simulator is\n\
             reached through this mode, not via --cache-predictor)\n\
     MACHINE: SNB | HSW | path/to/machine.yml\n\
     options: --cores N  --unit {cy/CL,It/s,FLOP/s}  --format {text,json}  -v\n\
              --cache-predictor {offsets,lc,auto}\n\
              --sim-engine {fast,reference}   (Validate mode: compressed-\n\
               trace testbed vs the per-access baseline; default fast)\n\
              --cache-viz  --machine-report  --scalar\n\
              --bench-path {virtual,native,pjrt}  --artifacts DIR\n\
     \n\
     parse-only lint (exit code = number of failing files):\n\
     kerncraft check FILE...\n\
     \n\
     analytic cache-blocking advice (layer-condition breakpoint solve,\n\
     no problem-size sweep; text output is the advice section alone):\n\
     kerncraft advise kernel.c|TAG [-m MACHINE] -D NAME VALUE ...\n\
              [--cores N] [--format {text,json}]\n\
     \n\
     batched sweeps over problem-size grids:\n\
     kerncraft sweep [-m M1,M2] kernel.c -D NAME GRID [-D NAME2 GRID2 ...]\n\
              GRID: VALUE | START:END[:log2|*K|+K]   (suffixes k/M/G, 1024-based)\n\
              --cores LIST  --predictor {offsets,lc,auto}  --threads K\n\
              --format {csv,json}  --serial  --validate  --advise  -v\n\
     \n\
     batch service (JSON lines over stdin/stdout, or HTTP with\n\
     --listen; see docs/SERVE.md for the wire protocol and\n\
     docs/OPERATIONS.md for operations):\n\
     kerncraft serve [--input FILE] [--threads K] [--unordered]\n\
              [--listen ADDR] [--idle-timeout SECS] [--cache-dir DIR] [-v]\n\
              --listen ADDR     HTTP mode: POST /analyze | /batch | /stream,\n\
                                GET /healthz | /metrics\n\
              --idle-timeout S  HTTP mode: reap idle keep-alive\n\
                                connections after S seconds (default 30)\n\
              --cache-dir DIR   persistent cross-process report cache"
        .to_string()
}

/// Load the machine model named by `-m` (builtin tag or file path).
pub fn load_machine(name: &str) -> Result<MachineModel> {
    MachineModel::load(name)
}

/// Build the typed session request a single-run invocation maps to.
/// Benchmark mode has no request (it executes code instead).
pub fn request_from_args(args: &Args) -> Result<Option<AnalysisRequest>> {
    let Some(model) = args.mode.model() else {
        return Ok(None);
    };
    let Some(path) = &args.kernel_path else {
        bail!("no kernel file given\n{}", usage());
    };
    Ok(Some(AnalysisRequest {
        id: None,
        kernel: KernelSpec::path(path),
        constants: args.constants.iter().map(|(k, v)| (k.clone(), *v)).collect(),
        machine: args.machine.clone(),
        cores: args.cores,
        model,
        predictor: args.cache_predictor,
        codegen: if args.scalar_codegen {
            CodegenSelection::Scalar
        } else {
            CodegenSelection::MachineDefault
        },
        sim_engine: args.sim_engine,
        unit: args.unit,
    }))
}

/// Run the CLI; returns the report text.
pub fn run(argv: &[String]) -> Result<String> {
    match argv.first().map(String::as_str) {
        Some("sweep") => return run_sweep(&argv[1..]),
        Some("serve") => return run_serve(&argv[1..]),
        Some("advise") => return run_advise(&argv[1..]),
        // main.rs dispatches `check` itself to map the failure count to
        // the exit code; this arm serves library callers of `run`
        Some("check") => return run_check(&argv[1..]).map(|(report, _)| report),
        _ => {}
    }
    let args = parse_args(argv)?;
    if args.format == OutputFormat::Json {
        // text-only output would be silently dropped from the single
        // JSON document — refuse instead of losing requested output
        if args.machine_report || args.cache_viz || args.verbose {
            bail!(
                "--format json cannot carry --machine-report/--cache-viz/-v \
                 (text-only sections); drop the flag or use --format text"
            );
        }
        if args.mode == Mode::Benchmark {
            bail!("--format json is not supported in Benchmark mode (text output only)");
        }
    }
    let session = Session::new();
    let mut out = String::new();

    if args.machine_report {
        let machine = session.machine(&args.machine)?;
        out.push_str(&report::machine_report(&machine));
        if args.kernel_path.is_none() {
            return Ok(out);
        }
    }

    if args.mode == Mode::Benchmark {
        let Some(path) = &args.kernel_path else {
            bail!("no kernel file given\n{}", usage());
        };
        out.push_str(&run_benchmark(&session, &args, path)?);
        return Ok(out);
    }

    let request = request_from_args(&args)?.expect("non-benchmark mode has a request");
    let ev = session.evaluate_full(&request).map_err(render_frontend_error)?;

    if args.format == OutputFormat::Json {
        // structured output: exactly one JSON document, no text extras
        return Ok(format!("{}\n", ev.report.to_json()));
    }

    if args.verbose {
        out.push_str(&report::analysis_report(&ev.analysis));
        out.push('\n');
    }
    out.push_str(&report::render_report(&ev.report, args.verbose));
    if args.cache_viz {
        if let Some(traffic) = &ev.traffic {
            out.push_str(&report::cache_viz(&ev.analysis, traffic));
        }
    }
    Ok(out)
}

/// Swap a kernel-frontend failure's single-line message for the
/// caret-rendered diagnostic block — the terminal front door of the
/// structured diagnostics (serve tiers embed the JSON form instead).
fn render_frontend_error(e: anyhow::Error) -> anyhow::Error {
    match e.downcast_ref::<crate::kernel::KernelError>() {
        Some(ke) => anyhow!("{}", ke.diag.render()),
        None => e,
    }
}

/// `kerncraft advise kernel.c|TAG ...` — the analytic blocking adviser
/// (DESIGN.md §5): one [`ModelKind::Advise`] evaluation, rendered as the
/// advice section alone (`--format text`, the default) or the full JSON
/// report (`--format json`). The kernel argument is a file path or a
/// Table 5 tag, as in `sweep`. Accepts the single-run flags (`-m`,
/// `-D`, `--cores`, `--format`); any `-p` mode given is overridden.
pub fn run_advise(argv: &[String]) -> Result<String> {
    let args = parse_args(argv)?;
    let Some(path) = &args.kernel_path else {
        bail!("no kernel file given for advise\n{}", usage());
    };
    // file path, or a Table 5 tag as a convenience (mirrors `sweep`);
    // a path that neither exists nor names a tag stays a path so the
    // evaluation reports the read error with the filename
    let kernel = if !std::path::Path::new(path).exists()
        && crate::models::reference::kernel_source(path).is_some()
    {
        KernelSpec::named(path)
    } else {
        KernelSpec::path(path)
    };
    let request = AnalysisRequest {
        id: None,
        kernel,
        constants: args.constants.iter().map(|(k, v)| (k.clone(), *v)).collect(),
        machine: args.machine.clone(),
        cores: args.cores,
        model: ModelKind::Advise,
        predictor: args.cache_predictor,
        codegen: if args.scalar_codegen {
            CodegenSelection::Scalar
        } else {
            CodegenSelection::MachineDefault
        },
        sim_engine: args.sim_engine,
        unit: args.unit,
    };
    let session = Session::new();
    let report = session.evaluate(&request).map_err(render_frontend_error)?;
    match args.format {
        OutputFormat::Json => Ok(format!("{}\n", report.to_json())),
        OutputFormat::Text => Ok(report::advise_report(&report)),
    }
}

/// `kerncraft check FILE...` — the parse-only lint: run every file
/// through the full frontend pipeline (lex, parse, lower — no constant
/// binding, so unbound symbolic sizes are fine) and report `ok` or the
/// caret-rendered diagnostic per file. Returns the report text and the
/// number of failing files; `main` uses the count as the exit code.
pub fn run_check(argv: &[String]) -> Result<(String, usize)> {
    if argv.is_empty() || argv.iter().any(|a| a == "-h" || a == "--help") {
        bail!("check needs at least one kernel file\n{}", usage());
    }
    let mut out = String::new();
    let mut failed = 0usize;
    for path in argv {
        let source = std::fs::read_to_string(path)
            .with_context(|| format!("reading kernel file {path}"))?;
        match crate::kernel::parser::parse(&source) {
            Ok(_) => out.push_str(&format!("{path}: ok\n")),
            Err(e) => {
                failed += 1;
                out.push_str(&format!("{path}: {}\n", e.diag.render()));
            }
        }
    }
    Ok((out, failed))
}

/// Benchmark mode (paper §4.6): execute the kernel on the virtual
/// testbed, the native host, or a PJRT artifact.
fn run_benchmark(session: &Session, args: &Args, path: &str) -> Result<String> {
    let constants: BTreeMap<String, i64> =
        args.constants.iter().map(|(k, v)| (k.clone(), *v)).collect();
    let analysis = session.kernel_analysis(&KernelSpec::path(path), &constants)?;
    let machine = session.machine(&args.machine)?;
    let mut out = String::new();
    if args.verbose {
        out.push_str(&report::analysis_report(&analysis));
        out.push('\n');
    }
    match args.bench_path.as_str() {
        "virtual" => {
            let r = crate::bench_mode::run_virtual(&analysis, &machine)?;
            out.push_str(&format!(
                "Benchmark (virtual testbed {}): {:.1} cy/CL ({:.3e} It/s)\n",
                machine.arch, r.cy_per_cl, r.it_per_s
            ));
        }
        "native" => {
            // map the kernel file back to a Table 5 tag by structure
            let tag = native_tag_for(path)
                .ok_or_else(|| anyhow!("no native implementation for {path}"))?;
            let consts: Vec<(&str, i64)> =
                args.constants.iter().map(|(k, v)| (k.as_str(), *v)).collect();
            let r = crate::bench_mode::run_native(tag, &consts, 3)?;
            out.push_str(&format!(
                "Benchmark (native host): {:.1} host-cy/CL ({:.3e} It/s)\n",
                r.cy_per_cl, r.it_per_s
            ));
        }
        "pjrt" => {
            let name = pjrt_name_for(path)
                .ok_or_else(|| anyhow!("no artifact mapping for {path}"))?;
            let r = crate::bench_mode::run_pjrt(
                std::path::Path::new(&args.artifacts_dir),
                name,
                3,
            )?;
            out.push_str(&format!(
                "Benchmark (PJRT artifact '{}'): {:.1} host-cy/CL ({:.3e} It/s, wall {:.3} ms)\n",
                name,
                r.cy_per_cl,
                r.it_per_s,
                r.wall_s * 1e3
            ));
        }
        other => bail!("unknown --bench-path '{other}'"),
    }
    Ok(out)
}

/// Parsed `sweep` subcommand arguments.
#[derive(Debug, Clone)]
pub struct SweepArgs {
    pub machines: Vec<String>,
    pub kernel_path: Option<String>,
    /// (name, grid values) in the order given on the command line.
    pub axes: Vec<(String, Vec<i64>)>,
    pub cores: Vec<u32>,
    pub predictor: CachePredictorKind,
    pub threads: Option<usize>,
    pub format: SweepFormat,
    pub verbose: bool,
    /// Evaluate every point as [`ModelKind::Validate`]: rows gain the
    /// simulated cy/CL and model-error columns.
    pub validate: bool,
    /// Evaluate every point as [`ModelKind::Advise`]: rows gain the
    /// best advised block extent and its predicted T_Mem columns.
    pub advise: bool,
}

/// Sweep output format.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SweepFormat {
    Csv,
    Json,
}

/// Parse `sweep` subcommand argv (after the `sweep` word).
pub fn parse_sweep_args(argv: &[String]) -> Result<SweepArgs> {
    let mut args = SweepArgs {
        machines: vec!["SNB".to_string()],
        kernel_path: None,
        axes: Vec::new(),
        cores: vec![1],
        predictor: CachePredictorKind::Auto,
        threads: None,
        format: SweepFormat::Csv,
        verbose: false,
        validate: false,
        advise: false,
    };
    let mut it = argv.iter().peekable();
    let mut next_val = |it: &mut std::iter::Peekable<std::slice::Iter<String>>,
                        flag: &str|
     -> Result<String> {
        it.next()
            .cloned()
            .ok_or_else(|| anyhow!("missing value after {flag}"))
    };
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "-m" | "--machine" => {
                let v = next_val(&mut it, "-m")?;
                args.machines = v.split(',').map(str::to_string).filter(|s| !s.is_empty()).collect();
                if args.machines.is_empty() {
                    bail!("empty machine list");
                }
            }
            "-D" | "--define" => {
                let name = next_val(&mut it, "-D")?;
                let spec = next_val(&mut it, "-D NAME")?;
                let values = sweep::parse_grid(&spec)
                    .with_context(|| format!("grid for -D {name}"))?;
                if args.axes.iter().any(|(n, _)| *n == name) {
                    bail!("duplicate -D {name}");
                }
                args.axes.push((name, values));
            }
            "--cores" => {
                let v = next_val(&mut it, "--cores")?;
                args.cores = v
                    .split(',')
                    .filter(|s| !s.is_empty())
                    .map(|s| s.parse::<u32>().with_context(|| format!("bad core count '{s}'")))
                    .collect::<Result<_>>()?;
                if args.cores.is_empty() {
                    bail!("empty core list");
                }
            }
            "--predictor" | "--cache-predictor" => {
                let v = next_val(&mut it, "--predictor")?;
                args.predictor = CachePredictorKind::parse(&v)
                    .ok_or_else(|| anyhow!("unknown cache predictor '{v}' (offsets|lc|auto)"))?;
            }
            "--threads" => {
                args.threads =
                    Some(next_val(&mut it, "--threads")?.parse().context("--threads")?);
            }
            "--serial" => args.threads = Some(1),
            "--validate" => args.validate = true,
            "--advise" => args.advise = true,
            "--format" => {
                args.format = match next_val(&mut it, "--format")?.as_str() {
                    "csv" => SweepFormat::Csv,
                    "json" => SweepFormat::Json,
                    other => bail!("unknown sweep format '{other}' (csv|json)"),
                };
            }
            "-v" | "--verbose" => args.verbose = true,
            "-h" | "--help" => bail!("{}", usage()),
            other if !other.starts_with('-') => {
                if args.kernel_path.is_some() {
                    bail!("multiple kernel files given");
                }
                args.kernel_path = Some(other.to_string());
            }
            other => bail!("unknown sweep flag '{other}'\n{}", usage()),
        }
    }
    Ok(args)
}

/// Run the `sweep` subcommand; returns CSV or JSON text.
pub fn run_sweep(argv: &[String]) -> Result<String> {
    let args = parse_sweep_args(argv)?;
    let Some(path) = &args.kernel_path else {
        bail!("no kernel file given for sweep\n{}", usage());
    };
    if args.axes.is_empty() {
        bail!("sweep needs at least one -D axis\n{}", usage());
    }
    // file path, or a Table 5 tag as a convenience
    let (label, source) = match std::fs::read_to_string(path) {
        Ok(text) => {
            let label = std::path::Path::new(path)
                .file_stem()
                .and_then(|s| s.to_str())
                .unwrap_or(path)
                .to_string();
            (label, text)
        }
        Err(io) => match crate::models::reference::kernel_source(path) {
            Some(src) => (path.clone(), src.to_string()),
            None => {
                return Err(anyhow::Error::new(io)
                    .context(format!("reading kernel file {path} (not a Table 5 tag either)")))
            }
        },
    };
    let source: Arc<str> = Arc::from(source.as_str());
    let mut jobs = sweep::build_jobs(
        &label,
        source,
        &args.machines,
        &args.cores,
        &args.axes,
        args.predictor,
    );
    if args.validate && args.advise {
        bail!("--validate and --advise are mutually exclusive (one model per sweep point)");
    }
    if args.validate {
        for job in &mut jobs {
            job.model = ModelKind::Validate;
        }
    }
    if args.advise {
        for job in &mut jobs {
            job.model = ModelKind::Advise;
        }
    }
    if jobs.is_empty() {
        bail!("sweep grid is empty");
    }
    let engine = match args.threads {
        Some(n) => sweep::SweepEngine::with_threads(n),
        None => sweep::SweepEngine::new(),
    };
    let out = engine.run(&jobs)?;
    let mut text = match args.format {
        SweepFormat::Csv => report::sweep_csv(&out.rows),
        SweepFormat::Json => report::sweep_json(&out.rows, &out.stats),
    };
    if args.verbose && args.format == SweepFormat::Csv {
        text.push_str(&report::sweep_stats_comment(&out));
    }
    Ok(text)
}

/// Parsed `serve` subcommand arguments.
#[derive(Debug, Clone)]
pub struct ServeArgs {
    /// Request file (JSON lines); None reads stdin.
    pub input: Option<String>,
    pub verbose: bool,
    /// Worker threads evaluating requests. None picks the mode default:
    /// 1 (serial) for the JSON-lines stream, the core count for
    /// `--listen` (one slow HTTP connection must not starve the rest).
    pub threads: Option<usize>,
    /// Deliver responses as they finish instead of in request order.
    pub unordered: bool,
    /// HTTP mode: listen address (e.g. `127.0.0.1:8157`); None keeps
    /// the JSON-lines stdin/stdout transport.
    pub listen: Option<String>,
    /// HTTP mode: reap an idle keep-alive connection after this many
    /// seconds; None keeps the server default
    /// ([`crate::server::DEFAULT_IDLE_TIMEOUT`]).
    pub idle_timeout: Option<f64>,
    /// Persistent cross-process report cache directory (both modes).
    pub cache_dir: Option<String>,
}

impl Default for ServeArgs {
    fn default() -> ServeArgs {
        ServeArgs {
            input: None,
            verbose: false,
            threads: None,
            unordered: false,
            listen: None,
            idle_timeout: None,
            cache_dir: None,
        }
    }
}

/// HTTP-mode worker default when `--threads` is not given: enough
/// parallelism that one keep-alive or slow connection cannot pin the
/// whole pool and starve `/healthz` (the stream transport keeps its
/// serial default — a single pipe has no second client to starve).
fn default_http_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8)
}

/// Parse `serve` subcommand argv (after the `serve` word).
pub fn parse_serve_args(argv: &[String]) -> Result<ServeArgs> {
    let mut args = ServeArgs::default();
    let mut it = argv.iter();
    while let Some(arg) = it.next() {
        match arg.as_str() {
            "--input" | "-i" => {
                args.input = Some(
                    it.next()
                        .cloned()
                        .ok_or_else(|| anyhow!("missing value after --input"))?,
                );
            }
            "--threads" => {
                let n: usize = it
                    .next()
                    .ok_or_else(|| anyhow!("missing value after --threads"))?
                    .parse()
                    .context("--threads")?;
                if n == 0 {
                    bail!("--threads needs at least one worker");
                }
                args.threads = Some(n);
            }
            "--unordered" => args.unordered = true,
            "--listen" => {
                args.listen = Some(
                    it.next()
                        .cloned()
                        .ok_or_else(|| anyhow!("missing value after --listen"))?,
                );
            }
            "--idle-timeout" => {
                let Some(raw) = it.next() else {
                    bail!("missing value after --idle-timeout");
                };
                let v: f64 = raw.parse().context("--idle-timeout")?;
                if !(v > 0.0 && v.is_finite()) {
                    bail!("--idle-timeout needs a positive number of seconds");
                }
                args.idle_timeout = Some(v);
            }
            "--cache-dir" => {
                args.cache_dir = Some(
                    it.next()
                        .cloned()
                        .ok_or_else(|| anyhow!("missing value after --cache-dir"))?,
                );
            }
            "-v" | "--verbose" => args.verbose = true,
            "-h" | "--help" => bail!("{}", usage()),
            other if !other.starts_with('-') => {
                if args.input.is_some() {
                    bail!("multiple request files given");
                }
                args.input = Some(other.to_string());
            }
            other => bail!("unknown serve flag '{other}'\n{}", usage()),
        }
    }
    Ok(args)
}

/// Outcome of one `serve` run (for logging; responses went to the sink).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ServeSummary {
    pub requests: u64,
    pub errors: u64,
    /// Session-wide memo counters accumulated over the whole run.
    pub stats: MemoStats,
}

impl std::fmt::Display for ServeSummary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "# serve: {} requests ({} errors), memo {} hits / {} misses",
            self.requests,
            self.errors,
            self.stats.hits(),
            self.stats.misses()
        )
    }
}

/// Longest request line `serve` buffers; anything longer becomes an
/// error line (the rest of the oversized line is drained and discarded)
/// so one runaway client line cannot exhaust memory.
const MAX_REQUEST_LINE_BYTES: usize = 4 << 20;

/// Bounded line read: like `read_until(b'\n')` but stops storing at
/// `cap` bytes while still consuming input through the newline. Returns
/// (bytes consumed, truncated?).
fn read_line_capped(
    input: &mut dyn BufRead,
    buf: &mut Vec<u8>,
    cap: usize,
) -> std::io::Result<(usize, bool)> {
    let mut consumed_total = 0usize;
    let mut truncated = false;
    loop {
        let (consume, done) = {
            let chunk = input.fill_buf()?;
            if chunk.is_empty() {
                break;
            }
            let newline = chunk.iter().position(|&b| b == b'\n');
            let end = newline.map(|ix| ix + 1).unwrap_or(chunk.len());
            let want = newline.unwrap_or(chunk.len());
            let take = cap.saturating_sub(buf.len()).min(want);
            buf.extend_from_slice(&chunk[..take]);
            if take < want {
                truncated = true;
            }
            (end, newline.is_some())
        };
        input.consume(consume);
        consumed_total += consume;
        if done {
            break;
        }
    }
    Ok((consumed_total, truncated))
}

/// Delivery and concurrency options of the serve loop.
#[derive(Debug, Clone)]
pub struct ServeOptions {
    /// Worker threads evaluating requests over one shared [`Session`]
    /// (1 = the serial loop, no pipeline).
    pub threads: usize,
    /// Emit responses in request order (true, the default) or as soon as
    /// each one finishes (false — lowest latency under mixed workloads).
    pub ordered: bool,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions { threads: 1, ordered: true }
    }
}

/// Evaluate one raw request line into a single-line JSON response.
/// `None` marks an oversized (truncated) line. `line_no` is the
/// 1-based *physical* input line (blank and comment lines count), so an
/// operator can jump straight to the offending line of a request file;
/// error lines carry it as `"line"`. Returns the response line and
/// whether it is an error line.
fn respond(session: &Session, payload: Option<&[u8]>, line_no: u64) -> (String, bool) {
    let Some(buf) = payload else {
        return (
            format!(
                "{{\"line\": {line_no}, \"error\": \"request line exceeds {MAX_REQUEST_LINE_BYTES} bytes\"}}"
            ),
            true,
        );
    };
    // lossy: a non-UTF-8 line must yield an error LINE, not kill the
    // stream (the replacement characters fail the JSON parse below)
    let line = String::from_utf8_lossy(buf);
    let trimmed = line.trim();
    // parse ONCE; keep the parsed value so the error path can echo the
    // request id without a second full parse of the line
    let (id, result) = match jsonio::parse(trimmed).context("parsing analysis request") {
        Ok(v) => {
            let id = v.get("id").and_then(|x| x.as_str().map(str::to_string));
            let r = AnalysisRequest::from_json_value(&v).and_then(|req| session.evaluate(&req));
            (id, r)
        }
        Err(e) => (None, Err(e)),
    };
    match result {
        Ok(report) => (report.to_json(), false),
        Err(e) => {
            let mut s = String::from("{");
            if let Some(id) = id {
                s.push_str("\"id\": ");
                s.push_str(&json_str(&id));
                s.push_str(", ");
            }
            s.push_str(&format!("\"line\": {line_no}, "));
            s.push_str("\"error\": ");
            s.push_str(&json_str(&format!("{e:#}")));
            // frontend rejections additionally carry the structured
            // diagnostic (code, span, snippet, hint — docs/SERVE.md)
            if let Some(ke) = e.downcast_ref::<crate::kernel::KernelError>() {
                s.push_str(", \"diagnostic\": ");
                s.push_str(&ke.diag.to_json());
            }
            s.push('}');
            (s, true)
        }
    }
}

/// The `serve` loop with default options (serial, ordered) — see
/// [`serve_with`] for the full contract and docs/SERVE.md for the wire
/// protocol.
pub fn serve(input: &mut dyn BufRead, output: &mut (dyn Write + Send)) -> Result<ServeSummary> {
    serve_with(input, output, &ServeOptions::default())
}

/// The `serve` loop, I/O-parameterized so tests can drive it in-process:
/// read one JSON [`AnalysisRequest`] per input line, stream one JSON
/// [`crate::session::AnalysisReport`] (or `{"error": ...}`) per output
/// line. Blank lines and `#` comments are skipped; a malformed or failing
/// request produces an error line (echoing its `id` when present) without
/// ending the stream. All requests share one [`Session`], so repeated
/// (machine, kernel) pairs hit the cache — the per-request `session`
/// counters in each response show it. The wire protocol is documented
/// end to end in docs/SERVE.md.
///
/// With `opts.threads > 1` requests are evaluated by a worker pool over
/// the shared session (its stage caches sit behind sharded locks): a
/// reader frames and numbers request lines into a *bounded* in-flight
/// queue, workers evaluate them in parallel, and a writer emits
/// responses — in request order by default, or as completed when
/// `opts.ordered` is false (`--unordered`). Either way every request
/// produces exactly one response line carrying its `id`.
///
/// Caching caveat: machine models are cached by *key* (tag or path) for
/// the lifetime of the serve process, while kernel `path` specs are
/// re-read per request (parsing is content-keyed). Editing a machine
/// YAML under a running server therefore has no effect until restart.
/// Resource bounds: request lines are capped (oversized lines become
/// error lines), the session's stage caches are size-bounded, and the
/// in-flight queue is bounded, so a long-running server's memory stays
/// flat under distinct-request traffic.
pub fn serve_with(
    input: &mut dyn BufRead,
    output: &mut (dyn Write + Send),
    opts: &ServeOptions,
) -> Result<ServeSummary> {
    serve_with_session(&Session::new(), input, output, opts)
}

/// The serve loop over a caller-owned [`Session`] — the seam that lets
/// the HTTP front end ([`crate::server`], `POST /stream`) and a
/// `--cache-dir`-backed stdin serve share one session (and therefore
/// one set of stage caches and one persistent report cache) across
/// streams. The returned summary's `stats` snapshot covers the whole
/// session lifetime, not just this stream.
pub fn serve_with_session(
    session: &Session,
    input: &mut dyn BufRead,
    output: &mut (dyn Write + Send),
    opts: &ServeOptions,
) -> Result<ServeSummary> {
    if opts.threads > 1 {
        serve_parallel(session, input, output, opts)
    } else {
        serve_serial(session, input, output)
    }
}

/// Single-threaded serve loop: read, evaluate, respond, flush.
fn serve_serial(
    session: &Session,
    input: &mut dyn BufRead,
    output: &mut (dyn Write + Send),
) -> Result<ServeSummary> {
    let mut summary = ServeSummary::default();
    let mut buf = Vec::new();
    let mut line_no = 0u64;
    loop {
        buf.clear();
        let (consumed, truncated) =
            read_line_capped(input, &mut buf, MAX_REQUEST_LINE_BYTES)?;
        if consumed == 0 {
            break;
        }
        line_no += 1;
        let payload = if truncated {
            None
        } else {
            let line = String::from_utf8_lossy(&buf);
            let trimmed = line.trim();
            if trimmed.is_empty() || trimmed.starts_with('#') {
                continue;
            }
            Some(buf.as_slice())
        };
        summary.requests += 1;
        let (line, is_err) = respond(session, payload, line_no);
        if is_err {
            summary.errors += 1;
        }
        writeln!(output, "{line}")?;
        // stream: one response per request, immediately
        output.flush()?;
    }
    summary.stats = session.stats();
    Ok(summary)
}

/// The writer stage of the parallel serve pipeline: drain completed
/// responses, count error lines, and emit them — immediately when
/// unordered, or reassembled by sequence number when ordered. After each
/// ordered write the shared `written` watermark advances (under its
/// mutex, with a condvar notify), which is what lets the reader bound
/// the reorder buffer.
fn writer_loop(
    res_rx: &std::sync::mpsc::Receiver<(u64, String, bool)>,
    output: &mut (dyn Write + Send),
    ordered: bool,
    written: &(Mutex<u64>, std::sync::Condvar),
) -> std::io::Result<u64> {
    let mut errors = 0u64;
    let mut next = 0u64;
    let mut pending: BTreeMap<u64, String> = BTreeMap::new();
    for (seq, line, is_err) in res_rx.iter() {
        if is_err {
            errors += 1;
        }
        if !ordered {
            writeln!(output, "{line}")?;
            output.flush()?;
            continue;
        }
        // ordered delivery: hold completed responses until every earlier
        // sequence number has been written. The reader throttles itself
        // against the `written` watermark, so this buffer stays bounded
        // even when one slow request holds the head of the line.
        pending.insert(seq, line);
        let mut wrote = false;
        while let Some(line) = pending.remove(&next) {
            writeln!(output, "{line}")?;
            output.flush()?;
            next += 1;
            wrote = true;
        }
        if wrote {
            *written.0.lock().unwrap() = next;
            written.1.notify_all();
        }
    }
    Ok(errors)
}

/// Parallel serve pipeline: reader (this thread) → bounded job queue →
/// worker pool over one shared session → writer thread (ordered
/// reassembly or immediate streaming).
fn serve_parallel(
    session: &Session,
    input: &mut dyn BufRead,
    output: &mut (dyn Write + Send),
    opts: &ServeOptions,
) -> Result<ServeSummary> {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{mpsc, Condvar};

    let threads = opts.threads;
    let ordered = opts.ordered;
    // bounded in-flight queue: the reader blocks once workers fall this
    // far behind, so a fast client cannot queue unbounded memory
    let cap = threads * 4;
    // jobs are (sequence, physical input line, payload)
    let (job_tx, job_rx) = mpsc::sync_channel::<(u64, u64, Option<Vec<u8>>)>(cap);
    let job_rx = Mutex::new(job_rx);
    let (res_tx, res_rx) = mpsc::channel::<(u64, String, bool)>();
    // ordered mode: count of responses written so far (u64::MAX once the
    // writer exits, so nobody waits on progress that cannot come)
    let written = (Mutex::new(0u64), Condvar::new());
    // set when the writer hit an I/O error: the reader stops consuming
    // input instead of silently draining an unbounded stream to EOF
    let writer_dead = AtomicBool::new(false);

    let mut requests = 0u64;
    let mut read_error: Option<std::io::Error> = None;

    let writer_outcome = std::thread::scope(|scope| {
        // writer: owns the output for the whole run
        let writer = {
            let written = &written;
            let writer_dead = &writer_dead;
            scope.spawn(move || {
                let res = writer_loop(&res_rx, output, ordered, written);
                if res.is_err() {
                    writer_dead.store(true, Ordering::Relaxed);
                }
                // wake the reader whatever happened: a finished writer
                // must not leave it waiting on the watermark
                *written.0.lock().unwrap() = u64::MAX;
                written.1.notify_all();
                res
            })
        };

        // workers: evaluate requests through the shared session
        for _ in 0..threads {
            let res_tx = res_tx.clone();
            let job_rx = &job_rx;
            scope.spawn(move || {
                let mut writer_gone = false;
                loop {
                    let job = job_rx.lock().unwrap().recv();
                    let Ok((seq, line_no, payload)) = job else { break };
                    if writer_gone {
                        // writer hit an I/O error: keep draining the job
                        // queue (so the reader never blocks on a full
                        // channel) without evaluating anything
                        continue;
                    }
                    // a panicking evaluation must cost one error line,
                    // not a worker — a shrinking pool would eventually
                    // leave the reader blocked on a full job queue with
                    // nobody draining it
                    let (line, is_err) = catch_unwind(AssertUnwindSafe(|| {
                        respond(session, payload.as_deref(), line_no)
                    }))
                    .unwrap_or_else(|_| {
                        (
                            format!(
                                "{{\"line\": {line_no}, \"error\": \"internal panic evaluating request\"}}"
                            ),
                            true,
                        )
                    });
                    if res_tx.send((seq, line, is_err)).is_err() {
                        writer_gone = true;
                    }
                }
            });
        }
        drop(res_tx);

        // reader (this thread): frame lines, skip blanks and comments,
        // assign sequence numbers
        let max_ahead = (cap + threads) as u64;
        let mut seq = 0u64;
        let mut line_no = 0u64;
        let mut buf = Vec::new();
        loop {
            if writer_dead.load(Ordering::Relaxed) {
                break; // responses can no longer be delivered
            }
            buf.clear();
            match read_line_capped(input, &mut buf, MAX_REQUEST_LINE_BYTES) {
                Ok((0, _)) => break,
                Ok((_, truncated)) => {
                    line_no += 1;
                    let payload = if truncated {
                        None
                    } else {
                        let line = String::from_utf8_lossy(&buf);
                        let trimmed = line.trim();
                        if trimmed.is_empty() || trimmed.starts_with('#') {
                            continue;
                        }
                        Some(buf.clone())
                    };
                    requests += 1;
                    if ordered {
                        // bound the writer's reorder buffer: run at most
                        // max_ahead requests past the last response
                        // written, however fast the input arrives
                        let mut w = written.0.lock().unwrap();
                        while *w != u64::MAX && seq >= *w + max_ahead {
                            w = written.1.wait(w).unwrap();
                        }
                    }
                    if job_tx.send((seq, line_no, payload)).is_err() {
                        break; // every worker exited; nothing can respond
                    }
                    seq += 1;
                }
                Err(e) => {
                    read_error = Some(e);
                    break;
                }
            }
        }
        drop(job_tx);
        writer.join().expect("serve writer panicked")
    });

    let errors = writer_outcome?;
    if let Some(e) = read_error {
        return Err(anyhow::Error::from(e).context("reading request stream"));
    }
    Ok(ServeSummary { requests, errors, stats: session.stats() })
}

/// Run the `serve` subcommand: JSON lines against stdin/stdout (or
/// `--input FILE`), or — with `--listen ADDR` — the HTTP front end of
/// [`crate::server`]. Responses stream directly to stdout / the
/// sockets; the returned string is empty so the binary adds nothing
/// after them.
pub fn run_serve(argv: &[String]) -> Result<String> {
    let args = parse_serve_args(argv)?;
    if let Some(addr) = &args.listen {
        if args.input.is_some() {
            bail!("--listen serves HTTP; --input does not apply (POST the file to /stream)");
        }
        if args.unordered {
            bail!("--unordered applies to the JSON-lines stream, not --listen (HTTP responses are per-request)");
        }
        let idle_timeout = match args.idle_timeout {
            Some(secs) => std::time::Duration::from_secs_f64(secs),
            None => crate::server::DEFAULT_IDLE_TIMEOUT,
        };
        let server = crate::server::Server::bind(crate::server::ServerOptions {
            listen: addr.clone(),
            threads: args.threads.unwrap_or_else(default_http_threads),
            cache_dir: args.cache_dir.as_ref().map(std::path::PathBuf::from),
            max_body_bytes: crate::server::DEFAULT_MAX_BODY_BYTES,
            idle_timeout,
            verbose: args.verbose,
        })?;
        eprintln!("# kerncraft serve: listening on http://{}", server.local_addr());
        server.run()?;
        return Ok(String::new());
    }
    if args.idle_timeout.is_some() {
        bail!("--idle-timeout applies to HTTP keep-alive connections; it needs --listen");
    }
    let session = match &args.cache_dir {
        Some(dir) => Session::with_report_cache(Arc::new(
            crate::server::cache::DiskCache::open(dir)?,
        )),
        None => Session::new(),
    };
    let opts =
        ServeOptions { threads: args.threads.unwrap_or(1), ordered: !args.unordered };
    let mut output = std::io::stdout();
    let summary = match &args.input {
        Some(path) => {
            let file = std::fs::File::open(path)
                .with_context(|| format!("opening request file {path}"))?;
            serve_with_session(
                &session,
                &mut std::io::BufReader::new(file),
                &mut output,
                &opts,
            )?
        }
        None => serve_with_session(
            &session,
            &mut std::io::BufReader::new(std::io::stdin()),
            &mut output,
            &opts,
        )?,
    };
    if args.verbose {
        eprintln!("{summary}");
    }
    Ok(String::new())
}

/// Map a kernel file path to the Table 5 tag used by the native bench.
fn native_tag_for(path: &str) -> Option<&'static str> {
    let stem = std::path::Path::new(path).file_stem()?.to_str()?;
    Some(match stem {
        "2d-5pt" => "2D-5pt",
        "uxx" => "UXX",
        "long-range" => "long-range",
        "kahan-ddot" => "Kahan-dot",
        "triad" => "triad",
        _ => return None,
    })
}

/// Map a kernel file path to the AOT artifact name.
fn pjrt_name_for(path: &str) -> Option<&'static str> {
    let stem = std::path::Path::new(path).file_stem()?.to_str()?;
    Some(match stem {
        "2d-5pt" => "jacobi2d",
        "uxx" => "uxx",
        "long-range" => "long_range",
        "kahan-ddot" => "kahan_ddot",
        "triad" => "triad",
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(str::to_string).collect()
    }

    #[test]
    fn parses_paper_invocation() {
        let a = parse_args(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert_eq!(a.mode, Mode::Model(ModelKind::Ecm));
        assert_eq!(a.machine, "SNB");
        assert_eq!(a.constants["N"], 6000);
        assert_eq!(a.cores, 1);
        assert_eq!(a.kernel_path.as_deref(), Some("kernels/2d-5pt.c"));
        assert_eq!(a.cache_predictor, CachePredictorKind::Offsets);
        assert_eq!(a.format, OutputFormat::Text);
    }

    #[test]
    fn roofline_iaca_alias() {
        let a = parse_args(&argv("-p RooflineIACA k.c")).unwrap();
        assert_eq!(a.mode, Mode::Model(ModelKind::RooflinePort));
    }

    #[test]
    fn validate_mode_runs_end_to_end() {
        let out = run(&argv("-p Validate -m SNB kernels/triad.c -D N 400000")).unwrap();
        assert!(out.contains("ECM model: {"), "{out}");
        assert!(out.contains("model validation (virtual testbed vs analytic ECM)"), "{out}");
        assert!(out.contains("model error:"), "{out}");
    }

    #[test]
    fn rejects_unknown_mode_and_flag() {
        assert!(parse_args(&argv("-p Nope k.c")).is_err());
        assert!(parse_args(&argv("--frobnicate k.c")).is_err());
    }

    #[test]
    fn unit_flag() {
        let a = parse_args(&argv("-p ECM --unit FLOP/s k.c")).unwrap();
        assert_eq!(a.unit, Unit::FlopPerS);
        // case-insensitive spellings are accepted
        let a = parse_args(&argv("-p ECM --unit it/S k.c")).unwrap();
        assert_eq!(a.unit, Unit::ItPerS);
    }

    #[test]
    fn unknown_unit_error_lists_valid_spellings() {
        let err = parse_args(&argv("-p ECM --unit parsecs k.c")).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("parsecs"), "{msg}");
        assert!(msg.contains("cy/CL"), "{msg}");
        assert!(msg.contains("It/s"), "{msg}");
        assert!(msg.contains("FLOP/s"), "{msg}");
    }

    #[test]
    fn cache_predictor_flag() {
        let a = parse_args(&argv("-p ECM --cache-predictor auto k.c")).unwrap();
        assert_eq!(a.cache_predictor, CachePredictorKind::Auto);
        assert!(parse_args(&argv("-p ECM --cache-predictor nope k.c")).is_err());
    }

    #[test]
    fn format_flag() {
        let a = parse_args(&argv("-p ECM --format json k.c")).unwrap();
        assert_eq!(a.format, OutputFormat::Json);
        assert!(parse_args(&argv("-p ECM --format xml k.c")).is_err());
    }

    #[test]
    fn sim_engine_flag() {
        let a = parse_args(&argv("-p Validate k.c")).unwrap();
        assert_eq!(a.sim_engine, crate::sim::SimEngine::Fast, "fast is the default");
        let a = parse_args(&argv("-p Validate --sim-engine reference k.c")).unwrap();
        assert_eq!(a.sim_engine, crate::sim::SimEngine::Reference);
        let req = request_from_args(&a).unwrap().unwrap();
        assert_eq!(req.sim_engine, crate::sim::SimEngine::Reference);
        assert!(parse_args(&argv("-p Validate --sim-engine warp k.c")).is_err());
    }

    #[test]
    fn json_format_refuses_text_only_sections() {
        for extra in ["--machine-report", "--cache-viz", "-v"] {
            let err = run(&argv(&format!(
                "-p ECM -m SNB kernels/triad.c -D N 1000 --format json {extra}"
            )))
            .unwrap_err();
            assert!(format!("{err}").contains("--format json"), "{extra}: {err}");
        }
        let err = run(&argv(
            "-p Benchmark -m SNB kernels/triad.c -D N 1000 --format json",
        ))
        .unwrap_err();
        assert!(format!("{err}").contains("Benchmark"), "{err}");
    }

    #[test]
    fn benchmark_verbose_shows_analysis_tables() {
        let out = run(&argv("-p Benchmark -m SNB kernels/triad.c -D N 400000 -v")).unwrap();
        assert!(out.contains("loop stack"), "{out}");
        assert!(out.contains("virtual testbed"), "{out}");
    }

    #[test]
    fn end_to_end_ecm_run_matches_listing5() {
        // paper Listing 5 invocation against the shipped kernel corpus
        let out = run(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert!(out.contains("ECM model"), "{out}");
        assert!(out.contains("saturating at 3 cores"), "{out}");
    }

    #[test]
    fn ecm_run_with_auto_predictor_matches_offsets() {
        let base = "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000";
        let walk = run(&argv(base)).unwrap();
        let auto = run(&argv(&format!("{base} --cache-predictor auto"))).unwrap();
        assert_eq!(walk, auto, "auto predictor must not change the report");
    }

    #[test]
    fn json_format_emits_one_parseable_report() {
        let out = run(&argv(
            "-p ECM --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 --format json",
        ))
        .unwrap();
        assert_eq!(out.lines().count(), 1, "{out}");
        let report =
            crate::session::AnalysisReport::from_json(out.trim()).unwrap();
        assert_eq!(report.kernel, "2d-5pt");
        assert_eq!(report.model, ModelKind::Ecm);
        assert_eq!(report.constants["N"], 6000);
        let ecm = report.ecm.expect("ECM section");
        assert!((ecm.t_mem - 36.7).abs() < 0.8, "{}", ecm.t_mem);
        assert_eq!(report.scaling.unwrap().saturation_cores, Some(3));
    }

    #[test]
    fn json_format_roofline() {
        let out = run(&argv(
            "-p RooflinePort -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 --format json",
        ))
        .unwrap();
        let report =
            crate::session::AnalysisReport::from_json(out.trim()).unwrap();
        let rf = report.roofline.expect("roofline section");
        assert!(rf.memory_bound);
        assert_eq!(rf.ceilings[rf.bottleneck].level, "L3-MEM");
    }

    #[test]
    fn end_to_end_roofline_run() {
        let out = run(&argv(
            "-p RooflinePort --unit cy/CL --cores 1 -m SNB kernels/2d-5pt.c -D N 6000 -D M 6000 -v",
        ))
        .unwrap();
        assert!(out.contains("Bottlenecks"), "{out}");
        assert!(out.contains("Cache or mem bound"), "{out}");
    }

    #[test]
    fn benchmark_virtual_mode_runs() {
        let out = run(&argv(
            "-p Benchmark -m SNB kernels/triad.c -D N 500000",
        ))
        .unwrap();
        assert!(out.contains("virtual testbed"), "{out}");
    }

    #[test]
    fn machine_report_standalone() {
        let out = run(&argv("--machine-report -m HSW")).unwrap();
        assert!(out.contains("HSW"), "{out}");
    }

    #[test]
    fn mapping_tables() {
        assert_eq!(native_tag_for("kernels/2d-5pt.c"), Some("2D-5pt"));
        assert_eq!(pjrt_name_for("kernels/long-range.c"), Some("long_range"));
        assert_eq!(native_tag_for("kernels/custom.c"), None);
    }

    #[test]
    fn parses_sweep_invocation() {
        let a = parse_sweep_args(&argv(
            "-m SNB,HSW kernels/2d-5pt.c -D N 128:1k:log2 -D M 4000 --cores 1,2 --predictor lc --format json --threads 3",
        ))
        .unwrap();
        assert_eq!(a.machines, vec!["SNB", "HSW"]);
        assert_eq!(a.kernel_path.as_deref(), Some("kernels/2d-5pt.c"));
        assert_eq!(a.axes.len(), 2);
        assert_eq!(a.axes[0].0, "N");
        assert_eq!(a.axes[0].1, vec![128, 256, 512, 1024]);
        assert_eq!(a.axes[1].1, vec![4000]);
        assert_eq!(a.cores, vec![1, 2]);
        assert_eq!(a.predictor, CachePredictorKind::LayerConditions);
        assert_eq!(a.format, SweepFormat::Json);
        assert_eq!(a.threads, Some(3));
        assert!(!a.validate);
        assert!(!a.advise);
        let a = parse_sweep_args(&argv("k.c -D N 1 --validate")).unwrap();
        assert!(a.validate);
        let a = parse_sweep_args(&argv("k.c -D N 1 --advise")).unwrap();
        assert!(a.advise);
        let err = run_sweep(&argv("kernels/2d-5pt.c -D N 1000 -D M 1000 --validate --advise"))
            .unwrap_err();
        assert!(format!("{err}").contains("mutually exclusive"), "{err}");
    }

    #[test]
    fn advise_subcommand_prints_breakpoints_and_advice() {
        let out = run(&argv(
            "advise kernels/2d-5pt.c -m SNB -D N 6000 -D M 6000",
        ))
        .unwrap();
        assert!(out.contains("blocking advice"), "{out}");
        assert!(out.contains("1. block i at 1024: unlocks j@L1"), "{out}");
        // tags work like in sweep, and JSON mode emits the full report
        let out = run(&argv("advise 2D-5pt -m SNB -D N 6000 -D M 6000 --format json")).unwrap();
        let report = crate::session::AnalysisReport::from_json(out.trim()).unwrap();
        assert_eq!(report.model, ModelKind::Advise);
        let a = report.advise.expect("advise section");
        assert_eq!(a.walk_levels, 0, "the advise path must stay analytic");
        assert_eq!(a.candidates[0].extent, 1024);
    }

    #[test]
    fn sweep_advise_rows_carry_block_columns() {
        let out = run_sweep(&argv(
            "kernels/2d-5pt.c -m SNB -D N 6000 -D M 6000 --advise --serial",
        ))
        .unwrap();
        let header = out.lines().next().unwrap();
        assert!(header.ends_with(",lc_bands,advise_block,advise_t_mem"), "{header}");
        assert!(out.lines().nth(1).unwrap().contains(",1024,"), "{out}");
    }

    #[test]
    fn sweep_rejects_bad_specs() {
        assert!(parse_sweep_args(&argv("k.c -D N 10:5:log2")).is_err());
        assert!(parse_sweep_args(&argv("k.c -D N 1 -D N 2")).is_err());
        assert!(parse_sweep_args(&argv("k.c --format xml")).is_err());
        assert!(run_sweep(&argv("kernels/triad.c")).is_err(), "missing -D axis");
    }

    #[test]
    fn parses_serve_invocation() {
        let a = parse_serve_args(&argv("--input reqs.jsonl -v")).unwrap();
        assert_eq!(a.input.as_deref(), Some("reqs.jsonl"));
        assert!(a.verbose);
        assert_eq!(a.threads, None, "mode default: serial stream, multi-worker HTTP");
        assert!(!a.unordered, "ordered by default");
        let a = parse_serve_args(&argv("reqs.jsonl")).unwrap();
        assert_eq!(a.input.as_deref(), Some("reqs.jsonl"));
        let a = parse_serve_args(&argv("--threads 4 --unordered")).unwrap();
        assert_eq!(a.threads, Some(4));
        assert!(a.unordered);
        assert!(default_http_threads() >= 2, "HTTP default leaves headroom for /healthz");
        assert!(a.listen.is_none() && a.cache_dir.is_none());
        let a = parse_serve_args(&argv("--listen 127.0.0.1:9000 --cache-dir /tmp/kc --threads 4"))
            .unwrap();
        assert_eq!(a.listen.as_deref(), Some("127.0.0.1:9000"));
        assert_eq!(a.cache_dir.as_deref(), Some("/tmp/kc"));
        assert_eq!(a.idle_timeout, None, "server default when the flag is absent");
        let a = parse_serve_args(&argv("--listen 127.0.0.1:0 --idle-timeout 2.5")).unwrap();
        assert_eq!(a.idle_timeout, Some(2.5));
        assert!(parse_serve_args(&argv("--idle-timeout 0")).is_err());
        assert!(parse_serve_args(&argv("--idle-timeout -3")).is_err());
        assert!(parse_serve_args(&argv("--idle-timeout soon")).is_err());
        assert!(parse_serve_args(&argv("--idle-timeout")).is_err());
        assert!(parse_serve_args(&argv("--listen")).is_err());
        assert!(parse_serve_args(&argv("--cache-dir")).is_err());
        assert!(parse_serve_args(&argv("--threads 0")).is_err());
        assert!(parse_serve_args(&argv("--threads")).is_err());
        assert!(parse_serve_args(&argv("--bogus")).is_err());
        assert!(parse_serve_args(&argv("a.jsonl b.jsonl")).is_err());
    }

    #[test]
    fn serve_streams_reports_and_error_lines() {
        let input = "\n\
            # comment line\n\
            {\"id\": \"ok\", \"kernel\": {\"name\": \"triad\"}, \"machine\": \"SNB\", \"constants\": {\"N\": 100000}}\n\
            {\"id\": \"bad\", \"kernel\": {\"name\": \"nope\"}, \"machine\": \"SNB\"}\n\
            not json at all\n";
        let mut output = Vec::new();
        let summary = serve(&mut input.as_bytes(), &mut output).unwrap();
        assert_eq!(summary.requests, 3);
        assert_eq!(summary.errors, 2);
        let text = String::from_utf8(output).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "{text}");
        let ok = crate::session::AnalysisReport::from_json(lines[0]).unwrap();
        assert_eq!(ok.id.as_deref(), Some("ok"));
        assert!(lines[1].contains("\"id\": \"bad\""), "{}", lines[1]);
        assert!(lines[1].contains("\"error\""), "{}", lines[1]);
        assert!(lines[2].contains("\"error\""), "{}", lines[2]);
        // error lines name the offending PHYSICAL input line (blanks and
        // comments count), so operators can jump straight to it
        assert!(lines[1].contains("\"line\": 4"), "{}", lines[1]);
        assert!(lines[2].contains("\"line\": 5"), "{}", lines[2]);
    }

    #[test]
    fn serve_reports_line_numbers_for_oversized_lines() {
        let mut input = Vec::new();
        input.extend_from_slice(b"# header comment\n");
        input.extend_from_slice(&vec![b'A'; MAX_REQUEST_LINE_BYTES + 10]);
        input.push(b'\n');
        let mut output = Vec::new();
        let summary = serve(&mut input.as_slice(), &mut output).unwrap();
        assert_eq!(summary.requests, 1);
        assert_eq!(summary.errors, 1);
        let text = String::from_utf8(output).unwrap();
        assert!(text.contains("\"line\": 2"), "{text}");
        assert!(text.contains("exceeds"), "{text}");
    }

    #[test]
    fn serve_rejects_conflicting_listen_flags() {
        let err = run_serve(&argv("--listen 127.0.0.1:0 --input reqs.jsonl")).unwrap_err();
        assert!(format!("{err}").contains("--listen"), "{err}");
        let err = run_serve(&argv("--listen 127.0.0.1:0 --unordered")).unwrap_err();
        assert!(format!("{err}").contains("--unordered"), "{err}");
        let err = run_serve(&argv("--input reqs.jsonl --idle-timeout 5")).unwrap_err();
        assert!(format!("{err}").contains("--listen"), "{err}");
    }

    #[test]
    fn capped_line_reader_truncates_and_drains() {
        let data: &[u8] = b"short\nAAAAAAAAAAAAAAAAAAAA\nnext\n";
        let mut r = data;
        let mut buf = Vec::new();
        let (n, t) = read_line_capped(&mut r, &mut buf, 8).unwrap();
        assert_eq!((n, t), (6, false));
        assert_eq!(buf, b"short");
        buf.clear();
        let (_, t) = read_line_capped(&mut r, &mut buf, 8).unwrap();
        assert!(t, "20 As exceed the cap");
        assert_eq!(buf.len(), 8, "stored bytes stay capped");
        buf.clear();
        let (_, t) = read_line_capped(&mut r, &mut buf, 8).unwrap();
        assert!(!t, "the oversized line was fully drained");
        assert_eq!(buf, b"next");
        buf.clear();
        let (n, _) = read_line_capped(&mut r, &mut buf, 8).unwrap();
        assert_eq!(n, 0, "EOF");
    }

    #[test]
    fn serve_survives_non_utf8_lines() {
        // a non-UTF-8 byte line yields an error LINE, not a dead stream
        let mut input: &[u8] = b"\xff\xfe not utf8\n{\"kernel\": {\"name\": \"triad\"}, \"machine\": \"SNB\", \"constants\": {\"N\": 4096}}\n";
        let mut output = Vec::new();
        let summary = serve(&mut input, &mut output).unwrap();
        assert_eq!(summary.requests, 2);
        assert_eq!(summary.errors, 1);
        let text = String::from_utf8(output).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert!(lines[0].contains("\"error\""), "{text}");
        assert!(lines[1].contains("\"kernel\": \"triad\""), "{text}");
    }
}
