//! Batched sweep engine — the paper's headline use case ("quickly gain
//! insights by accelerated analytic modeling") industrialized: evaluate a
//! whole grid of (kernel source × constants × machine × cores) points,
//! in parallel, as a map of typed [`AnalysisRequest`]s through one shared
//! [`Session`].
//!
//! The session owns every stage cache (parsed programs, kernel analyses,
//! in-core models, machine files — see [`crate::session`]), so per-point
//! work reduces to the cache prediction (which the layer-condition fast
//! path of [`crate::cache`] answers analytically for decisive levels) and
//! the ECM assembly. Results are bit-identical to evaluating the requests
//! one by one against a fresh session: every stage is a pure function of
//! its inputs, memoized or not.
//!
//! Grid axes use the CLI syntax `start:end:spec` (`-D N 128:8M:log2`),
//! see [`parse_grid`].

use crate::cache::CachePredictorKind;
use crate::models::Unit;
use crate::session::{
    AnalysisReport, AnalysisRequest, CodegenSelection, KernelSpec, ModelKind, Session,
};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

pub use crate::session::MemoStats;

/// One point of a sweep: a kernel source at one constants binding on one
/// machine with one core count.
#[derive(Debug, Clone)]
pub struct SweepJob {
    /// Display label (kernel tag or file stem).
    pub label: String,
    /// Kernel source text (share one `Arc` across the grid).
    pub source: Arc<str>,
    /// Machine key: builtin tag ("SNB"/"HSW") or a machine-file path.
    pub machine: String,
    /// Active cores (shared caches are partitioned accordingly).
    pub cores: u32,
    /// Constant bindings (ordered, so memo keys are stable).
    pub constants: BTreeMap<String, i64>,
    /// Cache predictor back end for this point.
    pub predictor: CachePredictorKind,
    /// Model evaluated per point: [`ModelKind::Ecm`] (the default sweep
    /// contract) or [`ModelKind::Validate`] to also run the virtual
    /// testbed and carry the simulated-vs-analytic comparison in the row.
    pub model: ModelKind,
}

impl SweepJob {
    /// The typed session request this point maps to (ECM or Validate
    /// model, machine-default codegen — the sweep contract).
    pub fn request(&self) -> AnalysisRequest {
        AnalysisRequest {
            id: None,
            kernel: KernelSpec::Source {
                label: self.label.clone(),
                source: self.source.clone(),
            },
            constants: self.constants.clone(),
            machine: self.machine.clone(),
            cores: self.cores,
            model: self.model,
            predictor: self.predictor,
            codegen: CodegenSelection::MachineDefault,
            // --validate rows ride the fast engine: sweeps evaluate many
            // Validate points, exactly the workload the compressed-trace
            // testbed exists for (`--sim-engine reference` is a single-run
            // debugging tool, not a sweep contract)
            sim_engine: crate::sim::SimEngine::Fast,
            unit: Unit::CyPerCl,
        }
    }
}

/// One evaluated sweep point.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepRow {
    pub label: String,
    pub machine: String,
    pub cores: u32,
    pub constants: BTreeMap<String, i64>,
    pub predictor: CachePredictorKind,
    /// Inner iterations per unit of work.
    pub unit_iterations: u64,
    pub t_ol: f64,
    pub t_nol: f64,
    /// Dependency-DAG critical path per unit of work (OSACA "CP").
    pub cp_cy: f64,
    /// Loop-carried dependency bound per unit of work (OSACA "LCD").
    pub lcd_cy: f64,
    /// Per-link (name, cache lines, cycles) contributions, inner first.
    pub links: Vec<(String, f64, f64)>,
    /// In-memory ECM prediction (cy/CL).
    pub t_ecm_mem: f64,
    /// ECM saturation core count.
    pub saturation_cores: u32,
    /// Memory traffic per unit of work in bytes.
    pub memory_bytes_per_unit: f64,
    /// Cache levels answered by the layer-condition fast path.
    pub lc_fast_levels: u32,
    /// Cache levels that ran the backward offset walk.
    pub walk_levels: u32,
    /// Per loop dimension: innermost cache level whose layer condition
    /// holds, e.g. `"j@L2"` (`"j@MEM"` when none does) — the Fig. 3
    /// breakpoint bands.
    pub lc_breakpoints: Vec<String>,
    /// Simulated cy/CL from the virtual testbed (Validate points only).
    pub sim_cy_per_cl: Option<f64>,
    /// Relative model error % vs the simulation (Validate points only).
    pub model_error_pct: Option<f64>,
    /// Best advised block extent of the inner dimension (Advise points
    /// with at least one viable candidate only).
    pub advise_block: Option<u64>,
    /// Predicted in-memory ECM time at that block (Advise points only).
    pub advise_t_mem: Option<f64>,
}

/// Result of an engine run.
#[derive(Debug, Clone)]
pub struct SweepOutput {
    /// One row per job, in job order.
    pub rows: Vec<SweepRow>,
    pub stats: MemoStats,
    /// Worker threads actually used.
    pub threads_used: usize,
}

/// The parallel sweep engine: a thread pool mapping jobs through one
/// shared [`Session`].
pub struct SweepEngine {
    threads: usize,
}

impl SweepEngine {
    /// Engine with one worker per available hardware thread.
    pub fn new() -> SweepEngine {
        let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        SweepEngine { threads }
    }

    /// Single-threaded engine (still memoized) — the baseline for the
    /// parallel-vs-serial equivalence guarantee.
    pub fn serial() -> SweepEngine {
        SweepEngine { threads: 1 }
    }

    /// Engine with an explicit worker count.
    pub fn with_threads(threads: usize) -> SweepEngine {
        SweepEngine { threads: threads.max(1) }
    }

    /// Evaluate all jobs through a fresh [`Session`]; rows come back in
    /// job order. Any failing point fails the sweep with its job context
    /// attached.
    pub fn run(&self, jobs: &[SweepJob]) -> Result<SweepOutput> {
        self.run_with_session(&Session::new(), jobs)
    }

    /// Evaluate all jobs through an existing (possibly warm) session.
    /// `SweepOutput::stats` reports only this run's hits and misses (the
    /// sum of per-request deltas), regardless of session warmth.
    pub fn run_with_session(&self, session: &Session, jobs: &[SweepJob]) -> Result<SweepOutput> {
        let next = AtomicUsize::new(0);
        let results: Vec<Mutex<Option<Result<AnalysisReport>>>> =
            jobs.iter().map(|_| Mutex::new(None)).collect();
        let threads = self.threads.min(jobs.len()).max(1);

        std::thread::scope(|scope| {
            for _ in 0..threads {
                scope.spawn(|| loop {
                    let ix = next.fetch_add(1, Ordering::Relaxed);
                    if ix >= jobs.len() {
                        break;
                    }
                    let report = session.evaluate(&jobs[ix].request());
                    *results[ix].lock().unwrap() = Some(report);
                });
            }
        });

        let mut rows = Vec::with_capacity(jobs.len());
        let mut stats = MemoStats::default();
        for (ix, slot) in results.into_iter().enumerate() {
            let r = slot
                .into_inner()
                .unwrap()
                .unwrap_or_else(|| Err(anyhow!("job was never evaluated")));
            let job = &jobs[ix];
            let report = r.with_context(|| {
                format!(
                    "sweep point {} on {} ({} cores, {:?})",
                    job.label, job.machine, job.cores, job.constants
                )
            })?;
            stats.absorb(report.session);
            rows.push(row_from_report(job, &report));
        }
        Ok(SweepOutput { rows, stats, threads_used: threads })
    }
}

impl Default for SweepEngine {
    fn default() -> Self {
        SweepEngine::new()
    }
}

/// Project one evaluated report onto the flat sweep-row shape.
fn row_from_report(job: &SweepJob, r: &AnalysisReport) -> SweepRow {
    let ecm = r.ecm.as_ref().expect("sweep requests the full ECM model");
    let traffic = r.traffic.as_ref().expect("the ECM model carries traffic");
    SweepRow {
        label: job.label.clone(),
        machine: job.machine.clone(),
        cores: job.cores,
        constants: job.constants.clone(),
        predictor: job.predictor,
        unit_iterations: r.unit_iterations,
        t_ol: ecm.t_ol,
        t_nol: ecm.t_nol,
        cp_cy: r.incore.as_ref().map(|i| i.cp_cy).unwrap_or(0.0),
        lcd_cy: r.incore.as_ref().map(|i| i.lcd_cy).unwrap_or(0.0),
        links: ecm
            .contributions
            .iter()
            .map(|ct| (ct.link.clone(), ct.lines, ct.cycles))
            .collect(),
        t_ecm_mem: ecm.t_mem,
        saturation_cores: ecm.saturation_cores.unwrap_or(u32::MAX),
        memory_bytes_per_unit: traffic.memory_bytes_per_unit,
        lc_fast_levels: traffic.lc_fast_levels,
        walk_levels: traffic.walk_levels,
        lc_breakpoints: traffic.lc_breakpoints.clone(),
        sim_cy_per_cl: r.validation.as_ref().map(|v| v.sim_cy_per_cl),
        model_error_pct: r.validation.as_ref().map(|v| v.model_error_pct),
        advise_block: r.advise.as_ref().and_then(|a| a.candidates.first()).map(|c| c.extent),
        advise_t_mem: r.advise.as_ref().and_then(|a| a.candidates.first()).map(|c| c.t_mem),
    }
}

/// Parse one grid axis:
///
/// * `4096` — a single value,
/// * `128:8M:log2` — geometric, doubling from 128 up to 8·1024² inclusive,
/// * `16:4096:*4` — geometric with factor 4,
/// * `10:100:+30` — arithmetic with step 30.
///
/// Values take binary magnitude suffixes `k`, `M`, `G` (1024-based).
pub fn parse_grid(spec: &str) -> Result<Vec<i64>> {
    let parts: Vec<&str> = spec.split(':').collect();
    match parts.as_slice() {
        [one] => Ok(vec![parse_size_value(one)?]),
        [start, end] => grid_points(parse_size_value(start)?, parse_size_value(end)?, Step::Mul(2)),
        [start, end, step] => {
            let step = if *step == "log2" {
                Step::Mul(2)
            } else if let Some(f) = step.strip_prefix('*') {
                let f: i64 = f.parse().with_context(|| format!("bad grid factor '{step}'"))?;
                if f < 2 {
                    bail!("grid factor must be >= 2, got {f}");
                }
                Step::Mul(f)
            } else if let Some(a) = step.strip_prefix('+') {
                let a = parse_size_value(a)?;
                if a <= 0 {
                    bail!("grid step must be positive, got {a}");
                }
                Step::Add(a)
            } else {
                bail!("unknown grid step '{step}' (use log2, *K, or +K)");
            };
            grid_points(parse_size_value(start)?, parse_size_value(end)?, step)
        }
        _ => bail!("bad grid spec '{spec}' (use VALUE or START:END[:log2|*K|+K])"),
    }
}

enum Step {
    Mul(i64),
    Add(i64),
}

fn grid_points(start: i64, end: i64, step: Step) -> Result<Vec<i64>> {
    if start <= 0 {
        bail!("grid start must be positive, got {start}");
    }
    if end < start {
        bail!("grid end {end} is below start {start}");
    }
    let mut out = Vec::new();
    let mut v = start;
    while v <= end {
        out.push(v);
        let next = match step {
            Step::Mul(f) => v.checked_mul(f),
            Step::Add(a) => v.checked_add(a),
        };
        match next {
            Some(n) if n > v => v = n,
            _ => break,
        }
        if out.len() > 100_000 {
            bail!("grid has more than 100000 points — check the spec");
        }
    }
    Ok(out)
}

/// Parse `8M`-style values: binary suffixes k (1024), M, G.
pub fn parse_size_value(s: &str) -> Result<i64> {
    let s = s.trim();
    let (num, mult) = if let Some(n) = s.strip_suffix(['k', 'K']) {
        (n, 1024i64)
    } else if let Some(n) = s.strip_suffix('M') {
        (n, 1024 * 1024)
    } else if let Some(n) = s.strip_suffix('G') {
        (n, 1024 * 1024 * 1024)
    } else {
        (s, 1)
    };
    let v: i64 = num.trim().parse().with_context(|| format!("bad grid value '{s}'"))?;
    v.checked_mul(mult).ok_or_else(|| anyhow!("grid value '{s}' overflows"))
}

/// Cartesian product of named grid axes into per-point constant bindings,
/// in row-major (last axis fastest) order.
pub fn expand_constants(axes: &[(String, Vec<i64>)]) -> Vec<BTreeMap<String, i64>> {
    let mut out: Vec<BTreeMap<String, i64>> = vec![BTreeMap::new()];
    for (name, values) in axes {
        let mut next = Vec::with_capacity(out.len() * values.len());
        for base in &out {
            for v in values {
                let mut m = base.clone();
                m.insert(name.clone(), *v);
                next.push(m);
            }
        }
        out = next;
    }
    out
}

/// Build the job list for a full sweep: every machine × core count ×
/// constants-grid point of one kernel source. Jobs default to the ECM
/// model; set [`SweepJob::model`] to [`ModelKind::Validate`] per job (or
/// pass `--validate` to the CLI subcommand) for simulated-vs-analytic
/// rows.
pub fn build_jobs(
    label: &str,
    source: Arc<str>,
    machines: &[String],
    cores: &[u32],
    axes: &[(String, Vec<i64>)],
    predictor: CachePredictorKind,
) -> Vec<SweepJob> {
    let bindings = expand_constants(axes);
    let mut jobs = Vec::new();
    for machine in machines {
        for &c in cores {
            for b in &bindings {
                jobs.push(SweepJob {
                    label: label.to_string(),
                    source: source.clone(),
                    machine: machine.clone(),
                    cores: c,
                    constants: b.clone(),
                    predictor,
                    model: ModelKind::Ecm,
                });
            }
        }
    }
    jobs
}

#[cfg(test)]
mod tests {
    use super::*;

    const TRIAD: &str =
        "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";

    fn triad_jobs(ns: &[i64], predictor: CachePredictorKind) -> Vec<SweepJob> {
        let src: Arc<str> = Arc::from(TRIAD);
        build_jobs(
            "triad",
            src,
            &["SNB".to_string()],
            &[1],
            &[("N".to_string(), ns.to_vec())],
            predictor,
        )
    }

    #[test]
    fn grid_parsing() {
        assert_eq!(parse_grid("4096").unwrap(), vec![4096]);
        assert_eq!(parse_grid("128:1k:log2").unwrap(), vec![128, 256, 512, 1024]);
        assert_eq!(parse_grid("16:256:*4").unwrap(), vec![16, 64, 256]);
        assert_eq!(parse_grid("10:70:+30").unwrap(), vec![10, 40, 70]);
        assert_eq!(parse_grid("8M").unwrap(), vec![8 * 1024 * 1024]);
        assert_eq!(parse_grid("1:2:log2").unwrap(), vec![1, 2]);
        assert!(parse_grid("10:5:log2").is_err());
        assert!(parse_grid("0:5:log2").is_err());
        assert!(parse_grid("1:5:*1").is_err());
        assert!(parse_grid("1:5:+0").is_err());
        assert!(parse_grid("1:5:frobnicate").is_err());
        assert!(parse_grid("a:b:c:d").is_err());
    }

    #[test]
    fn grid_endpoint_inclusive_when_hit_exactly() {
        assert_eq!(parse_grid("128:8M:log2").unwrap().len(), 17); // 2^7..2^23
    }

    #[test]
    fn cartesian_expansion_order() {
        let axes = vec![
            ("N".to_string(), vec![1i64, 2]),
            ("M".to_string(), vec![10i64, 20]),
        ];
        let b = expand_constants(&axes);
        assert_eq!(b.len(), 4);
        assert_eq!(b[0][&"N".to_string()], 1);
        assert_eq!(b[0][&"M".to_string()], 10);
        assert_eq!(b[1][&"M".to_string()], 20);
        assert_eq!(b[3][&"N".to_string()], 2);
    }

    #[test]
    fn parallel_rows_equal_serial_rows() {
        let ns: Vec<i64> = (10..18).map(|e| 1i64 << e).collect();
        let jobs = triad_jobs(&ns, CachePredictorKind::Auto);
        let serial = SweepEngine::serial().run(&jobs).unwrap();
        let parallel = SweepEngine::with_threads(8).run(&jobs).unwrap();
        assert_eq!(serial.rows, parallel.rows, "bit-identical rows required");
        assert_eq!(serial.rows.len(), ns.len());
    }

    #[test]
    fn memoization_counts() {
        // one source, 4 sizes, evaluated under two predictors: the second
        // predictor pass hits every per-(source,constants,machine) cache.
        let ns = [4096i64, 8192, 16384, 32768];
        let mut jobs = triad_jobs(&ns, CachePredictorKind::Offsets);
        jobs.extend(triad_jobs(&ns, CachePredictorKind::Auto));
        let out = SweepEngine::serial().run(&jobs).unwrap();
        assert_eq!(out.rows.len(), 8);
        assert_eq!(out.stats.program_misses, 1, "{:?}", out.stats);
        assert_eq!(out.stats.program_hits, 7);
        assert_eq!(out.stats.machine_misses, 1);
        assert_eq!(out.stats.analysis_misses, 4);
        assert_eq!(out.stats.analysis_hits, 4);
        assert_eq!(out.stats.incore_misses, 4);
        assert_eq!(out.stats.incore_hits, 4);
        // and the two predictor passes agree point by point
        for (a, b) in out.rows[..4].iter().zip(&out.rows[4..]) {
            assert_eq!(a.t_ecm_mem, b.t_ecm_mem);
            assert_eq!(a.links, b.links);
        }
    }

    #[test]
    fn sweep_rows_match_direct_pipeline() {
        // engine output == running the stages by hand (the serial
        // equivalence guarantee of the acceptance criteria)
        use crate::cache::CachePredictor;
        use crate::incore::{CodegenPolicy, PortModel};
        use crate::kernel::KernelAnalysis;
        use crate::machine::MachineModel;
        use crate::models::EcmModel;
        use std::collections::HashMap;
        let jobs = triad_jobs(&[1 << 20], CachePredictorKind::Offsets);
        let out = SweepEngine::serial().run(&jobs).unwrap();
        let row = &out.rows[0];

        let m = MachineModel::snb();
        let p = crate::kernel::parse(TRIAD).unwrap();
        let consts: HashMap<String, i64> =
            [("N".to_string(), 1i64 << 20)].into_iter().collect();
        let a = KernelAnalysis::from_program(&p, &consts).unwrap();
        let pm = PortModel::analyze(&a, &m, &CodegenPolicy::for_machine(&m)).unwrap();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        let e = EcmModel::build(&pm, &t, &m).unwrap();
        assert_eq!(row.t_ol, e.t_ol);
        assert_eq!(row.t_nol, e.t_nol);
        assert_eq!(row.t_ecm_mem, e.t_mem());
        for (l, c) in row.links.iter().zip(&e.contributions) {
            assert_eq!(l.0, c.link);
            assert_eq!(l.1, c.lines);
            assert_eq!(l.2, c.cycles);
        }
    }

    #[test]
    fn failing_point_reports_its_context() {
        let src: Arc<str> = Arc::from(TRIAD);
        let jobs = vec![SweepJob {
            label: "triad".into(),
            source: src,
            machine: "SNB".into(),
            cores: 1,
            constants: BTreeMap::new(), // N unbound
            predictor: CachePredictorKind::Auto,
            model: ModelKind::Ecm,
        }];
        let err = SweepEngine::serial().run(&jobs).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("sweep point triad"), "{msg}");
        assert!(msg.contains("unbound constant"), "{msg}");
    }

    #[test]
    fn validate_jobs_carry_sim_columns() {
        let mut jobs = triad_jobs(&[262144], CachePredictorKind::Auto);
        jobs.extend(triad_jobs(&[262144], CachePredictorKind::Auto));
        jobs[1].model = ModelKind::Validate;
        let out = SweepEngine::serial().run(&jobs).unwrap();
        // the plain ECM point has no simulation columns
        assert_eq!(out.rows[0].sim_cy_per_cl, None);
        assert_eq!(out.rows[0].model_error_pct, None);
        // the Validate point carries both, and the analytic figures agree
        let sim = out.rows[1].sim_cy_per_cl.expect("sim column");
        assert!(sim > 0.0);
        assert!(out.rows[1].model_error_pct.is_some());
        assert_eq!(out.rows[0].t_ecm_mem, out.rows[1].t_ecm_mem);
    }

    #[test]
    fn breakpoint_bands_cross_at_the_layer_condition() {
        // jacobi: the j-band must sit at L1 for small N and move outward
        // for large N (Fig. 3 bottom panel)
        let src: Arc<str> = Arc::from(
            "double a[M][N], b[M][N], s;\nfor (int j = 1; j < M - 1; j++)\n  for (int i = 1; i < N - 1; i++)\n    b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;",
        );
        let jobs = vec![
            SweepJob {
                label: "2d-5pt".into(),
                source: src.clone(),
                machine: "SNB".into(),
                cores: 1,
                constants: [("N".to_string(), 256i64), ("M".to_string(), 4000i64)]
                    .into_iter()
                    .collect(),
                predictor: CachePredictorKind::Auto,
                model: ModelKind::Ecm,
            },
            SweepJob {
                label: "2d-5pt".into(),
                source: src,
                machine: "SNB".into(),
                cores: 1,
                constants: [("N".to_string(), 6000i64), ("M".to_string(), 6000i64)]
                    .into_iter()
                    .collect(),
                predictor: CachePredictorKind::Auto,
                model: ModelKind::Ecm,
            },
        ];
        let out = SweepEngine::new().run(&jobs).unwrap();
        assert!(out.rows[0].lc_breakpoints.contains(&"j@L1".to_string()), "{:?}", out.rows[0]);
        assert!(out.rows[1].lc_breakpoints.contains(&"j@L2".to_string()), "{:?}", out.rows[1]);
        // the small-N point is fully decisive: no walk ran
        assert_eq!(out.rows[0].walk_levels, 0);
    }
}
