//! Data traffic analysis — the offset-set cache predictor of paper §4.5
//! plus the analytic layer-condition evaluator of [18].
//!
//! Two predictor back ends are available (mirroring upstream Kerncraft's
//! `--cache-predictor` knob), selected via [`CachePredictorKind`]:
//!
//! * **Offsets** — for each cache level (inspected independently, as the
//!   paper describes) we walk the iteration space *backwards* from a
//!   steady-state "unit of work" (the inner iterations covering one cache
//!   line), accumulating the set of cache lines touched by reads, until
//!   the accumulated footprint exceeds the cache capacity. Unit-of-work
//!   read lines not present in that window are misses at this level and
//!   generate traffic to the next level. Write-allocate and eviction
//!   traffic are added per the paper: "all writes offsets are also
//!   treated as reads [and] added to an evict list and no caching is
//!   tracked on this" — one write-allocate transfer (unless the line is
//!   covered by reads) and one eviction transfer per store line per level.
//!   The walk stops early once no original access could possibly be
//!   covered anymore (beyond the maximum reuse distance) — this is the
//!   hot path of the whole tool and is benchmarked by `benches/hotpath.rs`.
//!
//! * **LayerConditions** — the analytic evaluator of Stengel et al.: for
//!   each level, find the outermost loop dimension whose layer condition
//!   holds; per-array traffic is then the number of distinct access
//!   "layers" in the dimensions outside it. O(#accesses) per level — no
//!   walk at all.
//!
//! * **Auto** — consult the layer conditions first and take the analytic
//!   answer only when it is *decisive* (clear margins on every condition,
//!   unit-stride streaming shape); otherwise fall back to the offset
//!   walk. Decisive levels therefore skip the documented hot path
//!   entirely, which is what makes large sweeps (see [`crate::sweep`])
//!   cheap. [`PredictorStats`] counts which path served each level.

use crate::kernel::{DimAccess, KernelAnalysis, LinearAccess};
use crate::machine::{MachineModel, StreamSig};
use anyhow::{bail, Result};
use std::collections::{HashMap, HashSet};

/// Which cache predictor back end to use (upstream `--cache-predictor`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CachePredictorKind {
    /// Backward offset-walk simulation (the paper's §4.5 predictor).
    #[default]
    Offsets,
    /// Pure analytic layer-condition evaluation (fast, steady-state only).
    LayerConditions,
    /// Layer conditions when decisive, offset walk otherwise.
    Auto,
}

impl CachePredictorKind {
    /// Parse a CLI spelling: `offsets`, `lc`/`layer-conditions`, `auto`.
    ///
    /// `sim` is deliberately NOT accepted: it used to alias `Offsets`,
    /// which became actively misleading once a real simulator-backed
    /// analysis existed — the trace-driven cache simulator is reached
    /// through `ModelKind::Validate` (`-p Validate`), not through the
    /// analytic predictor selection.
    pub fn parse(s: &str) -> Option<CachePredictorKind> {
        match s.to_ascii_lowercase().as_str() {
            "offsets" => Some(CachePredictorKind::Offsets),
            "lc" | "layerconditions" | "layer-conditions" => {
                Some(CachePredictorKind::LayerConditions)
            }
            "auto" => Some(CachePredictorKind::Auto),
            _ => None,
        }
    }

    /// CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            CachePredictorKind::Offsets => "offsets",
            CachePredictorKind::LayerConditions => "lc",
            CachePredictorKind::Auto => "auto",
        }
    }
}

/// Which back end served each cache level of a prediction — the
/// observability hook for the layer-condition fast path.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PredictorStats {
    /// Levels answered analytically (backward walk skipped).
    pub lc_fast_levels: u32,
    /// Levels that ran the backward offset walk.
    pub walk_levels: u32,
}

/// Traffic across the link between one cache level and the next-outer
/// level, in cache lines per unit of work.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelTraffic {
    /// Cache level name on the inner side of the link ("L1" ⇒ L1↔L2).
    pub level: String,
    /// Distinct read lines of the unit that miss in this level.
    pub read_miss_lines: f64,
    /// Write-allocate transfers (store lines not covered by any read).
    pub write_allocate_lines: f64,
    /// Write-back (evict) transfers.
    pub evict_lines: f64,
    /// Distinct read lines of the unit that hit in this level.
    pub hit_lines: f64,
    /// Stream signature of the misses (for benchmark matching).
    pub miss_streams: StreamSig,
}

impl LevelTraffic {
    /// Total cache lines crossing this link per unit of work.
    pub fn total_lines(&self) -> f64 {
        self.read_miss_lines + self.write_allocate_lines + self.evict_lines
    }
}

/// One layer-condition evaluation (paper Fig. 3 bottom panel).
#[derive(Debug, Clone)]
pub struct LcEntry {
    /// Cache level name.
    pub level: String,
    /// Loop depth the condition refers to (0 = outermost). A satisfied
    /// condition at depth *d* means reuse across iterations of loop *d*
    /// is captured by this cache level.
    pub dim_index: usize,
    /// Loop index variable name.
    pub dim_name: String,
    /// Bytes that must fit for the condition to hold.
    pub required_bytes: u64,
    /// Capacity of the level.
    pub cache_bytes: u64,
    pub satisfied: bool,
}

/// Complete traffic prediction for a kernel on a machine.
#[derive(Debug, Clone)]
pub struct TrafficPrediction {
    /// Inner iterations per unit of work.
    pub unit_iterations: u64,
    pub cacheline_bytes: u64,
    /// One entry per cache level, inner to outer (L1, L2, L3): the
    /// traffic crossing to the next-outer level.
    pub levels: Vec<LevelTraffic>,
    /// For every entry of `analysis.reads`: the innermost level whose
    /// window covers it ("L1", ..., "MEM" when it misses everywhere).
    pub access_hit_level: Vec<String>,
    /// Layer-condition table.
    pub layer_conditions: Vec<LcEntry>,
    /// Which back end served each level.
    pub stats: PredictorStats,
}

impl TrafficPrediction {
    /// Traffic (cache lines per unit) across the link `level`↔next.
    pub fn lines_between(&self, level: &str) -> Option<f64> {
        self.levels.iter().find(|l| l.level == level).map(|l| l.total_lines())
    }

    /// Fig. 3 breakpoint bands: per loop dimension of `analysis`, the
    /// innermost cache level whose layer condition holds, rendered as
    /// `"j@L2"` (`"j@MEM"` when none does).
    pub fn lc_breakpoints(&self, analysis: &KernelAnalysis) -> Vec<String> {
        analysis
            .loops
            .iter()
            .enumerate()
            .map(|(d, l)| {
                let holds = self
                    .layer_conditions
                    .iter()
                    .find(|e| e.dim_index == d && e.satisfied)
                    .map(|e| e.level.clone())
                    .unwrap_or_else(|| "MEM".to_string());
                format!("{}@{}", l.index, holds)
            })
            .collect()
    }

    /// Bytes per unit of work across the outermost link (memory traffic).
    pub fn memory_bytes_per_unit(&self) -> f64 {
        self.levels
            .last()
            .map(|l| l.total_lines() * self.cacheline_bytes as f64)
            .unwrap_or(0.0)
    }
}

/// The §4.5 cache predictor.
pub struct CachePredictor<'m> {
    machine: &'m MachineModel,
    /// Cores assumed to be running this kernel concurrently: shared cache
    /// levels are partitioned accordingly.
    cores: u32,
    kind: CachePredictorKind,
}

impl<'m> CachePredictor<'m> {
    /// Predictor for single-core analysis (offset walk).
    pub fn new(machine: &'m MachineModel) -> Self {
        Self { machine, cores: 1, kind: CachePredictorKind::Offsets }
    }

    /// Predictor assuming `cores` active cores (shared caches divided).
    pub fn with_cores(machine: &'m MachineModel, cores: u32) -> Self {
        Self { machine, cores: cores.max(1), kind: CachePredictorKind::Offsets }
    }

    /// Predictor with an explicit back-end choice.
    pub fn with_kind(
        machine: &'m MachineModel,
        cores: u32,
        kind: CachePredictorKind,
    ) -> Self {
        Self { machine, cores: cores.max(1), kind }
    }

    /// Effective capacity of a cache level for one core.
    fn effective_size(&self, level: &crate::machine::MemLevel) -> u64 {
        let size = level.size_bytes.unwrap_or(0);
        if level.cores_per_group <= 1 {
            size
        } else {
            // shared level: when multiple cores run the kernel they
            // compete for capacity
            let sharers = self.cores.min(level.cores_per_group).max(1) as u64;
            size / sharers
        }
    }

    /// Run the traffic prediction.
    pub fn predict(&self, analysis: &KernelAnalysis) -> Result<TrafficPrediction> {
        let cl = self.machine.cacheline_bytes;
        if analysis.loops.is_empty() {
            bail!("kernel has no loops");
        }
        for l in &analysis.loops {
            if l.trip() <= 0 {
                bail!(
                    "empty iteration space: loop '{}' runs {}..{} (step {}) — no iterations",
                    l.index,
                    l.start,
                    l.end,
                    l.step
                );
            }
        }
        validate_magnitudes(analysis)?;
        let layout = ArrayLayout::new(analysis, cl);
        let unit_iterations = analysis.unit_of_work(cl);

        // --- iteration-space geometry ---
        let steps: Vec<i64> = analysis.loops.iter().map(|l| l.step).collect();
        let trips: Vec<i64> = analysis.loops.iter().map(|l| l.trip().max(1)).collect();
        // center the unit in the iteration space, aligning the inner index
        // so the unit starts on a cache-line boundary of stride-1 streams
        let mut center: Vec<i64> = analysis
            .loops
            .iter()
            .map(|l| l.start + (l.trip().max(1) / 2) * l.step)
            .collect();
        let inner = center.len() - 1;
        let epc = analysis.elements_per_cacheline(cl).max(1) as i64;
        let inner_l = analysis.loops[inner].clone();
        center[inner] -= center[inner].rem_euclid(epc * inner_l.step);
        center[inner] = center[inner]
            .max(inner_l.start)
            .min((inner_l.end - 1).max(inner_l.start));

        // iterations available before the unit start (for the space cap)
        let mut before: i64 = 0;
        {
            // count lexicographic predecessors of `center` (saturating:
            // huge iteration spaces only need "more than the reuse cap")
            let mut mult: i64 = 1;
            for k in (0..analysis.loops.len()).rev() {
                let l = &analysis.loops[k];
                let pos = ((center[k] - l.start) / l.step).max(0);
                before = before.saturating_add(pos.saturating_mul(mult));
                mult = mult.saturating_mul(trips[k]);
            }
        }

        // --- unit-of-work line sets ---
        let mut unit_read_lines: HashSet<(usize, i64)> = HashSet::new();
        let mut per_access_lines: Vec<HashSet<(usize, i64)>> = Vec::new();
        let mut pos = center.clone();
        let mut unit_positions = Vec::new();
        for _ in 0..unit_iterations {
            unit_positions.push(pos.clone());
            step_forward(&mut pos, analysis, &steps);
        }
        for acc in &analysis.reads {
            let mut lines = HashSet::new();
            for p in &unit_positions {
                lines.insert(layout.line_of(acc, p, analysis));
            }
            unit_read_lines.extend(lines.iter().copied());
            per_access_lines.push(lines);
        }
        let mut store_lines: HashSet<(usize, i64)> = HashSet::new();
        for acc in &analysis.writes {
            for p in &unit_positions {
                store_lines.insert(layout.line_of(acc, p, analysis));
            }
        }
        let store_arrays: HashSet<usize> = analysis.writes.iter().map(|w| w.array).collect();

        // --- backward-walk reuse cap ---
        // Beyond the maximum pairwise offset distance (in inner
        // iterations) no unit line can be covered anymore.
        let reuse_cap = max_reuse_iterations(analysis)?
            .saturating_add(unit_iterations as i64)
            .saturating_add(8i64.saturating_mul(epc));

        // --- layer conditions & analytic oracle ---
        let layer_conditions = layer_conditions(analysis, self.machine, self.cores);
        let oracle = LcOracle::build(analysis, cl);

        // --- per-level traffic ---
        let mut stats = PredictorStats::default();
        let mut levels = Vec::new();
        let mut hit_level: Vec<Option<String>> = vec![None; analysis.reads.len()];
        for lvl in self.machine.cache_levels() {
            let size = self.effective_size(lvl);
            let decision = match self.kind {
                CachePredictorKind::Offsets => None,
                CachePredictorKind::LayerConditions => {
                    Some(oracle.decide(&layer_conditions, &lvl.name, size))
                }
                CachePredictorKind::Auto => {
                    oracle.try_decide(&layer_conditions, &lvl.name, size)
                }
            };
            if let Some(d) = decision {
                // analytic fast path: the backward walk is skipped
                stats.lc_fast_levels += 1;
                let hits = unit_read_lines.len().saturating_sub(d.read_miss_total);
                let miss_streams =
                    stream_signature(analysis, &d.miss_per_array, &store_arrays);
                for (ix, covered) in d.covered.iter().enumerate() {
                    if hit_level[ix].is_none() && *covered {
                        hit_level[ix] = Some(lvl.name.clone());
                    }
                }
                levels.push(LevelTraffic {
                    level: lvl.name.clone(),
                    read_miss_lines: d.read_miss_total as f64,
                    write_allocate_lines: d.write_allocate as f64,
                    evict_lines: d.evict as f64,
                    hit_lines: hits as f64,
                    miss_streams,
                });
                continue;
            }
            stats.walk_levels += 1;
            let max_lines = (size / cl) as usize;
            let window = self.backward_window(
                analysis,
                &layout,
                &center,
                &steps,
                max_lines,
                reuse_cap.min(before),
            );
            // classify unit read lines
            let mut miss_lines: HashSet<(usize, i64)> = HashSet::new();
            let mut hits = 0usize;
            for line in &unit_read_lines {
                if window.contains(line.0, line.1) {
                    hits += 1;
                } else {
                    miss_lines.insert(*line);
                }
            }
            // per-access hit levels (first level whose window covers all
            // of the access's unit lines)
            for (ix, lines) in per_access_lines.iter().enumerate() {
                if hit_level[ix].is_none()
                    && lines.iter().all(|l| window.contains(l.0, l.1))
                {
                    hit_level[ix] = Some(lvl.name.clone());
                }
            }
            // write-allocate: store lines not covered by reads
            let wa = store_lines
                .iter()
                .filter(|l| !window.contains(l.0, l.1) && !unit_read_lines.contains(l))
                .count();
            let mut miss_per_array: HashMap<usize, u32> = HashMap::new();
            for (a, _) in &miss_lines {
                *miss_per_array.entry(*a).or_insert(0) += 1;
            }
            let miss_streams = stream_signature(analysis, &miss_per_array, &store_arrays);
            levels.push(LevelTraffic {
                level: lvl.name.clone(),
                read_miss_lines: miss_lines.len() as f64,
                write_allocate_lines: wa as f64,
                evict_lines: store_lines.len() as f64,
                hit_lines: hits as f64,
                miss_streams,
            });
        }

        let access_hit_level: Vec<String> = hit_level
            .into_iter()
            .map(|h| h.unwrap_or_else(|| "MEM".to_string()))
            .collect();

        Ok(TrafficPrediction {
            unit_iterations,
            cacheline_bytes: cl,
            levels,
            access_hit_level,
            layer_conditions,
            stats,
        })
    }

    /// Accumulate the backward window for one cache level: the set of
    /// (array, line) pairs touched by reads of iterations strictly before
    /// the unit, walking backwards until the footprint exceeds the cache
    /// size or no further coverage is possible.
    fn backward_window(
        &self,
        analysis: &KernelAnalysis,
        layout: &ArrayLayout,
        unit_start: &[i64],
        steps: &[i64],
        max_lines: usize,
        max_steps: i64,
    ) -> DenseWindow {
        let mut window = DenseWindow::new(analysis, layout, self.machine.cacheline_bytes);
        if max_lines == 0 {
            return window;
        }
        let mut pos = unit_start.to_vec();
        let mut taken: i64 = 0;
        while taken < max_steps {
            if !step_backward(&mut pos, analysis, steps) {
                break; // beginning of the iteration space
            }
            taken += 1;
            for acc in &analysis.reads {
                let (a, line) = layout.line_of(acc, &pos, analysis);
                window.insert(a, line);
            }
            if window.len() > max_lines {
                break;
            }
        }
        window
    }
}

/// One level's analytic (layer-condition) traffic answer.
struct LcDecision {
    /// Distinct missing streams per array (cache lines per unit of work).
    miss_per_array: HashMap<usize, u32>,
    read_miss_total: usize,
    write_allocate: usize,
    evict: usize,
    /// Per `analysis.reads` entry: covered (hits) at this level?
    covered: Vec<bool>,
}

/// Per-access data the analytic evaluator needs, precomputed once.
struct LcAccess {
    array: usize,
    /// Linear stride coefficient per loop dim (elements/iteration).
    coeffs: Vec<i64>,
    /// Summed relative offsets per loop dim (iteration units).
    rel: Vec<i64>,
    /// Full linear offset (elements).
    offset: i64,
}

/// The analytic layer-condition evaluator (fast path).
struct LcOracle {
    reads: Vec<LcAccess>,
    writes: Vec<LcAccess>,
    cacheline: u64,
    /// Element size of every accessed array (None when mixed — the
    /// streaming-shape preconditions then fail).
    uniform_elem: Option<u64>,
    /// Structural preconditions for Auto mode (unit-stride streaming nest).
    shape_ok: bool,
}

impl LcOracle {
    fn build(analysis: &KernelAnalysis, cacheline: u64) -> LcOracle {
        let n_loops = analysis.loops.len();
        let var_of: Vec<&str> = analysis.loops.iter().map(|l| l.index.as_str()).collect();
        let mk = |acc: &LinearAccess| -> LcAccess {
            let mut rel = vec![0i64; n_loops];
            for d in &acc.dims {
                if let DimAccess::Relative { var, offset } = d {
                    if let Some(ix) = var_of.iter().position(|v| v == var) {
                        rel[ix] += offset;
                    }
                }
            }
            LcAccess {
                array: acc.array,
                coeffs: acc.coeffs.clone(),
                rel,
                offset: acc.offset,
            }
        };
        let reads: Vec<LcAccess> = analysis.reads.iter().map(mk).collect();
        let writes: Vec<LcAccess> = analysis.writes.iter().map(mk).collect();

        let mut elem_sizes: Vec<u64> =
            analysis.arrays.iter().map(|a| a.ty.size()).collect();
        elem_sizes.sort_unstable();
        elem_sizes.dedup();
        let uniform_elem = if elem_sizes.len() == 1 { Some(elem_sizes[0]) } else { None };

        // Auto-mode structural preconditions: the closed-form per-unit
        // traffic (one new line per stream per unit of work) only holds
        // for dense unit-stride streaming nests in steady state.
        let mut shape_ok = uniform_elem == Some(analysis.element.size());
        shape_ok &= analysis.loops.iter().all(|l| l.step == 1 && l.trip() >= 4);
        for acc in analysis.reads.iter().chain(analysis.writes.iter()) {
            // every access streams with the inner loop at unit stride
            shape_ok &= acc.coeffs.last() == Some(&1);
            // every loop dimension advances the access: outer-invariant
            // accesses (coeff 0) are re-touched each outer iteration — a
            // reuse pattern the stream classes don't model (the walk does)
            shape_ok &= acc.coeffs.iter().all(|c| *c > 0);
            // each loop var indexes at most one array dimension
            let mut seen: Vec<&str> = Vec::new();
            for d in &acc.dims {
                if let DimAccess::Relative { var, .. } = d {
                    if seen.contains(&var.as_str()) {
                        shape_ok = false;
                    }
                    seen.push(var);
                }
            }
        }
        // write streams must either be the only streams of their array or
        // coincide exactly with a read stream: the closed-form
        // write-allocate rule only covers those two cases
        for w in &writes {
            let array_reads: Vec<&LcAccess> =
                reads.iter().filter(|r| r.array == w.array).collect();
            if !array_reads.is_empty()
                && !array_reads
                    .iter()
                    .any(|r| r.coeffs == w.coeffs && r.rel == w.rel && r.offset == w.offset)
            {
                shape_ok = false;
            }
        }

        LcOracle { reads, writes, cacheline, uniform_elem, shape_ok }
    }

    /// Required bytes of the condition at depth `d` for `level`.
    fn required<'e>(entries: &'e [LcEntry], level: &str, d: usize) -> Option<&'e LcEntry> {
        entries.iter().find(|e| e.level == level && e.dim_index == d)
    }

    /// Auto mode: answer only when decisive, with safety margins on every
    /// condition so the result is bit-identical to the offset walk.
    fn try_decide(
        &self,
        entries: &[LcEntry],
        level: &str,
        size: u64,
    ) -> Option<LcDecision> {
        if !self.shape_ok || size < 64 * self.cacheline {
            return None;
        }
        let n_loops = self.reads.first().map(|a| a.rel.len()).unwrap_or(0);
        if n_loops == 0 {
            return None;
        }
        // margin scan, outermost first: the chosen dimension must hold
        // with 2x headroom and every outer dimension must fail by 2x.
        let mut d_min: Option<usize> = None;
        for d in 0..n_loops {
            let e = Self::required(entries, level, d)?;
            let r = e.required_bytes;
            if r == 0 {
                return None; // dimension unused by any stream: indecisive
            }
            if r.saturating_mul(2) <= size {
                d_min = Some(d);
                break;
            }
            if r < size.saturating_mul(2) {
                return None; // gray zone around the breakpoint
            }
        }
        let d_min = d_min?;
        Some(self.evaluate(d_min, size))
    }

    /// Forced layer-condition mode: always answers, using the plain
    /// satisfied flags (approximate near breakpoints, exact in steady
    /// state away from them).
    fn decide(&self, entries: &[LcEntry], level: &str, size: u64) -> LcDecision {
        let n_loops = self
            .reads
            .iter()
            .chain(self.writes.iter())
            .next()
            .map(|a| a.rel.len())
            .unwrap_or(0);
        let mut d_min = n_loops; // n_loops ⇒ no condition holds: full resolution
        for d in 0..n_loops {
            if Self::required(entries, level, d).map(|e| e.satisfied).unwrap_or(false) {
                d_min = d;
                break;
            }
        }
        self.evaluate(d_min, size)
    }

    /// Shared evaluation: stream classes with dims `>= d_min` collapsed.
    /// Per unit of work each surviving class (one "leading layer") misses
    /// exactly one cache line; trailing members of a class hit. Note there
    /// is deliberately no whole-array residency shortcut: like the offset
    /// walk (whose window is capped at the reuse distance), reuse only
    /// exists between accesses — a stream touched once is a miss no matter
    /// how small its array is.
    fn evaluate(&self, d_min: usize, size: u64) -> LcDecision {
        // class key: (array, coeffs, outer rel offsets, residue). Streams
        // of one array that differ only by a small constant lag share the
        // leading line, so nearby residues merge into one cluster below.
        let key_of = |acc: &LcAccess| -> (usize, Vec<i64>, Vec<i64>, i64) {
            let stripped: i64 = acc
                .rel
                .iter()
                .zip(&acc.coeffs)
                .skip(d_min)
                .map(|(r, c)| r * c)
                .sum();
            (
                acc.array,
                acc.coeffs.clone(),
                acc.rel.iter().take(d_min).copied().collect(),
                acc.offset - stripped,
            )
        };
        let elem = self.uniform_elem.unwrap_or(8) as i64;
        let merge_gap = ((size / 4) as i64 / elem).max(2 * self.cacheline as i64 / elem);

        // group reads into classes, merging nearby residues
        let mut groups: HashMap<(usize, Vec<i64>, Vec<i64>), Vec<(i64, Vec<i64>, usize)>> =
            HashMap::new();
        for (ix, acc) in self.reads.iter().enumerate() {
            let (a, c, outer, res) = key_of(acc);
            // ties on residue break by full rel vector (outer-to-inner
            // lexicographic): the true stream leader is the access that
            // touches new data first
            groups.entry((a, c, outer)).or_default().push((res, acc.rel.clone(), ix));
        }
        let mut miss_per_array: HashMap<usize, u32> = HashMap::new();
        let mut covered = vec![false; self.reads.len()];
        for (key, members) in &groups {
            let a = key.0;
            let mut ms = members.clone();
            ms.sort();
            // split residues into clusters separated by more than the
            // merge gap; each cluster is one stream with one leading line
            let mut cluster_start = 0usize;
            for i in 0..ms.len() {
                let is_last = i + 1 == ms.len();
                let gap_breaks = !is_last && ms[i + 1].0 - ms[i].0 > merge_gap;
                if is_last || gap_breaks {
                    *miss_per_array.entry(a).or_insert(0) += 1;
                    // every member except the cluster leader (max key)
                    // trails another access of the same stream and hits
                    for (_, _, ix) in &ms[cluster_start..i] {
                        covered[*ix] = true;
                    }
                    cluster_start = i + 1;
                }
            }
        }
        let read_miss_total: usize = miss_per_array.values().map(|v| *v as usize).sum();

        // stores: same classing; evict is unconditional ("no caching is
        // tracked on the evict list"), write-allocate is waived when a
        // read stream shares the class (its lines are then read-covered)
        let gap = merge_gap.max(1);
        let mut store_groups: HashSet<(usize, Vec<i64>, Vec<i64>, i64)> = HashSet::new();
        for acc in &self.writes {
            let (a, c, outer, res) = key_of(acc);
            store_groups.insert((a, c, outer, res.div_euclid(gap)));
        }
        let read_keys: HashSet<(usize, Vec<i64>, Vec<i64>, i64)> = self
            .reads
            .iter()
            .map(|acc| {
                let (a, c, outer, res) = key_of(acc);
                (a, c, outer, res.div_euclid(gap))
            })
            .collect();
        let evict = store_groups.len();
        let write_allocate =
            store_groups.iter().filter(|k| !read_keys.contains(*k)).count();

        LcDecision { miss_per_array, read_miss_total, write_allocate, evict, covered }
    }
}

/// Byte layout of the kernel's arrays: consecutive placement, each array
/// aligned to a fresh cache line (the paper: "we arbitrarily decide that
/// the first cache-line starts at offset 0"). Shared with the virtual
/// testbed so both address spaces coincide.
pub(crate) struct ArrayLayout {
    /// Base byte address per array (indexed like `analysis.arrays`).
    bases: Vec<i64>,
    cacheline: i64,
}

impl ArrayLayout {
    /// Base byte address of an array.
    pub(crate) fn base_of(&self, array: usize) -> i64 {
        self.bases[array]
    }

    pub(crate) fn new(analysis: &KernelAnalysis, cacheline: u64) -> Self {
        let mut bases = Vec::new();
        let mut cursor: i64 = 0;
        for a in &analysis.arrays {
            bases.push(cursor);
            let sz = a.bytes() as i64;
            // pad to cache line and leave one guard line between arrays
            cursor += (sz + 2 * cacheline as i64 - 1) / cacheline as i64 * cacheline as i64
                + cacheline as i64;
        }
        Self { bases, cacheline: cacheline as i64 }
    }

    /// The (array, cache line) an access touches at iteration `pos`.
    fn line_of(
        &self,
        acc: &LinearAccess,
        pos: &[i64],
        analysis: &KernelAnalysis,
    ) -> (usize, i64) {
        let elem = analysis.arrays[acc.array].ty.size() as i64;
        let off_elems = acc.offset + acc.coeffs.iter().zip(pos).map(|(c, p)| c * p).sum::<i64>();
        let byte = self.bases[acc.array] + off_elems * elem;
        (acc.array, byte.div_euclid(self.cacheline))
    }
}

/// Dense per-array bit-set of cache lines — the backward-window
/// membership structure. Replaces a `HashSet<(usize, i64)>`: array line
/// ranges are known up front, so membership is one shift+mask (§Perf:
/// 8.3x on the long-range N=400 analysis).
pub(crate) struct DenseWindow {
    /// bit-vector per array, indexed by (line - first_line).
    bits: Vec<Vec<u64>>,
    first_line: Vec<i64>,
    len: usize,
}

impl DenseWindow {
    pub(crate) fn new(analysis: &KernelAnalysis, layout: &ArrayLayout, cacheline: u64) -> Self {
        let mut bits = Vec::new();
        let mut first_line = Vec::new();
        for (ix, a) in analysis.arrays.iter().enumerate() {
            let base = layout.base_of(ix);
            let first = base.div_euclid(cacheline as i64) - 1;
            let lines = (a.bytes() / cacheline + 3) as usize;
            bits.push(vec![0u64; lines.div_ceil(64)]);
            first_line.push(first);
        }
        DenseWindow { bits, first_line, len: 0 }
    }

    #[inline]
    fn index(&self, array: usize, line: i64) -> Option<(usize, usize, u64)> {
        let rel = line - self.first_line[array];
        if rel < 0 {
            return None;
        }
        let rel = rel as usize;
        let word = rel / 64;
        if word >= self.bits[array].len() {
            return None;
        }
        Some((array, word, 1u64 << (rel % 64)))
    }

    /// Insert; returns true if newly added. Out-of-range lines (guard
    /// slop) are ignored — they cannot correspond to in-bounds accesses.
    #[inline]
    pub(crate) fn insert(&mut self, array: usize, line: i64) -> bool {
        let Some((a, w, m)) = self.index(array, line) else { return false };
        let slot = &mut self.bits[a][w];
        if *slot & m == 0 {
            *slot |= m;
            self.len += 1;
            true
        } else {
            false
        }
    }

    #[inline]
    pub(crate) fn contains(&self, array: usize, line: i64) -> bool {
        match self.index(array, line) {
            Some((a, w, m)) => self.bits[a][w] & m != 0,
            None => false,
        }
    }

    /// Number of lines in the window.
    pub(crate) fn len(&self) -> usize {
        self.len
    }
}

/// Advance `pos` one iteration in lexicographic loop order.
fn step_forward(pos: &mut [i64], analysis: &KernelAnalysis, steps: &[i64]) {
    for k in (0..pos.len()).rev() {
        pos[k] += steps[k];
        if pos[k] < analysis.loops[k].end {
            return;
        }
        pos[k] = analysis.loops[k].start;
    }
    // wrapped the whole space: leave at start
}

/// Move `pos` one iteration backwards; false at the very first iteration.
fn step_backward(pos: &mut [i64], analysis: &KernelAnalysis, steps: &[i64]) -> bool {
    for k in (0..pos.len()).rev() {
        pos[k] -= steps[k];
        if pos[k] >= analysis.loops[k].start {
            return true;
        }
        // underflow: reset to last valid value of this index, borrow from
        // the next-outer loop
        let l = &analysis.loops[k];
        let last = l.start + (l.trip().max(1) - 1) * l.step;
        pos[k] = last;
    }
    false
}

/// Reject access offsets / stride coefficients whose line arithmetic
/// would overflow `i64` (wrapping would silently corrupt the prediction,
/// and the backward walk could spin on a wrapped reuse cap).
fn validate_magnitudes(analysis: &KernelAnalysis) -> Result<()> {
    let overflow = |name: &str| {
        anyhow::anyhow!(
            "access magnitudes of array '{name}' overflow the address arithmetic \
             (offset/stride × iteration count exceeds i64)"
        )
    };
    for acc in analysis.reads.iter().chain(analysis.writes.iter()) {
        let name = &analysis.arrays[acc.array].name;
        let elem = analysis.arrays[acc.array].ty.size() as i64;
        let mut extreme: i64 = acc.offset;
        for (c, l) in acc.coeffs.iter().zip(&analysis.loops) {
            let bound = l.start.unsigned_abs().max(l.end.unsigned_abs());
            let bound = i64::try_from(bound).map_err(|_| overflow(name))?;
            let term = c.checked_mul(bound).ok_or_else(|| overflow(name))?;
            let term = term.checked_abs().ok_or_else(|| overflow(name))?;
            extreme = extreme
                .checked_abs()
                .and_then(|e| e.checked_add(term))
                .ok_or_else(|| overflow(name))?;
        }
        extreme.checked_mul(elem).ok_or_else(|| overflow(name))?;
    }
    Ok(())
}

/// Maximum reuse distance in inner-loop iterations: the largest pairwise
/// linear-offset difference among accesses to the same array, divided by
/// the inner stride coefficient. Errors (instead of wrapping) on offset
/// spans that overflow `i64` — degenerate inputs the walk could otherwise
/// spin on.
fn max_reuse_iterations(analysis: &KernelAnalysis) -> Result<i64> {
    let mut max_iters: i64 = 0;
    for a in 0..analysis.arrays.len() {
        let offs: Vec<i64> = analysis
            .reads
            .iter()
            .filter(|r| r.array == a)
            .map(|r| r.offset)
            .collect();
        if offs.is_empty() {
            continue;
        }
        let inner_coeff = analysis
            .reads
            .iter()
            .find(|r| r.array == a)
            .map(|r| *r.coeffs.last().unwrap_or(&1))
            .unwrap_or(1)
            .abs()
            .max(1);
        let max = offs.iter().max().copied().unwrap_or(0);
        let min = offs.iter().min().copied().unwrap_or(0);
        let span = max.checked_sub(min).ok_or_else(|| {
            anyhow::anyhow!(
                "access offset span of array '{}' overflows ({} .. {})",
                analysis.arrays[a].name,
                min,
                max
            )
        })?;
        max_iters = max_iters.max(span / inner_coeff + 1);
    }
    Ok(max_iters)
}

/// Build the stream signature of a level's misses (for benchmark
/// matching) from per-array miss-line counts. Streams group accesses by
/// (array, row-class): two accesses differing only in the innermost
/// relative offset belong to one stream.
fn stream_signature(
    analysis: &KernelAnalysis,
    miss_per_array: &HashMap<usize, u32>,
    store_arrays: &HashSet<usize>,
) -> StreamSig {
    // arrays that are written / read
    let written: &HashSet<usize> = store_arrays;
    let read: HashSet<usize> = analysis.reads.iter().map(|r| r.array).collect();

    // group read accesses into row streams: key strips the innermost
    // relative offset so a[j][i-1] and a[j][i+1] share one stream
    let mut streams: HashSet<(usize, Vec<i64>, i64)> = HashSet::new();
    let inner_var = analysis.loops.last().map(|l| l.index.clone()).unwrap_or_default();
    for acc in &analysis.reads {
        let inner_off = acc
            .dims
            .iter()
            .zip(&analysis.arrays[acc.array].strides)
            .filter_map(|(d, stride)| match d {
                DimAccess::Relative { var, offset } if *var == inner_var => {
                    Some(offset * *stride as i64)
                }
                _ => None,
            })
            .sum::<i64>();
        streams.insert((acc.array, acc.coeffs.clone(), acc.offset - inner_off));
    }
    let mut per_array_streams: HashMap<usize, u32> = HashMap::new();
    for (a, _, _) in &streams {
        *per_array_streams.entry(*a).or_insert(0) += 1;
    }

    let mut sig = StreamSig { reads: 0, read_writes: 0, writes: 0 };
    for (a, n_streams) in per_array_streams {
        // at most one miss stream per distinct miss line of the array
        let n = n_streams.min(miss_per_array.get(&a).copied().unwrap_or(0));
        if n == 0 {
            continue;
        }
        if written.contains(&a) {
            sig.read_writes += 1; // read+write stream (e.g. `U`, `u1`)
            sig.reads += n - 1;
        } else {
            sig.reads += n;
        }
    }
    // pure write streams: written arrays never read
    let pure_writes = written.iter().filter(|a| !read.contains(a)).count();
    sig.writes += pure_writes as u32;
    sig
}

/// Analytic layer conditions (paper [18], Fig. 3 bottom): reuse across
/// loop dimension `d` is captured by cache level `k` iff the summed
/// footprint of all access "layers" in that dimension fits.
fn layer_conditions(
    analysis: &KernelAnalysis,
    machine: &MachineModel,
    cores: u32,
) -> Vec<LcEntry> {
    let mut out = Vec::new();
    let n_loops = analysis.loops.len();
    for lvl in machine.cache_levels() {
        let size = {
            let s = lvl.size_bytes.unwrap_or(0);
            if lvl.cores_per_group > 1 {
                s / cores.min(lvl.cores_per_group).max(1) as u64
            } else {
                s
            }
        };
        for d in 0..n_loops {
            let dim_name = analysis.loops[d].index.clone();
            let mut required: u64 = 0;
            for (aix, arr) in analysis.arrays.iter().enumerate() {
                // span of relative offsets along dim d over all accesses,
                // taken from the per-dimension classification (NOT from
                // the aggregated linear offset, which mixes dimensions)
                let mut lo = i64::MAX;
                let mut hi = i64::MIN;
                let mut coeff: i64 = 0;
                for acc in analysis.reads.iter().chain(analysis.writes.iter()) {
                    if acc.array != aix || acc.coeffs[d] == 0 {
                        continue;
                    }
                    coeff = acc.coeffs[d].abs();
                    let layer_off: i64 = acc
                        .dims
                        .iter()
                        .filter_map(|dim| match dim {
                            DimAccess::Relative { var, offset } if *var == dim_name => {
                                Some(*offset)
                            }
                            _ => None,
                        })
                        .sum();
                    lo = lo.min(layer_off);
                    hi = hi.max(layer_off);
                }
                if coeff == 0 {
                    continue;
                }
                let n_layers = (hi - lo) as u64 + 1;
                // one layer = memory touched while the dim-d index is
                // fixed = the dim-d stride of this array
                required = required
                    .saturating_add(n_layers.saturating_mul(coeff as u64) * arr.ty.size());
            }
            out.push(LcEntry {
                level: lvl.name.clone(),
                dim_index: d,
                dim_name,
                required_bytes: required,
                cache_bytes: size,
                satisfied: required > 0 && required <= size,
            });
        }
    }
    out
}

/// One analytically solved layer-condition breakpoint (DESIGN.md §5): the
/// largest extent of the varied array dimension at which the condition
/// `(level, dim)` still holds, from the exact linear decomposition
/// `required = const + slope · extent`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LcBreakpoint {
    /// Cache level name.
    pub level: String,
    /// Loop depth of the condition (0 = outermost).
    pub dim_index: usize,
    /// Loop index variable name.
    pub dim_name: String,
    /// Capacity of the level (per active core for shared levels).
    pub cache_bytes: u64,
    /// Extent-independent part of the required footprint.
    pub const_bytes: u64,
    /// Required bytes added per element of the varied extent (> 0).
    pub slope_bytes: u64,
    /// Largest varied extent satisfying the condition — inclusive, i.e.
    /// `const + slope · extent <= cache_bytes`, matching the
    /// `required <= size` test of the layer-condition evaluator.
    pub extent: u64,
}

/// Result of [`solve_lc_breakpoints`]: the layer-condition inequalities
/// of one kernel/machine pair solved in the extent of the array
/// dimension streamed by the innermost loop.
#[derive(Debug, Clone)]
pub struct LcBlockingSolve {
    /// Index variable of the innermost loop — the dimension being varied.
    pub varied_dim: String,
    /// Per `analysis.arrays` entry: the array-dimension position indexed
    /// by the varied loop variable (`None` when the array does not use it).
    pub varied_positions: Vec<Option<usize>>,
    /// Current extent of the varied array dimension (uniform across the
    /// participating arrays — checked).
    pub current_extent: u64,
    /// Solved breakpoints, levels inner→outer; only conditions whose
    /// footprint actually grows with the varied extent appear (positive
    /// slope) — constant conditions have no breakpoint.
    pub breakpoints: Vec<LcBreakpoint>,
}

/// Solve the layer-condition inequalities analytically in the extent of
/// the innermost-indexed array dimension (DESIGN.md §5).
///
/// Each condition's footprint decomposes per array into
/// `n_layers · coeff_d · elem_size`, where `coeff_d` is the array stride
/// at the dimension position indexed by loop `d`. In a row-major layout
/// that stride contains the varied extent as a factor exactly when the
/// varied dimension lies strictly *inside* position `d` — those terms are
/// linear in the extent; all others are constants. Inverting
/// `const + slope · E <= cache_bytes` per level gives the breakpoint
/// `E* = (cache_bytes − const) / slope` (inclusive floor) with no sweep
/// and no offset walk.
///
/// Errors when the kernel shape defeats the decomposition: fewer than two
/// loops, a loop variable indexing two dimensions of one array, arrays
/// disagreeing on the varied extent, or a footprint term the current
/// extent does not divide (non-linear dependence).
pub fn solve_lc_breakpoints(
    analysis: &KernelAnalysis,
    machine: &MachineModel,
    cores: u32,
) -> Result<LcBlockingSolve> {
    let n_loops = analysis.loops.len();
    if n_loops < 2 {
        bail!("blocking analysis needs a loop nest of depth >= 2");
    }
    let varied = analysis.loops[n_loops - 1].index.clone();
    // per (array, loop var): the array-dimension position the variable
    // indexes — must be unique per array or the footprint does not
    // factor into per-dimension strides
    let mut positions: Vec<Vec<Option<usize>>> = vec![vec![None; n_loops]; analysis.arrays.len()];
    for acc in analysis.reads.iter().chain(analysis.writes.iter()) {
        for (pos, dim) in acc.dims.iter().enumerate() {
            let DimAccess::Relative { var, .. } = dim else { continue };
            let Some(d) = analysis.loops.iter().position(|l| l.index == *var) else {
                continue;
            };
            match positions[acc.array][d] {
                None => positions[acc.array][d] = Some(pos),
                Some(p) if p == pos => {}
                Some(p) => bail!(
                    "array '{}': loop index '{}' appears at dimensions {} and {} — \
                     the layer conditions are not separable",
                    analysis.arrays[acc.array].name,
                    var,
                    p,
                    pos
                ),
            }
        }
    }
    let mut current_extent: Option<u64> = None;
    for (aix, pos) in positions.iter().enumerate() {
        let Some(p) = pos[n_loops - 1] else { continue };
        let e = analysis.arrays[aix].dims[p];
        match current_extent {
            None => current_extent = Some(e),
            Some(c) if c == e => {}
            Some(c) => bail!(
                "arrays disagree on the extent of the varied dimension '{}' ({} vs {}) — \
                 no single blocking factor governs it",
                varied,
                c,
                e
            ),
        }
    }
    let Some(current_extent) = current_extent else {
        bail!("no array dimension is indexed by the inner loop '{varied}' — nothing to block");
    };
    if current_extent == 0 {
        bail!("the varied dimension '{varied}' has extent 0");
    }

    let mut breakpoints = Vec::new();
    for lvl in machine.cache_levels() {
        let size = {
            let s = lvl.size_bytes.unwrap_or(0);
            if lvl.cores_per_group > 1 {
                s / cores.min(lvl.cores_per_group).max(1) as u64
            } else {
                s
            }
        };
        for d in 0..n_loops {
            let dim_name = analysis.loops[d].index.clone();
            let mut const_bytes: u64 = 0;
            let mut slope_bytes: u64 = 0;
            for (aix, arr) in analysis.arrays.iter().enumerate() {
                // identical span/coeff scan to layer_conditions()
                let mut lo = i64::MAX;
                let mut hi = i64::MIN;
                let mut coeff: i64 = 0;
                for acc in analysis.reads.iter().chain(analysis.writes.iter()) {
                    if acc.array != aix || acc.coeffs[d] == 0 {
                        continue;
                    }
                    coeff = acc.coeffs[d].abs();
                    let layer_off: i64 = acc
                        .dims
                        .iter()
                        .filter_map(|dim| match dim {
                            DimAccess::Relative { var, offset } if *var == dim_name => {
                                Some(*offset)
                            }
                            _ => None,
                        })
                        .sum();
                    lo = lo.min(layer_off);
                    hi = hi.max(layer_off);
                }
                if coeff == 0 {
                    continue;
                }
                let n_layers = (hi - lo) as u64 + 1;
                let term = n_layers.saturating_mul(coeff as u64) * arr.ty.size();
                // linear in the varied extent iff that extent is a factor
                // of the dim-d stride: the varied array dimension lies
                // strictly inside position d (row-major layout)
                let p_d = positions[aix][d];
                let p_v = positions[aix][n_loops - 1];
                if matches!((p_d, p_v), (Some(pd), Some(pv)) if pv > pd) {
                    if term % current_extent != 0 {
                        bail!(
                            "array '{}': footprint term {} is not divisible by the varied \
                             extent {} — the condition on '{}' is not linear in it",
                            arr.name,
                            term,
                            current_extent,
                            dim_name
                        );
                    }
                    slope_bytes = slope_bytes.saturating_add(term / current_extent);
                } else {
                    const_bytes = const_bytes.saturating_add(term);
                }
            }
            if slope_bytes == 0 {
                continue; // condition does not depend on the varied extent
            }
            let extent = if size > const_bytes { (size - const_bytes) / slope_bytes } else { 0 };
            breakpoints.push(LcBreakpoint {
                level: lvl.name.clone(),
                dim_index: d,
                dim_name,
                cache_bytes: size,
                const_bytes,
                slope_bytes,
                extent,
            });
        }
    }

    Ok(LcBlockingSolve {
        varied_dim: varied,
        varied_positions: positions.iter().map(|p| p[n_loops - 1]).collect(),
        current_extent,
        breakpoints,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kernel::{parse, KernelAnalysis};
    use std::collections::HashMap;

    fn consts(pairs: &[(&str, i64)]) -> HashMap<String, i64> {
        pairs.iter().map(|(k, v)| (k.to_string(), *v)).collect()
    }

    fn jacobi(n: i64, m: i64) -> KernelAnalysis {
        let src = r#"
            double a[M][N], b[M][N], s;
            for (int j = 1; j < M - 1; j++)
                for (int i = 1; i < N - 1; i++)
                    b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
        "#;
        let p = parse(src).unwrap();
        KernelAnalysis::from_program(&p, &consts(&[("N", n), ("M", m)])).unwrap()
    }

    fn triad(n: i64) -> KernelAnalysis {
        let src = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        KernelAnalysis::from_program(&p, &consts(&[("N", n)])).unwrap()
    }

    #[test]
    fn jacobi_snb_traffic_matches_paper() {
        // Paper Table 5, SNB, N=6000: T_L1L2 = 10 cy = 5 CL, T_L2L3 =
        // 6 cy = 3 CL, T_L3Mem = 3 CL. Layer condition holds in L2/L3 but
        // not L1.
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        assert_eq!(t.unit_iterations, 8);
        let l1 = &t.levels[0];
        assert_eq!(l1.read_miss_lines, 3.0, "rows j-1, j, j+1 miss L1");
        assert_eq!(l1.write_allocate_lines, 1.0);
        assert_eq!(l1.evict_lines, 1.0);
        assert_eq!(l1.total_lines(), 5.0);
        let l2 = &t.levels[1];
        assert_eq!(l2.read_miss_lines, 1.0, "only the leading row misses L2");
        assert_eq!(l2.total_lines(), 3.0);
        let l3 = &t.levels[2];
        assert_eq!(l3.total_lines(), 3.0);
    }

    #[test]
    fn jacobi_small_n_all_rows_hit_l1() {
        // With a short inner dimension the L1 layer condition holds and
        // only the leading row misses.
        let m = MachineModel::snb();
        let a = jacobi(256, 4000);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        let l1 = &t.levels[0];
        assert_eq!(l1.read_miss_lines, 1.0);
        assert_eq!(l1.total_lines(), 3.0);
    }

    #[test]
    fn triad_streams_miss_everywhere() {
        let a = triad(8_000_000);
        let m = MachineModel::snb();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        for lvl in &t.levels {
            assert_eq!(lvl.read_miss_lines, 3.0, "{}: b, c, d always miss", lvl.level);
            assert_eq!(lvl.write_allocate_lines, 1.0, "{}: a write-allocates", lvl.level);
            assert_eq!(lvl.evict_lines, 1.0);
            assert_eq!(lvl.total_lines(), 5.0);
        }
        // benchmark match at MEM: (3 reads, 0 rw, 1 write) → triad
        let sig = &t.levels.last().unwrap().miss_streams;
        assert_eq!(m.benchmarks.closest_kernel(sig).unwrap().name, "triad");
    }

    #[test]
    fn kahan_two_load_streams() {
        let src = r#"
            double a[N], b[N], c;
            double sum, prod, t, y;
            for (int i = 0; i < N; ++i) {
                prod = a[i] * b[i];
                y = prod - c;
                t = sum + y;
                c = (t - sum) - y;
                sum = t;
            }
        "#;
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 8_000_000)])).unwrap();
        let m = MachineModel::snb();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        for lvl in &t.levels {
            assert_eq!(lvl.total_lines(), 2.0, "{}", lvl.level);
            assert_eq!(lvl.evict_lines, 0.0);
        }
        let sig = &t.levels.last().unwrap().miss_streams;
        assert_eq!(sig, &StreamSig { reads: 2, read_writes: 0, writes: 0 });
        assert_eq!(m.benchmarks.closest_kernel(sig).unwrap().name, "load");
    }

    #[test]
    fn update_kernel_has_no_extra_write_allocate() {
        // a[i] = s * a[i]: the store line is already loaded by the read,
        // so only read-miss + evict traffic remains.
        let src = "double a[N], s;\nfor (int i = 0; i < N; i++) a[i] = s * a[i];";
        let p = parse(src).unwrap();
        let a = KernelAnalysis::from_program(&p, &consts(&[("N", 8_000_000)])).unwrap();
        let m = MachineModel::snb();
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        for lvl in &t.levels {
            assert_eq!(lvl.read_miss_lines, 1.0);
            assert_eq!(lvl.write_allocate_lines, 0.0, "{}", lvl.level);
            assert_eq!(lvl.evict_lines, 1.0);
        }
        let sig = &t.levels.last().unwrap().miss_streams;
        assert_eq!(sig, &StreamSig { reads: 0, read_writes: 1, writes: 0 });
        assert_eq!(m.benchmarks.closest_kernel(sig).unwrap().name, "update");
    }

    #[test]
    fn jacobi_layer_conditions() {
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        // j-dim (rows) condition: 4 rows × 48 kB = 192 kB — fails in L1
        // (32 kB), holds in L2 (256 kB) and L3.
        let find = |level: &str, dim: &str| {
            t.layer_conditions
                .iter()
                .find(|e| e.level == level && e.dim_name == dim)
                .unwrap()
        };
        assert!(!find("L1", "j").satisfied);
        assert!(find("L2", "j").satisfied);
        assert!(find("L3", "j").satisfied);
        // inner (i) condition is trivially satisfied everywhere
        assert!(find("L1", "i").satisfied);
    }

    #[test]
    fn access_hit_levels_jacobi() {
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        // at least one access must go all the way to memory (leading row)
        assert!(t.access_hit_level.iter().any(|l| l == "MEM"), "{:?}", t.access_hit_level);
        // the left neighbor (i-1) always hits L1
        let left_ix = a.reads.iter().position(|r| r.offset == -1).unwrap();
        assert_eq!(t.access_hit_level[left_ix], "L1");
    }

    #[test]
    fn shared_cache_partitioning() {
        // with 8 cores the per-core L3 share shrinks 8×
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let t1 = CachePredictor::new(&m).predict(&a).unwrap();
        let t8 = CachePredictor::with_cores(&m, 8).predict(&a).unwrap();
        let l3_1 = t1.levels[2].read_miss_lines;
        let l3_8 = t8.levels[2].read_miss_lines;
        assert!(l3_8 >= l3_1);
    }

    #[test]
    fn memory_bytes_per_unit() {
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        assert_eq!(t.memory_bytes_per_unit(), 192.0); // 3 CL × 64 B
    }

    #[test]
    fn miss_monotonicity_in_cache_size() {
        // property: for randomized stencil widths and sizes, misses must
        // not increase from inner to outer levels (window monotonicity).
        let mut rng = crate::util::XorShift64::new(0xC0FFEE);
        for _ in 0..10 {
            let w = rng.next_range(1, 4);
            let n = rng.next_range(64, 4096);
            let src = format!(
                "double a[M][N], b[M][N];\nfor (int j = {w}; j < M - {w}; j++)\n  for (int i = {w}; i < N - {w}; i++)\n    b[j][i] = a[j][i-{w}] + a[j][i+{w}] + a[j-{w}][i] + a[j+{w}][i];"
            );
            let p = parse(&src).unwrap();
            let a = KernelAnalysis::from_program(&p, &consts(&[("N", n), ("M", 1000)])).unwrap();
            let m = MachineModel::snb();
            let t = CachePredictor::new(&m).predict(&a).unwrap();
            let mut prev = f64::INFINITY;
            for lvl in &t.levels {
                assert!(
                    lvl.read_miss_lines <= prev + 1e-9,
                    "misses grew from inner to outer at {} (N={n}, w={w}): {:?}",
                    lvl.level,
                    t.levels.iter().map(|l| l.read_miss_lines).collect::<Vec<_>>()
                );
                prev = lvl.read_miss_lines;
            }
        }
    }

    #[test]
    fn hits_plus_misses_equal_unit_lines() {
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        let total0 = t.levels[0].hit_lines + t.levels[0].read_miss_lines;
        for lvl in &t.levels {
            assert_eq!(lvl.hit_lines + lvl.read_miss_lines, total0, "{}", lvl.level);
        }
    }

    // --- layer-condition fast path ---

    /// Compare every externally-visible field of two predictions.
    fn assert_traffic_eq(a: &TrafficPrediction, b: &TrafficPrediction, ctx: &str) {
        assert_eq!(a.unit_iterations, b.unit_iterations, "{ctx}: unit");
        assert_eq!(a.levels.len(), b.levels.len(), "{ctx}: levels");
        for (x, y) in a.levels.iter().zip(&b.levels) {
            assert_eq!(x, y, "{ctx}: level {}", x.level);
        }
        assert_eq!(a.access_hit_level, b.access_hit_level, "{ctx}: hit levels");
    }

    #[test]
    fn auto_matches_offsets_on_jacobi_across_lc_breakpoint() {
        let m = MachineModel::snb();
        // N=4000: L1 condition clearly fails (128 kB vs 32 kB), L2/L3
        // clearly hold. N=256: all levels hold. Both sides of the Fig. 3
        // breakpoint must agree bit-identically with the walk.
        for (n, mm) in [(4000i64, 4000i64), (256, 4000)] {
            let a = jacobi(n, mm);
            let walk = CachePredictor::new(&m).predict(&a).unwrap();
            let auto = CachePredictor::with_kind(&m, 1, CachePredictorKind::Auto)
                .predict(&a)
                .unwrap();
            assert_traffic_eq(&walk, &auto, &format!("jacobi N={n}"));
            assert_eq!(
                auto.stats.walk_levels, 0,
                "all levels decisive at N={n}: {:?}",
                auto.stats
            );
            assert_eq!(auto.stats.lc_fast_levels, 3);
            assert_eq!(walk.stats.lc_fast_levels, 0, "offsets mode never uses LC");
        }
    }

    #[test]
    fn auto_matches_offsets_on_triad_both_sizes() {
        let m = MachineModel::snb();
        for n in [256i64, 500_000] {
            let a = triad(n);
            let walk = CachePredictor::new(&m).predict(&a).unwrap();
            let auto = CachePredictor::with_kind(&m, 1, CachePredictorKind::Auto)
                .predict(&a)
                .unwrap();
            assert_traffic_eq(&walk, &auto, &format!("triad N={n}"));
            assert_eq!(auto.stats.walk_levels, 0, "triad N={n}: {:?}", auto.stats);
        }
    }

    #[test]
    fn auto_falls_back_to_walk_in_gray_zone() {
        // N=2020: the L1 j-condition needs ~63 kB against a 32 kB cache —
        // inside the 2x safety margin, so Auto must run the walk there
        // (and still agree with it, trivially).
        let m = MachineModel::snb();
        let a = jacobi(2020, 2020);
        let walk = CachePredictor::new(&m).predict(&a).unwrap();
        let auto =
            CachePredictor::with_kind(&m, 1, CachePredictorKind::Auto).predict(&a).unwrap();
        assert_traffic_eq(&walk, &auto, "jacobi gray zone");
        assert!(auto.stats.walk_levels >= 1, "{:?}", auto.stats);
        assert!(auto.stats.lc_fast_levels >= 1, "{:?}", auto.stats);
    }

    #[test]
    fn forced_lc_mode_answers_every_level() {
        let m = MachineModel::snb();
        let a = jacobi(6000, 6000);
        let lc = CachePredictor::with_kind(&m, 1, CachePredictorKind::LayerConditions)
            .predict(&a)
            .unwrap();
        assert_eq!(lc.stats.walk_levels, 0);
        assert_eq!(lc.stats.lc_fast_levels, 3);
        // steady-state numbers match the walk for this far-from-breakpoint size
        let walk = CachePredictor::new(&m).predict(&a).unwrap();
        assert_traffic_eq(&walk, &lc, "jacobi forced LC");
    }

    #[test]
    fn predictor_kind_parsing() {
        assert_eq!(CachePredictorKind::parse("offsets"), Some(CachePredictorKind::Offsets));
        assert_eq!(CachePredictorKind::parse("LC"), Some(CachePredictorKind::LayerConditions));
        assert_eq!(
            CachePredictorKind::parse("layer-conditions"),
            Some(CachePredictorKind::LayerConditions)
        );
        assert_eq!(CachePredictorKind::parse("auto"), Some(CachePredictorKind::Auto));
        assert_eq!(CachePredictorKind::parse("bogus"), None);
        // 'sim' used to alias Offsets; the simulator is -p Validate now
        assert_eq!(CachePredictorKind::parse("sim"), None);
    }

    // --- analytic breakpoint solver (DESIGN.md §5) ---

    #[test]
    fn solver_matches_hand_derived_jacobi_breakpoints() {
        // required(j) = (3 rows of a + 1 of b) · N · 8 B = 32·N, so the
        // inclusive breakpoint is N* = cache_bytes / 32: SNB L1 32 kB →
        // 1024, L2 256 kB → 8192, L3 20 MB (1 core) → 655360. The inner
        // (i) condition is constant in N and must yield no breakpoint.
        let m = MachineModel::snb();
        let s = solve_lc_breakpoints(&jacobi(4000, 4000), &m, 1).unwrap();
        assert_eq!(s.varied_dim, "i");
        assert_eq!(s.current_extent, 4000);
        let rows: Vec<(&str, &str, u64, u64, u64)> = s
            .breakpoints
            .iter()
            .map(|b| (b.level.as_str(), b.dim_name.as_str(), b.const_bytes, b.slope_bytes, b.extent))
            .collect();
        assert_eq!(
            rows,
            vec![
                ("L1", "j", 0, 32, 1024),
                ("L2", "j", 0, 32, 8192),
                ("L3", "j", 0, 32, 655360),
            ],
        );
    }

    #[test]
    fn lc_satisfied_flips_exactly_at_each_solved_breakpoint() {
        // the solved extent is the last satisfied size (inclusive bound):
        // the condition must hold at E* and fail at E*+1
        let m = MachineModel::snb();
        let solve = solve_lc_breakpoints(&jacobi(4000, 4000), &m, 1).unwrap();
        assert_eq!(solve.breakpoints.len(), 3);
        for b in &solve.breakpoints {
            for (extent, expect) in [(b.extent, true), (b.extent + 1, false)] {
                let a = jacobi(extent as i64, 4000);
                let e = layer_conditions(&a, &m, 1)
                    .into_iter()
                    .find(|e| e.level == b.level && e.dim_index == b.dim_index)
                    .unwrap();
                assert_eq!(
                    e.satisfied, expect,
                    "{}@{} at extent {extent}: required {} vs cache {}",
                    b.dim_name, b.level, e.required_bytes, e.cache_bytes
                );
            }
        }
    }

    #[test]
    fn auto_walks_at_exact_breakpoint_sizes_and_agrees_with_offsets() {
        // N = 1024 puts the L1 j-condition exactly at required == size.
        // The analytic answer is ambiguous there (the steady-state window
        // straddles the capacity), so Auto must treat it as gray zone,
        // fall back to the walk for that level, and match the offset
        // predictor bit for bit.
        let m = MachineModel::snb();
        let solve = solve_lc_breakpoints(&jacobi(4000, 4000), &m, 1).unwrap();
        for b in &solve.breakpoints {
            let a = jacobi(b.extent as i64, 4000);
            let walk = CachePredictor::new(&m).predict(&a).unwrap();
            let auto =
                CachePredictor::with_kind(&m, 1, CachePredictorKind::Auto).predict(&a).unwrap();
            assert_traffic_eq(&walk, &auto, &format!("jacobi at exact {} breakpoint", b.level));
            assert!(
                auto.stats.walk_levels >= 1,
                "{} boundary must not be answered analytically: {:?}",
                b.level,
                auto.stats
            );
        }
    }

    #[test]
    fn solver_rejects_one_dimensional_kernels() {
        let m = MachineModel::snb();
        let err = solve_lc_breakpoints(&triad(100_000), &m, 1).unwrap_err();
        assert!(format!("{err}").contains("depth >= 2"), "{err}");
    }

    // --- degenerate inputs ---

    #[test]
    fn empty_iteration_space_is_a_clean_error() {
        // M=2 leaves the outer loop with zero iterations (1..1).
        let m = MachineModel::snb();
        let a = jacobi(100, 2);
        let err = CachePredictor::new(&m).predict(&a).unwrap_err();
        assert!(format!("{err}").contains("empty iteration space"), "{err}");
    }

    #[test]
    fn absurd_offset_span_is_a_clean_error() {
        // Hand-craft an analysis whose offsets would overflow the reuse
        // computation; predict() must error, not wrap or spin.
        let mut a = jacobi(64, 64);
        a.reads[0].offset = i64::MIN + 1;
        a.reads[1].offset = i64::MAX - 1;
        let m = MachineModel::snb();
        let err = CachePredictor::new(&m).predict(&a).unwrap_err();
        assert!(format!("{err}").contains("overflow"), "{err}");
    }

    #[test]
    fn single_iteration_loops_are_fine() {
        // 3x3 jacobi: each loop runs exactly once; no spin, no panic.
        let m = MachineModel::snb();
        let a = jacobi(3, 3);
        let t = CachePredictor::new(&m).predict(&a).unwrap();
        assert_eq!(t.levels.len(), 3);
    }
}
