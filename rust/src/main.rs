//! `kerncraft` binary — see [`kerncraft::cli`] for the flag reference.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", kerncraft::cli::usage());
        std::process::exit(2);
    }
    match kerncraft::cli::run(&argv) {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("kerncraft: {e:#}");
            std::process::exit(1);
        }
    }
}
