//! `kerncraft` binary — see [`kerncraft::cli`] for the flag reference.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", kerncraft::cli::usage());
        std::process::exit(2);
    }
    // `check` maps its failure count to the exit code (clamped to the
    // 8-bit range), so CI can gate on `kerncraft check kernels/*.c`
    if argv[0] == "check" {
        match kerncraft::cli::run_check(&argv[1..]) {
            Ok((report, failed)) => {
                print!("{report}");
                std::process::exit(failed.min(255) as i32);
            }
            Err(e) => {
                eprintln!("kerncraft: {e:#}");
                std::process::exit(2);
            }
        }
    }
    match kerncraft::cli::run(&argv) {
        Ok(report) => print!("{report}"),
        Err(e) => {
            eprintln!("kerncraft: {e:#}");
            std::process::exit(1);
        }
    }
}
