//! `kerncraft serve --listen` — the network front end.
//!
//! A hand-rolled HTTP/1.1 server over [`std::net::TcpListener`] (the
//! offline crate set has no async runtime or HTTP stack; see
//! [`http`]) multiplexing concurrent connections onto the shared
//! [`Session`] pipeline of DESIGN.md §2. Endpoints:
//!
//! * `POST /analyze` — one JSON [`AnalysisRequest`] body, one JSON
//!   report (or error object) back.
//! * `POST /batch` — a JSON array of requests, evaluated in parallel
//!   through the shared session; one response array back, failed
//!   elements carrying their `index`.
//! * `POST /stream` — a JSON-lines body, answered with JSON-lines: the
//!   exact stdin/stdout wire protocol of `kerncraft serve`, over HTTP.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — text exposition of per-endpoint request/error
//!   totals, connection/queue gauges, the session's [`MemoStats`], the
//!   per-diagnostic-code rejected-input counters, and the
//!   persistent-cache counters (see [`metrics`]).
//!
//! A kernel the frontend rejects answers with 422 and the structured
//! diagnostic (stable code, span, snippet, hint) as a `"diagnostic"`
//! object next to the `"error"` string — see docs/SERVE.md.
//!
//! With `--cache-dir` the session consults a persistent, cross-process
//! [`cache::DiskCache`]: a restarted or sibling server answers repeated
//! requests byte-identically without re-evaluating. The wire contract is
//! documented in docs/SERVE.md, operational guidance (thread sizing,
//! cache layout, metrics reference) in docs/OPERATIONS.md.
//!
//! Concurrency model: a fixed pool of `--threads` connection workers
//! pulls accepted sockets from a bounded queue (backpressure: the
//! acceptor blocks when every worker is busy and the queue is full
//! rather than buffering unbounded connections). Keep-alive connections
//! are served until close or a 30 s idle timeout.
//!
//! [`MemoStats`]: crate::session::MemoStats

pub mod cache;
pub mod http;
pub mod metrics;

use crate::jsonio::{self, json_str, JsonValue};
use crate::session::{AnalysisRequest, Session};
use anyhow::{Context, Result};
use cache::DiskCache;
use metrics::{Endpoint, Metrics};
use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

const JSON: &str = "application/json";
const TEXT: &str = "text/plain; charset=utf-8";
const NDJSON: &str = "application/x-ndjson";

/// Default cap on one request body (`/batch` arrays included).
pub const DEFAULT_MAX_BODY_BYTES: usize = 16 << 20;

/// Most requests accepted in one `/batch` array or `/stream` body.
/// The body-size cap alone does not bound the *response*: report lines
/// are ~50× larger than minimal request lines, so an uncapped 16 MiB
/// body could balloon into a ~1 GB buffered response (and hours of
/// evaluation). Split larger workloads across calls — the shared
/// session keeps the cache warmth.
pub const MAX_REQUESTS_PER_CALL: usize = 10_000;

/// Reads time out after this much socket inactivity, so an *idle*
/// keep-alive connection releases its worker. A deliberately slow
/// client can still hold one worker by trickling bytes — which is why
/// the CLI defaults `--listen` to a multi-worker pool and
/// docs/OPERATIONS.md says to size `--threads` at the expected
/// concurrent connections.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Configuration of [`Server::bind`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Listen address, e.g. `127.0.0.1:8157` (`:0` picks a free port).
    pub listen: String,
    /// Connection workers (each batch request additionally fans its
    /// elements out over up to this many evaluation threads).
    pub threads: usize,
    /// Directory of the persistent report cache; None disables it.
    pub cache_dir: Option<PathBuf>,
    /// Largest accepted request body.
    pub max_body_bytes: usize,
    /// Log one `# method path -> status` line per request to stderr
    /// (the HTTP counterpart of the stream mode's `-v` summary).
    pub verbose: bool,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            listen: "127.0.0.1:8157".to_string(),
            threads: 1,
            cache_dir: None,
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
            verbose: false,
        }
    }
}

/// Everything a connection worker needs, shared behind one `Arc`.
struct ServerState {
    session: Session,
    /// Held concretely (not as the trait object the session owns) so
    /// `/metrics` can read the counters.
    cache: Option<Arc<DiskCache>>,
    metrics: Metrics,
    threads: usize,
    max_body: usize,
    verbose: bool,
}

/// A bound (but not yet running) server. [`Server::run`] blocks the
/// calling thread until [`ServerHandle::stop`] is invoked.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    shutdown: Arc<AtomicBool>,
    threads: usize,
}

/// Clonable stop trigger for a running [`Server`] (tests, signal
/// handlers).
#[derive(Clone)]
pub struct ServerHandle {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
}

impl ServerHandle {
    /// Ask the accept loop to exit. In-flight connections finish; the
    /// blocked `accept` is woken by a throwaway local connection.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(self.addr);
    }
}

impl Server {
    /// Bind the listen address and open the cache directory (when
    /// configured). No traffic is served until [`Server::run`].
    pub fn bind(opts: ServerOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding listen address {}", opts.listen))?;
        let (session, cache) = match &opts.cache_dir {
            Some(dir) => {
                let cache = Arc::new(DiskCache::open(dir)?);
                (Session::with_report_cache(cache.clone()), Some(cache))
            }
            None => (Session::new(), None),
        };
        let threads = opts.threads.max(1);
        Ok(Server {
            listener,
            state: Arc::new(ServerState {
                session,
                cache,
                metrics: Metrics::default(),
                threads,
                max_body: opts.max_body_bytes,
                verbose: opts.verbose,
            }),
            shutdown: Arc::new(AtomicBool::new(false)),
            threads,
        })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Stop trigger usable from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { addr: self.local_addr(), shutdown: self.shutdown.clone() }
    }

    /// Accept loop: distribute connections over the worker pool. Blocks
    /// until [`ServerHandle::stop`]; returns after in-flight
    /// connections drain.
    pub fn run(self) -> Result<()> {
        let state = &self.state;
        let shutdown = &self.shutdown;
        // bounded hand-off: an acceptor that outruns the workers blocks
        // here instead of buffering unbounded sockets
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(self.threads * 4);
        let conn_rx = Mutex::new(conn_rx);
        std::thread::scope(|scope| {
            for _ in 0..self.threads {
                let conn_rx = &conn_rx;
                scope.spawn(move || loop {
                    let conn = conn_rx.lock().unwrap().recv();
                    let Ok(stream) = conn else { break };
                    state.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    handle_connection(state, stream);
                });
            }
            for conn in self.listener.incoming() {
                if shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                state.metrics.connections.fetch_add(1, Ordering::Relaxed);
                state.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                if conn_tx.send(stream).is_err() {
                    break;
                }
            }
            drop(conn_tx);
        });
        Ok(())
    }
}

/// Serve one connection until close, error, or idle timeout.
fn handle_connection(state: &ServerState, stream: TcpStream) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let Ok(write_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(stream);
    let mut writer = BufWriter::new(write_half);
    loop {
        match http::read_request(&mut reader, &mut writer, state.max_body) {
            Ok(None) => break, // clean close between requests
            Ok(Some(req)) => {
                let ep = Endpoint::of_path(route(&req.path));
                state.metrics.request(ep);
                // a panicking evaluation must cost one 500, not a pool
                // worker — a shrinking pool would strand queued sockets
                let (status, ctype, body) =
                    match catch_unwind(AssertUnwindSafe(|| dispatch(state, &req))) {
                        Ok(r) => r,
                        Err(_) => (
                            500,
                            JSON,
                            error_body(None, None, "internal panic handling request"),
                        ),
                    };
                if status >= 400 {
                    state.metrics.errors_add(ep, 1);
                }
                if state.verbose {
                    eprintln!("# serve: {} {} -> {status}", req.method, req.path);
                }
                let keep = req.keep_alive && status != 500;
                if http::write_response(&mut writer, status, ctype, body.as_bytes(), keep)
                    .is_err()
                {
                    break;
                }
                if !keep {
                    break;
                }
            }
            Err(e) => {
                // framing errors answer with a status when the protocol
                // still allows one, then always close
                if let Some((status, msg)) = e.status() {
                    state.metrics.request(Endpoint::Other);
                    state.metrics.errors_add(Endpoint::Other, 1);
                    let _ = http::write_response(
                        &mut writer,
                        status,
                        JSON,
                        error_body(None, None, &msg).as_bytes(),
                        false,
                    );
                }
                break;
            }
        }
    }
}

/// Route component of a request-target: the path with any query string
/// stripped, so `GET /healthz?probe=1` (load balancers love query
/// markers) routes like `/healthz`.
fn route(path: &str) -> &str {
    match path.split_once('?') {
        Some((p, _)) => p,
        None => path,
    }
}

/// Route one parsed request to its handler.
fn dispatch(state: &ServerState, req: &http::HttpRequest) -> (u16, &'static str, String) {
    match (req.method.as_str(), route(&req.path)) {
        ("GET", "/healthz") => (200, JSON, "{\"status\": \"ok\"}".to_string()),
        ("GET", "/metrics") => (
            200,
            TEXT,
            state.metrics.render(
                &state.session.stats(),
                &state.session.rejected_by_code(),
                &state.session.requests_by_isa(),
                state.cache.as_ref().map(|c| c.stats()),
            ),
        ),
        ("POST", "/analyze") => handle_analyze(state, &req.body),
        ("POST", "/batch") => handle_batch(state, &req.body),
        ("POST", "/stream") => handle_stream(state, &req.body),
        (_, "/healthz" | "/metrics" | "/analyze" | "/batch" | "/stream") => (
            405,
            JSON,
            error_body(
                None,
                None,
                &format!("method {} not allowed on {}", req.method, req.path),
            ),
        ),
        (_, path) => (404, JSON, error_body(None, None, &format!("no such endpoint {path}"))),
    }
}

/// `POST /analyze`: one request in, one report (or error object) out.
fn handle_analyze(state: &ServerState, body: &[u8]) -> (u16, &'static str, String) {
    let Ok(text) = std::str::from_utf8(body) else {
        return (400, JSON, error_body(None, None, "request body is not UTF-8"));
    };
    let v = match jsonio::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (
                400,
                JSON,
                error_body(None, None, &format!("parsing analysis request: {e:#}")),
            )
        }
    };
    let id = v.get("id").and_then(|x| x.as_str().map(str::to_string));
    let req = match AnalysisRequest::from_json_value(&v) {
        Ok(r) => r,
        Err(e) => return (400, JSON, error_body(id.as_deref(), None, &format!("{e:#}"))),
    };
    match state.session.evaluate(&req) {
        Ok(report) => (200, JSON, report.to_json()),
        Err(e) => (422, JSON, eval_error_body(req.id.as_deref(), None, &e)),
    }
}

/// `POST /batch`: a JSON array of requests, evaluated in parallel over
/// the shared session; element `i` of the response array is either a
/// report or an error object carrying `"index": i`.
fn handle_batch(state: &ServerState, body: &[u8]) -> (u16, &'static str, String) {
    let Ok(text) = std::str::from_utf8(body) else {
        return (400, JSON, error_body(None, None, "request body is not UTF-8"));
    };
    let v = match jsonio::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (400, JSON, error_body(None, None, &format!("parsing batch body: {e:#}")))
        }
    };
    let JsonValue::Arr(items) = v else {
        return (
            400,
            JSON,
            error_body(None, None, "batch body must be a JSON array of analysis requests"),
        );
    };
    if items.len() > MAX_REQUESTS_PER_CALL {
        return (
            400,
            JSON,
            error_body(
                None,
                None,
                &format!(
                    "batch of {} elements exceeds the {MAX_REQUESTS_PER_CALL} element cap (split the batch)",
                    items.len()
                ),
            ),
        );
    }
    // one slot per element: (response JSON, is_error), filled in parallel
    type BatchSlot = Mutex<Option<(String, bool)>>;
    let n = items.len();
    let results: Vec<BatchSlot> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = state.threads.min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let ix = next.fetch_add(1, Ordering::Relaxed);
                if ix >= n {
                    break;
                }
                let out = evaluate_batch_item(state, &items[ix], ix);
                *results[ix].lock().unwrap() = Some(out);
            });
        }
    });
    let mut failed = 0u64;
    let mut s = String::from("[");
    for (ix, slot) in results.iter().enumerate() {
        let (line, is_err) =
            slot.lock().unwrap().take().expect("every batch element was evaluated");
        if is_err {
            failed += 1;
        }
        if ix > 0 {
            s.push_str(", ");
        }
        s.push_str(&line);
    }
    s.push(']');
    state.metrics.errors_add(Endpoint::Batch, failed);
    (200, JSON, s)
}

/// Evaluate one batch element; errors echo the element's `id` (when one
/// parses) and always its array `index`.
fn evaluate_batch_item(
    state: &ServerState,
    item: &JsonValue,
    ix: usize,
) -> (String, bool) {
    let id = item.get("id").and_then(|x| x.as_str().map(str::to_string));
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        AnalysisRequest::from_json_value(item).and_then(|req| state.session.evaluate(&req))
    }));
    match outcome {
        Ok(Ok(report)) => (report.to_json(), false),
        Ok(Err(e)) => (eval_error_body(id.as_deref(), Some(ix), &e), true),
        Err(_) => (
            error_body(id.as_deref(), Some(ix), "internal panic evaluating request"),
            true,
        ),
    }
}

/// `POST /stream`: the JSON-lines wire protocol of stdin-mode serve,
/// carried in an HTTP body — one response line per request line, same
/// framing, comments, and error-line rules (docs/SERVE.md).
fn handle_stream(state: &ServerState, body: &[u8]) -> (u16, &'static str, String) {
    // responses are buffered before the status line goes out, so bound
    // the request count — report lines amplify small request lines ~50×
    let lines = body.iter().filter(|&&b| b == b'\n').count()
        + usize::from(!body.is_empty() && body.last() != Some(&b'\n'));
    if lines > MAX_REQUESTS_PER_CALL {
        return (
            400,
            JSON,
            error_body(
                None,
                None,
                &format!(
                    "stream body of {lines} lines exceeds the {MAX_REQUESTS_PER_CALL} line cap (split the stream)"
                ),
            ),
        );
    }
    let mut input: &[u8] = body;
    let mut output: Vec<u8> = Vec::new();
    let opts = crate::cli::ServeOptions { threads: state.threads, ordered: true };
    match crate::cli::serve_with_session(&state.session, &mut input, &mut output, &opts) {
        Ok(summary) => {
            state.metrics.errors_add(Endpoint::Stream, summary.errors);
            let text = String::from_utf8(output).expect("response lines are UTF-8");
            (200, NDJSON, text)
        }
        Err(e) => (500, JSON, error_body(None, None, &format!("{e:#}"))),
    }
}

/// The error-object shape shared by every endpoint:
/// `{"id"?, "index"?, "error"}` — the HTTP counterpart of the JSON-lines
/// error line (which carries `"line"` instead of `"index"`).
fn error_body(id: Option<&str>, index: Option<usize>, msg: &str) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        s.push_str("\"id\": ");
        s.push_str(&json_str(id));
        s.push_str(", ");
    }
    if let Some(ix) = index {
        s.push_str(&format!("\"index\": {ix}, "));
    }
    s.push_str("\"error\": ");
    s.push_str(&json_str(msg));
    s.push('}');
    s
}

/// [`error_body`] for *evaluation* failures: when the failure is a
/// kernel-frontend rejection, the structured [`crate::kernel::Diagnostic`]
/// rides along as a `"diagnostic"` object (code, severity, message,
/// span, snippet, hint — docs/SERVE.md). Other failures keep the plain
/// shape, so the addition is strictly additive on the wire.
fn eval_error_body(id: Option<&str>, index: Option<usize>, e: &anyhow::Error) -> String {
    let mut s = error_body(id, index, &format!("{e:#}"));
    if let Some(ke) = e.downcast_ref::<crate::kernel::KernelError>() {
        s.truncate(s.len() - 1); // re-open the object
        s.push_str(", \"diagnostic\": ");
        s.push_str(&ke.diag.to_json());
        s.push('}');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_state() -> ServerState {
        ServerState {
            session: Session::new(),
            cache: None,
            metrics: Metrics::default(),
            threads: 2,
            max_body: DEFAULT_MAX_BODY_BYTES,
            verbose: false,
        }
    }

    fn req(method: &str, path: &str, body: &str) -> http::HttpRequest {
        http::HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
            keep_alive: true,
        }
    }

    #[test]
    fn dispatch_routes_and_statuses() {
        let state = test_state();
        let (status, _, body) = dispatch(&state, &req("GET", "/healthz", ""));
        assert_eq!(status, 200);
        assert!(body.contains("ok"), "{body}");
        let (status, _, body) = dispatch(&state, &req("GET", "/nope", ""));
        assert_eq!(status, 404);
        assert!(body.contains("\"error\""), "{body}");
        let (status, _, _) = dispatch(&state, &req("GET", "/analyze", ""));
        assert_eq!(status, 405);
        let (status, _, _) = dispatch(&state, &req("POST", "/healthz", "x"));
        assert_eq!(status, 405);
        let (status, ctype, body) = dispatch(&state, &req("GET", "/metrics", ""));
        assert_eq!(status, 200);
        assert!(ctype.starts_with("text/plain"));
        assert!(body.contains("kerncraft_requests_total"), "{body}");
        assert!(!body.contains("report_cache"), "no cache configured: {body}");
    }

    #[test]
    fn analyze_statuses_split_parse_and_evaluation_errors() {
        let state = test_state();
        let good = r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#;
        let (status, _, body) = dispatch(&state, &req("POST", "/analyze", good));
        assert_eq!(status, 200, "{body}");
        assert!(body.contains("\"kernel\": \"triad\""), "{body}");
        let (status, _, body) = dispatch(&state, &req("POST", "/analyze", "not json"));
        assert_eq!(status, 400);
        assert!(body.contains("\"error\""), "{body}");
        let bad = r#"{"id": "r9", "kernel": {"name": "nope"}, "machine": "SNB"}"#;
        let (status, _, body) = dispatch(&state, &req("POST", "/analyze", bad));
        assert_eq!(status, 422);
        assert!(body.contains("\"id\": \"r9\""), "{body}");
        assert!(body.contains("unknown reference kernel"), "{body}");
    }

    #[test]
    fn batch_indexes_errors_and_answers_every_element() {
        let state = test_state();
        let body = concat!(
            "[",
            r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}, "#,
            r#"{"id": "bad", "kernel": {"name": "nope"}, "machine": "SNB"}, "#,
            r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#,
            "]"
        );
        let (status, _, text) = dispatch(&state, &req("POST", "/batch", body));
        assert_eq!(status, 200, "{text}");
        let v = jsonio::parse(&text).unwrap();
        let items = v.items();
        assert_eq!(items.len(), 3, "{text}");
        assert!(items[0].get("ecm").is_some(), "{text}");
        assert_eq!(items[1].get("index").and_then(|x| x.as_u64()), Some(1), "{text}");
        assert_eq!(items[1].get("id").and_then(|x| x.as_str()), Some("bad"));
        assert!(items[1].get("error").is_some());
        assert!(items[2].get("ecm").is_some());
        assert_eq!(state.metrics.errors_for(Endpoint::Batch), 1);
        // non-array bodies are rejected up front
        let (status, _, text) = dispatch(&state, &req("POST", "/batch", "{}"));
        assert_eq!(status, 400, "{text}");
    }

    #[test]
    fn query_strings_do_not_change_routing() {
        let state = test_state();
        let (status, _, body) = dispatch(&state, &req("GET", "/healthz?probe=1", ""));
        assert_eq!(status, 200, "{body}");
        let (status, _, _) = dispatch(&state, &req("GET", "/metrics?format=text", ""));
        assert_eq!(status, 200);
        let (status, _, _) = dispatch(&state, &req("GET", "/nope?x", ""));
        assert_eq!(status, 404);
        assert_eq!(route("/analyze?pretty"), "/analyze");
        assert_eq!(route("/analyze"), "/analyze");
    }

    #[test]
    fn oversized_batches_and_streams_are_rejected_up_front() {
        let state = test_state();
        // a batch over the element cap is refused before any evaluation
        let mut batch = String::from("[");
        for ix in 0..(MAX_REQUESTS_PER_CALL + 1) {
            if ix > 0 {
                batch.push(',');
            }
            batch.push_str("{}");
        }
        batch.push(']');
        let (status, _, body) = dispatch(&state, &req("POST", "/batch", &batch));
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("element cap"), "{body}");
        // a stream body over the line cap is refused the same way
        let stream = "x\n".repeat(MAX_REQUESTS_PER_CALL + 1);
        let (status, _, body) = dispatch(&state, &req("POST", "/stream", &stream));
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("line cap"), "{body}");
        // no evaluation ran for either
        assert_eq!(state.session.stats().misses(), 0);
    }

    #[test]
    fn frontend_rejection_answers_422_with_diagnostic_and_counts() {
        let state = test_state();
        let body = r#"{"id": "bad-src", "kernel": {"source": "double a[N];\nfor (int i = 0; i < N; ++i) a[i] = ;", "label": "broken"}, "machine": "SNB", "constants": {"N": 64}}"#;
        let (status, _, text) = dispatch(&state, &req("POST", "/analyze", body));
        assert_eq!(status, 422, "{text}");
        let v = jsonio::parse(&text).unwrap();
        assert_eq!(v.get("id").and_then(|x| x.as_str()), Some("bad-src"));
        let diag = v.get("diagnostic").expect("structured diagnostic rides along");
        assert_eq!(diag.get("code").and_then(|x| x.as_str()), Some("E100"));
        let span = diag.get("span").expect("parse errors carry a span");
        assert_eq!(span.get("line").and_then(|x| x.as_u64()), Some(2));
        // ...and /metrics now exposes the per-code rejection counter
        let (_, _, metrics) = dispatch(&state, &req("GET", "/metrics", ""));
        assert!(
            metrics.contains("kerncraft_rejected_inputs_total{code=\"E100\"} 1"),
            "{metrics}"
        );
        // non-frontend failures keep the plain error shape
        let bad_ref = r#"{"kernel": {"name": "nope"}, "machine": "SNB"}"#;
        let (status, _, text) = dispatch(&state, &req("POST", "/analyze", bad_ref));
        assert_eq!(status, 422);
        assert!(!text.contains("diagnostic"), "{text}");
    }

    #[test]
    fn error_body_shapes() {
        assert_eq!(error_body(None, None, "x"), "{\"error\": \"x\"}");
        assert_eq!(
            error_body(Some("a"), Some(3), "boom"),
            "{\"id\": \"a\", \"index\": 3, \"error\": \"boom\"}"
        );
    }
}
