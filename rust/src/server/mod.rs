//! `kerncraft serve --listen` — the network front end.
//!
//! A hand-rolled HTTP/1.1 server over [`std::net::TcpListener`] (the
//! offline crate set has no async runtime or HTTP stack; see
//! [`http`]) multiplexing concurrent connections onto the shared
//! [`Session`] pipeline of DESIGN.md §2. Endpoints:
//!
//! * `POST /analyze` — one JSON [`AnalysisRequest`] body, one JSON
//!   report (or error object) back.
//! * `POST /advise` — an `/analyze` body with the model forced to
//!   `"Advise"`: the response report carries the analytic blocking
//!   advice of [`crate::advise`] in its `advise` section.
//! * `POST /batch` — a JSON array of requests, evaluated in parallel
//!   through the shared session; one response array back, failed
//!   elements carrying their `index`.
//! * `POST /stream` — a JSON-lines body, answered with JSON-lines: the
//!   exact stdin/stdout wire protocol of `kerncraft serve`, over HTTP.
//! * `GET /healthz` — liveness.
//! * `GET /metrics` — text exposition of per-endpoint request/error
//!   totals, connection/queue gauges, the session's [`MemoStats`], the
//!   per-diagnostic-code rejected-input counters, and the
//!   persistent-cache counters (see [`metrics`]).
//!
//! A kernel the frontend rejects answers with 422 and the structured
//! diagnostic (stable code, span, snippet, hint) as a `"diagnostic"`
//! object next to the `"error"` string — see docs/SERVE.md.
//!
//! With `--cache-dir` the session consults a persistent, cross-process
//! [`cache::DiskCache`]: a restarted or sibling server answers repeated
//! requests byte-identically without re-evaluating. The wire contract is
//! documented in docs/SERVE.md, operational guidance (thread sizing,
//! cache layout, metrics reference) in docs/OPERATIONS.md.
//!
//! Concurrency model ([`reactor`]): one event-loop thread owns every
//! socket behind a hand-rolled `poll(2)` readiness loop and runs the
//! per-connection read/write state machines over the incremental
//! parser in [`http`]; only *complete* parsed requests are dispatched
//! to the pool of `--threads` evaluation workers. An idle keep-alive
//! connection therefore costs a pollfd and a buffer, not a worker, and
//! is reaped after the (configurable) idle timeout. Backpressure is
//! per connection: at most one request per connection is in flight,
//! and a connection's read interest is dropped until its response is
//! written.
//!
//! [`MemoStats`]: crate::session::MemoStats

pub mod cache;
pub mod http;
pub mod metrics;
pub mod reactor;

use crate::jsonio::{self, json_str, JsonValue};
use crate::session::{AnalysisRequest, Session};
use anyhow::{Context, Result};
use cache::DiskCache;
use metrics::{Endpoint, Metrics};
use std::io::Write;
use std::net::{SocketAddr, TcpListener};
use std::os::unix::net::UnixStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

const JSON: &str = "application/json";
const TEXT: &str = "text/plain; charset=utf-8";
const NDJSON: &str = "application/x-ndjson";

/// Default cap on one request body (`/batch` arrays included).
pub const DEFAULT_MAX_BODY_BYTES: usize = 16 << 20;

/// Most requests accepted in one `/batch` array or `/stream` body.
/// The body-size cap alone does not bound the *response*: report lines
/// are ~50× larger than minimal request lines, so an uncapped 16 MiB
/// body could balloon into a ~1 GB buffered response (and hours of
/// evaluation). Split larger workloads across calls — the shared
/// session keeps the cache warmth.
pub const MAX_REQUESTS_PER_CALL: usize = 10_000;

/// Default reap deadline for a keep-alive connection that sits in the
/// reading state without delivering a byte (`--idle-timeout` overrides
/// it). Idle connections are cheap under the readiness loop — a pollfd
/// and a buffer, not a worker — so the timeout protects fd budget and
/// tracking state, not evaluation throughput (docs/OPERATIONS.md).
pub const DEFAULT_IDLE_TIMEOUT: Duration = Duration::from_secs(30);

/// Configuration of [`Server::bind`].
#[derive(Debug, Clone)]
pub struct ServerOptions {
    /// Listen address, e.g. `127.0.0.1:8157` (`:0` picks a free port).
    pub listen: String,
    /// Evaluation workers (each batch request additionally fans its
    /// elements out over up to this many evaluation threads).
    pub threads: usize,
    /// Directory of the persistent report cache; None disables it.
    pub cache_dir: Option<PathBuf>,
    /// Largest accepted request body.
    pub max_body_bytes: usize,
    /// Reap a keep-alive connection after this long without receiving
    /// a byte while no request of it is being evaluated or answered.
    pub idle_timeout: Duration,
    /// Log one `# method path -> status` line per request to stderr
    /// (the HTTP counterpart of the stream mode's `-v` summary).
    pub verbose: bool,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            listen: "127.0.0.1:8157".to_string(),
            threads: 1,
            cache_dir: None,
            max_body_bytes: DEFAULT_MAX_BODY_BYTES,
            idle_timeout: DEFAULT_IDLE_TIMEOUT,
            verbose: false,
        }
    }
}

/// Everything the reactor and its evaluation workers need, shared
/// behind one `Arc`.
struct ServerState {
    session: Session,
    /// Held concretely (not as the trait object the session owns) so
    /// `/metrics` can read the counters.
    cache: Option<Arc<DiskCache>>,
    metrics: Metrics,
    threads: usize,
    max_body: usize,
    verbose: bool,
}

/// A bound (but not yet running) server. [`Server::run`] blocks the
/// calling thread until [`ServerHandle::stop`] is invoked.
pub struct Server {
    listener: TcpListener,
    state: Arc<ServerState>,
    shutdown: Arc<AtomicBool>,
    /// Read end of the self-pipe the reactor polls alongside the
    /// sockets.
    wake_rx: UnixStream,
    /// Write end: rung by evaluation workers (completions) and by
    /// [`ServerHandle::stop`] (shutdown).
    wake_tx: Arc<UnixStream>,
    threads: usize,
    idle_timeout: Duration,
}

/// Clonable stop trigger for a running [`Server`] (tests, signal
/// handlers).
#[derive(Clone)]
pub struct ServerHandle {
    shutdown: Arc<AtomicBool>,
    wake: Arc<UnixStream>,
}

impl ServerHandle {
    /// Ask the reactor to shut down: a flag plus one byte down the
    /// self-pipe it is polling (no throwaway wake connection — the old
    /// blocked-`accept` trick raced real clients for the accept queue).
    /// The reactor stops accepting, closes idle connections, finishes
    /// writing every dispatched response, then [`Server::run`] returns.
    pub fn stop(&self) {
        self.shutdown.store(true, Ordering::Relaxed);
        let mut wake: &UnixStream = &self.wake;
        let _ = wake.write(&[1u8]);
    }
}

impl Server {
    /// Bind the listen address and open the cache directory (when
    /// configured). No traffic is served until [`Server::run`].
    pub fn bind(opts: ServerOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.listen)
            .with_context(|| format!("binding listen address {}", opts.listen))?;
        let (session, cache) = match &opts.cache_dir {
            Some(dir) => {
                let cache = Arc::new(DiskCache::open(dir)?);
                (Session::with_report_cache(cache.clone()), Some(cache))
            }
            None => (Session::new(), None),
        };
        let threads = opts.threads.max(1);
        // a socketpair as the self-pipe: no extra FFI, and both ends
        // are made nonblocking so a wake write can never block a
        // worker (a full pipe already guarantees a pending wakeup)
        let (wake_tx, wake_rx) = UnixStream::pair().context("creating wake pipe")?;
        wake_tx.set_nonblocking(true).context("setting wake pipe nonblocking")?;
        wake_rx.set_nonblocking(true).context("setting wake pipe nonblocking")?;
        Ok(Server {
            listener,
            state: Arc::new(ServerState {
                session,
                cache,
                metrics: Metrics::default(),
                threads,
                max_body: opts.max_body_bytes,
                verbose: opts.verbose,
            }),
            shutdown: Arc::new(AtomicBool::new(false)),
            wake_rx,
            wake_tx: Arc::new(wake_tx),
            threads,
            idle_timeout: opts.idle_timeout,
        })
    }

    /// The bound address (resolves `:0` to the picked port).
    pub fn local_addr(&self) -> SocketAddr {
        self.listener.local_addr().expect("bound listener has an address")
    }

    /// Stop trigger usable from another thread.
    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shutdown: self.shutdown.clone(), wake: self.wake_tx.clone() }
    }

    /// Run the readiness loop ([`reactor`]) on the calling thread with
    /// `--threads` evaluation workers beside it. Blocks until
    /// [`ServerHandle::stop`]; returns after every dispatched response
    /// has been written.
    pub fn run(self) -> Result<()> {
        let Server { listener, state, shutdown, wake_rx, wake_tx, threads, idle_timeout } = self;
        reactor::run(&state, listener, wake_rx, &wake_tx, &shutdown, threads, idle_timeout)
    }
}

/// Route component of a request-target: the path with any query string
/// stripped, so `GET /healthz?probe=1` (load balancers love query
/// markers) routes like `/healthz`.
fn route(path: &str) -> &str {
    match path.split_once('?') {
        Some((p, _)) => p,
        None => path,
    }
}

/// Route one parsed request to its handler.
fn dispatch(state: &ServerState, req: &http::HttpRequest) -> (u16, &'static str, String) {
    match (req.method.as_str(), route(&req.path)) {
        ("GET", "/healthz") => (200, JSON, "{\"status\": \"ok\"}".to_string()),
        ("GET", "/metrics") => (
            200,
            TEXT,
            state.metrics.render(
                &state.session.stats(),
                &state.session.rejected_by_code(),
                &state.session.requests_by_isa(),
                &state.session.eval_seconds_by_model(),
                &state.session.sim_touches_by_engine(),
                state.cache.as_ref().map(|c| c.stats()),
            ),
        ),
        ("POST", "/analyze") => handle_analyze(state, &req.body, None),
        ("POST", "/advise") => {
            handle_analyze(state, &req.body, Some(crate::session::ModelKind::Advise))
        }
        ("POST", "/batch") => handle_batch(state, &req.body),
        ("POST", "/stream") => handle_stream(state, &req.body),
        (_, "/healthz" | "/metrics" | "/analyze" | "/advise" | "/batch" | "/stream") => (
            405,
            JSON,
            error_body(
                None,
                None,
                &format!("method {} not allowed on {}", req.method, req.path),
            ),
        ),
        (_, path) => (404, JSON, error_body(None, None, &format!("no such endpoint {path}"))),
    }
}

/// `POST /analyze`: one request in, one report (or error object) out.
/// `/advise` shares this handler with `force_model` set — the body's
/// own `"model"` field (if any) is overridden.
fn handle_analyze(
    state: &ServerState,
    body: &[u8],
    force_model: Option<crate::session::ModelKind>,
) -> (u16, &'static str, String) {
    let Ok(text) = std::str::from_utf8(body) else {
        return (400, JSON, error_body(None, None, "request body is not UTF-8"));
    };
    let v = match jsonio::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (
                400,
                JSON,
                error_body(None, None, &format!("parsing analysis request: {e:#}")),
            )
        }
    };
    let id = v.get("id").and_then(|x| x.as_str().map(str::to_string));
    let mut req = match AnalysisRequest::from_json_value(&v) {
        Ok(r) => r,
        Err(e) => return (400, JSON, error_body(id.as_deref(), None, &format!("{e:#}"))),
    };
    if let Some(model) = force_model {
        req.model = model;
    }
    match state.session.evaluate(&req) {
        Ok(report) => (200, JSON, report.to_json()),
        Err(e) => (422, JSON, eval_error_body(req.id.as_deref(), None, &e)),
    }
}

/// `POST /batch`: a JSON array of requests, evaluated in parallel over
/// the shared session; element `i` of the response array is either a
/// report or an error object carrying `"index": i`.
fn handle_batch(state: &ServerState, body: &[u8]) -> (u16, &'static str, String) {
    let Ok(text) = std::str::from_utf8(body) else {
        return (400, JSON, error_body(None, None, "request body is not UTF-8"));
    };
    let v = match jsonio::parse(text) {
        Ok(v) => v,
        Err(e) => {
            return (400, JSON, error_body(None, None, &format!("parsing batch body: {e:#}")))
        }
    };
    let JsonValue::Arr(items) = v else {
        return (
            400,
            JSON,
            error_body(None, None, "batch body must be a JSON array of analysis requests"),
        );
    };
    if items.len() > MAX_REQUESTS_PER_CALL {
        return (
            400,
            JSON,
            error_body(
                None,
                None,
                &format!(
                    "batch of {} elements exceeds the {MAX_REQUESTS_PER_CALL} element cap (split the batch)",
                    items.len()
                ),
            ),
        );
    }
    // one slot per element: (response JSON, is_error), filled in parallel
    type BatchSlot = Mutex<Option<(String, bool)>>;
    let n = items.len();
    let results: Vec<BatchSlot> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let workers = state.threads.min(n.max(1));
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let ix = next.fetch_add(1, Ordering::Relaxed);
                if ix >= n {
                    break;
                }
                let out = evaluate_batch_item(state, &items[ix], ix);
                *results[ix].lock().unwrap() = Some(out);
            });
        }
    });
    let mut failed = 0u64;
    let mut s = String::from("[");
    for (ix, slot) in results.iter().enumerate() {
        let (line, is_err) =
            slot.lock().unwrap().take().expect("every batch element was evaluated");
        if is_err {
            failed += 1;
        }
        if ix > 0 {
            s.push_str(", ");
        }
        s.push_str(&line);
    }
    s.push(']');
    state.metrics.errors_add(Endpoint::Batch, failed);
    (200, JSON, s)
}

/// Evaluate one batch element; errors echo the element's `id` (when one
/// parses) and always its array `index`.
fn evaluate_batch_item(
    state: &ServerState,
    item: &JsonValue,
    ix: usize,
) -> (String, bool) {
    let id = item.get("id").and_then(|x| x.as_str().map(str::to_string));
    let outcome = catch_unwind(AssertUnwindSafe(|| {
        AnalysisRequest::from_json_value(item).and_then(|req| state.session.evaluate(&req))
    }));
    match outcome {
        Ok(Ok(report)) => (report.to_json(), false),
        Ok(Err(e)) => (eval_error_body(id.as_deref(), Some(ix), &e), true),
        Err(_) => (
            error_body(id.as_deref(), Some(ix), "internal panic evaluating request"),
            true,
        ),
    }
}

/// `POST /stream`: the JSON-lines wire protocol of stdin-mode serve,
/// carried in an HTTP body — one response line per request line, same
/// framing, comments, and error-line rules (docs/SERVE.md).
fn handle_stream(state: &ServerState, body: &[u8]) -> (u16, &'static str, String) {
    // responses are buffered before the status line goes out, so bound
    // the request count — report lines amplify small request lines ~50×
    let lines = body.iter().filter(|&&b| b == b'\n').count()
        + usize::from(!body.is_empty() && body.last() != Some(&b'\n'));
    if lines > MAX_REQUESTS_PER_CALL {
        return (
            400,
            JSON,
            error_body(
                None,
                None,
                &format!(
                    "stream body of {lines} lines exceeds the {MAX_REQUESTS_PER_CALL} line cap (split the stream)"
                ),
            ),
        );
    }
    let mut input: &[u8] = body;
    let mut output: Vec<u8> = Vec::new();
    let opts = crate::cli::ServeOptions { threads: state.threads, ordered: true };
    match crate::cli::serve_with_session(&state.session, &mut input, &mut output, &opts) {
        Ok(summary) => {
            state.metrics.errors_add(Endpoint::Stream, summary.errors);
            let text = String::from_utf8(output).expect("response lines are UTF-8");
            (200, NDJSON, text)
        }
        Err(e) => (500, JSON, error_body(None, None, &format!("{e:#}"))),
    }
}

/// The error-object shape shared by every endpoint:
/// `{"id"?, "index"?, "error"}` — the HTTP counterpart of the JSON-lines
/// error line (which carries `"line"` instead of `"index"`).
fn error_body(id: Option<&str>, index: Option<usize>, msg: &str) -> String {
    let mut s = String::from("{");
    if let Some(id) = id {
        s.push_str("\"id\": ");
        s.push_str(&json_str(id));
        s.push_str(", ");
    }
    if let Some(ix) = index {
        s.push_str(&format!("\"index\": {ix}, "));
    }
    s.push_str("\"error\": ");
    s.push_str(&json_str(msg));
    s.push('}');
    s
}

/// [`error_body`] for *evaluation* failures: when the failure is a
/// kernel-frontend rejection, the structured [`crate::kernel::Diagnostic`]
/// rides along as a `"diagnostic"` object (code, severity, message,
/// span, snippet, hint — docs/SERVE.md). Other failures keep the plain
/// shape, so the addition is strictly additive on the wire.
fn eval_error_body(id: Option<&str>, index: Option<usize>, e: &anyhow::Error) -> String {
    let mut s = error_body(id, index, &format!("{e:#}"));
    if let Some(ke) = e.downcast_ref::<crate::kernel::KernelError>() {
        s.truncate(s.len() - 1); // re-open the object
        s.push_str(", \"diagnostic\": ");
        s.push_str(&ke.diag.to_json());
        s.push('}');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_state() -> ServerState {
        ServerState {
            session: Session::new(),
            cache: None,
            metrics: Metrics::default(),
            threads: 2,
            max_body: DEFAULT_MAX_BODY_BYTES,
            verbose: false,
        }
    }

    fn req(method: &str, path: &str, body: &str) -> http::HttpRequest {
        http::HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            headers: Vec::new(),
            body: body.as_bytes().to_vec(),
            keep_alive: true,
        }
    }

    #[test]
    fn dispatch_routes_and_statuses() {
        let state = test_state();
        let (status, _, body) = dispatch(&state, &req("GET", "/healthz", ""));
        assert_eq!(status, 200);
        assert!(body.contains("ok"), "{body}");
        let (status, _, body) = dispatch(&state, &req("GET", "/nope", ""));
        assert_eq!(status, 404);
        assert!(body.contains("\"error\""), "{body}");
        let (status, _, _) = dispatch(&state, &req("GET", "/analyze", ""));
        assert_eq!(status, 405);
        let (status, _, _) = dispatch(&state, &req("GET", "/advise", ""));
        assert_eq!(status, 405, "/advise is POST-only");
        let (status, _, _) = dispatch(&state, &req("POST", "/healthz", "x"));
        assert_eq!(status, 405);
        let (status, ctype, body) = dispatch(&state, &req("GET", "/metrics", ""));
        assert_eq!(status, 200);
        assert!(ctype.starts_with("text/plain"));
        assert!(body.contains("kerncraft_requests_total"), "{body}");
        assert!(!body.contains("report_cache"), "no cache configured: {body}");
    }

    #[test]
    fn analyze_statuses_split_parse_and_evaluation_errors() {
        let state = test_state();
        let good = r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#;
        let (status, _, body) = dispatch(&state, &req("POST", "/analyze", good));
        assert_eq!(status, 200, "{body}");
        assert!(body.contains("\"kernel\": \"triad\""), "{body}");
        let (status, _, body) = dispatch(&state, &req("POST", "/analyze", "not json"));
        assert_eq!(status, 400);
        assert!(body.contains("\"error\""), "{body}");
        let bad = r#"{"id": "r9", "kernel": {"name": "nope"}, "machine": "SNB"}"#;
        let (status, _, body) = dispatch(&state, &req("POST", "/analyze", bad));
        assert_eq!(status, 422);
        assert!(body.contains("\"id\": \"r9\""), "{body}");
        assert!(body.contains("unknown reference kernel"), "{body}");
    }

    #[test]
    fn advise_endpoint_forces_the_model_and_carries_the_section() {
        let state = test_state();
        // no "model" field: /advise must force Advise itself
        let body = r#"{"id": "adv", "kernel": {"name": "2D-5pt"}, "machine": "SNB", "constants": {"N": 6000, "M": 6000}}"#;
        let (status, _, resp) = dispatch(&state, &req("POST", "/advise", body));
        assert_eq!(status, 200, "{resp}");
        assert!(resp.contains("\"model\": \"Advise\""), "{resp}");
        assert!(resp.contains("\"advise\": {"), "{resp}");
        assert!(resp.contains("\"candidates\""), "{resp}");
        // a kernel the adviser cannot block answers 422, like any
        // evaluation failure
        let bad = r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#;
        let (status, _, resp) = dispatch(&state, &req("POST", "/advise", bad));
        assert_eq!(status, 422, "{resp}");
        assert!(resp.contains("depth >= 2"), "{resp}");
    }

    #[test]
    fn batch_indexes_errors_and_answers_every_element() {
        let state = test_state();
        let body = concat!(
            "[",
            r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}, "#,
            r#"{"id": "bad", "kernel": {"name": "nope"}, "machine": "SNB"}, "#,
            r#"{"kernel": {"name": "triad"}, "machine": "SNB", "constants": {"N": 65536}}"#,
            "]"
        );
        let (status, _, text) = dispatch(&state, &req("POST", "/batch", body));
        assert_eq!(status, 200, "{text}");
        let v = jsonio::parse(&text).unwrap();
        let items = v.items();
        assert_eq!(items.len(), 3, "{text}");
        assert!(items[0].get("ecm").is_some(), "{text}");
        assert_eq!(items[1].get("index").and_then(|x| x.as_u64()), Some(1), "{text}");
        assert_eq!(items[1].get("id").and_then(|x| x.as_str()), Some("bad"));
        assert!(items[1].get("error").is_some());
        assert!(items[2].get("ecm").is_some());
        assert_eq!(state.metrics.errors_for(Endpoint::Batch), 1);
        // non-array bodies are rejected up front
        let (status, _, text) = dispatch(&state, &req("POST", "/batch", "{}"));
        assert_eq!(status, 400, "{text}");
    }

    #[test]
    fn query_strings_do_not_change_routing() {
        let state = test_state();
        let (status, _, body) = dispatch(&state, &req("GET", "/healthz?probe=1", ""));
        assert_eq!(status, 200, "{body}");
        let (status, _, _) = dispatch(&state, &req("GET", "/metrics?format=text", ""));
        assert_eq!(status, 200);
        let (status, _, _) = dispatch(&state, &req("GET", "/nope?x", ""));
        assert_eq!(status, 404);
        assert_eq!(route("/analyze?pretty"), "/analyze");
        assert_eq!(route("/analyze"), "/analyze");
    }

    #[test]
    fn oversized_batches_and_streams_are_rejected_up_front() {
        let state = test_state();
        // a batch over the element cap is refused before any evaluation
        let mut batch = String::from("[");
        for ix in 0..(MAX_REQUESTS_PER_CALL + 1) {
            if ix > 0 {
                batch.push(',');
            }
            batch.push_str("{}");
        }
        batch.push(']');
        let (status, _, body) = dispatch(&state, &req("POST", "/batch", &batch));
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("element cap"), "{body}");
        // a stream body over the line cap is refused the same way
        let stream = "x\n".repeat(MAX_REQUESTS_PER_CALL + 1);
        let (status, _, body) = dispatch(&state, &req("POST", "/stream", &stream));
        assert_eq!(status, 400, "{body}");
        assert!(body.contains("line cap"), "{body}");
        // no evaluation ran for either
        assert_eq!(state.session.stats().misses(), 0);
    }

    #[test]
    fn frontend_rejection_answers_422_with_diagnostic_and_counts() {
        let state = test_state();
        let body = r#"{"id": "bad-src", "kernel": {"source": "double a[N];\nfor (int i = 0; i < N; ++i) a[i] = ;", "label": "broken"}, "machine": "SNB", "constants": {"N": 64}}"#;
        let (status, _, text) = dispatch(&state, &req("POST", "/analyze", body));
        assert_eq!(status, 422, "{text}");
        let v = jsonio::parse(&text).unwrap();
        assert_eq!(v.get("id").and_then(|x| x.as_str()), Some("bad-src"));
        let diag = v.get("diagnostic").expect("structured diagnostic rides along");
        assert_eq!(diag.get("code").and_then(|x| x.as_str()), Some("E100"));
        let span = diag.get("span").expect("parse errors carry a span");
        assert_eq!(span.get("line").and_then(|x| x.as_u64()), Some(2));
        // ...and /metrics now exposes the per-code rejection counter
        let (_, _, metrics) = dispatch(&state, &req("GET", "/metrics", ""));
        assert!(
            metrics.contains("kerncraft_rejected_inputs_total{code=\"E100\"} 1"),
            "{metrics}"
        );
        // non-frontend failures keep the plain error shape
        let bad_ref = r#"{"kernel": {"name": "nope"}, "machine": "SNB"}"#;
        let (status, _, text) = dispatch(&state, &req("POST", "/analyze", bad_ref));
        assert_eq!(status, 422);
        assert!(!text.contains("diagnostic"), "{text}");
    }

    #[test]
    fn error_body_shapes() {
        assert_eq!(error_body(None, None, "x"), "{\"error\": \"x\"}");
        assert_eq!(
            error_body(Some("a"), Some(3), "boom"),
            "{\"id\": \"a\", \"index\": 3, \"error\": \"boom\"}"
        );
    }
}
