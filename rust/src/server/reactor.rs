//! The readiness loop behind `kerncraft serve --listen`: a hand-rolled
//! `poll(2)` reactor over `std::os::fd` (the offline crate set has no
//! mio/tokio, and the discipline matches the hand-rolled HTTP and
//! jsonio layers — see docs/OPERATIONS.md for the operator's view).
//!
//! One reactor thread owns every socket: the listener, a self-pipe
//! wake channel, and all client connections, each a nonblocking
//! [`TcpStream`] with a per-connection read/write state machine over
//! the incremental parser of [`super::http::try_parse`]. Only
//! *complete* parsed requests are handed to the worker pool, so an
//! idle keep-alive connection costs one `pollfd` and a small buffer —
//! not a pool worker. `GET /healthz` and `GET /metrics` are answered
//! inline by the reactor itself (they never evaluate anything), so a
//! saturated worker pool cannot fail a liveness probe.
//!
//! Flow of one request: `poll` reports the socket readable → bytes are
//! pulled into the connection's read buffer → `try_parse` either waits
//! for more, rejects the framing (the error response is queued and the
//! connection marked close-after-write), or yields a request →
//! evaluation requests are dispatched to a worker over a channel →
//! the worker pushes the serialized response onto the completion list
//! and writes one byte to the wake pipe → the reactor attaches the
//! bytes to the connection's write buffer and drains it as `POLLOUT`
//! allows → the connection returns to the reading state (pipelined
//! bytes already buffered are parsed immediately).
//!
//! Shutdown ([`super::ServerHandle::stop`]) writes the same wake pipe:
//! the reactor stops accepting, closes connections that are owed
//! nothing, finishes writing every dispatched response, then drops the
//! job channel so the workers drain and exit.

use super::http::{self, HttpRequest};
use super::metrics::Endpoint;
use super::ServerState;
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

/// `struct pollfd` of poll(2). A negative `fd` makes the kernel skip
/// the entry (used to keep index alignment for connections that want
/// no events this round, e.g. while their request is being evaluated).
#[repr(C)]
#[derive(Clone, Copy)]
struct PollFd {
    fd: i32,
    events: i16,
    revents: i16,
}

const POLLIN: i16 = 0x001;
const POLLOUT: i16 = 0x004;
const POLLNVAL: i16 = 0x020;

extern "C" {
    fn poll(
        fds: *mut PollFd,
        nfds: std::os::raw::c_ulong,
        timeout: std::os::raw::c_int,
    ) -> std::os::raw::c_int;
}

/// poll(2) with EINTR retry. `timeout_ms < 0` blocks indefinitely.
fn poll_all(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
    loop {
        let nfds = fds.len() as std::os::raw::c_ulong;
        let n = unsafe { poll(fds.as_mut_ptr(), nfds, timeout_ms) };
        if n >= 0 {
            return Ok(n as usize);
        }
        let e = std::io::Error::last_os_error();
        if e.kind() != ErrorKind::Interrupted {
            return Err(e);
        }
    }
}

/// One parsed request on its way to a worker.
struct Job {
    token: u64,
    req: HttpRequest,
}

/// One serialized response on its way back to the reactor.
struct Completion {
    token: u64,
    bytes: Vec<u8>,
    keep: bool,
}

/// Per-connection lifecycle.
enum ConnState {
    /// Accumulating bytes of the next request.
    Reading,
    /// A complete request is with a worker; no response queued yet.
    InFlight,
    /// A response is queued/draining; on empty, back to Reading or
    /// close (`close_after_write`).
    Writing,
}

/// One client connection owned by the reactor.
struct Conn {
    stream: TcpStream,
    state: ConnState,
    read_buf: Vec<u8>,
    write_buf: Vec<u8>,
    /// Bytes of `write_buf` already written to the socket.
    write_pos: usize,
    /// Close as soon as `write_buf` drains (error responses,
    /// `Connection: close`, shutdown).
    close_after_write: bool,
    /// An interim `100 Continue` went out for the current request.
    sent_continue: bool,
    /// Peer sent FIN — no more request bytes will arrive.
    read_closed: bool,
    /// When an idle connection in `Reading` is reaped.
    idle_deadline: Instant,
}

/// What to do with a connection after a pump step.
enum Disposition {
    Keep,
    Close,
}

/// One step of the parse/dispatch side of the state machine.
enum Step {
    /// Progress was made (bytes queued or state changed) — pump again.
    Continue,
    /// Waiting on the peer or on a worker.
    Wait,
    /// The connection is done.
    Close,
}

/// Spawn the worker pool and run the reactor until shutdown. Owns the
/// calling thread; returns after every dispatched response is written.
pub(crate) fn run(
    state: &ServerState,
    listener: TcpListener,
    wake_rx: UnixStream,
    wake_tx: &UnixStream,
    shutdown: &AtomicBool,
    threads: usize,
    idle_timeout: Duration,
) -> Result<()> {
    listener.set_nonblocking(true).context("setting listener nonblocking")?;
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Mutex::new(job_rx);
    let done: Mutex<Vec<Completion>> = Mutex::new(Vec::new());
    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            let job_rx = &job_rx;
            let done = &done;
            let wake = wake_tx;
            scope.spawn(move || worker_loop(state, job_rx, done, wake));
        }
        // event_loop drops job_tx on return, which drains the workers
        event_loop(state, &listener, &wake_rx, shutdown, idle_timeout, job_tx, &done)
    })
}

/// A pool worker: evaluate dispatched requests, serialize the
/// response, push it on the completion list, ring the wake pipe.
fn worker_loop(
    state: &ServerState,
    jobs: &Mutex<mpsc::Receiver<Job>>,
    done: &Mutex<Vec<Completion>>,
    wake: &UnixStream,
) {
    loop {
        let job = jobs.lock().unwrap().recv();
        let Ok(Job { token, req }) = job else { break };
        let ep = Endpoint::of_path(super::route(&req.path));
        // a panicking evaluation must cost one 500, not a pool worker —
        // a shrinking pool would strand dispatched requests
        let (status, ctype, body) =
            match catch_unwind(AssertUnwindSafe(|| super::dispatch(state, &req))) {
                Ok(r) => r,
                Err(_) => (
                    500,
                    super::JSON,
                    super::error_body(None, None, "internal panic handling request"),
                ),
            };
        if status >= 400 {
            state.metrics.errors_add(ep, 1);
        }
        if state.verbose {
            eprintln!("# serve: {} {} -> {status}", req.method, req.path);
        }
        let keep = req.keep_alive && status != 500;
        let mut bytes = Vec::with_capacity(body.len() + 128);
        let _ = http::write_response(&mut bytes, status, ctype, body.as_bytes(), keep);
        done.lock().unwrap().push(Completion { token, bytes, keep });
        notify(wake);
    }
}

/// Ring the wake pipe (nonblocking: a full pipe already guarantees a
/// pending wakeup, so a failed write is fine).
fn notify(mut wake: &UnixStream) {
    let _ = wake.write(&[1u8]);
}

/// Drain every pending wake byte.
fn drain_wake(mut wake: &UnixStream) {
    let mut sink = [0u8; 256];
    loop {
        match wake.read(&mut sink) {
            Ok(0) => break,
            Ok(_) => continue,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(_) => break, // WouldBlock: drained
        }
    }
}

/// Pull every available byte off a readable connection.
fn read_some(c: &mut Conn, idle_timeout: Duration) -> std::io::Result<()> {
    let mut scratch = [0u8; 16 * 1024];
    loop {
        match c.stream.read(&mut scratch) {
            Ok(0) => {
                c.read_closed = true;
                return Ok(());
            }
            Ok(n) => {
                c.read_buf.extend_from_slice(&scratch[..n]);
                c.idle_deadline = Instant::now() + idle_timeout;
                if n < scratch.len() {
                    return Ok(()); // socket very likely drained
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
}

/// Write as much queued response as the socket accepts. `Ok(true)`
/// when the buffer fully drained (and was reset), `Ok(false)` when the
/// socket is full.
fn write_some(c: &mut Conn) -> std::io::Result<bool> {
    while c.write_pos < c.write_buf.len() {
        match c.stream.write(&c.write_buf[c.write_pos..]) {
            Ok(0) => return Err(std::io::Error::from(ErrorKind::WriteZero)),
            Ok(n) => c.write_pos += n,
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(false),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    c.write_buf.clear();
    c.write_pos = 0;
    Ok(true)
}

/// The reactor's mutable world: every open connection plus the
/// dispatch bookkeeping.
struct EventLoop<'a> {
    state: &'a ServerState,
    job_tx: mpsc::Sender<Job>,
    idle_timeout: Duration,
    /// Shutdown observed: no new connections or requests; drain what
    /// is owed and exit.
    stopping: bool,
    /// Requests dispatched to workers whose responses have not yet
    /// been attached to their connection.
    inflight: usize,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

fn event_loop(
    state: &ServerState,
    listener: &TcpListener,
    wake_rx: &UnixStream,
    shutdown: &AtomicBool,
    idle_timeout: Duration,
    job_tx: mpsc::Sender<Job>,
    done: &Mutex<Vec<Completion>>,
) -> Result<()> {
    let mut lp = EventLoop {
        state,
        job_tx,
        idle_timeout,
        stopping: false,
        inflight: 0,
        conns: HashMap::new(),
        next_token: 0,
    };
    let mut fds: Vec<PollFd> = Vec::new();
    let mut tokens: Vec<u64> = Vec::new();
    loop {
        if !lp.stopping && shutdown.load(Ordering::Relaxed) {
            lp.begin_shutdown();
        }
        if lp.stopping && lp.inflight == 0 && lp.conns.is_empty() {
            break;
        }

        // assemble the pollfd set: wake pipe, listener (while
        // accepting), then one entry per connection
        fds.clear();
        tokens.clear();
        fds.push(PollFd { fd: wake_rx.as_raw_fd(), events: POLLIN, revents: 0 });
        let accepting = !lp.stopping;
        if accepting {
            fds.push(PollFd { fd: listener.as_raw_fd(), events: POLLIN, revents: 0 });
        }
        let base = fds.len();
        let now = Instant::now();
        let mut next_deadline_ms: i64 = -1;
        for (&tok, c) in lp.conns.iter() {
            let mut ev: i16 = 0;
            if matches!(c.state, ConnState::Reading) && !c.read_closed {
                ev |= POLLIN;
            }
            if c.write_pos < c.write_buf.len() {
                ev |= POLLOUT;
            }
            // no interest (request being evaluated): negative fd, so
            // the kernel skips the entry but indexes stay aligned
            let fd = if ev == 0 { -1 } else { c.stream.as_raw_fd() };
            fds.push(PollFd { fd, events: ev, revents: 0 });
            tokens.push(tok);
            if matches!(c.state, ConnState::Reading) && c.write_buf.is_empty() {
                let left = c.idle_deadline.saturating_duration_since(now);
                let left_ms = left.as_millis() as i64;
                if next_deadline_ms < 0 || left_ms < next_deadline_ms {
                    next_deadline_ms = left_ms;
                }
            }
        }
        // small slack so deadline wakeups land just past the deadline
        let timeout = if next_deadline_ms < 0 {
            -1
        } else {
            (next_deadline_ms + 20).min(i32::MAX as i64) as i32
        };
        poll_all(&mut fds, timeout).context("poll")?;

        // worker completions: drain the wake byte first so one written
        // after this point re-triggers the next poll
        if fds[0].revents != 0 {
            drain_wake(wake_rx);
        }
        let completed: Vec<Completion> = std::mem::take(&mut *done.lock().unwrap());
        for comp in completed {
            lp.inflight -= 1;
            state.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
            // the connection may be gone (peer error while evaluating);
            // the response is then simply dropped
            let Some(mut c) = lp.conns.remove(&comp.token) else { continue };
            c.write_buf.extend_from_slice(&comp.bytes);
            c.state = ConnState::Writing;
            if !comp.keep || c.read_closed {
                c.close_after_write = true;
            }
            lp.finish(comp.token, c);
        }

        if accepting && fds[1].revents != 0 {
            lp.accept_all(listener);
        }

        // per-connection readiness
        for (i, &tok) in tokens.iter().enumerate() {
            let re = fds[base + i].revents;
            if re == 0 {
                continue;
            }
            let Some(mut c) = lp.conns.remove(&tok) else { continue };
            if re & POLLNVAL != 0 {
                lp.drop_conn(c);
                continue;
            }
            // POLLERR/POLLHUP surface through read()/write() below
            if matches!(c.state, ConnState::Reading)
                && !c.read_closed
                && read_some(&mut c, idle_timeout).is_err()
            {
                lp.drop_conn(c);
                continue;
            }
            lp.finish(tok, c);
        }

        lp.reap_idle();
    }
    Ok(())
}

impl EventLoop<'_> {
    /// Accept every pending connection (edge of the listener's
    /// readiness; loop until `WouldBlock`).
    fn accept_all(&mut self, listener: &TcpListener) {
        loop {
            match listener.accept() {
                Ok((stream, _peer)) => {
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    // request/response pairs are single writes; Nagle
                    // only adds tail latency here
                    let _ = stream.set_nodelay(true);
                    let token = self.next_token;
                    self.next_token += 1;
                    self.state.metrics.connections.fetch_add(1, Ordering::Relaxed);
                    self.state.metrics.open_connections.fetch_add(1, Ordering::Relaxed);
                    self.conns.insert(
                        token,
                        Conn {
                            stream,
                            state: ConnState::Reading,
                            read_buf: Vec::new(),
                            write_buf: Vec::new(),
                            write_pos: 0,
                            close_after_write: false,
                            sent_continue: false,
                            read_closed: false,
                            idle_deadline: Instant::now() + self.idle_timeout,
                        },
                    );
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    /// Run the state machine for one connection until it blocks, then
    /// either reinsert it or drop it.
    fn finish(&mut self, token: u64, mut c: Conn) {
        match self.pump(token, &mut c) {
            Disposition::Keep => {
                self.conns.insert(token, c);
            }
            Disposition::Close => self.drop_conn(c),
        }
    }

    /// Drive writes and parses as far as they go without blocking.
    fn pump(&mut self, token: u64, c: &mut Conn) -> Disposition {
        loop {
            match write_some(c) {
                Err(_) => return Disposition::Close,
                Ok(false) => return Disposition::Keep, // socket full: POLLOUT
                Ok(true) => {}
            }
            if matches!(c.state, ConnState::Writing) {
                // the queued response went out fully
                if c.close_after_write {
                    return Disposition::Close;
                }
                c.state = ConnState::Reading;
                c.sent_continue = false;
                c.idle_deadline = Instant::now() + self.idle_timeout;
            }
            if !matches!(c.state, ConnState::Reading) {
                return Disposition::Keep; // InFlight: a completion wakes us
            }
            if self.stopping {
                // shutdown: no new requests, even pipelined ones
                return Disposition::Close;
            }
            match self.advance_parse(token, c) {
                Step::Close => return Disposition::Close,
                Step::Wait => return Disposition::Keep,
                Step::Continue => {}
            }
        }
    }

    /// Try to turn buffered bytes into the next request (state is
    /// `Reading`, nothing pending to write).
    fn advance_parse(&mut self, token: u64, c: &mut Conn) -> Step {
        match http::try_parse(&c.read_buf, self.state.max_body) {
            Ok(http::Parse::Complete { req, consumed }) => {
                c.read_buf.drain(..consumed);
                c.sent_continue = false;
                let ep = Endpoint::of_path(super::route(&req.path));
                self.state.metrics.request(ep);
                if req.method == "GET"
                    && matches!(super::route(&req.path), "/healthz" | "/metrics")
                {
                    // liveness endpoints answer inline from the reactor:
                    // they never evaluate anything, so a saturated
                    // worker pool cannot fail a health probe
                    let (status, ctype, body) = super::dispatch(self.state, &req);
                    if self.state.verbose {
                        eprintln!("# serve: {} {} -> {status}", req.method, req.path);
                    }
                    let _ = http::write_response(
                        &mut c.write_buf,
                        status,
                        ctype,
                        body.as_bytes(),
                        req.keep_alive,
                    );
                    c.state = ConnState::Writing;
                    c.close_after_write = !req.keep_alive;
                    return Step::Continue;
                }
                c.state = ConnState::InFlight;
                self.inflight += 1;
                self.state.metrics.queue_depth.fetch_add(1, Ordering::Relaxed);
                if self.job_tx.send(Job { token, req }).is_err() {
                    // workers gone (shutdown): nothing can answer
                    self.inflight -= 1;
                    self.state.metrics.queue_depth.fetch_sub(1, Ordering::Relaxed);
                    return Step::Close;
                }
                Step::Continue
            }
            Ok(http::Parse::Incomplete { headers_done, expect_continue }) => {
                if c.read_closed {
                    if c.read_buf.is_empty() || headers_done {
                        // clean close between requests, or FIN inside a
                        // promised body (nobody is listening for a
                        // status) — close silently
                        return Step::Close;
                    }
                    // partial header then FIN still gets its 400
                    self.state.metrics.request(Endpoint::Other);
                    self.state.metrics.errors_add(Endpoint::Other, 1);
                    self.framing_error(c, 400, "connection closed inside request");
                    return Step::Continue;
                }
                if expect_continue && !c.sent_continue {
                    c.sent_continue = true;
                    c.write_buf.extend_from_slice(b"HTTP/1.1 100 Continue\r\n\r\n");
                    return Step::Continue;
                }
                Step::Wait
            }
            Err(e) => {
                let (status, msg) = e.status();
                self.state.metrics.request(Endpoint::Other);
                self.state.metrics.errors_add(Endpoint::Other, 1);
                self.framing_error(c, status, &msg);
                Step::Continue
            }
        }
    }

    /// Queue a framing-error response; the connection closes once it
    /// is written (a framing error desynchronizes keep-alive).
    fn framing_error(&self, c: &mut Conn, status: u16, msg: &str) {
        let body = super::error_body(None, None, msg);
        let w = &mut c.write_buf;
        let _ = http::write_response(w, status, super::JSON, body.as_bytes(), false);
        c.state = ConnState::Writing;
        c.close_after_write = true;
    }

    /// Shutdown begins: stop accepting, close every connection that is
    /// owed nothing (no dispatched request, no queued response).
    fn begin_shutdown(&mut self) {
        self.stopping = true;
        let mut idle = Vec::new();
        for (&t, c) in self.conns.iter() {
            if matches!(c.state, ConnState::Reading) && c.write_pos >= c.write_buf.len() {
                idle.push(t);
            }
        }
        for t in idle {
            if let Some(c) = self.conns.remove(&t) {
                self.drop_conn(c);
            }
        }
    }

    /// Close connections whose idle deadline passed while waiting for
    /// a request.
    fn reap_idle(&mut self) {
        let now = Instant::now();
        let mut expired = Vec::new();
        for (&t, c) in self.conns.iter() {
            let waiting = matches!(c.state, ConnState::Reading) && c.write_buf.is_empty();
            if waiting && now >= c.idle_deadline {
                expired.push(t);
            }
        }
        for t in expired {
            if let Some(c) = self.conns.remove(&t) {
                self.state.metrics.idle_timeouts.fetch_add(1, Ordering::Relaxed);
                self.drop_conn(c);
            }
        }
    }

    fn drop_conn(&mut self, c: Conn) {
        self.state.metrics.open_connections.fetch_sub(1, Ordering::Relaxed);
        drop(c);
    }
}
