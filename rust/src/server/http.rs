//! Minimal HTTP/1.1 framing for `kerncraft serve --listen`.
//!
//! Hand-rolled on `std` for the same reason [`crate::jsonio`] exists:
//! the offline crate set has no hyper/axum, and the server needs only a
//! strict, bounded subset — request line, headers, and a
//! `Content-Length` body. Chunked transfer encoding is answered with
//! `501`, oversized declarations with `413`, and every limit is
//! enforced on the bytes *seen so far*, so one hostile or dribbling
//! connection cannot exhaust server memory.
//!
//! The parser is incremental: [`try_parse`] inspects a growing byte
//! buffer and reports [`Parse::Incomplete`] until one complete request
//! is present, which is what lets the readiness loop of
//! [`crate::server::reactor`] own thousands of partially received
//! connections without dedicating a thread (or an intermediate framing
//! buffer copy) to any of them. The endpoint semantics on top of this
//! framing live in [`crate::server`] and docs/SERVE.md.

use std::io::Write;

/// Longest accepted request/header line.
pub const MAX_HEADER_LINE_BYTES: usize = 8 << 10;

/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 100;

/// Blank lines tolerated before the request line (robust clients may
/// send a stray CRLF after a previous body).
const MAX_LEADING_BLANKS: usize = 8;

/// One parsed request: method, path, lower-cased headers, body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header (name, value) pairs; names are lower-cased on read.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection may serve another request after this one
    /// (HTTP/1.1 default true, `Connection: close` or HTTP/1.0 false).
    pub keep_alive: bool,
}

/// Why a request could not be parsed. Every variant maps to a response
/// status via [`HttpError::status`]; the transport layer answers with
/// it and closes the connection (a framing error desynchronizes
/// keep-alive).
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, header, or framing.
    BadRequest(String),
    /// `POST` without a `Content-Length`.
    LengthRequired,
    /// Declared body length exceeds the server's cap.
    TooLarge { declared: usize, cap: usize },
    /// A protocol feature this server does not speak (chunked bodies).
    NotImplemented(String),
}

impl HttpError {
    /// Status code and error message for the client.
    pub fn status(&self) -> (u16, String) {
        match self {
            HttpError::BadRequest(msg) => (400, msg.clone()),
            HttpError::LengthRequired => {
                (411, "POST requires a Content-Length header".to_string())
            }
            HttpError::TooLarge { declared, cap } => (
                413,
                format!("request body of {declared} bytes exceeds the {cap} byte cap"),
            ),
            HttpError::NotImplemented(msg) => (501, msg.clone()),
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            HttpError::LengthRequired => write!(f, "length required"),
            HttpError::TooLarge { declared, cap } => {
                write!(f, "body of {declared} bytes exceeds {cap} byte cap")
            }
            HttpError::NotImplemented(msg) => write!(f, "not implemented: {msg}"),
        }
    }
}

/// Outcome of [`try_parse`] over a partially received buffer.
#[derive(Debug)]
pub enum Parse {
    /// The buffer does not yet hold one complete request.
    Incomplete {
        /// The header block is complete and the request is only waiting
        /// on body bytes; `false` while still inside the request line
        /// or headers. The transport uses this to tell "FIN inside the
        /// headers" (answer 400) from "FIN inside the body" (close
        /// silently — the framing already promised more bytes).
        headers_done: bool,
        /// The complete headers carried `Expect: 100-continue` and the
        /// body has not fully arrived: the transport should emit the
        /// interim `100 Continue` response once (curl sends the header
        /// for bodies over 1 KiB and would otherwise stall a full
        /// second before transmitting the body).
        expect_continue: bool,
    },
    /// One complete request occupying the first `consumed` bytes of the
    /// buffer; bytes past `consumed` belong to the next (pipelined)
    /// request.
    Complete { req: HttpRequest, consumed: usize },
}

/// One LF-terminated line starting at `pos`: the line (trailing CR
/// stripped, UTF-8 checked) and the offset just past its newline, or
/// `None` when the buffer ends before the newline. An over-long line
/// errors as soon as the excess bytes exist — without waiting for the
/// newline — so a straddled or endless header line fails at the cap,
/// not at the buffer.
fn next_line(buf: &[u8], pos: usize) -> Result<Option<(String, usize)>, HttpError> {
    let rest = &buf[pos..];
    let Some(ix) = rest.iter().position(|&b| b == b'\n') else {
        if rest.len() > MAX_HEADER_LINE_BYTES {
            return Err(HttpError::BadRequest(format!(
                "header line exceeds {MAX_HEADER_LINE_BYTES} bytes"
            )));
        }
        return Ok(None);
    };
    if ix > MAX_HEADER_LINE_BYTES {
        return Err(HttpError::BadRequest(format!(
            "header line exceeds {MAX_HEADER_LINE_BYTES} bytes"
        )));
    }
    let mut line = &rest[..ix];
    if line.last() == Some(&b'\r') {
        line = &line[..line.len() - 1];
    }
    match std::str::from_utf8(line) {
        Ok(s) => Ok(Some((s.to_string(), pos + ix + 1))),
        Err(_) => Err(HttpError::BadRequest("non-UTF-8 header line".to_string())),
    }
}

/// Parse one request from the front of `buf`. Call again with the same
/// (longer) buffer after more bytes arrive; the parse restarts from the
/// beginning, which is O(header bytes) and therefore bounded by the
/// header caps however slowly a client dribbles.
pub fn try_parse(buf: &[u8], max_body: usize) -> Result<Parse, HttpError> {
    const MORE: Parse = Parse::Incomplete { headers_done: false, expect_continue: false };
    // leading blank lines before the request line
    let mut pos = 0usize;
    let mut blanks = 0usize;
    let request_line = loop {
        match next_line(buf, pos)? {
            None => return Ok(MORE),
            Some((line, next)) => {
                pos = next;
                if line.is_empty() {
                    blanks += 1;
                    if blanks > MAX_LEADING_BLANKS {
                        return Err(HttpError::BadRequest(
                            "blank lines before request line".to_string(),
                        ));
                    }
                } else {
                    break line;
                }
            }
        }
    };

    let mut parts = request_line.split_whitespace();
    let (Some(method), Some(path), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(HttpError::BadRequest(format!(
            "malformed request line '{request_line}'"
        )));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::BadRequest(format!("unsupported version '{version}'")));
    }
    let mut keep_alive = version != "HTTP/1.0";
    let mut headers = Vec::new();
    let mut content_length: Option<usize> = None;
    let mut expect_continue = false;
    let mut chunked = false;
    loop {
        let Some((h, next)) = next_line(buf, pos)? else {
            return Ok(MORE);
        };
        pos = next;
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::BadRequest(format!("more than {MAX_HEADERS} headers")));
        }
        let Some((name, value)) = h.split_once(':') else {
            return Err(HttpError::BadRequest(format!("malformed header line '{h}'")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_string();
        match name.as_str() {
            "content-length" => {
                let parsed: usize = value.parse().map_err(|_| {
                    HttpError::BadRequest(format!("bad content-length '{value}'"))
                })?;
                // conflicting lengths desynchronize keep-alive framing
                // between this parser and any front proxy (request
                // smuggling); RFC 7230 §3.3.3 says reject
                if content_length.is_some_and(|prev| prev != parsed) {
                    return Err(HttpError::BadRequest(
                        "conflicting content-length headers".to_string(),
                    ));
                }
                content_length = Some(parsed);
            }
            "transfer-encoding" => {
                if !value.eq_ignore_ascii_case("identity") {
                    chunked = true;
                }
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.split(',').any(|t| t.trim() == "close") {
                    keep_alive = false;
                } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                    keep_alive = true;
                }
            }
            "expect" => expect_continue = value.eq_ignore_ascii_case("100-continue"),
            _ => {}
        }
        headers.push((name, value));
    }
    if chunked {
        return Err(HttpError::NotImplemented(
            "chunked transfer encoding is not supported; send Content-Length".to_string(),
        ));
    }
    if method == "POST" && content_length.is_none() {
        return Err(HttpError::LengthRequired);
    }
    let len = content_length.unwrap_or(0);
    // rejected on the declared length, before any body byte is buffered
    if len > max_body {
        return Err(HttpError::TooLarge { declared: len, cap: max_body });
    }
    if buf.len() - pos < len {
        return Ok(Parse::Incomplete { headers_done: true, expect_continue });
    }
    let body = buf[pos..pos + len].to_vec();
    Ok(Parse::Complete {
        req: HttpRequest {
            method: method.to_string(),
            path: path.to_string(),
            headers,
            body,
            keep_alive,
        },
        consumed: pos + len,
    })
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        _ => "Unknown",
    }
}

/// Write one complete response (status line, headers, body) and flush.
pub fn write_response(
    w: &mut dyn Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Parse a complete buffer, expecting one whole request.
    fn parse_one(input: &str, max_body: usize) -> Result<HttpRequest, HttpError> {
        match try_parse(input.as_bytes(), max_body)? {
            Parse::Complete { req, consumed } => {
                assert_eq!(consumed, input.len(), "whole buffer consumed");
                Ok(req)
            }
            other => panic!("expected a complete request, got {other:?}"),
        }
    }

    fn incomplete(input: &[u8], max_body: usize) -> (bool, bool) {
        match try_parse(input, max_body) {
            Ok(Parse::Incomplete { headers_done, expect_continue }) => {
                (headers_done, expect_continue)
            }
            other => panic!("expected incomplete, got {other:?}"),
        }
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse_one(
            "POST /analyze HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello",
            1024,
        )
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/analyze");
        assert_eq!(req.body, b"hello");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        // header names are lower-cased
        assert!(req.headers.iter().any(|(n, v)| n == "host" && v == "x"));
    }

    #[test]
    fn parses_get_without_body_and_connection_close() {
        let req = parse_one("GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n", 1024).unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(!req.keep_alive);
        // HTTP/1.0 defaults to close
        let req = parse_one("GET / HTTP/1.0\r\n\r\n", 1024).unwrap();
        assert!(!req.keep_alive);
        let req = parse_one("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", 1024).unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn partial_requests_report_incomplete_with_header_progress() {
        // empty buffer, a stray blank line, a half request line, and a
        // header block without its terminating blank line are all
        // "headers not done yet"
        for input in [
            &b""[..],
            b"\r\n",
            b"GET /heal",
            b"GET /healthz HTTP/1.1\r\n",
            b"GET /healthz HTTP/1.1\r\nhost: x\r\n",
        ] {
            let (headers_done, expect) = incomplete(input, 1024);
            assert!(!headers_done, "{input:?}");
            assert!(!expect, "{input:?}");
        }
        // complete headers waiting on body bytes
        let (headers_done, expect) =
            incomplete(b"POST / HTTP/1.1\r\ncontent-length: 4\r\n\r\nhi", 1024);
        assert!(headers_done);
        assert!(!expect);
    }

    #[test]
    fn expect_continue_is_surfaced_until_the_body_arrives() {
        let head = "POST /analyze HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\n";
        let (headers_done, expect) = incomplete(head.as_bytes(), 1024);
        assert!(headers_done);
        assert!(expect, "interim 100 Continue wanted");
        // once the body is present the request completes normally
        let req = parse_one(&format!("{head}ok"), 1024).unwrap();
        assert_eq!(req.body, b"ok");
    }

    #[test]
    fn pipelined_requests_are_consumed_one_at_a_time() {
        let first = "GET /healthz HTTP/1.1\r\nhost: t\r\n\r\n";
        let second = "POST /analyze HTTP/1.1\r\ncontent-length: 2\r\n\r\nok";
        let both = format!("{first}{second}");
        let Parse::Complete { req, consumed } = try_parse(both.as_bytes(), 1024).unwrap()
        else {
            panic!("first request is complete");
        };
        assert_eq!(req.path, "/healthz");
        assert_eq!(consumed, first.len(), "stops at the request boundary");
        let req = parse_one(&both[consumed..], 1024).unwrap();
        assert_eq!(req.path, "/analyze");
        assert_eq!(req.body, b"ok");
    }

    #[test]
    fn rejects_malformed_requests() {
        let parse = |s: &str| try_parse(s.as_bytes(), 1024);
        assert!(matches!(parse("NOPE\r\n\r\n"), Err(HttpError::BadRequest(_))));
        assert!(matches!(parse("GET / SPDY/3\r\n\r\n"), Err(HttpError::BadRequest(_))));
        assert!(matches!(
            parse("GET / HTTP/1.1\r\nbad header line\r\n\r\n"),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(parse("POST / HTTP/1.1\r\n\r\n"), Err(HttpError::LengthRequired)));
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 2\r\n\r\nok"),
            Err(HttpError::NotImplemented(_))
        ));
        // conflicting content-length headers are a smuggling vector
        assert!(matches!(
            parse("POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 50\r\n\r\nhello"),
            Err(HttpError::BadRequest(_))
        ));
        // repeated IDENTICAL lengths are harmless and accepted
        let req = parse_one(
            "POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok",
            1024,
        )
        .unwrap();
        assert_eq!(req.body, b"ok");
        // a flood of leading blank lines is rejected, a few are tolerated
        let req = parse_one("\r\n\r\nGET / HTTP/1.1\r\n\r\n", 1024).unwrap();
        assert_eq!(req.method, "GET");
        let flood = "\r\n".repeat(MAX_LEADING_BLANKS + 1) + "GET / HTTP/1.1\r\n\r\n";
        assert!(matches!(parse(&flood), Err(HttpError::BadRequest(_))));
    }

    #[test]
    fn oversized_declarations_are_rejected_before_buffering() {
        // the declared length alone triggers 413 — no body byte arrived
        let err = try_parse(b"POST / HTTP/1.1\r\nContent-Length: 9999\r\n\r\n", 16).unwrap_err();
        match err {
            HttpError::TooLarge { declared, cap } => {
                assert_eq!((declared, cap), (9999, 16));
                assert_eq!(err.status().0, 413);
            }
            other => panic!("{other}"),
        }
        // an over-long header line errors instead of buffering
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_HEADER_LINE_BYTES));
        assert!(matches!(try_parse(long.as_bytes(), 1024), Err(HttpError::BadRequest(_))));
        // ...even while the line is still unterminated (straddling a
        // read boundary): the cap fires on the bytes seen so far
        let straddle = format!("GET /{}", "a".repeat(MAX_HEADER_LINE_BYTES + 8));
        assert!(matches!(
            try_parse(straddle.as_bytes(), 1024),
            Err(HttpError::BadRequest(_))
        ));
        // just under the cap with no newline yet: still incomplete
        let under = format!("GET /{}", "a".repeat(100));
        assert!(matches!(
            try_parse(under.as_bytes(), 1024),
            Ok(Parse::Incomplete { .. })
        ));
    }

    #[test]
    fn response_writer_frames_correctly() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.contains("connection: keep-alive\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        let mut out = Vec::new();
        write_response(&mut out, 404, "application/json", b"x", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
    }
}
