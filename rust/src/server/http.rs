//! Minimal HTTP/1.1 framing for `kerncraft serve --listen`.
//!
//! Hand-rolled on [`std::io`] for the same reason [`crate::jsonio`]
//! exists: the offline crate set has no hyper/axum, and the server needs
//! only a strict, bounded subset — request line, headers, and a
//! `Content-Length` body. Chunked transfer encoding is answered with
//! `501`, oversized declarations with `413`, and every limit is enforced
//! *before* the offending bytes are buffered, so one hostile connection
//! cannot exhaust server memory. The endpoint semantics on top of this
//! framing live in [`crate::server`] and docs/SERVE.md.

use std::io::{BufRead, Write};

/// Longest accepted request/header line.
pub const MAX_HEADER_LINE_BYTES: usize = 8 << 10;

/// Most headers accepted on one request.
pub const MAX_HEADERS: usize = 100;

/// Blank lines tolerated before the request line (robust clients may
/// send a stray CRLF after a previous body).
const MAX_LEADING_BLANKS: usize = 8;

/// One parsed request: method, path, lower-cased headers, body.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    /// Header (name, value) pairs; names are lower-cased on read.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Whether the connection may serve another request after this one
    /// (HTTP/1.1 default true, `Connection: close` or HTTP/1.0 false).
    pub keep_alive: bool,
}

/// Why a request could not be read. Every variant except [`Io`] maps to
/// a response status via [`HttpError::status`]; `Io` (including read
/// timeouts on idle keep-alive connections) closes the connection
/// silently.
///
/// [`Io`]: HttpError::Io
#[derive(Debug)]
pub enum HttpError {
    /// Malformed request line, header, or framing.
    BadRequest(String),
    /// `POST` without a `Content-Length`.
    LengthRequired,
    /// Declared body length exceeds the server's cap.
    TooLarge { declared: usize, cap: usize },
    /// A protocol feature this server does not speak (chunked bodies).
    NotImplemented(String),
    /// The socket failed or timed out mid-request.
    Io(std::io::Error),
}

impl HttpError {
    /// Status code and error message for the client, or `None` when the
    /// connection should just be closed (I/O failure — nobody is
    /// listening for a status).
    pub fn status(&self) -> Option<(u16, String)> {
        match self {
            HttpError::BadRequest(msg) => Some((400, msg.clone())),
            HttpError::LengthRequired => {
                Some((411, "POST requires a Content-Length header".to_string()))
            }
            HttpError::TooLarge { declared, cap } => Some((
                413,
                format!("request body of {declared} bytes exceeds the {cap} byte cap"),
            )),
            HttpError::NotImplemented(msg) => Some((501, msg.clone())),
            HttpError::Io(_) => None,
        }
    }
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::BadRequest(msg) => write!(f, "bad request: {msg}"),
            HttpError::LengthRequired => write!(f, "length required"),
            HttpError::TooLarge { declared, cap } => {
                write!(f, "body of {declared} bytes exceeds {cap} byte cap")
            }
            HttpError::NotImplemented(msg) => write!(f, "not implemented: {msg}"),
            HttpError::Io(e) => write!(f, "i/o: {e}"),
        }
    }
}

/// Read one line (LF-terminated, trailing CR stripped), erroring instead
/// of buffering past `cap`. `Ok(None)` is clean EOF before any byte.
fn read_line_limited(
    input: &mut dyn BufRead,
    cap: usize,
) -> Result<Option<String>, HttpError> {
    let mut buf = Vec::new();
    loop {
        let (consume, done) = {
            let chunk = input.fill_buf().map_err(HttpError::Io)?;
            if chunk.is_empty() {
                if buf.is_empty() {
                    return Ok(None);
                }
                break;
            }
            let newline = chunk.iter().position(|&b| b == b'\n');
            let want = newline.unwrap_or(chunk.len());
            if buf.len() + want > cap {
                return Err(HttpError::BadRequest(format!(
                    "header line exceeds {cap} bytes"
                )));
            }
            buf.extend_from_slice(&chunk[..want]);
            (newline.map(|ix| ix + 1).unwrap_or(chunk.len()), newline.is_some())
        };
        input.consume(consume);
        if done {
            break;
        }
    }
    if buf.last() == Some(&b'\r') {
        buf.pop();
    }
    String::from_utf8(buf)
        .map(Some)
        .map_err(|_| HttpError::BadRequest("non-UTF-8 header line".to_string()))
}

/// Read one request from the connection. `Ok(None)` means the client
/// closed cleanly between requests (normal keep-alive teardown). The
/// writer is only touched for `Expect: 100-continue` interim responses
/// (curl sends the header for bodies over 1 KiB and would otherwise
/// stall a full second before transmitting the body).
pub fn read_request(
    reader: &mut dyn BufRead,
    writer: &mut dyn Write,
    max_body: usize,
) -> Result<Option<HttpRequest>, HttpError> {
    let mut blanks = 0usize;
    let line = loop {
        match read_line_limited(reader, MAX_HEADER_LINE_BYTES)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => {
                blanks += 1;
                if blanks > MAX_LEADING_BLANKS {
                    return Err(HttpError::BadRequest(
                        "blank lines before request line".to_string(),
                    ));
                }
            }
            Some(l) => break l,
        }
    };

    let mut parts = line.split_whitespace();
    let (Some(method), Some(path), Some(version), None) =
        (parts.next(), parts.next(), parts.next(), parts.next())
    else {
        return Err(HttpError::BadRequest(format!("malformed request line '{line}'")));
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::BadRequest(format!("unsupported version '{version}'")));
    }
    let mut keep_alive = version != "HTTP/1.0";
    let mut headers = Vec::new();
    let mut content_length: Option<usize> = None;
    let mut expect_continue = false;
    let mut chunked = false;
    loop {
        let Some(h) = read_line_limited(reader, MAX_HEADER_LINE_BYTES)? else {
            return Err(HttpError::BadRequest("connection closed inside headers".to_string()));
        };
        if h.is_empty() {
            break;
        }
        if headers.len() >= MAX_HEADERS {
            return Err(HttpError::BadRequest(format!("more than {MAX_HEADERS} headers")));
        }
        let Some((name, value)) = h.split_once(':') else {
            return Err(HttpError::BadRequest(format!("malformed header line '{h}'")));
        };
        let name = name.trim().to_ascii_lowercase();
        let value = value.trim().to_string();
        match name.as_str() {
            "content-length" => {
                let parsed: usize = value.parse().map_err(|_| {
                    HttpError::BadRequest(format!("bad content-length '{value}'"))
                })?;
                // conflicting lengths desynchronize keep-alive framing
                // between this parser and any front proxy (request
                // smuggling); RFC 7230 §3.3.3 says reject
                if content_length.is_some_and(|prev| prev != parsed) {
                    return Err(HttpError::BadRequest(
                        "conflicting content-length headers".to_string(),
                    ));
                }
                content_length = Some(parsed);
            }
            "transfer-encoding" => {
                if !value.eq_ignore_ascii_case("identity") {
                    chunked = true;
                }
            }
            "connection" => {
                let v = value.to_ascii_lowercase();
                if v.split(',').any(|t| t.trim() == "close") {
                    keep_alive = false;
                } else if v.split(',').any(|t| t.trim() == "keep-alive") {
                    keep_alive = true;
                }
            }
            "expect" => expect_continue = value.eq_ignore_ascii_case("100-continue"),
            _ => {}
        }
        headers.push((name, value));
    }
    if chunked {
        return Err(HttpError::NotImplemented(
            "chunked transfer encoding is not supported; send Content-Length".to_string(),
        ));
    }
    if method == "POST" && content_length.is_none() {
        return Err(HttpError::LengthRequired);
    }
    let len = content_length.unwrap_or(0);
    if len > max_body {
        return Err(HttpError::TooLarge { declared: len, cap: max_body });
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        if expect_continue {
            writer
                .write_all(b"HTTP/1.1 100 Continue\r\n\r\n")
                .and_then(|()| writer.flush())
                .map_err(HttpError::Io)?;
        }
        reader.read_exact(&mut body).map_err(HttpError::Io)?;
    }
    Ok(Some(HttpRequest {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body,
        keep_alive,
    }))
}

/// Reason phrase for the status codes this server emits.
pub fn reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        411 => "Length Required",
        413 => "Payload Too Large",
        422 => "Unprocessable Entity",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        _ => "Unknown",
    }
}

/// Write one complete response (status line, headers, body) and flush.
pub fn write_response(
    w: &mut dyn Write,
    status: u16,
    content_type: &str,
    body: &[u8],
    keep_alive: bool,
) -> std::io::Result<()> {
    write!(
        w,
        "HTTP/1.1 {status} {}\r\ncontent-type: {content_type}\r\ncontent-length: {}\r\nconnection: {}\r\n\r\n",
        reason(status),
        body.len(),
        if keep_alive { "keep-alive" } else { "close" }
    )?;
    w.write_all(body)?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn read(input: &str, max_body: usize) -> Result<Option<HttpRequest>, HttpError> {
        let mut sink = Vec::new();
        read_request(&mut input.as_bytes(), &mut sink, max_body)
    }

    #[test]
    fn parses_post_with_body() {
        let req = read(
            "POST /analyze HTTP/1.1\r\nHost: x\r\nContent-Length: 5\r\n\r\nhello",
            1024,
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/analyze");
        assert_eq!(req.body, b"hello");
        assert!(req.keep_alive, "HTTP/1.1 defaults to keep-alive");
        // header names are lower-cased
        assert!(req.headers.iter().any(|(n, v)| n == "host" && v == "x"));
    }

    #[test]
    fn parses_get_without_body_and_connection_close() {
        let req = read("GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n", 1024)
            .unwrap()
            .unwrap();
        assert_eq!(req.method, "GET");
        assert!(req.body.is_empty());
        assert!(!req.keep_alive);
        // HTTP/1.0 defaults to close
        let req = read("GET / HTTP/1.0\r\n\r\n", 1024).unwrap().unwrap();
        assert!(!req.keep_alive);
        let req = read("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", 1024)
            .unwrap()
            .unwrap();
        assert!(req.keep_alive);
    }

    #[test]
    fn clean_eof_is_none_not_an_error() {
        assert!(read("", 1024).unwrap().is_none());
        // a stray blank line then EOF is also a clean close
        assert!(read("\r\n", 1024).unwrap().is_none());
    }

    #[test]
    fn expect_continue_gets_an_interim_response() {
        let input = "POST /analyze HTTP/1.1\r\nExpect: 100-continue\r\nContent-Length: 2\r\n\r\nok";
        let mut sink = Vec::new();
        let req = read_request(&mut input.as_bytes(), &mut sink, 1024).unwrap().unwrap();
        assert_eq!(req.body, b"ok");
        assert_eq!(sink, b"HTTP/1.1 100 Continue\r\n\r\n");
    }

    #[test]
    fn rejects_malformed_requests() {
        assert!(matches!(read("NOPE\r\n\r\n", 1024), Err(HttpError::BadRequest(_))));
        assert!(matches!(
            read("GET / SPDY/3\r\n\r\n", 1024),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            read("GET / HTTP/1.1\r\nbad header line\r\n\r\n", 1024),
            Err(HttpError::BadRequest(_))
        ));
        assert!(matches!(
            read("POST / HTTP/1.1\r\n\r\n", 1024),
            Err(HttpError::LengthRequired)
        ));
        assert!(matches!(
            read(
                "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 2\r\n\r\nok",
                1024
            ),
            Err(HttpError::NotImplemented(_))
        ));
        // conflicting content-length headers are a smuggling vector
        assert!(matches!(
            read(
                "POST / HTTP/1.1\r\nContent-Length: 5\r\nContent-Length: 50\r\n\r\nhello",
                1024
            ),
            Err(HttpError::BadRequest(_))
        ));
        // repeated IDENTICAL lengths are harmless and accepted
        let req = read(
            "POST / HTTP/1.1\r\nContent-Length: 2\r\nContent-Length: 2\r\n\r\nok",
            1024,
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.body, b"ok");
    }

    #[test]
    fn oversized_declarations_are_rejected_before_buffering() {
        let err = read("POST / HTTP/1.1\r\nContent-Length: 9999\r\n\r\n", 16).unwrap_err();
        match err {
            HttpError::TooLarge { declared, cap } => {
                assert_eq!((declared, cap), (9999, 16));
                assert_eq!(err.status().unwrap().0, 413);
            }
            other => panic!("{other}"),
        }
        // an over-long header line errors instead of buffering
        let long = format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_HEADER_LINE_BYTES));
        assert!(matches!(read(&long, 1024), Err(HttpError::BadRequest(_))));
    }

    #[test]
    fn response_writer_frames_correctly() {
        let mut out = Vec::new();
        write_response(&mut out, 200, "application/json", b"{}", true).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"), "{text}");
        assert!(text.contains("content-length: 2\r\n"), "{text}");
        assert!(text.contains("connection: keep-alive\r\n"), "{text}");
        assert!(text.ends_with("\r\n\r\n{}"), "{text}");
        let mut out = Vec::new();
        write_response(&mut out, 404, "application/json", b"x", false).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("connection: close\r\n"), "{text}");
    }
}
