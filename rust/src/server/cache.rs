//! Persistent, cross-process report cache (`kerncraft serve
//! --cache-dir`).
//!
//! A [`DiskCache`] stores one evaluated [`AnalysisReport`] per file,
//! keyed by [`crate::session::AnalysisRequest::cache_key`] — the
//! canonical hash of the normalized request plus content digests of the
//! kernel source and machine file. Because the key is pure content, two
//! sibling server processes (or one server across restarts) sharing a
//! directory answer repeated requests byte-identically without
//! re-evaluating, and editing a kernel or machine file invalidates its
//! entries with no bookkeeping.
//!
//! Durability rules:
//!
//! * **Atomic writes.** Entries are written to a temp file in the cache
//!   root and `rename(2)`d into place, so a concurrent reader (or a
//!   crash mid-write) sees either the whole entry or none of it — never
//!   a torn file.
//! * **Validated loads.** Every entry read from disk is round-tripped
//!   through [`crate::jsonio`] (`AnalysisReport::from_json` then
//!   `to_json`) and must reproduce the stored bytes exactly; anything
//!   else — truncation, corruption, a foreign file — counts as
//!   `invalid`, is deleted, and falls back to re-evaluation.
//! * **Failures degrade, never fail.** A read-only directory or a full
//!   disk silently turns the cache off for the affected entries; the
//!   request is still answered by the pipeline.
//!
//! The directory layout and operational guidance live in
//! docs/OPERATIONS.md; the counters surface on `GET /metrics`.

use crate::session::{AnalysisReport, ReportCache};
use anyhow::{Context, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

/// Persistent-cache counters, as exposed on `GET /metrics`. Lookups
/// satisfy `hits + misses = gets`; `invalid` entries also count as
/// misses (the request re-evaluates).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from disk with a validated entry.
    pub hits: u64,
    /// Lookups that found no usable entry.
    pub misses: u64,
    /// Entries written (atomically) to disk.
    pub stores: u64,
    /// Entries that failed the round-trip validation and were deleted.
    pub invalid: u64,
}

/// The disk-backed [`ReportCache`] implementation behind `--cache-dir`.
pub struct DiskCache {
    dir: PathBuf,
    /// Temp-file disambiguator within this process (the pid separates
    /// sibling processes sharing one directory).
    seq: AtomicU64,
    hits: AtomicU64,
    misses: AtomicU64,
    stores: AtomicU64,
    invalid: AtomicU64,
}

impl DiskCache {
    /// Open (creating if needed) a cache directory.
    pub fn open(dir: impl Into<PathBuf>) -> Result<DiskCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating cache directory {}", dir.display()))?;
        Ok(DiskCache {
            dir,
            seq: AtomicU64::new(0),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            stores: AtomicU64::new(0),
            invalid: AtomicU64::new(0),
        })
    }

    /// Snapshot of the cache counters (this process only — the
    /// directory itself carries no counters).
    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            stores: self.stores.load(Ordering::Relaxed),
            invalid: self.invalid.load(Ordering::Relaxed),
        }
    }

    /// Entry path: a two-hex-character fan-out directory keeps any
    /// single directory from accumulating every entry.
    fn entry_path(&self, key: &str) -> PathBuf {
        let shard = if key.len() >= 2 && key.is_char_boundary(2) { &key[..2] } else { "xx" };
        self.dir.join(shard).join(format!("{key}.json"))
    }
}

impl ReportCache for DiskCache {
    fn get(&self, key: &str) -> Option<AnalysisReport> {
        let path = self.entry_path(key);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(_) => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                return None;
            }
        };
        // validate by round-tripping through jsonio: the parsed report
        // must re-serialize to the stored bytes exactly, or the entry is
        // corrupt (or written by an incompatible build) and is dropped
        match AnalysisReport::from_json(&text) {
            Ok(report) if report.to_json() == text => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(report)
            }
            _ => {
                self.invalid.fetch_add(1, Ordering::Relaxed);
                self.misses.fetch_add(1, Ordering::Relaxed);
                let _ = std::fs::remove_file(&path);
                None
            }
        }
    }

    fn put(&self, key: &str, report: &AnalysisReport) {
        let path = self.entry_path(key);
        let Some(parent) = path.parent() else { return };
        if std::fs::create_dir_all(parent).is_err() {
            return; // degraded cache, not a failed request
        }
        let tmp = self.dir.join(format!(
            ".tmp-{}-{}",
            std::process::id(),
            self.seq.fetch_add(1, Ordering::Relaxed)
        ));
        if std::fs::write(&tmp, report.to_json()).is_err() {
            let _ = std::fs::remove_file(&tmp);
            return;
        }
        // rename within one filesystem is atomic: readers see the old
        // entry or the new one, never a torn file
        if std::fs::rename(&tmp, &path).is_ok() {
            self.stores.fetch_add(1, Ordering::Relaxed);
        } else {
            let _ = std::fs::remove_file(&tmp);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::session::{AnalysisRequest, KernelSpec, Session};

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("kerncraft_diskcache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn sample_report() -> AnalysisReport {
        let session = Session::new();
        session
            .evaluate(
                &AnalysisRequest::new(KernelSpec::named("triad"), "SNB")
                    .with_constant("N", 65536),
            )
            .unwrap()
    }

    #[test]
    fn put_then_get_round_trips_across_instances() {
        let dir = tmp_dir("roundtrip");
        let report = sample_report();
        let key = "00ff00ff00ff00ff00ff00ff00ff00ff";
        let a = DiskCache::open(&dir).unwrap();
        assert!(a.get(key).is_none(), "cold cache misses");
        a.put(key, &report);
        assert_eq!(a.stats(), CacheStats { hits: 0, misses: 1, stores: 1, invalid: 0 });
        let back = a.get(key).unwrap();
        assert_eq!(back, report);
        // a second instance over the same directory (the warm-restart /
        // sibling-process case) sees the entry too
        let b = DiskCache::open(&dir).unwrap();
        let again = b.get(key).unwrap();
        assert_eq!(again.to_json(), report.to_json(), "byte-identical re-serialization");
        assert_eq!(b.stats().hits, 1);
        // no temp files survive an atomic store
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().starts_with(".tmp-"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_entries_are_invalidated_and_deleted() {
        let dir = tmp_dir("corrupt");
        let cache = DiskCache::open(&dir).unwrap();
        let report = sample_report();
        let key = "abababababababababababababababab";
        cache.put(key, &report);
        let path = cache.entry_path(key);
        // truncation
        std::fs::write(&path, &report.to_json()[..40]).unwrap();
        assert!(cache.get(key).is_none());
        assert!(!path.exists(), "corrupt entry was deleted");
        // valid JSON that is not a report round-trip
        cache.put(key, &report);
        std::fs::write(&path, "{\"kernel\": \"x\"}").unwrap();
        assert!(cache.get(key).is_none());
        let stats = cache.stats();
        assert_eq!(stats.invalid, 2, "{stats:?}");
        assert_eq!(stats.hits, 0);
        let _ = std::fs::remove_dir_all(&dir);
    }
}
