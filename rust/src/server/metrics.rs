//! Request metrics for `kerncraft serve --listen`, exposed as a plain
//! text exposition on `GET /metrics`.
//!
//! All counters are atomic and monotonic since process start; the
//! exposition format is the Prometheus text convention (one
//! `name{labels} value` sample per line) so any scraper — or `grep` —
//! can consume it. The field-by-field reference for operators lives in
//! docs/OPERATIONS.md.

use crate::server::cache::CacheStats;
use crate::session::MemoStats;
use std::sync::atomic::{AtomicU64, Ordering};

/// The served endpoints, as a metrics label. `Other` covers unknown
/// paths (404s) and disallowed methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Endpoint {
    Analyze,
    Advise,
    Batch,
    Stream,
    Healthz,
    Metrics,
    Other,
}

impl Endpoint {
    /// Every endpoint, in exposition order.
    pub const ALL: [Endpoint; 7] = [
        Endpoint::Analyze,
        Endpoint::Advise,
        Endpoint::Batch,
        Endpoint::Stream,
        Endpoint::Healthz,
        Endpoint::Metrics,
        Endpoint::Other,
    ];

    /// Label value in the exposition.
    pub fn name(self) -> &'static str {
        match self {
            Endpoint::Analyze => "analyze",
            Endpoint::Advise => "advise",
            Endpoint::Batch => "batch",
            Endpoint::Stream => "stream",
            Endpoint::Healthz => "healthz",
            Endpoint::Metrics => "metrics",
            Endpoint::Other => "other",
        }
    }

    /// Route a request path to its endpoint label.
    pub fn of_path(path: &str) -> Endpoint {
        match path {
            "/analyze" => Endpoint::Analyze,
            "/advise" => Endpoint::Advise,
            "/batch" => Endpoint::Batch,
            "/stream" => Endpoint::Stream,
            "/healthz" => Endpoint::Healthz,
            "/metrics" => Endpoint::Metrics,
            _ => Endpoint::Other,
        }
    }

    fn ix(self) -> usize {
        match self {
            Endpoint::Analyze => 0,
            Endpoint::Advise => 1,
            Endpoint::Batch => 2,
            Endpoint::Stream => 3,
            Endpoint::Healthz => 4,
            Endpoint::Metrics => 5,
            Endpoint::Other => 6,
        }
    }
}

/// Per-endpoint request/error counters plus connection gauges.
#[derive(Default)]
pub struct Metrics {
    requests: [AtomicU64; 7],
    errors: [AtomicU64; 7],
    /// Connections accepted over the process lifetime.
    pub connections: AtomicU64,
    /// Connections currently open in the reactor (gauge).
    pub open_connections: AtomicU64,
    /// Requests dispatched to an evaluation worker whose response has
    /// not yet been produced (gauge; persistently ≥ the worker count
    /// means the pool is saturated).
    pub queue_depth: AtomicU64,
    /// Idle keep-alive connections reaped by the idle deadline.
    pub idle_timeouts: AtomicU64,
}

impl Metrics {
    /// Count one request against an endpoint.
    pub fn request(&self, ep: Endpoint) {
        self.requests[ep.ix()].fetch_add(1, Ordering::Relaxed);
    }

    /// Count `n` errors against an endpoint (batch responses carry one
    /// error per failed element).
    pub fn errors_add(&self, ep: Endpoint, n: u64) {
        if n > 0 {
            self.errors[ep.ix()].fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Requests counted so far for one endpoint.
    pub fn requests_for(&self, ep: Endpoint) -> u64 {
        self.requests[ep.ix()].load(Ordering::Relaxed)
    }

    /// Errors counted so far for one endpoint.
    pub fn errors_for(&self, ep: Endpoint) -> u64 {
        self.errors[ep.ix()].load(Ordering::Relaxed)
    }

    /// Render the text exposition: per-endpoint request/error totals,
    /// connection counters, the session's per-stage memo counters, the
    /// per-diagnostic-code rejected-input tallies, the per-ISA-family
    /// request tallies, the per-model evaluation-latency family, the
    /// per-engine virtual-testbed touch totals, and — when a persistent
    /// cache is attached — its hit/miss/store/invalid counters.
    /// `rejected` is `(code, count)` pairs, already sorted
    /// ([`crate::session::Session::rejected_by_code`]); `isa` is
    /// `(family, count)` pairs, already sorted
    /// ([`crate::session::Session::requests_by_isa`]); `eval` is
    /// `(model, seconds, count)` triples
    /// ([`crate::session::Session::eval_seconds_by_model`]); `sim` is
    /// `(engine, touches)` pairs
    /// ([`crate::session::Session::sim_touches_by_engine`]). Zero-count
    /// eval models and zero-touch engines are omitted, like the other
    /// sparse families.
    pub fn render(
        &self,
        memo: &MemoStats,
        rejected: &[(String, u64)],
        isa: &[(String, u64)],
        eval: &[(&'static str, f64, u64)],
        sim: &[(&'static str, u64)],
        cache: Option<CacheStats>,
    ) -> String {
        let mut s = String::new();
        s.push_str("# kerncraft serve metrics (counters monotonic since process start)\n");
        for ep in Endpoint::ALL {
            s.push_str(&format!(
                "kerncraft_requests_total{{endpoint=\"{}\"}} {}\n",
                ep.name(),
                self.requests_for(ep)
            ));
        }
        for (family, count) in isa {
            s.push_str(&format!(
                "kerncraft_requests_total{{isa=\"{family}\"}} {count}\n"
            ));
        }
        for ep in Endpoint::ALL {
            s.push_str(&format!(
                "kerncraft_errors_total{{endpoint=\"{}\"}} {}\n",
                ep.name(),
                self.errors_for(ep)
            ));
        }
        s.push_str(&format!(
            "kerncraft_connections_total {}\n",
            self.connections.load(Ordering::Relaxed)
        ));
        s.push_str(&format!(
            "kerncraft_open_connections {}\n",
            self.open_connections.load(Ordering::Relaxed)
        ));
        s.push_str(&format!(
            "kerncraft_queue_depth {}\n",
            self.queue_depth.load(Ordering::Relaxed)
        ));
        s.push_str(&format!(
            "kerncraft_idle_timeouts_total {}\n",
            self.idle_timeouts.load(Ordering::Relaxed)
        ));
        for (stage, hits, misses) in [
            ("machine", memo.machine_hits, memo.machine_misses),
            ("program", memo.program_hits, memo.program_misses),
            ("analysis", memo.analysis_hits, memo.analysis_misses),
            ("incore", memo.incore_hits, memo.incore_misses),
        ] {
            s.push_str(&format!(
                "kerncraft_memo_hits_total{{stage=\"{stage}\"}} {hits}\n"
            ));
            s.push_str(&format!(
                "kerncraft_memo_misses_total{{stage=\"{stage}\"}} {misses}\n"
            ));
        }
        for (code, count) in rejected {
            s.push_str(&format!(
                "kerncraft_rejected_inputs_total{{code=\"{code}\"}} {count}\n"
            ));
        }
        for (model, seconds, count) in eval {
            if *count == 0 {
                continue;
            }
            s.push_str(&format!(
                "kerncraft_eval_seconds_total{{model=\"{model}\"}} {seconds}\n"
            ));
            s.push_str(&format!(
                "kerncraft_eval_seconds_count{{model=\"{model}\"}} {count}\n"
            ));
        }
        for (engine, touches) in sim {
            if *touches == 0 {
                continue;
            }
            s.push_str(&format!(
                "kerncraft_sim_touches_total{{engine=\"{engine}\"}} {touches}\n"
            ));
        }
        if let Some(c) = cache {
            s.push_str(&format!("kerncraft_report_cache_hits_total {}\n", c.hits));
            s.push_str(&format!("kerncraft_report_cache_misses_total {}\n", c.misses));
            s.push_str(&format!("kerncraft_report_cache_stores_total {}\n", c.stores));
            s.push_str(&format!("kerncraft_report_cache_invalid_total {}\n", c.invalid));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exposition_carries_every_counter_family() {
        let m = Metrics::default();
        m.request(Endpoint::Analyze);
        m.request(Endpoint::Analyze);
        m.request(Endpoint::Batch);
        m.errors_add(Endpoint::Batch, 3);
        m.connections.fetch_add(1, Ordering::Relaxed);
        m.open_connections.fetch_add(1, Ordering::Relaxed);
        m.idle_timeouts.fetch_add(2, Ordering::Relaxed);
        let memo = MemoStats { program_hits: 7, ..MemoStats::default() };
        let cache = CacheStats { hits: 1, misses: 2, stores: 2, invalid: 0 };
        let rejected = vec![("E100".to_string(), 4), ("E201".to_string(), 1)];
        let isa = vec![("aarch64".to_string(), 1), ("x86".to_string(), 2)];
        let eval = vec![("ECM", 0.125f64, 3u64), ("Validate", 0.0, 0)];
        let sim = vec![("fast", 288_000_000u64), ("reference", 0)];
        let text = m.render(&memo, &rejected, &isa, &eval, &sim, Some(cache));
        assert!(text.contains("kerncraft_requests_total{endpoint=\"analyze\"} 2"), "{text}");
        assert!(text.contains("kerncraft_requests_total{isa=\"x86\"} 2"), "{text}");
        assert!(text.contains("kerncraft_requests_total{isa=\"aarch64\"} 1"), "{text}");
        assert!(text.contains("kerncraft_requests_total{endpoint=\"batch\"} 1"), "{text}");
        assert!(text.contains("kerncraft_errors_total{endpoint=\"batch\"} 3"), "{text}");
        assert!(text.contains("kerncraft_connections_total 1"), "{text}");
        assert!(text.contains("kerncraft_open_connections 1"), "{text}");
        assert!(text.contains("kerncraft_queue_depth 0"), "{text}");
        assert!(text.contains("kerncraft_idle_timeouts_total 2"), "{text}");
        assert!(text.contains("kerncraft_memo_hits_total{stage=\"program\"} 7"), "{text}");
        assert!(text.contains("kerncraft_rejected_inputs_total{code=\"E100\"} 4"), "{text}");
        assert!(text.contains("kerncraft_rejected_inputs_total{code=\"E201\"} 1"), "{text}");
        assert!(text.contains("kerncraft_report_cache_hits_total 1"), "{text}");
        assert!(text.contains("kerncraft_report_cache_invalid_total 0"), "{text}");
        assert!(text.contains("kerncraft_eval_seconds_total{model=\"ECM\"} 0.125"), "{text}");
        assert!(text.contains("kerncraft_eval_seconds_count{model=\"ECM\"} 3"), "{text}");
        assert!(
            text.contains("kerncraft_sim_touches_total{engine=\"fast\"} 288000000"),
            "{text}"
        );
        // zero-count models / zero-touch engines are omitted
        assert!(!text.contains("model=\"Validate\""), "{text}");
        assert!(!text.contains("engine=\"reference\""), "{text}");
        // without a cache, the persistent-cache family is absent; with no
        // rejections or evaluated requests, those families are too
        let text = m.render(&memo, &[], &[], &[], &[], None);
        assert!(!text.contains("report_cache"), "{text}");
        assert!(!text.contains("rejected_inputs"), "{text}");
        assert!(!text.contains("isa="), "{text}");
        assert!(!text.contains("eval_seconds"), "{text}");
        assert!(!text.contains("sim_touches"), "{text}");
    }

    #[test]
    fn paths_route_to_endpoints() {
        assert_eq!(Endpoint::of_path("/analyze"), Endpoint::Analyze);
        assert_eq!(Endpoint::of_path("/advise"), Endpoint::Advise);
        assert_eq!(Endpoint::of_path("/metrics"), Endpoint::Metrics);
        assert_eq!(Endpoint::of_path("/nope"), Endpoint::Other);
    }
}
