//! Structured frontend diagnostics (DESIGN.md §3).
//!
//! Every stage of the kernel frontend — lexer, parser, lowering,
//! analysis — reports failures as a [`Diagnostic`]: a stable error
//! code, a severity, a human message, and (when the failing construct
//! can be located) a byte-span into the original source plus the
//! source line it sits on. The one struct feeds all three front doors:
//! the CLI renders the caret snippet ([`Diagnostic::render`]), the
//! serve tiers embed the machine-readable form ([`Diagnostic::to_json`])
//! in their error objects, and `/metrics` counts rejections per code.
//!
//! ## Error codes
//!
//! Codes are stable API: tooling may match on them, so they are never
//! renumbered. Lexical errors are `E0xx`, syntactic errors `E1xx`,
//! lowering/semantic restrictions `E2xx`.
//!
//! | code | meaning |
//! |------|---------|
//! | E001 | unexpected character |
//! | E002 | malformed numeric literal |
//! | E003 | unterminated block comment |
//! | E100 | unexpected token |
//! | E101 | unexpected end of input |
//! | E102 | malformed loop header |
//! | E103 | malformed declaration |
//! | E110 | trailing tokens after the loop nest |
//! | E120 | imperfect loop nest |
//! | E121 | unsupported construct |
//! | E200 | language restriction violated |
//! | E201 | unbound constant |
//! | E202 | semantic error |

use crate::jsonio::json_str;
use std::fmt;

/// A half-open byte range `[start, end)` into the kernel source, plus
/// the 1-based line/column of `start` (columns count characters, so
/// caret rendering lines up with what an editor shows).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    /// Byte offset of the first byte of the spanned text.
    pub start: usize,
    /// Byte offset one past the last byte of the spanned text.
    pub end: usize,
    /// 1-based source line of `start`.
    pub line: usize,
    /// 1-based character column of `start` within its line.
    pub col: usize,
}

impl Span {
    /// A zero-width span at a point (used for end-of-input positions).
    pub fn point(offset: usize, line: usize, col: usize) -> Span {
        Span { start: offset, end: offset, line, col }
    }
}

/// Diagnostic severity. The frontend currently only emits errors, but
/// the wire format carries the field so warnings can be added without
/// breaking consumers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
        }
    }
}

/// A structured frontend diagnostic. Construct with
/// [`Diagnostic::error`] and the `with_*` builders; `snippet` is
/// captured from the source at construction time (via
/// [`Diagnostic::with_snippet`]) so rendering never needs the source
/// again — the source string does not survive past the frontend in
/// the session pipeline.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Stable error code (`E001`…`E202`, see module docs).
    pub code: &'static str,
    pub severity: Severity,
    /// One-line human message, no trailing punctuation.
    pub message: String,
    /// Location of the offending construct, when known.
    pub span: Option<Span>,
    /// The full source line containing `span.start`, tabs expanded to
    /// single spaces so the caret column stays aligned.
    pub snippet: Option<String>,
    /// Optional remedy ("pass -D N <value>", …).
    pub hint: Option<String>,
}

impl Diagnostic {
    /// A new error diagnostic with no location attached yet.
    pub fn error(code: &'static str, message: impl Into<String>) -> Diagnostic {
        Diagnostic {
            code,
            severity: Severity::Error,
            message: message.into(),
            span: None,
            snippet: None,
            hint: None,
        }
    }

    pub fn with_span(mut self, span: Span) -> Diagnostic {
        self.span = Some(span);
        self
    }

    pub fn with_hint(mut self, hint: impl Into<String>) -> Diagnostic {
        self.hint = Some(hint.into());
        self
    }

    /// Capture the source line under `self.span` so the diagnostic can
    /// be caret-rendered later without the source text.
    pub fn with_snippet(mut self, source: &str) -> Diagnostic {
        if let Some(span) = self.span {
            let line = source.lines().nth(span.line.saturating_sub(1)).unwrap_or("");
            self.snippet = Some(line.replace('\t', " "));
        }
        self
    }

    /// Multi-line human rendering with a caret marking the span:
    ///
    /// ```text
    /// error[E100]: expected ';', found '}'
    ///   --> line 4, col 12
    ///    |
    ///  4 | y[i] = a * x[i] + y[i]
    ///    |            ^
    ///    = hint: terminate the statement with ';'
    /// ```
    pub fn render(&self) -> String {
        let mut s = format!("{}[{}]: {}", self.severity.as_str(), self.code, self.message);
        if let Some(span) = self.span {
            s.push_str(&format!("\n  --> line {}, col {}", span.line, span.col));
            if let Some(snippet) = &self.snippet {
                let gutter = span.line.to_string().len().max(2);
                // clamp the caret run to what is left of the line so a
                // span ending past it (e.g. end-of-input) stays inside
                let remaining = snippet.chars().count().saturating_sub(span.col - 1).max(1);
                let carets = "^".repeat((span.end - span.start).clamp(1, remaining));
                s.push_str(&format!("\n {:gutter$} |", ""));
                s.push_str(&format!("\n {:>gutter$} | {}", span.line, snippet));
                s.push_str(&format!("\n {:gutter$} | {}{}", "", " ".repeat(span.col.saturating_sub(1)), carets));
            }
        }
        if let Some(hint) = &self.hint {
            s.push_str(&format!("\n   = hint: {hint}"));
        }
        s
    }

    /// Machine-readable JSON object, embedded by the serve tiers in
    /// their error bodies (docs/SERVE.md):
    /// `{"code", "severity", "message", "span"?: {"line","col","start","end"},
    ///   "snippet"?, "hint"?}`.
    pub fn to_json(&self) -> String {
        let mut s = format!(
            "{{\"code\": {}, \"severity\": {}, \"message\": {}",
            json_str(self.code),
            json_str(self.severity.as_str()),
            json_str(&self.message)
        );
        if let Some(span) = self.span {
            s.push_str(&format!(
                ", \"span\": {{\"line\": {}, \"col\": {}, \"start\": {}, \"end\": {}}}",
                span.line, span.col, span.start, span.end
            ));
        }
        if let Some(snippet) = &self.snippet {
            s.push_str(&format!(", \"snippet\": {}", json_str(snippet)));
        }
        if let Some(hint) = &self.hint {
            s.push_str(&format!(", \"hint\": {}", json_str(hint)));
        }
        s.push('}');
        s
    }
}

/// One-line form: `error[E100] at 4:12: expected ';', found '}'`.
/// This is what `{e:#}` prints through the anyhow chain, so it stays
/// single-line for the JSON-lines serve tier.
impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[{}]", self.severity.as_str(), self.code)?;
        if let Some(span) = self.span {
            write!(f, " at {}:{}", span.line, span.col)?;
        }
        write!(f, ": {}", self.message)?;
        if let Some(hint) = &self.hint {
            write!(f, " (hint: {hint})")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_places_caret_under_span() {
        let src = "double a[8];\nfor (int i = 0; i < 8 ++i)\n";
        let d = Diagnostic::error("E100", "expected ';', found '++'")
            .with_span(Span { start: 35, end: 37, line: 2, col: 23 })
            .with_snippet(src)
            .with_hint("separate the loop header clauses with ';'");
        let r = d.render();
        assert!(r.starts_with("error[E100]: expected ';', found '++'"), "{r}");
        assert!(r.contains("--> line 2, col 23"), "{r}");
        assert!(r.contains(" 2 | for (int i = 0; i < 8 ++i)"), "{r}");
        let caret_line = r.lines().nth(4).unwrap();
        assert_eq!(caret_line.find('^'), caret_line.find("^^"), "span width renders two carets: {r}");
        // caret column lines up with the '+' in the snippet line
        let snippet_line = r.lines().nth(3).unwrap();
        assert_eq!(snippet_line.find("++"), caret_line.find("^^"), "{r}");
        assert!(r.ends_with("= hint: separate the loop header clauses with ';'"), "{r}");
    }

    #[test]
    fn display_is_single_line() {
        let d = Diagnostic::error("E201", "unbound constant 'M'")
            .with_hint("pass -D M <value>");
        let line = d.to_string();
        assert_eq!(line, "error[E201]: unbound constant 'M' (hint: pass -D M <value>)");
        assert!(!line.contains('\n'));
    }

    #[test]
    fn json_carries_span_and_hint() {
        let d = Diagnostic::error("E001", "unexpected character '@'")
            .with_span(Span { start: 3, end: 4, line: 1, col: 4 });
        let v = crate::jsonio::parse(&d.to_json()).unwrap();
        assert_eq!(v.get("code").and_then(|c| c.as_str()), Some("E001"));
        assert_eq!(v.get("severity").and_then(|c| c.as_str()), Some("error"));
        let span = v.get("span").unwrap();
        assert_eq!(span.get("line").and_then(|x| x.as_i64()), Some(1));
        assert_eq!(span.get("col").and_then(|x| x.as_i64()), Some(4));
        assert_eq!(span.get("start").and_then(|x| x.as_i64()), Some(3));
        assert!(v.get("hint").is_none());
    }
}
