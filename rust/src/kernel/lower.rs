//! Lowering: surface [`syntax`] tree → the restricted analysis IR in
//! [`ast`] (DESIGN.md §3, stage 3).
//!
//! This pass normalizes everything the analysis does not want to know
//! about:
//!
//! * `i <= e` bounds become the exclusive `e + 1` (and flipped bounds
//!   were already re-oriented by the parser);
//! * casts are erased — the analysis models data movement by declared
//!   type (paper §4.3);
//! * compound blocks are flattened into the enclosing body;
//! * `if`/`else` conditionals are lowered to straight-line code under
//!   an *all-paths* execution model: the condition's data-dependent
//!   operands become guard assignments (`__cond0 = b[i];` — preserving
//!   their reads and arithmetic for traffic and flop counting), then
//!   the statements of both branches follow unconditionally. This
//!   matches how the paper treats a kernel body as one steady-state
//!   iteration mix;
//! * imperfect nests (a loop mixed with statements, or several loops
//!   in one body) are rejected with a spanned E120 — the models only
//!   exist for perfect nests.
//!
//! [`syntax`]: super::syntax
//! [`ast`]: super::ast

use super::ast::{AssignOp, BinOp, Decl, Expr, Loop, LoopBody, Program, Stmt};
use super::diag::Diagnostic;
use super::syntax::*;
use super::KernelError;

/// Lower a parsed surface unit into the analysis IR. `src` is the
/// original source, used to attach snippets to diagnostics.
pub fn lower(unit: &Unit, src: &str) -> Result<Program, KernelError> {
    let mut lw = Lowerer { src, guards: 0 };
    let decls = unit
        .decls
        .iter()
        .map(|d| {
            Ok(Decl {
                name: d.name.clone(),
                ty: d.ty,
                dims: d.dims.iter().map(|e| lw.value_expr(e)).collect::<Result<_, _>>()?,
                init: d.init,
            })
        })
        .collect::<Result<Vec<_>, KernelError>>()?;
    let nest = lw.lower_loop(&unit.nest)?;
    Ok(Program { decls, nest })
}

struct Lowerer<'a> {
    src: &'a str,
    /// Counter for synthesized `__cond<k>` guard destinations.
    guards: usize,
}

impl<'a> Lowerer<'a> {
    fn err(&self, code: &'static str, msg: impl Into<String>, span: super::diag::Span) -> KernelError {
        Diagnostic::error(code, msg).with_span(span).with_snippet(self.src).into()
    }

    fn lower_loop(&mut self, sl: &SLoop) -> Result<Loop, KernelError> {
        let start = self.value_expr(&sl.start)?;
        let mut end = self.value_expr(&sl.bound)?;
        if sl.cmp == CmpDir::Le {
            // normalize `i <= e` to the exclusive bound `e + 1`
            end = Expr::Binary { op: BinOp::Add, lhs: Box::new(end), rhs: Box::new(Expr::Int(1)) };
        }
        let step = self.value_expr(&sl.step)?;
        let mut loops: Vec<&SLoop> = Vec::new();
        let mut stmts: Vec<Stmt> = Vec::new();
        self.collect_body(&sl.body, &mut loops, &mut stmts)?;
        let body = match (loops.as_slice(), stmts.is_empty()) {
            ([inner], true) => LoopBody::Nest(Box::new(self.lower_loop(inner)?)),
            ([], false) => LoopBody::Stmts(stmts),
            ([], true) => return Err(self.err("E120", "loop body is empty", sl.span)),
            (more, _) => {
                let offender = if more.len() > 1 { more[1] } else { more[0] };
                return Err(self
                    .err(
                        "E120",
                        "imperfect loop nest: a loop body must be either one nested loop or a flat list of statements",
                        offender.span,
                    )
                    .diag
                    .with_hint("hoist the extra statements out of the nest or split the kernel")
                    .into());
            }
        };
        Ok(Loop { index: sl.index.clone(), start, end, step, body })
    }

    /// Flatten blocks and lower conditionals/assignments, gathering
    /// nested loops separately so nest shape can be validated.
    fn collect_body<'u>(
        &mut self,
        items: &'u [SItem],
        loops: &mut Vec<&'u SLoop>,
        stmts: &mut Vec<Stmt>,
    ) -> Result<(), KernelError> {
        for item in items {
            match item {
                SItem::Loop(l) => loops.push(l),
                SItem::Block(inner) => self.collect_body(inner, loops, stmts)?,
                SItem::Assign(a) => stmts.push(self.lower_assign(a)?),
                SItem::If(i) => self.lower_if(i, stmts)?,
            }
        }
        Ok(())
    }

    /// Lower `if (cond) then else els` into guard assignments followed
    /// by the statements of both branches (all-paths model, see module
    /// docs). Loops inside conditionals have no steady-state iteration
    /// mix and are rejected.
    fn lower_if(&mut self, sif: &SIf, stmts: &mut Vec<Stmt>) -> Result<(), KernelError> {
        self.lower_condition(&sif.cond, stmts)?;
        for items in [&sif.then_items, &sif.else_items] {
            let mut inner_loops = Vec::new();
            self.collect_body(items, &mut inner_loops, stmts)?;
            if let Some(l) = inner_loops.first() {
                return Err(self
                    .err("E120", "a loop inside a conditional is not supported", l.span)
                    .diag
                    .with_hint("kerncraft models one steady-state iteration mix per nest")
                    .into());
            }
        }
        Ok(())
    }

    /// Emit one `__cond<k> = <operand>;` guard per data-dependent
    /// operand of the condition, preserving its reads and arithmetic.
    fn lower_condition(&mut self, cond: &SExpr, stmts: &mut Vec<Stmt>) -> Result<(), KernelError> {
        match &cond.kind {
            SExprKind::Cmp { lhs, rhs, .. } => {
                self.guard_operand(lhs, stmts)?;
                self.guard_operand(rhs, stmts)?;
            }
            SExprKind::Logical { lhs, rhs, .. } => {
                self.lower_condition(lhs, stmts)?;
                self.lower_condition(rhs, stmts)?;
            }
            SExprKind::Not(inner) => self.lower_condition(inner, stmts)?,
            // a bare arithmetic truth value, e.g. `if (mask[i])`
            _ => self.guard_operand(cond, stmts)?,
        }
        Ok(())
    }

    fn guard_operand(&mut self, e: &SExpr, stmts: &mut Vec<Stmt>) -> Result<(), KernelError> {
        if !reads_data(e) {
            return Ok(()); // pure literal side: no traffic, no guard
        }
        let rhs = self.value_expr(e)?;
        let name = format!("__cond{}", self.guards);
        self.guards += 1;
        stmts.push(Stmt { lhs: Expr::Var(name), op: AssignOp::Set, rhs });
        Ok(())
    }

    fn lower_assign(&mut self, a: &SAssign) -> Result<Stmt, KernelError> {
        Ok(Stmt {
            lhs: self.value_expr(&a.lhs)?,
            op: a.op,
            rhs: self.value_expr(&a.rhs)?,
        })
    }

    /// Lower a value-position expression. Comparisons and logical
    /// operators only make sense in `if` conditions; using their
    /// result as a number is rejected here with the exact span.
    fn value_expr(&mut self, e: &SExpr) -> Result<Expr, KernelError> {
        Ok(match &e.kind {
            SExprKind::Int(v) => Expr::Int(*v),
            SExprKind::Float(v) => Expr::Float(*v),
            SExprKind::Var(n) => Expr::Var(n.clone()),
            SExprKind::Index { array, indices } => Expr::Index {
                array: array.clone(),
                indices: indices.iter().map(|i| self.value_expr(i)).collect::<Result<_, _>>()?,
            },
            SExprKind::Binary { op, lhs, rhs } => Expr::Binary {
                op: *op,
                lhs: Box::new(self.value_expr(lhs)?),
                rhs: Box::new(self.value_expr(rhs)?),
            },
            SExprKind::Neg(inner) => Expr::Neg(Box::new(self.value_expr(inner)?)),
            SExprKind::Cast { expr, .. } => self.value_expr(expr)?, // casts are erased
            SExprKind::Cmp { .. } | SExprKind::Logical { .. } | SExprKind::Not(_) => {
                return Err(self
                    .err("E121", "a comparison result cannot be used as a value", e.span)
                    .diag
                    .with_hint("comparisons are only supported inside `if (...)` conditions")
                    .into())
            }
        })
    }
}

/// True when the expression reads any variable or array element.
fn reads_data(e: &SExpr) -> bool {
    match &e.kind {
        SExprKind::Int(_) | SExprKind::Float(_) => false,
        SExprKind::Var(_) | SExprKind::Index { .. } => true,
        SExprKind::Binary { lhs, rhs, .. }
        | SExprKind::Cmp { lhs, rhs, .. }
        | SExprKind::Logical { lhs, rhs, .. } => reads_data(lhs) || reads_data(rhs),
        SExprKind::Neg(inner) | SExprKind::Not(inner) | SExprKind::Cast { expr: inner, .. } => {
            reads_data(inner)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;

    #[test]
    fn conditional_lowered_to_guard_plus_both_branches() {
        let src = r#"
            double a[N], b[N], t;
            for (int i = 0; i < N; ++i)
                if (b[i] > 0.0) a[i] = b[i]; else a[i] = t;
        "#;
        let p = parse(src).unwrap();
        let stmts = p.inner_stmts();
        assert_eq!(stmts.len(), 3);
        assert_eq!(stmts[0].lhs, Expr::Var("__cond0".into()));
        assert_eq!(stmts[0].op, AssignOp::Set);
        // the guard keeps the b[i] read; literal 0.0 emits nothing
        assert!(matches!(&stmts[0].rhs, Expr::Index { array, .. } if array == "b"));
    }

    #[test]
    fn logical_condition_guards_each_data_operand() {
        let src = r#"
            double a[N], b[N], c[N];
            for (int i = 0; i < N; ++i)
                if (b[i] > 0.0 && c[i] < 1.0) a[i] = 2.0;
        "#;
        let p = parse(src).unwrap();
        let stmts = p.inner_stmts();
        assert_eq!(stmts.len(), 3);
        assert_eq!(stmts[1].lhs, Expr::Var("__cond1".into()));
    }

    #[test]
    fn blocks_flatten_into_the_body() {
        let src = r#"
            double a[N], b[N];
            for (int i = 0; i < N; ++i) {
                { a[i] = 1.0; }
                { { b[i] = 2.0; } }
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.inner_stmts().len(), 2);
    }

    #[test]
    fn rejects_imperfect_nest_with_span() {
        let src = r#"
            double a[N], b[N][N];
            for (int j = 0; j < N; ++j) {
                a[j] = 0.0;
                for (int i = 0; i < N; ++i)
                    b[j][i] = a[j];
            }
        "#;
        let err = parse(src).unwrap_err();
        assert_eq!(err.code(), "E120");
        assert_eq!(err.diag.span.unwrap().line, 5);
    }

    #[test]
    fn rejects_loop_inside_conditional() {
        let src = r#"
            double a[N][N], s;
            for (int j = 0; j < N; ++j)
                if (s > 0.0)
                    for (int i = 0; i < N; ++i)
                        a[j][i] = s;
        "#;
        let err = parse(src).unwrap_err();
        assert_eq!(err.code(), "E120");
    }

    #[test]
    fn rejects_comparison_as_value_with_span() {
        let src = "double a[N], b[N];\nfor (int i = 0; i < N; ++i) a[i] = b[i] > 0.0;";
        let err = parse(src).unwrap_err();
        assert_eq!(err.code(), "E121");
        assert_eq!(err.diag.span.unwrap().line, 2);
    }

    #[test]
    fn casts_are_erased() {
        let src = "double a[N], b[N];\nfor (int i = 0; i < N; ++i) a[i] = (float)b[i];";
        let p = parse(src).unwrap();
        assert!(matches!(&p.inner_stmts()[0].rhs, Expr::Index { array, .. } if array == "b"));
    }
}
