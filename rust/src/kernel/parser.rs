//! Recursive-descent parser for the restricted kernel language.

use super::ast::*;
use super::lexer::{lex, Kw, Token, TokenKind};
use super::KernelError;

/// Parse kernel source into a [`Program`].
pub fn parse(src: &str) -> Result<Program, KernelError> {
    let tokens = lex(src)?;
    Parser { toks: tokens, pos: 0 }.program()
}

struct Parser {
    toks: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> Option<&TokenKind> {
        self.toks.get(self.pos).map(|t| &t.kind)
    }

    fn peek2(&self) -> Option<&TokenKind> {
        self.toks.get(self.pos + 1).map(|t| &t.kind)
    }

    fn next(&mut self) -> Option<TokenKind> {
        let t = self.toks.get(self.pos).map(|t| t.kind.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    fn err(&self, msg: impl Into<String>) -> KernelError {
        let (line, col) = self
            .toks
            .get(self.pos.min(self.toks.len().saturating_sub(1)))
            .map(|t| (t.line, t.col))
            .unwrap_or((0, 0));
        KernelError::Parse { line, col, msg: msg.into() }
    }

    fn expect(&mut self, kind: &TokenKind) -> Result<(), KernelError> {
        match self.peek() {
            Some(k) if k == kind => {
                self.pos += 1;
                Ok(())
            }
            other => Err(self.err(format!("expected {kind:?}, found {other:?}"))),
        }
    }

    fn eat(&mut self, kind: &TokenKind) -> bool {
        if self.peek() == Some(kind) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn program(&mut self) -> Result<Program, KernelError> {
        let mut decls = Vec::new();
        // Declarations until the first `for`.
        loop {
            match self.peek() {
                Some(TokenKind::Kw(Kw::For)) => break,
                Some(TokenKind::Kw(Kw::Const)) => {
                    self.pos += 1; // `const` qualifier on a declaration
                }
                Some(TokenKind::Kw(Kw::Double)) | Some(TokenKind::Kw(Kw::Float)) => {
                    decls.extend(self.declaration()?);
                }
                Some(TokenKind::Kw(Kw::Int)) | Some(TokenKind::Kw(Kw::Long))
                | Some(TokenKind::Kw(Kw::Unsigned)) => {
                    // Integer declarations (e.g. problem-size constants
                    // declared in-source) are skipped up to `;`: sizes
                    // must come from `-D` bindings, per the paper's CLI.
                    while !matches!(self.peek(), Some(TokenKind::Semicolon) | None) {
                        self.pos += 1;
                    }
                    self.expect(&TokenKind::Semicolon)?;
                }
                None => return Err(self.err("expected a for loop, found end of input")),
                other => {
                    return Err(self.err(format!("expected declaration or for loop, found {other:?}")))
                }
            }
        }
        let nest = self.for_loop()?;
        // Trailing tokens (besides stray semicolons/braces) are an error:
        // the paper's kernels are a single loop nest.
        while self.eat(&TokenKind::Semicolon) {}
        if self.peek().is_some() {
            return Err(self.err("unexpected trailing tokens after the loop nest (only a single loop nest is supported)"));
        }
        Ok(Program { decls, nest })
    }

    /// `double a[M][N], s = 0., c1;`
    fn declaration(&mut self) -> Result<Vec<Decl>, KernelError> {
        let ty = match self.next() {
            Some(TokenKind::Kw(Kw::Double)) => Type::Double,
            Some(TokenKind::Kw(Kw::Float)) => Type::Float,
            other => return Err(self.err(format!("expected type, found {other:?}"))),
        };
        let mut out = Vec::new();
        loop {
            // optional `restrict` / `*` (pointer declarations degrade to 1D
            // arrays of unknown size, which the analysis rejects later if
            // actually indexed multi-dimensionally)
            while self.eat(&TokenKind::Star) || self.eat(&TokenKind::Kw(Kw::Restrict)) {}
            let name = match self.next() {
                Some(TokenKind::Ident(n)) => n,
                other => return Err(self.err(format!("expected identifier, found {other:?}"))),
            };
            let mut dims = Vec::new();
            while self.eat(&TokenKind::LBracket) {
                // `double a[]` (empty dimension) is allowed for 1D streaming
                // arrays; it is treated as "large" by the analysis.
                if self.eat(&TokenKind::RBracket) {
                    dims.push(Expr::Var("__unbounded__".into()));
                    continue;
                }
                let e = self.expr()?;
                self.expect(&TokenKind::RBracket)?;
                dims.push(e);
            }
            let mut init = None;
            if self.eat(&TokenKind::Assign) {
                match self.expr()? {
                    Expr::Float(v) => init = Some(v),
                    Expr::Int(v) => init = Some(v as f64),
                    Expr::Neg(inner) => match *inner {
                        Expr::Float(v) => init = Some(-v),
                        Expr::Int(v) => init = Some(-(v as f64)),
                        _ => return Err(self.err("initializer must be a literal")),
                    },
                    _ => return Err(self.err("initializer must be a literal")),
                }
            }
            out.push(Decl { name, ty, dims, init });
            if self.eat(&TokenKind::Comma) {
                continue;
            }
            self.expect(&TokenKind::Semicolon)?;
            break;
        }
        Ok(out)
    }

    /// `for (int i = start; i < end; ++i) body`
    fn for_loop(&mut self) -> Result<Loop, KernelError> {
        self.expect(&TokenKind::Kw(Kw::For))?;
        self.expect(&TokenKind::LParen)?;
        // init: optional type keyword, then `i = expr`
        while matches!(
            self.peek(),
            Some(TokenKind::Kw(Kw::Int)) | Some(TokenKind::Kw(Kw::Long)) | Some(TokenKind::Kw(Kw::Unsigned))
        ) {
            self.pos += 1;
        }
        let index = match self.next() {
            Some(TokenKind::Ident(n)) => n,
            other => return Err(self.err(format!("expected loop index, found {other:?}"))),
        };
        self.expect(&TokenKind::Assign)?;
        let start = self.expr()?;
        self.expect(&TokenKind::Semicolon)?;
        // condition: `i < expr` or `i <= expr`
        match self.next() {
            Some(TokenKind::Ident(n)) if n == index => {}
            other => return Err(self.err(format!("loop condition must test '{index}', found {other:?}"))),
        }
        let le = match self.next() {
            Some(TokenKind::Lt) => false,
            Some(TokenKind::Le) => true,
            other => return Err(self.err(format!("expected < or <= in loop condition, found {other:?}"))),
        };
        let mut end = self.expr()?;
        if le {
            // normalize `i <= e` to exclusive bound `e + 1`
            end = Expr::Binary {
                op: BinOp::Add,
                lhs: Box::new(end),
                rhs: Box::new(Expr::Int(1)),
            };
        }
        self.expect(&TokenKind::Semicolon)?;
        // increment: ++i | i++ | i += k
        let step = match self.peek() {
            Some(TokenKind::Incr) => {
                self.pos += 1;
                match self.next() {
                    Some(TokenKind::Ident(n)) if n == index => 1,
                    other => return Err(self.err(format!("expected '{index}' after ++, found {other:?}"))),
                }
            }
            Some(TokenKind::Ident(n)) if *n == index => {
                self.pos += 1;
                match self.next() {
                    Some(TokenKind::Incr) => 1,
                    Some(TokenKind::CompoundAssign('+')) => match self.next() {
                        Some(TokenKind::Int(k)) if k > 0 => k,
                        other => {
                            return Err(self.err(format!("expected positive step, found {other:?}")))
                        }
                    },
                    other => return Err(self.err(format!("unsupported loop increment {other:?}"))),
                }
            }
            other => return Err(self.err(format!("unsupported loop increment {other:?}"))),
        };
        self.expect(&TokenKind::RParen)?;
        let body = self.loop_body()?;
        Ok(Loop { index, start, end, step, body })
    }

    fn loop_body(&mut self) -> Result<LoopBody, KernelError> {
        if self.eat(&TokenKind::LBrace) {
            // Either a nested loop (possibly with trailing '}'s) or
            // statements.
            if self.peek() == Some(&TokenKind::Kw(Kw::For)) {
                let inner = self.for_loop()?;
                while self.eat(&TokenKind::Semicolon) {}
                self.expect(&TokenKind::RBrace)?;
                return Ok(LoopBody::Nest(Box::new(inner)));
            }
            let mut stmts = Vec::new();
            while self.peek() != Some(&TokenKind::RBrace) {
                if self.peek().is_none() {
                    return Err(self.err("unterminated loop body"));
                }
                stmts.push(self.statement()?);
                while self.eat(&TokenKind::Semicolon) {}
            }
            self.expect(&TokenKind::RBrace)?;
            if stmts.is_empty() {
                return Err(self.err("empty loop body"));
            }
            Ok(LoopBody::Stmts(stmts))
        } else if self.peek() == Some(&TokenKind::Kw(Kw::For)) {
            Ok(LoopBody::Nest(Box::new(self.for_loop()?)))
        } else {
            let stmt = self.statement()?;
            while self.eat(&TokenKind::Semicolon) {}
            Ok(LoopBody::Stmts(vec![stmt]))
        }
    }

    /// `lhs (=|+=|-=|*=|/=) expr ;`
    fn statement(&mut self) -> Result<Stmt, KernelError> {
        let lhs = self.primary()?;
        match &lhs {
            Expr::Var(_) | Expr::Index { .. } => {}
            _ => return Err(self.err("assignment destination must be a variable or array element")),
        }
        let op = match self.next() {
            Some(TokenKind::Assign) => AssignOp::Set,
            Some(TokenKind::CompoundAssign('+')) => AssignOp::Add,
            Some(TokenKind::CompoundAssign('-')) => AssignOp::Sub,
            Some(TokenKind::CompoundAssign('*')) => AssignOp::Mul,
            Some(TokenKind::CompoundAssign('/')) => AssignOp::Div,
            other => return Err(self.err(format!("expected assignment operator, found {other:?}"))),
        };
        let rhs = self.expr()?;
        self.expect(&TokenKind::Semicolon)?;
        Ok(Stmt { lhs, op, rhs })
    }

    /// expr := term (('+'|'-') term)*
    fn expr(&mut self) -> Result<Expr, KernelError> {
        let mut lhs = self.term()?;
        loop {
            let op = match self.peek() {
                Some(TokenKind::Plus) => BinOp::Add,
                Some(TokenKind::Minus) => BinOp::Sub,
                _ => break,
            };
            self.pos += 1;
            let rhs = self.term()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    /// term := factor (('*'|'/') factor)*
    fn term(&mut self) -> Result<Expr, KernelError> {
        let mut lhs = self.factor()?;
        loop {
            let op = match self.peek() {
                Some(TokenKind::Star) => BinOp::Mul,
                Some(TokenKind::Slash) => BinOp::Div,
                _ => break,
            };
            self.pos += 1;
            let rhs = self.factor()?;
            lhs = Expr::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) };
        }
        Ok(lhs)
    }

    /// factor := '-' factor | primary
    fn factor(&mut self) -> Result<Expr, KernelError> {
        if self.eat(&TokenKind::Minus) {
            return Ok(Expr::Neg(Box::new(self.factor()?)));
        }
        self.primary()
    }

    /// primary := number | ident ('[' expr ']')* | '(' expr ')'
    fn primary(&mut self) -> Result<Expr, KernelError> {
        match self.peek().cloned() {
            Some(TokenKind::Int(v)) => {
                self.pos += 1;
                Ok(Expr::Int(v))
            }
            Some(TokenKind::Float(v)) => {
                self.pos += 1;
                Ok(Expr::Float(v))
            }
            Some(TokenKind::LParen) => {
                self.pos += 1;
                let e = self.expr()?;
                self.expect(&TokenKind::RParen)?;
                Ok(e)
            }
            Some(TokenKind::Ident(name)) => {
                self.pos += 1;
                if self.peek() == Some(&TokenKind::LBracket) {
                    let mut indices = Vec::new();
                    while self.eat(&TokenKind::LBracket) {
                        let e = self.expr()?;
                        self.expect(&TokenKind::RBracket)?;
                        indices.push(e);
                    }
                    Ok(Expr::Index { array: name, indices })
                } else {
                    Ok(Expr::Var(name))
                }
            }
            other => Err(self.err(format!("expected expression, found {other:?}"))),
        }
    }
}

/// Make `peek2` reachable for future lookahead needs without a dead-code
/// warning (used by tests).
#[allow(dead_code)]
fn _lookahead_is_used(p: &Parser) -> Option<&TokenKind> {
    p.peek2()
}

#[cfg(test)]
mod tests {
    use super::*;

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    #[test]
    fn parses_jacobi() {
        let p = parse(JACOBI).unwrap();
        assert_eq!(p.decls.len(), 3);
        assert!(p.decls[0].is_array());
        assert!(!p.decls[2].is_array());
        let loops = p.loops();
        assert_eq!(loops.len(), 2);
        assert_eq!(loops[0].index, "j");
        assert_eq!(loops[1].index, "i");
        assert_eq!(p.inner_stmts().len(), 1);
    }

    #[test]
    fn parses_scalar_product() {
        let src = "double a[N], b[N], s = 0.;\nfor (i = 0; i < N; ++i)\n  s += a[i] * b[i];";
        let p = parse(src).unwrap();
        assert_eq!(p.decls[2].init, Some(0.0));
        assert_eq!(p.nest.step, 1);
        let st = &p.inner_stmts()[0];
        assert_eq!(st.op, AssignOp::Add);
    }

    #[test]
    fn parses_triad() {
        let src = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++)\n  a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        assert_eq!(p.loops().len(), 1);
    }

    #[test]
    fn parses_multi_statement_body() {
        let src = r#"
            double a[N], b[N], c;
            double sum, prod, t, y;
            for (int i = 0; i < N; ++i) {
                prod = a[i] * b[i];
                y = prod - c;
                t = sum + y;
                c = (t - sum) - y;
                sum = t;
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.inner_stmts().len(), 5);
    }

    #[test]
    fn parses_3d_nest_with_braces() {
        let src = r#"
            double u[M][N][N], v[M][N][N];
            for (int k = 2; k < M - 2; k++) {
                for (int j = 2; j < N - 2; j++) {
                    for (int i = 2; i < N - 2; i++) {
                        u[k][j][i] = v[k][j][i] + v[k][j][i-1];
                    }
                }
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.loops().len(), 3);
        assert_eq!(p.loops()[0].index, "k");
    }

    #[test]
    fn normalizes_le_condition() {
        let src = "double a[N];\nfor (int i = 0; i <= N - 1; i++) a[i] = a[i] + 1.0;";
        let p = parse(src).unwrap();
        // `<= N-1` becomes exclusive `< (N-1)+1`
        match &p.nest.end {
            Expr::Binary { op: BinOp::Add, rhs, .. } => assert_eq!(**rhs, Expr::Int(1)),
            other => panic!("expected normalized end, got {other:?}"),
        }
    }

    #[test]
    fn dim_with_offset() {
        let src = "double u[N][M+3];\nfor (int i = 0; i < N; i++) u[i][0] = 1.0;";
        let p = parse(src).unwrap();
        assert_eq!(p.decls[0].dims.len(), 2);
    }

    #[test]
    fn rejects_trailing_junk() {
        let src = "double a[N];\nfor (int i = 0; i < N; i++) a[i] = 1.0;\ndouble z;";
        assert!(parse(src).is_err());
    }

    #[test]
    fn rejects_weird_increment() {
        let src = "double a[N];\nfor (int i = 0; i < N; i = i * 2) a[i] = 1.0;";
        assert!(parse(src).is_err());
    }

    #[test]
    fn rejects_missing_semicolon() {
        let src = "double a[N];\nfor (int i = 0; i < N; i++) a[i] = 1.0";
        assert!(parse(src).is_err());
    }

    #[test]
    fn parses_step_gt_one() {
        let src = "double a[N];\nfor (int i = 0; i < N; i += 2) a[i] = 0.5;";
        let p = parse(src).unwrap();
        assert_eq!(p.nest.step, 2);
    }

    #[test]
    fn parses_negated_literal_init() {
        let src = "double a[N], s = -1.5;\nfor (int i = 0; i < N; i++) a[i] = s;";
        let p = parse(src).unwrap();
        assert_eq!(p.decls[1].init, Some(-1.5));
    }

    #[test]
    fn precedence_mul_over_add() {
        let src = "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        match &p.inner_stmts()[0].rhs {
            Expr::Binary { op: BinOp::Add, rhs, .. } => match rhs.as_ref() {
                Expr::Binary { op: BinOp::Mul, .. } => {}
                other => panic!("expected mul on rhs, got {other:?}"),
            },
            other => panic!("expected add at top, got {other:?}"),
        }
    }
}
