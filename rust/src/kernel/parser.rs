//! Recursive-descent parser for the kernel surface language
//! (DESIGN.md §3, stage 2: tokens → [`syntax`] tree).
//!
//! The parser accepts a wider language than the analysis models —
//! typedefs, casts, conditionals, compound blocks, non-canonical loop
//! bounds — and leaves normalization to [`super::lower`]. Every
//! diagnostic carries the byte span of the offending token(s) and
//! renders tokens by their C spelling.

use super::ast::{AssignOp, BinOp, Type};
use super::diag::{Diagnostic, Span};
use super::lexer::{lex, Kw, Token, TokenKind};
use super::syntax::*;
use super::KernelError;
use std::collections::HashMap;

/// Parse kernel source all the way to the lowered [`super::ast::Program`]
/// the analysis consumes (lex → parse → lower).
pub fn parse(src: &str) -> Result<super::ast::Program, KernelError> {
    let unit = parse_unit(src)?;
    super::lower::lower(&unit, src)
}

/// Parse kernel source into the span-carrying surface [`Unit`].
pub fn parse_unit(src: &str) -> Result<Unit, KernelError> {
    let toks = lex(src)?;
    Parser { src, toks, pos: 0, typedefs: Parser::builtin_typedefs() }.unit()
}

/// What a typedef name resolves to: a modeled floating-point type, or
/// an integer type (declarations of which are skipped, like `int`).
#[derive(Debug, Clone, Copy, PartialEq)]
enum TypeSpec {
    Float(Type),
    Integer,
}

struct Parser<'a> {
    src: &'a str,
    toks: Vec<Token>,
    pos: usize,
    typedefs: HashMap<String, TypeSpec>,
}

impl<'a> Parser<'a> {
    /// Integer-like standard-library names accepted without a typedef.
    fn builtin_typedefs() -> HashMap<String, TypeSpec> {
        ["size_t", "ssize_t", "ptrdiff_t", "int32_t", "int64_t", "uint32_t", "uint64_t"]
            .into_iter()
            .map(|n| (n.to_string(), TypeSpec::Integer))
            .collect()
    }

    fn peek(&self) -> Option<&TokenKind> {
        self.toks.get(self.pos).map(|t| &t.kind)
    }

    fn next(&mut self) -> Option<TokenKind> {
        let t = self.toks.get(self.pos).map(|t| t.kind.clone());
        if t.is_some() {
            self.pos += 1;
        }
        t
    }

    /// Span of the current token, or the position just past the last
    /// token when the input ended early (never "line 0, col 0").
    fn here(&self) -> Span {
        if let Some(t) = self.toks.get(self.pos) {
            return t.span;
        }
        match self.toks.last() {
            Some(t) => Span::point(t.span.end, t.span.line, t.span.col + (t.span.end - t.span.start)),
            None => Span::point(0, 1, 1),
        }
    }

    /// Span of the most recently consumed token.
    fn prev_span(&self) -> Span {
        self.toks.get(self.pos.saturating_sub(1)).map(|t| t.span).unwrap_or_else(|| self.here())
    }

    /// Span from the first token at `from` through the last consumed one.
    fn span_from(&self, from: usize) -> Span {
        let a = self.toks.get(from).map(|t| t.span).unwrap_or_else(|| self.here());
        let b = self.prev_span();
        Span { start: a.start, end: b.end.max(a.start), line: a.line, col: a.col }
    }

    /// C spelling of the current token, or "end of input".
    fn found(&self) -> String {
        match self.peek() {
            Some(k) => k.spelling(),
            None => "end of input".into(),
        }
    }

    fn err(&self, code: &'static str, msg: impl Into<String>) -> KernelError {
        self.err_at(code, msg, self.here())
    }

    fn err_at(&self, code: &'static str, msg: impl Into<String>, span: Span) -> KernelError {
        Diagnostic::error(code, msg).with_span(span).with_snippet(self.src).into()
    }

    fn expect(&mut self, kind: &TokenKind) -> Result<(), KernelError> {
        if self.peek() == Some(kind) {
            self.pos += 1;
            return Ok(());
        }
        let code = if self.peek().is_none() { "E101" } else { "E100" };
        Err(self.err(code, format!("expected {}, found {}", kind.spelling(), self.found())))
    }

    fn eat(&mut self, kind: &TokenKind) -> bool {
        if self.peek() == Some(kind) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn is_int_type_kw(k: &TokenKind) -> bool {
        matches!(
            k,
            TokenKind::Kw(Kw::Int)
                | TokenKind::Kw(Kw::Long)
                | TokenKind::Kw(Kw::Short)
                | TokenKind::Kw(Kw::Char)
                | TokenKind::Kw(Kw::Signed)
                | TokenKind::Kw(Kw::Unsigned)
        )
    }

    /// Resolve an identifier through the typedef table.
    fn typedef_of(&self, k: &TokenKind) -> Option<TypeSpec> {
        match k {
            TokenKind::Ident(n) => self.typedefs.get(n).copied(),
            _ => None,
        }
    }

    fn unit(&mut self) -> Result<Unit, KernelError> {
        let mut decls = Vec::new();
        loop {
            match self.peek() {
                Some(TokenKind::Kw(Kw::For)) => break,
                Some(TokenKind::Kw(Kw::Typedef)) => self.typedef_decl()?,
                Some(TokenKind::Kw(Kw::Const)) | Some(TokenKind::Kw(Kw::Static)) => {
                    self.pos += 1; // qualifiers on a declaration
                }
                Some(TokenKind::Kw(Kw::Double)) => {
                    self.pos += 1;
                    decls.extend(self.declaration(Type::Double)?);
                }
                Some(TokenKind::Kw(Kw::Float)) => {
                    self.pos += 1;
                    decls.extend(self.declaration(Type::Float)?);
                }
                Some(k) if Self::is_int_type_kw(k) || self.typedef_of(k) == Some(TypeSpec::Integer) => {
                    // Integer declarations (e.g. problem-size constants
                    // declared in-source) are skipped up to `;`: sizes
                    // must come from `-D` or `#define` bindings.
                    while !matches!(self.peek(), Some(TokenKind::Semi) | None) {
                        self.pos += 1;
                    }
                    self.expect(&TokenKind::Semi)?;
                }
                Some(k) if self.typedef_of(k).is_some() => {
                    let Some(TypeSpec::Float(ty)) = self.typedef_of(k) else { unreachable!() };
                    self.pos += 1;
                    decls.extend(self.declaration(ty)?);
                }
                None => return Err(self.err("E101", "expected a for loop, found end of input")),
                _ => {
                    return Err(self.err(
                        "E100",
                        format!("expected declaration or for loop, found {}", self.found()),
                    ))
                }
            }
        }
        let nest = self.for_loop()?;
        // Trailing tokens (besides stray semicolons) are an error: a
        // kernel is a single loop nest.
        while self.eat(&TokenKind::Semi) {}
        if self.peek().is_some() {
            return Err(self.err(
                "E110",
                format!(
                    "unexpected trailing {} after the loop nest (only a single loop nest is supported)",
                    self.found()
                ),
            ));
        }
        Ok(Unit { decls, nest })
    }

    /// `typedef <type tokens> NAME;` — records what NAME means. The
    /// base type is the last floating keyword seen (or another typedef
    /// name); anything else makes NAME an integer type.
    fn typedef_decl(&mut self) -> Result<(), KernelError> {
        self.expect(&TokenKind::Kw(Kw::Typedef))?;
        let mut spec = TypeSpec::Integer;
        let mut name: Option<String> = None;
        loop {
            match self.peek() {
                Some(TokenKind::Semi) => break,
                Some(TokenKind::Kw(Kw::Double)) => {
                    spec = TypeSpec::Float(Type::Double);
                    self.pos += 1;
                }
                Some(TokenKind::Kw(Kw::Float)) => {
                    spec = TypeSpec::Float(Type::Float);
                    self.pos += 1;
                }
                Some(k) if Self::is_int_type_kw(k) || matches!(k, TokenKind::Kw(Kw::Const)) => {
                    self.pos += 1;
                }
                Some(TokenKind::Ident(_)) => {
                    let Some(TokenKind::Ident(n)) = self.next() else { unreachable!() };
                    if let Some(prev) = self.typedefs.get(&n).copied() {
                        // a typedef chained off another typedef
                        if name.is_none() && self.peek() != Some(&TokenKind::Semi) {
                            spec = prev;
                            continue;
                        }
                    }
                    name = Some(n);
                }
                _ => {
                    return Err(self.err(
                        "E103",
                        format!("unsupported typedef, found {}", self.found()),
                    ))
                }
            }
        }
        self.expect(&TokenKind::Semi)?;
        let Some(name) = name else {
            return Err(self.err_at("E103", "typedef is missing a name", self.prev_span()));
        };
        self.typedefs.insert(name, spec);
        Ok(())
    }

    /// `double a[M][N], s = 0., c1;` — the leading type keyword is
    /// already consumed.
    fn declaration(&mut self, ty: Type) -> Result<Vec<SDecl>, KernelError> {
        let mut out = Vec::new();
        loop {
            let start = self.pos;
            // optional `restrict` / `*` (pointer declarations degrade to
            // unbounded arrays, sized by the analysis if indexed 1-D)
            while self.eat(&TokenKind::Star) || self.eat(&TokenKind::Kw(Kw::Restrict)) {}
            let name = match self.peek() {
                Some(TokenKind::Ident(_)) => {
                    let Some(TokenKind::Ident(n)) = self.next() else { unreachable!() };
                    n
                }
                _ => {
                    return Err(self.err(
                        "E103",
                        format!("expected identifier in declaration, found {}", self.found()),
                    ))
                }
            };
            let mut dims = Vec::new();
            while self.eat(&TokenKind::LBracket) {
                // `double a[]` (empty dimension) is allowed for 1D
                // streaming arrays; it is treated as "large" by the
                // analysis.
                if self.peek() == Some(&TokenKind::RBracket) {
                    let span = self.here();
                    self.pos += 1;
                    dims.push(SExpr::new(SExprKind::Var("__unbounded__".into()), span));
                    continue;
                }
                let e = self.add_expr()?;
                self.expect(&TokenKind::RBracket)?;
                dims.push(e);
            }
            let mut init = None;
            if self.eat(&TokenKind::Assign) {
                let e = self.add_expr()?;
                init = Some(self.literal_value(&e)?);
            }
            out.push(SDecl { name, ty, dims, init, span: self.span_from(start) });
            if self.eat(&TokenKind::Comma) {
                continue;
            }
            self.expect(&TokenKind::Semi)?;
            break;
        }
        Ok(out)
    }

    /// Evaluate a literal initializer (casts are erased, a leading `-`
    /// folds into the value).
    fn literal_value(&self, e: &SExpr) -> Result<f64, KernelError> {
        match &e.kind {
            SExprKind::Int(v) => Ok(*v as f64),
            SExprKind::Float(v) => Ok(*v),
            SExprKind::Neg(inner) => Ok(-self.literal_value(inner)?),
            SExprKind::Cast { expr, .. } => self.literal_value(expr),
            _ => Err(self.err_at("E103", "initializer must be a literal", e.span)),
        }
    }

    /// `for (int i = start; i < end; ++i) body`
    fn for_loop(&mut self) -> Result<SLoop, KernelError> {
        let start_pos = self.pos;
        self.expect(&TokenKind::Kw(Kw::For))?;
        self.expect(&TokenKind::LParen)?;
        // init: optional integer type (keyword or typedef), then `i = expr`
        loop {
            match self.peek() {
                Some(k) if Self::is_int_type_kw(k) => self.pos += 1,
                Some(k)
                    if self.typedef_of(k) == Some(TypeSpec::Integer)
                        && matches!(
                            self.toks.get(self.pos + 1).map(|t| &t.kind),
                            Some(TokenKind::Ident(_))
                        ) =>
                {
                    self.pos += 1
                }
                _ => break,
            }
        }
        let index = match self.peek() {
            Some(TokenKind::Ident(_)) => {
                let Some(TokenKind::Ident(n)) = self.next() else { unreachable!() };
                n
            }
            _ => {
                return Err(self.err("E102", format!("expected loop index, found {}", self.found())))
            }
        };
        self.expect(&TokenKind::Assign)?;
        let init = self.add_expr()?;
        self.expect(&TokenKind::Semi)?;
        let (cmp, bound) = self.loop_condition(&index)?;
        self.expect(&TokenKind::Semi)?;
        let step = self.loop_increment(&index)?;
        self.expect(&TokenKind::RParen)?;
        let body = self.body_items()?;
        Ok(SLoop { index, start: init, cmp, bound, step, body, span: self.span_from(start_pos) })
    }

    /// Loop condition: `i < e`, `i <= e`, or the flipped `e > i` /
    /// `e >= i`. Downward-counting loops are rejected.
    fn loop_condition(&mut self, index: &str) -> Result<(CmpDir, SExpr), KernelError> {
        let cond_start = self.pos;
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Some(TokenKind::Lt) => CmpOp::Lt,
            Some(TokenKind::Le) => CmpOp::Le,
            Some(TokenKind::Gt) => CmpOp::Gt,
            Some(TokenKind::Ge) => CmpOp::Ge,
            _ => {
                return Err(self.err(
                    "E102",
                    format!("expected a comparison in the loop condition, found {}", self.found()),
                ))
            }
        };
        self.pos += 1;
        let rhs = self.add_expr()?;
        let span = self.span_from(cond_start);
        let is_index = |e: &SExpr| matches!(&e.kind, SExprKind::Var(v) if v == index);
        match (is_index(&lhs), op) {
            (true, CmpOp::Lt) => Ok((CmpDir::Lt, rhs)),
            (true, CmpOp::Le) => Ok((CmpDir::Le, rhs)),
            (true, _) => Err(self.err_at(
                "E102",
                format!("loop over '{index}' must count upward ('<' or '<=')"),
                span,
            )),
            (false, _) if is_index(&rhs) => match op {
                // `bound > i` reads as `i < bound`
                CmpOp::Gt => Ok((CmpDir::Lt, lhs)),
                CmpOp::Ge => Ok((CmpDir::Le, lhs)),
                _ => Err(self.err_at(
                    "E102",
                    format!("loop over '{index}' must count upward ('<' or '<=')"),
                    span,
                )),
            },
            _ => Err(self.err_at(
                "E102",
                format!("loop condition must test the loop index '{index}'"),
                span,
            )),
        }
    }

    /// Loop increment: `++i`, `i++`, `i += e`, or `i = i + e`.
    fn loop_increment(&mut self, index: &str) -> Result<SExpr, KernelError> {
        let one = |span: Span| SExpr::new(SExprKind::Int(1), span);
        match self.peek() {
            Some(TokenKind::Incr) => {
                let span = self.here();
                self.pos += 1;
                match self.peek() {
                    Some(TokenKind::Ident(n)) if n == index => {
                        self.pos += 1;
                        Ok(one(span))
                    }
                    _ => Err(self.err(
                        "E102",
                        format!("expected '{index}' after '++', found {}", self.found()),
                    )),
                }
            }
            Some(TokenKind::Decr) => Err(self.err(
                "E102",
                format!("loop over '{index}' must count upward ('++', '+=')"),
            )),
            Some(TokenKind::Ident(n)) if n == index => {
                self.pos += 1;
                match self.peek() {
                    Some(TokenKind::Incr) => {
                        let span = self.here();
                        self.pos += 1;
                        Ok(one(span))
                    }
                    Some(TokenKind::CompoundAssign('+')) => {
                        self.pos += 1;
                        self.add_expr()
                    }
                    Some(TokenKind::Assign) => {
                        // `i = i + e` or `i = e + i`
                        self.pos += 1;
                        let e = self.add_expr()?;
                        let is_index = |e: &SExpr| matches!(&e.kind, SExprKind::Var(v) if v == index);
                        match e.kind {
                            SExprKind::Binary { op: BinOp::Add, ref lhs, ref rhs }
                                if is_index(lhs) =>
                            {
                                Ok((**rhs).clone())
                            }
                            SExprKind::Binary { op: BinOp::Add, ref lhs, ref rhs }
                                if is_index(rhs) =>
                            {
                                Ok((**lhs).clone())
                            }
                            _ => Err(self.err_at(
                                "E102",
                                format!("unsupported loop increment (expected '{index} = {index} + step')"),
                                e.span,
                            )),
                        }
                    }
                    Some(TokenKind::Decr) | Some(TokenKind::CompoundAssign('-')) => Err(self.err(
                        "E102",
                        format!("loop over '{index}' must count upward ('++', '+=')"),
                    )),
                    _ => Err(self.err(
                        "E102",
                        format!("unsupported loop increment, found {}", self.found()),
                    )),
                }
            }
            _ => Err(self.err(
                "E102",
                format!("unsupported loop increment, found {}", self.found()),
            )),
        }
    }

    /// A loop/branch body: a braced item list or a single item.
    fn body_items(&mut self) -> Result<Vec<SItem>, KernelError> {
        if self.eat(&TokenKind::LBrace) {
            let mut items = Vec::new();
            loop {
                while self.eat(&TokenKind::Semi) {}
                match self.peek() {
                    Some(TokenKind::RBrace) => {
                        self.pos += 1;
                        return Ok(items);
                    }
                    None => return Err(self.err("E101", "unterminated loop body, expected '}'")),
                    _ => items.push(self.body_item()?),
                }
            }
        }
        let item = self.body_item()?;
        while self.eat(&TokenKind::Semi) {}
        Ok(vec![item])
    }

    fn body_item(&mut self) -> Result<SItem, KernelError> {
        match self.peek() {
            Some(TokenKind::Kw(Kw::For)) => Ok(SItem::Loop(self.for_loop()?)),
            Some(TokenKind::Kw(Kw::If)) => Ok(SItem::If(self.if_stmt()?)),
            Some(TokenKind::LBrace) => Ok(SItem::Block(self.body_items()?)),
            _ => Ok(SItem::Assign(self.statement()?)),
        }
    }

    /// `if (cond) item [else item]`
    fn if_stmt(&mut self) -> Result<SIf, KernelError> {
        let start = self.pos;
        self.expect(&TokenKind::Kw(Kw::If))?;
        self.expect(&TokenKind::LParen)?;
        let cond = self.cond_expr()?;
        self.expect(&TokenKind::RParen)?;
        let then_items = self.branch_items()?;
        let else_items = if self.eat(&TokenKind::Kw(Kw::Else)) {
            self.branch_items()?
        } else {
            Vec::new()
        };
        Ok(SIf { cond, then_items, else_items, span: self.span_from(start) })
    }

    fn branch_items(&mut self) -> Result<Vec<SItem>, KernelError> {
        match self.body_item()? {
            SItem::Block(items) => Ok(items),
            item => Ok(vec![item]),
        }
    }

    /// `lhs (=|+=|-=|*=|/=) expr ;`
    fn statement(&mut self) -> Result<SAssign, KernelError> {
        let start = self.pos;
        let lhs = self.unary()?;
        match &lhs.kind {
            SExprKind::Var(_) | SExprKind::Index { .. } => {}
            _ => {
                return Err(self.err_at(
                    "E100",
                    "assignment destination must be a variable or array element",
                    lhs.span,
                ))
            }
        }
        let op = match self.peek() {
            Some(TokenKind::Assign) => AssignOp::Set,
            Some(TokenKind::CompoundAssign('+')) => AssignOp::Add,
            Some(TokenKind::CompoundAssign('-')) => AssignOp::Sub,
            Some(TokenKind::CompoundAssign('*')) => AssignOp::Mul,
            Some(TokenKind::CompoundAssign('/')) => AssignOp::Div,
            _ => {
                let code = if self.peek().is_none() { "E101" } else { "E100" };
                return Err(
                    self.err(code, format!("expected assignment operator, found {}", self.found()))
                );
            }
        };
        self.pos += 1;
        let rhs = self.cond_expr()?;
        self.expect(&TokenKind::Semi)?;
        Ok(SAssign { lhs, op, rhs, span: self.span_from(start) })
    }

    // ---- expressions ----------------------------------------------------

    /// cond := and ('||' and)*
    fn cond_expr(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        let mut lhs = self.and_expr()?;
        while self.eat(&TokenKind::OrOr) {
            let rhs = self.and_expr()?;
            lhs = SExpr::new(
                SExprKind::Logical { op: LogicalOp::Or, lhs: Box::new(lhs), rhs: Box::new(rhs) },
                self.span_from(start),
            );
        }
        Ok(lhs)
    }

    /// and := cmp ('&&' cmp)*
    fn and_expr(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        let mut lhs = self.cmp_expr()?;
        while self.eat(&TokenKind::AndAnd) {
            let rhs = self.cmp_expr()?;
            lhs = SExpr::new(
                SExprKind::Logical { op: LogicalOp::And, lhs: Box::new(lhs), rhs: Box::new(rhs) },
                self.span_from(start),
            );
        }
        Ok(lhs)
    }

    /// cmp := add (('<'|'<='|'>'|'>='|'=='|'!=') add)?   (non-associative)
    fn cmp_expr(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        let lhs = self.add_expr()?;
        let op = match self.peek() {
            Some(TokenKind::Lt) => CmpOp::Lt,
            Some(TokenKind::Le) => CmpOp::Le,
            Some(TokenKind::Gt) => CmpOp::Gt,
            Some(TokenKind::Ge) => CmpOp::Ge,
            Some(TokenKind::EqEq) => CmpOp::Eq,
            Some(TokenKind::Ne) => CmpOp::Ne,
            _ => return Ok(lhs),
        };
        self.pos += 1;
        let rhs = self.add_expr()?;
        Ok(SExpr::new(
            SExprKind::Cmp { op, lhs: Box::new(lhs), rhs: Box::new(rhs) },
            self.span_from(start),
        ))
    }

    /// add := mul (('+'|'-') mul)*
    fn add_expr(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        let mut lhs = self.mul_expr()?;
        loop {
            let op = match self.peek() {
                Some(TokenKind::Plus) => BinOp::Add,
                Some(TokenKind::Minus) => BinOp::Sub,
                _ => break,
            };
            self.pos += 1;
            let rhs = self.mul_expr()?;
            lhs = SExpr::new(
                SExprKind::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) },
                self.span_from(start),
            );
        }
        Ok(lhs)
    }

    /// mul := unary (('*'|'/') unary)*
    fn mul_expr(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        let mut lhs = self.unary()?;
        loop {
            let op = match self.peek() {
                Some(TokenKind::Star) => BinOp::Mul,
                Some(TokenKind::Slash) => BinOp::Div,
                _ => break,
            };
            self.pos += 1;
            let rhs = self.unary()?;
            lhs = SExpr::new(
                SExprKind::Binary { op, lhs: Box::new(lhs), rhs: Box::new(rhs) },
                self.span_from(start),
            );
        }
        Ok(lhs)
    }

    /// unary := '-' unary | '!' unary | '(' type ')' unary | primary
    fn unary(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        if self.eat(&TokenKind::Minus) {
            let e = self.unary()?;
            return Ok(SExpr::new(SExprKind::Neg(Box::new(e)), self.span_from(start)));
        }
        if self.eat(&TokenKind::Bang) {
            let e = self.unary()?;
            return Ok(SExpr::new(SExprKind::Not(Box::new(e)), self.span_from(start)));
        }
        if self.cast_ahead() {
            // consume '(' <type tokens> ')'
            self.pos += 1;
            let mut name = String::new();
            while self.peek() != Some(&TokenKind::RParen) {
                if !name.is_empty() {
                    name.push(' ');
                }
                match self.next() {
                    Some(TokenKind::Kw(k)) => name.push_str(k.as_str()),
                    Some(TokenKind::Ident(n)) => name.push_str(&n),
                    Some(TokenKind::Star) => name.push('*'),
                    _ => unreachable!("cast_ahead validated the type tokens"),
                }
            }
            self.pos += 1;
            let e = self.unary()?;
            return Ok(SExpr::new(
                SExprKind::Cast { ty: name, expr: Box::new(e) },
                self.span_from(start),
            ));
        }
        self.primary()
    }

    /// Detect a cast at the cursor: `'('` followed only by type tokens
    /// (type keywords or typedef names, optionally `*`) then `')'`,
    /// with an operand after it.
    fn cast_ahead(&self) -> bool {
        if self.peek() != Some(&TokenKind::LParen) {
            return false;
        }
        let mut i = self.pos + 1;
        let mut saw_type = false;
        while let Some(t) = self.toks.get(i) {
            match &t.kind {
                k if Self::is_int_type_kw(k) => {}
                TokenKind::Kw(Kw::Double) | TokenKind::Kw(Kw::Float) | TokenKind::Kw(Kw::Const)
                | TokenKind::Kw(Kw::Void) => {}
                k @ TokenKind::Ident(_) if self.typedef_of(k).is_some() => {}
                TokenKind::Star if saw_type => {}
                TokenKind::RParen => {
                    // at least one type token, and an operand must follow
                    return saw_type && self.toks.get(i + 1).is_some();
                }
                _ => return false,
            }
            saw_type = true;
            i += 1;
        }
        false
    }

    /// primary := number | ident ('[' expr ']')* | '(' cond ')'
    fn primary(&mut self) -> Result<SExpr, KernelError> {
        let start = self.pos;
        match self.peek().cloned() {
            Some(TokenKind::Int(v)) => {
                self.pos += 1;
                Ok(SExpr::new(SExprKind::Int(v), self.prev_span()))
            }
            Some(TokenKind::Float(v)) => {
                self.pos += 1;
                Ok(SExpr::new(SExprKind::Float(v), self.prev_span()))
            }
            Some(TokenKind::LParen) => {
                self.pos += 1;
                let e = self.cond_expr()?;
                self.expect(&TokenKind::RParen)?;
                Ok(SExpr::new(e.kind, self.span_from(start)))
            }
            Some(TokenKind::Ident(name)) => {
                self.pos += 1;
                if self.peek() == Some(&TokenKind::LBracket) {
                    let mut indices = Vec::new();
                    while self.eat(&TokenKind::LBracket) {
                        let e = self.add_expr()?;
                        self.expect(&TokenKind::RBracket)?;
                        indices.push(e);
                    }
                    Ok(SExpr::new(SExprKind::Index { array: name, indices }, self.span_from(start)))
                } else {
                    Ok(SExpr::new(SExprKind::Var(name), self.prev_span()))
                }
            }
            _ => {
                let code = if self.peek().is_none() { "E101" } else { "E100" };
                Err(self.err(code, format!("expected expression, found {}", self.found())))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::ast::{Expr, Program};
    use super::*;

    const JACOBI: &str = r#"
        double a[M][N], b[M][N], s;
        for (int j = 1; j < M - 1; j++)
            for (int i = 1; i < N - 1; i++)
                b[j][i] = (a[j][i-1] + a[j][i+1] + a[j-1][i] + a[j+1][i]) * s;
    "#;

    fn step_of(p: &Program) -> &Expr {
        &p.nest.step
    }

    #[test]
    fn parses_jacobi() {
        let p = parse(JACOBI).unwrap();
        assert_eq!(p.decls.len(), 3);
        assert!(p.decls[0].is_array());
        assert!(!p.decls[2].is_array());
        let loops = p.loops();
        assert_eq!(loops.len(), 2);
        assert_eq!(loops[0].index, "j");
        assert_eq!(loops[1].index, "i");
        assert_eq!(p.inner_stmts().len(), 1);
    }

    #[test]
    fn parses_scalar_product() {
        let src = "double a[N], b[N], s = 0.;\nfor (i = 0; i < N; ++i)\n  s += a[i] * b[i];";
        let p = parse(src).unwrap();
        assert_eq!(p.decls[2].init, Some(0.0));
        assert_eq!(*step_of(&p), Expr::Int(1));
        let st = &p.inner_stmts()[0];
        assert_eq!(st.op, AssignOp::Add);
    }

    #[test]
    fn parses_triad() {
        let src =
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++)\n  a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        assert_eq!(p.loops().len(), 1);
    }

    #[test]
    fn parses_multi_statement_body() {
        let src = r#"
            double a[N], b[N], c;
            double sum, prod, t, y;
            for (int i = 0; i < N; ++i) {
                prod = a[i] * b[i];
                y = prod - c;
                t = sum + y;
                c = (t - sum) - y;
                sum = t;
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.inner_stmts().len(), 5);
    }

    #[test]
    fn parses_3d_nest_with_braces() {
        let src = r#"
            double u[M][N][N], v[M][N][N];
            for (int k = 2; k < M - 2; k++) {
                for (int j = 2; j < N - 2; j++) {
                    for (int i = 2; i < N - 2; i++) {
                        u[k][j][i] = v[k][j][i] + v[k][j][i-1];
                    }
                }
            }
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.loops().len(), 3);
        assert_eq!(p.loops()[0].index, "k");
    }

    #[test]
    fn normalizes_le_condition() {
        let src = "double a[N];\nfor (int i = 0; i <= N - 1; i++) a[i] = a[i] + 1.0;";
        let p = parse(src).unwrap();
        // `<= N-1` becomes exclusive `< (N-1)+1`
        match &p.nest.end {
            Expr::Binary { op: BinOp::Add, rhs, .. } => assert_eq!(**rhs, Expr::Int(1)),
            other => panic!("expected normalized end, got {other:?}"),
        }
    }

    #[test]
    fn dim_with_offset() {
        let src = "double u[N][M+3];\nfor (int i = 0; i < N; i++) u[i][0] = 1.0;";
        let p = parse(src).unwrap();
        assert_eq!(p.decls[0].dims.len(), 2);
    }

    #[test]
    fn rejects_trailing_junk() {
        let src = "double a[N];\nfor (int i = 0; i < N; i++) a[i] = 1.0;\ndouble z;";
        let err = parse(src).unwrap_err();
        assert_eq!(err.code(), "E110");
    }

    #[test]
    fn rejects_weird_increment() {
        let src = "double a[N];\nfor (int i = 0; i < N; i = i * 2) a[i] = 1.0;";
        let err = parse(src).unwrap_err();
        assert_eq!(err.code(), "E102");
    }

    #[test]
    fn rejects_missing_semicolon_past_last_token() {
        let src = "double a[N];\nfor (int i = 0; i < N; i++) a[i] = 1.0";
        let err = parse(src).unwrap_err();
        assert_eq!(err.code(), "E101");
        // position is just past the final `1.0`, never "line 0, col 0"
        let span = err.diag.span.unwrap();
        assert_eq!(span.line, 2);
        assert_eq!(span.col, 39);
        assert_eq!(span.start, src.len());
    }

    #[test]
    fn parses_step_gt_one() {
        let src = "double a[N];\nfor (int i = 0; i < N; i += 2) a[i] = 0.5;";
        let p = parse(src).unwrap();
        assert_eq!(*step_of(&p), Expr::Int(2));
    }

    #[test]
    fn parses_symbolic_and_written_out_steps() {
        let src = "double a[N];\nfor (int i = 0; i < N; i += S) a[i] = 0.5;";
        assert_eq!(*step_of(&parse(src).unwrap()), Expr::Var("S".into()));
        let src = "double a[N];\nfor (int i = 0; i < N; i = i + 4) a[i] = 0.5;";
        assert_eq!(*step_of(&parse(src).unwrap()), Expr::Int(4));
    }

    #[test]
    fn parses_flipped_bound() {
        let src = "double a[N];\nfor (int i = 0; N > i; i++) a[i] = 0.5;";
        let p = parse(src).unwrap();
        assert_eq!(p.nest.end, Expr::Var("N".into()));
    }

    #[test]
    fn parses_negated_literal_init() {
        let src = "double a[N], s = -1.5;\nfor (int i = 0; i < N; i++) a[i] = s;";
        let p = parse(src).unwrap();
        assert_eq!(p.decls[1].init, Some(-1.5));
    }

    #[test]
    fn parses_typedef_and_cast() {
        let src = r#"
            typedef double real;
            real a[N], b[N];
            for (size_t i = 0; i < N; ++i)
                a[i] = (real)b[i] + (double)2;
        "#;
        let p = parse(src).unwrap();
        assert_eq!(p.decls[0].ty, Type::Double);
        assert_eq!(p.inner_stmts().len(), 1);
    }

    #[test]
    fn parses_conditional_body() {
        let src = r#"
            double a[N], b[N], t;
            for (int i = 0; i < N; ++i) {
                if (b[i] > 0.0) { a[i] = b[i]; } else { a[i] = t; }
            }
        "#;
        let p = parse(src).unwrap();
        // condition guard + both branches are modeled
        assert!(p.inner_stmts().len() >= 3);
    }

    #[test]
    fn error_messages_use_c_spelling() {
        let src = "double a[N];\nfor (int i = 0; i < N; i++) a[i = 1.0;";
        let err = parse(src).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("']'"), "renders C spelling: {msg}");
        assert!(!msg.contains("RBracket"), "no Rust debug names: {msg}");
        let src = "double a[N] for (int i = 0; i < N; i++) a[i] = 1.0;";
        let msg = parse(src).unwrap_err().to_string();
        assert!(msg.contains("'for'"), "{msg}");
        assert!(!msg.contains("Kw("), "{msg}");
    }

    #[test]
    fn precedence_mul_over_add() {
        let src =
            "double a[N], b[N], c[N], d[N];\nfor (int i = 0; i < N; i++) a[i] = b[i] + c[i] * d[i];";
        let p = parse(src).unwrap();
        match &p.inner_stmts()[0].rhs {
            Expr::Binary { op: BinOp::Add, rhs, .. } => match rhs.as_ref() {
                Expr::Binary { op: BinOp::Mul, .. } => {}
                other => panic!("expected mul on rhs, got {other:?}"),
            },
            other => panic!("expected add at top, got {other:?}"),
        }
    }
}
