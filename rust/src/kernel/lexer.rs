//! Tokenizer for the restricted kernel language (DESIGN.md §3, stage 1).
//!
//! Produces a flat token stream where every token carries a byte
//! [`Span`] into the original source. Handles `//` and `/* */`
//! comments, preprocessor lines (only `#define NAME <literal>` has an
//! effect: later uses of `NAME` are substituted by the literal, span
//! kept at the use site; other `#` lines are skipped like the real
//! preprocessor output would be), and the full operator set of the
//! surface grammar — including comparisons and logical operators so
//! conditionals inside loop bodies lex cleanly.

use super::diag::{Diagnostic, Span};
use super::KernelError;
use std::collections::HashMap;

/// Keywords recognized by the frontend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kw {
    For,
    If,
    Else,
    Typedef,
    Int,
    Long,
    Short,
    Char,
    Signed,
    Unsigned,
    Double,
    Float,
    Void,
    Const,
    Static,
    Restrict,
}

impl Kw {
    pub fn as_str(self) -> &'static str {
        match self {
            Kw::For => "for",
            Kw::If => "if",
            Kw::Else => "else",
            Kw::Typedef => "typedef",
            Kw::Int => "int",
            Kw::Long => "long",
            Kw::Short => "short",
            Kw::Char => "char",
            Kw::Signed => "signed",
            Kw::Unsigned => "unsigned",
            Kw::Double => "double",
            Kw::Float => "float",
            Kw::Void => "void",
            Kw::Const => "const",
            Kw::Static => "static",
            Kw::Restrict => "restrict",
        }
    }

    fn of(word: &str) -> Option<Kw> {
        Some(match word {
            "for" => Kw::For,
            "if" => Kw::If,
            "else" => Kw::Else,
            "typedef" => Kw::Typedef,
            "int" => Kw::Int,
            "long" => Kw::Long,
            "short" => Kw::Short,
            "char" => Kw::Char,
            "signed" => Kw::Signed,
            "unsigned" => Kw::Unsigned,
            "double" => Kw::Double,
            "float" => Kw::Float,
            "void" => Kw::Void,
            "const" => Kw::Const,
            "static" => Kw::Static,
            "restrict" | "__restrict" | "__restrict__" => Kw::Restrict,
            _ => return None,
        })
    }
}

/// Token kinds. `CompoundAssign('+')` is `+=` and so on.
#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    Ident(String),
    Int(i64),
    Float(f64),
    Kw(Kw),
    LParen,
    RParen,
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    Semi,
    Comma,
    Assign,
    Plus,
    Minus,
    Star,
    Slash,
    CompoundAssign(char),
    Lt,
    Le,
    Gt,
    Ge,
    EqEq,
    Ne,
    AndAnd,
    OrOr,
    Bang,
    Incr,
    Decr,
}

impl TokenKind {
    /// The C source spelling of the token, quoted, for diagnostics —
    /// `'for'`, `'}'`, `'+='` — never Rust debug formatting.
    pub fn spelling(&self) -> String {
        match self {
            TokenKind::Ident(s) => format!("'{s}'"),
            TokenKind::Int(v) => format!("'{v}'"),
            TokenKind::Float(v) => format!("'{v}'"),
            TokenKind::Kw(k) => format!("'{}'", k.as_str()),
            TokenKind::LParen => "'('".into(),
            TokenKind::RParen => "')'".into(),
            TokenKind::LBrace => "'{'".into(),
            TokenKind::RBrace => "'}'".into(),
            TokenKind::LBracket => "'['".into(),
            TokenKind::RBracket => "']'".into(),
            TokenKind::Semi => "';'".into(),
            TokenKind::Comma => "','".into(),
            TokenKind::Assign => "'='".into(),
            TokenKind::Plus => "'+'".into(),
            TokenKind::Minus => "'-'".into(),
            TokenKind::Star => "'*'".into(),
            TokenKind::Slash => "'/'".into(),
            TokenKind::CompoundAssign(op) => format!("'{op}='"),
            TokenKind::Lt => "'<'".into(),
            TokenKind::Le => "'<='".into(),
            TokenKind::Gt => "'>'".into(),
            TokenKind::Ge => "'>='".into(),
            TokenKind::EqEq => "'=='".into(),
            TokenKind::Ne => "'!='".into(),
            TokenKind::AndAnd => "'&&'".into(),
            TokenKind::OrOr => "'||'".into(),
            TokenKind::Bang => "'!'".into(),
            TokenKind::Incr => "'++'".into(),
            TokenKind::Decr => "'--'".into(),
        }
    }
}

/// A token plus its byte span in the original source.
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub span: Span,
}

struct Lexer<'a> {
    src: &'a str,
    chars: Vec<(usize, char)>,
    pos: usize,
    line: usize,
    col: usize,
    /// `#define NAME <literal>` substitutions seen so far.
    defines: HashMap<String, TokenKind>,
}

impl<'a> Lexer<'a> {
    fn new(src: &'a str) -> Lexer<'a> {
        Lexer {
            src,
            chars: src.char_indices().collect(),
            pos: 0,
            line: 1,
            col: 1,
            defines: HashMap::new(),
        }
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).map(|&(_, c)| c)
    }

    fn peek2(&self) -> Option<char> {
        self.chars.get(self.pos + 1).map(|&(_, c)| c)
    }

    /// Byte offset of the current position (source length at EOF).
    fn offset(&self) -> usize {
        self.chars.get(self.pos).map(|&(o, _)| o).unwrap_or(self.src.len())
    }

    fn bump(&mut self) -> Option<char> {
        let &(_, c) = self.chars.get(self.pos)?;
        self.pos += 1;
        if c == '\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(c)
    }

    fn err(&self, code: &'static str, msg: String, span: Span) -> KernelError {
        Diagnostic::error(code, msg).with_span(span).with_snippet(self.src).into()
    }

    fn mark(&self) -> (usize, usize, usize) {
        (self.offset(), self.line, self.col)
    }

    fn span_from(&self, start: (usize, usize, usize)) -> Span {
        Span { start: start.0, end: self.offset(), line: start.1, col: start.2 }
    }

    /// Skip whitespace, comments and preprocessor lines; errors on an
    /// unterminated block comment.
    fn skip_trivia(&mut self) -> Result<(), KernelError> {
        loop {
            match self.peek() {
                Some(c) if c.is_whitespace() => {
                    self.bump();
                }
                Some('/') if self.peek2() == Some('/') => {
                    while let Some(c) = self.peek() {
                        if c == '\n' {
                            break;
                        }
                        self.bump();
                    }
                }
                Some('/') if self.peek2() == Some('*') => {
                    let start = self.mark();
                    self.bump();
                    self.bump();
                    loop {
                        match self.peek() {
                            Some('*') if self.peek2() == Some('/') => {
                                self.bump();
                                self.bump();
                                break;
                            }
                            Some(_) => {
                                self.bump();
                            }
                            None => {
                                let span = Span {
                                    start: start.0,
                                    end: start.0 + 2,
                                    line: start.1,
                                    col: start.2,
                                };
                                return Err(self.err(
                                    "E003",
                                    "unterminated block comment".into(),
                                    span,
                                ));
                            }
                        }
                    }
                }
                Some('#') => {
                    self.preprocessor_line();
                }
                _ => return Ok(()),
            }
        }
    }

    /// Consume a `#` line. `#define NAME <int|float literal>` records a
    /// substitution; every other directive is skipped, matching what
    /// preprocessed kernel source would look like.
    fn preprocessor_line(&mut self) {
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c == '\n' {
                break;
            }
            self.bump();
        }
        let text: String = self.chars[start..self.pos].iter().map(|&(_, c)| c).collect();
        let mut words = text.trim_start_matches('#').split_whitespace();
        if words.next() != Some("define") {
            return;
        }
        let (Some(name), Some(value)) = (words.next(), words.next()) else { return };
        if words.next().is_some() {
            return; // expression-valued macros are not substituted
        }
        let kind = if let Ok(v) = value.parse::<i64>() {
            TokenKind::Int(v)
        } else if let Ok(v) = value.parse::<f64>() {
            TokenKind::Float(v)
        } else {
            return;
        };
        self.defines.insert(name.to_string(), kind);
    }

    fn number(&mut self) -> Result<TokenKind, KernelError> {
        let start = self.mark();
        let mut text = String::new();
        let mut is_float = false;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() {
                text.push(c);
                self.bump();
            } else if c == '.' && !is_float {
                is_float = true;
                text.push(c);
                self.bump();
            } else if (c == 'e' || c == 'E')
                && self
                    .peek2()
                    .map(|n| n.is_ascii_digit() || n == '+' || n == '-')
                    .unwrap_or(false)
            {
                is_float = true;
                text.push(c);
                self.bump();
                if let Some(sign @ ('+' | '-')) = self.peek() {
                    text.push(sign);
                    self.bump();
                }
            } else {
                break;
            }
        }
        // C float/integer suffixes are accepted and dropped
        while let Some(c @ ('f' | 'F' | 'l' | 'L' | 'u' | 'U')) = self.peek() {
            if c == 'f' || c == 'F' {
                is_float = true;
            }
            self.bump();
        }
        let parsed = if is_float {
            text.parse::<f64>().map(TokenKind::Float).ok()
        } else {
            text.parse::<i64>().map(TokenKind::Int).ok()
        };
        parsed.ok_or_else(|| {
            self.err("E002", format!("malformed numeric literal '{text}'"), self.span_from(start))
        })
    }

    fn next_token(&mut self) -> Result<Option<Token>, KernelError> {
        self.skip_trivia()?;
        let start = self.mark();
        let Some(c) = self.peek() else { return Ok(None) };
        let kind = if c.is_ascii_alphabetic() || c == '_' {
            let mut word = String::new();
            while let Some(c) = self.peek() {
                if c.is_ascii_alphanumeric() || c == '_' {
                    word.push(c);
                    self.bump();
                } else {
                    break;
                }
            }
            if let Some(kw) = Kw::of(&word) {
                TokenKind::Kw(kw)
            } else if let Some(sub) = self.defines.get(&word) {
                sub.clone()
            } else {
                TokenKind::Ident(word)
            }
        } else if c.is_ascii_digit()
            || (c == '.' && self.peek2().map(|n| n.is_ascii_digit()).unwrap_or(false))
        {
            self.number()?
        } else {
            self.bump();
            match c {
                '(' => TokenKind::LParen,
                ')' => TokenKind::RParen,
                '{' => TokenKind::LBrace,
                '}' => TokenKind::RBrace,
                '[' => TokenKind::LBracket,
                ']' => TokenKind::RBracket,
                ';' => TokenKind::Semi,
                ',' => TokenKind::Comma,
                '+' => match self.peek() {
                    Some('+') => {
                        self.bump();
                        TokenKind::Incr
                    }
                    Some('=') => {
                        self.bump();
                        TokenKind::CompoundAssign('+')
                    }
                    _ => TokenKind::Plus,
                },
                '-' => match self.peek() {
                    Some('-') => {
                        self.bump();
                        TokenKind::Decr
                    }
                    Some('=') => {
                        self.bump();
                        TokenKind::CompoundAssign('-')
                    }
                    _ => TokenKind::Minus,
                },
                '*' => match self.peek() {
                    Some('=') => {
                        self.bump();
                        TokenKind::CompoundAssign('*')
                    }
                    _ => TokenKind::Star,
                },
                '/' => match self.peek() {
                    Some('=') => {
                        self.bump();
                        TokenKind::CompoundAssign('/')
                    }
                    _ => TokenKind::Slash,
                },
                '<' => match self.peek() {
                    Some('=') => {
                        self.bump();
                        TokenKind::Le
                    }
                    _ => TokenKind::Lt,
                },
                '>' => match self.peek() {
                    Some('=') => {
                        self.bump();
                        TokenKind::Ge
                    }
                    _ => TokenKind::Gt,
                },
                '=' => match self.peek() {
                    Some('=') => {
                        self.bump();
                        TokenKind::EqEq
                    }
                    _ => TokenKind::Assign,
                },
                '!' => match self.peek() {
                    Some('=') => {
                        self.bump();
                        TokenKind::Ne
                    }
                    _ => TokenKind::Bang,
                },
                '&' if self.peek() == Some('&') => {
                    self.bump();
                    TokenKind::AndAnd
                }
                '|' if self.peek() == Some('|') => {
                    self.bump();
                    TokenKind::OrOr
                }
                other => {
                    return Err(self.err(
                        "E001",
                        format!("unexpected character '{other}'"),
                        self.span_from(start),
                    ))
                }
            }
        };
        Ok(Some(Token { kind, span: self.span_from(start) }))
    }
}

/// Tokenize kernel source. Every token carries its byte span;
/// `#define NAME <literal>` lines substitute later uses of `NAME`.
pub fn lex(src: &str) -> Result<Vec<Token>, KernelError> {
    let mut lx = Lexer::new(src);
    let mut toks = Vec::new();
    while let Some(tok) = lx.next_token()? {
        toks.push(tok);
    }
    Ok(toks)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn lexes_loop_header() {
        assert_eq!(
            kinds("for (int i = 0; i < N; ++i)"),
            vec![
                TokenKind::Kw(Kw::For),
                TokenKind::LParen,
                TokenKind::Kw(Kw::Int),
                TokenKind::Ident("i".into()),
                TokenKind::Assign,
                TokenKind::Int(0),
                TokenKind::Semi,
                TokenKind::Ident("i".into()),
                TokenKind::Lt,
                TokenKind::Ident("N".into()),
                TokenKind::Semi,
                TokenKind::Incr,
                TokenKind::Ident("i".into()),
                TokenKind::RParen,
            ]
        );
    }

    #[test]
    fn tracks_spans_in_bytes_and_lines() {
        let toks = lex("a =\n  b;").unwrap();
        assert_eq!(toks[0].span, Span { start: 0, end: 1, line: 1, col: 1 });
        assert_eq!(toks[1].span, Span { start: 2, end: 3, line: 1, col: 3 });
        // 'b' sits on line 2, col 3, byte offset 6
        assert_eq!(toks[2].span, Span { start: 6, end: 7, line: 2, col: 3 });
    }

    #[test]
    fn lexes_floats_and_suffixes() {
        assert_eq!(
            kinds("0.25 1e-3 2.0f 3L"),
            vec![
                TokenKind::Float(0.25),
                TokenKind::Float(1e-3),
                TokenKind::Float(2.0),
                TokenKind::Int(3),
            ]
        );
    }

    #[test]
    fn lexes_comparison_and_logical_operators() {
        assert_eq!(
            kinds("a == b != c && d || !e"),
            vec![
                TokenKind::Ident("a".into()),
                TokenKind::EqEq,
                TokenKind::Ident("b".into()),
                TokenKind::Ne,
                TokenKind::Ident("c".into()),
                TokenKind::AndAnd,
                TokenKind::Ident("d".into()),
                TokenKind::OrOr,
                TokenKind::Bang,
                TokenKind::Ident("e".into()),
            ]
        );
    }

    #[test]
    fn skips_comments_and_unknown_directives() {
        assert_eq!(
            kinds("// line\n#include <stdio.h>\n/* block\n */ x"),
            vec![TokenKind::Ident("x".into())]
        );
    }

    #[test]
    fn define_substitutes_integer_literal() {
        let toks = lex("#define N 1024\na[N];").unwrap();
        assert_eq!(toks[2].kind, TokenKind::Int(1024));
        // the substituted token keeps the span of the use site
        assert_eq!(toks[2].span.line, 2);
        assert_eq!(toks[2].span.col, 3);
    }

    #[test]
    fn define_with_expression_value_is_ignored() {
        let toks = lex("#define N (M+1)\nN").unwrap();
        assert_eq!(toks[0].kind, TokenKind::Ident("N".into()));
    }

    #[test]
    fn rejects_unknown_character_with_span() {
        let err = lex("a @ b").unwrap_err();
        assert_eq!(err.code(), "E001");
        let span = err.diag.span.unwrap();
        assert_eq!((span.line, span.col, span.start, span.end), (1, 3, 2, 3));
    }

    #[test]
    fn rejects_unterminated_block_comment() {
        let err = lex("x /* open").unwrap_err();
        assert_eq!(err.code(), "E003");
        assert_eq!(err.diag.span.unwrap().col, 3);
    }

    #[test]
    fn spelling_is_c_source_not_debug() {
        assert_eq!(TokenKind::Kw(Kw::For).spelling(), "'for'");
        assert_eq!(TokenKind::RBracket.spelling(), "']'");
        assert_eq!(TokenKind::CompoundAssign('+').spelling(), "'+='");
        assert_eq!(TokenKind::Ident("acc".into()).spelling(), "'acc'");
    }
}
