//! Tokenizer for the restricted kernel language.

use super::KernelError;

/// A lexical token with its source position (1-based line/column).
#[derive(Debug, Clone, PartialEq)]
pub struct Token {
    pub kind: TokenKind,
    pub line: usize,
    pub col: usize,
}

/// Token kinds. Keywords are folded into [`TokenKind::Kw`].
#[derive(Debug, Clone, PartialEq)]
pub enum TokenKind {
    /// Identifier (variable / array name).
    Ident(String),
    /// Integer literal.
    Int(i64),
    /// Floating-point literal (including forms like `0.25`, `2.f`, `1e-3`).
    Float(f64),
    /// Keyword: `for`, `int`, `long`, `double`, `float`, `const`,
    /// `unsigned`, `restrict`.
    Kw(Kw),
    LParen,
    RParen,
    LBracket,
    RBracket,
    LBrace,
    RBrace,
    Semicolon,
    Comma,
    /// `=`
    Assign,
    /// `+=`, `-=`, `*=`, `/=`
    CompoundAssign(char),
    Plus,
    Minus,
    Star,
    Slash,
    /// `<`
    Lt,
    /// `<=`
    Le,
    /// `>`
    Gt,
    /// `>=`
    Ge,
    /// `++`
    Incr,
    /// `--`
    Decr,
}

/// Recognized keywords.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kw {
    For,
    Int,
    Long,
    Double,
    Float,
    Const,
    Unsigned,
    Restrict,
}

fn keyword(s: &str) -> Option<Kw> {
    Some(match s {
        "for" => Kw::For,
        "int" => Kw::Int,
        "long" => Kw::Long,
        "double" => Kw::Double,
        "float" => Kw::Float,
        "const" => Kw::Const,
        "unsigned" => Kw::Unsigned,
        "restrict" | "__restrict__" | "__restrict" => Kw::Restrict,
        _ => return None,
    })
}

/// Tokenize `src`. `//` and `/* */` comments and `#`-lines (preprocessor
/// remnants) are skipped.
pub fn lex(src: &str) -> Result<Vec<Token>, KernelError> {
    let mut out = Vec::new();
    let bytes: Vec<char> = src.chars().collect();
    let n = bytes.len();
    let mut i = 0;
    let mut line = 1usize;
    let mut col = 1usize;

    macro_rules! push {
        ($kind:expr, $len:expr) => {{
            out.push(Token { kind: $kind, line, col });
            i += $len;
            col += $len;
        }};
    }

    while i < n {
        let c = bytes[i];
        let c2 = if i + 1 < n { bytes[i + 1] } else { '\0' };
        match c {
            '\n' => {
                i += 1;
                line += 1;
                col = 1;
            }
            ' ' | '\t' | '\r' => {
                i += 1;
                col += 1;
            }
            '#' => {
                // preprocessor line: skip to end of line
                while i < n && bytes[i] != '\n' {
                    i += 1;
                }
            }
            '/' if c2 == '/' => {
                while i < n && bytes[i] != '\n' {
                    i += 1;
                }
            }
            '/' if c2 == '*' => {
                i += 2;
                col += 2;
                loop {
                    if i + 1 >= n {
                        return Err(KernelError::Lex {
                            line,
                            col,
                            msg: "unterminated block comment".into(),
                        });
                    }
                    if bytes[i] == '*' && bytes[i + 1] == '/' {
                        i += 2;
                        col += 2;
                        break;
                    }
                    if bytes[i] == '\n' {
                        line += 1;
                        col = 1;
                        i += 1;
                    } else {
                        i += 1;
                        col += 1;
                    }
                }
            }
            '(' => push!(TokenKind::LParen, 1),
            ')' => push!(TokenKind::RParen, 1),
            '[' => push!(TokenKind::LBracket, 1),
            ']' => push!(TokenKind::RBracket, 1),
            '{' => push!(TokenKind::LBrace, 1),
            '}' => push!(TokenKind::RBrace, 1),
            ';' => push!(TokenKind::Semicolon, 1),
            ',' => push!(TokenKind::Comma, 1),
            '+' if c2 == '+' => push!(TokenKind::Incr, 2),
            '-' if c2 == '-' => push!(TokenKind::Decr, 2),
            '+' if c2 == '=' => push!(TokenKind::CompoundAssign('+'), 2),
            '-' if c2 == '=' => push!(TokenKind::CompoundAssign('-'), 2),
            '*' if c2 == '=' => push!(TokenKind::CompoundAssign('*'), 2),
            '/' if c2 == '=' => push!(TokenKind::CompoundAssign('/'), 2),
            '+' => push!(TokenKind::Plus, 1),
            '-' => push!(TokenKind::Minus, 1),
            '*' => push!(TokenKind::Star, 1),
            '/' => push!(TokenKind::Slash, 1),
            '<' if c2 == '=' => push!(TokenKind::Le, 2),
            '<' => push!(TokenKind::Lt, 1),
            '>' if c2 == '=' => push!(TokenKind::Ge, 2),
            '>' => push!(TokenKind::Gt, 1),
            '=' => push!(TokenKind::Assign, 1),
            c if c.is_ascii_digit() || (c == '.' && c2.is_ascii_digit()) => {
                let start = i;
                let start_col = col;
                let mut is_float = false;
                while i < n && (bytes[i].is_ascii_digit()) {
                    i += 1;
                }
                if i < n && bytes[i] == '.' {
                    is_float = true;
                    i += 1;
                    while i < n && bytes[i].is_ascii_digit() {
                        i += 1;
                    }
                }
                if i < n && (bytes[i] == 'e' || bytes[i] == 'E') {
                    let save = i;
                    i += 1;
                    if i < n && (bytes[i] == '+' || bytes[i] == '-') {
                        i += 1;
                    }
                    if i < n && bytes[i].is_ascii_digit() {
                        is_float = true;
                        while i < n && bytes[i].is_ascii_digit() {
                            i += 1;
                        }
                    } else {
                        i = save; // not an exponent ('e' belongs to an ident? reject later)
                    }
                }
                let text: String = bytes[start..i].iter().collect();
                // float suffixes f/F/l/L (e.g. `2.f` in the long-range kernel)
                let mut suffixed = false;
                if i < n && matches!(bytes[i], 'f' | 'F' | 'l' | 'L') {
                    suffixed = true;
                    i += 1;
                }
                col = start_col + (i - start);
                if is_float || suffixed && text.contains('.') {
                    let v: f64 = text.parse().map_err(|_| KernelError::Lex {
                        line,
                        col: start_col,
                        msg: format!("bad float literal '{text}'"),
                    })?;
                    out.push(Token { kind: TokenKind::Float(v), line, col: start_col });
                } else if suffixed {
                    // e.g. `2f` — treat as float
                    let v: f64 = text.parse().map_err(|_| KernelError::Lex {
                        line,
                        col: start_col,
                        msg: format!("bad literal '{text}'"),
                    })?;
                    out.push(Token { kind: TokenKind::Float(v), line, col: start_col });
                } else {
                    let v: i64 = text.parse().map_err(|_| KernelError::Lex {
                        line,
                        col: start_col,
                        msg: format!("bad int literal '{text}'"),
                    })?;
                    out.push(Token { kind: TokenKind::Int(v), line, col: start_col });
                }
            }
            c if c.is_ascii_alphabetic() || c == '_' => {
                let start = i;
                let start_col = col;
                while i < n && (bytes[i].is_ascii_alphanumeric() || bytes[i] == '_') {
                    i += 1;
                }
                let text: String = bytes[start..i].iter().collect();
                col = start_col + (i - start);
                match keyword(&text) {
                    Some(kw) => out.push(Token { kind: TokenKind::Kw(kw), line, col: start_col }),
                    None => out.push(Token { kind: TokenKind::Ident(text), line, col: start_col }),
                }
            }
            other => {
                return Err(KernelError::Lex {
                    line,
                    col,
                    msg: format!("unexpected character '{other}'"),
                });
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<TokenKind> {
        lex(src).unwrap().into_iter().map(|t| t.kind).collect()
    }

    #[test]
    fn lexes_simple_loop() {
        let ks = kinds("for(i=0; i<N; ++i) s += a[i]*b[i];");
        assert_eq!(ks[0], TokenKind::Kw(Kw::For));
        assert!(ks.contains(&TokenKind::Incr));
        assert!(ks.contains(&TokenKind::CompoundAssign('+')));
        assert!(ks.contains(&TokenKind::Ident("a".into())));
    }

    #[test]
    fn lexes_floats_and_suffixes() {
        assert_eq!(kinds("0.25"), vec![TokenKind::Float(0.25)]);
        assert_eq!(kinds("2.f"), vec![TokenKind::Float(2.0)]);
        assert_eq!(kinds("1e-3"), vec![TokenKind::Float(1e-3)]);
        assert_eq!(kinds("1.5E2"), vec![TokenKind::Float(150.0)]);
        assert_eq!(kinds("0."), vec![TokenKind::Float(0.0)]);
    }

    #[test]
    fn lexes_ints() {
        assert_eq!(kinds("42"), vec![TokenKind::Int(42)]);
        assert_eq!(
            kinds("a[5000]"),
            vec![
                TokenKind::Ident("a".into()),
                TokenKind::LBracket,
                TokenKind::Int(5000),
                TokenKind::RBracket
            ]
        );
    }

    #[test]
    fn skips_comments_and_preprocessor() {
        let ks = kinds("// comment\n#define X 1\n/* block\n comment */ x");
        assert_eq!(ks, vec![TokenKind::Ident("x".into())]);
    }

    #[test]
    fn compound_operators() {
        assert_eq!(kinds("<="), vec![TokenKind::Le]);
        assert_eq!(kinds("<"), vec![TokenKind::Lt]);
        assert_eq!(kinds("-="), vec![TokenKind::CompoundAssign('-')]);
        assert_eq!(kinds("--"), vec![TokenKind::Decr]);
    }

    #[test]
    fn restrict_variants_fold_to_keyword() {
        assert_eq!(kinds("restrict"), vec![TokenKind::Kw(Kw::Restrict)]);
        assert_eq!(kinds("__restrict__"), vec![TokenKind::Kw(Kw::Restrict)]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(lex("a @ b").is_err());
        assert!(lex("/* unterminated").is_err());
    }

    #[test]
    fn tracks_line_numbers() {
        let toks = lex("a\nb\n  c").unwrap();
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[1].line, 2);
        assert_eq!(toks[2].line, 3);
        assert_eq!(toks[2].col, 3);
    }
}
