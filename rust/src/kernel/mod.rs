//! Restricted-C99 kernel language frontend (paper §4.3, DESIGN.md §3).
//!
//! Kerncraft analyzes loop kernels written in a small C dialect:
//! declarations of scalars and fixed-size arrays followed by a single
//! `for`-loop nest whose innermost body is a sequence of assignment
//! statements, optionally wrapped in conditionals and compound blocks.
//! Array sizes may use symbolic constants (bound on the command line
//! via `-D NAME VALUE` or with `#define NAME VALUE` in the source)
//! with an optional `±integer`, and array indices must be
//! `loop_var ± integer`, a constant, or a fixed integer — exactly the
//! restrictions the paper states.
//!
//! The frontend is a staged pipeline (DESIGN.md §3); every token and
//! surface-AST node carries a byte-[`Span`] so each stage can point at
//! the exact source it rejected:
//!
//! * [`lexer`] — bytes → spanned tokens (plus `#define` substitution),
//! * [`syntax`] — the span-carrying surface AST,
//! * [`parser`] — tokens → surface AST (recursive descent),
//! * [`lower`] — surface AST → the analysis IR in [`ast`] (condition
//!   guards, cast erasure, `<=`/flipped-bound normalization),
//! * [`ast`] — the lowered loop-nest IR the models consume,
//! * [`analysis`] — static analysis: loop stack (Table 2), data sources
//!   and destinations (Tables 3/4), flop counts, and the linearized
//!   (1D) access representation that feeds the cache predictor (§4.5),
//! * [`diag`] — the structured [`Diagnostic`] every stage reports
//!   failures through.

pub mod analysis;
pub mod ast;
pub mod diag;
pub mod lexer;
pub mod lower;
pub mod parser;
pub mod syntax;

pub use analysis::{
    AccessPattern, ArrayInfo, DimAccess, FlopCount, KernelAnalysis, LinearAccess, LoopInfo,
    ScalarUse,
};
pub use ast::{AssignOp, BinOp, Expr, Program, Stmt, Type};
pub use diag::{Diagnostic, Severity, Span};
pub use parser::parse;

/// The error type of the whole kernel frontend: a [`Diagnostic`] with
/// a stable code, severity, message, optional span/snippet/hint.
///
/// `Display` is the diagnostic's single-line form (so it embeds
/// cleanly in the JSON-lines serve error strings); front ends that
/// want the caret-rendered block downcast through `anyhow` and call
/// [`Diagnostic::render`] on [`KernelError::diag`].
#[derive(Debug, Clone, PartialEq)]
pub struct KernelError {
    pub diag: Diagnostic,
}

impl KernelError {
    /// Stable error code of the underlying diagnostic.
    pub fn code(&self) -> &'static str {
        self.diag.code
    }

    /// E200: the source violates one of the paper's §4.3 restrictions.
    pub fn restriction(msg: impl Into<String>) -> KernelError {
        Diagnostic::error("E200", msg).into()
    }

    /// E201: a symbolic constant was not bound via `-D`/`#define`.
    pub fn unbound_constant(name: &str) -> KernelError {
        Diagnostic::error("E201", format!("unbound constant '{name}'"))
            .with_hint(format!("pass -D {name} <value> or add '#define {name} <value>'"))
            .into()
    }

    /// E202: semantic inconsistency (e.g. use of an undeclared array).
    pub fn semantic(msg: impl Into<String>) -> KernelError {
        Diagnostic::error("E202", msg).into()
    }
}

impl From<Diagnostic> for KernelError {
    fn from(diag: Diagnostic) -> KernelError {
        KernelError { diag }
    }
}

impl std::fmt::Display for KernelError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        self.diag.fmt(f)
    }
}

impl std::error::Error for KernelError {}
