//! Restricted-C99 kernel language frontend (paper §4.3).
//!
//! Kerncraft analyzes loop kernels written in a small C dialect:
//! declarations of scalars and fixed-size arrays followed by a single
//! `for`-loop nest whose innermost body is a sequence of assignment
//! statements. Array sizes may use symbolic constants (bound on the
//! command line via `-D NAME VALUE`) with an optional `±integer`, and
//! array indices must be `loop_var ± integer`, a constant, or a fixed
//! integer — exactly the restrictions the paper states.
//!
//! The module is split conventionally:
//! * [`lexer`] — tokenizer,
//! * [`ast`] — syntax tree,
//! * [`parser`] — recursive-descent parser,
//! * [`analysis`] — static analysis: loop stack (Table 2), data sources
//!   and destinations (Tables 3/4), flop counts, and the linearized
//!   (1D) access representation that feeds the cache predictor (§4.5).

pub mod analysis;
pub mod ast;
pub mod lexer;
pub mod parser;

pub use analysis::{
    AccessPattern, ArrayInfo, DimAccess, FlopCount, KernelAnalysis, LinearAccess, LoopInfo,
    ScalarUse,
};
pub use ast::{AssignOp, BinOp, Expr, Program, Stmt, Type};
pub use parser::parse;

use thiserror::Error;

/// Errors produced anywhere in the kernel frontend.
#[derive(Debug, Error)]
pub enum KernelError {
    /// Tokenizer rejected a character or malformed literal.
    #[error("lex error at line {line}, col {col}: {msg}")]
    Lex { line: usize, col: usize, msg: String },
    /// Parser rejected the token stream.
    #[error("parse error at line {line}, col {col}: {msg}")]
    Parse { line: usize, col: usize, msg: String },
    /// Source violates one of the paper's §4.3 restrictions.
    #[error("unsupported kernel construct: {0}")]
    Restriction(String),
    /// A symbolic constant was not bound via `-D`.
    #[error("unbound constant '{0}' (pass -D {0} <value>)")]
    UnboundConstant(String),
    /// Semantic inconsistency (e.g. use of an undeclared array).
    #[error("semantic error: {0}")]
    Semantic(String),
}
