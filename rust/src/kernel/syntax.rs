//! Span-carrying surface AST (DESIGN.md §3, stage 2).
//!
//! This tree mirrors what the user actually wrote — casts, compound
//! blocks, conditionals, comparison/logical expressions, non-canonical
//! loop bounds — before [`super::lower`] normalizes it into the
//! restricted IR in [`super::ast`] that the analysis consumes. Every
//! node keeps the byte [`Span`] of the source it came from so lowering
//! and analysis can attach exact locations to their diagnostics.

use super::ast::{AssignOp, BinOp, Type};
use super::diag::Span;

/// A whole kernel: declarations followed by one loop nest.
#[derive(Debug, Clone, PartialEq)]
pub struct Unit {
    pub decls: Vec<SDecl>,
    pub nest: SLoop,
}

/// A floating-point scalar or array declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct SDecl {
    pub name: String,
    pub ty: Type,
    /// One expression per array dimension (empty for scalars). An
    /// unsized dimension `a[]` is recorded as the `__unbounded__`
    /// variable, matching the lowered IR convention.
    pub dims: Vec<SExpr>,
    /// Literal initializer, when present (`double s = 0.25;`).
    pub init: Option<f64>,
    pub span: Span,
}

/// Comparison direction of a loop bound, already normalized so the
/// loop index is on the left (`N > i` parses as `i < N`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpDir {
    /// `i < bound`
    Lt,
    /// `i <= bound`
    Le,
}

/// A `for` loop with its header clauses still in surface form.
#[derive(Debug, Clone, PartialEq)]
pub struct SLoop {
    pub index: String,
    pub start: SExpr,
    pub cmp: CmpDir,
    pub bound: SExpr,
    /// Increment per iteration (`++i` and `i++` record `1`; `i += s`
    /// and `i = i + s` record `s`). Positivity is checked at analysis
    /// time once constants are bound.
    pub step: SExpr,
    pub body: Vec<SItem>,
    pub span: Span,
}

/// One item of a loop (or block/branch) body.
#[derive(Debug, Clone, PartialEq)]
pub enum SItem {
    Loop(SLoop),
    If(SIf),
    Assign(SAssign),
    /// A braced compound statement; flattened during lowering.
    Block(Vec<SItem>),
}

/// An `if`/`else` conditional.
#[derive(Debug, Clone, PartialEq)]
pub struct SIf {
    pub cond: SExpr,
    pub then_items: Vec<SItem>,
    pub else_items: Vec<SItem>,
    pub span: Span,
}

/// An assignment statement `lhs op= rhs;`.
#[derive(Debug, Clone, PartialEq)]
pub struct SAssign {
    pub lhs: SExpr,
    pub op: AssignOp,
    pub rhs: SExpr,
    pub span: Span,
}

/// Comparison operators (only valid in condition positions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpOp {
    Lt,
    Le,
    Gt,
    Ge,
    Eq,
    Ne,
}

/// Short-circuit logical operators.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LogicalOp {
    And,
    Or,
}

/// A surface expression with its source span.
#[derive(Debug, Clone, PartialEq)]
pub struct SExpr {
    pub kind: SExprKind,
    pub span: Span,
}

#[derive(Debug, Clone, PartialEq)]
pub enum SExprKind {
    Int(i64),
    Float(f64),
    Var(String),
    Index { array: String, indices: Vec<SExpr> },
    Binary { op: BinOp, lhs: Box<SExpr>, rhs: Box<SExpr> },
    Neg(Box<SExpr>),
    /// A C cast `(double)x` / `(real)x`; erased during lowering (the
    /// analysis models data movement by declared type, paper §4.3).
    Cast { ty: String, expr: Box<SExpr> },
    /// Comparison — only meaningful inside `if` conditions.
    Cmp { op: CmpOp, lhs: Box<SExpr>, rhs: Box<SExpr> },
    /// `&&` / `||` — only meaningful inside `if` conditions.
    Logical { op: LogicalOp, lhs: Box<SExpr>, rhs: Box<SExpr> },
    /// `!cond` — only meaningful inside `if` conditions.
    Not(Box<SExpr>),
}

impl SExpr {
    pub fn new(kind: SExprKind, span: Span) -> SExpr {
        SExpr { kind, span }
    }
}
